//! Theorem 2.1 in action: leverage-score sampling for Nonnegative Least
//! Squares. Builds random overdetermined NLS instances, solves them
//! exactly (BPP) and via leverage-score sketching at several sample
//! sizes, and prints the observed error against the theorem's bound
//! √ε·‖r‖/σ_min(A). Also demonstrates the hybrid scheme (§4.2) on a
//! coherent (spiked-leverage) design where uniform sampling fails.
//!
//!     cargo run --release --example nls_sampling

use symnmf::linalg::{blas, eig, qr, DenseMat};
use symnmf::nls::bpp;
use symnmf::randnla::leverage::{sample_hybrid, sample_standard, theorem21_sample_count};
use symnmf::util::rng::Pcg64;

fn solve_nls(a: &DenseMat, b: &[f64]) -> Vec<f64> {
    let g = blas::gram(a);
    let k = a.cols();
    let y: Vec<f64> = (0..k)
        .map(|j| (0..a.rows()).map(|i| a.at(i, j) * b[i]).sum())
        .collect();
    bpp::solve_row(&g, &y, 300)
}

fn main() {
    let mut rng = Pcg64::seed_from_u64(1);
    let (m, k) = (20_000, 6);

    // --- incoherent Gaussian design -------------------------------------
    let a = DenseMat::gaussian(m, k, &mut rng);
    let x_true: Vec<f64> = (0..k).map(|_| rng.uniform()).collect();
    let b: Vec<f64> = (0..m)
        .map(|i| {
            let mut s = 0.0;
            for j in 0..k {
                s += a.at(i, j) * x_true[j];
            }
            s + 0.5 * rng.gaussian()
        })
        .collect();

    let x_nls = solve_nls(&a, &b);
    let r_norm = {
        let mut acc = 0.0;
        for i in 0..m {
            let mut p = 0.0;
            for j in 0..k {
                p += a.at(i, j) * x_nls[j];
            }
            acc += (p - b[i]) * (p - b[i]);
        }
        acc.sqrt()
    };
    let sigma_min = *eig::singular_values(&a).last().unwrap();
    let lev = qr::leverage_scores(&a);

    println!("NLS instance: A {m}x{k}, ‖r_nls‖ = {r_norm:.2}, σ_min = {sigma_min:.2}");
    println!("Theorem 2.1 count for (δ=0.1, ε=0.5): s = {}", theorem21_sample_count(k, 0.1, 0.5));
    println!("\n  s        ‖x̂−x‖      bound √ε‖r‖/σ_min (ε=0.5)");
    let bound = 0.5f64.sqrt() * r_norm / sigma_min;
    for s in [100, 400, 1600, 6400] {
        let mut errs = Vec::new();
        for _ in 0..5 {
            let sm = sample_standard(&lev, s, &mut rng);
            let sa = a.gather_rows_scaled(&sm.indices, &sm.scales);
            let sb: Vec<f64> = sm
                .indices
                .iter()
                .zip(&sm.scales)
                .map(|(&i, &c)| c * b[i])
                .collect();
            let x_hat = solve_nls(&sa, &sb);
            let err: f64 = x_hat
                .iter()
                .zip(&x_nls)
                .map(|(p, q)| (p - q) * (p - q))
                .sum::<f64>()
                .sqrt();
            errs.push(err);
        }
        errs.sort_by(|p, q| p.partial_cmp(q).unwrap());
        println!("  {s:<8} {:.4} (median of 5)   {bound:.4}", errs[2]);
    }

    // --- coherent design: hybrid vs pure sampling ------------------------
    println!("\n== spiked-leverage design: hybrid (τ=1/s) vs standard ==");
    let mut a2 = DenseMat::gaussian(m, k, &mut rng);
    for j in 0..k {
        a2.set(17, j, 300.0 * (j as f64 + 1.0));
        a2.set(4242, j, -250.0 * (j as f64 + 0.5));
    }
    let b2: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
    let x2 = solve_nls(&a2, &b2);
    let lev2 = qr::leverage_scores(&a2);
    let s = 800;
    let mut err_std = Vec::new();
    let mut err_hyb = Vec::new();
    for _ in 0..7 {
        for (errs, hybrid) in [(&mut err_std, false), (&mut err_hyb, true)] {
            let sm = if hybrid {
                sample_hybrid(&lev2, s, 1.0 / s as f64, &mut rng)
            } else {
                sample_standard(&lev2, s, &mut rng)
            };
            let sa = a2.gather_rows_scaled(&sm.indices, &sm.scales);
            let sb: Vec<f64> = sm
                .indices
                .iter()
                .zip(&sm.scales)
                .map(|(&i, &c)| c * b2[i])
                .collect();
            let x_hat = solve_nls(&sa, &sb);
            let err: f64 = x_hat
                .iter()
                .zip(&x2)
                .map(|(p, q)| (p - q) * (p - q))
                .sum::<f64>()
                .sqrt();
            errs.push(err);
        }
    }
    let med = |v: &mut Vec<f64>| {
        v.sort_by(|p, q| p.partial_cmp(q).unwrap());
        v[v.len() / 2]
    };
    println!("  standard sampling median error: {:.4}", med(&mut err_std));
    println!("  hybrid   sampling median error: {:.4}", med(&mut err_hyb));
    println!("(hybrid deterministically includes the spiked rows — §4.2)");
}
