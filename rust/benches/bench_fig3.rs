//! Regenerates paper **Figure 3** (§5.2): per-iteration time breakdown —
//! Matrix Multiplication / Solve / Sampling — for HALS, LvS-HALS and
//! LvS-BPP on the sparse workload.
//!
//! Shape to reproduce: leverage-score sampling collapses the MM bar while
//! adding an acceptable Sampling bar; for BPP the Solve bar dominates and
//! caps the end-to-end gain at ~50% (§5.2).
//!
//!     cargo bench --bench bench_fig3
//! writes results/fig3_breakdown.txt

use symnmf::coordinator::driver::Method;
use symnmf::coordinator::experiments::{fig3_methods, oag_options, oag_workload};
use symnmf::coordinator::report;

fn main() {
    let m = std::env::var("SYMNMF_BENCH_M")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    println!("== Fig. 3 bench: time breakdown on OAG sparse workload (m={m}) ==");
    let g = oag_workload(m, 3);
    let mut opts = oag_options().with_seed(30);
    opts.max_iters = 25;
    opts.patience = 1000; // plot the full horizon (paper's Figs. show complete curves)

    let methods: Vec<Method> = fig3_methods();
    let mut results = Vec::new();
    for method in methods {
        let res = method.run(&g.adj, &opts);
        println!("  {:<22} {} iters in {:.2}s", res.label, res.iters(), res.total_secs());
        results.push(res);
    }
    let refs: Vec<&symnmf::symnmf::SymNmfResult> = results.iter().collect();
    let table = report::time_breakdown_table(&refs);
    println!("\n{table}");

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig3_breakdown.txt", &table).unwrap();
    println!("wrote results/fig3_breakdown.txt");
}
