"""Pure-jnp oracles for the Pallas kernels — the correctness reference.

Every kernel in this package must match its oracle to float32 tolerance
across the hypothesis shape sweep in ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, f: jax.Array) -> jax.Array:
    """Reference for kernels.matmul: plain x @ f."""
    return jnp.dot(x, f, preferred_element_type=x.dtype)


def gram(f: jax.Array) -> jax.Array:
    """Reference for kernels.gram: fᵀ @ f."""
    return jnp.dot(f.T, f, preferred_element_type=f.dtype)


def products(x: jax.Array, f: jax.Array):
    """Reference for model.products."""
    return matmul(x, f), gram(f)


def lai_products(u: jax.Array, v: jax.Array, f: jax.Array):
    """Reference for model.lai_products: (U(VᵀF), FᵀF)."""
    return jnp.dot(u, jnp.dot(v.T, f)), gram(f)


def hals_sweep(xh: jax.Array, g: jax.Array, w: jax.Array, h: jax.Array,
               alpha: jax.Array) -> jax.Array:
    """Reference for model.hals_sweep — literal sequential loop over columns
    of the regularized symmetric HALS update (paper Eq. 2.6):

        w_i ← [ ((XH)_i − W·G_i + α h_i) / (G_ii + α)
                + (G_ii / (G_ii + α)) w_i ]_+
    """
    k = w.shape[1]
    w = jnp.asarray(w)
    for i in range(k):
        denom = g[i, i] + alpha
        numer = xh[:, i] - w @ g[:, i] + alpha * h[:, i]
        wi = numer / denom + (g[i, i] / denom) * w[:, i]
        w = w.at[:, i].set(jnp.maximum(wi, 0.0))
    return w
