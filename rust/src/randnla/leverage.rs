//! Leverage-score sampling matrices (paper Eq. 2.11) and the **hybrid**
//! deterministic + randomized scheme of §4.2 / Eq. 4.2–4.3 that the paper
//! shows is crucial for speedups in practice (§5.2, Fig. 2).
//!
//! A sampling matrix is stored implicitly as (row indices, rescale
//! factors): S·A is a scaled row gather, never a matmul.

use crate::linalg::workspace::SampleWorkspace;
use crate::util::rng::{AliasTable, Pcg64};

/// Implicit row-sampling-and-rescaling matrix S ∈ R^{s×m}.
#[derive(Clone, Debug)]
pub struct SampleMatrix {
    /// source row index i_r of each sample row
    pub indices: Vec<usize>,
    /// rescale factor c_r (1/√(s·p_i) for random rows, 1 for
    /// deterministically included rows)
    pub scales: Vec<f64>,
    /// squared scales c_r², cached at construction (read twice per LvS
    /// iteration — once per half-step's `sampled_apply_into`)
    weights_sq: Vec<f64>,
    /// number of deterministically included rows (they come first)
    pub num_deterministic: usize,
    /// leverage mass θ = Σ_{i∈deterministic} l_i captured deterministically
    pub theta: f64,
}

impl SampleMatrix {
    /// Assemble from indices/scales, caching the squared scales.
    pub fn new(
        indices: Vec<usize>,
        scales: Vec<f64>,
        num_deterministic: usize,
        theta: f64,
    ) -> SampleMatrix {
        let weights_sq = scales.iter().map(|c| c * c).collect();
        SampleMatrix { indices, scales, weights_sq, num_deterministic, theta }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Squared scales c_r², the weights of X·SᵀS·F accumulation —
    /// computed once at construction, borrowed (not re-allocated) per
    /// call.
    pub fn weights_sq(&self) -> &[f64] {
        &self.weights_sq
    }

    /// Fraction of samples taken deterministically (paper Fig. 6a).
    pub fn deterministic_fraction(&self) -> f64 {
        if self.indices.is_empty() {
            0.0
        } else {
            self.num_deterministic as f64 / self.indices.len() as f64
        }
    }
}

/// Standard leverage-score sampling (Eq. 2.11): draw `s` rows i.i.d. with
/// replacement with p_i = l_i / Σl, rescale by 1/√(s·p_i).
///
/// The normalizer Σl is read from the alias table's cached total
/// ([`AliasTable::total`], bitwise-identical to a re-sum), so this
/// per-iteration call makes ONE pass over the leverage vector (the table
/// build) instead of two.
pub fn sample_standard(leverage: &[f64], s: usize, rng: &mut Pcg64) -> SampleMatrix {
    let table = AliasTable::new(leverage); // asserts Σl > 0
    let total = table.total();
    let indices = table.sample_many(rng, s);
    let scales = indices
        .iter()
        .map(|&i| {
            let p = leverage[i] / total;
            1.0 / (s as f64 * p).sqrt()
        })
        .collect();
    SampleMatrix::new(indices, scales, 0, 0.0)
}

/// Hybrid sampling (§4.2): rows with normalized leverage p_i = l_i/k ≥ τ
/// are included deterministically (scale 1, a pure permutation block
/// Eq. 4.3); the remaining budget s_R = s − s_D is drawn from the leftover
/// rows with renormalized probabilities p̃_i = l_i / ξ, ξ = k − θ
/// (rescale 1/√(s_R·p̃_i)).
///
/// τ = 1 reduces to pure random sampling (no row reaches p_i ≥ 1 unless it
/// carries *all* the mass); the paper's sparse experiments use τ = 1/s.
pub fn sample_hybrid(
    leverage: &[f64],
    s: usize,
    tau: f64,
    rng: &mut Pcg64,
) -> SampleMatrix {
    // Σ l_i = rank (= k for full-rank F): read from the alias table's
    // cached normalizer instead of a separate pass. When no row crosses
    // the deterministic threshold (e.g. τ = 1 — the residual weights
    // equal the leverage vector) the table is reused for the random
    // draws, so that common path builds and sums the vector exactly once.
    let table_all = AliasTable::new(leverage); // asserts Σ l_i > 0
    let k = table_all.total();
    let mut det: Vec<usize> = Vec::new();
    let mut theta = 0.0;
    for (i, &l) in leverage.iter().enumerate() {
        if l / k >= tau {
            det.push(i);
            theta += l;
        }
    }
    // Never spend the whole budget deterministically: keep at least one
    // random slot unless every row is deterministic.
    if det.len() >= s && s > 0 {
        // keep the top (s-1) by leverage
        det.sort_by(|&a, &b| leverage[b].partial_cmp(&leverage[a]).unwrap());
        det.truncate(s.saturating_sub(1));
        theta = det.iter().map(|&i| leverage[i]).sum();
    }
    let s_d = det.len();
    let s_r = s - s_d;

    let mut indices = det.clone();
    let mut scales = vec![1.0; s_d];

    if s_r > 0 {
        let xi: f64 = k - theta;
        if det.is_empty() {
            // no deterministic rows: the residual distribution IS the
            // leverage distribution (θ = 0, ξ = k) — reuse the table
            // built for the normalizer.
            if xi > 1e-300 {
                for _ in 0..s_r {
                    let i = table_all.sample(rng);
                    let p = leverage[i] / xi; // renormalized p̃_i
                    indices.push(i);
                    scales.push(1.0 / (s_r as f64 * p).sqrt());
                }
            }
        } else {
            let in_det: std::collections::HashSet<usize> = det.iter().copied().collect();
            // residual weights over the non-deterministic rows
            let mut resid = leverage.to_vec();
            for &i in &in_det {
                resid[i] = 0.0;
            }
            if xi > 1e-300 && resid.iter().any(|&w| w > 0.0) {
                let table = AliasTable::new(&resid);
                for _ in 0..s_r {
                    let i = table.sample(rng);
                    let p = leverage[i] / xi; // renormalized p̃_i
                    indices.push(i);
                    scales.push(1.0 / (s_r as f64 * p).sqrt());
                }
            }
        }
    }
    SampleMatrix::new(indices, scales, s_d, theta)
}

/// [`sample_hybrid`] over the workspace's leverage buffer
/// (`ws.leverage`), writing the draw into the persistent
/// `ws.indices`/`ws.scales`/`ws.weights_sq` buffers — zero heap
/// allocation once the alias table is warm. Returns
/// `(num_deterministic, theta)`.
///
/// The control flow and, critically, the **RNG draw sequence** are
/// identical to the allocating form (alias-table construction consumes
/// no randomness; each random slot is exactly one `below` + one
/// `uniform`), so a solver switched to this path resumes existing
/// checkpoints bitwise. Differences are bookkeeping-only: the
/// normalizer k = Σ l_i is summed directly (same left-to-right order as
/// the table's cached total), and the residual zeroing iterates the
/// deterministic list instead of hashing it.
pub fn sample_hybrid_ws(
    s: usize,
    tau: f64,
    rng: &mut Pcg64,
    ws: &mut SampleWorkspace,
) -> (usize, f64) {
    assert!(!ws.leverage.is_empty());
    let k: f64 = ws.leverage.iter().sum();
    assert!(k > 0.0, "alias table needs positive total weight");
    ws.det.clear();
    let mut theta = 0.0;
    for (i, &l) in ws.leverage.iter().enumerate() {
        if l / k >= tau {
            ws.det.push(i);
            theta += l;
        }
    }
    // Never spend the whole budget deterministically: keep at least one
    // random slot unless every row is deterministic.
    if ws.det.len() >= s && s > 0 {
        // keep the top (s-1) by leverage
        let lev = &ws.leverage;
        ws.det.sort_by(|&a, &b| lev[b].partial_cmp(&lev[a]).unwrap());
        ws.det.truncate(s.saturating_sub(1));
        theta = ws.det.iter().map(|&i| lev[i]).sum();
    }
    let s_d = ws.det.len();
    let s_r = s - s_d;

    ws.indices.clear();
    ws.indices.extend_from_slice(&ws.det);
    ws.scales.clear();
    ws.scales.resize(s_d, 1.0);

    if s_r > 0 {
        let xi: f64 = k - theta;
        if ws.det.is_empty() {
            // no deterministic rows: the residual distribution IS the
            // leverage distribution (θ = 0, ξ = k).
            if xi > 1e-300 {
                ws.table.rebuild(&ws.leverage);
                for _ in 0..s_r {
                    let i = ws.table.sample(rng);
                    let p = ws.leverage[i] / xi; // renormalized p̃_i
                    ws.indices.push(i);
                    ws.scales.push(1.0 / (s_r as f64 * p).sqrt());
                }
            }
        } else {
            // residual weights over the non-deterministic rows
            ws.resid.clear();
            ws.resid.extend_from_slice(&ws.leverage);
            for &i in &ws.det {
                ws.resid[i] = 0.0;
            }
            if xi > 1e-300 && ws.resid.iter().any(|&w| w > 0.0) {
                ws.table.rebuild(&ws.resid);
                for _ in 0..s_r {
                    let i = ws.table.sample(rng);
                    let p = ws.leverage[i] / xi; // renormalized p̃_i
                    ws.indices.push(i);
                    ws.scales.push(1.0 / (s_r as f64 * p).sqrt());
                }
            }
        }
    }
    ws.weights_sq.clear();
    ws.weights_sq.extend(ws.scales.iter().map(|c| c * c));
    (s_d, theta)
}

/// Number of samples Theorem 2.1 prescribes:
/// s ≥ k·max(C·log(k/δ), 1/(δ·ε_r)), C = 144/(1−√2)².
pub fn theorem21_sample_count(k: usize, delta: f64, eps_r: f64) -> usize {
    let c = 144.0 / (1.0 - std::f64::consts::SQRT_2).powi(2);
    let kf = k as f64;
    (kf * (c * (kf / delta).ln()).max(1.0 / (delta * eps_r))).ceil() as usize
}

/// Hybrid-sampling budget from Lemma 4.2/4.3 discussion: s_D + ξ·φ with
/// φ = max(C·log(k/δ), 1/(δ·ε_r)) — vs k·φ for standard sampling.
pub fn hybrid_sample_count(s_d: usize, xi: f64, k: usize, delta: f64, eps_r: f64) -> usize {
    let c = 144.0 / (1.0 - std::f64::consts::SQRT_2).powi(2);
    let phi = (c * ((k as f64) / delta).ln()).max(1.0 / (delta * eps_r));
    s_d + (xi * phi).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, qr, DenseMat};

    fn orthonormal(m: usize, k: usize, rng: &mut Pcg64) -> DenseMat {
        let f = DenseMat::gaussian(m, k, rng);
        qr::householder_qr(&f).0
    }

    /// SC1 sanity: with many samples, (SQ)ᵀ(SQ) ≈ I for orthonormal Q.
    #[test]
    fn standard_sampling_preserves_gram() {
        let mut rng = Pcg64::seed_from_u64(1);
        let q = orthonormal(400, 4, &mut rng);
        let lev = qr::leverage_scores_from_q(&q);
        let s = 2000;
        let sm = sample_standard(&lev, s, &mut rng);
        let sq = q.gather_rows_scaled(&sm.indices, &sm.scales);
        let g = blas::gram(&sq);
        let err = g.diff_fro(&DenseMat::eye(4));
        assert!(err < 0.25, "‖(SQ)ᵀSQ − I‖ = {err}");
    }

    #[test]
    fn hybrid_sampling_preserves_gram_with_spiked_rows() {
        let mut rng = Pcg64::seed_from_u64(2);
        // matrix with a few huge-leverage rows
        let mut f = DenseMat::gaussian(500, 4, &mut rng);
        for j in 0..4 {
            f.set(13, j, 40.0 * ((j + 1) as f64));
            f.set(99, j, -35.0 * ((j + 2) as f64));
        }
        let (q, _) = qr::householder_qr(&f);
        let lev = qr::leverage_scores_from_q(&q);
        let s = 1500;
        let sm = sample_hybrid(&lev, s, 1.0 / s as f64, &mut rng);
        assert!(sm.num_deterministic >= 2, "spiked rows should be deterministic");
        assert!(sm.indices[..sm.num_deterministic].contains(&13));
        let sq = q.gather_rows_scaled(&sm.indices, &sm.scales);
        let err = blas::gram(&sq).diff_fro(&DenseMat::eye(4));
        assert!(err < 0.25, "hybrid gram err {err}");
    }

    #[test]
    fn tau_one_is_pure_random() {
        let mut rng = Pcg64::seed_from_u64(3);
        let q = orthonormal(200, 3, &mut rng);
        let lev = qr::leverage_scores_from_q(&q);
        let sm = sample_hybrid(&lev, 50, 1.0, &mut rng);
        assert_eq!(sm.num_deterministic, 0);
        assert_eq!(sm.theta, 0.0);
        assert_eq!(sm.len(), 50);
    }

    #[test]
    fn sampling_matrix_is_unbiased_for_gram() {
        // E[(SQ)ᵀSQ] = QᵀQ: check the Monte-Carlo average over repeats.
        let mut rng = Pcg64::seed_from_u64(4);
        let q = orthonormal(100, 3, &mut rng);
        let lev = qr::leverage_scores_from_q(&q);
        let mut acc = DenseMat::zeros(3, 3);
        let reps = 300;
        for _ in 0..reps {
            let sm = sample_standard(&lev, 20, &mut rng);
            let sq = q.gather_rows_scaled(&sm.indices, &sm.scales);
            acc.axpy(1.0 / reps as f64, &blas::gram(&sq));
        }
        let err = acc.diff_fro(&DenseMat::eye(3));
        assert!(err < 0.1, "bias {err}");
    }

    #[test]
    fn deterministic_budget_never_exceeds_s() {
        let mut rng = Pcg64::seed_from_u64(5);
        // every row has identical leverage → τ tiny would select all
        let lev = vec![0.01; 300];
        let sm = sample_hybrid(&lev, 10, 1e-9, &mut rng);
        assert!(sm.len() <= 10);
        assert!(sm.num_deterministic < 10);
    }

    /// The workspace sampler reproduces the allocating sampler exactly —
    /// indices, scales, cached squared weights, stats, AND the RNG
    /// end-state (same draw count) — across every control-flow regime:
    /// pure random (τ = 1), hybrid with deterministic rows, and the
    /// deterministic-budget guard. One warm workspace is reused across
    /// all regimes to pin buffer-reuse transparency.
    #[test]
    fn sample_hybrid_ws_matches_allocating_bitwise() {
        let mut rng = Pcg64::seed_from_u64(6);
        let q = orthonormal(300, 4, &mut rng);
        let mut lev = qr::leverage_scores_from_q(&q);
        // spike two rows so the hybrid regime has deterministic picks
        lev[13] += 2.0;
        lev[99] += 1.5;
        let uniform = vec![0.01; 300];
        let mut ws = SampleWorkspace::new(300, 4, 64);
        for (weights, s, tau) in [
            (&lev, 64usize, 1.0),          // pure random
            (&lev, 64, 1.0 / 64.0),        // hybrid
            (&uniform, 10, 1e-9),          // budget guard: all rows cross τ
            (&lev, 64, 1.0 / 64.0),        // reuse after shrink
        ] {
            let mut rng_a = Pcg64::seed_from_u64(777);
            let mut rng_b = Pcg64::seed_from_u64(777);
            let sm = sample_hybrid(weights, s, tau, &mut rng_a);
            ws.leverage.clear();
            ws.leverage.extend_from_slice(weights);
            let (nd, theta) = sample_hybrid_ws(s, tau, &mut rng_b, &mut ws);
            assert_eq!(sm.indices, ws.indices, "s={s} tau={tau}");
            assert_eq!(sm.num_deterministic, nd);
            assert_eq!(sm.theta.to_bits(), theta.to_bits());
            assert_eq!(sm.scales.len(), ws.scales.len());
            for (a, b) in sm.scales.iter().zip(&ws.scales) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in sm.weights_sq().iter().zip(&ws.weights_sq) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(rng_a.state(), rng_b.state(), "draw sequences must match");
        }
    }

    /// weights_sq is cached at construction and equals the squares of
    /// the scales (the former per-call allocation).
    #[test]
    fn weights_sq_is_cached_square_of_scales() {
        let mut rng = Pcg64::seed_from_u64(7);
        let q = orthonormal(100, 3, &mut rng);
        let lev = qr::leverage_scores_from_q(&q);
        let sm = sample_standard(&lev, 30, &mut rng);
        let p1 = sm.weights_sq().as_ptr();
        let p2 = sm.weights_sq().as_ptr();
        assert_eq!(p1, p2, "repeated calls must borrow the same buffer");
        for (w, c) in sm.weights_sq().iter().zip(&sm.scales) {
            assert_eq!(w.to_bits(), (c * c).to_bits());
        }
    }

    #[test]
    fn theorem21_counts_monotone() {
        let base = theorem21_sample_count(8, 0.1, 0.5);
        // ε only matters once 1/(δε) exceeds C·log(k/δ) (the max)
        assert!(theorem21_sample_count(8, 0.1, 0.1) >= base);
        assert!(theorem21_sample_count(8, 0.01, 0.5) > base, "smaller delta → more samples");
        assert!(theorem21_sample_count(16, 0.1, 0.5) > base, "larger k → more samples");
        // regime where the 1/(δε) branch dominates: tiny δ·ε
        let tight = theorem21_sample_count(8, 0.01, 0.001);
        let loose = theorem21_sample_count(8, 0.01, 0.01);
        assert!(tight > loose, "ε-dominated regime must be monotone in ε");
    }

    #[test]
    fn hybrid_count_beats_standard_when_theta_large() {
        // θ = k−ξ large (deterministic rows grab most mass) with small s_D
        let k = 16;
        let std_count = theorem21_sample_count(k, 0.1, 0.5);
        let hyb = hybrid_sample_count(40, 1.0, k, 0.1, 0.5); // ξ=1, s_D=40
        assert!(
            hyb < std_count,
            "hybrid {hyb} should beat standard {std_count}"
        );
    }
}
