//! Hierarchical Alternating Least Squares column updates.
//!
//! In Update(G, Y) form (App. E) the regularized symmetric HALS rule of
//! paper Eq. 2.6 reduces to the classic rule
//!
//! ```text
//!     w_i ← [ w_i + (Y_i − W·G_i) / G_ii ]_+
//! ```
//!
//! with G = HᵀH + αI, Y = X·H + αH (the derivation in App. A composed
//! with the normal-equation substitution; both forms are tested equal in
//! `tests::matches_eq26_form`). Columns update sequentially in place —
//! later columns see earlier updates — which is exactly why the paper's
//! "modified HALS" (Eq. 2.6/2.7) lets XH and HᵀH be computed once per
//! sweep and reused.

use crate::linalg::DenseMat;

/// One full HALS sweep updating every column of `w` given (G, Y).
/// `w` is modified in place and stays nonnegative. Allocating wrapper
/// over [`hals_sweep_ws`] for setup-phase and test callers.
pub fn hals_sweep(g: &DenseMat, y: &DenseMat, w: &mut DenseMat) {
    let (m, k) = w.shape();
    let mut wt = DenseMat::zeros(k, m);
    let mut yt = DenseMat::zeros(k, m);
    let mut delta = vec![0.0f64; m];
    hals_sweep_ws(g, y, w, &mut wt, &mut yt, &mut delta);
}

/// HALS sweep with caller-provided scratch (the `ft`/`yt`/`delta` buffers
/// of [`crate::linalg::workspace::UpdateScratch`]): `w` is updated fully
/// in place and the hot loop performs no allocation.
///
/// Column-major scratch gives contiguous column access: W is row-major,
/// so the sweep runs on a transposed copy (k×m) where each column update
/// is a contiguous slice, then transposes back into `w`. The delta buffer
/// is reused across columns (§Perf: no per-column allocation).
pub fn hals_sweep_ws(
    g: &DenseMat,
    y: &DenseMat,
    w: &mut DenseMat,
    wt: &mut DenseMat,
    yt: &mut DenseMat,
    delta: &mut [f64],
) {
    let (m, k) = w.shape();
    assert_eq!(g.shape(), (k, k));
    assert_eq!(y.shape(), (m, k));
    assert_eq!(wt.shape(), (k, m), "hals_sweep_ws wt shape");
    assert_eq!(yt.shape(), (k, m), "hals_sweep_ws yt shape");
    assert_eq!(delta.len(), m, "hals_sweep_ws delta length");
    w.transpose_into(wt);
    y.transpose_into(yt);
    for i in 0..k {
        let gii = g.at(i, i);
        if gii <= 0.0 {
            continue;
        }
        // delta = (Y_i − W·G_i) / G_ii = yt[i,:] − Σ_j G_ij · wt[j,:]
        delta.copy_from_slice(yt.row(i));
        let grow = g.row(i);
        for (j, &gij) in grow.iter().enumerate() {
            if gij != 0.0 && j != i {
                crate::linalg::blas::axpy(-gij, wt.row(j), delta);
            }
        }
        // fold the j == i term into the final update: with the diagonal
        // term excluded above, delta currently holds Y_i − Σ_{j≠i}G_ij w_j,
        // so the classic rule w_i ← [w_i + (Y_i − W·G_i)/G_ii]_+ becomes
        // w_i ← [(delta_i)/G_ii]_+ since W·G_i includes G_ii·w_i.
        let wrow = wt.row_mut(i);
        let inv = 1.0 / gii;
        for (wv, dv) in wrow.iter_mut().zip(delta.iter()) {
            *wv = (dv * inv).max(0.0);
        }
    }
    wt.transpose_into(w);
}

/// `fix_zero_columns`: HALS can zero out a column entirely (a dead
/// component); the standard remedy reseeds it with a tiny positive value
/// so the factor keeps rank k. Returns how many columns were reseeded.
pub fn fix_zero_columns(w: &mut DenseMat, eps: f64) -> usize {
    let (m, k) = w.shape();
    let mut fixed = 0;
    for j in 0..k {
        let norm_sq: f64 = (0..m).map(|i| w.at(i, j) * w.at(i, j)).sum();
        if norm_sq < eps * eps {
            for i in 0..m {
                w.set(i, j, eps);
            }
            fixed += 1;
        }
    }
    fixed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Pcg64;

    fn setup2(
        m: usize,
        k: usize,
        alpha: f64,
        seed: u64,
    ) -> (DenseMat, DenseMat, DenseMat, DenseMat, DenseMat) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut x = DenseMat::gaussian(m, m, &mut rng);
        x.symmetrize();
        let mut h = DenseMat::gaussian(m, k, &mut rng);
        h.project_nonneg();
        let mut w = DenseMat::gaussian(m, k, &mut rng);
        w.project_nonneg();
        let mut g = blas::gram(&h);
        for i in 0..k {
            *g.at_mut(i, i) += alpha;
        }
        let mut y = blas::matmul(&x, &h);
        y.axpy(alpha, &h);
        (x, h, w, g, y)
    }

    #[test]
    fn output_nonnegative() {
        let (_x, _h, mut w, g, y) = setup2(30, 5, 1.0, 1);
        hals_sweep(&g, &y, &mut w);
        assert!(w.is_nonneg());
    }

    /// The sweep must not increase the regularized objective
    /// ‖X − WHᵀ‖² + α‖W − H‖² (exact per-column minimization).
    #[test]
    fn decreases_regularized_objective() {
        for seed in [2, 3, 4, 5] {
            let (x, h, mut w, g, y) = setup2(25, 4, 1.5, seed);
            let alpha = 1.5;
            let obj = |wm: &DenseMat| {
                let rec = blas::matmul_nt(wm, &h);
                let mut d = x.clone();
                d.axpy(-1.0, &rec);
                d.fro_norm_sq() + alpha * wm.diff_fro(&h).powi(2)
            };
            let before = obj(&w);
            hals_sweep(&g, &y, &mut w);
            let after = obj(&w);
            assert!(after <= before + 1e-9, "seed {seed}: {before} → {after}");
        }
    }

    /// Update(G,Y)-form equals the paper's Eq. 2.6 form computed literally.
    #[test]
    fn matches_eq26_form() {
        let (x, h, w0, g, y) = setup2(20, 4, 2.0, 7);
        let alpha = 2.0;
        let k = 4;
        // ours
        let mut w_fast = w0.clone();
        hals_sweep(&g, &y, &mut w_fast);
        // literal Eq. 2.6: w_i ← [((X − WHᵀ + αI)h_i)/(‖h_i‖²+α)
        //                        + (‖h_i‖²/(‖h_i‖²+α)) w_i]_+
        let mut w_lit = w0.clone();
        for i in 0..k {
            let hi = h.col(i);
            let hnorm: f64 = hi.iter().map(|v| v * v).sum();
            let denom = hnorm + alpha;
            let rec = blas::matmul_nt(&w_lit, &h); // uses current W
            let m = x.rows();
            let mut newcol = vec![0.0; m];
            for r in 0..m {
                let mut acc = 0.0;
                for c in 0..m {
                    let xv = x.at(r, c) - rec.at(r, c)
                        + if r == c { alpha } else { 0.0 };
                    acc += xv * hi[c];
                }
                newcol[r] = (acc / denom + (hnorm / denom) * w_lit.at(r, i)).max(0.0);
            }
            w_lit.set_col(i, &newcol);
        }
        assert!(
            w_fast.diff_fro(&w_lit) < 1e-8,
            "Update(G,Y) HALS ≠ Eq. 2.6 literal: {}",
            w_fast.diff_fro(&w_lit)
        );
    }

    #[test]
    fn reseeds_dead_columns() {
        let mut w = DenseMat::zeros(10, 3);
        w.set(0, 1, 5.0);
        let fixed = fix_zero_columns(&mut w, 1e-8);
        assert_eq!(fixed, 2);
        assert!(w.col(0).iter().all(|&v| v > 0.0));
    }
}
