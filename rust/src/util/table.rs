//! ASCII table rendering for reproducing the paper's tables (Table 2,
//! Tables 3–8) on stdout and in `results/*.txt`.

/// A simple left-aligned ASCII table with a header row.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width must match header"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            line.push('\n');
            line
        };
        let sep = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('|');
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format a float the way the paper's tables do (4 significant decimals).
pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

/// Format seconds with 3 decimals.
pub fn secs(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Alg.", "Time", "Min-Res"]);
        t.row_strs(&["BPP", "66.95", "0.9436"]);
        t.row_strs(&["LAI-HALS-IR", "23.799", "0.9436"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{s}");
        assert!(s.contains("LAI-HALS-IR"));
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
