//! Regenerates paper **Table 3 / Tables 7–8** (§5.2.1, App. G.2):
//! top-10 key words per cluster (tf-idf association) for HALS vs
//! LvS-HALS output, plus cluster sizes and silhouette scores.
//!
//! The OAG has no redistributable text; per DESIGN.md §3 each SBM vertex
//! carries a synthetic abstract drawn from a 16-topic corpus aligned
//! with its block, so the tf-idf/topword pipeline runs unchanged. Shape
//! to reproduce: LvS-HALS's small clusters map onto coherent topics
//! (Table 3/8) while the giant core cluster is mixed (Table 7's
//! repetitive rows); silhouettes high for small clusters, low for the
//! core.
//!
//!     cargo bench --bench bench_topwords
//! writes results/table3_7_8.txt

use symnmf::clustering::silhouette::cluster_silhouettes;
use symnmf::coordinator::driver::Method;
use symnmf::coordinator::experiments::{oag_options, oag_workload};
use symnmf::coordinator::report;
use symnmf::data::corpus::{self, CorpusParams};
use symnmf::nls::UpdateRule;
use symnmf::symnmf::options::Tau;

fn main() {
    let m = std::env::var("SYMNMF_BENCH_M")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8_000);
    println!("== Tables 3/7/8 bench: topwords on OAG (m={m}, k=16) ==");
    let g = oag_workload(m, 5);

    // synthetic per-vertex abstracts aligned with the SBM blocks: doc d's
    // topic is the vertex's planted block.
    let cp = CorpusParams {
        num_docs: m,
        num_terms: 4_000,
        num_topics: 16,
        doc_len: 40,
        noise: 0.4,
        topic_mix: 0.1,
        seed: 77,
    };
    // generate() assigns topics round-robin; re-map to the SBM labels by
    // generating per-vertex docs directly: easiest is to reuse generate()
    // and permute docs so labels match the graph blocks.
    let mut corpus = corpus::generate(&cp);
    {
        // permutation: for each vertex with block b, pick an unused doc
        // with label b (labels are balanced mod 16; blocks are skewed, so
        // recycle docs when a label runs dry — acceptable for text).
        let mut pools: Vec<Vec<usize>> = vec![Vec::new(); 16];
        for (d, &l) in corpus.labels.iter().enumerate() {
            pools[l].push(d);
        }
        let mut cursor = vec![0usize; 16];
        let mut trips = Vec::new();
        for v in 0..m {
            let b = g.labels[v] % 16;
            let pool = &pools[b];
            let d = pool[cursor[b] % pool.len()];
            cursor[b] += 1;
            let (cols, vals) = corpus.counts.row(d);
            for (&t, &val) in cols.iter().zip(vals) {
                trips.push((v, t, val));
            }
        }
        corpus.counts = symnmf::sparse::CsrMat::from_coo(m, 4_000, trips);
        corpus.labels = g.labels.clone();
    }
    let weights = corpus::tfidf(&corpus.counts);

    let mut opts = oag_options().with_seed(50);
    opts.max_iters = 30;

    let mut out = String::new();
    for method in [
        Method::Exact(UpdateRule::Hals),
        Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS },
    ] {
        let res = method.run(&g.adj, &opts);
        let assign = res.cluster_assignments();
        let sizes = symnmf::clustering::assign::cluster_sizes(&assign, 16);
        let (sil, _) = cluster_silhouettes(&g.adj, &assign, 16);
        let words = corpus::topwords(&weights, &corpus.vocab, &assign, 16, 10);
        let table = report::topwords_table(&words, 10);

        out.push_str(&format!("=== {} ===\ncluster sizes: {:?}\n", res.label, sizes));
        out.push_str("silhouettes: ");
        for s in &sil {
            out.push_str(&format!("{s:.2} "));
        }
        out.push('\n');
        out.push_str(&table);
        out.push('\n');
        println!("{} done: sizes {:?}", res.label, sizes);
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table3_7_8.txt", &out).unwrap();
    println!("wrote results/table3_7_8.txt");
}
