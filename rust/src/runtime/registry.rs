//! Artifact registry: `artifacts/manifest.json` → shape-keyed specs.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled program.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// program family: "products" | "lai_products" | "hals_sweep"
    pub program: String,
    /// HLO text file (absolute path)
    pub path: PathBuf,
    /// named dimensions, e.g. {m: 1024, k: 7}
    pub dims: BTreeMap<String, usize>,
    /// input shapes in argument order
    pub inputs: Vec<Vec<usize>>,
    /// output shapes (tuple elements) in order
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactSpec {
    pub fn dim(&self, name: &str) -> Option<usize> {
        self.dims.get(name).copied()
    }
}

/// All artifacts from one manifest.
#[derive(Debug, Default)]
pub struct Registry {
    pub specs: Vec<ArtifactSpec>,
}

impl Registry {
    /// Load `<dir>/manifest.json`. Missing file → empty registry (the
    /// runtime then always falls back to native kernels).
    pub fn load(dir: &Path) -> Result<Registry, String> {
        let manifest = dir.join("manifest.json");
        if !manifest.exists() {
            return Ok(Registry::default());
        }
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| format!("read {manifest:?}: {e}"))?;
        let v = Json::parse(&text)?;
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or("manifest missing artifacts array")?;
        let mut specs = Vec::with_capacity(arts.len());
        for a in arts {
            let program = a
                .get("program")
                .and_then(|p| p.as_str())
                .ok_or("artifact missing program")?
                .to_string();
            let file = a
                .get("file")
                .and_then(|p| p.as_str())
                .ok_or("artifact missing file")?;
            let mut dims = BTreeMap::new();
            if let Some(Json::Obj(dm)) = a.get("dims") {
                for (k, v) in dm {
                    dims.insert(k.clone(), v.as_usize().ok_or("bad dim")?);
                }
            }
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>, String> {
                a.get(key)
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| format!("artifact missing {key}"))?
                    .iter()
                    .map(|shp| {
                        shp.as_arr()
                            .ok_or_else(|| "bad shape".to_string())?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| "bad dim".to_string()))
                            .collect()
                    })
                    .collect()
            };
            specs.push(ArtifactSpec {
                program,
                path: dir.join(file),
                dims,
                inputs: shapes("inputs")?,
                outputs: shapes("outputs")?,
            });
        }
        Ok(Registry { specs })
    }

    /// Default artifact directory: `$SYMNMF_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("SYMNMF_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Find a program matching all given dims exactly.
    pub fn find(&self, program: &str, dims: &[(&str, usize)]) -> Option<&ArtifactSpec> {
        self.specs.iter().find(|s| {
            s.program == program
                && dims
                    .iter()
                    .all(|(name, val)| s.dim(name) == Some(*val))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": [
                {"program": "products", "file": "p.hlo.txt",
                 "dims": {"m": 64, "k": 8},
                 "inputs": [[64,64],[64,8]], "outputs": [[64,8],[8,8]],
                 "dtype": "f32"}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("symnmf_registry_test");
        write_manifest(&dir);
        let reg = Registry::load(&dir).unwrap();
        assert_eq!(reg.specs.len(), 1);
        let spec = reg.find("products", &[("m", 64), ("k", 8)]).unwrap();
        assert_eq!(spec.inputs[0], vec![64, 64]);
        assert_eq!(spec.outputs[1], vec![8, 8]);
        assert!(reg.find("products", &[("m", 64), ("k", 9)]).is_none());
        assert!(reg.find("nothing", &[]).is_none());
    }

    #[test]
    fn missing_manifest_is_empty() {
        let dir = std::env::temp_dir().join("symnmf_registry_none");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let reg = Registry::load(&dir).unwrap();
        assert!(reg.specs.is_empty());
    }
}
