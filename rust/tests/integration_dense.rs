//! Integration: the dense §5.1 pipeline end to end — synthetic corpus →
//! tf-idf → EDVW adjacency → every SymNMF method → clustering quality.

use symnmf::clustering::ari::adjusted_rand_index;
use symnmf::coordinator::driver::{
    batch_trials_enabled, run_trials, run_trials_batched, Method, MethodStats,
};
use symnmf::coordinator::experiments::{fig1_table2_methods, wos_workload};
use symnmf::coordinator::report;
use symnmf::linalg::DenseMat;
use symnmf::nls::UpdateRule;
use symnmf::symnmf::SymNmfOptions;
use symnmf::util::rng::Pcg64;

/// Run trials through the driver the environment selects:
/// `SYMNMF_BATCH_TRIALS=1` (the CI bench-regression job sets it) routes
/// the whole dense pipeline through the batched multi-seed driver, which
/// is bitwise-identical to the serial path — so every assertion below
/// holds for both.
fn drive(
    method: Method,
    x: &DenseMat,
    opts: &SymNmfOptions,
    labels: Option<&[usize]>,
    trials: usize,
) -> MethodStats {
    if batch_trials_enabled() {
        run_trials_batched(method, x, opts, labels, trials)
    } else {
        run_trials(method, x, opts, labels, trials)
    }
}

#[test]
fn wos_pipeline_all_methods_cluster_better_than_chance() {
    let w = wos_workload(140, 7); // 140 docs, 7 topics
    let mut opts = SymNmfOptions::new(7).with_seed(1);
    opts.max_iters = 60;
    for method in fig1_table2_methods() {
        let stats = drive(method, &w.adjacency, &opts, Some(&w.labels), 1);
        assert!(
            stats.mean_ari > 0.15,
            "{}: ARI {} not better than chance",
            stats.label,
            stats.mean_ari
        );
        assert!(
            stats.min_res < 1.0,
            "{}: residual {} did not drop below trivial",
            stats.label,
            stats.min_res
        );
    }
}

#[test]
fn randomized_methods_preserve_quality_vs_exact() {
    let w = wos_workload(140, 3);
    let mut opts = SymNmfOptions::new(7).with_seed(2);
    opts.max_iters = 80;
    let exact = drive(
        Method::Exact(UpdateRule::Hals),
        &w.adjacency,
        &opts,
        Some(&w.labels),
        2,
    );
    let lai = drive(
        Method::Lai { rule: UpdateRule::Hals, refine: false },
        &w.adjacency,
        &opts,
        Some(&w.labels),
        2,
    );
    // §5.1: randomized methods "maintain accuracy in terms of normalized
    // residual norms and cluster quality"
    assert!(
        lai.avg_min_res < exact.avg_min_res + 0.02,
        "LAI residual {} vs exact {}",
        lai.avg_min_res,
        exact.avg_min_res
    );
    assert!(
        lai.mean_ari > exact.mean_ari - 0.15,
        "LAI ARI {} vs exact {}",
        lai.mean_ari,
        exact.mean_ari
    );
}

/// End-to-end engine semantics through the driver: a zero-deadline solve
/// returns the unstepped initial iterate; resuming its checkpoint — after
/// a serialize/parse round-trip — completes to the unlimited run bitwise.
/// (CI additionally re-runs this whole suite under
/// `SYMNMF_DEADLINE_MS=60000`, which routes every plain-entry solve
/// through the deadline path without firing it.)
#[test]
fn engine_deadline_and_resume_through_driver() {
    use symnmf::symnmf::{Checkpoint, RunControl};
    let w = wos_workload(80, 4);
    let mut opts = SymNmfOptions::new(4).with_seed(5);
    opts.max_iters = 8;
    for method in [
        Method::Exact(UpdateRule::Hals),
        Method::Lai { rule: UpdateRule::Hals, refine: true },
    ] {
        let full =
            method.run_controlled(&w.adjacency, &opts, &RunControl::unlimited(), None);
        assert!(full.completed(), "{}", method.label());
        let dead = method.run_controlled(
            &w.adjacency,
            &opts,
            &RunControl::unlimited().with_deadline(0.0),
            None,
        );
        assert_eq!(dead.result.iters(), 0, "{}: deadline 0 must not step", method.label());
        let cp = Checkpoint::parse(&dead.checkpoint.serialize()).expect("roundtrip");
        let resumed =
            method.run_controlled(&w.adjacency, &opts, &RunControl::unlimited(), Some(&cp));
        assert_eq!(full.result.iters(), resumed.result.iters(), "{}", method.label());
        for (a, b) in full.result.h.data().iter().zip(resumed.result.h.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{}: H differs", method.label());
        }
    }
}

#[test]
fn spectral_baseline_runs_on_wos() {
    let w = wos_workload(120, 4);
    let mut rng = Pcg64::seed_from_u64(3);
    let assign = symnmf::clustering::spectral::spectral_cluster(&w.adjacency, 7, &mut rng);
    let ari = adjusted_rand_index(&assign, &w.labels);
    assert!(ari > 0.1, "spectral ARI {ari}");
}

#[test]
fn report_artifacts_are_generated() {
    let w = wos_workload(100, 5);
    let mut opts = SymNmfOptions::new(7).with_seed(4);
    opts.max_iters = 10;
    let stats = vec![drive(
        Method::Lai { rule: UpdateRule::Hals, refine: false },
        &w.adjacency,
        &opts,
        Some(&w.labels),
        1,
    )];
    let table = report::stats_table(&stats);
    assert!(table.contains("LAI-HALS"));
    let dir = std::env::temp_dir().join("symnmf_integration_report");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("fig1.csv");
    report::write_convergence_csv(&csv, &stats).unwrap();
    assert!(std::fs::read_to_string(&csv).unwrap().lines().count() > 1);
}
