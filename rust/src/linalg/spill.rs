//! Out-of-core tier for [`SymPacked`]: a versioned on-disk panel file
//! plus a streaming operator that faults tiles back on demand.
//!
//! The packed block-panel layout (see `linalg::packed`) is contiguous
//! and offset-addressable — tile p lives at `block_off[p]` — so spilling
//! is a straight serialization of the payload, and a spilled apply can
//! address any tile with one positioned read. No mmap, no dependencies:
//! reads go through `read_exact_at` (pread) into a small reusable
//! buffer ring.
//!
//! ## File format (version 1)
//!
//! All integers and float bit patterns little-endian:
//!
//! ```text
//!   offset  size  field
//!   0       8     magic "SYMPKSPL"
//!   8       4     format version (u32, = 1)
//!   12      4     reserved (u32, = 0)
//!   16      8     dim m (u64)
//!   24      8     block size (u64)
//!   32      8     packed_len: stored f64 count (u64)
//!   40      8     fro_sq bit pattern (‖X‖²_F, cached stat)
//!   48      8     max bit pattern (max entry, cached stat)
//!   56      8     mean bit pattern (mean entry, cached stat)
//!   64      8     FNV-1a 64 checksum over the payload bytes (u64)
//!   72      8·packed_len   payload: the packed tiles, f64 LE, in
//!                 block-row-major order — tile p starts at byte
//!                 72 + 8·block_off[p]
//! ```
//!
//! The cached aggregate statistics ride in the header as raw bit
//! patterns, so a spilled operator answers the [`SymOp`] stat surface
//! bitwise-identically to the resident operator without touching the
//! payload. Files are written via temp + rename (never a torn file at
//! the final path), and [`SymPackedSpilled::open`] validates magic,
//! version, layout (the reader recomputes `block_layout` from (dim,
//! block) and the recorded `packed_len` must match), exact file size
//! (truncation), and the payload checksum **once at open** — after
//! that, per-tile reads are trusted and cheap.
//!
//! ## Bitwise contract
//!
//! [`SymPackedSpilled::apply_blocked_into`] drives the identical
//! [`tile_pair_apply_slice`] kernel on the identical
//! [`pair_pool_accumulate`] harness as the resident
//! [`SymPacked::apply_blocked_into`]; the only difference is where the
//! tile slice comes from (a ring buffer filled by pread instead of the
//! resident payload). The result is therefore bitwise-identical to the
//! resident apply on every `simd::supported()` ISA, under every
//! thread budget, and under either dispatch backend of the shared
//! persistent pool ([`crate::util::pool`]) — pinned by the parity
//! tests below and by `tests/integration_pool.rs`.
//!
//! [`pair_pool_accumulate`]: crate::linalg::blas::pair_pool_accumulate
//! [`tile_pair_apply_slice`]: crate::linalg::packed::tile_pair_apply_slice

use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

use crate::linalg::blas::{axpy, pair_pool_accumulate, pair_to_blocks};
use crate::linalg::packed::{block_layout, tile_pair_apply_slice};
use crate::linalg::simd::{self, KernelIsa};
use crate::linalg::{DenseMat, SymPacked};
use crate::randnla::SymOp;
use crate::util::retry;
use crate::util::threadpool::{num_threads, parallel_for_chunks, SendPtr};

/// File magic: "SYMPKSPL".
const MAGIC: [u8; 8] = *b"SYMPKSPL";
/// Format version this build reads and writes.
const VERSION: u32 = 1;
/// Header size in bytes; the payload starts here.
const HEADER_LEN: usize = 72;
/// Chunk size (in f64 elements) for streaming writes and checksum scans.
const IO_CHUNK: usize = 128 * 1024;

/// Streaming FNV-1a 64-bit hash — the zero-dependency content hash used
/// for both the spill payload checksum and the operator-cache content
/// keys (`serve::opcache`).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        self.0 = h;
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_f64(&mut self, v: f64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// Serialize a resident [`SymPacked`] to `path` in the version-1 panel
/// format, via a same-directory temp file + atomic rename — a reader
/// never observes a torn file at the final path. The payload checksum
/// is computed in a first pass over the (memory-resident) payload so the
/// header can be written up front and the tiles streamed after it.
pub fn write_spill(sp: &SymPacked, path: &Path) -> Result<(), String> {
    crate::util::failpoint::hit("spill_write")?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)
                .map_err(|e| format!("spill: create dir {}: {e}", dir.display()))?;
        }
    }
    let data = sp.payload();
    let mut ck = Fnv64::new();
    for &v in data {
        ck.write_f64(v);
    }
    let (fro_sq, max, mean) = sp.stats();
    let mut header = [0u8; HEADER_LEN];
    header[0..8].copy_from_slice(&MAGIC);
    header[8..12].copy_from_slice(&VERSION.to_le_bytes());
    // bytes 12..16 reserved, zero
    header[16..24].copy_from_slice(&(sp.dim() as u64).to_le_bytes());
    header[24..32].copy_from_slice(&(sp.block() as u64).to_le_bytes());
    header[32..40].copy_from_slice(&(data.len() as u64).to_le_bytes());
    header[40..48].copy_from_slice(&fro_sq.to_le_bytes());
    header[48..56].copy_from_slice(&max.to_le_bytes());
    header[56..64].copy_from_slice(&mean.to_le_bytes());
    header[64..72].copy_from_slice(&ck.finish().to_le_bytes());

    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    let res = (|| -> Result<(), String> {
        let mut f = File::create(&tmp)
            .map_err(|e| format!("spill: create {}: {e}", tmp.display()))?;
        f.write_all(&header)
            .map_err(|e| format!("spill: write header: {e}"))?;
        let mut buf = Vec::with_capacity(IO_CHUNK.min(data.len().max(1)) * 8);
        for chunk in data.chunks(IO_CHUNK) {
            buf.clear();
            for &v in chunk {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&buf)
                .map_err(|e| format!("spill: write payload: {e}"))?;
        }
        f.sync_all().map_err(|e| format!("spill: sync: {e}"))?;
        fs::rename(&tmp, path)
            .map_err(|e| format!("spill: rename into {}: {e}", path.display()))
    })();
    if res.is_err() {
        let _ = fs::remove_file(&tmp);
    }
    res
}

/// Positioned read that leaves no shared cursor state: pread on unix,
/// seek_read on windows, and a process-serialized seek+read fallback
/// elsewhere. Safe to call concurrently on one `&File`.
fn read_exact_at(file: &File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        std::os::unix::fs::FileExt::read_exact_at(file, buf, offset)
    }
    #[cfg(windows)]
    {
        let mut buf = buf;
        let mut offset = offset;
        while !buf.is_empty() {
            let n = std::os::windows::fs::FileExt::seek_read(file, buf, offset)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "spill file shorter than expected",
                ));
            }
            let rest = buf;
            buf = &mut rest[n..];
            offset += n as u64;
        }
        Ok(())
    }
    #[cfg(not(any(unix, windows)))]
    {
        use std::io::{Read, Seek, SeekFrom};
        // no positioned-read primitive: serialize the shared cursor
        static IO_LOCK: Mutex<()> = Mutex::new(());
        let _g = IO_LOCK.lock().unwrap();
        let mut f = file;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }
}

/// One reusable read buffer: raw bytes straight off the pread, plus the
/// decoded f64 tile. Both grow-only, bounded by the largest tile
/// (min(block, m)² elements).
struct RingSlot {
    bytes: Vec<u8>,
    vals: Vec<f64>,
}

/// A [`SymPacked`] whose payload lives on disk: the same block-panel
/// addressing, but `apply` streams each tile through a small reusable
/// read-buffer ring instead of indexing resident memory. Construction
/// ([`SymPackedSpilled::open`]) validates the file fully (magic,
/// version, layout, size, checksum); after that the operator is
/// immutable and `Sync` — concurrent pool workers read disjoint tiles
/// through independent ring slots via positioned reads.
///
/// Resident footprint: the `block_off` table plus the ring buffers
/// (≤ `num_threads() · min(block,m)² · 16` bytes, allocated lazily) —
/// the payload itself never loads as a whole. The operator cache
/// (`serve::opcache`) therefore accounts a spilled operator's *payload*
/// bytes as zero against the resident-X budget and documents the ring
/// as bounded scratch, like the SYMM accumulator pool.
pub struct SymPackedSpilled {
    path: PathBuf,
    file: File,
    m: usize,
    block: usize,
    nb: usize,
    /// prefix offsets of each tile in the payload (len = npairs + 1)
    block_off: Vec<usize>,
    fro_sq: f64,
    max: f64,
    mean: f64,
    ring: Vec<Mutex<RingSlot>>,
}

impl std::fmt::Debug for SymPackedSpilled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SymPackedSpilled")
            .field("path", &self.path)
            .field("m", &self.m)
            .field("block", &self.block)
            .field("packed_len", &self.packed_len())
            .finish()
    }
}

impl SymPackedSpilled {
    /// Open and fully validate a version-1 spill file. Every rejection
    /// names what failed: magic, version, layout, truncation, or
    /// checksum.
    pub fn open(path: &Path) -> Result<SymPackedSpilled, String> {
        crate::util::failpoint::hit("spill_open")?;
        let file =
            File::open(path).map_err(|e| format!("spill: open {}: {e}", path.display()))?;
        let mut header = [0u8; HEADER_LEN];
        read_exact_at(&file, &mut header, 0)
            .map_err(|e| format!("spill: {} too short for header: {e}", path.display()))?;
        let u64_at = |o: usize| u64::from_le_bytes(header[o..o + 8].try_into().unwrap());
        if header[0..8] != MAGIC {
            return Err(format!(
                "spill: {} is not a SymPacked spill file (bad magic)",
                path.display()
            ));
        }
        let version = u32::from_le_bytes(header[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(format!(
                "spill: {} has unsupported format version {version} (this build reads {VERSION})",
                path.display()
            ));
        }
        let m = u64_at(16) as usize;
        let block = u64_at(24) as usize;
        let packed_len = u64_at(32) as usize;
        if block == 0 {
            return Err(format!("spill: {} header: block size 0", path.display()));
        }
        // Size check before the layout allocation: bounds packed_len (and
        // with it the offset-table allocation below) by the real file.
        // Saturating: a wrapped product from a hostile header could
        // collide with the real file length; saturation never can.
        let want_len = (packed_len as u64)
            .saturating_mul(8)
            .saturating_add(HEADER_LEN as u64);
        let have_len = file
            .metadata()
            .map_err(|e| format!("spill: stat {}: {e}", path.display()))?
            .len();
        if have_len != want_len {
            return Err(format!(
                "spill: {} truncated or oversized: header promises {want_len} bytes, file has {have_len}",
                path.display()
            ));
        }
        // Every tile holds >= 1 element, so a consistent header satisfies
        // npairs <= packed_len + 1 — reject before allocating the table.
        let nb128 = (m as u128).div_ceil(block as u128);
        if nb128 * (nb128 + 1) / 2 > packed_len as u128 + 1 {
            return Err(format!(
                "spill: {} header: layout mismatch (dim {m}, block {block} cannot pack into {packed_len} elements)",
                path.display()
            ));
        }
        let (nb, block_off, total) = block_layout(m, block);
        if total != packed_len {
            return Err(format!(
                "spill: {} header: layout mismatch (dim {m}, block {block} packs {total} elements, header says {packed_len})",
                path.display()
            ));
        }
        // Checksum scan — the one full pass over the payload, at open.
        let mut ck = Fnv64::new();
        let mut buf = vec![0u8; (IO_CHUNK * 8).min((packed_len * 8).max(1))];
        let mut off = HEADER_LEN as u64;
        let mut left = packed_len * 8;
        while left > 0 {
            let n = left.min(buf.len());
            read_exact_at(&file, &mut buf[..n], off)
                .map_err(|e| format!("spill: read {}: {e}", path.display()))?;
            ck.write(&buf[..n]);
            off += n as u64;
            left -= n;
        }
        if ck.finish() != u64_at(64) {
            return Err(format!(
                "spill: {} payload checksum mismatch (corrupted spill file)",
                path.display()
            ));
        }
        let slots = num_threads().max(1);
        let ring = (0..slots)
            .map(|_| Mutex::new(RingSlot { bytes: Vec::new(), vals: Vec::new() }))
            .collect();
        Ok(SymPackedSpilled {
            path: path.to_path_buf(),
            file,
            m,
            block,
            nb,
            block_off,
            fro_sq: f64::from_le_bytes(header[40..48].try_into().unwrap()),
            max: f64::from_le_bytes(header[48..56].try_into().unwrap()),
            mean: f64::from_le_bytes(header[56..64].try_into().unwrap()),
            ring,
        })
    }

    /// Dimension m.
    pub fn dim(&self) -> usize {
        self.m
    }

    /// Block size of the panel layout.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Stored (on-disk) elements.
    pub fn packed_len(&self) -> usize {
        self.block_off[self.block_off.len() - 1]
    }

    /// The backing spill file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Rows/cols of block index `b` (edge blocks truncated).
    #[inline]
    fn bdim(&self, b: usize) -> usize {
        (self.m - b * self.block).min(self.block)
    }

    /// Grab a ring slot, preferring an uncontended one: scan from
    /// `p % slots` with try_lock so concurrent pool workers spread over
    /// the ring, fall back to blocking on the home slot.
    fn acquire_slot(&self, p: usize) -> MutexGuard<'_, RingSlot> {
        let n = self.ring.len();
        for i in 0..n {
            if let Ok(g) = self.ring[(p + i) % n].try_lock() {
                return g;
            }
        }
        self.ring[p % n].lock().unwrap()
    }

    /// Fault tile `p` from disk into the slot's buffers; returns the
    /// decoded element count. Buffers grow to the largest tile and are
    /// reused thereafter — steady-state applies allocate nothing.
    fn read_tile(&self, slot: &mut RingSlot, p: usize) -> usize {
        let len = self.block_off[p + 1] - self.block_off[p];
        let nbytes = len * 8;
        if slot.bytes.len() < nbytes {
            slot.bytes.resize(nbytes, 0);
        }
        if slot.vals.len() < len {
            slot.vals.resize(len, 0.0);
        }
        let off = HEADER_LEN as u64 + 8 * self.block_off[p] as u64;
        // Validated at open; a failure here is environmental (file
        // deleted/device gone mid-serve, transient I/O pressure). A
        // transient error heals inside the bounded deterministic retry;
        // a persistent one cannot be answered with a wrong result, so
        // after the budget the apply fails loudly (under the serve
        // scheduler, panic isolation turns that into a Failed job).
        let mut last_err = String::new();
        for attempt in 1..=retry::DEFAULT_ATTEMPTS {
            let read = crate::util::failpoint::hit("spill_read").and_then(|()| {
                read_exact_at(&self.file, &mut slot.bytes[..nbytes], off)
                    .map_err(|e| e.to_string())
            });
            match read {
                Ok(()) => {
                    for (dst, src) in
                        slot.vals[..len].iter_mut().zip(slot.bytes[..nbytes].chunks_exact(8))
                    {
                        *dst = f64::from_le_bytes(src.try_into().unwrap());
                    }
                    return len;
                }
                Err(e) => {
                    last_err = e;
                    retry::backoff(attempt);
                }
            }
        }
        panic!(
            "spill: read tile {p} of {} failed after {} attempts: {last_err}",
            self.path.display(),
            retry::DEFAULT_ATTEMPTS
        );
    }

    /// out = X·F streaming tiles from disk — the spilled twin of
    /// [`SymPacked::apply_blocked_into`]: identical pair enumeration,
    /// identical per-tile kernel ([`tile_pair_apply_slice`]), identical
    /// fixed-order reduction, hence bitwise-identical output.
    pub fn apply_blocked_into(&self, f: &DenseMat, out: &mut DenseMat) {
        self.apply_blocked_into_isa(simd::active(), f, out);
    }

    /// [`apply_blocked_into`](Self::apply_blocked_into) with an explicit
    /// kernel tier — the parity suite's entry point.
    pub fn apply_blocked_into_isa(&self, isa: KernelIsa, f: &DenseMat, out: &mut DenseMat) {
        let m = self.m;
        let (mf, k) = f.shape();
        assert_eq!(m, mf, "SymPackedSpilled::apply: X is {m}x{m} but F has {mf} rows");
        assert_eq!(out.shape(), (m, k), "SymPackedSpilled::apply: output must be {m}x{k}");
        if m == 0 || k == 0 {
            out.data_mut().fill(0.0);
            return;
        }
        let nb = self.nb;
        let npairs = nb * (nb + 1) / 2;
        let fd = f.data();
        pair_pool_accumulate(m, k, npairs, out, |p, acc| {
            let (ib, jb) = pair_to_blocks(p, nb);
            let mut slot = self.acquire_slot(p);
            let len = self.read_tile(&mut slot, p);
            tile_pair_apply_slice(isa, m, self.block, ib, jb, &slot.vals[..len], fd, k, acc);
        });
    }
}

impl SymOp for SymPackedSpilled {
    fn dim(&self) -> usize {
        self.m
    }

    fn apply_into(&self, f: &DenseMat, out: &mut DenseMat) {
        self.apply_blocked_into(f, out);
    }

    fn fro_norm_sq(&self) -> f64 {
        self.fro_sq
    }

    fn max_value(&self) -> f64 {
        self.max
    }

    fn mean_value(&self) -> f64 {
        self.mean
    }

    fn sampled_apply_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        self.sampled_apply_into_isa(simd::active(), f, samples, weights_sq, out);
    }
}

impl SymPackedSpilled {
    /// Serial scalar oracle for the sampled product. Same walk as
    /// [`SymPacked::sampled_apply_into_serial`], with each touched tile
    /// faulted through the ring. A sampled row reads its whole block-row
    /// of tiles — acceptable I/O amplification for the row-sampled (LvS)
    /// path, which is rare on spilled graphs; the accumulation order is
    /// identical to the resident operator, so the result is
    /// bitwise-identical. Retained verbatim as the pinning reference for
    /// [`SymPackedSpilled::sampled_apply_into_isa`].
    pub fn sampled_apply_into_serial(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        let k = f.cols();
        assert_eq!(out.shape(), (self.m, k), "sampled_apply_into shape");
        let od = out.data_mut();
        od.fill(0.0);
        let block = self.block;
        for (&ir, &w) in samples.iter().zip(weights_sq) {
            let frow = f.row(ir);
            let ib = ir / block;
            let li = ir - ib * block;
            for jb in 0..self.nb {
                let j0 = jb * block;
                let j1 = (j0 + block).min(self.m);
                if jb < ib {
                    // mirrored: column li of stored tile (jb, ib)
                    let p = jb * (2 * self.nb - jb + 1) / 2 + (ib - jb);
                    let mut slot = self.acquire_slot(p);
                    let len = self.read_tile(&mut slot, p);
                    let bd = &slot.vals[..len];
                    let ld = self.bdim(ib); // cols of tile (jb, ib)
                    for j in j0..j1 {
                        let v = bd[(j - j0) * ld + li];
                        if v != 0.0 {
                            axpy(w * v, frow, &mut od[j * k..(j + 1) * k]);
                        }
                    }
                } else {
                    let p = ib * (2 * self.nb - ib + 1) / 2 + (jb - ib);
                    let mut slot = self.acquire_slot(p);
                    let len = self.read_tile(&mut slot, p);
                    let bd = &slot.vals[..len];
                    let bj = j1 - j0;
                    let xrow = &bd[li * bj..(li + 1) * bj];
                    for (jj, &v) in xrow.iter().enumerate() {
                        if v != 0.0 {
                            let j = j0 + jj;
                            axpy(w * v, frow, &mut od[j * k..(j + 1) * k]);
                        }
                    }
                }
            }
        }
    }

    /// Parallel, ISA-dispatched sampled product — the scatter of
    /// [`SymPackedSpilled::sampled_apply_into_serial`] reformulated as a
    /// gather over disjoint block-row chunks (see `randnla::op` module
    /// docs), tiles faulted through the ring from inside each chunk (the
    /// Mutex ring is safe under concurrent faulting — workers spread
    /// over the slots via `acquire_slot`). Per output element the
    /// accumulation order matches the serial oracle exactly, so the
    /// result is bitwise-identical at any thread count.
    pub fn sampled_apply_into_isa(
        &self,
        isa: KernelIsa,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        let k = f.cols();
        assert_eq!(out.shape(), (self.m, k), "sampled_apply_into shape");
        assert_eq!(samples.len(), weights_sq.len(), "samples/weights length");
        let block = self.block;
        let fd = f.data();
        let optr = SendPtr(out.data_mut().as_mut_ptr());
        parallel_for_chunks(self.nb, 1, move |cb_lo, cb_hi| {
            let lo = cb_lo * block;
            let hi = (cb_hi * block).min(self.m);
            // SAFETY: chunks hand out disjoint block-row ranges, so each
            // worker touches a disjoint slice of `out`.
            let od = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add(lo * k), (hi - lo) * k)
            };
            od.fill(0.0);
            for (&ir, &w) in samples.iter().zip(weights_sq) {
                let frow = &fd[ir * k..(ir + 1) * k];
                let ib = ir / block;
                let li = ir - ib * block;
                for jb in cb_lo..cb_hi {
                    let j0 = jb * block;
                    let j1 = (j0 + block).min(self.m);
                    if jb < ib {
                        // mirrored: column li of stored tile (jb, ib)
                        let p = jb * (2 * self.nb - jb + 1) / 2 + (ib - jb);
                        let mut slot = self.acquire_slot(p);
                        let len = self.read_tile(&mut slot, p);
                        let bd = &slot.vals[..len];
                        let ld = self.bdim(ib); // cols of tile (jb, ib)
                        for j in j0..j1 {
                            let v = bd[(j - j0) * ld + li];
                            if v != 0.0 {
                                let o = (j - lo) * k;
                                simd::axpy(isa, w * v, frow, &mut od[o..o + k]);
                            }
                        }
                    } else {
                        let p = ib * (2 * self.nb - ib + 1) / 2 + (jb - ib);
                        let mut slot = self.acquire_slot(p);
                        let len = self.read_tile(&mut slot, p);
                        let bd = &slot.vals[..len];
                        let bj = j1 - j0;
                        let xrow = &bd[li * bj..(li + 1) * bj];
                        for (jj, &v) in xrow.iter().enumerate() {
                            if v != 0.0 {
                                let o = (j0 + jj - lo) * k;
                                simd::axpy(isa, w * v, frow, &mut od[o..o + k]);
                            }
                        }
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::util::threadpool::with_thread_budget;

    struct TempDir(PathBuf);

    impl TempDir {
        fn new(tag: &str) -> TempDir {
            let d = std::env::temp_dir()
                .join(format!("symnmf-spill-test-{tag}-{}", std::process::id()));
            fs::create_dir_all(&d).unwrap();
            TempDir(d)
        }

        fn file(&self, name: &str) -> PathBuf {
            self.0.join(name)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    fn random_symmetric(m: usize, rng: &mut Pcg64) -> DenseMat {
        let mut x = DenseMat::gaussian(m, m, rng);
        x.symmetrize();
        x
    }

    fn assert_bitwise(a: &DenseMat, b: &DenseMat, ctx: &str) {
        for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}");
        }
    }

    /// The acceptance pinning: the spilled apply is bitwise-identical to
    /// the resident apply at m,k ∈ {1,3,7,31,33,65} (edge tiles
    /// everywhere at block 8) on every supported kernel tier.
    #[test]
    fn spilled_apply_bitwise_equals_resident_across_shapes_and_isas() {
        let dir = TempDir::new("parity");
        let mut rng = Pcg64::seed_from_u64(11);
        for m in [1usize, 3, 7, 31, 33, 65] {
            let x = random_symmetric(m, &mut rng);
            for block in [8usize, 256] {
                let sp = SymPacked::from_dense_with_block(&x, block);
                let path = dir.file(&format!("m{m}-b{block}.sympk"));
                write_spill(&sp, &path).unwrap();
                let spilled = SymPackedSpilled::open(&path).unwrap();
                assert_eq!(spilled.dim(), m);
                assert_eq!(spilled.block(), block);
                assert_eq!(spilled.packed_len(), sp.packed_len());
                for k in [1usize, 3, 7, 31, 33, 65] {
                    let f = DenseMat::gaussian(m, k, &mut rng);
                    for isa in simd::supported() {
                        let mut want = DenseMat::zeros(m, k);
                        want.fill(-3.0);
                        sp.apply_blocked_into_isa(isa, &f, &mut want);
                        let mut got = DenseMat::zeros(m, k);
                        got.fill(7.0); // stale data must be overwritten
                        spilled.apply_blocked_into_isa(isa, &f, &mut got);
                        assert_bitwise(
                            &want,
                            &got,
                            &format!("m={m} k={k} block={block} isa={isa:?}"),
                        );
                    }
                }
            }
        }
    }

    /// Thread budgets exercise concurrent ring traffic and must not
    /// change a bit (slot pool geometry is pinned; the ring only decides
    /// which scratch buffer a read lands in).
    #[test]
    fn spilled_apply_is_budget_invariant_bitwise() {
        let dir = TempDir::new("budget");
        let mut rng = Pcg64::seed_from_u64(12);
        let m = 300;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, 8, &mut rng);
        let sp = SymPacked::from_dense_with_block(&x, 64);
        let path = dir.file("budget.sympk");
        write_spill(&sp, &path).unwrap();
        let spilled = SymPackedSpilled::open(&path).unwrap();
        let mut resident = DenseMat::zeros(m, 8);
        sp.apply_blocked_into(&f, &mut resident);
        for budget in [1usize, 2, 3] {
            let mut capped = DenseMat::zeros(m, 8);
            with_thread_budget(budget, || {
                spilled.apply_blocked_into(&f, &mut capped);
            });
            assert_bitwise(&resident, &capped, &format!("budget={budget}"));
        }
    }

    /// The sampled (row-walk) product faults mirrored tiles from disk
    /// and still equals the resident operator bitwise.
    #[test]
    fn spilled_sampled_apply_bitwise_equals_resident() {
        let dir = TempDir::new("sampled");
        let mut rng = Pcg64::seed_from_u64(13);
        let m = 45;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, 5, &mut rng);
        let samples = vec![0usize, 13, 13, 31, 44, 7];
        let w = vec![0.5, 1.0, 2.0, 0.25, 1.5, 0.75];
        for block in [8usize, 16, 64] {
            let sp = SymPacked::from_dense_with_block(&x, block);
            let path = dir.file(&format!("sampled-b{block}.sympk"));
            write_spill(&sp, &path).unwrap();
            let spilled = SymPackedSpilled::open(&path).unwrap();
            let mut want = DenseMat::zeros(m, 5);
            SymOp::sampled_apply_into(&sp, &f, &samples, &w, &mut want);
            let mut got = DenseMat::zeros(m, 5);
            got.fill(-9.0); // stale data must be overwritten
            SymOp::sampled_apply_into(&spilled, &f, &samples, &w, &mut got);
            assert_bitwise(&want, &got, &format!("block={block}"));
        }
    }

    /// The cached stats ride the header as bit patterns — the spilled
    /// operator's SymOp stat surface equals the resident one's exactly.
    #[test]
    fn stats_survive_the_header_bitwise() {
        let dir = TempDir::new("stats");
        let mut rng = Pcg64::seed_from_u64(14);
        let x = random_symmetric(65, &mut rng);
        let sp = SymPacked::from_dense_with_block(&x, 32);
        let path = dir.file("stats.sympk");
        write_spill(&sp, &path).unwrap();
        let spilled = SymPackedSpilled::open(&path).unwrap();
        assert_eq!(
            SymOp::fro_norm_sq(&sp).to_bits(),
            SymOp::fro_norm_sq(&spilled).to_bits()
        );
        assert_eq!(SymOp::max_value(&sp).to_bits(), SymOp::max_value(&spilled).to_bits());
        assert_eq!(SymOp::mean_value(&sp).to_bits(), SymOp::mean_value(&spilled).to_bits());
    }

    /// Every corruption mode is rejected at open with an error naming
    /// what failed: magic, version, truncation, layout, checksum.
    #[test]
    fn corrupted_spill_files_are_rejected_with_clear_errors() {
        let dir = TempDir::new("corrupt");
        let mut rng = Pcg64::seed_from_u64(15);
        let x = random_symmetric(33, &mut rng);
        let sp = SymPacked::from_dense_with_block(&x, 8);
        let good = dir.file("good.sympk");
        write_spill(&sp, &good).unwrap();
        let pristine = fs::read(&good).unwrap();
        // sanity: the pristine file opens
        SymPackedSpilled::open(&good).unwrap();

        let corrupt = |name: &str, mutate: &dyn Fn(&mut Vec<u8>)| -> String {
            let p = dir.file(name);
            let mut bytes = pristine.clone();
            mutate(&mut bytes);
            fs::write(&p, &bytes).unwrap();
            SymPackedSpilled::open(&p).expect_err("corrupted file must be rejected")
        };

        let e = corrupt("magic.sympk", &|b| b[0] = b'X');
        assert!(e.contains("magic"), "{e}");
        let e = corrupt("version.sympk", &|b| b[8] = 99);
        assert!(e.contains("version 99"), "{e}");
        let e = corrupt("trunc.sympk", &|b| b.truncate(b.len() - 9));
        assert!(e.contains("truncated"), "{e}");
        let e = corrupt("layout.sympk", &|b| b[16..24].copy_from_slice(&34u64.to_le_bytes()));
        assert!(e.contains("layout mismatch"), "{e}");
        let last = pristine.len() - 1;
        let e = corrupt("payload.sympk", &move |b| b[last] ^= 0x40);
        assert!(e.contains("checksum"), "{e}");
        // absurd header dims must be rejected before any big allocation
        let e = corrupt("huge.sympk", &|b| {
            b[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
            b[24..32].copy_from_slice(&1u64.to_le_bytes());
        });
        assert!(e.contains("layout mismatch"), "{e}");
    }

    /// Transient tile-read failures heal inside the bounded retry — the
    /// apply still returns, bitwise-identical to the resident one — and
    /// the `spill_open`/`spill_write` fail points surface as plain
    /// errors on their normal error paths.
    #[test]
    fn transient_read_failures_heal_and_io_failpoints_inject_errors() {
        use crate::util::failpoint;
        let dir = TempDir::new("fp");
        let mut rng = Pcg64::seed_from_u64(21);
        let m = 33;
        let x = random_symmetric(m, &mut rng);
        let sp = SymPacked::from_dense_with_block(&x, 8);
        let path = dir.file("fp.sympk");

        {
            let _fp = failpoint::scoped("spill_write=err_once");
            let e = write_spill(&sp, &path).expect_err("armed write must fail");
            assert!(e.contains("injected error"), "{e}");
            write_spill(&sp, &path).expect("one-shot injection is spent");
        }
        {
            let _fp = failpoint::scoped("spill_open=err_once");
            let e = SymPackedSpilled::open(&path).expect_err("armed open must fail");
            assert!(e.contains("injected error"), "{e}");
        }

        let spilled = SymPackedSpilled::open(&path).unwrap();
        let f = DenseMat::gaussian(m, 4, &mut rng);
        let mut want = DenseMat::zeros(m, 4);
        sp.apply_blocked_into(&f, &mut want);
        // the first read attempt of the apply fails; the retry's second
        // attempt succeeds, so the apply completes — run single-threaded
        // so the hit sequence is deterministic
        let _fp = failpoint::scoped("spill_read=err@1");
        with_thread_budget(1, || {
            let mut got = DenseMat::zeros(m, 4);
            spilled.apply_blocked_into(&f, &mut got);
            assert_bitwise(&want, &got, "healed-retry apply");
        });
        assert!(failpoint::hits("spill_read") > 1, "retry re-attempted the read");
    }

    /// FNV-1a reference vectors (the standard test values), so the
    /// checksum/content-hash primitive itself is pinned.
    #[test]
    fn fnv1a_reference_vectors() {
        let h = Fnv64::new();
        assert_eq!(h.finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv64::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv64::new();
        h.write(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
        // chunked writes equal one-shot writes
        let mut a = Fnv64::new();
        a.write(b"foo");
        a.write(b"bar");
        assert_eq!(a.finish(), h.finish());
    }
}
