//! Convergence metrics (paper App. C): the normalized residual via the
//! trace trick (App. C.2) and the projected gradient norm (App. C.3).

use crate::linalg::{blas, DenseMat};

/// ‖X − W·Hᵀ‖²_F via the App. C.2 trace trick:
///     ‖X‖² + tr((WᵀW)(HᵀH)) − 2·tr(Wᵀ·(XH))
/// reusing the already-computed product XH and Gram matrices, so the
/// check is almost free each iteration.
pub fn residual_sq_from_products(
    x_norm_sq: f64,
    xh: &DenseMat, // X·H (m×k)
    w: &DenseMat,  // m×k
    gw: &DenseMat, // WᵀW (k×k, WITHOUT α)
    gh: &DenseMat, // HᵀH (k×k, WITHOUT α)
) -> f64 {
    let k = gw.rows();
    // tr((WᵀW)(HᵀH)) = Σ_ij gw_ij · gh_ji = Σ_ij gw_ij · gh_ij (sym)
    let mut tr_gram = 0.0;
    for i in 0..k {
        tr_gram += blas::dot(gw.row(i), gh.row(i));
    }
    // tr(Wᵀ(XH)) = Σ_ij W_ij (XH)_ij
    let tr_wxh = blas::dot(w.data(), xh.data());
    (x_norm_sq + tr_gram - 2.0 * tr_wxh).max(0.0)
}

/// Normalized residual ‖X − WHᵀ‖_F / ‖X‖_F.
pub fn normalized_residual(
    x_norm_sq: f64,
    xh: &DenseMat,
    w: &DenseMat,
    gw: &DenseMat,
    gh: &DenseMat,
) -> f64 {
    (residual_sq_from_products(x_norm_sq, xh, w, gw, gh) / x_norm_sq.max(1e-300)).sqrt()
}

/// Projected gradient norm of the *symmetric* objective (App. C.3,
/// Eq. C.7): ∇f_H = 4(HHᵀ − X)H = 4(H·(HᵀH) − XH), projected per
/// Eq. C.6 (free entries, plus negative components at the boundary).
pub fn projected_gradient_norm_sym(h: &DenseMat, xh: &DenseMat, gh: &DenseMat) -> f64 {
    let (m, k) = h.shape();
    assert_eq!(xh.shape(), (m, k));
    let hg = blas::matmul(h, gh);
    let mut acc = 0.0;
    for i in 0..m {
        let hrow = h.row(i);
        let hgrow = hg.row(i);
        let xhrow = xh.row(i);
        for j in 0..k {
            let g = 4.0 * (hgrow[j] - xhrow[j]);
            if g < 0.0 || hrow[j] > 0.0 {
                acc += g * g;
            }
        }
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn trace_trick_matches_explicit() {
        let mut rng = Pcg64::seed_from_u64(1);
        let (m, k) = (20, 4);
        let mut x = DenseMat::gaussian(m, m, &mut rng);
        x.symmetrize();
        let mut w = DenseMat::gaussian(m, k, &mut rng);
        w.project_nonneg();
        let mut h = DenseMat::gaussian(m, k, &mut rng);
        h.project_nonneg();
        let xh = blas::matmul(&x, &h);
        let gw = blas::gram(&w);
        let gh = blas::gram(&h);
        let fast = residual_sq_from_products(x.fro_norm_sq(), &xh, &w, &gw, &gh);
        let rec = blas::matmul_nt(&w, &h);
        let mut d = x.clone();
        d.axpy(-1.0, &rec);
        let explicit = d.fro_norm_sq();
        assert!(
            (fast - explicit).abs() < 1e-8 * (1.0 + explicit),
            "fast {fast} explicit {explicit}"
        );
    }

    #[test]
    fn residual_zero_at_exact_factorization() {
        let mut rng = Pcg64::seed_from_u64(2);
        let h = DenseMat::uniform(15, 3, 1.0, &mut rng);
        let x = blas::matmul_nt(&h, &h);
        let xh = blas::matmul(&x, &h);
        let g = blas::gram(&h);
        let r = normalized_residual(x.fro_norm_sq(), &xh, &h, &g, &g);
        assert!(r < 1e-10, "r={r}");
    }

    #[test]
    fn projected_gradient_zero_at_stationary_interior() {
        // At an exact strictly-positive factorization the gradient is 0.
        let mut rng = Pcg64::seed_from_u64(3);
        let mut h = DenseMat::uniform(12, 3, 1.0, &mut rng);
        for v in h.data_mut() {
            *v += 0.1; // strictly positive
        }
        let x = blas::matmul_nt(&h, &h);
        let xh = blas::matmul(&x, &h);
        let gh = blas::gram(&h);
        let pg = projected_gradient_norm_sym(&h, &xh, &gh);
        assert!(pg < 1e-8, "pg={pg}");
    }

    #[test]
    fn boundary_entries_with_positive_gradient_excluded() {
        // H=0 at an entry whose gradient is positive (pushing further
        // negative is blocked) → that entry contributes nothing.
        let h = DenseMat::zeros(2, 1);
        let x = DenseMat::from_vec(2, 2, vec![-1.0, 0.0, 0.0, -1.0]);
        let xh = blas::matmul(&x, &h); // zero
        let gh = blas::gram(&h); // zero
        let pg = projected_gradient_norm_sym(&h, &xh, &gh);
        assert_eq!(pg, 0.0);
    }
}
