//! Experiment coordination: method dispatch ([`driver::Method`]),
//! multi-trial aggregation, per-figure experiment definitions matching
//! the paper's §5 evaluation, and table/CSV reporting.

pub mod driver;
pub mod experiments;
pub mod report;

pub use driver::{Method, MethodStats};
