//! Thin QR decompositions and row leverage scores.
//!
//! The paper computes exact leverage scores of the factor matrices every
//! iteration via **CholeskyQR** (§4.2: "CholeskyQR is numerically less
//! stable than Householder QR but faster and empirically we find that it
//! works well for computing leverage scores"). We implement both:
//! CholeskyQR is the fast path, Householder the stable fallback and test
//! oracle.

use crate::linalg::workspace::SampleWorkspace;
use crate::linalg::{blas, chol, DenseMat};

/// Thin QR via CholeskyQR: G = FᵀF = RᵀR, Q = F·R⁻¹. Cost O(mk²).
/// Falls back to jittered Cholesky if G is numerically semidefinite.
pub fn cholesky_qr(f: &DenseMat) -> (DenseMat, DenseMat) {
    let g = blas::gram(f);
    let (r, _eps) = chol::cholesky_upper_jittered(&g);
    let q = chol::solve_right_upper(f, &r);
    (q, r)
}

/// Orthonormal basis for range(F): CholeskyQR fast path, Householder
/// fallback when the Gram matrix needed diagonal jitter (rank-deficient
/// or extremely ill-conditioned F, where CholQR's orthogonality breaks).
/// This is the per-power-step orthonormalization of the RRF (§Perf).
pub fn orthonormalize(f: &DenseMat) -> DenseMat {
    let g = blas::gram(f);
    let scale = (0..g.rows()).map(|i| g.at(i, i)).fold(0.0f64, f64::max);
    match chol::cholesky_upper(&g) {
        Ok(r) => {
            // reject borderline factors: tiny trailing pivot → CholQR
            // orthogonality loss
            let min_piv = (0..r.rows()).map(|i| r.at(i, i)).fold(f64::INFINITY, f64::min);
            if min_piv * min_piv > scale * 1e-10 {
                return chol::solve_right_upper(f, &r);
            }
            householder_qr(f).0
        }
        Err(_) => householder_qr(f).0,
    }
}

/// Thin Householder QR (returns Q: m×k with orthonormal columns, R: k×k
/// upper-triangular). O(mk²), numerically robust; used as the oracle and
/// inside the RRF where orthonormality quality matters across power
/// iterations.
pub fn householder_qr(f: &DenseMat) -> (DenseMat, DenseMat) {
    let (m, k) = f.shape();
    assert!(m >= k, "householder_qr expects a tall matrix, got {m}x{k}");
    let mut a = f.clone();
    // Householder vectors stored below the diagonal of `a`; betas aside.
    let mut betas = vec![0.0f64; k];
    for j in 0..k {
        // norm of column j below row j
        let mut norm_sq = 0.0;
        for i in j..m {
            let v = a.at(i, j);
            norm_sq += v * v;
        }
        let norm = norm_sq.sqrt();
        if norm == 0.0 {
            betas[j] = 0.0;
            continue;
        }
        let a0 = a.at(j, j);
        let alpha = if a0 >= 0.0 { -norm } else { norm };
        // v = x - alpha e1, normalized so v[0] = 1
        let v0 = a0 - alpha;
        betas[j] = -v0 / alpha; // beta = 2/(vᵀv) with v0=1 scaling
        for i in (j + 1)..m {
            *a.at_mut(i, j) /= v0;
        }
        a.set(j, j, alpha);
        // apply reflector to trailing columns
        for c in (j + 1)..k {
            let mut s = a.at(j, c);
            for i in (j + 1)..m {
                s += a.at(i, j) * a.at(i, c);
            }
            s *= betas[j];
            *a.at_mut(j, c) -= s;
            for i in (j + 1)..m {
                let vij = a.at(i, j);
                *a.at_mut(i, c) -= s * vij;
            }
        }
    }
    // R is the upper triangle
    let mut r = DenseMat::zeros(k, k);
    for i in 0..k {
        for j in i..k {
            r.set(i, j, a.at(i, j));
        }
    }
    // form thin Q by applying reflectors to the first k columns of I
    let mut q = DenseMat::zeros(m, k);
    for i in 0..k {
        q.set(i, i, 1.0);
    }
    for j in (0..k).rev() {
        if betas[j] == 0.0 {
            continue;
        }
        for c in 0..k {
            let mut s = q.at(j, c);
            for i in (j + 1)..m {
                s += a.at(i, j) * q.at(i, c);
            }
            s *= betas[j];
            *q.at_mut(j, c) -= s;
            for i in (j + 1)..m {
                let vij = a.at(i, j);
                *q.at_mut(i, c) -= s * vij;
            }
        }
    }
    (q, r)
}

/// Row leverage scores l_i = ‖Q[i,:]‖² (paper Eq. 2.10) from any matrix
/// with orthonormal columns. Σ l_i = k.
pub fn leverage_scores_from_q(q: &DenseMat) -> Vec<f64> {
    (0..q.rows())
        .map(|i| blas::dot(q.row(i), q.row(i)))
        .collect()
}

/// Leverage scores of a tall full-rank matrix F via CholeskyQR. O(mk²).
pub fn leverage_scores(f: &DenseMat) -> Vec<f64> {
    leverage_scores_via_chol(f)
}

/// Q-free leverage scores (§Perf): l_i = ‖R⁻ᵀ f_i‖² with G = FᵀF = RᵀR.
/// Never materializes the m×k Q — each row's forward substitution runs in
/// a k-sized stack buffer, saving 2·m·k·8 bytes of traffic per call
/// (called twice per LvS iteration).
pub fn leverage_scores_via_chol(f: &DenseMat) -> Vec<f64> {
    let (m, k) = f.shape();
    let g = blas::gram(f);
    let (r, _eps) = chol::cholesky_upper_jittered(&g);
    let mut z = vec![0.0f64; k];
    let mut out = Vec::with_capacity(m);
    for i in 0..m {
        let fi = f.row(i);
        // solve Rᵀ z = f_i (forward substitution; Rᵀ is lower-triangular)
        for a in 0..k {
            let mut v = fi[a];
            for b in 0..a {
                v -= r.at(b, a) * z[b];
            }
            z[a] = v / r.at(a, a);
        }
        out.push(blas::dot(&z, &z));
    }
    out
}

/// [`leverage_scores_via_chol`] threaded through the persistent sample
/// workspace: the Gram, the jitter scratch, the Cholesky factor, the
/// k-sized substitution buffer and the score vector all live in `ws`, so
/// the per-iteration call performs no heap allocation once the buffers
/// are warm (the k×k mats re-shape only if k changes). Identical FP
/// order to the allocating form — the scores land in `ws.leverage`
/// bitwise-equal.
pub fn leverage_scores_via_chol_into(f: &DenseMat, ws: &mut SampleWorkspace) {
    let (m, k) = f.shape();
    if ws.chol_g.shape() != (k, k) {
        ws.chol_g = DenseMat::zeros(k, k);
        ws.chol_scratch = DenseMat::zeros(k, k);
        ws.chol_r = DenseMat::zeros(k, k);
    }
    if ws.z.len() != k {
        ws.z.clear();
        ws.z.resize(k, 0.0);
    }
    blas::gram_into(f, &mut ws.chol_g);
    let _eps = chol::cholesky_upper_jittered_into(&ws.chol_g, &mut ws.chol_scratch, &mut ws.chol_r);
    let r = &ws.chol_r;
    let z = &mut ws.z;
    let out = &mut ws.leverage;
    out.clear();
    out.reserve(m);
    for i in 0..m {
        let fi = f.row(i);
        // solve Rᵀ z = f_i (forward substitution; Rᵀ is lower-triangular)
        for a in 0..k {
            let mut v = fi[a];
            for b in 0..a {
                v -= r.at(b, a) * z[b];
            }
            z[a] = v / r.at(a, a);
        }
        out.push(blas::dot(&z[..], &z[..]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{dim, forall};
    use crate::util::rng::Pcg64;

    fn check_qr(f: &DenseMat, q: &DenseMat, r: &DenseMat, tol: f64) -> Result<(), String> {
        let k = f.cols();
        let qtq = blas::gram(q);
        let orth_err = qtq.diff_fro(&DenseMat::eye(k));
        if orth_err > tol {
            return Err(format!("QᵀQ−I = {orth_err:.2e}"));
        }
        let qr = blas::matmul(q, r);
        let rec_err = qr.diff_fro(f) / (1.0 + f.fro_norm());
        if rec_err > tol {
            return Err(format!("QR−F = {rec_err:.2e}"));
        }
        Ok(())
    }

    #[test]
    fn cholesky_qr_property() {
        forall(
            20,
            400,
            |rng| {
                let k = dim(rng, 1, 12);
                let m = k + dim(rng, 0, 40);
                DenseMat::gaussian(m, k, rng)
            },
            |f| {
                let (q, r) = cholesky_qr(f);
                check_qr(f, &q, &r, 1e-8)
            },
        );
    }

    #[test]
    fn householder_qr_property() {
        forall(
            20,
            500,
            |rng| {
                let k = dim(rng, 1, 12);
                let m = k + dim(rng, 0, 40);
                DenseMat::gaussian(m, k, rng)
            },
            |f| {
                let (q, r) = householder_qr(f);
                check_qr(f, &q, &r, 1e-10)
            },
        );
    }

    #[test]
    fn householder_handles_ill_conditioned() {
        // nearly collinear columns — CholeskyQR squares the condition
        // number; Householder must still produce an orthonormal Q.
        let mut rng = Pcg64::seed_from_u64(77);
        let base = rng.gaussian_vec(60);
        let f = DenseMat::from_fn(60, 3, |i, j| {
            base[i] + 1e-7 * (i as f64 * (j as f64 + 1.0)).sin()
        });
        let (q, _r) = householder_qr(&f);
        let orth = blas::gram(&q).diff_fro(&DenseMat::eye(3));
        assert!(orth < 1e-8, "orth err {orth}");
    }

    #[test]
    fn leverage_scores_sum_to_k() {
        forall(
            15,
            600,
            |rng| {
                let k = dim(rng, 1, 10);
                let m = k + dim(rng, 5, 60);
                DenseMat::gaussian(m, k, rng)
            },
            |f| {
                let l = leverage_scores(f);
                let sum: f64 = l.iter().sum();
                let k = f.cols() as f64;
                if l.iter().all(|&x| x >= -1e-12 && x <= 1.0 + 1e-8)
                    && (sum - k).abs() < 1e-6
                {
                    Ok(())
                } else {
                    Err(format!("sum={sum}, k={k}"))
                }
            },
        );
    }

    /// The workspace-threaded leverage scores are bitwise-equal to the
    /// allocating oracle, including across reuse of one warm workspace
    /// at a different m (grow-only buffers).
    #[test]
    fn leverage_scores_into_matches_allocating_bitwise() {
        let mut rng = Pcg64::seed_from_u64(31);
        let mut ws = SampleWorkspace::new(0, 0, 0); // cold: must warm up lazily
        for (m, k) in [(40usize, 4usize), (9, 3), (65, 4)] {
            let f = DenseMat::gaussian(m, k, &mut rng);
            let want = leverage_scores_via_chol(&f);
            leverage_scores_via_chol_into(&f, &mut ws);
            assert_eq!(ws.leverage.len(), want.len());
            for (a, b) in ws.leverage.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "m={m} k={k}");
            }
        }
    }

    #[test]
    fn leverage_scores_detect_spiked_row() {
        // One huge row dominates the column space → its score → ~1.
        let mut rng = Pcg64::seed_from_u64(21);
        let mut f = DenseMat::gaussian(100, 4, &mut rng);
        for j in 0..4 {
            f.set(17, j, 1000.0 * (j as f64 + 1.0));
        }
        let l = leverage_scores(&f);
        assert!(l[17] > 0.99, "spiked row score {}", l[17]);
    }
}
