//! Stochastic block model citation-graph generator (Microsoft-OAG
//! stand-in for §5.2).
//!
//! The paper found the OAG decomposes into one dominant cluster plus many
//! small communities (§5.2.1); the generator therefore supports highly
//! skewed block sizes (a "core" block plus k−1 small blocks). Edge counts
//! per block pair are sampled Poisson-approximately (expected-count
//! rounding + random endpoints), which scales to millions of edges
//! without touching the O(m²) pair space.

use crate::sparse::CsrMat;
use crate::util::rng::Pcg64;

/// SBM parameters.
pub struct SbmParams {
    /// block sizes (sum = number of vertices)
    pub sizes: Vec<usize>,
    /// expected within-block degree (per vertex)
    pub degree_within: f64,
    /// expected cross-block degree (per vertex)
    pub degree_across: f64,
    /// within-degree override for block 0 (the "core"); None → degree_within.
    /// Real citation graphs' giant component is much denser than the small
    /// communities — and under symmetric normalization a LOWER small-block
    /// degree gives those blocks HIGHER per-edge weight (stronger planted
    /// signal), matching the §5.2 regime where the small clusters are
    /// sharply separable.
    pub core_degree: Option<f64>,
    pub seed: u64,
}

impl SbmParams {
    /// The §5.2-shaped default: one core block holding `core_frac` of the
    /// vertices and k−1 equal small blocks.
    pub fn skewed(m: usize, k: usize, core_frac: f64, seed: u64) -> SbmParams {
        assert!(k >= 2);
        let core = ((m as f64) * core_frac) as usize;
        let rest = m - core;
        let small = rest / (k - 1);
        let mut sizes = vec![core];
        for i in 0..(k - 1) {
            // last block absorbs the rounding remainder
            sizes.push(if i + 2 == k { rest - small * (k - 2) } else { small });
        }
        SbmParams { sizes, degree_within: 20.0, degree_across: 2.0, core_degree: None, seed }
    }

    pub fn with_degrees(mut self, within: f64, across: f64) -> SbmParams {
        self.degree_within = within;
        self.degree_across = across;
        self
    }

    pub fn with_core_degree(mut self, core: f64) -> SbmParams {
        self.core_degree = Some(core);
        self
    }
}

/// Generated graph: adjacency + planted block labels.
pub struct SbmGraph {
    pub adj: CsrMat,
    pub labels: Vec<usize>,
}

/// Sample the SBM; the adjacency is unweighted (1.0), symmetric, with no
/// self loops or duplicate edges.
pub fn generate(params: &SbmParams) -> SbmGraph {
    let mut rng = Pcg64::seed_from_u64(params.seed);
    let k = params.sizes.len();
    let m: usize = params.sizes.iter().sum();
    let offsets: Vec<usize> = params
        .sizes
        .iter()
        .scan(0usize, |acc, &s| {
            let o = *acc;
            *acc += s;
            Some(o)
        })
        .collect();
    let mut labels = vec![0usize; m];
    for (b, (&off, &sz)) in offsets.iter().zip(&params.sizes).enumerate() {
        for v in off..off + sz {
            labels[v] = b;
        }
    }

    let mut edges: std::collections::HashSet<(usize, usize)> =
        std::collections::HashSet::new();
    for bi in 0..k {
        for bj in bi..k {
            let ni = params.sizes[bi] as f64;
            let nj = params.sizes[bj] as f64;
            // expected edges: within block → n·deg/2; across → balanced
            // split of the per-vertex across-degree over other blocks
            let expected = if bi == bj {
                let deg = if bi == 0 {
                    params.core_degree.unwrap_or(params.degree_within)
                } else {
                    params.degree_within
                };
                ni * deg / 2.0
            } else {
                // proportional allocation of across-degree
                ni * params.degree_across * (nj / (m as f64 - ni))
            };
            let count = poisson_round(expected, &mut rng);
            for _ in 0..count {
                let u = offsets[bi] + rng.below(params.sizes[bi]);
                let v = offsets[bj] + rng.below(params.sizes[bj]);
                if u == v {
                    continue;
                }
                let key = (u.min(v), u.max(v));
                edges.insert(key);
            }
        }
    }
    let mut trips = Vec::with_capacity(edges.len() * 2);
    for (u, v) in edges {
        trips.push((u, v, 1.0));
        trips.push((v, u, 1.0));
    }
    let adj = CsrMat::from_coo(m, m, trips);
    SbmGraph { adj, labels }
}

/// Cheap Poisson-ish rounding of an expected count (exact Poisson is
/// unnecessary at these magnitudes: relative sd ~ 1/√λ).
fn poisson_round(lambda: f64, rng: &mut Pcg64) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    if lambda < 30.0 {
        // Knuth's method for small λ
        let l = (-lambda).exp();
        let mut kk = 0usize;
        let mut p = 1.0;
        loop {
            p *= rng.uniform();
            if p <= l {
                return kk;
            }
            kk += 1;
        }
    }
    // Gaussian approximation for large λ
    ((lambda + lambda.sqrt() * rng.gaussian()).round().max(0.0)) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_symmetry() {
        let p = SbmParams::skewed(500, 4, 0.5, 1);
        let g = generate(&p);
        assert_eq!(g.adj.rows(), 500);
        assert!(g.adj.is_symmetric(1e-12));
        assert_eq!(g.labels.len(), 500);
        // no self loops
        for i in 0..500 {
            assert_eq!(g.adj.get(i, i), 0.0);
        }
    }

    #[test]
    fn degrees_roughly_match() {
        let p = SbmParams {
            sizes: vec![300, 300],
            degree_within: 20.0,
            degree_across: 2.0,
            core_degree: None,
            seed: 2,
        };
        let g = generate(&p);
        let avg_deg = g.adj.nnz() as f64 / 600.0;
        assert!(
            (avg_deg - 22.0).abs() < 5.0,
            "avg degree {avg_deg}, expected ≈ 22"
        );
    }

    #[test]
    fn skewed_sizes_sum_to_m() {
        let p = SbmParams::skewed(1000, 16, 0.55, 3);
        assert_eq!(p.sizes.iter().sum::<usize>(), 1000);
        assert_eq!(p.sizes.len(), 16);
        assert!(p.sizes[0] > 5 * p.sizes[1], "core block dominates");
    }

    #[test]
    fn within_block_density_higher() {
        let p = SbmParams {
            sizes: vec![200, 200],
            degree_within: 30.0,
            degree_across: 2.0,
            core_degree: None,
            seed: 4,
        };
        let g = generate(&p);
        let mut within = 0usize;
        let mut across = 0usize;
        for i in 0..400 {
            let (cols, _) = g.adj.row(i);
            for &j in cols {
                if g.labels[i] == g.labels[j] {
                    within += 1;
                } else {
                    across += 1;
                }
            }
        }
        assert!(within > 5 * across, "within {within} across {across}");
    }
}
