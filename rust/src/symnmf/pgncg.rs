//! Projected Gauss–Newton with Conjugate Gradients (paper §2.1.3, [22])
//! and its LAI variant (App. B.2, Alg. LAI-PGNCG-SymNMF).
//!
//! The all-at-once method minimizes ‖X − HHᵀ‖ directly. Each outer step
//! solves the Gauss–Newton normal equations JᵀJ·z = g approximately with
//! CG, exploiting the Kronecker structure of J so that the JᵀJ-product is
//! two skinny matmuls (line 11 of Alg. LAI-PGNCG):
//!
//! ```text
//!     Y = 2(P·(HᵀH) + H·(PᵀH)),   g = −2·(X·H − H·(HᵀH))
//! ```
//!
//! then projects: H ← [H − Z]_+. The only X-sized work per outer
//! iteration is the single product X·H — which is why LAI substitution
//! (X·H → U(VᵀH)) accelerates PGNCG just as well as the AU methods,
//! something the compression-based randomized NMF methods cannot do
//! (paper §3.4).

use crate::linalg::{blas, DenseMat};
use crate::randnla::SymOp;
use crate::symnmf::anls::Metrics;
use crate::symnmf::init::initial_factor;
use crate::symnmf::lai::build_lai;
use crate::symnmf::metrics::{IterRecord, StopRule, SymNmfResult};
use crate::symnmf::options::SymNmfOptions;
use crate::util::rng::Pcg64;
use crate::util::timer::{PhaseTimer, Stopwatch, PHASE_MM, PHASE_SOLVE};

/// One CG solve of JᵀJ·Z ≈ R₀ (Gauss–Newton direction). `g` = HᵀH is held
/// fixed during the inner solve. Returns Z.
fn cg_direction(h: &DenseMat, g: &DenseMat, r0: DenseMat, iters: usize) -> DenseMat {
    let mut z = DenseMat::zeros(h.rows(), h.cols());
    let mut r = r0;
    let mut p = r.clone();
    let mut e_old = r.fro_norm_sq();
    if e_old == 0.0 {
        return z;
    }
    for _ in 0..iters {
        // Y = JᵀJ·P = 2(P·G + H·(PᵀH))
        let pth = blas::matmul_tn(&p, h);
        let mut y = blas::matmul(&p, g);
        let hp = blas::matmul(h, &pth);
        y.axpy(1.0, &hp);
        y.scale(2.0);
        let py = blas::dot(p.data(), y.data());
        if py.abs() < 1e-300 {
            break;
        }
        let a = e_old / py;
        z.axpy(a, &p);
        r.axpy(-a, &y);
        let e_new = r.fro_norm_sq();
        if e_new.sqrt() < 1e-12 {
            break;
        }
        let beta = e_new / e_old;
        // p = r + beta·p
        let mut p_next = r.clone();
        p_next.axpy(beta, &p);
        p = p_next;
        e_old = e_new;
    }
    z
}

/// Shared PGNCG loop over any operator (`x_iter` drives the iteration,
/// `metrics` measures against the true X).
fn run_pgncg_loop(
    x_iter: &dyn SymOp,
    opts: &SymNmfOptions,
    mut h: DenseMat,
    metrics: &Metrics,
    label: String,
    setup_secs: f64,
    mut phases: PhaseTimer,
) -> SymNmfResult {
    let mut records: Vec<IterRecord> = Vec::new();
    let mut stop = StopRule::new(opts.tol, opts.patience);
    let mut clock = setup_secs;

    for iter in 0..opts.max_iters {
        let sw = Stopwatch::start();
        let t = Stopwatch::start();
        let xh = x_iter.apply(&h);
        let g = blas::gram(&h);
        let mm = t.elapsed_secs();

        let t = Stopwatch::start();
        // gradient direction: R = −g/2 form: R₀ = 2(XH − H·G) is the CG
        // right-hand side (−gradient); Alg. LAI-PGNCG phrases it with the
        // opposite sign and a minus in the final update — equivalent.
        let hg = blas::matmul(&h, &g);
        let mut r0 = xh;
        r0.axpy(-1.0, &hg);
        r0.scale(2.0);
        let z = cg_direction(&h, &g, r0, opts.cg_iters);
        // H ← [H + Z]_+ (Z approximates the Newton step along −gradient)
        h.axpy(1.0, &z);
        h.project_nonneg();
        let solve = t.elapsed_secs();

        clock += sw.elapsed_secs();
        phases.add(PHASE_MM, std::time::Duration::from_secs_f64(mm));
        phases.add(PHASE_SOLVE, std::time::Duration::from_secs_f64(solve));

        let (res, pg) = metrics.eval(&h, &h);
        records.push(IterRecord {
            iter,
            time_secs: clock,
            residual: res,
            proj_grad: pg,
            phase_secs: (mm, solve, 0.0),
            hybrid_stats: None,
        });
        if stop.update(res) {
            break;
        }
    }

    SymNmfResult { label, h: h.clone(), w: h, records, phases, setup_secs }
}

/// PGNCG-SymNMF on the exact X (the paper's "PGNCG" baseline).
pub fn pgncg_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let h0 = initial_factor(x, opts, &mut rng);
    let metrics = Metrics::new(x, true);
    run_pgncg_loop(
        x,
        opts,
        h0,
        &metrics,
        "PGNCG".to_string(),
        0.0,
        PhaseTimer::new(),
    )
}

/// LAI-PGNCG-SymNMF (App. B.2): identical loop against the factored LAI;
/// with `opts.refine`, iterative refinement on the true X afterwards
/// ("PGNCG-IR" rows of Table 2).
pub fn lai_pgncg_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let mut phases = PhaseTimer::new();
    let (lai, setup_secs, _evd) = build_lai(x, opts, &mut rng, &mut phases);
    let h0 = initial_factor(x, opts, &mut rng);
    let metrics = Metrics::new(x, true);
    let result = run_pgncg_loop(
        &lai,
        opts,
        h0,
        &metrics,
        "LAI-PGNCG".to_string(),
        setup_secs,
        phases,
    );
    if !opts.refine {
        return result;
    }
    let clock = result.total_secs();
    let refined = run_pgncg_loop(
        x,
        opts,
        result.h.clone(),
        &metrics,
        "LAI-PGNCG-IR".to_string(),
        clock,
        result.phases.clone(),
    );
    let mut records = result.records;
    let offset = records.len();
    records.extend(refined.records.into_iter().map(|mut r| {
        r.iter += offset;
        r
    }));
    SymNmfResult {
        label: "LAI-PGNCG-IR".to_string(),
        h: refined.h,
        w: refined.w,
        records,
        phases: refined.phases,
        setup_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        x.symmetrize();
        x
    }

    #[test]
    fn pgncg_converges_on_planted() {
        let x = planted(50, 3, 1);
        let mut opts = SymNmfOptions::new(3).with_seed(2);
        opts.max_iters = 80;
        opts.cg_iters = 15;
        let res = pgncg_symnmf(&x, &opts);
        assert!(res.h.is_nonneg());
        let last = res.min_residual();
        let first = res.records.first().unwrap().residual;
        assert!(last < 0.5 * first, "residual {first} → {last}");
    }

    #[test]
    fn cg_direction_solves_psd_system_when_unconstrained() {
        // JᵀJ is PSD but can be singular; pick an RHS in its range
        // (r0 = JᵀJ·y for random y) so CG must recover it exactly.
        let mut rng = Pcg64::seed_from_u64(3);
        let h = DenseMat::uniform(12, 3, 1.0, &mut rng);
        let g = blas::gram(&h);
        let y0 = DenseMat::gaussian(12, 3, &mut rng);
        let r0 = {
            let yth = blas::matmul_tn(&y0, &h);
            let mut r = blas::matmul(&y0, &g);
            r.axpy(1.0, &blas::matmul(&h, &yth));
            r.scale(2.0);
            r
        };
        let z = cg_direction(&h, &g, r0.clone(), 400);
        // apply JᵀJ to z
        let zth = blas::matmul_tn(&z, &h);
        let mut y = blas::matmul(&z, &g);
        y.axpy(1.0, &blas::matmul(&h, &zth));
        y.scale(2.0);
        let rel = y.diff_fro(&r0) / r0.fro_norm();
        assert!(rel < 1e-6, "CG residual {rel}");
    }

    #[test]
    fn lai_pgncg_matches_quality() {
        let x = planted(60, 4, 4);
        let mut opts = SymNmfOptions::new(4).with_seed(5);
        opts.max_iters = 80;
        let exact = pgncg_symnmf(&x, &opts);
        let lai = lai_pgncg_symnmf(&x, &opts);
        assert!(
            lai.min_residual() < exact.min_residual() + 0.05,
            "LAI {} vs exact {}",
            lai.min_residual(),
            exact.min_residual()
        );
    }

    #[test]
    fn ir_label_and_continuation() {
        let x = planted(40, 3, 6);
        let mut opts = SymNmfOptions::new(3).with_seed(7);
        opts.max_iters = 20;
        opts.refine = true;
        let res = lai_pgncg_symnmf(&x, &opts);
        assert_eq!(res.label, "LAI-PGNCG-IR");
        for w in res.records.windows(2) {
            assert!(w[1].time_secs >= w[0].time_secs - 1e-12);
            assert_eq!(w[1].iter, w[0].iter + 1);
        }
    }
}
