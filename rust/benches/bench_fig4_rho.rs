//! Regenerates paper **Figure 4 + Tables 4–5** (App. G.1): the ρ
//! (column-oversampling) sweep on the WoS workload — ρ ∈ {2k, 40, 80}.
//!
//! Shape to reproduce: increasing ρ does NOT improve final residual or
//! ARI but DOES increase run time (Tables 4 vs 5 vs 2).
//!
//!     cargo bench --bench bench_fig4_rho
//! writes results/table4_5.txt

use symnmf::coordinator::driver::run_trials;
use symnmf::coordinator::experiments::{rho_sweep_methods, wos_options, wos_workload};
use symnmf::coordinator::report;

fn main() {
    let docs = std::env::var("SYMNMF_BENCH_DOCS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let trials = 2;
    println!("== Fig. 4 / Tables 4–5 bench: ρ sweep on WoS ({docs} docs) ==");
    let w = wos_workload(docs, 1);

    let mut out = String::new();
    for rho in [14usize, 40, 80] {
        // 14 = 2k for k=7 — the Table 2 default
        let mut opts = wos_options().with_seed(40);
        opts.rho = rho;
        opts.max_iters = 150;
        println!("--- ρ = {rho} (l = {}) ---", opts.sketch_width());
        let mut all = Vec::new();
        for method in rho_sweep_methods() {
            // deterministic rows don't depend on ρ; keep them for table parity
            let stats = run_trials(method, &w.adjacency, &opts, Some(&w.labels), trials);
            println!(
                "  {:<14} {:7.3}s  min-res {:.4}  ARI {:.3}",
                stats.label, stats.mean_time, stats.min_res, stats.mean_ari
            );
            all.push(stats);
        }
        out.push_str(&format!("ρ = {rho}\n"));
        out.push_str(&report::stats_table(&all));
        out.push('\n');
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table4_5.txt", &out).unwrap();
    println!("\nwrote results/table4_5.txt");
}
