#!/usr/bin/env python3
"""Kernel bench regression gate.

Compares the freshly generated BENCH_kernels.json against the committed
baseline, prints the per-kernel GFLOP/s delta table, and fails (exit 1)
when any gated kernel row regresses by more than the allowed fraction.

Every (op, shape) row present in BOTH files is gated, except rows on the
noisy allowlist: end-to-end trial drivers and sub-millisecond micro rows
bounce too much on shared CI runners for a hard gate (their deltas are
still printed). Rows with a positive GFLOP/s rate are gated on that rate
dropping; timing-only rows (gflops == 0, e.g. construction passes like
`from_csr_streamed`) are gated on secs_per_iter growing by more than the
allowed fraction.

Bootstrap behaviour: if the baseline is the bootstrap placeholder (its
header carries "bootstrap": true, or it simply has no measured rows),
the check still exits 0 so the first CI run can publish real numbers to
commit as the next baseline — but it shouts a WARNING to stderr instead
of passing quietly: a repo whose perf gate has never gated anything
should look unhealthy in the logs, not green-and-silent.

Provenance: the bench header records the dispatched kernel `isa` and the
`hostname` the numbers were measured on. Numbers taken under different
dispatch (or on a different box) are not comparable — a scalar baseline
vs an AVX-512 run would "regress" or "improve" by 2-8x without any code
change. When both files carry a value for a provenance field and the
values differ, the gate prints a loud WARNING and skips entirely
(exit 0): cross-host deltas are noise, not regressions. A missing/null
field on either side gates normally (pre-provenance baselines).
"""

import argparse
import json
import sys

# Rows exempt from the hard gate: wall-clock trial drivers (scheduling
# noise), sampling/solve micro-benches dominated by allocation and RNG,
# sub-millisecond packing passes, and the PJRT round-trip (artifact
# availability varies by runner).
DEFAULT_ALLOW_NOISY = [
    "trials_serial",
    "trials_batched",
    "trials_batched_budget",
    "sampled_spmm_into",
    "leverage_scores",
    "bpp_multi_into",
    "pack_b_panels_par",
    "pjrt_products",
    "native_products",
    # I/O-bound: streams the whole packed payload from disk per apply, so
    # the rate tracks the runner's page cache and storage, not the kernels
    "symm_spilled_apply_into",
    # sub-microsecond bookkeeping row (mutex + refcount bump) — pure
    # timer noise on shared runners; opcache_miss_build stays gated
    "opcache_hit",
    # nanoseconds-per-hit atomic load loop — tracks CPU frequency
    # scaling on shared runners, not any code path we gate
    "failpoint_unarmed_hit",
    # empty-body dispatch fan-out: microseconds of pure scheduler +
    # futex behavior, entirely at the mercy of a shared runner's load
    # (the pooled-beats-scoped claim is asserted by eye via the printed
    # ratio, not gated)
    "pool_fanout_overhead",
    "pool_fanout_scoped_ref",
    # short sampled products (s = m/20 rows): wall time swings with pool
    # scheduling on shared runners; the parallel-vs-serial-oracle ratio
    # is printed for the eye, and bitwise parity is what the test suite
    # gates
    "lvs_sampled_apply_dense",
    "lvs_sampled_apply_csr",
    "lvs_sampled_apply_packed",
    # sub-millisecond sampling pipeline (leverage scores + alias draws),
    # dominated by RNG and branchy alias-table walks — timer noise on
    # shared runners
    "lvs_sample_build",
]


def load_rows(path):
    """Returns (rows-by-(op, shape), provenance-header) for a bench file."""
    with open(path) as fh:
        doc = json.load(fh)
    rows = {}
    for rec in doc.get("kernels", []):
        rows[(rec["op"], rec.get("shape", ""))] = rec
    header = {
        "isa": doc.get("isa"),
        "hostname": doc.get("hostname"),
        "bootstrap": bool(doc.get("bootstrap", False)),
    }
    return rows, header


def provenance_mismatch(base_header, cur_header):
    """Fields where baseline and current both carry a value and disagree."""
    return [
        (field, base_header[field], cur_header[field])
        for field in ("isa", "hostname")
        if base_header.get(field) is not None
        and cur_header.get(field) is not None
        and base_header[field] != cur_header[field]
    ]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_kernels.json")
    ap.add_argument("--current", required=True, help="freshly generated BENCH_kernels.json")
    ap.add_argument(
        "--allow-noisy",
        default=",".join(DEFAULT_ALLOW_NOISY),
        help="comma-separated ops exempt from the hard gate "
        "(default: %(default)s)",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.05,
        help="allowed fractional GFLOP/s drop per gated row (default 5%%)",
    )
    args = ap.parse_args(argv)

    allow_noisy = {op.strip() for op in args.allow_noisy.split(",") if op.strip()}
    base, base_header = load_rows(args.baseline)
    cur, cur_header = load_rows(args.current)

    mismatched = provenance_mismatch(base_header, cur_header)
    if mismatched:
        for field, bval, cval in mismatched:
            print(
                f"WARNING: baseline {field}={bval!r} but current run has "
                f"{field}={cval!r} — these numbers are not comparable; "
                "SKIPPING the regression gate for this pair. Re-measure "
                "the baseline under the same dispatch/host to restore "
                "gating.",
                file=sys.stderr,
            )
        return 0

    failures = []
    gated = 0
    print(
        f"{'op':<24} {'shape':<24} {'base GF/s':>10} {'cur GF/s':>10} "
        f"{'delta':>8}  gate"
    )
    for key in sorted(cur):
        op, shape = key
        c = cur[key]
        cg = c.get("gflops", 0.0)
        cs = c.get("secs_per_iter", 0.0)
        b = base.get(key)
        bg_str, delta, verdict = "-", "  (new)", "-"
        if b is not None and b.get("gflops", 0.0) > 0.0:
            # rate-gated row: fail when GFLOP/s drops past the floor
            bgf = b["gflops"]
            bg_str = f"{bgf:10.2f}"
            delta = f"{100.0 * (cg - bgf) / bgf:+7.1f}%"
            if cg <= 0.0:
                verdict = "skip (no rate)"
            elif op in allow_noisy:
                verdict = "skip (noisy)"
            else:
                gated += 1
                floor = bgf * (1.0 - args.max_regression)
                if cg < floor:
                    verdict = "FAIL"
                    failures.append(
                        f"{op} [{shape}] regressed: {cg:.2f} GF/s < "
                        f"{floor:.2f} GF/s ({bgf:.2f} baseline, "
                        f"-{args.max_regression:.0%} allowed)"
                    )
                else:
                    verdict = "ok"
        elif b is not None and b.get("secs_per_iter", 0.0) > 0.0:
            # timing-gated row (baseline has no rate — even if the current
            # run gained one, keep gating on time so the row never
            # silently falls out of the gate): fail when secs/iter grows
            # past the ceiling
            bs = b["secs_per_iter"]
            delta = f"{100.0 * (cs - bs) / bs:+7.1f}%"
            if cs <= 0.0:
                verdict = "skip (no time)"
            elif op in allow_noisy:
                verdict = "skip (noisy)"
            else:
                gated += 1
                ceiling = bs * (1.0 + args.max_regression)
                if cs > ceiling:
                    verdict = "FAIL"
                    failures.append(
                        f"{op} [{shape}] regressed: {cs:.6f} s/iter > "
                        f"{ceiling:.6f} s/iter ({bs:.6f} baseline, "
                        f"+{args.max_regression:.0%} allowed)"
                    )
                else:
                    verdict = "ok (time)"
        print(f"{op:<24} {shape:<24} {bg_str:>10} {cg:>10.2f} {delta:>8}  {verdict}")

    measured_base = [
        r
        for r in base.values()
        if r.get("gflops", 0.0) > 0.0 or r.get("secs_per_iter", 0.0) > 0.0
    ]
    if base_header.get("bootstrap") or not measured_base:
        print(
            "WARNING: the committed baseline is a bootstrap placeholder "
            "with no measured rows — NOTHING WAS GATED on this run. The "
            "perf gate is green only because it has no baseline to gate "
            "against. Run `cargo bench --bench bench_kernels` on the "
            "canonical runner and commit the generated BENCH_kernels.json "
            "(the bench-regression CI job uploads it as an artifact) to "
            "arm the gate.",
            file=sys.stderr,
        )
        return 0
    if not cur:
        print("ERROR: current run produced no kernel rows", file=sys.stderr)
        return 1

    # A gated row that VANISHES from the current run must fail too —
    # otherwise renaming or dropping a bench section silently un-gates it.
    for key in sorted(base):
        op, shape = key
        gated_row = (
            base[key].get("gflops", 0.0) > 0.0
            or base[key].get("secs_per_iter", 0.0) > 0.0
        )
        if key in cur or op in allow_noisy or not gated_row:
            continue
        failures.append(
            f"gated baseline row {op} [{shape}] is missing from the "
            "current run (renamed or dropped bench section?)"
        )

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    print(
        f"OK: {gated} gated row(s) within -{args.max_regression:.0%} of baseline"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
