//! Filesystem-backed checkpoint persistence, keyed by job id.
//!
//! Layout: one JSON file per (job, generation) under the store
//! directory — `<id>.g<gen 8-digit>.ckpt.json`, written atomically
//! (temp file + rename) so a reader never observes a torn checkpoint.
//! Every save bumps the generation and then garbage-collects superseded
//! generations beyond the configured retention (default: keep only the
//! newest), because full checkpoints embed the factors — and, in the
//! full (version 1) encoding, the whole residual history — at 16 hex
//! chars per f64: without GC a long-running job would accumulate
//! `O(generations · m·k)` of dead bytes. Factor-only *slim* (version 2)
//! checkpoints drop the history for fleets that stream it to a
//! [`crate::symnmf::trace`] sink instead.
//!
//! Job ids are sanitized into a conservative filename alphabet
//! ([`sanitize_id`]) so an id arriving from a network spec can never
//! escape the store directory.

use crate::symnmf::engine::Checkpoint;
use crate::util::failpoint;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Map an arbitrary job id onto the store's filename alphabet:
/// `[A-Za-z0-9_-]`, everything else replaced by `_`, empty ids become
/// `"job"`. Distinct ids can collide after sanitization; submitters that
/// care (the CLI does) should use clean ids.
pub fn sanitize_id(id: &str) -> String {
    let s: String = id
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '_'
            }
        })
        .collect();
    if s.is_empty() {
        "job".to_string()
    } else {
        s
    }
}

/// A directory of per-job checkpoint generations.
#[derive(Clone, Debug)]
pub struct JobStore {
    dir: PathBuf,
    keep: usize,
}

impl JobStore {
    /// Open (creating if needed) a store rooted at `dir`, retaining one
    /// generation per job.
    pub fn open(dir: &Path) -> Result<JobStore, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("create store dir {dir:?}: {e}"))?;
        Ok(JobStore { dir: dir.to_path_buf(), keep: 1 })
    }

    /// Retain the newest `keep` generations per job (floored at 1).
    pub fn with_keep(mut self, keep: usize) -> JobStore {
        self.keep = keep.max(1);
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_name(id: &str, gen: u64) -> String {
        format!("{}.g{gen:08}.ckpt.json", sanitize_id(id))
    }

    /// Path a given (job, generation) lives at.
    pub fn path_for(&self, id: &str, gen: u64) -> PathBuf {
        self.dir.join(JobStore::file_name(id, gen))
    }

    /// Persist one checkpoint generation (atomic: temp + rename, with
    /// the temp file fsynced before the rename so the payload is durable
    /// when the new name appears), then GC generations beyond the
    /// retention. `slim` selects the factor-only version-2 encoding.
    pub fn save(
        &self,
        id: &str,
        gen: u64,
        cp: &Checkpoint,
        slim: bool,
    ) -> Result<PathBuf, String> {
        failpoint::hit_scoped("ckpt_save", id)?;
        let path = self.path_for(id, gen);
        let tmp = path.with_extension("json.tmp");
        let text = if slim { cp.serialize_slim() } else { cp.serialize() };
        (|| -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(text.as_bytes())?;
            // the durability half of the temp+rename contract: the bytes
            // must be on disk before the rename publishes the name
            f.sync_all()
        })()
        .map_err(|e| format!("write {tmp:?}: {e}"))?;
        std::fs::rename(&tmp, &path).map_err(|e| format!("rename to {path:?}: {e}"))?;
        // best-effort directory fsync so the rename itself survives a
        // crash; not every filesystem supports fsync on a directory
        if let Ok(d) = std::fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        self.gc(id)?;
        Ok(path)
    }

    /// Generations currently on disk for a job, ascending.
    pub fn generations(&self, id: &str) -> Result<Vec<u64>, String> {
        let prefix = format!("{}.g", sanitize_id(id));
        let suffix = ".ckpt.json";
        let mut gens = Vec::new();
        let entries = std::fs::read_dir(&self.dir)
            .map_err(|e| format!("read store dir {:?}: {e}", self.dir))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read store dir entry: {e}"))?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(rest) = name.strip_prefix(&prefix) else { continue };
            let Some(digits) = rest.strip_suffix(suffix) else { continue };
            if let Ok(g) = digits.parse::<u64>() {
                gens.push(g);
            }
        }
        gens.sort_unstable();
        Ok(gens)
    }

    /// Load the newest **parseable** generation, if any: a torn or
    /// corrupt newest file (e.g. a crash mid-write on a filesystem
    /// without atomic rename durability) falls back to the next-older
    /// generation instead of stranding the job. Files are left in place
    /// — quarantining is [`crate::serve::recovery`]'s job. Errors only
    /// when generations exist but none parses.
    pub fn load_latest(&self, id: &str) -> Result<Option<(u64, Checkpoint)>, String> {
        let gens = self.generations(id)?;
        let mut last_err: Option<String> = None;
        for &gen in gens.iter().rev() {
            let path = self.path_for(id, gen);
            let parsed = std::fs::read_to_string(&path)
                .map_err(|e| format!("read {path:?}: {e}"))
                .and_then(|text| {
                    Checkpoint::parse(&text).map_err(|e| format!("parse {path:?}: {e}"))
                });
            match parsed {
                Ok(cp) => {
                    if let Some(e) = &last_err {
                        eprintln!(
                            "[store] {id}: newest generation unreadable ({e}); \
                             falling back to generation {gen}"
                        );
                    }
                    return Ok(Some((gen, cp)));
                }
                Err(e) => last_err = Some(e),
            }
        }
        match last_err {
            None => Ok(None),
            Some(e) => Err(format!("no parseable generation for {id:?}: {e}")),
        }
    }

    /// Job ids (sanitized form) with at least one generation on disk —
    /// the recovery scan's starting set.
    pub fn job_ids(&self) -> Result<Vec<String>, String> {
        let suffix = ".ckpt.json";
        let mut ids = Vec::new();
        let entries = std::fs::read_dir(&self.dir)
            .map_err(|e| format!("read store dir {:?}: {e}", self.dir))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read store dir entry: {e}"))?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(stem) = name.strip_suffix(suffix) else { continue };
            // strip the trailing ".g<digits>" generation tag
            let Some((id, gen)) = stem.rsplit_once(".g") else { continue };
            if !gen.is_empty() && gen.bytes().all(|b| b.is_ascii_digit()) {
                ids.push(id.to_string());
            }
        }
        ids.sort_unstable();
        ids.dedup();
        Ok(ids)
    }

    /// Remove superseded generations beyond the retention; returns how
    /// many files were deleted.
    pub fn gc(&self, id: &str) -> Result<usize, String> {
        let gens = self.generations(id)?;
        if gens.len() <= self.keep {
            return Ok(0);
        }
        let doomed = &gens[..gens.len() - self.keep];
        let mut removed = 0;
        for &g in doomed {
            let path = self.path_for(id, g);
            std::fs::remove_file(&path).map_err(|e| format!("remove {path:?}: {e}"))?;
            removed += 1;
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMat;
    use crate::symnmf::engine::{EngineState, RunStatus};
    use crate::symnmf::metrics::IterRecord;
    use crate::util::rng::Pcg64;

    fn tmp_store(name: &str) -> JobStore {
        let dir = std::env::temp_dir()
            .join(format!("symnmf-store-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        JobStore::open(&dir).expect("open store")
    }

    fn sample_cp(seed: u64, iters: usize) -> Checkpoint {
        let mut rng = Pcg64::seed_from_u64(seed);
        Checkpoint {
            status: RunStatus::Paused,
            stage: 0,
            stage_iter: iters,
            iter: iters,
            clock: 0.5,
            stop_best: 0.33,
            stop_stall: 1,
            state: EngineState {
                h: DenseMat::gaussian(6, 2, &mut rng),
                w: Some(DenseMat::gaussian(6, 2, &mut rng)),
                rng: None,
            },
            records: (0..iters)
                .map(|i| IterRecord {
                    iter: i,
                    time_secs: 0.1 * (i + 1) as f64,
                    residual: 1.0 / (i + 2) as f64,
                    proj_grad: None,
                    phase_secs: (0.0, 0.0, 0.0),
                    hybrid_stats: None,
                })
                .collect(),
            isa: Some("scalar".to_string()),
        }
    }

    #[test]
    fn sanitizes_hostile_ids() {
        assert_eq!(sanitize_id("trial-3"), "trial-3");
        assert_eq!(sanitize_id("../../etc/passwd"), "______etc_passwd");
        assert_eq!(sanitize_id("a b/c"), "a_b_c");
        assert_eq!(sanitize_id(""), "job");
    }

    #[test]
    fn save_load_roundtrips_and_gcs_superseded_generations() {
        let store = tmp_store("gc").with_keep(2);
        let cp3 = sample_cp(3, 3);
        for (gen, iters) in [(1u64, 1usize), (2, 2), (3, 3)] {
            store
                .save("job-a", gen, &sample_cp(gen, iters), false)
                .expect("save");
        }
        // keep=2: generation 1 must be gone, 2 and 3 retained
        assert_eq!(store.generations("job-a").unwrap(), vec![2, 3]);
        let (gen, back) = store.load_latest("job-a").unwrap().expect("latest");
        assert_eq!(gen, 3);
        assert_eq!(back.iter, 3);
        assert_eq!(back.records.len(), 3);
        for (a, b) in cp3.state.h.data().iter().zip(back.state.h.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "factors must round-trip bitwise");
        }
        // unknown job: no generations, no latest
        assert!(store.generations("ghost").unwrap().is_empty());
        assert!(store.load_latest("ghost").unwrap().is_none());
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn default_retention_keeps_only_newest() {
        let store = tmp_store("keep1");
        for gen in 1..=4u64 {
            store.save("j", gen, &sample_cp(gen, 1), false).expect("save");
        }
        assert_eq!(store.generations("j").unwrap(), vec![4]);
        std::fs::remove_dir_all(store.dir()).ok();
    }

    /// Satellite: a torn/truncated newest generation must not strand the
    /// job — `load_latest` falls back to the next-older parseable one.
    #[test]
    fn torn_newest_generation_falls_back_to_older() {
        let store = tmp_store("torn").with_keep(3);
        store.save("t", 1, &sample_cp(1, 1), false).expect("save g1");
        store.save("t", 2, &sample_cp(2, 2), false).expect("save g2");
        // tear generation 2: keep only the first half of its bytes (a
        // crash mid-write without the fsync+rename discipline)
        let g2 = store.path_for("t", 2);
        let bytes = std::fs::read(&g2).unwrap();
        std::fs::write(&g2, &bytes[..bytes.len() / 2]).unwrap();
        let (gen, cp) = store.load_latest("t").unwrap().expect("fallback");
        assert_eq!(gen, 1, "must fall back past the torn newest generation");
        assert_eq!(cp.iter, 1);
        // the torn file is left in place (quarantine is recovery's job)
        assert!(g2.exists());
        // truncating EVERY generation leaves nothing to load: that is an
        // error (generations exist but none parses), not a silent cold start
        let g1 = store.path_for("t", 1);
        std::fs::write(&g1, "{").unwrap();
        let err = store.load_latest("t").expect_err("all torn");
        assert!(err.contains("no parseable generation"), "{err}");
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn job_ids_lists_each_job_once() {
        let store = tmp_store("ids").with_keep(2);
        store.save("a", 1, &sample_cp(1, 1), false).unwrap();
        store.save("a", 2, &sample_cp(2, 2), false).unwrap();
        store.save("b", 1, &sample_cp(3, 1), false).unwrap();
        // stray files are ignored
        std::fs::write(store.dir().join("notes.txt"), "x").unwrap();
        std::fs::write(store.dir().join("c.g0000001x.ckpt.json"), "x").unwrap();
        assert_eq!(store.job_ids().unwrap(), vec!["a".to_string(), "b".to_string()]);
        std::fs::remove_dir_all(store.dir()).ok();
    }

    /// The `ckpt_save` fail point surfaces as a plain save error — the
    /// scheduler's bounded retry sits on top of exactly this path.
    #[test]
    fn ckpt_save_failpoint_injects_an_error() {
        let _fp = crate::util::failpoint::scoped("ckpt_save:flaky=err_once");
        let store = tmp_store("fp");
        let err = store
            .save("flaky", 1, &sample_cp(1, 1), false)
            .expect_err("first save must fail");
        assert!(err.contains("injected error"), "{err}");
        // the injection is one-shot; the retry heals
        store.save("flaky", 1, &sample_cp(1, 1), false).expect("second save");
        assert!(store.load_latest("flaky").unwrap().is_some());
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn slim_saves_parse_without_records() {
        let store = tmp_store("slim");
        let cp = sample_cp(9, 4);
        let path = store.save("s", 1, &cp, true).expect("save slim");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"version\":2"));
        let (_, back) = store.load_latest("s").unwrap().expect("latest");
        assert!(back.records.is_empty(), "slim checkpoints drop the history");
        assert_eq!(back.iter, 4, "but keep the global iteration counter");
        // slim is strictly smaller than the full encoding of the same state
        assert!(text.len() < cp.serialize().len());
        std::fs::remove_dir_all(store.dir()).ok();
    }
}
