//! Nonnegative least squares solvers — the Update(G, Y) toolbox of the
//! paper's Appendix E. All rules consume the normal-equations pair
//! (G = FᵀF + αI ∈ R^{k×k}, Y = X·F + αF ∈ R^{m×k}) so the same code
//! serves the exact products, the LAI products, and the leverage-score
//! sampled products.

pub mod bpp;
pub mod hals;
pub mod mu;
pub mod update;

pub use update::{update, update_into, UpdateRule};
