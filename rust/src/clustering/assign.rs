//! Hard cluster assignment from the SymNMF factor: vertex i joins the
//! cluster argmax_j H[i, j] (paper §5, methodology of [35]).

use crate::linalg::DenseMat;

/// Row-wise argmax.
pub fn argmax_rows(h: &DenseMat) -> Vec<usize> {
    (0..h.rows())
        .map(|i| {
            let row = h.row(i);
            let mut best = 0;
            let mut bv = row[0];
            for (j, &v) in row.iter().enumerate().skip(1) {
                if v > bv {
                    bv = v;
                    best = j;
                }
            }
            best
        })
        .collect()
}

/// Cluster sizes given assignments and cluster count.
pub fn cluster_sizes(assign: &[usize], k: usize) -> Vec<usize> {
    let mut sizes = vec![0usize; k];
    for &a in assign {
        sizes[a] += 1;
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_max_per_row() {
        let h = DenseMat::from_vec(3, 3, vec![
            0.1, 0.9, 0.0, //
            0.5, 0.2, 0.3, //
            0.0, 0.0, 1.0,
        ]);
        assert_eq!(argmax_rows(&h), vec![1, 0, 2]);
        assert_eq!(cluster_sizes(&argmax_rows(&h), 3), vec![1, 1, 1]);
    }

    #[test]
    fn ties_go_to_first() {
        let h = DenseMat::from_vec(1, 3, vec![0.5, 0.5, 0.5]);
        assert_eq!(argmax_rows(&h), vec![0]);
    }
}
