//! Regenerates paper **Figure 6** (App. G.2.1): hybrid-sampling
//! statistics of LvS-HALS per iteration — (a) the fraction of samples
//! taken deterministically s_D/(s_D+s_R) and (b) the leverage-score mass
//! θ/k captured deterministically.
//!
//! Shape to reproduce: the deterministic *fraction* shrinks over
//! iterations while θ/k climbs toward 1 — a few deterministic rows end up
//! accounting for nearly all the leverage mass as H localizes onto the
//! small clusters.
//!
//!     cargo bench --bench bench_fig6_hybrid
//! writes results/fig6_hybrid.csv

use symnmf::coordinator::driver::Method;
use symnmf::coordinator::experiments::{oag_options, oag_workload};
use symnmf::coordinator::report;
use symnmf::nls::UpdateRule;
use symnmf::symnmf::options::Tau;

fn main() {
    let m = std::env::var("SYMNMF_BENCH_M")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    println!("== Fig. 6 bench: hybrid sampling stats, LvS-HALS on OAG (m={m}) ==");
    let g = oag_workload(m, 11);
    let mut opts = oag_options().with_seed(66);
    opts.max_iters = 40;
    opts.patience = 1000; // plot the full horizon (paper's Figs. show complete curves)

    // cold start: the random-init trajectory (θ stays small at this scale
    // because H has not yet localized onto the small clusters)
    let cold = Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS }.run(&g.adj, &opts);
    let (cf, ct) = cold.records.last().unwrap().hybrid_stats.unwrap();
    println!("cold start (random init): final det-fraction {cf:.4}, θ/k {ct:.4}");

    // localized trajectory: warm-start from the planted block structure
    // (the paper's Fig. 6 measures a run whose H has already localized —
    // their m = 37.7M gives the sampler 1,900× more absolute samples, so
    // localization happens within the plotted run; at our scale we study
    // the sampler's behaviour on a localized H directly).
    let mut hw = symnmf::linalg::DenseMat::zeros(m, 16);
    {
        let mut rng = symnmf::util::rng::Pcg64::seed_from_u64(5);
        for (v, &b) in g.labels.iter().enumerate() {
            hw.set(v, b, 0.5 + 0.5 * rng.uniform());
        }
    }
    opts.warm_start = Some(hw);
    let res = Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS }.run(&g.adj, &opts);

    println!("iter  det-fraction  theta/k");
    for r in res.records.iter().step_by(5) {
        if let Some((frac, theta)) = r.hybrid_stats {
            println!("{:>4}  {:>12.4}  {:>7.4}", r.iter, frac, theta);
        }
    }
    let last = res.records.last().unwrap().hybrid_stats.unwrap();
    let first = res.records.first().unwrap().hybrid_stats.unwrap();
    println!(
        "\nθ/k: {:.3} → {:.3} over {} iterations (paper: climbs toward 1)",
        first.1,
        last.1,
        res.iters()
    );

    std::fs::create_dir_all("results").ok();
    report::write_hybrid_stats_csv(std::path::Path::new("results/fig6_hybrid.csv"), &res)
        .unwrap();
    println!("wrote results/fig6_hybrid.csv");
}
