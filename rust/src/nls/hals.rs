//! Hierarchical Alternating Least Squares column updates.
//!
//! In Update(G, Y) form (App. E) the regularized symmetric HALS rule of
//! paper Eq. 2.6 reduces to the classic rule
//!
//! ```text
//!     w_i ← [ w_i + (Y_i − W·G_i) / G_ii ]_+
//! ```
//!
//! with G = HᵀH + αI, Y = X·H + αH (the derivation in App. A composed
//! with the normal-equation substitution; both forms are tested equal in
//! `tests::matches_eq26_form`). Columns update sequentially in place —
//! later columns see earlier updates — which is exactly why the paper's
//! "modified HALS" (Eq. 2.6/2.7) lets XH and HᵀH be computed once per
//! sweep and reused.
//!
//! ## Row-major, transpose-free sweep
//!
//! The column-sequential dependency only couples entries of the SAME row
//! of W: column i's update at row r reads W[r, j] for all j. So instead
//! of staging k×m transposes of W and Y (2·m·k·8 bytes of pure memory
//! traffic per sweep, as the previous implementation did), the sweep
//! runs row-major: each row r walks its k columns in order, forming
//! `Y[r,i] + G_ii·W[r,i] − G[i,:]·W[r,:]` from two contiguous length-k
//! slices (the G row and the W row, both cache-hot) via the 4-way
//! unrolled [`blas::dot`]. Rows are independent, so the sweep
//! parallelizes over row chunks with bitwise-deterministic results, and
//! needs no scratch buffers at all.

use crate::linalg::simd::{self, KernelIsa};
use crate::linalg::{blas, DenseMat};
use crate::util::threadpool::{parallel_for_chunks, SendPtr};

/// One full HALS sweep updating every column of `w` given (G, Y), fully
/// in place (no scratch, no allocation). `w` stays nonnegative. Runs on
/// the process-wide dispatched kernel tier
/// ([`crate::linalg::simd::active`]).
pub fn hals_sweep(g: &DenseMat, y: &DenseMat, w: &mut DenseMat) {
    hals_sweep_isa(simd::active(), g, y, w);
}

/// [`hals_sweep`] with an explicit kernel tier: the inner `G[i,:]·W[r,:]`
/// contraction runs on [`simd::dot_fma`] (FMA tier — the Scalar tier is
/// the historical [`blas::dot`], bitwise). The parity suite pins every
/// supported tier against the Scalar tier at 1e-12. The row fan-out
/// executes on the shared persistent pool ([`crate::util::pool`]);
/// chunk geometry is fixed by the logical width, so the dispatch
/// backend cannot change bits.
pub fn hals_sweep_isa(isa: KernelIsa, g: &DenseMat, y: &DenseMat, w: &mut DenseMat) {
    let (m, k) = w.shape();
    assert_eq!(g.shape(), (k, k), "hals_sweep: G must be {k}x{k}");
    assert_eq!(y.shape(), (m, k), "hals_sweep: Y must be {m}x{k}");
    if m == 0 || k == 0 {
        return;
    }
    let gd = g.data();
    let yd = y.data();
    let wptr = SendPtr(w.data_mut().as_mut_ptr());
    parallel_for_chunks(m, 128, move |lo, hi| {
        for r in lo..hi {
            // SAFETY: disjoint row ranges per worker.
            let wrow = unsafe { std::slice::from_raw_parts_mut(wptr.0.add(r * k), k) };
            let yrow = &yd[r * k..(r + 1) * k];
            for i in 0..k {
                let gii = gd[i * k + i];
                if gii <= 0.0 {
                    continue;
                }
                let grow = &gd[i * k..(i + 1) * k];
                // Y[r,i] − Σ_{j≠i} G_ij·W[r,j], with the j == i term of
                // the contiguous dot added back.
                let num = yrow[i] + gii * wrow[i] - simd::dot_fma(isa, grow, wrow);
                wrow[i] = (num / gii).max(0.0);
            }
        }
    });
}

/// The pre-blocking reference sweep: stages W and Y as k×m transposes so
/// each column update is a contiguous slice, then transposes back. Kept
/// (allocating) as the oracle for property tests pinning the row-major
/// sweep, and as documentation of the classic formulation.
pub fn hals_sweep_reference(g: &DenseMat, y: &DenseMat, w: &mut DenseMat) {
    let (m, k) = w.shape();
    assert_eq!(g.shape(), (k, k));
    assert_eq!(y.shape(), (m, k));
    let mut wt = DenseMat::zeros(k, m);
    let mut yt = DenseMat::zeros(k, m);
    let mut delta = vec![0.0f64; m];
    w.transpose_into(&mut wt);
    y.transpose_into(&mut yt);
    for i in 0..k {
        let gii = g.at(i, i);
        if gii <= 0.0 {
            continue;
        }
        // delta = (Y_i − W·G_i) / G_ii = yt[i,:] − Σ_j G_ij · wt[j,:]
        delta.copy_from_slice(yt.row(i));
        let grow = g.row(i);
        for (j, &gij) in grow.iter().enumerate() {
            if gij != 0.0 && j != i {
                blas::axpy(-gij, wt.row(j), &mut delta);
            }
        }
        // with the diagonal term excluded above, delta holds
        // Y_i − Σ_{j≠i}G_ij w_j, so the classic rule becomes
        // w_i ← [delta/G_ii]_+ (W·G_i includes G_ii·w_i).
        let wrow = wt.row_mut(i);
        let inv = 1.0 / gii;
        for (wv, dv) in wrow.iter_mut().zip(delta.iter()) {
            *wv = (dv * inv).max(0.0);
        }
    }
    wt.transpose_into(w);
}

/// `fix_zero_columns`: HALS can zero out a column entirely (a dead
/// component); the standard remedy reseeds it with a tiny positive value
/// so the factor keeps rank k. Returns how many columns were reseeded.
pub fn fix_zero_columns(w: &mut DenseMat, eps: f64) -> usize {
    let (m, k) = w.shape();
    let mut fixed = 0;
    for j in 0..k {
        let norm_sq: f64 = w.col_iter(j).map(|v| v * v).sum();
        if norm_sq < eps * eps {
            for i in 0..m {
                w.set(i, j, eps);
            }
            fixed += 1;
        }
    }
    fixed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Pcg64;

    fn setup2(
        m: usize,
        k: usize,
        alpha: f64,
        seed: u64,
    ) -> (DenseMat, DenseMat, DenseMat, DenseMat, DenseMat) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut x = DenseMat::gaussian(m, m, &mut rng);
        x.symmetrize();
        let mut h = DenseMat::gaussian(m, k, &mut rng);
        h.project_nonneg();
        let mut w = DenseMat::gaussian(m, k, &mut rng);
        w.project_nonneg();
        let mut g = blas::gram(&h);
        for i in 0..k {
            *g.at_mut(i, i) += alpha;
        }
        let mut y = blas::matmul(&x, &h);
        y.axpy(alpha, &h);
        (x, h, w, g, y)
    }

    #[test]
    fn output_nonnegative() {
        let (_x, _h, mut w, g, y) = setup2(30, 5, 1.0, 1);
        hals_sweep(&g, &y, &mut w);
        assert!(w.is_nonneg());
    }

    /// The sweep must not increase the regularized objective
    /// ‖X − WHᵀ‖² + α‖W − H‖² (exact per-column minimization).
    #[test]
    fn decreases_regularized_objective() {
        for seed in [2, 3, 4, 5] {
            let (x, h, mut w, g, y) = setup2(25, 4, 1.5, seed);
            let alpha = 1.5;
            let obj = |wm: &DenseMat| {
                let rec = blas::matmul_nt(wm, &h);
                let mut d = x.clone();
                d.axpy(-1.0, &rec);
                d.fro_norm_sq() + alpha * wm.diff_fro(&h).powi(2)
            };
            let before = obj(&w);
            hals_sweep(&g, &y, &mut w);
            let after = obj(&w);
            assert!(after <= before + 1e-9, "seed {seed}: {before} → {after}");
        }
    }

    /// Update(G,Y)-form equals the paper's Eq. 2.6 form computed literally.
    #[test]
    fn matches_eq26_form() {
        let (x, h, w0, g, y) = setup2(20, 4, 2.0, 7);
        let alpha = 2.0;
        let k = 4;
        // ours
        let mut w_fast = w0.clone();
        hals_sweep(&g, &y, &mut w_fast);
        // literal Eq. 2.6: w_i ← [((X − WHᵀ + αI)h_i)/(‖h_i‖²+α)
        //                        + (‖h_i‖²/(‖h_i‖²+α)) w_i]_+
        let mut w_lit = w0.clone();
        for i in 0..k {
            let hi = h.col(i);
            let hnorm: f64 = hi.iter().map(|v| v * v).sum();
            let denom = hnorm + alpha;
            let rec = blas::matmul_nt(&w_lit, &h); // uses current W
            let m = x.rows();
            let mut newcol = vec![0.0; m];
            for r in 0..m {
                let mut acc = 0.0;
                for c in 0..m {
                    let xv = x.at(r, c) - rec.at(r, c)
                        + if r == c { alpha } else { 0.0 };
                    acc += xv * hi[c];
                }
                newcol[r] = (acc / denom + (hnorm / denom) * w_lit.at(r, i)).max(0.0);
            }
            w_lit.set_col(i, &newcol);
        }
        assert!(
            w_fast.diff_fro(&w_lit) < 1e-8,
            "Update(G,Y) HALS ≠ Eq. 2.6 literal: {}",
            w_fast.diff_fro(&w_lit)
        );
    }

    /// Transpose-free row-major sweep vs the staged-transpose reference,
    /// across non-multiple-of-block shapes (the satellite pinning test).
    #[test]
    fn rowmajor_sweep_matches_reference_across_shapes() {
        let mut rng = Pcg64::seed_from_u64(31);
        for m in [1usize, 3, 31, 33, 65] {
            for k in [1usize, 3, 31, 33, 65] {
                let mut h = DenseMat::gaussian(m, k, &mut rng);
                h.project_nonneg();
                let mut g = blas::gram(&h);
                g.add_diag(0.7); // keep G_ii > 0
                let y = DenseMat::gaussian(m, k, &mut rng);
                let mut w0 = DenseMat::gaussian(m, k, &mut rng);
                w0.project_nonneg();
                let mut w_fast = w0.clone();
                hals_sweep(&g, &y, &mut w_fast);
                let mut w_ref = w0.clone();
                hals_sweep_reference(&g, &y, &mut w_ref);
                let err = w_fast.diff_fro(&w_ref);
                assert!(
                    err < 1e-12 * (1.0 + w_ref.fro_norm()),
                    "m={m} k={k}: err={err}"
                );
            }
        }
    }

    /// The issue's scalar-vs-SIMD parity grid for the dispatched sweep:
    /// every supported tier vs the forced-Scalar tier at 1e-12 across
    /// m,k ∈ {1,2,3,7,8,9,31,33,65} (the Scalar tier itself is the
    /// historical sweep bitwise, which the reference pin above covers).
    #[test]
    fn sweep_simd_tiers_match_scalar_oracle() {
        use crate::linalg::simd::{self, KernelIsa};
        let mut rng = Pcg64::seed_from_u64(41);
        for m in [1usize, 2, 3, 7, 8, 9, 31, 33, 65] {
            for k in [1usize, 2, 3, 7, 8, 9, 31, 33, 65] {
                let mut h = DenseMat::gaussian(m, k, &mut rng);
                h.project_nonneg();
                let mut g = blas::gram(&h);
                g.add_diag(0.7); // keep G_ii > 0
                let y = DenseMat::gaussian(m, k, &mut rng);
                let mut w0 = DenseMat::gaussian(m, k, &mut rng);
                w0.project_nonneg();
                let mut want = w0.clone();
                hals_sweep_isa(KernelIsa::Scalar, &g, &y, &mut want);
                for isa in simd::supported() {
                    let mut got = w0.clone();
                    hals_sweep_isa(isa, &g, &y, &mut got);
                    let err = got.diff_fro(&want);
                    assert!(
                        err < 1e-12 * (1.0 + want.fro_norm()),
                        "isa={isa:?} m={m} k={k}: err={err}"
                    );
                }
            }
        }
    }

    /// Rows are independent, so the parallel row-major sweep must be
    /// bitwise-deterministic across repeated calls (batched trials rely
    /// on this).
    #[test]
    fn rowmajor_sweep_is_deterministic() {
        let (_x, _h, w0, g, y) = setup2(257, 5, 1.0, 8);
        let mut wa = w0.clone();
        let mut wb = w0.clone();
        hals_sweep(&g, &y, &mut wa);
        hals_sweep(&g, &y, &mut wb);
        for (a, b) in wa.data().iter().zip(wb.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn reseeds_dead_columns() {
        let mut w = DenseMat::zeros(10, 3);
        w.set(0, 1, 5.0);
        let fixed = fix_zero_columns(&mut w, 1e-8);
        assert_eq!(fixed, 2);
        assert!(w.col(0).iter().all(|&v| v > 0.0));
    }
}
