//! Micro-benchmarks of the hot-path kernels (the §Perf tool, DESIGN.md
//! §6): dense matmul X·F (blocked-SYMM vs generic GEMM vs allocating vs
//! packed-triangular SymPacked), packed-panel vs unpacked NT GEMM,
//! Gram, SpMM (column-tiled vs untiled on wide k), the transpose-free
//! HALS sweep vs the staged-transpose reference, batched vs serial
//! multi-seed trials (plus batched under an explicit thread budget),
//! CholeskyQR + leverage scores, BPP multi-RHS solve, sampled SpMM, the
//! out-of-core SymPacked apply vs its resident twin plus operator-cache
//! hit/miss round trips, and the PJRT round-trip for the same product —
//! with achieved GF/s against the 1-core f64 roofline.
//!
//! Besides the stdout report, emits machine-readable
//! **`BENCH_kernels.json`** at the repo root (op, shape, secs/iter,
//! GFLOP/s) so perf trajectory tracking can diff runs across commits.
//!
//!     cargo bench --bench bench_kernels

use std::rc::Rc;
use symnmf::coordinator::driver::{run_trials, run_trials_batched};
use symnmf::coordinator::Method;
use symnmf::linalg::{
    blas, qr, simd, spill, DenseMat, KernelIsa, PanelBuf, Precision, SymPacked, SymPackedSpilled,
};
use symnmf::nls::{bpp, hals, UpdateRule};
use symnmf::linalg::workspace::SampleWorkspace;
use symnmf::randnla::leverage::{sample_hybrid, sample_hybrid_ws};
use symnmf::randnla::op::{sampled_apply_dense_isa, sampled_apply_dense_serial};
use symnmf::randnla::SymOp;
use symnmf::runtime::{PjrtRuntime, PjrtSymOp};
use symnmf::serve::{
    CachedOperator, JobSpec, OpCache, OpCacheConfig, OpKey, Scheduler, SchedulerConfig,
};
use symnmf::sparse::CsrMat;
use symnmf::symnmf::anls::{resolve_alpha, run_alternating_loop, symnmf_anls, Metrics};
use symnmf::symnmf::compressed::compressed_symnmf;
use symnmf::symnmf::engine::{Checkpoint, EngineState, RunControl, RunStatus};
use symnmf::symnmf::metrics::IterRecord;
use symnmf::symnmf::init::initial_factor;
use symnmf::symnmf::options::SymNmfOptions;
use symnmf::util::bench::{bench, gflops, BenchResult};
use symnmf::util::json::Json;
use symnmf::util::pool::{self, PoolBackend};
use symnmf::util::rng::Pcg64;
use symnmf::util::threadpool::num_threads;
use symnmf::util::timer::PhaseTimer;

/// One record of the JSON report.
struct Record {
    op: String,
    shape: String,
    secs_per_iter: f64,
    gflops: f64,
}

fn record(records: &mut Vec<Record>, op: &str, shape: &str, r: &BenchResult, flops: f64) {
    records.push(Record {
        op: op.to_string(),
        shape: shape.to_string(),
        secs_per_iter: r.median,
        gflops: if flops > 0.0 { gflops(flops, r.median) } else { 0.0 },
    });
}

/// Repo root: parent of the cargo manifest dir (benches run with the
/// manifest dir as cwd, the repo root is one level up).
fn repo_root() -> std::path::PathBuf {
    let manifest = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".to_string());
    let p = std::path::PathBuf::from(manifest);
    p.parent().map(|q| q.to_path_buf()).unwrap_or(p)
}

fn write_json(records: &[Record]) {
    let arr: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("op", Json::Str(r.op.clone())),
                ("shape", Json::Str(r.shape.clone())),
                ("secs_per_iter", Json::Num(r.secs_per_iter)),
                ("gflops", Json::Num(r.gflops)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("bench", Json::Str("kernels".to_string())),
        // provenance: rows measured under a different dispatch (or on a
        // different box) are not comparable — the regression gate skips
        // cross-ISA/hostname diffs instead of flagging phantom deltas.
        ("isa", Json::Str(simd::active().as_str().to_string())),
        ("hostname", Json::Str(simd::hostname())),
        ("kernels", Json::Arr(arr)),
    ]);
    let path = repo_root().join("BENCH_kernels.json");
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("\nwrote {path:?}"),
        Err(e) => eprintln!("could not write {path:?}: {e}"),
    }
}

fn main() {
    let mut rng = Pcg64::seed_from_u64(1);
    let mut records: Vec<Record> = Vec::new();
    let m = 1024;
    let k = 16;

    // --- dense X·F (the dominant per-iteration product) ---
    let mut x = DenseMat::gaussian(m, m, &mut rng);
    x.symmetrize();
    let f = DenseMat::gaussian(m, k, &mut rng);
    let mut out = DenseMat::zeros(m, k);
    let r = bench(&format!("dense X·F  ({m}x{m} · {m}x{k})"), 2, 9, || {
        blas::symm_tall_into(&x, &f, &mut out);
    });
    let flops = 2.0 * (m * m * k) as f64;
    println!("{}   {:.2} GF/s", r.report(), gflops(flops, r.median));
    record(&mut records, "dense_xf_into", &format!("{m}x{m}·{m}x{k}"), &r, flops);

    // --- the acceptance shape (m=2048, k=32): apply_into vs allocating ---
    let m2 = 2048;
    let k2 = 32;
    let mut x2 = DenseMat::gaussian(m2, m2, &mut rng);
    x2.symmetrize();
    let f2 = DenseMat::gaussian(m2, k2, &mut rng);
    let mut out2 = DenseMat::zeros(m2, k2);
    let flops2 = 2.0 * (m2 * m2 * k2) as f64;
    let r_into = bench(&format!("dense X·F apply_into ({m2}x{m2}, k={k2})"), 1, 5, || {
        x2.apply_into(&f2, &mut out2);
    });
    println!("{}   {:.2} GF/s", r_into.report(), gflops(flops2, r_into.median));
    record(
        &mut records,
        "dense_xf_apply_into",
        &format!("{m2}x{m2}·{m2}x{k2}"),
        &r_into,
        flops2,
    );
    let r_alloc = bench(&format!("dense X·F allocating  ({m2}x{m2}, k={k2})"), 1, 5, || {
        std::hint::black_box(SymOp::apply(&x2, &f2));
    });
    println!("{}   {:.2} GF/s", r_alloc.report(), gflops(flops2, r_alloc.median));
    record(
        &mut records,
        "dense_xf_apply_alloc",
        &format!("{m2}x{m2}·{m2}x{k2}"),
        &r_alloc,
        flops2,
    );
    println!(
        "apply_into vs allocating at m={m2}, k={k2}: {:.2}% time",
        100.0 * r_into.median / r_alloc.median.max(1e-300)
    );
    // generic GEMM on the same shape — what the PR-1 `symm_tall_into`
    // alias dispatched to; the gap to `dense_xf_apply_into` is the
    // blocked-SYMM win (halved X traffic + fixed-order block reduction).
    let r_gemm = bench(&format!("dense X·F generic GEMM ({m2}x{m2}, k={k2})"), 1, 5, || {
        blas::matmul_into(&x2, &f2, &mut out2);
    });
    println!("{}   {:.2} GF/s", r_gemm.report(), gflops(flops2, r_gemm.median));
    record(
        &mut records,
        "dense_xf_matmul_into",
        &format!("{m2}x{m2}·{m2}x{k2}"),
        &r_gemm,
        flops2,
    );
    println!(
        "blocked SYMM vs generic GEMM at m={m2}, k={k2}: {:.2}% time",
        100.0 * r_into.median / r_gemm.median.max(1e-300)
    );

    // --- packed-triangular X (SymPacked): same product, half-resident X ---
    let xp = SymPacked::from_dense(&x2);
    println!(
        "SymPacked resident: {} vs {} doubles ({:.1}%)",
        xp.packed_len(),
        m2 * m2,
        100.0 * xp.packed_len() as f64 / (m2 * m2) as f64
    );
    // scalar-pinned baseline row: stable across hosts, the SIMD row below
    // shows the dispatch win on this box.
    let r_packedx = bench(&format!("packed X·F apply_into ({m2}x{m2}, k={k2})"), 1, 5, || {
        xp.apply_blocked_into_isa(KernelIsa::Scalar, &f2, &mut out2);
    });
    println!("{}   {:.2} GF/s", r_packedx.report(), gflops(flops2, r_packedx.median));
    record(
        &mut records,
        "symm_packed_apply_into",
        &format!("{m2}x{m2}·{m2}x{k2}"),
        &r_packedx,
        flops2,
    );
    let r_packedx_simd =
        bench(&format!("packed X·F simd [{}] ({m2}x{m2}, k={k2})", simd::active().as_str()), 1, 5, || {
            xp.apply_into(&f2, &mut out2);
        });
    println!("{}   {:.2} GF/s", r_packedx_simd.report(), gflops(flops2, r_packedx_simd.median));
    record(
        &mut records,
        "symm_packed_simd",
        &format!("{m2}x{m2}·{m2}x{k2}"),
        &r_packedx_simd,
        flops2,
    );
    println!(
        "packed vs full-storage SYMM at m={m2}, k={k2}: {:.2}% time",
        100.0 * r_packedx.median / r_into.median.max(1e-300)
    );

    // --- out-of-core SymPacked: the same product streamed panel-by-panel
    // from the checksummed spill file — the ratio to the resident SIMD
    // row is the price of serving a graph that lost its cache residency
    // (bitwise-identical output, so it is ONLY a time tax)
    let bench_tmp =
        std::env::temp_dir().join(format!("symnmf-bench-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&bench_tmp);
    std::fs::create_dir_all(&bench_tmp).expect("create bench temp dir");
    let spill_path = bench_tmp.join("bench.sympk");
    spill::write_spill(&xp, &spill_path).expect("write spill file");
    let xs = SymPackedSpilled::open(&spill_path).expect("open spill file");
    let r_spilled = bench(&format!("spilled X·F apply_into ({m2}x{m2}, k={k2})"), 1, 5, || {
        xs.apply_into(&f2, &mut out2);
    });
    println!("{}   {:.2} GF/s", r_spilled.report(), gflops(flops2, r_spilled.median));
    record(
        &mut records,
        "symm_spilled_apply_into",
        &format!("{m2}x{m2}·{m2}x{k2}"),
        &r_spilled,
        flops2,
    );
    println!(
        "spilled vs resident packed SYMM at m={m2}, k={k2}: {:.2}% time",
        100.0 * r_spilled.median / r_packedx_simd.median.max(1e-300)
    );

    // --- operator cache: a hit must skip construction entirely (the row
    // is bookkeeping-only, orders of magnitude under the miss row, which
    // pays the full SymPacked build)
    let cache = OpCache::new(OpCacheConfig::new(bench_tmp.join("opcache")));
    let key = OpKey::of_packed(&xp);
    drop(cache.pin_or_build(&key, || CachedOperator::Packed(SymPacked::from_dense(&x2))));
    let r_hit = bench(&format!("opcache pin hit ({m2}x{m2} packed)"), 10, 9, || {
        std::hint::black_box(&cache.pin_or_build(&key, || unreachable!("hit must not build")));
    });
    println!("{}", r_hit.report());
    record(&mut records, "opcache_hit", &format!("{m2}x{m2} packed"), &r_hit, 0.0);
    let cache_dir = bench_tmp.join("opcache-miss");
    let r_miss = bench(&format!("opcache miss + build ({m2}x{m2} packed)"), 1, 5, || {
        let fresh = OpCache::new(OpCacheConfig::new(cache_dir.clone()));
        drop(fresh.pin_or_build(&key, || CachedOperator::Packed(SymPacked::from_dense(&x2))));
    });
    println!("{}", r_miss.report());
    record(&mut records, "opcache_miss_build", &format!("{m2}x{m2} packed"), &r_miss, 0.0);
    println!(
        "opcache hit vs miss+build: {:.4}% time",
        100.0 * r_hit.median / r_miss.median.max(1e-300)
    );
    let _ = std::fs::remove_dir_all(&bench_tmp);

    // --- packed-panel NT GEMM vs the unpacked 2×4 reference ---
    // (the W·Hᵀ reconstruction shape at the acceptance m=2048/k=32)
    let nt_a = DenseMat::gaussian(m2, k2, &mut rng);
    let nt_b = DenseMat::gaussian(m2, k2, &mut rng);
    let mut nt_c = DenseMat::zeros(m2, m2);
    let nt_flops = 2.0 * (m2 * m2 * k2) as f64;
    let r_pk = bench(&format!("matmul_nt packed   ({m2}x{k2} · {m2}x{k2}ᵀ)"), 1, 5, || {
        blas::matmul_nt_into_packed_isa(KernelIsa::Scalar, &nt_a, &nt_b, &mut nt_c);
    });
    println!("{}   {:.2} GF/s", r_pk.report(), gflops(nt_flops, r_pk.median));
    record(&mut records, "matmul_nt_packed", &format!("{m2}x{k2}·{m2}x{k2}T"), &r_pk, nt_flops);
    let r_pk_simd =
        bench(&format!("matmul_nt simd [{}] ({m2}x{k2} · {m2}x{k2}ᵀ)", simd::active().as_str()), 1, 5, || {
            blas::matmul_nt_into_packed(&nt_a, &nt_b, &mut nt_c);
        });
    println!("{}   {:.2} GF/s", r_pk_simd.report(), gflops(nt_flops, r_pk_simd.median));
    record(
        &mut records,
        "matmul_nt_simd",
        &format!("{m2}x{k2}·{m2}x{k2}T"),
        &r_pk_simd,
        nt_flops,
    );
    let r_un = bench(&format!("matmul_nt unpacked ({m2}x{k2} · {m2}x{k2}ᵀ)"), 1, 5, || {
        blas::matmul_nt_into_unpacked(&nt_a, &nt_b, &mut nt_c);
    });
    println!("{}   {:.2} GF/s", r_un.report(), gflops(nt_flops, r_un.median));
    record(
        &mut records,
        "matmul_nt_unpacked",
        &format!("{m2}x{k2}·{m2}x{k2}T"),
        &r_un,
        nt_flops,
    );
    println!(
        "packed vs unpacked NT GEMM at m={m2}, k={k2}: {:.2}% time",
        100.0 * r_pk.median / r_un.median.max(1e-300)
    );

    // --- Gram FᵀF ---
    let tall = DenseMat::gaussian(100_000, k, &mut rng);
    let mut gout = DenseMat::zeros(k, k);
    let r = bench("gram FᵀF   (100000x16)", 2, 9, || {
        blas::gram_into(&tall, &mut gout);
    });
    let gflop = (100_000 * k * k) as f64;
    println!("{}   {:.2} GF/s", r.report(), gflops(gflop, r.median));
    record(&mut records, "gram_into", "100000x16", &r, gflop);

    // --- sparse SpMM ---
    let n = 50_000;
    let mut trips = Vec::new();
    for i in 0..n {
        for _ in 0..20 {
            let j = rng.below(n);
            trips.push((i, j, 1.0));
        }
    }
    let sp = CsrMat::from_coo(n, n, trips);
    let fs = DenseMat::gaussian(n, k, &mut rng);
    let mut spout = DenseMat::zeros(n, k);
    let r = bench(&format!("spmm       ({n}x{n}, {} nnz, k={k})", sp.nnz()), 2, 9, || {
        sp.spmm_into(&fs, &mut spout);
    });
    let spflops = 2.0 * (sp.nnz() * k) as f64;
    println!("{}   {:.2} GF/s", r.report(), gflops(spflops, r.median));
    record(&mut records, "spmm_into", &format!("{n}x{n} nnz={}", sp.nnz()), &r, spflops);

    // --- tiled vs untiled SpMM on a wide factor (k = 64 > SPMM_PANEL) ---
    let kw = 64;
    let fw = DenseMat::gaussian(n, kw, &mut rng);
    let mut spout_w = DenseMat::zeros(n, kw);
    let spflops_w = 2.0 * (sp.nnz() * kw) as f64;
    let r_tiled = bench(&format!("spmm tiled  ({n}x{n}, k={kw})"), 2, 9, || {
        sp.spmm_into(&fw, &mut spout_w);
    });
    println!("{}   {:.2} GF/s", r_tiled.report(), gflops(spflops_w, r_tiled.median));
    record(&mut records, "spmm_tiled_into", &format!("{n}x{n} k={kw}"), &r_tiled, spflops_w);
    let r_flat = bench(&format!("spmm untiled ({n}x{n}, k={kw})"), 2, 9, || {
        sp.spmm_into_panels(&fw, &mut spout_w, kw);
    });
    println!("{}   {:.2} GF/s", r_flat.report(), gflops(spflops_w, r_flat.median));
    record(&mut records, "spmm_untiled_into", &format!("{n}x{n} k={kw}"), &r_flat, spflops_w);

    // --- transpose-free HALS sweep vs the staged-transpose reference ---
    let hm = 20_000;
    let hals_w0 = {
        let mut w = DenseMat::gaussian(hm, k, &mut rng);
        w.project_nonneg();
        w
    };
    let hals_g = {
        let a = DenseMat::gaussian(hm, k, &mut rng);
        let mut g = blas::gram(&a);
        g.add_diag(1.0);
        g
    };
    let hals_y = DenseMat::gaussian(hm, k, &mut rng);
    let hals_flops = 2.0 * (hm * k * k) as f64;
    let mut hw = hals_w0.clone();
    let r_hals = bench(&format!("HALS row-major sweep ({hm}x{k})"), 2, 9, || {
        hals::hals_sweep_isa(KernelIsa::Scalar, &hals_g, &hals_y, &mut hw);
    });
    println!("{}   {:.2} GF/s", r_hals.report(), gflops(hals_flops, r_hals.median));
    record(&mut records, "hals_rowmajor", &format!("{hm}x{k}"), &r_hals, hals_flops);
    let mut hw_simd = hals_w0.clone();
    let r_hals_simd =
        bench(&format!("HALS simd sweep [{}] ({hm}x{k})", simd::active().as_str()), 2, 9, || {
            hals::hals_sweep(&hals_g, &hals_y, &mut hw_simd);
        });
    println!("{}   {:.2} GF/s", r_hals_simd.report(), gflops(hals_flops, r_hals_simd.median));
    record(&mut records, "hals_sweep_simd", &format!("{hm}x{k}"), &r_hals_simd, hals_flops);
    let mut hw_ref = hals_w0.clone();
    let r_hals_ref = bench(&format!("HALS transpose-staged ({hm}x{k})"), 2, 9, || {
        hals::hals_sweep_reference(&hals_g, &hals_y, &mut hw_ref);
    });
    println!(
        "{}   {:.2} GF/s",
        r_hals_ref.report(),
        gflops(hals_flops, r_hals_ref.median)
    );
    record(
        &mut records,
        "hals_transpose_ref",
        &format!("{hm}x{k}"),
        &r_hals_ref,
        hals_flops,
    );

    // --- dispatch fan-out overhead: persistent pool vs per-call spawn ---
    // Empty slot bodies, so secs_per_iter IS the dispatch cost. On a
    // 1-core host both collapse to an inline call and the ratio is ~1;
    // on multicore the pooled row should beat the scoped row by the
    // thread spawn+join cost. Pure-overhead timings are scheduler-noisy,
    // so both rows sit on the regression gate's noisy allowlist.
    let fan_parts = num_threads();
    let r_fan_pooled = {
        let _g = pool::override_backend(PoolBackend::Pooled);
        bench(&format!("dispatch fan-out pooled (parts={fan_parts})"), 20, 200, || {
            pool::dispatch_with(PoolBackend::Pooled, fan_parts, &|_| {});
        })
    };
    println!("{}", r_fan_pooled.report());
    record(&mut records, "pool_fanout_overhead", &format!("parts={fan_parts}"), &r_fan_pooled, 0.0);
    let r_fan_scoped = bench(&format!("dispatch fan-out scoped (parts={fan_parts})"), 5, 50, || {
        pool::dispatch_with(PoolBackend::Scoped, fan_parts, &|_| {});
    });
    println!("{}", r_fan_scoped.report());
    record(&mut records, "pool_fanout_scoped_ref", &format!("parts={fan_parts}"), &r_fan_scoped, 0.0);
    println!(
        "    fan-out speedup (scoped/pooled): {:.2}x",
        r_fan_scoped.median / r_fan_pooled.median.max(1e-12)
    );

    // --- HALS sweep pinned per dispatch backend ---
    // hals_sweep_simd above runs whatever SYMNMF_POOL says; these two
    // rows pin each backend so the pooled win (and any regression in it)
    // is visible regardless of the leg's environment.
    let mut hw_pooled = hals_w0.clone();
    let r_hals_pooled = {
        let _g = pool::override_backend(PoolBackend::Pooled);
        bench(&format!("HALS sweep pooled ({hm}x{k})"), 2, 9, || {
            hals::hals_sweep(&hals_g, &hals_y, &mut hw_pooled);
        })
    };
    println!("{}   {:.2} GF/s", r_hals_pooled.report(), gflops(hals_flops, r_hals_pooled.median));
    record(&mut records, "hals_sweep_pooled", &format!("{hm}x{k}"), &r_hals_pooled, hals_flops);
    let mut hw_scoped = hals_w0.clone();
    let r_hals_scoped = {
        let _g = pool::override_backend(PoolBackend::Scoped);
        bench(&format!("HALS sweep scoped ({hm}x{k})"), 2, 9, || {
            hals::hals_sweep(&hals_g, &hals_y, &mut hw_scoped);
        })
    };
    println!("{}   {:.2} GF/s", r_hals_scoped.report(), gflops(hals_flops, r_hals_scoped.median));
    record(&mut records, "hals_sweep_scoped", &format!("{hm}x{k}"), &r_hals_scoped, hals_flops);
    println!(
        "    hals sweep speedup (scoped/pooled): {:.2}x",
        r_hals_scoped.median / r_hals_pooled.median.max(1e-12)
    );
    for (a, b) in hw_pooled.data().iter().zip(hw_scoped.data()) {
        assert_eq!(a.to_bits(), b.to_bits(), "pooled HALS sweep diverged from scoped");
    }

    // --- compressed solve, f64 vs f32 sketched GEMMs ---
    // Same workload either way; the f32 row shows what staging the inner
    // Q/ B̃ᵀ products in single precision (f64 accumulation) buys.
    let (cx, copts) = {
        let mut crng = Pcg64::seed_from_u64(9);
        let ch = DenseMat::uniform(512, 8, 1.0, &mut crng);
        let mut cx = blas::matmul_nt(&ch, &ch);
        cx.symmetrize();
        let mut o = SymNmfOptions::new(8).with_rule(UpdateRule::Hals).with_seed(5);
        o.max_iters = 15;
        (cx, o)
    };
    let o64 = copts.clone().with_precision(Precision::F64);
    let r_c64 = bench("compressed f64 (512², k=8, 15 iters)", 1, 5, || {
        std::hint::black_box(compressed_symnmf(&cx, &o64));
    });
    println!("{}", r_c64.report());
    record(&mut records, "compressed_f64", "512x512 k=8", &r_c64, 0.0);
    let o32 = copts.clone().with_precision(Precision::F32);
    let r_c32 = bench("compressed f32 (512², k=8, 15 iters)", 1, 5, || {
        std::hint::black_box(compressed_symnmf(&cx, &o32));
    });
    println!("{}", r_c32.report());
    record(&mut records, "compressed_f32", "512x512 k=8", &r_c32, 0.0);
    println!(
        "compressed f32 vs f64 solve: {:.2}% time",
        100.0 * r_c32.median / r_c64.median.max(1e-300)
    );

    // --- batched vs serial multi-seed trials (shared X, 4 seeds) ---
    let (tx, topts) = {
        let mut trng = Pcg64::seed_from_u64(7);
        let th = DenseMat::uniform(192, 4, 1.0, &mut trng);
        let mut tx = blas::matmul_nt(&th, &th);
        tx.symmetrize();
        let mut o = SymNmfOptions::new(4);
        o.rule = UpdateRule::Hals;
        o.max_iters = 10;
        (tx, o)
    };
    let r_ser = bench("run_trials serial (192², k=4, 4 seeds)", 1, 5, || {
        std::hint::black_box(run_trials(
            Method::Exact(UpdateRule::Hals),
            &tx,
            &topts,
            None,
            4,
        ));
    });
    println!("{}", r_ser.report());
    record(&mut records, "trials_serial", "m=192 k=4 x4", &r_ser, 0.0);
    let r_bat = bench("run_trials batched (192², k=4, 4 seeds)", 1, 5, || {
        std::hint::black_box(run_trials_batched(
            Method::Exact(UpdateRule::Hals),
            &tx,
            &topts,
            None,
            4,
        ));
    });
    println!("{}", r_bat.report());
    record(&mut records, "trials_batched", "m=192 k=4 x4", &r_bat, 0.0);
    println!(
        "batched vs serial trials: {:.2}% time",
        100.0 * r_bat.median / r_ser.median.max(1e-300)
    );
    // batched trials under an explicit outer thread budget (half the
    // machine): results are bitwise identical by construction — this row
    // tracks the scheduling cost of the cap.
    let half = (symnmf::util::threadpool::num_threads() / 2).max(1);
    let r_budget = bench(
        &format!("run_trials batched, budget {half} (192², k=4, 4 seeds)"),
        1,
        5,
        || {
            symnmf::util::threadpool::with_thread_budget(half, || {
                std::hint::black_box(run_trials_batched(
                    Method::Exact(UpdateRule::Hals),
                    &tx,
                    &topts,
                    None,
                    4,
                ));
            });
        },
    );
    println!("{}", r_budget.report());
    record(
        &mut records,
        "trials_batched_budget",
        &format!("m=192 k=4 x4 nt={half}"),
        &r_budget,
        0.0,
    );

    // --- engine outer loop vs the frozen legacy loop (Exact-HALS on the
    // acceptance shape m=2048/k=32, 3 iterations per solve): the delta is
    // the per-step overhead of the resumable engine machinery — it should
    // be noise against the three m²k products every iteration performs ---
    let eng_opts = {
        let mut o = SymNmfOptions::new(k2).with_rule(UpdateRule::Hals).with_seed(5);
        o.max_iters = 3;
        o.patience = usize::MAX; // fixed 3 iterations, no early stop
        o
    };
    let eng_flops = 3.0 * 3.0 * flops2; // 3 iters × (2 update + 1 metric) X·F
    let r_eng = bench(&format!("engine loop Exact-HALS ({m2}², k={k2}, 3 iters)"), 1, 5, || {
        std::hint::black_box(symnmf_anls(&x2, &eng_opts));
    });
    println!("{}   {:.2} GF/s", r_eng.report(), gflops(eng_flops, r_eng.median));
    record(
        &mut records,
        "engine_step_overhead",
        &format!("m={m2} k={k2} x3"),
        &r_eng,
        eng_flops,
    );
    let r_leg = bench(&format!("legacy loop Exact-HALS ({m2}², k={k2}, 3 iters)"), 1, 5, || {
        let mut rng = Pcg64::seed_from_u64(eng_opts.seed);
        let alpha = resolve_alpha(&x2, &eng_opts);
        let h0 = initial_factor(&x2, &eng_opts, &mut rng);
        let metrics = Metrics::new(&x2, true);
        std::hint::black_box(run_alternating_loop(
            &x2,
            alpha,
            &eng_opts,
            h0,
            &metrics,
            "HALS".to_string(),
            0.0,
            PhaseTimer::new(),
        ));
    });
    println!("{}   {:.2} GF/s", r_leg.report(), gflops(eng_flops, r_leg.median));
    record(
        &mut records,
        "legacy_loop_step",
        &format!("m={m2} k={k2} x3"),
        &r_leg,
        eng_flops,
    );
    println!(
        "engine vs legacy loop at m={m2}, k={k2}: {:.2}% time",
        100.0 * r_eng.median / r_leg.median.max(1e-300)
    );

    // --- streamed CSR → SymPacked construction (no transient dense) ---
    let m4 = 4096;
    let mut sp4_trips = Vec::new();
    for i in 0..m4 {
        for _ in 0..10 {
            let j = rng.below(m4);
            let v = 1.0 + rng.uniform();
            sp4_trips.push((i, j, v));
            if i != j {
                sp4_trips.push((j, i, v));
            }
        }
    }
    let sp4 = CsrMat::from_coo(m4, m4, sp4_trips);
    let r_csr = bench(
        &format!("SymPacked::from_csr streamed ({m4}², {} nnz)", sp4.nnz()),
        1,
        5,
        || {
            std::hint::black_box(SymPacked::from_csr(&sp4));
        },
    );
    println!("{}", r_csr.report());
    record(
        &mut records,
        "from_csr_streamed",
        &format!("{m4}x{m4} nnz={}", sp4.nnz()),
        &r_csr,
        0.0,
    );

    // --- parallel panel packing (wide B: 256 panels split across
    // workers; pure data movement, bitwise-neutral) ---
    let pk_b = DenseMat::gaussian(2048, 256, &mut rng);
    let mut pk_buf = PanelBuf::new();
    let r_pack = bench("pack B panels, parallel (2048x256 → 256 panels)", 2, 9, || {
        std::hint::black_box(blas::pack_nt_panels(&pk_b, &mut pk_buf));
    });
    println!("{}", r_pack.report());
    record(&mut records, "pack_b_panels_par", "2048x256", &r_pack, 0.0);

    // --- serve path: scheduler-sliced solve vs one-shot engine run ---
    // A fixed-length 6-iteration HALS solve driven as a serve job in 6
    // single-step slices (checkpoint clone + requeue per slice) against
    // the same solve in one direct engine call — the delta is the
    // serving layer's slice overhead.
    let (srv_m, srv_k) = (256usize, 8usize);
    let srv_x = {
        let hh = DenseMat::uniform(srv_m, srv_k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&hh, &hh);
        x.symmetrize();
        x
    };
    let mut srv_opts = SymNmfOptions::new(srv_k).with_seed(3);
    srv_opts.max_iters = 6;
    srv_opts.patience = 1000; // fixed length: measure slicing, not stopping
    let srv_method = Method::Exact(UpdateRule::Hals);
    let r_direct = bench(
        &format!("direct engine run ({srv_m}², k={srv_k}, 6 iters)"),
        1,
        5,
        || {
            std::hint::black_box(srv_method.run_controlled(
                &srv_x,
                &srv_opts,
                &RunControl::unlimited(),
                None,
            ));
        },
    );
    println!("{}", r_direct.report());
    let r_sliced = bench("serve-sliced run (same solve, 6 slices of 1)", 1, 5, || {
        let mut sched = Scheduler::new(SchedulerConfig {
            slice_steps: Some(1),
            ..SchedulerConfig::default()
        });
        let h = sched
            .submit(&srv_x, JobSpec::new("bench", srv_method, srv_opts.clone()))
            .expect("submit");
        sched.drain();
        std::hint::black_box(h.outcome().expect("drained").expect_result().iters());
    });
    println!(
        "{}   ({:.1}% of direct)",
        r_sliced.report(),
        100.0 * r_sliced.median / r_direct.median.max(1e-300)
    );
    record(
        &mut records,
        "serve_slice_overhead",
        &format!("{srv_m}x{srv_m} k={srv_k} 6x1"),
        &r_sliced,
        0.0,
    );

    // --- unarmed fail-point hit (the crash-safety steady-state tax) ---
    // SYMNMF_FAILPOINTS is unset in the bench environment, so every hit
    // is the off path: one relaxed atomic load. 1M scoped hits per rep
    // keep the measurement above timer noise.
    let r_fp = bench("failpoint unarmed hit (1M scoped hits)", 2, 9, || {
        for _ in 0..1_000_000u32 {
            std::hint::black_box(symnmf::util::failpoint::hit_scoped(
                "ckpt_save", "bench",
            ))
            .expect("unarmed fail point never errors");
        }
    });
    println!("{}", r_fp.report());
    record(&mut records, "failpoint_unarmed_hit", "1M hits", &r_fp, 0.0);

    // --- checkpoint serialize + parse (the job-store hot path) ---
    let big_cp = Checkpoint {
        status: RunStatus::Paused,
        stage: 0,
        stage_iter: 50,
        iter: 50,
        clock: 1.0,
        stop_best: 0.1,
        stop_stall: 0,
        state: EngineState {
            h: DenseMat::gaussian(2048, 32, &mut rng),
            w: Some(DenseMat::gaussian(2048, 32, &mut rng)),
            rng: None,
        },
        records: (0..50)
            .map(|i| IterRecord {
                iter: i,
                time_secs: 0.1 * (i + 1) as f64,
                residual: 1.0 / (i + 2) as f64,
                proj_grad: Some(1e-3),
                phase_secs: (0.05, 0.04, 0.0),
                hybrid_stats: None,
            })
            .collect(),
        isa: Some(simd::active().as_str().to_string()),
    };
    let r_cp = bench("checkpoint serialize+parse (2048x32, 50 records)", 1, 5, || {
        let text = big_cp.serialize();
        std::hint::black_box(Checkpoint::parse(&text).expect("parse"));
    });
    println!("{}", r_cp.report());
    record(&mut records, "checkpoint_save_load", "2048x32x50", &r_cp, 0.0);

    // --- sampled SpMM (LvS inner product, s = 0.05·n) ---
    let h = DenseMat::gaussian(n, k, &mut rng);
    let lev = qr::leverage_scores(&h);
    let s = n / 20;
    let sm = sample_hybrid(&lev, s, 1.0 / s as f64, &mut rng);
    let w_sq = sm.weights_sq();
    let mut samp_out = DenseMat::zeros(n, k);
    let r = bench(&format!("sampled spmm (s={s})"), 2, 9, || {
        sp.sampled_spmm_sym_into(&fs, &sm.indices, w_sq, &mut samp_out);
    });
    println!("{}", r.report());
    record(&mut records, "sampled_spmm_into", &format!("s={s}"), &r, 0.0);

    // --- CholeskyQR leverage scores (the per-iteration sampling cost) ---
    let r = bench(&format!("choleskyQR + leverage ({n}x{k})"), 2, 9, || {
        std::hint::black_box(qr::leverage_scores(&h));
    });
    println!("{}", r.report());
    record(&mut records, "leverage_scores", &format!("{n}x{k}"), &r, 0.0);

    // --- LvS sampled apply: chunked parallel kernels vs their retained
    // serial scalar oracles. The two are bitwise-equal by construction
    // (gather-over-chunks, see randnla::op), so the printed ratio is the
    // pure parallel+SIMD win on this box. ---
    let isa = simd::active();
    let s2 = m2 / 20;
    let smd = sample_hybrid(&qr::leverage_scores(&f2), s2, 1.0 / s2 as f64, &mut rng);
    let mut lvs_out = DenseMat::zeros(m2, k2);
    let r_par = bench(&format!("LvS sampled apply dense ({m2}², s={s2})"), 2, 9, || {
        sampled_apply_dense_isa(isa, &x2, &f2, &smd.indices, smd.weights_sq(), &mut lvs_out);
    });
    let r_ser = bench("LvS sampled apply dense (serial oracle)", 2, 9, || {
        sampled_apply_dense_serial(&x2, &f2, &smd.indices, smd.weights_sq(), &mut lvs_out);
    });
    println!("{}", r_par.report());
    println!(
        "LvS sampled apply dense: parallel vs serial oracle {:.2}% time",
        100.0 * r_par.median / r_ser.median.max(1e-300)
    );
    record(
        &mut records,
        "lvs_sampled_apply_dense",
        &format!("{m2}²,s={s2}"),
        &r_par,
        0.0,
    );

    let r_par = bench(&format!("LvS sampled apply packed ({m2}², s={s2})"), 2, 9, || {
        xp.sampled_apply_into_isa(isa, &f2, &smd.indices, smd.weights_sq(), &mut lvs_out);
    });
    let r_ser = bench("LvS sampled apply packed (serial oracle)", 2, 9, || {
        xp.sampled_apply_into_serial(&f2, &smd.indices, smd.weights_sq(), &mut lvs_out);
    });
    println!("{}", r_par.report());
    println!(
        "LvS sampled apply packed: parallel vs serial oracle {:.2}% time",
        100.0 * r_par.median / r_ser.median.max(1e-300)
    );
    record(
        &mut records,
        "lvs_sampled_apply_packed",
        &format!("{m2}²,s={s2}"),
        &r_par,
        0.0,
    );

    let r_par = bench(&format!("LvS sampled apply csr (s={s})"), 2, 9, || {
        sp.sampled_spmm_sym_into_isa(isa, &fs, &sm.indices, w_sq, &mut samp_out);
    });
    let r_ser = bench("LvS sampled apply csr (serial oracle)", 2, 9, || {
        sp.sampled_spmm_sym_into_serial(&fs, &sm.indices, w_sq, &mut samp_out);
    });
    println!("{}", r_par.report());
    println!(
        "LvS sampled apply csr: parallel vs serial oracle {:.2}% time",
        100.0 * r_par.median / r_ser.median.max(1e-300)
    );
    record(&mut records, "lvs_sampled_apply_csr", &format!("s={s}"), &r_par, 0.0);

    // --- allocation-free sampling pipeline (leverage scores + hybrid
    // sampler, all buffers persistent — one LvS half-step's sampling
    // phase after warm-up) ---
    let mut sw = SampleWorkspace::new(n, k, s);
    let mut rng_sb = Pcg64::seed_from_u64(9);
    qr::leverage_scores_via_chol_into(&h, &mut sw);
    sample_hybrid_ws(s, 1.0 / s as f64, &mut rng_sb, &mut sw); // warm-up
    let r_sb = bench(&format!("LvS sample build ({n}x{k}, s={s})"), 2, 9, || {
        qr::leverage_scores_via_chol_into(&h, &mut sw);
        std::hint::black_box(sample_hybrid_ws(s, 1.0 / s as f64, &mut rng_sb, &mut sw));
    });
    println!("{}", r_sb.report());
    record(&mut records, "lvs_sample_build", &format!("{n}x{k},s={s}"), &r_sb, 0.0);

    // --- BPP multi-RHS (the Solve bar of Fig. 3) ---
    let g = {
        let a = DenseMat::gaussian(k + 8, k, &mut rng);
        let mut g = blas::gram(&a);
        for i in 0..k {
            *g.at_mut(i, i) += 0.1;
        }
        g
    };
    let y = DenseMat::gaussian(20_000, k, &mut rng);
    let mut bpp_out = DenseMat::zeros(20_000, k);
    let r = bench("BPP multi-RHS (20000 rows, k=16)", 1, 5, || {
        bpp::solve_multi_into(&g, &y, None, &mut bpp_out);
    });
    println!("{}", r.report());
    record(&mut records, "bpp_multi_into", "20000x16", &r, 0.0);

    // --- PJRT round-trip for the same X·F (AOT Pallas path) ---
    match PjrtRuntime::from_default_dir() {
        Ok(rt) => {
            let f7 = DenseMat::gaussian(m, 7, &mut rng);
            let op = PjrtSymOp::new(x.clone(), Rc::new(rt));
            if op.products_pjrt(&f7).is_some() {
                let r = bench("PJRT products (1024x1024·1024x7 + gram)", 2, 9, || {
                    std::hint::black_box(op.products_pjrt(&f7));
                });
                let flops = 2.0 * (m * m * 7) as f64;
                println!("{}   {:.2} GF/s", r.report(), gflops(flops, r.median));
                record(&mut records, "pjrt_products", "1024x1024·1024x7", &r, flops);
                // native same-shape comparison
                let mut o7 = DenseMat::zeros(m, 7);
                let r = bench("native products (same shapes)", 2, 9, || {
                    blas::symm_tall_into(&x, &f7, &mut o7);
                    std::hint::black_box(blas::gram(&f7));
                });
                println!("{}   {:.2} GF/s", r.report(), gflops(flops, r.median));
                record(&mut records, "native_products", "1024x1024·1024x7", &r, flops);
            } else {
                println!("PJRT products artifact for m=1024,k=7 not found — run `make artifacts`");
            }
        }
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }

    write_json(&records);
}
