//! Compressed Sparse Row matrix.
//!
//! The paper stores the OAG adjacency in MATLAB's CSC and exploits
//! symmetry for fast row slicing (§5.2); we store CSR and rely on the
//! same symmetry (row i ≡ column i), which makes both the SpMM X·F and
//! the LvS sampled products row-gather-friendly.

use crate::linalg::simd::{self, KernelIsa};
use crate::linalg::DenseMat;
use crate::util::threadpool::{parallel_for_chunks, SendPtr};

/// Column-panel width of the tiled SpMM paths. 32 f64 columns keep a
/// panel row within half a cache line pair and bound the working set of
/// a 256-row chunk's gathered F rows to the L2 budget on wide factors
/// (k > SPMM_PANEL triggers tiling; the LAI/compressed drivers run with
/// l = k + ρ ≥ 3k columns, well past it).
pub const SPMM_PANEL: usize = 32;

/// CSR sparse matrix of f64.
#[derive(Clone, Debug)]
pub struct CsrMat {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl CsrMat {
    /// Build from COO triplets; duplicate (i, j) entries are summed.
    pub fn from_coo(
        rows: usize,
        cols: usize,
        mut triplets: Vec<(usize, usize, f64)>,
    ) -> CsrMat {
        triplets.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values: Vec<f64> = Vec::with_capacity(triplets.len());
        let mut last: Option<(usize, usize)> = None;
        for &(i, j, v) in &triplets {
            assert!(i < rows && j < cols, "triplet ({i},{j}) out of bounds");
            if last == Some((i, j)) {
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(j);
                values.push(v);
                indptr[i + 1] += 1; // per-row count for now
                last = Some((i, j));
            }
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i]; // counts → offsets
        }
        CsrMat { rows, cols, indptr, indices, values }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// (column indices, values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    pub fn fro_norm_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    pub fn max_value(&self) -> f64 {
        self.values.iter().cloned().fold(0.0f64, f64::max)
    }

    /// Mean over ALL m·n entries (zeros included) — the ζ of the §5 init.
    pub fn mean_dense(&self) -> f64 {
        self.values.iter().sum::<f64>() / (self.rows as f64 * self.cols as f64)
    }

    /// Check structural symmetry with matching values (used by tests and
    /// the experiment driver to validate generated graphs).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                if (self.get(j, i) - v).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// O(log nnz_row) entry lookup.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&j) {
            Ok(p) => vals[p],
            Err(_) => 0.0,
        }
    }

    /// Dense SpMM: out = X·F (X: m×n sparse, F: n×k dense) — the sparse
    /// counterpart of the per-iteration hot product.
    pub fn spmm(&self, f: &DenseMat) -> DenseMat {
        let mut out = DenseMat::zeros(self.rows, f.cols());
        self.spmm_into(f, &mut out);
        out
    }

    /// SpMM into a pre-allocated output, with column-panel tiling on wide
    /// factors: for k > [`SPMM_PANEL`] the dense factor is processed in
    /// `SPMM_PANEL`-wide column panels, so the randomly-gathered F rows of
    /// a row chunk stay cache-resident within each panel instead of
    /// thrashing on full k-wide rows. Per-entry accumulation order is
    /// unchanged, so results are bitwise identical to the untiled path.
    pub fn spmm_into(&self, f: &DenseMat, out: &mut DenseMat) {
        self.spmm_into_panels(f, out, SPMM_PANEL);
    }

    /// [`CsrMat::spmm_into`] with an explicit column-panel width
    /// (`panel >= k` disables tiling). Exposed so benchmarks and property
    /// tests can compare tiled and untiled execution directly. Row chunks
    /// are dispatched on the shared persistent pool
    /// ([`crate::util::pool`]); the backend choice cannot change bits.
    pub fn spmm_into_panels(&self, f: &DenseMat, out: &mut DenseMat, panel: usize) {
        assert_eq!(self.cols, f.rows(), "spmm dims");
        assert_eq!(out.shape(), (self.rows, f.cols()));
        let k = f.cols();
        let panel = panel.max(1);
        let indptr = &self.indptr;
        let indices = &self.indices;
        let values = &self.values;
        let fd = f.data();
        let optr = SendPtr(out.data_mut().as_mut_ptr());
        parallel_for_chunks(self.rows, 256, move |lo, hi| {
            let odata = optr;
            if k <= panel {
                for i in lo..hi {
                    // SAFETY: disjoint row ranges per worker.
                    let orow = unsafe {
                        std::slice::from_raw_parts_mut(odata.0.add(i * k), k)
                    };
                    orow.fill(0.0);
                    for p in indptr[i]..indptr[i + 1] {
                        let j = indices[p];
                        let v = values[p];
                        crate::linalg::blas::axpy(v, &fd[j * k..(j + 1) * k], orow);
                    }
                }
                return;
            }
            // Column-tiled: the CSR structure of the chunk is re-streamed
            // once per panel (sequential, cheap) while the F panel rows it
            // gathers stay L2-resident across the chunk's sparse rows.
            let mut c0 = 0;
            while c0 < k {
                let c1 = (c0 + panel).min(k);
                let w = c1 - c0;
                for i in lo..hi {
                    // SAFETY: disjoint row ranges per worker.
                    let oseg = unsafe {
                        std::slice::from_raw_parts_mut(odata.0.add(i * k + c0), w)
                    };
                    oseg.fill(0.0);
                    for p in indptr[i]..indptr[i + 1] {
                        let j = indices[p];
                        let v = values[p];
                        crate::linalg::blas::axpy(v, &fd[j * k + c0..j * k + c1], oseg);
                    }
                }
                c0 = c1;
            }
        });
    }

    /// Sampled product X·SᵀS·F = Σ_r c_r² · x_{:,i_r} · F[i_r, :] for a
    /// **symmetric** X (column i_r read as row i_r). This is the LvS
    /// replacement of X·F (paper §4.1.1): cost O(s·nnz_row·k) instead of
    /// O(nnz·k). `samples` are row indices i_r, `weights` the squared
    /// rescaling factors c_r² = 1/(s·p_{i_r}).
    pub fn sampled_spmm_sym(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights: &[f64],
    ) -> DenseMat {
        let mut out = DenseMat::zeros(self.rows, f.cols());
        self.sampled_spmm_sym_into(f, samples, weights, &mut out);
        out
    }

    /// [`CsrMat::sampled_spmm_sym`] into a pre-allocated output (fully
    /// overwritten) — the LvS hot-path form. Dispatches to the parallel
    /// ISA-routed kernel; bitwise-pinned to the serial oracle.
    pub fn sampled_spmm_sym_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights: &[f64],
        out: &mut DenseMat,
    ) {
        self.sampled_spmm_sym_into_isa(simd::active(), f, samples, weights, out);
    }

    /// Serial scalar oracle for the sampled product: sample-major
    /// scatter, columns ascending inside each sample, column-panel tiled
    /// on wide k like [`CsrMat::spmm_into`] (per-entry accumulation
    /// order is unchanged, so tiling is bitwise-neutral). Retained
    /// verbatim as the pinning reference for
    /// [`CsrMat::sampled_spmm_sym_into_isa`].
    pub fn sampled_spmm_sym_into_serial(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights: &[f64],
        out: &mut DenseMat,
    ) {
        assert_eq!(self.rows, self.cols, "sampled_spmm_sym needs symmetric X");
        assert_eq!(samples.len(), weights.len());
        let k = f.cols();
        assert_eq!(out.shape(), (self.rows, k), "sampled_spmm_sym_into shape");
        let od = out.data_mut();
        od.fill(0.0);
        let fd = f.data();
        if k <= SPMM_PANEL {
            for (&ir, &w) in samples.iter().zip(weights) {
                let frow = &fd[ir * k..(ir + 1) * k];
                let (cols, vals) = self.row(ir);
                for (&j, &v) in cols.iter().zip(vals) {
                    crate::linalg::blas::axpy(w * v, frow, &mut od[j * k..(j + 1) * k]);
                }
            }
            return;
        }
        let mut c0 = 0;
        while c0 < k {
            let c1 = (c0 + SPMM_PANEL).min(k);
            for (&ir, &w) in samples.iter().zip(weights) {
                let fseg = &fd[ir * k + c0..ir * k + c1];
                let (cols, vals) = self.row(ir);
                for (&j, &v) in cols.iter().zip(vals) {
                    crate::linalg::blas::axpy(w * v, fseg, &mut od[j * k + c0..j * k + c1]);
                }
            }
            c0 = c1;
        }
    }

    /// Parallel, ISA-dispatched sampled product — the scatter of
    /// [`CsrMat::sampled_spmm_sym_into_serial`] reformulated as a gather
    /// over disjoint output-row chunks (see `randnla::op` module docs).
    /// Each worker owns rows `[lo,hi)` and walks all samples in order,
    /// binary-searching the sampled row's sorted column slice down to
    /// the entries landing in its range; per output element the
    /// accumulation order matches the serial oracle exactly, so the
    /// result is bitwise-identical at any thread count.
    pub fn sampled_spmm_sym_into_isa(
        &self,
        isa: KernelIsa,
        f: &DenseMat,
        samples: &[usize],
        weights: &[f64],
        out: &mut DenseMat,
    ) {
        assert_eq!(self.rows, self.cols, "sampled_spmm_sym needs symmetric X");
        assert_eq!(samples.len(), weights.len());
        let k = f.cols();
        assert_eq!(out.shape(), (self.rows, k), "sampled_spmm_sym_into shape");
        let fd = f.data();
        let optr = SendPtr(out.data_mut().as_mut_ptr());
        parallel_for_chunks(self.rows, 256, move |lo, hi| {
            // SAFETY: chunks hand out disjoint [lo,hi) row ranges, so
            // each worker touches a disjoint slice of `out`.
            let od = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add(lo * k), (hi - lo) * k)
            };
            od.fill(0.0);
            if k <= SPMM_PANEL {
                for (&ir, &w) in samples.iter().zip(weights) {
                    let frow = &fd[ir * k..(ir + 1) * k];
                    let (cols, vals) = self.row(ir);
                    let a = cols.partition_point(|&j| j < lo);
                    let b = cols.partition_point(|&j| j < hi);
                    for (&j, &v) in cols[a..b].iter().zip(&vals[a..b]) {
                        let o = (j - lo) * k;
                        simd::axpy(isa, w * v, frow, &mut od[o..o + k]);
                    }
                }
                return;
            }
            let mut c0 = 0;
            while c0 < k {
                let c1 = (c0 + SPMM_PANEL).min(k);
                for (&ir, &w) in samples.iter().zip(weights) {
                    let fseg = &fd[ir * k + c0..ir * k + c1];
                    let (cols, vals) = self.row(ir);
                    let a = cols.partition_point(|&j| j < lo);
                    let b = cols.partition_point(|&j| j < hi);
                    for (&j, &v) in cols[a..b].iter().zip(&vals[a..b]) {
                        let o = (j - lo) * k;
                        simd::axpy(isa, w * v, fseg, &mut od[o + c0..o + c1]);
                    }
                }
                c0 = c1;
            }
        });
    }

    /// Dense copy (tests / small problems only).
    pub fn to_dense(&self) -> DenseMat {
        let mut out = DenseMat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                out.set(i, j, v);
            }
        }
        out
    }

    /// Scale row i and column i by d[i] (symmetric diagonal scaling
    /// D·A·D). Used by `sym::normalize_sym`.
    pub fn scale_sym(&mut self, d: &[f64]) {
        assert_eq!(d.len(), self.rows);
        for i in 0..self.rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            for p in lo..hi {
                let j = self.indices[p];
                self.values[p] *= d[i] * d[j];
            }
        }
    }

    /// Remove the diagonal (paper §5.2: "the diagonal is zeroed out").
    pub fn zero_diagonal(&mut self) {
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(self.indices.len());
        let mut values = Vec::with_capacity(self.values.len());
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                if j != i {
                    indices.push(j);
                    values.push(v);
                }
            }
            indptr[i + 1] = indices.len();
        }
        self.indptr = indptr;
        self.indices = indices;
        self.values = values;
    }

    /// Row sums (weighted degrees).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| self.row(i).1.iter().sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{dim, forall};
    use crate::util::rng::Pcg64;

    fn random_sparse(rng: &mut Pcg64, n: usize, density: f64) -> CsrMat {
        let mut trips = Vec::new();
        for i in 0..n {
            for j in 0..n {
                if rng.uniform() < density {
                    trips.push((i, j, rng.gaussian()));
                }
            }
        }
        CsrMat::from_coo(n, n, trips)
    }

    #[test]
    fn from_coo_sums_duplicates() {
        let m = CsrMat::from_coo(2, 2, vec![(0, 1, 1.0), (0, 1, 2.5), (1, 0, -1.0)]);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(0, 1), 3.5);
        assert_eq!(m.get(1, 0), -1.0);
        assert_eq!(m.get(0, 0), 0.0);
    }

    #[test]
    fn empty_rows_ok() {
        let m = CsrMat::from_coo(5, 5, vec![(4, 0, 2.0)]);
        assert_eq!(m.nnz(), 1);
        for i in 0..4 {
            assert_eq!(m.row(i).0.len(), 0);
        }
        assert_eq!(m.get(4, 0), 2.0);
    }

    #[test]
    fn spmm_matches_dense_property() {
        forall(
            15,
            800,
            |rng| {
                let n = dim(rng, 1, 25);
                let k = dim(rng, 1, 8);
                (random_sparse(rng, n, 0.3), DenseMat::gaussian(n, k, rng))
            },
            |(x, f)| {
                let got = x.spmm(f);
                let want = crate::linalg::blas::matmul(&x.to_dense(), f);
                let err = got.diff_fro(&want);
                if err < 1e-10 * (1.0 + want.fro_norm()) {
                    Ok(())
                } else {
                    Err(format!("err {err}"))
                }
            },
        );
    }

    /// Tiled SpMM vs the untiled path and the dense product, across
    /// non-multiple-of-panel widths (k = 33, 65 exercise tiling with
    /// partial tail panels; k ≤ 32 exercises the untiled fast path).
    #[test]
    fn tiled_spmm_matches_untiled_and_dense() {
        let mut rng = Pcg64::seed_from_u64(9);
        for n in [1usize, 3, 31, 33, 65] {
            let x = random_sparse(&mut rng, n, 0.4);
            let dense = x.to_dense();
            for k in [1usize, 3, 31, 33, 65] {
                let f = DenseMat::gaussian(n, k, &mut rng);
                let want = crate::linalg::blas::matmul(&dense, &f);
                let mut tiled = DenseMat::zeros(n, k);
                tiled.fill(7.0); // stale data must be overwritten
                x.spmm_into(&f, &mut tiled);
                let err = tiled.diff_fro(&want);
                assert!(
                    err < 1e-12 * (1.0 + want.fro_norm()),
                    "n={n} k={k}: err={err}"
                );
                // tiling must be bitwise-neutral vs the untiled path
                let mut untiled = DenseMat::zeros(n, k);
                x.spmm_into_panels(&f, &mut untiled, k.max(1));
                for (a, b) in tiled.data().iter().zip(untiled.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} k={k}");
                }
            }
        }
    }

    /// The tiled sampled product must stay bitwise identical to the
    /// untiled accumulation (same per-entry order) on wide k.
    #[test]
    fn tiled_sampled_spmm_matches_dense_reference() {
        let mut rng = Pcg64::seed_from_u64(10);
        let n = 30;
        // symmetric sparse X
        let mut trips = Vec::new();
        for i in 0..n {
            for j in i..n {
                if rng.uniform() < 0.3 {
                    let v = rng.gaussian();
                    trips.push((i, j, v));
                    if i != j {
                        trips.push((j, i, v));
                    }
                }
            }
        }
        let x = CsrMat::from_coo(n, n, trips);
        let dense = x.to_dense();
        for k in [31usize, 33, 65] {
            let f = DenseMat::gaussian(n, k, &mut rng);
            let samples = vec![0, 4, 4, 11, 29];
            let weights = vec![0.5, 1.0, 2.0, 0.25, 1.5];
            let got = x.sampled_spmm_sym(&f, &samples, &weights);
            // dense reference: Σ_r w_r · x_{:,i_r} ⊗ F[i_r,:]
            let mut want = DenseMat::zeros(n, k);
            for (&ir, &w) in samples.iter().zip(&weights) {
                for j in 0..n {
                    let xv = dense.at(ir, j);
                    for c in 0..k {
                        *want.at_mut(j, c) += w * xv * f.at(ir, c);
                    }
                }
            }
            let err = got.diff_fro(&want);
            assert!(err < 1e-12 * (1.0 + want.fro_norm()), "k={k}: err={err}");
        }
    }

    #[test]
    fn sampled_spmm_full_sampling_recovers_product() {
        // Taking every row once with weight 1 reproduces X·F exactly.
        let mut rng = Pcg64::seed_from_u64(4);
        let mut x = random_sparse(&mut rng, 20, 0.4);
        // make symmetric
        let dense = x.to_dense();
        let mut trips = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                let v = 0.5 * (dense.at(i, j) + dense.at(j, i));
                if v != 0.0 {
                    trips.push((i, j, v));
                }
            }
        }
        x = CsrMat::from_coo(20, 20, trips);
        let f = DenseMat::gaussian(20, 5, &mut rng);
        let samples: Vec<usize> = (0..20).collect();
        let weights = vec![1.0; 20];
        let got = x.sampled_spmm_sym(&f, &samples, &weights);
        let want = x.spmm(&f);
        assert!(got.diff_fro(&want) < 1e-10, "err {}", got.diff_fro(&want));
    }

    #[test]
    fn zero_diagonal_and_scale() {
        let mut m = CsrMat::from_coo(
            3,
            3,
            vec![(0, 0, 5.0), (0, 1, 1.0), (1, 0, 1.0), (2, 2, 3.0)],
        );
        m.zero_diagonal();
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.get(2, 2), 0.0);
        assert_eq!(m.get(0, 1), 1.0);
        m.scale_sym(&[2.0, 3.0, 1.0]);
        assert_eq!(m.get(0, 1), 6.0);
        assert_eq!(m.get(1, 0), 6.0);
    }

    #[test]
    fn symmetry_check() {
        let sym = CsrMat::from_coo(2, 2, vec![(0, 1, 2.0), (1, 0, 2.0)]);
        assert!(sym.is_symmetric(1e-12));
        let asym = CsrMat::from_coo(2, 2, vec![(0, 1, 2.0)]);
        assert!(!asym.is_symmetric(1e-12));
    }
}
