//! Minimal JSON: enough to read `artifacts/manifest.json` and write metric
//! logs (`results/*.json`). serde is unavailable offline; this parser
//! supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (not needed by our files).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or("bad \\u escape")?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or("bad hex")?;
                        }
                        out.push(char::from_u32(code).ok_or("bad codepoint")?);
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(b) => {
                    // Collect the raw UTF-8 byte; str::from_utf8 below is
                    // avoided by pushing bytes through a small buffer.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        // multi-byte sequence: find its length
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or("truncated utf8")?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            // reject rather than last-wins: a duplicated key in a
            // checkpoint or job spec is corruption or tampering, and
            // silently dropping one value would mask it
            if out.insert(key.clone(), val).is_some() {
                return Err(format!("duplicate key {key:?} in object"));
            }
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"version": 1, "artifacts": [{"program": "products",
            "file": "products_m64_k8.hlo.txt", "dims": {"m": 64, "k": 8},
            "inputs": [[64,64],[64,8]], "outputs": [[64,8],[8,8]],
            "dtype": "f32"}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_f64(), Some(1.0));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("program").unwrap().as_str(), Some("products"));
        assert_eq!(
            arts[0].get("dims").unwrap().get("m").unwrap().as_usize(),
            Some(64)
        );
        // serialize → parse again
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn parses_escapes_and_numbers() {
        let v = Json::parse(r#"["a\nb", -1.5e3, true, null, "A"]"#).unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_str(), Some("a\nb"));
        assert_eq!(a[1].as_f64(), Some(-1500.0));
        assert_eq!(a[2], Json::Bool(true));
        assert_eq!(a[3], Json::Null);
        assert_eq!(a[4].as_str(), Some("A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn rejects_duplicate_object_keys() {
        let e = Json::parse("{\"a\":1,\"a\":2}").expect_err("duplicate key");
        assert!(e.contains("duplicate key \"a\""), "{e}");
        // nested objects are checked too
        assert!(Json::parse("{\"o\":{\"x\":1,\"x\":1}}").is_err());
        // distinct keys still parse
        let j = Json::parse("{\"a\":1,\"b\":2}").unwrap();
        assert_eq!(j.get("b").and_then(Json::as_usize), Some(2));
    }
}
