"""L1 Pallas kernels: tiled dense matmul and Gram product.

These are the compute hot spots of every SymNMF iteration (paper §4.1.1):
the products X·F (m×m · m×k) and FᵀF (k×k) dominate the per-iteration cost
of ANLS/HALS/PGNCG and of the RRF power iterations.

TPU-style structure (DESIGN.md §Hardware-Adaptation):
  * the (M, K) output is produced one (bm, K) VMEM block at a time,
  * the contraction dimension is streamed HBM→VMEM in bk-sized slabs via
    BlockSpec index maps (the grid's minor-most axis), and
  * partial sums accumulate in the output block across grid steps — the
    classic "revisiting output tile" Pallas accumulation pattern that maps
    onto the MXU systolic array when compiled for real TPU.

On this image the kernels MUST run with interpret=True: CPU PJRT cannot
execute Mosaic custom-calls.  interpret=True lowers the same schedule to
plain HLO (while-loops + dynamic slices), which the rust PJRT client runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ≤ cap (tile sizes must divide the
    dimension exactly; no padding logic is needed for our shape set)."""
    if n <= cap:
        return n
    for t in range(cap, 0, -1):
        if n % t == 0:
            return t
    return 1


def _matmul_kernel(x_ref, f_ref, o_ref):
    """One grid step: o[i, :] += x[i, s] @ f[s, :].

    Grid is (M/bm, N/bn, K/bk) with the contraction axis minor-most, so the
    output block is revisited K/bk times; zero it on the first visit.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], f_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jax.Array, f: jax.Array, *, bm: int = 0, bn: int = 0, bk: int = 0):
    """Tiled Pallas matmul ``x @ f`` with x: (M, K), f: (K, N).

    Tile sizes default to the largest divisors ≤ (64, 128, 64) — multiples
    of the (8, 128) TPU register tile whenever the shape allows it.
    """
    m, kc = x.shape
    kc2, n = f.shape
    assert kc == kc2, f"contraction mismatch {x.shape} @ {f.shape}"
    bm = bm or _tile(m, 64)
    bn = bn or _tile(n, 128)
    bk = bk or _tile(kc, 64)
    grid = (m // bm, n // bn, kc // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, f)


def _gram_kernel(f_ref, o_ref):
    """One grid step: o += f[s, :]ᵀ @ f[s, :] (SYRK-style accumulation)."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = f_ref[...]
    o_ref[...] += jnp.dot(blk.T, blk, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm",))
def gram(f: jax.Array, *, bm: int = 0):
    """Pallas Gram product ``fᵀ @ f`` with f: (M, K) → (K, K).

    The M axis is streamed through VMEM in bm-row slabs; the (K, K) output
    block lives in VMEM for the whole pass (K ≤ 128 in all our workloads).
    """
    m, k = f.shape
    bm = bm or _tile(m, 128)
    return pl.pallas_call(
        _gram_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda s: (s, 0))],
        out_specs=pl.BlockSpec((k, k), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, k), f.dtype),
        interpret=True,
    )(f)
