//! Regenerates paper **Figure 2** (§5.2): normalized residual and
//! projected gradient vs time on the sparse OAG-substitute graph, for
//! HALS/BPP × {plain, LvS τ=1, LvS τ=1/s, LAI}.
//!
//! Paper setup: 37.7M vertices / 966M nnz. Testbed scaling: 20,000
//! vertices (DESIGN.md §3). Shape to reproduce: hybrid (τ=1/s) clearly
//! faster per unit residual than pure random (τ=1) which gives no
//! speedup; LvS-HALS ≫ LvS-BPP gains (solve-bound); LAI-BPP struggles to
//! reduce the residual on this input (§5.2 ¶1).
//!
//!     cargo bench --bench bench_fig2
//! writes results/fig2_convergence.csv

use symnmf::coordinator::driver::run_trials;
use symnmf::coordinator::experiments::{fig2_methods, oag_options, oag_workload};
use symnmf::coordinator::report;

fn main() {
    let m = std::env::var("SYMNMF_BENCH_M")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    println!("== Fig. 2 bench: OAG sparse workload (m={m}) ==");
    let g = oag_workload(m, 7);
    println!(
        "graph: {} vertices, {} nnz, k=16, s=⌈0.05m⌉={}",
        g.adj.rows(),
        g.adj.nnz(),
        ((m as f64) * 0.05).ceil() as usize
    );
    let mut opts = oag_options().with_seed(20);
    opts.max_iters = 40;
    opts.patience = 1000; // plot the full horizon (paper's Figs. show complete curves)

    let mut all = Vec::new();
    for method in fig2_methods() {
        let stats = run_trials(method, &g.adj, &opts, Some(&g.labels), 1);
        let run = &stats.trials[0];
        println!(
            "  {:<22} {:>3} iters  {:>8.3}s  min-res {:.5}  final-pg {:.3}",
            stats.label,
            stats.mean_iters,
            stats.mean_time,
            stats.min_res,
            run.records.last().and_then(|r| r.proj_grad).unwrap_or(f64::NAN),
        );
        all.push(stats);
    }

    std::fs::create_dir_all("results").ok();
    report::write_convergence_csv(std::path::Path::new("results/fig2_convergence.csv"), &all)
        .unwrap();

    // headline shape check: per-iteration time of hybrid vs exact
    let find = |label: &str| all.iter().find(|s| s.label.contains(label));
    if let (Some(hals), Some(hyb)) = (find("HALS"), find("LvS-HALS (τ=1/s)")) {
        let t_exact = hals.mean_time / hals.mean_iters;
        let t_hyb = hyb.mean_time / hyb.mean_iters;
        println!(
            "\nper-iteration speedup LvS-HALS(τ=1/s) vs HALS: {:.2}x (paper: ≈5.5x)",
            t_exact / t_hyb
        );
    }
    println!("wrote results/fig2_convergence.csv");
}
