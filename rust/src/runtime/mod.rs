//! PJRT runtime: load the AOT-compiled HLO artifacts (lowered from the
//! JAX/Pallas model by `python/compile/aot.py`) and execute them from the
//! rust hot path.
//!
//! * [`registry`] — parses `artifacts/manifest.json` into shape-keyed
//!   artifact specs.
//! * [`backend`] — the `xla`-crate facade (functional `Literal`
//!   container; client construction gated so zero-dependency builds fall
//!   back to native kernels).
//! * [`pjrt`] — CPU PJRT client wrapper: HLO-text → compile → execute,
//!   f64⇄f32 conversion at the boundary, lazy executable cache, reusable
//!   host staging buffers.
//! * [`exec`] — typed entry points: [`exec::PjrtSymOp`] is a [`SymOp`]
//!   whose X·F runs the Pallas matmul kernel through PJRT when an
//!   artifact matches the shape, with transparent native fallback.
//!
//! Python never runs here — artifacts are plain HLO text files.

pub mod backend;
pub mod exec;
pub mod pjrt;
pub mod registry;

pub use exec::PjrtSymOp;
pub use pjrt::PjrtRuntime;
