//! `symnmf` CLI — run SymNMF methods on generated workloads or
//! MatrixMarket graphs, serve job fleets through the slice scheduler,
//! inspect artifacts, and print runtime diagnostics.
//!
//! Examples:
//!   symnmf run --workload wos --docs 800 --method lai-hals --trials 3
//!   symnmf run --workload oag --m 5000 --method lvs-hals --tau 0.001
//!   symnmf run --input graph.mtx --k 8 --method bpp
//!   symnmf serve --jobs jobs.jsonl --store ckpts --slice-steps 2
//!   symnmf artifacts            # list loaded AOT artifacts
//!   symnmf info                 # platform / runtime diagnostics

use std::collections::BTreeMap;
use symnmf::coordinator::driver::{run_trials, Method};
use symnmf::coordinator::{experiments, report};
use symnmf::linalg::SymPacked;
use symnmf::nls::UpdateRule;
use symnmf::runtime::registry::Registry;
use symnmf::runtime::PjrtRuntime;
use symnmf::serve::recovery::{self, RecoveryReport, RecoveryScan};
use symnmf::serve::{
    sanitize_id, CachedOperator, JobHandle, JobSpec, JobStore, OpCache, OpCacheConfig, OpKey,
    Scheduler, SchedulerConfig,
};
use symnmf::symnmf::options::{SymNmfOptions, Tau};
use symnmf::symnmf::trace::{num_or_null, TraceFormat};
use symnmf::util::cli::Args;
use symnmf::util::json::Json;
use symnmf::util::table::Table;

fn parse_method(s: &str, tau: Tau) -> Option<Method> {
    let s = s.to_ascii_lowercase();
    let rule = UpdateRule::parse;
    Some(match s.as_str() {
        "bpp" | "hals" | "mu" => Method::Exact(rule(&s)?),
        "pgncg" => Method::Pgncg,
        "lai-pgncg" => Method::LaiPgncg { refine: false },
        "lai-pgncg-ir" => Method::LaiPgncg { refine: true },
        _ => {
            if let Some(rest) = s.strip_prefix("lai-") {
                let (r, refine) = match rest.strip_suffix("-ir") {
                    Some(r) => (r, true),
                    None => (rest, false),
                };
                Method::Lai { rule: rule(r)?, refine }
            } else if let Some(r) = s.strip_prefix("comp-") {
                Method::Comp(rule(r)?)
            } else if let Some(r) = s.strip_prefix("lvs-") {
                Method::Lvs { rule: rule(r)?, tau }
            } else {
                return None;
            }
        }
    })
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let tau = match args.get("tau") {
        Some(t) => Tau::Fixed(t.parse().map_err(|e| format!("bad --tau: {e}"))?),
        None => Tau::OneOverS,
    };
    let method = parse_method(args.get_str("method", "bpp"), tau)
        .ok_or_else(|| format!("unknown method {:?}", args.get_str("method", "")))?;
    let trials = args.get_usize("trials", 1);
    let seed = args.get_usize("seed", 0) as u64;

    if let Some(path) = args.get("input") {
        // user-supplied MatrixMarket graph
        let mut adj =
            symnmf::sparse::io::read_matrix_market(std::path::Path::new(path))?;
        symnmf::sparse::sym::prepare_adjacency(&mut adj);
        let k = args.get_usize("k", 8);
        let mut opts = SymNmfOptions::new(k).with_seed(seed);
        opts.max_iters = args.get_usize("max-iters", 300);
        let stats = run_trials(method, &adj, &opts, None, trials);
        println!("{}", report::stats_table(&[stats]));
        return Ok(());
    }
    match args.get_str("workload", "wos") {
        "wos" => {
            let docs = args.get_usize("docs", 800);
            let w = experiments::wos_workload(docs, seed);
            let mut opts = experiments::wos_options().with_seed(seed);
            opts.max_iters = args.get_usize("max-iters", 300);
            println!(
                "WoS workload: {} docs, dense {}x{} adjacency, 7 topics",
                docs,
                w.adjacency.rows(),
                w.adjacency.cols()
            );
            let stats =
                run_trials(method, &w.adjacency, &opts, Some(&w.labels), trials);
            println!("{}", report::stats_table(&[stats]));
        }
        "oag" => {
            let m = args.get_usize("m", 5000);
            let g = experiments::oag_workload(m, seed);
            let mut opts = experiments::oag_options().with_seed(seed);
            opts.max_iters = args.get_usize("max-iters", 100);
            println!(
                "OAG workload: sparse {}x{} adjacency, {} nnz, k=16",
                g.adj.rows(),
                g.adj.cols(),
                g.adj.nnz()
            );
            let stats = run_trials(method, &g.adj, &opts, Some(&g.labels), trials);
            println!("{}", report::stats_table(&[stats]));
        }
        other => return Err(format!("unknown workload {other:?} (wos|oag)")),
    }
    Ok(())
}

fn spec_str<'a>(j: &'a Json, key: &str, default: &'a str) -> &'a str {
    j.get(key).and_then(Json::as_str).unwrap_or(default)
}

fn spec_usize(j: &Json, key: &str) -> Option<usize> {
    j.get(key).and_then(Json::as_usize)
}

/// Workload cache key: one operator per (workload, size, data seed,
/// storage form). Storage is part of the key because packed and CSR
/// operators of the same graph are different cache entries with
/// different eviction behavior (spill vs drop+rebuild).
fn workload_key(j: &Json) -> Result<String, String> {
    let workload = spec_str(j, "workload", "wos");
    let data_seed = spec_usize(j, "data_seed").unwrap_or(1);
    match workload {
        "wos" => Ok(format!("wos:{}:{data_seed}", spec_usize(j, "docs").unwrap_or(200))),
        "oag" => {
            let storage = spec_str(j, "storage", "csr");
            if storage != "csr" && storage != "packed" {
                return Err(format!("unknown storage {storage:?} (csr|packed)"));
            }
            Ok(format!(
                "oag:{}:{data_seed}:{storage}",
                spec_usize(j, "m").unwrap_or(300)
            ))
        }
        other => Err(format!("unknown workload {other:?} (wos|oag)")),
    }
}

/// Build the operator a job line names, in its cacheable storage form:
/// the WoS dense adjacency is staged as [`SymPacked`] (upper-triangle
/// block panels — half the resident footprint, spillable under budget
/// pressure); the OAG sparse adjacency stays CSR unless the line opts
/// into `"storage": "packed"`. Deterministic per workload key, so an
/// evicted-and-dropped entry rebuilds to the same content hash.
fn build_cached_operator(j: &Json) -> CachedOperator {
    let data_seed = spec_usize(j, "data_seed").unwrap_or(1) as u64;
    match spec_str(j, "workload", "wos") {
        "wos" => {
            let docs = spec_usize(j, "docs").unwrap_or(200);
            CachedOperator::Packed(SymPacked::from_dense(
                &experiments::wos_workload(docs, data_seed).adjacency,
            ))
        }
        _ => {
            let m = spec_usize(j, "m").unwrap_or(300);
            let adj = experiments::oag_workload(m, data_seed).adj;
            if spec_str(j, "storage", "csr") == "packed" {
                CachedOperator::Packed(SymPacked::from_csr(&adj))
            } else {
                CachedOperator::Csr(adj)
            }
        }
    }
}

/// Build one job spec from a JSONL line of the `serve --jobs` file.
/// `recovery` (the `--recover` pre-pass) wins over `resume`: it already
/// walked the generations, quarantined corrupt ones, and holds the
/// newest valid checkpoint per job.
fn job_from_spec(
    j: &Json,
    store: Option<&JobStore>,
    resume: bool,
    recovery: Option<&RecoveryScan>,
) -> Result<JobSpec, String> {
    let id = j
        .get("id")
        .and_then(Json::as_str)
        .ok_or_else(|| "job line needs a string \"id\"".to_string())?
        .to_string();
    let tau = match j.get("tau").and_then(Json::as_f64) {
        Some(t) => Tau::Fixed(t),
        None => Tau::OneOverS,
    };
    let method_name = spec_str(j, "method", "bpp");
    let method = parse_method(method_name, tau)
        .ok_or_else(|| format!("job {id:?}: unknown method {method_name:?}"))?;
    let mut opts = match (spec_usize(j, "k"), spec_str(j, "workload", "wos")) {
        (Some(k), _) => SymNmfOptions::new(k),
        (None, "wos") => experiments::wos_options(),
        (None, _) => experiments::oag_options(),
    };
    opts.seed = spec_usize(j, "seed").unwrap_or(0) as u64;
    if let Some(n) = spec_usize(j, "max_iters") {
        opts.max_iters = n;
    }
    if let Some(s) = spec_usize(j, "samples") {
        opts.samples = Some(s);
    }
    let mut spec = JobSpec::new(id.clone(), method, opts);
    if let Some(p) = j.get("priority").and_then(Json::as_f64) {
        spec.priority = p as i64;
    }
    if let Some(ms) = j.get("deadline_ms").and_then(Json::as_f64) {
        spec.deadline_secs = Some(ms / 1000.0);
    }
    spec.max_steps = spec_usize(j, "max_steps");
    spec.cancel_after_iters = spec_usize(j, "cancel_after");
    if let Some(path) = j.get("trace").and_then(Json::as_str) {
        let format = TraceFormat::parse(spec_str(j, "trace_format", "jsonl"))?;
        spec.trace = Some((std::path::PathBuf::from(path), format));
    }
    if let Some(scan) = recovery {
        match scan.checkpoint_for(&id) {
            Some((gen, cp)) => {
                println!(
                    "  {id}: recovered from persisted generation {gen} (iter {})",
                    cp.iter
                );
                spec.resume = Some(cp.clone());
            }
            None => println!("  {id}: no valid persisted generation; restarting cold"),
        }
    } else if resume {
        if let Some(store) = store {
            if let Some((gen, cp)) = store.load_latest(&id)? {
                println!("  {id}: resuming from stored generation {gen} (iter {})", cp.iter);
                spec.resume = Some(cp);
            }
        }
    }
    Ok(spec)
}

fn job_report_row(h: &JobHandle) -> (Vec<String>, Json) {
    let o = h.outcome().expect("drained job has an outcome");
    // result/checkpoint are None only for a job whose first slice
    // panicked (status "failed"): the report degrades to placeholders
    // instead of refusing to describe the rest of the fleet
    let label = o
        .result
        .as_ref()
        .map(|r| r.label.clone())
        .unwrap_or_else(|| "-".to_string());
    let final_res = o.result.as_ref().map(|r| r.final_residual()).unwrap_or(f64::NAN);
    let min_res = o.result.as_ref().map(|r| r.min_residual()).unwrap_or(f64::NAN);
    let iters = o.checkpoint.as_ref().map(|c| c.iter).unwrap_or(0);
    let clock = o.checkpoint.as_ref().map(|c| c.clock).unwrap_or(0.0);
    let row = vec![
        h.name().to_string(),
        label.clone(),
        o.status.as_str().to_string(),
        o.slices.to_string(),
        o.spilled_slices.to_string(),
        iters.to_string(),
        format!("{final_res:.6}"),
        format!("{clock:.3}s"),
        if o.persist_degraded { "degraded" } else { "ok" }.to_string(),
    ];
    let json = Json::obj(vec![
        ("id", Json::Str(h.name().to_string())),
        ("label", Json::Str(label)),
        ("status", Json::Str(o.status.as_str().to_string())),
        (
            "run_status",
            match o.run_status {
                Some(rs) => Json::Str(rs.as_str().to_string()),
                None => Json::Null,
            },
        ),
        ("slices", Json::Num(o.slices as f64)),
        ("spilled_slices", Json::Num(o.spilled_slices as f64)),
        ("steps", Json::Num(o.steps as f64)),
        ("iters", Json::Num(iters as f64)),
        // num_or_null: a zero-record job reports NaN/inf residuals, and
        // the in-repo JSON printer would emit them as bare invalid
        // tokens; the hex field stays bitwise-exact either way
        ("final_residual", num_or_null(final_res)),
        (
            "final_residual_hex",
            Json::Str(format!("{:016x}", final_res.to_bits())),
        ),
        ("min_residual", num_or_null(min_res)),
        ("clock_secs", Json::Num(clock)),
        ("persist_degraded", Json::Bool(o.persist_degraded)),
        (
            "failure",
            match &o.failure {
                Some(f) => Json::Str(f.clone()),
                None => Json::Null,
            },
        ),
    ]);
    (row, json)
}

/// `symnmf serve`: submit jobs from a JSONL spec, drain them through the
/// slice scheduler, optionally resume cancelled jobs, report per job.
fn cmd_serve(args: &Args) -> Result<(), String> {
    let jobs_path = args
        .get("jobs")
        .ok_or_else(|| "serve requires --jobs <spec.jsonl>".to_string())?;
    let text = std::fs::read_to_string(jobs_path)
        .map_err(|e| format!("read {jobs_path:?}: {e}"))?;
    let mut lines = Vec::new();
    for (no, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("{jobs_path}:{}: {e}", no + 1))?;
        lines.push(j);
    }
    if lines.is_empty() {
        return Err(format!("{jobs_path}: no job lines"));
    }

    let store = match args.get("store") {
        Some(dir) => {
            let keep = args.get_usize("keep", 1);
            Some(JobStore::open(std::path::Path::new(dir))?.with_keep(keep))
        }
        None => None,
    };
    let resume = args.has_flag("resume");
    if resume && store.is_none() {
        return Err("--resume needs --store".to_string());
    }
    let recover = args.has_flag("recover");
    if recover && store.is_none() {
        return Err("--recover needs --store".to_string());
    }
    if recover && resume {
        return Err(
            "--recover and --resume are mutually exclusive (--recover already \
             resumes from the newest valid generation, after quarantining \
             corrupt ones)"
                .to_string(),
        );
    }
    // --recover pre-pass: scan the whole store BEFORE submitting — walk
    // every persisted job's generations newest→oldest, quarantine
    // unparseable files as *.corrupt (renamed, never deleted), and keep
    // the newest valid checkpoint per job for resubmission below
    let scan = match (&store, recover) {
        (Some(s), true) => {
            println!("recovering from store {:?}...", s.dir());
            Some(recovery::scan(s)?)
        }
        _ => None,
    };

    // the cross-request operator cache: every distinct workload is
    // built exactly once (the pre-pass pin below is its one miss); under
    // a resident-bytes budget (--x-budget-mb / SYMNMF_X_BUDGET_MB),
    // least-recently-used idle operators spill to disk (packed) or drop
    // (CSR) and fault back on the next pin
    let spill_dir = match args.get("spill-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir().join(format!("symnmf-spill-{}", std::process::id())),
    };
    let mut cache_cfg = OpCacheConfig::new(spill_dir).budget_from_env();
    if let Some(mb) = args.get("x-budget-mb") {
        let mb: f64 = mb
            .parse()
            .map_err(|e| format!("--x-budget-mb expects a number, got {mb:?}: {e}"))?;
        cache_cfg = cache_cfg.with_budget_mb(mb);
    }
    let cache = std::sync::Arc::new(OpCache::new(cache_cfg));
    let mut keys: BTreeMap<String, OpKey> = BTreeMap::new();
    for j in &lines {
        let wkey = workload_key(j)?;
        if !keys.contains_key(&wkey) {
            println!("building workload {wkey}...");
            let op = build_cached_operator(j);
            let opkey = op.key();
            drop(cache.pin_or_build(&opkey, move || op));
            keys.insert(wkey, opkey);
        }
    }

    let cfg = SchedulerConfig {
        workers: args.get("workers").map(|w| {
            w.parse().unwrap_or_else(|_| panic!("--workers expects an integer, got {w:?}"))
        }),
        slice_steps: args.get("slice-steps").map(|s| {
            s.parse()
                .unwrap_or_else(|_| panic!("--slice-steps expects an integer, got {s:?}"))
        }),
        slice_secs: args.get("slice-ms").map(|s| {
            s.parse::<f64>()
                .unwrap_or_else(|_| panic!("--slice-ms expects a number, got {s:?}"))
                / 1000.0
        }),
        store: store.clone(),
        slim_checkpoints: args.has_flag("slim"),
    };
    let mut sched = Scheduler::new(cfg);
    let mut handles: Vec<JobHandle> = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    let mut recovery_report = RecoveryReport::default();
    for j in &lines {
        let spec = job_from_spec(j, store.as_ref(), resume, scan.as_ref())?;
        if let Some(scan) = &scan {
            if scan.checkpoint_for(&spec.name).is_some() {
                recovery_report.jobs_recovered += 1;
            } else {
                recovery_report.jobs_cold += 1;
            }
        }
        // uniqueness is checked on the SANITIZED id — the store keys
        // checkpoint files by it, so "a.b" and "a b" must not be allowed
        // to share (and GC) one checkpoint lineage
        if !seen.insert(sanitize_id(&spec.name)) {
            return Err(format!(
                "duplicate job id {:?} (ids collide after sanitization)",
                spec.name
            ));
        }
        let wkey = workload_key(j)?;
        let opkey = keys.get(&wkey).expect("workload keyed above").clone();
        // the builder regenerates the operator from the job line if the
        // cache dropped it under budget pressure (CSR eviction)
        let line = j.clone();
        let h = sched.submit_cached(&cache, opkey, move || build_cached_operator(&line), spec)?;
        handles.push(h);
    }

    println!("draining {} jobs...", handles.len());
    sched.drain();
    if args.has_flag("resume-cancelled") {
        let cancelled: Vec<&JobHandle> = handles
            .iter()
            .filter(|h| h.poll() == symnmf::serve::JobStatus::Cancelled)
            .collect();
        if !cancelled.is_empty() {
            println!("resuming {} cancelled job(s)...", cancelled.len());
            for h in cancelled {
                sched.resume(h)?;
            }
            sched.drain();
        }
    }

    let mut table = Table::new(&[
        "Job", "Alg.", "Status", "Slices", "Spilled", "Iters", "Final-Res", "Clock", "Persist",
    ]);
    let mut reports = Vec::new();
    for h in &handles {
        let (row, json) = job_report_row(h);
        table.row(&row);
        reports.push(json);
    }
    println!("{}", table.render());
    if let Some(scan) = &scan {
        recovery_report.files_quarantined = scan.files_quarantined();
        println!("{}", recovery_report.render());
    }
    let s = cache.stats();
    println!(
        "opcache: {} hits ({} from spill), {} misses, {} evictions, {} spill writes, {} resident bytes",
        s.hits, s.spilled_hits, s.misses, s.evictions, s.spill_writes, s.resident_bytes
    );
    if let Some(path) = args.get("report") {
        let doc = Json::obj(vec![
            // version 4: adds the "pool" dispatch-provenance object
            // (backend + width; informational only — backend choice
            // cannot change bits, so nothing validates it on resume).
            // version 3 added the "recovery" object (null outside
            // --recover) and per-job "persist_degraded" / "failure".
            ("version", Json::Num(4.0)),
            (
                "pool",
                Json::obj(vec![
                    (
                        "backend",
                        Json::Str(symnmf::util::pool::active_backend().as_str().to_string()),
                    ),
                    ("width", Json::Num(symnmf::util::pool::pool_width() as f64)),
                ]),
            ),
            (
                "recovery",
                match &scan {
                    Some(_) => recovery_report.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "opcache",
                Json::obj(vec![
                    (
                        "budget_bytes",
                        match s.budget_bytes {
                            Some(b) => Json::Num(b as f64),
                            None => Json::Null,
                        },
                    ),
                    ("resident_bytes", Json::Num(s.resident_bytes as f64)),
                    ("entries", Json::Num(s.entries as f64)),
                    ("hits", Json::Num(s.hits as f64)),
                    ("spilled_hits", Json::Num(s.spilled_hits as f64)),
                    ("misses", Json::Num(s.misses as f64)),
                    ("evictions", Json::Num(s.evictions as f64)),
                    ("spill_writes", Json::Num(s.spill_writes as f64)),
                ]),
            ),
            ("jobs", Json::Arr(reports)),
        ]);
        std::fs::write(path, format!("{doc}\n"))
            .map_err(|e| format!("write {path:?}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_artifacts() -> Result<(), String> {
    let dir = Registry::default_dir();
    let reg = Registry::load(&dir)?;
    if reg.specs.is_empty() {
        println!("no artifacts found in {dir:?} — run `make artifacts`");
        return Ok(());
    }
    println!("{} artifacts in {dir:?}:", reg.specs.len());
    for s in &reg.specs {
        println!(
            "  {:<14} dims={:?} inputs={:?} outputs={:?}",
            s.program, s.dims, s.inputs, s.outputs
        );
    }
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    match PjrtRuntime::from_default_dir() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts loaded: {}", rt.registry.specs.len());
        }
        Err(e) => println!("PJRT unavailable ({e:#}); native kernels only"),
    }
    println!("threads: {}", symnmf::util::threadpool::num_threads());
    println!("kernel isa: {}", symnmf::linalg::simd::active().as_str());
    Ok(())
}

/// `symnmf --features`: the kernel-dispatch diagnostics — detected vs
/// forced vs active ISA, plus the tier each dispatched routine runs on
/// under the active choice (see `linalg::blas`'s dispatch-tier docs).
fn cmd_features() -> Result<(), String> {
    use symnmf::linalg::simd;
    let active = simd::active();
    let supported: Vec<&str> = simd::supported().iter().map(|i| i.as_str()).collect();
    println!("host:            {}", simd::hostname());
    println!("detected isa:    {}", simd::detect().as_str());
    println!("supported tiers: {}", supported.join(", "));
    match std::env::var("SYMNMF_KERNEL") {
        Ok(v) if !v.trim().is_empty() => println!("SYMNMF_KERNEL:   {v} (forced)"),
        _ => println!("SYMNMF_KERNEL:   (unset: auto-detect)"),
    }
    println!("active kernel:   {}", active.as_str());
    println!(
        "precision:       {} (SYMNMF_PRECISION, sketched GEMMs only)",
        symnmf::linalg::Precision::from_env().as_str()
    );
    match std::env::var("SYMNMF_POOL") {
        Ok(v) if !v.trim().is_empty() => println!("SYMNMF_POOL:     {v} (forced)"),
        _ => println!("SYMNMF_POOL:     (unset: pooled)"),
    }
    println!(
        "pool backend:    {} (cannot change bits; scoped = per-call spawn oracle)",
        symnmf::util::pool::active_backend().as_str()
    );
    println!(
        "pool width:      {} (1 submitter + {} persistent symnmf-pool-N workers)",
        symnmf::util::pool::pool_width(),
        symnmf::util::pool::pool_width().saturating_sub(1)
    );
    println!();
    // dot/axpy are the bitwise tier: under AVX-512 they still run the
    // 256-bit lane-grouped bodies so every tier reproduces scalar bits
    let bitwise = match active {
        symnmf::linalg::KernelIsa::Avx512 => "avx2 (lane-grouped)",
        other => other.as_str(),
    };
    let isa = active.as_str();
    let mut table = Table::new(&["Routine", "Tier", "Kernel"]);
    table.row_strs(&["matmul_nt packed microkernel", "fma (1e-12 vs scalar)", isa]);
    table.row_strs(&["symm blocked tile product", "fma (1e-12 vs scalar)", isa]);
    table.row_strs(&["gram_into", "fma (1e-12 vs scalar)", isa]);
    table.row_strs(&["hals_sweep row update", "fma (1e-12 vs scalar)", isa]);
    table.row_strs(&["dot / axpy", "bitwise (= scalar)", bitwise]);
    table.row_strs(&["f32 widening gemms", "bitwise (= scalar)", isa]);
    println!("{}", table.render());
    Ok(())
}

fn usage() -> &'static str {
    "symnmf — randomized symmetric NMF (Hayashi et al. 2024 reproduction)

USAGE:
  symnmf run [--workload wos|oag] [--method M] [--trials N] [--seed S]
             [--docs N | --m N] [--tau T] [--max-iters N]
             [--input graph.mtx --k K]
  symnmf serve --jobs spec.jsonl [--store DIR] [--keep N] [--workers N]
               [--slice-steps N] [--slice-ms MS] [--report out.json]
               [--x-budget-mb MB] [--spill-dir DIR]
               [--slim] [--resume] [--recover] [--resume-cancelled]
  symnmf artifacts      list AOT artifacts
  symnmf info           runtime diagnostics
  symnmf --features     kernel dispatch diagnostics (detected/forced ISA,
                        per-routine tier; SYMNMF_KERNEL + SYMNMF_PRECISION
                        + SYMNMF_POOL backend and pool width)

PARALLEL DISPATCH:
  SYMNMF_POOL=pooled (default) runs every parallel kernel on persistent
  symnmf-pool-N workers spawned once per process; =scoped reverts to a
  fresh std::thread::scope per call (the pinning oracle). The backend
  can never change results — chunk geometry and accumulator-slot counts
  derive from the logical width (SYMNMF_THREADS) before the executor is
  chosen — so it is not recorded in checkpoints and resume never
  validates it. Serve workers (symnmf-serve-N) submit kernels to the
  pool under their per-slice thread budget, keeping pool + serve demand
  at about the machine width.

SERVE JOB SPEC (one JSON object per line; # comments allowed):
  {\"id\": \"j1\", \"workload\": \"oag\", \"m\": 300, \"data_seed\": 7,
   \"method\": \"hals\", \"seed\": 3, \"max_iters\": 20, \"priority\": 1,
   \"deadline_ms\": 10000, \"cancel_after\": 4, \"storage\": \"packed\",
   \"trace\": \"results/j1.jsonl\", \"trace_format\": \"jsonl\"}

SERVE OPERATOR CACHE:
  Each distinct (workload, size, data_seed, storage) is built once and
  shared by every job that names it, under a resident-bytes ceiling set
  by --x-budget-mb (or SYMNMF_X_BUDGET_MB; the flag wins; unset = no
  ceiling). Over budget, the least-recently-used idle operator is
  evicted: packed storage spills to a checksummed panel file under
  --spill-dir (default: a per-process temp dir) and streams back on
  demand with bitwise-identical results; CSR storage is dropped and
  rebuilt on next use. \"storage\": \"packed\" opts an oag graph into
  packed (spillable) form; wos graphs are always packed.

SERVE CRASH SAFETY:
  --recover (needs --store; excludes --resume) restarts a fleet after a
  crash: the store is scanned before submission, each job's checkpoint
  generations are walked newest to oldest, unparseable files are
  QUARANTINED by renaming to <file>.corrupt (never deleted), and each
  job resubmits from its newest valid generation — or cold if none
  parses. Recovered runs are bitwise-identical to uninterrupted ones.
  Transient checkpoint-save failures are retried a bounded number of
  times (deterministic, clockless backoff); a save that exhausts the
  budget degrades persistence — the solve continues in memory and the
  job reports persist_degraded — instead of failing. A job whose engine
  panics is isolated: it lands in status \"failed\" (panic message in the
  report's \"failure\" field) while every other job finishes unaffected.

FAIL POINTS (testing):
  SYMNMF_FAILPOINTS=site=action[,site=action...] injects deterministic
  faults; action = err | panic | exit, optionally _once (first hit) or
  @N (Nth hit, 1-based). Sites: ckpt_save, spill_open, spill_read,
  spill_write, opcache_build, slice — each also matches a per-key
  variant like slice:<job id>. exit aborts the process with code 86
  (crash simulation for --recover tests). Unset = zero overhead.

METHODS:
  bpp hals mu pgncg lai-<rule>[-ir] comp-<rule> lvs-<rule> lai-pgncg[-ir]
"
}

fn main() {
    let args = Args::from_env();
    if args.has_flag("features") {
        if let Err(e) = cmd_features() {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
        return;
    }
    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("artifacts") => cmd_artifacts(),
        Some("info") => cmd_info(),
        _ => {
            println!("{}", usage());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
