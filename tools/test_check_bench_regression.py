#!/usr/bin/env python3
"""Fixture tests for the bench regression gate (tools/check_bench_regression.py).

Exercises the gate against synthetic BENCH_kernels.json pairs: a genuine
same-provenance regression must fail, a cross-ISA/hostname pair must be
skipped with a loud warning (exit 0), and pre-provenance files (no
isa/hostname header) must keep gating exactly as before.

    python3 tools/test_check_bench_regression.py
"""

import io
import json
import os
import sys
import tempfile
import unittest
from contextlib import redirect_stderr, redirect_stdout

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import check_bench_regression as gate  # noqa: E402


def bench_doc(rows, isa=None, hostname=None):
    doc = {"version": 1, "bench": "kernels", "kernels": rows}
    if isa is not None:
        doc["isa"] = isa
    if hostname is not None:
        doc["hostname"] = hostname
    return doc


def row(op, gflops, secs=0.01, shape="2048x32"):
    return {"op": op, "shape": shape, "secs_per_iter": secs, "gflops": gflops}


class GateFixtureTest(unittest.TestCase):
    def setUp(self):
        self.tmp = tempfile.TemporaryDirectory()
        self.addCleanup(self.tmp.cleanup)

    def write(self, name, doc):
        path = os.path.join(self.tmp.name, name)
        with open(path, "w") as fh:
            json.dump(doc, fh)
        return path

    def run_gate(self, base_doc, cur_doc):
        base = self.write("base.json", base_doc)
        cur = self.write("cur.json", cur_doc)
        out, err = io.StringIO(), io.StringIO()
        with redirect_stdout(out), redirect_stderr(err):
            code = gate.main(["--baseline", base, "--current", cur])
        return code, out.getvalue(), err.getvalue()

    def test_same_isa_regression_fails(self):
        base = bench_doc([row("matmul_nt_simd", 20.0)], isa="avx2", hostname="ci-1")
        cur = bench_doc([row("matmul_nt_simd", 10.0)], isa="avx2", hostname="ci-1")
        code, _, err = self.run_gate(base, cur)
        self.assertEqual(code, 1, "a 50% drop under identical provenance must fail")
        self.assertIn("regressed", err)

    def test_same_isa_within_tolerance_passes(self):
        base = bench_doc([row("matmul_nt_simd", 20.0)], isa="avx2", hostname="ci-1")
        cur = bench_doc([row("matmul_nt_simd", 19.5)], isa="avx2", hostname="ci-1")
        code, out, _ = self.run_gate(base, cur)
        self.assertEqual(code, 0)
        self.assertIn("OK", out)

    def test_different_isa_skips_with_warning(self):
        base = bench_doc([row("matmul_nt_simd", 20.0)], isa="avx512", hostname="ci-1")
        cur = bench_doc([row("matmul_nt_simd", 5.0)], isa="scalar", hostname="ci-1")
        code, _, err = self.run_gate(base, cur)
        self.assertEqual(code, 0, "cross-ISA pairs are noise, not regressions")
        self.assertIn("WARNING", err)
        self.assertIn("isa", err)
        self.assertIn("not comparable", err)

    def test_different_hostname_skips_with_warning(self):
        base = bench_doc([row("gram_into", 30.0)], isa="avx2", hostname="box-a")
        cur = bench_doc([row("gram_into", 3.0)], isa="avx2", hostname="box-b")
        code, _, err = self.run_gate(base, cur)
        self.assertEqual(code, 0)
        self.assertIn("WARNING", err)
        self.assertIn("hostname", err)

    def test_missing_header_gates_normally(self):
        # pre-provenance baseline (no isa/hostname): the gate must still
        # catch regressions rather than treat the absence as a mismatch
        base = bench_doc([row("matmul_nt_packed", 20.0)])
        cur = bench_doc([row("matmul_nt_packed", 10.0)], isa="avx2", hostname="ci-1")
        code, _, err = self.run_gate(base, cur)
        self.assertEqual(code, 1, "null provenance on one side still gates")
        self.assertIn("regressed", err)

    def test_bootstrap_placeholder_passes_but_warns_loudly(self):
        base = bench_doc([], isa=None, hostname=None)
        cur = bench_doc([row("matmul_nt_simd", 20.0)], isa="avx2", hostname="ci-1")
        code, _, err = self.run_gate(base, cur)
        self.assertEqual(code, 0)
        self.assertIn("WARNING", err, "the fallback must shout, not pass quietly")
        self.assertIn("NOTHING WAS GATED", err)

    def test_bootstrap_header_flag_warns_even_with_rows(self):
        # a placeholder that somehow carries rows is still a placeholder:
        # the explicit header flag wins
        base = bench_doc([row("matmul_nt_simd", 20.0)], isa="avx2", hostname="ci-1")
        base["bootstrap"] = True
        cur = bench_doc([row("matmul_nt_simd", 10.0)], isa="avx2", hostname="ci-1")
        code, _, err = self.run_gate(base, cur)
        self.assertEqual(code, 0, "a flagged placeholder never hard-fails")
        self.assertIn("NOTHING WAS GATED", err)

    def test_io_bound_spill_and_hit_rows_are_noisy_not_gated(self):
        base = bench_doc(
            [
                row("symm_spilled_apply_into", 8.0),
                row("opcache_hit", 0.0, secs=1e-7),
                row("opcache_miss_build", 0.0, secs=0.02),
            ],
            isa="avx2",
            hostname="ci-1",
        )
        cur = bench_doc(
            [
                row("symm_spilled_apply_into", 1.0),  # page-cache luck, not a bug
                row("opcache_hit", 0.0, secs=1e-5),
                row("opcache_miss_build", 0.0, secs=0.02),
            ],
            isa="avx2",
            hostname="ci-1",
        )
        code, out, _ = self.run_gate(base, cur)
        self.assertEqual(code, 0, "I/O-bound rows must not hard-gate")
        self.assertIn("skip (noisy)", out)

    def test_opcache_miss_build_stays_time_gated(self):
        base = bench_doc(
            [row("opcache_miss_build", 0.0, secs=0.02)], isa="avx2", hostname="ci-1"
        )
        cur = bench_doc(
            [row("opcache_miss_build", 0.0, secs=0.05)], isa="avx2", hostname="ci-1"
        )
        code, _, err = self.run_gate(base, cur)
        self.assertEqual(code, 1, "the miss row pays a real build and stays gated")
        self.assertIn("regressed", err)

    def test_missing_gated_row_fails(self):
        base = bench_doc(
            [row("matmul_nt_simd", 20.0), row("gram_into", 30.0, shape="100000x16")],
            isa="avx2",
            hostname="ci-1",
        )
        cur = bench_doc([row("matmul_nt_simd", 20.0)], isa="avx2", hostname="ci-1")
        code, _, err = self.run_gate(base, cur)
        self.assertEqual(code, 1, "a vanished gated row must fail")
        self.assertIn("missing from the", err)


if __name__ == "__main__":
    unittest.main()
