//! Cholesky factorization and triangular solves.
//!
//! Used by CholeskyQR (paper Alg. LvS-SymNMF lines 4–5 and 11–12): the
//! Gram matrix FᵀF is factored as RᵀR, then Q = F·R⁻¹ is obtained by a
//! right triangular solve applied row-by-row.

use crate::linalg::DenseMat;

/// Upper-triangular Cholesky factor R of a symmetric positive-definite
/// matrix A = RᵀR. Returns Err if a pivot is not positive (A not SPD).
pub fn cholesky_upper(a: &DenseMat) -> Result<DenseMat, String> {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols());
    let mut r = DenseMat::zeros(n, n);
    cholesky_upper_into(a, &mut r)?;
    Ok(r)
}

/// [`cholesky_upper`] into a pre-allocated n×n output (fully
/// overwritten, lower triangle zeroed) — the allocation-free form the
/// per-iteration leverage-score path uses. Same loop, same arithmetic:
/// bitwise-identical to the allocating form.
pub fn cholesky_upper_into(a: &DenseMat, r: &mut DenseMat) -> Result<(), String> {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols());
    assert_eq!(r.shape(), (n, n), "cholesky_upper_into shape");
    r.data_mut().fill(0.0);
    for i in 0..n {
        for j in i..n {
            let mut sum = a.at(i, j);
            for k in 0..i {
                sum -= r.at(k, i) * r.at(k, j);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(format!(
                        "cholesky: non-positive pivot {sum:.3e} at {i}"
                    ));
                }
                r.set(i, j, sum.sqrt());
            } else {
                r.set(i, j, sum / r.at(i, i));
            }
        }
    }
    Ok(())
}

/// Cholesky with diagonal jitter retry: A + εI for growing ε. Returns the
/// factor and the jitter actually used. LvS-SymNMF calls this on HᵀH
/// which can be numerically semidefinite early in the iteration.
pub fn cholesky_upper_jittered(a: &DenseMat) -> (DenseMat, f64) {
    let mut r = DenseMat::zeros(a.rows(), a.cols());
    let mut scratch = DenseMat::zeros(a.rows(), a.cols());
    let eps = cholesky_upper_jittered_into(a, &mut scratch, &mut r);
    (r, eps)
}

/// [`cholesky_upper_jittered`] into pre-allocated n×n buffers: `scratch`
/// holds the jittered copy A + εI on retries, `r` receives the factor.
/// Identical attempt sequence and arithmetic to the allocating form.
pub fn cholesky_upper_jittered_into(
    a: &DenseMat,
    scratch: &mut DenseMat,
    r: &mut DenseMat,
) -> f64 {
    if cholesky_upper_into(a, r).is_ok() {
        return 0.0;
    }
    assert_eq!(scratch.shape(), a.shape(), "cholesky jitter scratch shape");
    let scale = (0..a.rows()).map(|i| a.at(i, i)).fold(0.0f64, f64::max).max(1e-300);
    let mut eps = scale * 1e-14;
    loop {
        scratch.data_mut().copy_from_slice(a.data());
        for i in 0..a.rows() {
            *scratch.at_mut(i, i) += eps;
        }
        if cholesky_upper_into(scratch, r).is_ok() {
            return eps;
        }
        eps *= 10.0;
        assert!(eps.is_finite(), "cholesky jitter diverged");
    }
}

/// Solve Q·R = F for Q given upper-triangular R, i.e. each row q of Q
/// satisfies qᵀR = fᵀ → forward substitution over columns.
pub fn solve_right_upper(f: &DenseMat, r: &DenseMat) -> DenseMat {
    let (m, k) = f.shape();
    assert_eq!(r.shape(), (k, k));
    let mut q = f.clone();
    for i in 0..m {
        let row = q.row_mut(i);
        for j in 0..k {
            let mut v = row[j];
            for t in 0..j {
                v -= row[t] * r.at(t, j);
            }
            row[j] = v / r.at(j, j);
        }
    }
    q
}

/// Solve Rᵀ·y = b (forward substitution), single RHS.
pub fn solve_lower_t(r: &DenseMat, b: &[f64]) -> Vec<f64> {
    let n = r.rows();
    assert_eq!(b.len(), n);
    let mut y = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            y[i] -= r.at(k, i) * y[k];
        }
        y[i] /= r.at(i, i);
    }
    y
}

/// Solve R·x = y (back substitution), single RHS.
pub fn solve_upper(r: &DenseMat, y: &[f64]) -> Vec<f64> {
    let n = r.rows();
    assert_eq!(y.len(), n);
    let mut x = y.to_vec();
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            x[i] -= r.at(i, k) * x[k];
        }
        x[i] /= r.at(i, i);
    }
    x
}

/// Solve the SPD system A·x = b via Cholesky (A = RᵀR → Rᵀy = b, Rx = y).
pub fn spd_solve(a: &DenseMat, b: &[f64]) -> Result<Vec<f64>, String> {
    let r = cholesky_upper(a)?;
    Ok(solve_upper(&r, &solve_lower_t(&r, b)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::propcheck::{dim, forall};
    use crate::util::rng::Pcg64;

    fn random_spd(n: usize, rng: &mut Pcg64) -> DenseMat {
        let f = DenseMat::gaussian(n + 4, n, rng);
        let mut g = blas::gram(&f);
        for i in 0..n {
            *g.at_mut(i, i) += 0.1;
        }
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        forall(
            20,
            300,
            |rng| random_spd(dim(rng, 1, 20), rng),
            |a| {
                let r = cholesky_upper(a).map_err(|e| e)?;
                let rtr = blas::matmul_tn(&r, &r);
                let err = rtr.diff_fro(a) / a.fro_norm();
                if err < 1e-12 {
                    Ok(())
                } else {
                    Err(format!("rel err {err}"))
                }
            },
        );
    }

    #[test]
    fn rejects_indefinite() {
        let a = DenseMat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(cholesky_upper(&a).is_err());
        let (r, eps) = cholesky_upper_jittered(&a);
        assert!(eps > 0.0);
        assert_eq!(r.shape(), (2, 2));
    }

    /// The into-forms reproduce the allocating forms bitwise, including
    /// the jitter-retry path on an indefinite input and stale-output
    /// overwrite.
    #[test]
    fn into_forms_match_allocating_bitwise() {
        let mut rng = Pcg64::seed_from_u64(21);
        let a = random_spd(7, &mut rng);
        let r_alloc = cholesky_upper(&a).unwrap();
        let mut r_into = DenseMat::gaussian(7, 7, &mut rng); // stale garbage
        cholesky_upper_into(&a, &mut r_into).unwrap();
        for (x, y) in r_alloc.data().iter().zip(r_into.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let indef = DenseMat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        let (rj, eps) = cholesky_upper_jittered(&indef);
        let mut scratch = DenseMat::zeros(2, 2);
        let mut rj_into = DenseMat::zeros(2, 2);
        let eps_into = cholesky_upper_jittered_into(&indef, &mut scratch, &mut rj_into);
        assert_eq!(eps.to_bits(), eps_into.to_bits());
        for (x, y) in rj.data().iter().zip(rj_into.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn spd_solve_matches() {
        let mut rng = Pcg64::seed_from_u64(8);
        let a = random_spd(6, &mut rng);
        let x_true: Vec<f64> = rng.gaussian_vec(6);
        let b: Vec<f64> = (0..6)
            .map(|i| (0..6).map(|j| a.at(i, j) * x_true[j]).sum())
            .collect();
        let x = spd_solve(&a, &b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-9, "{x:?} vs {x_true:?}");
        }
    }

    #[test]
    fn right_solve_gives_orthonormal_q() {
        let mut rng = Pcg64::seed_from_u64(9);
        let f = DenseMat::gaussian(50, 7, &mut rng);
        let g = blas::gram(&f);
        let r = cholesky_upper(&g).unwrap();
        let q = solve_right_upper(&f, &r);
        let qtq = blas::gram(&q);
        assert!(qtq.diff_fro(&DenseMat::eye(7)) < 1e-10);
    }
}
