//! Symmetric graph-matrix preprocessing, following the paper's §5.2
//! methodology (from Kuang, Yun & Park [35]): symmetric normalization
//! D^{-1/2}·A·D^{-1/2} of an adjacency matrix and diagonal removal.

use crate::sparse::CsrMat;

/// Symmetrically normalize an adjacency matrix in place:
/// A ← D^{-1/2}·A·D^{-1/2} with D = diag(row sums). Isolated vertices
/// (zero degree) are left untouched.
pub fn normalize_sym(a: &mut CsrMat) {
    let deg = a.row_sums();
    let dinv: Vec<f64> = deg
        .iter()
        .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
        .collect();
    a.scale_sym(&dinv);
}

/// The full §5.2 pipeline: symmetric normalization then zeroed diagonal.
pub fn prepare_adjacency(a: &mut CsrMat) {
    a.zero_diagonal();
    normalize_sym(a);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_row_sums_bounded() {
        let mut a = CsrMat::from_coo(
            3,
            3,
            vec![
                (0, 1, 2.0),
                (1, 0, 2.0),
                (1, 2, 1.0),
                (2, 1, 1.0),
                (0, 0, 4.0),
            ],
        );
        prepare_adjacency(&mut a);
        assert_eq!(a.get(0, 0), 0.0, "diagonal removed");
        assert!(a.is_symmetric(1e-12));
        // normalized value: 2 / sqrt(2·3)
        let want = 2.0 / (2.0f64 * 3.0).sqrt();
        assert!((a.get(0, 1) - want).abs() < 1e-12);
    }

    #[test]
    fn isolated_vertex_no_nan() {
        let mut a = CsrMat::from_coo(3, 3, vec![(0, 1, 1.0), (1, 0, 1.0)]);
        prepare_adjacency(&mut a);
        assert!(a.row_sums().iter().all(|x| x.is_finite()));
    }
}
