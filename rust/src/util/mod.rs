//! Framework substrates built in-repo (crates.io is unreachable in this
//! environment; see DESIGN.md §2 "Offline-dependency substitutions"):
//! a PCG64 PRNG, a persistent worker pool (with a scoped-spawn oracle)
//! behind the data-parallel helpers, a tiny CLI parser, a minimal JSON
//! reader/writer, ASCII table rendering, timers, and a property-testing
//! harness used by the test suite.

pub mod bench;
pub mod cli;
pub mod error;
pub mod failpoint;
pub mod json;
pub mod pool;
pub mod propcheck;
pub mod retry;
pub mod rng;
pub mod table;
pub mod threadpool;
pub mod timer;
