"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes (including tile-unfriendly odd/prime sizes, which
exercise the divisor-based tile picker) and both float dtypes the kernels
support. These tests are the CORE correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as K
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=40)


def rand(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, f = rand(rng, m, k), rand(rng, k, n)
    got = K.matmul(x, f)
    want = ref.matmul(x, f)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, seed=st.integers(0, 2**31 - 1))
def test_gram_matches_ref(m, k, seed):
    rng = np.random.default_rng(seed)
    f = rand(rng, m, k)
    got = K.gram(f)
    want = ref.gram(f)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_matmul_dtypes(dtype):
    if dtype == jnp.float64 and not jax.config.read("jax_enable_x64"):
        pytest.skip("x64 disabled")
    rng = np.random.default_rng(0)
    x, f = rand(rng, 16, 12, dtype=dtype), rand(rng, 12, 5, dtype=dtype)
    np.testing.assert_allclose(K.matmul(x, f), ref.matmul(x, f),
                               rtol=1e-5, atol=1e-5)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(1)
    f = rand(rng, 33, 7)
    g = np.asarray(K.gram(f))
    np.testing.assert_allclose(g, g.T, atol=1e-6)
    eigs = np.linalg.eigvalsh(g)
    assert (eigs > -1e-4).all()


def test_matmul_explicit_tiles():
    """Explicit tile sizes must not change the result (different grid)."""
    rng = np.random.default_rng(2)
    x, f = rand(rng, 64, 64), rand(rng, 64, 8)
    base = np.asarray(K.matmul(x, f))
    for bm, bk in [(8, 8), (16, 64), (64, 16), (32, 32)]:
        got = np.asarray(K.matmul(x, f, bm=bm, bk=bk))
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_tile_picker():
    assert K._tile(64, 64) == 64
    assert K._tile(1024, 64) == 64
    assert K._tile(7, 64) == 7
    assert K._tile(97, 64) == 1          # prime > cap
    assert K._tile(96, 64) == 48
