//! LvS sampled-apply parity acceptance: the parallel, ISA-dispatched
//! sampled product X·SᵀS·F must be **bitwise identical** to the retained
//! serial scalar oracle on every backend (dense, CSR, packed, spilled),
//! for every `simd::supported()` ISA, under both `SYMNMF_POOL` dispatch
//! backends — the gather-over-chunks reformulation (see `randnla::op`)
//! preserves the serial per-element accumulation order by construction,
//! so any bit of divergence is a kernel bug, not an FP tolerance
//! question. Plus the end-to-end contract: LvS checkpoints resume
//! bitwise and the sampler's RNG draw sequence is unchanged by the
//! workspace-threaded sampling pipeline.

use std::path::PathBuf;

use symnmf::linalg::{blas, simd, DenseMat, IterWorkspace, SymPacked, SymPackedSpilled};
use symnmf::nls::UpdateRule;
use symnmf::randnla::op::{sampled_apply_dense_isa, sampled_apply_dense_serial};
use symnmf::sparse::CsrMat;
use symnmf::symnmf::engine::{Checkpoint, RunControl, RunStatus};
use symnmf::symnmf::lvs::{lvs_symnmf_run, lvs_symnmf_ws};
use symnmf::symnmf::metrics::SymNmfResult;
use symnmf::symnmf::options::{SymNmfOptions, Tau};
use symnmf::util::pool::{self, PoolBackend};
use symnmf::util::rng::Pcg64;

/// The shape sweep from the issue: covers the degenerate (1), the
/// sub-microkernel (3, 7), and both sides of every tile boundary
/// (31/33 around 32, 65 past 64 — and past the SPMM column panel).
const SIZES: [usize; 6] = [1, 3, 7, 31, 33, 65];

/// Run `f` once under each dispatch backend and return both results.
fn both<T>(f: impl Fn() -> T) -> (T, T) {
    let pooled = {
        let _g = pool::override_backend(PoolBackend::Pooled);
        f()
    };
    let scoped = {
        let _g = pool::override_backend(PoolBackend::Scoped);
        f()
    };
    (pooled, scoped)
}

fn assert_mats_bitwise(a: &DenseMat, b: &DenseMat, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}");
    }
}

/// Dense symmetric test matrix with exact zeros sprinkled in, so the
/// `xv != 0.0` skip branch of the kernels is exercised.
fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
    let mut rng = Pcg64::seed_from_u64(seed);
    let h = DenseMat::uniform(m, k, 1.0, &mut rng);
    let mut x = blas::matmul_nt(&h, &h);
    x.symmetrize();
    for i in 0..m {
        for j in i..m {
            if rng.uniform() < 0.2 {
                x.set(i, j, 0.0);
                x.set(j, i, 0.0);
            }
        }
    }
    x
}

/// Sparse symmetric matrix (~30% fill) mirroring the dense generator.
fn planted_csr(m: usize, seed: u64) -> CsrMat {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut trips = Vec::new();
    for i in 0..m {
        for j in i..m {
            let v = rng.uniform();
            if v < 0.3 {
                trips.push((i, j, v));
                if i != j {
                    trips.push((j, i, v));
                }
            }
        }
    }
    CsrMat::from_coo(m, m, trips)
}

/// A sample list with repeats (the hybrid sampler draws with
/// replacement) and non-uniform positive weights.
fn sample_list(m: usize, s: usize, rng: &mut Pcg64) -> (Vec<usize>, Vec<f64>) {
    let indices: Vec<usize> = (0..s).map(|_| rng.below(m)).collect();
    let weights: Vec<f64> = (0..s).map(|_| 0.25 + rng.uniform()).collect();
    (indices, weights)
}

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let d = std::env::temp_dir()
            .join(format!("symnmf-lvs-parity-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        TempDir(d)
    }
    fn file(&self, name: &str) -> PathBuf {
        self.0.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[test]
fn dense_sampled_apply_parallel_matches_serial_per_isa() {
    for isa in simd::supported() {
        for m in SIZES {
            for k in SIZES {
                let mut rng = Pcg64::seed_from_u64(0xD5A + (m * 67 + k) as u64);
                let x = planted(m, k.min(m), 0xD0 + (m * 67 + k) as u64);
                let f = DenseMat::gaussian(m, k, &mut rng);
                let s = m.div_ceil(2) + 1;
                let (idx, w) = sample_list(m, s, &mut rng);
                let mut want = DenseMat::zeros(m, k);
                want.fill(f64::NAN); // oracle must fully overwrite
                sampled_apply_dense_serial(&x, &f, &idx, &w, &mut want);
                let (p, sc) = both(|| {
                    let mut out = DenseMat::zeros(m, k);
                    out.fill(f64::NAN);
                    sampled_apply_dense_isa(isa, &x, &f, &idx, &w, &mut out);
                    out
                });
                assert_mats_bitwise(&p, &want, &format!("dense pooled {isa:?} m={m} k={k}"));
                assert_mats_bitwise(&sc, &want, &format!("dense scoped {isa:?} m={m} k={k}"));
            }
        }
    }
}

#[test]
fn csr_sampled_apply_parallel_matches_serial_per_isa() {
    for isa in simd::supported() {
        for m in SIZES {
            for k in SIZES {
                let mut rng = Pcg64::seed_from_u64(0xC5A + (m * 67 + k) as u64);
                let x = planted_csr(m, 0xC0 + (m * 67 + k) as u64);
                let f = DenseMat::gaussian(m, k, &mut rng);
                let s = m.div_ceil(2) + 1;
                let (idx, w) = sample_list(m, s, &mut rng);
                let mut want = DenseMat::zeros(m, k);
                want.fill(f64::NAN);
                x.sampled_spmm_sym_into_serial(&f, &idx, &w, &mut want);
                let (p, sc) = both(|| {
                    let mut out = DenseMat::zeros(m, k);
                    out.fill(f64::NAN);
                    x.sampled_spmm_sym_into_isa(isa, &f, &idx, &w, &mut out);
                    out
                });
                assert_mats_bitwise(&p, &want, &format!("csr pooled {isa:?} m={m} k={k}"));
                assert_mats_bitwise(&sc, &want, &format!("csr scoped {isa:?} m={m} k={k}"));
            }
        }
    }
}

/// Block size 8 on the SIZES sweep exercises single-tile, edge-tile and
/// multi-block-row layouts, including mirrored (jb < ib) reads.
#[test]
fn packed_sampled_apply_parallel_matches_serial_per_isa() {
    for isa in simd::supported() {
        for m in SIZES {
            for k in SIZES {
                let mut rng = Pcg64::seed_from_u64(0xBA + (m * 67 + k) as u64);
                let x = planted(m, k.min(m), 0xB0 + (m * 67 + k) as u64);
                let sp = SymPacked::from_dense_with_block(&x, 8);
                let f = DenseMat::gaussian(m, k, &mut rng);
                let s = m.div_ceil(2) + 1;
                let (idx, w) = sample_list(m, s, &mut rng);
                let mut want = DenseMat::zeros(m, k);
                want.fill(f64::NAN);
                sp.sampled_apply_into_serial(&f, &idx, &w, &mut want);
                let (p, sc) = both(|| {
                    let mut out = DenseMat::zeros(m, k);
                    out.fill(f64::NAN);
                    sp.sampled_apply_into_isa(isa, &f, &idx, &w, &mut out);
                    out
                });
                assert_mats_bitwise(&p, &want, &format!("packed pooled {isa:?} m={m} k={k}"));
                assert_mats_bitwise(&sc, &want, &format!("packed scoped {isa:?} m={m} k={k}"));
            }
        }
    }
}

/// The out-of-core tier faults tiles through the Mutex ring from inside
/// concurrent chunks; one spilled operator per k at the largest shape
/// keeps the I/O bounded.
#[test]
fn spilled_sampled_apply_parallel_matches_serial_per_isa() {
    let dir = TempDir::new("sampled");
    let m = 65;
    for k in [1usize, 7, 33] {
        let x = planted(m, k, 0x5B11 + k as u64);
        let sp = SymPacked::from_dense_with_block(&x, 8);
        let path = dir.file(&format!("x-{k}.spill"));
        symnmf::linalg::spill::write_spill(&sp, &path).expect("write spill");
        let spilled = SymPackedSpilled::open(&path).expect("open spill");
        let mut rng = Pcg64::seed_from_u64(0x5B12 + k as u64);
        let f = DenseMat::gaussian(m, k, &mut rng);
        let (idx, w) = sample_list(m, 40, &mut rng);
        let mut want = DenseMat::zeros(m, k);
        want.fill(f64::NAN);
        spilled.sampled_apply_into_serial(&f, &idx, &w, &mut want);
        for isa in simd::supported() {
            let (p, sc) = both(|| {
                let mut out = DenseMat::zeros(m, k);
                out.fill(f64::NAN);
                spilled.sampled_apply_into_isa(isa, &f, &idx, &w, &mut out);
                out
            });
            assert_mats_bitwise(&p, &want, &format!("spilled pooled {isa:?} k={k}"));
            assert_mats_bitwise(&sc, &want, &format!("spilled scoped {isa:?} k={k}"));
        }
    }
}

fn assert_runs_bitwise(a: &SymNmfResult, b: &SymNmfResult, what: &str) {
    assert_eq!(a.iters(), b.iters(), "{what}: iteration count");
    assert_mats_bitwise(&a.h, &b.h, &format!("{what}: H"));
    assert_mats_bitwise(&a.w, &b.w, &format!("{what}: W"));
    for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        assert_eq!(
            ra.residual.to_bits(),
            rb.residual.to_bits(),
            "{what}: residual at iter {i}"
        );
        assert_eq!(ra.hybrid_stats, rb.hybrid_stats, "{what}: hybrid stats at iter {i}");
    }
}

/// End-to-end contract of the allocation-free sampling pipeline: the
/// engine run equals the frozen allocating reference loop bitwise (the
/// RNG draw sequence is unchanged — same leverage scores, same alias
/// draws), and an interrupted run resumes from its checkpoint onto the
/// identical trajectory AND the identical final RNG state, on both
/// dispatch backends.
#[test]
fn lvs_end_to_end_checkpoint_resume_and_rng_stream_unchanged() {
    let x = planted_csr(90, 0xE2E);
    let mut opts = SymNmfOptions::new(3).with_rule(UpdateRule::Hals).with_seed(41);
    opts.max_iters = 6;
    opts.samples = Some(45);
    opts.tau = Tau::OneOverS;

    // Engine ≡ frozen reference loop (allocating sampler): pins the
    // workspace sampler's draw stream to the legacy one.
    let s = opts.effective_samples(90);
    let mut ws = IterWorkspace::with_samples(90, 3, s);
    let oracle = lvs_symnmf_ws(&x, &opts, &mut ws);
    let full = lvs_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
    assert_runs_bitwise(&oracle, &full.result, "engine vs reference");

    let (full_p, full_s) = both(|| {
        lvs_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None)
    });
    assert_runs_bitwise(&full_p.result, &full_s.result, "full pooled vs scoped");

    // Interrupt after 2 steps, serialize, resume: bitwise trajectory and
    // identical final sampler RNG state — the stream a pre-existing
    // checkpoint replays is exactly the stream the new pipeline draws.
    let paused =
        lvs_symnmf_run(&x, &opts, &RunControl::unlimited().with_max_steps(2), None, None);
    assert_eq!(paused.checkpoint.status, RunStatus::Paused);
    let cp = Checkpoint::parse(&paused.checkpoint.serialize()).expect("roundtrip");
    let (res_p, res_s) =
        both(|| lvs_symnmf_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None));
    assert_runs_bitwise(&full.result, &res_p.result, "resume pooled");
    assert_runs_bitwise(&full.result, &res_s.result, "resume scoped");
    assert_eq!(
        full.checkpoint.state.rng, res_p.checkpoint.state.rng,
        "resumed run must end on the identical sampler RNG state"
    );
    assert_eq!(full.checkpoint.state.rng, res_s.checkpoint.state.rng);
}
