//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `forall(cases, gen, prop)` runs `prop` on `cases` generated inputs from
//! a seeded PRNG; on failure it reports the case index and seed so the
//! failure replays deterministically. Generators for the shapes/values the
//! linalg and coordinator invariants need are provided.

use crate::util::rng::Pcg64;

/// Run `prop` on `cases` inputs from `gen`. Panics with the replay seed on
/// the first failing case.
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    base_seed: u64,
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg64::seed_from_u64(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {case} (replay seed {seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Random dimension in [lo, hi].
pub fn dim(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Vec of standard normals.
pub fn gaussian_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    rng.gaussian_vec(n)
}

/// Vec of nonnegative values (|N(0,1)|).
pub fn nonneg_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.gaussian().abs()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(
            50,
            1,
            |rng| dim(rng, 1, 10),
            |&n| {
                if n >= 1 && n <= 10 {
                    Ok(())
                } else {
                    Err(format!("out of range: {n}"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        forall(50, 2, |rng| dim(rng, 1, 10), |&n| {
            if n < 10 {
                Ok(())
            } else {
                Err("hit 10".into())
            }
        });
    }
}
