//! Clustering evaluation stack for the paper's §5 experiments: hard
//! assignments from the H factor, Adjusted Rand Index (WoS, Table 2),
//! similarity-based silhouette scores (OAG, §5.2.1), k-means and the
//! spectral-clustering comparison baseline (§5.1.1).

pub mod ari;
pub mod assign;
pub mod kmeans;
pub mod silhouette;
pub mod spectral;
