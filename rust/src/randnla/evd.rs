//! Approximate truncated eigenvalue decomposition of a symmetric matrix
//! (paper Alg. Apx-EVD): X ≈ U·Λ·Uᵀ with U = Q·Q_T from an RRF basis Q
//! and the small projected eigenproblem T = QᵀXQ = Q_T·Λ·Q_Tᵀ.

use crate::linalg::{blas, eig, DenseMat};
use crate::randnla::op::SymOp;
use crate::randnla::rrf::{ada_rrf, rrf, RrfResult};
use crate::util::rng::Pcg64;

/// X ≈ U·diag(lambda)·Uᵀ.
pub struct ApxEvd {
    /// m×l orthonormal-column factor U.
    pub u: DenseMat,
    /// l eigenvalue approximations, sorted by decreasing magnitude.
    pub lambda: Vec<f64>,
    /// how many times X was applied (RRF applications + 1 projection)
    pub applications: usize,
    /// Ada-RRF residual history when adaptive, else empty.
    pub residual_history: Vec<f64>,
}

impl ApxEvd {
    /// V = U·Λ, so X ≈ U·Vᵀ — the factored form LAI-SymNMF multiplies by.
    pub fn v(&self) -> DenseMat {
        let mut v = self.u.clone();
        for i in 0..v.rows() {
            let row = v.row_mut(i);
            for (j, val) in row.iter_mut().enumerate() {
                *val *= self.lambda[j];
            }
        }
        v
    }

    /// Dense reconstruction U·Λ·Uᵀ (tests / small problems).
    pub fn reconstruct(&self) -> DenseMat {
        blas::matmul_nt(&self.u, &self.v())
    }

    /// ‖UΛUᵀ‖²_F = Σ λ_i² (U has orthonormal columns).
    pub fn fro_norm_sq(&self) -> f64 {
        self.lambda.iter().map(|l| l * l).sum()
    }
}

fn project_and_eig<X: SymOp>(x: &X, basis: RrfResult) -> ApxEvd {
    let b = x.apply(&basis.q_basis); // X·Q, one more application
    let t = blas::matmul_tn(&basis.q_basis, &b); // l×l (symmetric up to fp)
    let (lambda, qt) = eig::symmetric_eig(&t);
    let u = blas::matmul(&basis.q_basis, &qt);
    ApxEvd {
        u,
        lambda,
        applications: basis.applications + 1,
        residual_history: basis.residual_history,
    }
}

/// Apx-EVD with a static power-iteration count q (paper Alg. Apx-EVD).
pub fn apx_evd<X: SymOp>(x: &X, l: usize, q: usize, rng: &mut Pcg64) -> ApxEvd {
    project_and_eig(x, rrf(x, l, q, rng))
}

/// Apx-EVD on top of Ada-RRF (the §3.3 "Adaptive RRF" practical
/// consideration; `tol` is the per-power-iteration residual-improvement
/// threshold, 1e-3 in the paper's WoS runs).
pub fn apx_evd_adaptive<X: SymOp>(
    x: &X,
    l: usize,
    q_max: usize,
    tol: f64,
    rng: &mut Pcg64,
) -> ApxEvd {
    project_and_eig(x, ada_rrf(x, l, q_max, tol, rng))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn low_rank_sym(m: usize, r: usize, noise: f64, rng: &mut Pcg64) -> DenseMat {
        let u = DenseMat::gaussian(m, r, rng);
        let mut x = blas::matmul_nt(&u, &u);
        if noise > 0.0 {
            let mut e = DenseMat::gaussian(m, m, rng);
            e.symmetrize();
            x.axpy(noise, &e);
        }
        x.symmetrize();
        x
    }

    #[test]
    fn recovers_exact_low_rank() {
        let mut rng = Pcg64::seed_from_u64(1);
        let x = low_rank_sym(60, 4, 0.0, &mut rng);
        let evd = apx_evd(&x, 8, 1, &mut rng);
        let rec = evd.reconstruct();
        let rel = x.diff_fro(&rec) / x.fro_norm();
        assert!(rel < 1e-8, "rel err {rel}");
        // only 4 nonzero eigenvalues
        assert!(evd.lambda[3].abs() > 1e-6);
        assert!(evd.lambda[4].abs() < 1e-6 * evd.lambda[0].abs());
    }

    #[test]
    fn u_has_orthonormal_columns() {
        let mut rng = Pcg64::seed_from_u64(2);
        let x = low_rank_sym(50, 5, 0.1, &mut rng);
        let evd = apx_evd(&x, 10, 2, &mut rng);
        let utu = blas::gram(&evd.u);
        assert!(utu.diff_fro(&DenseMat::eye(10)) < 1e-9);
    }

    #[test]
    fn factored_v_matches_reconstruction() {
        let mut rng = Pcg64::seed_from_u64(3);
        let x = low_rank_sym(40, 3, 0.05, &mut rng);
        let evd = apx_evd(&x, 8, 2, &mut rng);
        // U·Vᵀ applied to a block == reconstruct() applied to the block
        let f = DenseMat::gaussian(40, 6, &mut rng);
        let via_factored = blas::matmul(&evd.u, &blas::matmul_tn(&evd.v(), &f));
        let via_dense = blas::matmul(&evd.reconstruct(), &f);
        assert!(via_factored.diff_fro(&via_dense) < 1e-8);
    }

    #[test]
    fn fro_norm_identity() {
        let mut rng = Pcg64::seed_from_u64(4);
        let x = low_rank_sym(30, 3, 0.0, &mut rng);
        let evd = apx_evd(&x, 6, 1, &mut rng);
        assert!((evd.fro_norm_sq() - evd.reconstruct().fro_norm_sq()).abs() < 1e-6);
    }

    #[test]
    fn adaptive_close_to_truth_on_noisy_input() {
        let mut rng = Pcg64::seed_from_u64(5);
        let x = low_rank_sym(80, 5, 0.2, &mut rng);
        let evd = apx_evd_adaptive(&x, 12, 8, 1e-3, &mut rng);
        let rel = x.diff_fro(&evd.reconstruct()) / x.fro_norm();
        assert!(rel < 0.5, "rel {rel}");
        assert!(!evd.residual_history.is_empty());
    }
}
