//! Quickstart: factor a small symmetric similarity matrix with both a
//! deterministic baseline and the paper's LAI-SymNMF, and compare.
//!
//!     cargo run --release --example quickstart

use symnmf::linalg::{blas, DenseMat};
use symnmf::nls::UpdateRule;
use symnmf::symnmf::anls::symnmf_anls;
use symnmf::symnmf::lai::lai_symnmf;
use symnmf::symnmf::SymNmfOptions;
use symnmf::util::rng::Pcg64;

fn main() {
    // --- build a toy symmetric nonnegative matrix with rank-4 structure
    let (m, k) = (300, 4);
    let mut rng = Pcg64::seed_from_u64(42);
    let h_true = DenseMat::uniform(m, k, 1.0, &mut rng);
    let mut x = blas::matmul_nt(&h_true, &h_true);
    x.symmetrize();
    println!("input: {m}x{m} symmetric, planted rank {k}");

    // --- deterministic SymNMF (regularized ANLS with BPP, §2.1.1)
    let mut opts = SymNmfOptions::new(k).with_rule(UpdateRule::Bpp).with_seed(7);
    opts.max_iters = 100;
    let exact = symnmf_anls(&x, &opts);
    println!(
        "{:>12}: {:3} iters, {:.3}s, final residual {:.5}",
        exact.label,
        exact.iters(),
        exact.total_secs(),
        exact.final_residual()
    );

    // --- LAI-SymNMF (paper §3): Apx-EVD once, then cheap iterations
    let lai = lai_symnmf(&x, &opts);
    println!(
        "{:>12}: {:3} iters, {:.3}s ({:.3}s LAI setup), final residual {:.5}",
        lai.label,
        lai.iters(),
        lai.total_secs(),
        lai.setup_secs,
        lai.final_residual()
    );

    let speedup = exact.total_secs() / lai.total_secs().max(1e-9);
    println!("speedup: {speedup:.2}x at matched quality");
    assert!(lai.final_residual() < exact.final_residual() + 0.05);
}
