//! Symmetric eigensolver: cyclic Jacobi rotations.
//!
//! Apx-EVD (paper Alg. Apx-EVD line 5) needs the full EVD of the small
//! projected matrix T = QᵀXQ ∈ R^{l×l} with l = k + ρ ≤ ~130. Cyclic
//! Jacobi is O(l³) per sweep, converges in a handful of sweeps, is
//! unconditionally stable, and returns an orthogonal eigenvector matrix —
//! exactly what the randomized EVD needs.

use crate::linalg::DenseMat;

/// Eigen-decomposition A = V·diag(w)·Vᵀ of a symmetric matrix.
/// Eigenvalues are returned sorted by decreasing |w| (the order Apx-EVD
/// wants: leading eigenpairs first); columns of V are the matching
/// eigenvectors.
pub fn symmetric_eig(a: &DenseMat) -> (Vec<f64>, DenseMat) {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "symmetric_eig needs a square matrix");
    let mut m = a.clone();
    m.symmetrize();
    let mut v = DenseMat::eye(n);

    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m.at(p, q) * m.at(p, q);
            }
        }
        let scale = m.fro_norm_sq().max(1e-300);
        if off / scale < 1e-30 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at(p, q);
                if apq == 0.0 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                // Rutishauser-stable rotation
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rows/cols p and q of m
                for i in 0..n {
                    let mip = m.at(i, p);
                    let miq = m.at(i, q);
                    m.set(i, p, c * mip - s * miq);
                    m.set(i, q, s * mip + c * miq);
                }
                for j in 0..n {
                    let mpj = m.at(p, j);
                    let mqj = m.at(q, j);
                    m.set(p, j, c * mpj - s * mqj);
                    m.set(q, j, s * mpj + c * mqj);
                }
                for i in 0..n {
                    let vip = v.at(i, p);
                    let viq = v.at(i, q);
                    v.set(i, p, c * vip - s * viq);
                    v.set(i, q, s * vip + c * viq);
                }
            }
        }
    }

    let mut w: Vec<f64> = (0..n).map(|i| m.at(i, i)).collect();
    // sort by decreasing magnitude, permute eigenvector columns to match
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| w[b].abs().partial_cmp(&w[a].abs()).unwrap());
    let w_sorted: Vec<f64> = order.iter().map(|&i| w[i]).collect();
    let mut v_sorted = DenseMat::zeros(n, n);
    for (newj, &oldj) in order.iter().enumerate() {
        for i in 0..n {
            v_sorted.set(i, newj, v.at(i, oldj));
        }
    }
    w = w_sorted;
    (w, v_sorted)
}

/// Largest singular value (2-norm) of a small matrix, via the square root
/// of the largest eigenvalue of AᵀA. Used by tests and the Theorem 2.1
/// verification harness (σ_min / σ_max of the NLS coefficient matrix).
pub fn singular_values(a: &DenseMat) -> Vec<f64> {
    let g = crate::linalg::blas::gram(a);
    let (w, _) = symmetric_eig(&g);
    let mut sv: Vec<f64> = w.iter().map(|&x| x.max(0.0).sqrt()).collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::propcheck::{dim, forall};
    use crate::util::rng::Pcg64;

    #[test]
    fn reconstructs_symmetric_matrix() {
        forall(
            15,
            700,
            |rng| {
                let n = dim(rng, 1, 20);
                let mut a = DenseMat::gaussian(n, n, rng);
                a.symmetrize();
                a
            },
            |a| {
                let n = a.rows();
                let (w, v) = symmetric_eig(a);
                // A·V = V·diag(w)
                let av = blas::matmul(a, &v);
                let mut vd = v.clone();
                for j in 0..n {
                    for i in 0..n {
                        *vd.at_mut(i, j) *= w[j];
                    }
                }
                let err = av.diff_fro(&vd) / (1.0 + a.fro_norm());
                if err < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("AV−VΛ err {err:.2e}"))
                }
            },
        );
    }

    #[test]
    fn eigvecs_orthonormal() {
        let mut rng = Pcg64::seed_from_u64(3);
        let mut a = DenseMat::gaussian(15, 15, &mut rng);
        a.symmetrize();
        let (_w, v) = symmetric_eig(&a);
        let vtv = blas::gram(&v);
        assert!(vtv.diff_fro(&DenseMat::eye(15)) < 1e-10);
    }

    #[test]
    fn known_eigenvalues() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = DenseMat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (w, _) = symmetric_eig(&a);
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sorted_by_magnitude() {
        let mut rng = Pcg64::seed_from_u64(13);
        let mut a = DenseMat::gaussian(12, 12, &mut rng);
        a.symmetrize();
        let (w, _) = symmetric_eig(&a);
        for i in 1..w.len() {
            assert!(w[i - 1].abs() >= w[i].abs() - 1e-12);
        }
    }

    #[test]
    fn singular_values_of_orthonormal_are_ones() {
        let mut rng = Pcg64::seed_from_u64(17);
        let f = DenseMat::gaussian(30, 5, &mut rng);
        let (q, _) = crate::linalg::qr::householder_qr(&f);
        let sv = singular_values(&q);
        for s in sv {
            assert!((s - 1.0).abs() < 1e-8);
        }
    }
}
