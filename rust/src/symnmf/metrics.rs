//! Per-iteration metric records and the solver result type — the raw data
//! behind every convergence plot (Figs. 1, 2, 5) and summary table
//! (Tables 2, 4–6) of the paper.

use crate::linalg::DenseMat;
use crate::util::timer::PhaseTimer;

/// One row of a convergence log.
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    /// cumulative *algorithm* time in seconds at the end of this
    /// iteration. Metric evaluation (residual / projected gradient) is
    /// excluded so randomized methods are not billed for exact-metric
    /// computation they don't need (App. C discusses cheap estimates; we
    /// log exact values but keep them off the clock for all methods
    /// uniformly). Setup time (e.g. the LAI computation) IS included —
    /// that is why the randomized curves "start later" in Fig. 1.
    pub time_secs: f64,
    /// normalized residual ‖X − WHᵀ‖_F / ‖X‖_F (App. C.1)
    pub residual: f64,
    /// projected gradient norm (App. C.3), when computed
    pub proj_grad: Option<f64>,
    /// per-phase seconds of THIS iteration: (matmul, solve, sampling)
    pub phase_secs: (f64, f64, f64),
    /// LvS hybrid-sampling stats for Fig. 6: (deterministic fraction,
    /// θ/k leverage mass), averaged over the W and H samplers
    pub hybrid_stats: Option<(f64, f64)>,
}

/// Result of a SymNMF solve.
#[derive(Clone, Debug)]
pub struct SymNmfResult {
    /// display label, e.g. "LAI-HALS-IR" (§5.1 labeling scheme)
    pub label: String,
    /// final H factor (m×k)
    pub h: DenseMat,
    /// final W factor (≈ H at convergence of the regularized surrogate);
    /// equals `h` for methods that only maintain H (PGNCG)
    pub w: DenseMat,
    /// convergence log
    pub records: Vec<IterRecord>,
    /// aggregate per-phase timings
    pub phases: PhaseTimer,
    /// seconds spent before the first iteration (LAI / sketch setup)
    pub setup_secs: f64,
}

impl SymNmfResult {
    /// Total algorithm time (setup + all iterations).
    pub fn total_secs(&self) -> f64 {
        self.records.last().map(|r| r.time_secs).unwrap_or(self.setup_secs)
    }

    pub fn iters(&self) -> usize {
        self.records.len()
    }

    /// Lowest residual reached.
    pub fn min_residual(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.residual)
            .fold(f64::INFINITY, f64::min)
    }

    /// Final residual.
    pub fn final_residual(&self) -> f64 {
        self.records.last().map(|r| r.residual).unwrap_or(f64::NAN)
    }

    /// Hard clustering by row-wise argmax of H (§5, from [35]).
    pub fn cluster_assignments(&self) -> Vec<usize> {
        crate::clustering::assign::argmax_rows(&self.h)
    }
}

/// Tracks the §5.1 stopping rule: stop once the normalized residual fails
/// to drop by more than `tol` for `patience` consecutive iterations.
pub struct StopRule {
    tol: f64,
    patience: usize,
    best: f64,
    stall: usize,
}

impl StopRule {
    pub fn new(tol: f64, patience: usize) -> Self {
        StopRule { tol, patience, best: f64::INFINITY, stall: 0 }
    }

    /// Resumable internal state `(best, stall)` — serialized into solver
    /// checkpoints so a resumed run applies the identical stopping
    /// decisions the uninterrupted run would have.
    pub fn state(&self) -> (f64, usize) {
        (self.best, self.stall)
    }

    /// Rebuild a rule mid-run from its serialized `(best, stall)` state.
    pub fn from_state(tol: f64, patience: usize, best: f64, stall: usize) -> Self {
        StopRule { tol, patience, best, stall }
    }

    /// Feed the residual of the iteration that just finished; returns
    /// true when the algorithm should stop.
    pub fn update(&mut self, residual: f64) -> bool {
        if self.best - residual > self.tol {
            self.best = residual;
            self.stall = 0;
        } else {
            self.best = self.best.min(residual);
            self.stall += 1;
        }
        self.stall >= self.patience
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_rule_fires_after_patience_stalls() {
        let mut s = StopRule::new(1e-4, 4);
        assert!(!s.update(0.9));
        assert!(!s.update(0.8)); // improving
        assert!(!s.update(0.8)); // stall 1
        assert!(!s.update(0.79999)); // stall 2 (below tol improvement)
        assert!(!s.update(0.8)); // stall 3
        assert!(s.update(0.8)); // stall 4 → stop
    }

    #[test]
    fn stop_rule_resets_on_improvement() {
        let mut s = StopRule::new(1e-4, 2);
        assert!(!s.update(0.5));
        assert!(!s.update(0.5)); // stall 1
        assert!(!s.update(0.4)); // improves → reset
        assert!(!s.update(0.4)); // stall 1
        assert!(s.update(0.4)); // stall 2 → stop
    }
}
