//! Integration: the sparse §5.2 pipeline — SBM graph → LvS-SymNMF with
//! hybrid sampling → silhouettes, plus the Fig. 3 phase accounting.

use symnmf::clustering::silhouette::cluster_silhouettes;
use symnmf::coordinator::driver::{run_trials, Method};
use symnmf::coordinator::experiments::oag_workload;
use symnmf::nls::UpdateRule;
use symnmf::symnmf::options::{SymNmfOptions, Tau};
use symnmf::util::timer::{PHASE_MM, PHASE_SAMPLING, PHASE_SOLVE};

fn opts(k: usize, seed: u64) -> SymNmfOptions {
    let mut o = SymNmfOptions::new(k).with_seed(seed);
    o.max_iters = 30;
    o
}

#[test]
fn lvs_reduces_residual_and_finds_blocks() {
    let g = oag_workload(600, 1);
    let o = opts(16, 2);
    let stats = run_trials(
        Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS },
        &g.adj,
        &o,
        Some(&g.labels),
        1,
    );
    let run = &stats.trials[0];
    let first = run.records.first().unwrap().residual;
    assert!(stats.min_res < first, "residual must drop: {first} → {}", stats.min_res);
    // silhouettes of the found clusters
    let assign = run.cluster_assignments();
    let (scores, sizes) = cluster_silhouettes(&g.adj, &assign, 16);
    let occupied: Vec<f64> = scores
        .iter()
        .zip(&sizes)
        .filter(|(_, &s)| s >= 2)
        .map(|(&sc, _)| sc)
        .collect();
    assert!(!occupied.is_empty());
    let mean: f64 = occupied.iter().sum::<f64>() / occupied.len() as f64;
    assert!(mean > -0.5, "mean silhouette {mean}");
}

#[test]
fn phase_accounting_matches_fig3_structure() {
    let g = oag_workload(500, 3);
    let o = opts(16, 4);
    // exact HALS: no sampling phase
    let exact = Method::Exact(UpdateRule::Hals).run(&g.adj, &o);
    assert!(exact.phases.get_secs(PHASE_SAMPLING) == 0.0);
    assert!(exact.phases.get_secs(PHASE_MM) > 0.0);
    // LvS: all three phases populated
    let lvs = Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS }.run(&g.adj, &o);
    assert!(lvs.phases.get_secs(PHASE_SAMPLING) > 0.0);
    assert!(lvs.phases.get_secs(PHASE_MM) > 0.0);
    assert!(lvs.phases.get_secs(PHASE_SOLVE) > 0.0);
}

#[test]
fn hybrid_beats_pure_random_on_skewed_graph() {
    // §5.2 headline: τ=1/s (hybrid) reaches a given residual in less MM
    // work than τ=1 (pure random) at the same sample budget. On small
    // graphs timing is noisy, so compare residual after a fixed iteration
    // budget instead.
    let g = oag_workload(700, 5);
    let mut o = opts(16, 6);
    o.max_iters = 15;
    let hybrid = Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS }.run(&g.adj, &o);
    let random = Method::Lvs { rule: UpdateRule::Hals, tau: Tau::Fixed(1.0) }.run(&g.adj, &o);
    assert!(
        hybrid.min_residual() <= random.min_residual() + 0.02,
        "hybrid {} vs pure random {}",
        hybrid.min_residual(),
        random.min_residual()
    );
    // hybrid stats must be recorded and consistent (θ > 0 requires rows
    // whose leverage exceeds τ·k — guaranteed on spiked designs, tested
    // in randnla::leverage; small near-uniform SBMs may take none)
    let (frac, theta) = hybrid.records.last().unwrap().hybrid_stats.unwrap();
    assert!((0.0..=1.0).contains(&frac));
    assert!((0.0..=1.0 + 1e-9).contains(&theta));
    assert!(theta >= frac * 0.0); // θ and fraction co-vanish
}

#[test]
fn lvs_works_for_bpp_rule_too() {
    let g = oag_workload(400, 7);
    let o = opts(16, 8);
    let res = Method::Lvs { rule: UpdateRule::Bpp, tau: Tau::OneOverS }.run(&g.adj, &o);
    assert!(res.h.is_nonneg());
    let first = res.records.first().unwrap().residual;
    assert!(res.min_residual() <= first);
}
