//! Persistent worker pool behind every parallel kernel dispatch.
//!
//! Before this module, `parallel_for_chunks` / `parallel_map_into` and
//! the SYMM `pair_pool_accumulate` harness opened a fresh
//! `std::thread::scope` on every call — an OS spawn + join per SYMM tile
//! pass, per HALS sweep, per SpMM, several times per solver iteration.
//! At the small-m/small-k sizes where the randomized methods are
//! cheapest per iteration, that fixed dispatch tax dominates. Here the
//! workers are spawned **lazily once per process** (total compute width
//! = [`num_threads`], counting the submitting thread), park on a Condvar
//! when idle, and receive work via an epoch-stamped broadcast: the
//! submitter publishes a type-erased job pointer plus a generation
//! counter under the pool mutex, wakes the workers, runs its own share,
//! and waits on an atomic countdown — spinning first, parking on a
//! Condvar only if the tail outlives the spin window, so sub-millisecond
//! kernels never touch the futex path.
//!
//! ## The two backends
//!
//! [`dispatch`] routes through one of two interchangeable executors,
//! selected once per process by `SYMNMF_POOL` (same override idiom as
//! `SYMNMF_KERNEL`, reported by `symnmf --features`):
//!
//! * `pooled` (default) — the persistent pool described above. Worker
//!   threads are named `symnmf-pool-N` for profilers.
//! * `scoped` — the historical per-call `std::thread::scope` spawn,
//!   kept as the pinning oracle. `SYMNMF_POOL=scoped` reverts every
//!   parallel site in the process, including `pair_pool_accumulate`.
//!
//! Backend choice can never change results: both executors run the same
//! slot closures over the same slot indices, and every caller derives
//! its geometry (chunk ranges, accumulator-slot counts) from the logical
//! width before asking for execution. The choice is therefore never
//! serialized into checkpoints or trace headers — unlike the kernel ISA,
//! which does change bits and is recorded/validated on resume.
//!
//! ## Reentrancy rule
//!
//! The pool executes one job at a time, so a dispatch issued from inside
//! a running slot (nested data parallelism, e.g. a batched trial worker
//! whose solver calls a kernel) must not re-submit — a naive
//! implementation would deadlock waiting for workers that are busy
//! running its caller. Instead, nested dispatch runs **inline**: the
//! calling slot executes all of the nested call's slots sequentially, in
//! index order, on its own thread. The nested caller still computes its
//! chunk geometry from its thread budget exactly as before, so the
//! partitioning — and therefore every bit of output — matches the scoped
//! oracle. Distinct submitting threads (e.g. serve workers) are *not*
//! nested: they serialize on the pool, each submission running at its
//! budgeted width while the others park.
//!
//! ## Panic semantics
//!
//! A panicking slot body is caught on the worker, the remaining slots
//! still run (matching `std::thread::scope`, where sibling spawns are
//! unaffected by one thread's panic), the pool is left reusable, and the
//! first captured payload is resent on the submitting thread once the
//! countdown drains. `catch_unwind` callers — the serve scheduler's
//! panic isolation — observe exactly what they observed under scoped
//! spawning.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};

use super::threadpool::num_threads;

/// How a parallel dispatch is executed. Selection never affects results
/// — see the module docs — only where the slot closures run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolBackend {
    /// Persistent `symnmf-pool-N` workers, spawned once per process.
    Pooled,
    /// Per-call `std::thread::scope` spawn + join (the pinning oracle).
    Scoped,
}

impl PoolBackend {
    pub fn as_str(self) -> &'static str {
        match self {
            PoolBackend::Pooled => "pooled",
            PoolBackend::Scoped => "scoped",
        }
    }

    pub fn parse(s: &str) -> Option<PoolBackend> {
        match s.to_ascii_lowercase().as_str() {
            "pooled" => Some(PoolBackend::Pooled),
            "scoped" => Some(PoolBackend::Scoped),
            _ => None,
        }
    }
}

/// Resolve `SYMNMF_POOL` once. Unset or empty means `pooled`; anything
/// else must name a backend, and an unknown name fails loudly (the
/// `SYMNMF_KERNEL` idiom: a typo must not silently run the default).
fn env_backend() -> PoolBackend {
    static ACTIVE: OnceLock<PoolBackend> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("SYMNMF_POOL") {
        Ok(raw) if !raw.is_empty() => PoolBackend::parse(&raw)
            .unwrap_or_else(|| panic!("SYMNMF_POOL={raw}: expected scoped|pooled")),
        _ => PoolBackend::Pooled,
    })
}

/// Test/bench override slot: 0 = none (use the env), otherwise the
/// backend discriminant + 1. Written only under [`override_backend`]'s
/// serializing guard.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// The backend every [`dispatch`] call uses: a live [`override_backend`]
/// guard if one is held, else the process-wide `SYMNMF_POOL` resolution.
pub fn active_backend() -> PoolBackend {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => PoolBackend::Pooled,
        2 => PoolBackend::Scoped,
        _ => env_backend(),
    }
}

/// Serializes tests/benches that pin a backend; restores the env-derived
/// resolution on drop (the `failpoint::scoped` idiom).
pub struct BackendOverride {
    _serial: MutexGuard<'static, ()>,
}

/// Pin the dispatch backend for the guard's lifetime. Guards serialize
/// on a global lock so concurrent tests cannot see each other's pins;
/// on drop the process reverts to whatever `SYMNMF_POOL` says. Intended
/// for the pooled ≡ scoped parity tests and the fan-out benches — the
/// backend cannot change results, so a concurrent kernel observing the
/// pin is harmless.
pub fn override_backend(backend: PoolBackend) -> BackendOverride {
    static SCOPE_LOCK: Mutex<()> = Mutex::new(());
    let serial = SCOPE_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let code = match backend {
        PoolBackend::Pooled => 1,
        PoolBackend::Scoped => 2,
    };
    OVERRIDE.store(code, Ordering::Relaxed);
    BackendOverride { _serial: serial }
}

impl Drop for BackendOverride {
    fn drop(&mut self) {
        OVERRIDE.store(0, Ordering::Relaxed);
    }
}

/// Total compute width of the pooled backend: the submitting thread plus
/// the persistent workers. Equal to the logical width by construction.
pub fn pool_width() -> usize {
    num_threads()
}

thread_local! {
    /// True while this thread is executing a dispatch slot (pool workers
    /// set it for their whole life; submitters set it around their own
    /// share). Nested dispatch observes it and runs inline.
    static IN_DISPATCH: Cell<bool> = const { Cell::new(false) };
}

/// Set `IN_DISPATCH` for a scope, restoring the previous value on drop
/// (including unwind, so a caught slot panic cannot leak the flag).
struct DispatchScope(bool);

impl DispatchScope {
    fn enter() -> DispatchScope {
        let prev = IN_DISPATCH.with(Cell::get);
        IN_DISPATCH.with(|f| f.set(true));
        DispatchScope(prev)
    }
}

impl Drop for DispatchScope {
    fn drop(&mut self) {
        let prev = self.0;
        IN_DISPATCH.with(|f| f.set(prev));
    }
}

/// A dispatch body: called exactly once per slot index in `0..parts`.
type Task<'a> = &'a (dyn Fn(usize) + Sync);

/// Run `task(i)` exactly once for every `i in 0..parts`, concurrently up
/// to the machine width, returning after all slots complete. `parts` is
/// a *slot count*, not a thread count — callers derive it from logical
/// geometry and the executor is free to run several slots on one thread
/// (it does whenever `parts` exceeds the available workers, and for the
/// whole job when the call is nested inside another dispatch).
///
/// If any slot panics, the remaining slots still run and the first
/// captured panic is rethrown here after all of them finish.
pub fn dispatch(parts: usize, task: Task) {
    dispatch_with(active_backend(), parts, task);
}

/// [`dispatch`] with an explicit backend — the parity tests and fan-out
/// benches use this to pin one side of a comparison without touching the
/// process-wide resolution.
pub fn dispatch_with(backend: PoolBackend, parts: usize, task: Task) {
    match parts {
        0 => return,
        1 => {
            task(0);
            return;
        }
        _ => {}
    }
    if IN_DISPATCH.with(Cell::get) {
        // Nested dispatch: run inline on the caller's thread (see the
        // module docs). The geometry `parts` encodes is unchanged.
        for i in 0..parts {
            task(i);
        }
        return;
    }
    match backend {
        PoolBackend::Scoped => scoped_dispatch(parts, task),
        PoolBackend::Pooled => global_pool().run(parts, task),
    }
}

/// The pinning oracle: one fresh scope thread per slot, exactly the
/// historical `parallel_for_chunks` shape. Scope join propagates a slot
/// panic on the submitting thread after all siblings finish.
fn scoped_dispatch(parts: usize, task: Task) {
    std::thread::scope(|s| {
        for i in 0..parts {
            s.spawn(move || task(i));
        }
    });
}

/// Type-erased job pointer: the submitter's `&dyn Fn` with the lifetime
/// erased. Valid for the whole job because the submitter does not return
/// from [`Pool::run`] until the countdown drains.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));
unsafe impl Send for JobPtr {}

/// Per-job completion block, owned by the submitter's stack frame.
/// Workers must not touch it after their final `pending` decrement.
struct Completion {
    /// Slots not yet finished; the submitter waits for zero.
    pending: AtomicUsize,
    /// First captured slot panic, resent on the submitting thread.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Broadcast state, guarded by [`Shared::state`].
struct State {
    /// Generation counter, bumped per published job: the stamp workers
    /// (and tests) use to tell "new job" from a spurious wake.
    epoch: u64,
    /// A job is published and its countdown has not yet drained.
    active: bool,
    job: Option<JobPtr>,
    done: Option<CompletionPtr>,
    /// Total slots of the active job.
    parts: usize,
    /// Slots claimed so far (slot 0 is pre-claimed by the submitter).
    /// Workers — and the submitter, once its own share is done — claim
    /// the next unclaimed slot, so a descheduled worker never strands
    /// work: someone else picks the slot up.
    started: usize,
}

#[derive(Clone, Copy)]
struct CompletionPtr(*const Completion);
unsafe impl Send for CompletionPtr {}

struct Shared {
    state: Mutex<State>,
    /// Workers park here when no job (or no unclaimed slot) exists.
    work: Condvar,
    /// The submitter parks here if the countdown outlives its spin.
    done_cv: Condvar,
    /// Queued submitters park here until the active job drains.
    idle: Condvar,
}

/// Spin iterations before a waiting submitter falls back to the Condvar.
/// Covers the tail imbalance of sub-millisecond kernels (the submitter
/// has already run its own share by the time it starts waiting).
const SPIN_LIMIT: u32 = 50_000;

struct Pool {
    shared: &'static Shared,
}

fn global_pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool::new(num_threads().saturating_sub(1)))
}

impl Pool {
    /// Spawn `helpers` persistent workers (the submitter is the
    /// remaining unit of width). Zero helpers is valid: every slot then
    /// runs on the submitting thread, which is the 1-core degradation.
    fn new(helpers: usize) -> Pool {
        let shared: &'static Shared = Box::leak(Box::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                active: false,
                job: None,
                done: None,
                parts: 0,
                started: 0,
            }),
            work: Condvar::new(),
            done_cv: Condvar::new(),
            idle: Condvar::new(),
        }));
        for i in 0..helpers {
            let b = std::thread::Builder::new().name(format!("symnmf-pool-{i}"));
            // A failed spawn just narrows the pool: slots the missing
            // worker would have claimed run on the remaining threads.
            let _ = b.spawn(move || worker_loop(shared));
        }
        Pool { shared }
    }

    fn run(&self, parts: usize, task: Task) {
        debug_assert!(parts >= 2, "parts <= 1 handled by dispatch_with");
        let completion = Completion {
            pending: AtomicUsize::new(parts),
            panic: Mutex::new(None),
        };
        let job = JobPtr(task as *const (dyn Fn(usize) + Sync));
        let my_epoch;
        {
            let mut st = lock(&self.shared.state);
            // One job at a time: queue behind the active one. Distinct
            // submitters (serve workers) serialize here while the pool
            // runs each at its budgeted width.
            while st.active {
                st = wait(&self.shared.idle, st);
            }
            st.epoch = st.epoch.wrapping_add(1);
            my_epoch = st.epoch;
            st.active = true;
            st.job = Some(job);
            st.done = Some(CompletionPtr(&completion));
            st.parts = parts;
            st.started = 1; // slot 0 is ours
            self.shared.work.notify_all();
        }
        // Run our own share first, then help with any still-unclaimed
        // slots (covers parts > width and descheduled workers alike).
        run_slot(self.shared, task, 0, &completion);
        loop {
            let slot = {
                let mut st = lock(&self.shared.state);
                // The epoch stamp guards against claiming a *successor*
                // job: if our own slot-0 decrement was the last, a
                // queued submitter may have installed a new generation
                // by the time we get back here.
                if st.active && st.epoch == my_epoch && st.started < st.parts {
                    let s = st.started;
                    st.started += 1;
                    Some(s)
                } else {
                    None
                }
            };
            match slot {
                Some(s) => run_slot(self.shared, task, s, &completion),
                None => break,
            }
        }
        // Spin-then-park for the helpers' slots.
        let mut spins = 0u32;
        loop {
            if completion.pending.load(Ordering::Acquire) == 0 {
                break;
            }
            if spins < SPIN_LIMIT {
                spins += 1;
                std::hint::spin_loop();
            } else {
                let mut st = lock(&self.shared.state);
                while completion.pending.load(Ordering::Acquire) != 0 {
                    st = wait(&self.shared.done_cv, st);
                }
                break;
            }
        }
        let payload = lock(&completion.panic).take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

fn lock(m: &Mutex<State>) -> MutexGuard<'_, State> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn wait<'a>(cv: &Condvar, g: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Execute one slot, capture a panic into the job's completion block,
/// and decrement the countdown. The *last* finisher releases the pool
/// (clears `active`, wakes the parked submitter and any queued ones).
/// Panic storage happens before the decrement: after it, the completion
/// block may leave the submitter's stack at any moment.
fn run_slot(shared: &Shared, task: Task, slot: usize, completion: &Completion) {
    let _scope = DispatchScope::enter();
    if let Err(p) = catch_unwind(AssertUnwindSafe(|| task(slot))) {
        let mut first = lock_panic(&completion.panic);
        if first.is_none() {
            *first = Some(p);
        }
    }
    drop(_scope);
    if completion.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        let mut st = lock(&shared.state);
        st.active = false;
        st.job = None;
        st.done = None;
        shared.done_cv.notify_all();
        shared.idle.notify_all();
        drop(st);
    }
}

#[allow(clippy::type_complexity)]
fn lock_panic(
    m: &Mutex<Option<Box<dyn std::any::Any + Send>>>,
) -> MutexGuard<'_, Option<Box<dyn std::any::Any + Send>>> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Park until a job with an unclaimed slot appears, claim the next slot,
/// run it, repeat forever. A worker that finishes a slot while its job
/// still has unclaimed slots claims another — fewer physical threads
/// than slots is always legal (the budget contract guarantees slot
/// bodies never require concurrency).
fn worker_loop(shared: &'static Shared) {
    loop {
        let (task, slot, completion) = {
            let mut st = lock(&shared.state);
            while !(st.active && st.started < st.parts) {
                st = wait(&shared.work, st);
            }
            let s = st.started;
            st.started += 1;
            (st.job.expect("active job has a task"), s, st.done.expect("active job has a completion"))
        };
        // SAFETY: the submitter keeps both the closure and the
        // completion block alive until `pending` drains, and we claimed
        // a slot before that can happen.
        let task: Task = unsafe { &*task.0 };
        let completion: &Completion = unsafe { &*completion.0 };
        run_slot(shared, task, slot, completion);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// A private pool with its own helper threads, so the broadcast
    /// machinery is exercised cross-thread even on a 1-core host (the
    /// global pool would have zero helpers there).
    fn test_pool(helpers: usize) -> Pool {
        Pool::new(helpers)
    }

    fn counts(n: usize) -> Vec<AtomicUsize> {
        (0..n).map(|_| AtomicUsize::new(0)).collect()
    }

    #[test]
    fn pooled_runs_every_slot_exactly_once() {
        let pool = test_pool(3);
        for parts in [2usize, 3, 4, 7, 16] {
            let c = counts(parts);
            pool.run(parts, &|i| {
                c[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                c.iter().all(|x| x.load(Ordering::Relaxed) == 1),
                "parts={parts}"
            );
        }
    }

    #[test]
    fn zero_helper_pool_degrades_to_the_submitter() {
        let pool = test_pool(0);
        let c = counts(5);
        pool.run(5, &|i| {
            c[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(c.iter().all(|x| x.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn epoch_advances_per_job_and_pool_is_reusable() {
        let pool = test_pool(2);
        let before = lock(&pool.shared.state).epoch;
        for _ in 0..10 {
            pool.run(3, &|_| {});
        }
        let after = lock(&pool.shared.state).epoch;
        assert_eq!(after.wrapping_sub(before), 10, "one epoch per broadcast");
    }

    /// A panicking slot: remaining slots still run (scope semantics),
    /// the panic is resent on the submitter, and the pool stays usable.
    #[test]
    fn slot_panic_propagates_and_pool_survives() {
        let pool = test_pool(2);
        let c = counts(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                c[i].fetch_add(1, Ordering::Relaxed);
                if i == 1 {
                    panic!("slot boom");
                }
            });
        }));
        let p = r.expect_err("slot panic must reach the submitter");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "slot boom");
        assert!(
            c.iter().all(|x| x.load(Ordering::Relaxed) == 1),
            "siblings of a panicked slot must still run"
        );
        // reusable afterward
        let c2 = counts(4);
        pool.run(4, &|i| {
            c2[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(c2.iter().all(|x| x.load(Ordering::Relaxed) == 1));
    }

    /// Nested dispatch from inside a slot runs inline instead of
    /// re-submitting — a naive pool would deadlock here, with every
    /// worker busy in the outer job waiting for workers to run the
    /// inner one.
    #[test]
    fn nested_dispatch_runs_inline_not_deadlocking() {
        let pool = test_pool(2);
        let inner_runs = AtomicUsize::new(0);
        pool.run(3, &|_| {
            // IN_DISPATCH is set on this thread, so this goes inline.
            dispatch(4, &|_| {
                inner_runs.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(inner_runs.load(Ordering::Relaxed), 3 * 4);
    }

    /// Distinct submitting threads serialize on one pool without
    /// deadlock — the serve-worker scenario.
    #[test]
    fn concurrent_submitters_serialize_without_deadlock() {
        static TOTAL: AtomicUsize = AtomicUsize::new(0);
        let pool: &'static Pool = Box::leak(Box::new(test_pool(2)));
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..20 {
                        pool.run(3, &|_| {
                            TOTAL.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(TOTAL.load(Ordering::Relaxed), 4 * 20 * 3);
    }

    #[test]
    fn dispatch_with_both_backends_covers_all_slots() {
        for backend in [PoolBackend::Pooled, PoolBackend::Scoped] {
            let c = counts(9);
            dispatch_with(backend, 9, &|i| {
                c[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                c.iter().all(|x| x.load(Ordering::Relaxed) == 1),
                "{}",
                backend.as_str()
            );
        }
    }

    #[test]
    fn scoped_backend_propagates_a_slot_panic_too() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            dispatch_with(PoolBackend::Scoped, 2, &|i| {
                if i == 1 {
                    panic!("scoped boom");
                }
            });
        }));
        assert!(r.is_err());
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        for b in [PoolBackend::Pooled, PoolBackend::Scoped] {
            assert_eq!(PoolBackend::parse(b.as_str()), Some(b));
        }
        assert_eq!(PoolBackend::parse("POOLED"), Some(PoolBackend::Pooled));
        assert_eq!(PoolBackend::parse("rayon"), None);
        assert_eq!(PoolBackend::parse(""), None);
    }

    #[test]
    fn override_guard_pins_and_restores() {
        {
            let _g = override_backend(PoolBackend::Scoped);
            assert_eq!(active_backend(), PoolBackend::Scoped);
        }
        {
            let _g = override_backend(PoolBackend::Pooled);
            assert_eq!(active_backend(), PoolBackend::Pooled);
        }
        // back to the env-derived resolution (pooled when unset)
        assert_eq!(OVERRIDE.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pool_width_is_the_logical_width() {
        assert_eq!(pool_width(), num_threads());
    }
}
