//! Similarity-based Silhouette Scores — the paper's §5.2.1 cluster-quality
//! metric for the unlabeled OAG graph. NOTE this is the *similarity*
//! variant defined in the paper (higher adjacency = closer), not the
//! classic dissimilarity form:
//!
//! ```text
//!     a(v) = (1/(|C_l|−1)) Σ_{j∈C_l, j≠v} A_vj
//!     b(v) = max_{t≠l} (1/|C_t|) Σ_{j∈C_t} A_vj
//!     s(v) = (a(v) − b(v)) / max(a(v), b(v))
//! ```
//!
//! Per-vertex scores are averaged per cluster. The per-vertex cluster
//! sums Σ_{j∈C_t} A_vj for all t are one block product A·M with M the
//! one-hot membership matrix — a single [`SymOp::apply`], so the metric
//! scales to sparse graphs.

use crate::linalg::DenseMat;
use crate::randnla::SymOp;

/// Mean silhouette per cluster; clusters with < 2 vertices get NaN.
/// Returns (per-cluster mean score, per-cluster size).
pub fn cluster_silhouettes<X: SymOp>(
    a: &X,
    assign: &[usize],
    k: usize,
) -> (Vec<f64>, Vec<usize>) {
    let m = a.dim();
    assert_eq!(assign.len(), m);
    let sizes = crate::clustering::assign::cluster_sizes(assign, k);
    // membership matrix M (m×k)
    let mut mem = DenseMat::zeros(m, k);
    for (i, &c) in assign.iter().enumerate() {
        mem.set(i, c, 1.0);
    }
    let sums = a.apply(&mem); // sums[v][t] = Σ_{j∈C_t} A_vj
    let mut acc = vec![0.0f64; k];
    let mut cnt = vec![0usize; k];
    for v in 0..m {
        let l = assign[v];
        if sizes[l] < 2 {
            continue;
        }
        // own-cluster similarity excludes the (zeroed-diagonal) self term;
        // if A has a nonzero diagonal the caller should zero it first.
        let av = sums.at(v, l) / (sizes[l] - 1) as f64;
        let mut bv = f64::NEG_INFINITY;
        for t in 0..k {
            if t != l && sizes[t] > 0 {
                bv = bv.max(sums.at(v, t) / sizes[t] as f64);
            }
        }
        if !bv.is_finite() {
            continue;
        }
        let denom = av.max(bv);
        let s = if denom.abs() < 1e-300 {
            0.0
        } else {
            (av - bv) / denom
        };
        acc[l] += s;
        cnt[l] += 1;
    }
    let means = acc
        .iter()
        .zip(&cnt)
        .map(|(&a, &c)| if c > 0 { a / c as f64 } else { f64::NAN })
        .collect();
    (means, sizes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CsrMat;

    /// Two perfect cliques, no cross edges → silhouettes = 1.
    #[test]
    fn perfect_clusters_score_one() {
        let mut trips = Vec::new();
        for block in 0..2usize {
            let off = block * 4;
            for i in 0..4 {
                for j in 0..4 {
                    if i != j {
                        trips.push((off + i, off + j, 1.0));
                    }
                }
            }
        }
        let a = CsrMat::from_coo(8, 8, trips);
        let assign = vec![0, 0, 0, 0, 1, 1, 1, 1];
        let (scores, sizes) = cluster_silhouettes(&a, &assign, 2);
        assert_eq!(sizes, vec![4, 4]);
        for s in scores {
            assert!((s - 1.0).abs() < 1e-12, "s={s}");
        }
    }

    /// Vertex assigned to the wrong clique scores negative.
    #[test]
    fn misassigned_vertex_drags_score_negative() {
        let mut trips = Vec::new();
        for block in 0..2usize {
            let off = block * 4;
            for i in 0..4 {
                for j in 0..4 {
                    if i != j {
                        trips.push((off + i, off + j, 1.0));
                    }
                }
            }
        }
        let a = CsrMat::from_coo(8, 8, trips);
        // vertex 0 wrongly assigned to cluster 1
        let assign = vec![1, 0, 0, 0, 1, 1, 1, 1];
        let (scores, _) = cluster_silhouettes(&a, &assign, 2);
        // cluster 1 contains the misassigned vertex → mean dips below 1
        assert!(scores[1] < 1.0);
    }

    /// Uniform graph (all pairs equal) → a(v) == b(v) → score 0.
    #[test]
    fn uniform_graph_scores_zero() {
        let n = 6;
        let mut trips = Vec::new();
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    trips.push((i, j, 1.0));
                }
            }
        }
        let a = CsrMat::from_coo(n, n, trips);
        let assign = vec![0, 0, 0, 1, 1, 1];
        let (scores, _) = cluster_silhouettes(&a, &assign, 2);
        for s in scores {
            // a(v) = 2/2 = 1, b(v) = 3/3 = 1 → 0
            assert!(s.abs() < 1e-12, "s={s}");
        }
    }
}
