//! Compressed-NMF baseline (Tepper & Sapiro [51]) extended to SymNMF —
//! the comparison method of paper App. B.1 ("Comp-BPP" / "Comp-HALS" in
//! Fig. 1 / Table 2).
//!
//! One RRF basis Q ∈ R^{m×l} is computed up front (symmetric input needs
//! only one side); each H-update then solves the projected problem
//! min_{H≥0} ‖Qᵀ(WHᵀ − X)‖² + α‖W − H‖², whose normal equations are
//!
//! ```text
//!     G = (QᵀW)ᵀ(QᵀW) + αI,   Y = Bᵀ·(QᵀW) + αW,   B = QᵀX (l×m).
//! ```
//!
//! The only difference from LAI-NMF is the projection QQᵀ inside the
//! Gram matrix (App. B.1 shows the RHS terms coincide) — empirically the
//! two behave nearly identically, which Table 2 (and our bench) confirms.
//!
//! ## Reduced-precision compute (`SYMNMF_PRECISION=f32`)
//!
//! The two inner GEMMs of each half-update (QᵀF and B̂ᵀ·(QᵀF)) touch the
//! m×l sketch operands — the dominant memory traffic of a compressed
//! iteration. Under [`Precision::F32`] those operands are staged once as
//! f32 (Q and Bᵀ at setup, the k-wide factors per half-update through a
//! grow-only [`F32Buf`]) and the products run with f32 multiplies but
//! **f64 accumulation** (`linalg::simd`'s widening policy); the Gram
//! matrix, the α-regularization, the NLS update, and the residual /
//! stopping rule all stay f64. Precision is an option
//! ([`SymNmfOptions::precision`], env-defaulted), not checkpoint state:
//! resume with the same options or forfeit bitwise reproduction.

use crate::linalg::simd::{self, KernelIsa, Precision};
use crate::linalg::{blas, DenseMat, F32Buf, IterWorkspace};
use crate::nls::{update_into, UpdateRule};
use crate::randnla::rrf::{ada_rrf, rrf};
use crate::randnla::SymOp;
use crate::symnmf::anls::{resolve_alpha, Metrics};
use crate::symnmf::engine::{
    run_solver, workspace_for, Checkpoint, EngineRun, EngineState, RunControl, SolveSpec,
    SolverEngine, Stage, StepOutcome, TraceSink,
};
use crate::symnmf::init::initial_factor;
#[cfg(test)]
use crate::symnmf::metrics::{IterRecord, StopRule};
use crate::symnmf::metrics::SymNmfResult;
use crate::symnmf::options::{PowerIter, SymNmfOptions};
use crate::util::rng::Pcg64;
#[cfg(test)]
use crate::util::timer::PHASE_SOLVE;
use crate::util::timer::{PhaseTimer, Stopwatch, PHASE_MM};

/// Compressed SymNMF as a [`SolverEngine`]: the RRF basis Q and the
/// projected data Bᵀ = X·Q are built once at init (the setup phase); one
/// step is the full W-then-H iteration over the projected normal
/// equations. The l×k projection scratch lives in the engine (the shared
/// workspace is sized for k-wide factors).
pub struct CompressedEngine {
    q: DenseMat,
    bt: DenseMat,
    alpha: f64,
    rule: UpdateRule,
    /// l×k scratch for QᵀF
    qtf: DenseMat,
    w: DenseMat,
    h: DenseMat,
    /// compute precision of the two sketch GEMMs (module header)
    precision: Precision,
    /// f32 stagings of Q / Bᵀ (empty under [`Precision::F64`])
    q32: Vec<f32>,
    bt32: Vec<f32>,
    /// grow-only per-half-update stagings of the factor and of QᵀF
    fstage: F32Buf,
    pstage: F32Buf,
}

impl CompressedEngine {
    pub fn new(
        q: DenseMat,
        bt: DenseMat,
        alpha: f64,
        rule: UpdateRule,
        h0: DenseMat,
        precision: Precision,
    ) -> CompressedEngine {
        let l = q.cols();
        let k = h0.cols();
        let (q32, bt32) = match precision {
            Precision::F64 => (Vec::new(), Vec::new()),
            Precision::F32 => (q.to_f32(), bt.to_f32()),
        };
        CompressedEngine {
            q,
            bt,
            alpha,
            rule,
            qtf: DenseMat::zeros(l, k),
            w: h0.clone(),
            h: h0,
            precision,
            q32,
            bt32,
            fstage: F32Buf::new(),
            pstage: F32Buf::new(),
        }
    }
}

/// One compressed half-update's sketch products under [`Precision::F32`]:
/// stage the k-wide factor, form QᵀF with f32 operands / f64
/// accumulation, take the (f64) Gram, re-stage QᵀF, and form B̂ᵀ·(QᵀF)
/// the same way. Free function over explicit fields so the `step` body
/// can keep its disjoint field borrows.
#[allow(clippy::too_many_arguments)]
fn project_f32(
    isa: KernelIsa,
    q32: &[f32],
    bt32: &[f32],
    m: usize,
    l: usize,
    fstage: &mut F32Buf,
    pstage: &mut F32Buf,
    f: &DenseMat,
    qtf: &mut DenseMat,
    g: &mut DenseMat,
    y: &mut DenseMat,
) {
    let k = f.cols();
    let sf = fstage.stage(f.data());
    simd::matmul_tn_f32_into(isa, q32, m, l, sf, k, qtf); // QᵀF, l×k
    blas::gram_into(qtf, g); // Fᵀ·QQᵀ·F — f64 accumulation
    let sp = pstage.stage(qtf.data());
    simd::matmul_f32_into(isa, bt32, m, l, sp, k, y); // (XQ)·(QᵀF)
}

impl SolverEngine for CompressedEngine {
    fn h(&self) -> &DenseMat {
        &self.h
    }

    fn w(&self) -> &DenseMat {
        &self.w
    }

    fn step(&mut self, ws: &mut IterWorkspace) -> StepOutcome {
        let mut mm = 0.0;
        let mut solve = 0.0;
        let isa = simd::active();
        let (m, l) = self.q.shape();

        // --- W update from H ---
        let t = Stopwatch::start();
        match self.precision {
            Precision::F64 => {
                blas::matmul_tn_into(&self.q, &self.h, &mut self.qtf); // QᵀH, l×k
                blas::gram_into(&self.qtf, &mut ws.g); // Hᵀ·QQᵀ·H
                blas::matmul_into(&self.bt, &self.qtf, &mut ws.y); // (XQ)·(QᵀH)
            }
            Precision::F32 => project_f32(
                isa,
                &self.q32,
                &self.bt32,
                m,
                l,
                &mut self.fstage,
                &mut self.pstage,
                &self.h,
                &mut self.qtf,
                &mut ws.g,
                &mut ws.y,
            ),
        }
        mm += t.elapsed_secs();
        ws.g.add_diag(self.alpha);
        ws.y.axpy(self.alpha, &self.h);
        let t = Stopwatch::start();
        update_into(self.rule, &ws.g, &ws.y, &mut self.w, &mut ws.update);
        solve += t.elapsed_secs();

        // --- H update from W ---
        let t = Stopwatch::start();
        match self.precision {
            Precision::F64 => {
                blas::matmul_tn_into(&self.q, &self.w, &mut self.qtf);
                blas::gram_into(&self.qtf, &mut ws.g);
                blas::matmul_into(&self.bt, &self.qtf, &mut ws.y);
            }
            Precision::F32 => project_f32(
                isa,
                &self.q32,
                &self.bt32,
                m,
                l,
                &mut self.fstage,
                &mut self.pstage,
                &self.w,
                &mut self.qtf,
                &mut ws.g,
                &mut ws.y,
            ),
        }
        mm += t.elapsed_secs();
        ws.g.add_diag(self.alpha);
        ws.y.axpy(self.alpha, &self.w);
        let t = Stopwatch::start();
        update_into(self.rule, &ws.g, &ws.y, &mut self.h, &mut ws.update);
        solve += t.elapsed_secs();

        StepOutcome { mm_secs: mm, solve_secs: solve, ..StepOutcome::default() }
    }

    fn save(&self) -> EngineState {
        EngineState { h: self.h.clone(), w: Some(self.w.clone()), rng: None }
    }

    fn load(&mut self, st: &EngineState) {
        assert_eq!(st.h.shape(), self.h.shape(), "CompressedEngine::load: H shape");
        self.h = st.h.clone();
        self.w = match &st.w {
            Some(w) => {
                assert_eq!(w.shape(), self.h.shape(), "CompressedEngine::load: W shape");
                w.clone()
            }
            None => self.h.clone(),
        };
    }
}

/// Compressed SymNMF ("Comp-<rule>") — thin wrapper over the engine path
/// (`SYMNMF_DEADLINE_MS` honored).
pub fn compressed_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    compressed_symnmf_run(x, opts, &RunControl::from_env(), None, None).result
}

/// The controlled engine entry: the RRF + projection setup recomputes
/// deterministically on resume; the checkpoint carries (H, W).
pub fn compressed_symnmf_run<X: SymOp>(
    x: &X,
    opts: &SymNmfOptions,
    ctrl: &RunControl,
    resume: Option<&Checkpoint>,
    trace: Option<&mut dyn TraceSink>,
) -> EngineRun {
    let xd: &dyn SymOp = x;
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let alpha = resolve_alpha(x, opts);
    let l = opts.sketch_width();
    let mut phases = PhaseTimer::new();

    // --- setup: one RRF + B = QᵀX (timed) ---
    let sw = Stopwatch::start();
    let basis = match opts.power {
        PowerIter::Static(q) => rrf(x, l, q, &mut rng),
        PowerIter::Adaptive { q_max, tol } => ada_rrf(x, l, q_max, tol, &mut rng),
    };
    let q = basis.q_basis;
    // B = QᵀX = (X·Q)ᵀ for symmetric X → store Bᵀ = X·Q (m×l)
    let bt = x.apply(&q);
    let setup_secs = sw.elapsed_secs();
    phases.add(PHASE_MM, std::time::Duration::from_secs_f64(setup_secs));

    let h0 = initial_factor(x, opts, &mut rng);
    let mut spec = SolveSpec {
        stages: vec![Stage {
            engine: Box::new(CompressedEngine::new(
                q,
                bt,
                alpha,
                opts.rule,
                h0,
                opts.resolved_precision(),
            )),
            label: format!("Comp-{}", opts.rule.label()),
        }],
        metrics: Metrics::new(xd, true),
        setup_secs,
        phases,
    };
    let mut ws = workspace_for(&spec);
    run_solver(&mut spec, opts, ctrl, resume, trace, &mut ws)
}

/// The frozen pre-engine Compressed loop (pinning oracle).
#[cfg(test)]
fn compressed_symnmf_reference<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let alpha = resolve_alpha(x, opts);
    let k = opts.k;
    let l = opts.sketch_width();
    let mut phases = PhaseTimer::new();

    // --- setup: one RRF + B = QᵀX (timed) ---
    let sw = Stopwatch::start();
    let basis = match opts.power {
        PowerIter::Static(q) => rrf(x, l, q, &mut rng),
        PowerIter::Adaptive { q_max, tol } => ada_rrf(x, l, q_max, tol, &mut rng),
    };
    let q = basis.q_basis;
    // B = QᵀX = (X·Q)ᵀ for symmetric X → store Bᵀ = X·Q (m×l)
    let bt = x.apply(&q);
    let setup_secs = sw.elapsed_secs();
    phases.add(PHASE_MM, std::time::Duration::from_secs_f64(setup_secs));

    let mut h = initial_factor(x, opts, &mut rng);
    let mut w = h.clone();
    let metrics = Metrics::new(x, true);
    let mut records: Vec<IterRecord> = Vec::new();
    let mut stop = StopRule::new(opts.tol, opts.patience);
    let mut clock = setup_secs;
    let label = format!("Comp-{}", opts.rule.label());
    // per-iteration buffers, sized once: shared (m,k) workspace plus the
    // l×k projected-factor buffer specific to the compressed formulation
    let m = x.dim();
    let mut ws = IterWorkspace::new(m, k);
    let mut qtf = DenseMat::zeros(l, k);

    for iter in 0..opts.max_iters {
        let sw = Stopwatch::start();
        let mut mm = 0.0;
        let mut solve = 0.0;

        // --- W update from H ---
        let t = Stopwatch::start();
        blas::matmul_tn_into(&q, &h, &mut qtf); // QᵀH, l×k
        blas::gram_into(&qtf, &mut ws.g); // Hᵀ·QQᵀ·H
        blas::matmul_into(&bt, &qtf, &mut ws.y); // (XQ)·(QᵀH) = (QQᵀX)ᵀ… m×k
        mm += t.elapsed_secs();
        ws.g.add_diag(alpha);
        ws.y.axpy(alpha, &h);
        let t = Stopwatch::start();
        update_into(opts.rule, &ws.g, &ws.y, &mut w, &mut ws.update);
        solve += t.elapsed_secs();

        // --- H update from W ---
        let t = Stopwatch::start();
        blas::matmul_tn_into(&q, &w, &mut qtf);
        blas::gram_into(&qtf, &mut ws.g);
        blas::matmul_into(&bt, &qtf, &mut ws.y);
        mm += t.elapsed_secs();
        ws.g.add_diag(alpha);
        ws.y.axpy(alpha, &w);
        let t = Stopwatch::start();
        update_into(opts.rule, &ws.g, &ws.y, &mut h, &mut ws.update);
        solve += t.elapsed_secs();

        clock += sw.elapsed_secs();
        phases.add(PHASE_MM, std::time::Duration::from_secs_f64(mm));
        phases.add(PHASE_SOLVE, std::time::Duration::from_secs_f64(solve));

        let (res, pg) = metrics.eval_ws(&w, &h, &mut ws);
        records.push(IterRecord {
            iter,
            time_secs: clock,
            residual: res,
            proj_grad: pg,
            phase_secs: (mm, solve, 0.0),
            hybrid_stats: None,
        });
        if stop.update(res) {
            break;
        }
    }

    SymNmfResult { label, h, w, records, phases, setup_secs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symnmf::engine::{assert_results_bitwise_eq, RunStatus};
    use crate::symnmf::lai::lai_symnmf;

    /// Acceptance: the engine wrapper is bitwise-identical to the frozen
    /// pre-refactor loop.
    #[test]
    fn engine_path_pinned_bitwise_to_reference() {
        for (m, k) in [(40, 2), (63, 7)] {
            let x = planted(m, k, 19);
            let mut opts = SymNmfOptions::new(k)
                .with_rule(UpdateRule::Hals)
                .with_seed(23);
            opts.max_iters = 10;
            let oracle = compressed_symnmf_reference(&x, &opts);
            let engine =
                compressed_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
            assert_results_bitwise_eq(&oracle, &engine.result, &format!("comp k={k}"));
        }
    }

    /// Satellite acceptance: cancel-before-first-step and mid-run cancel
    /// both leave resumable checkpoints completing to the uninterrupted
    /// run bitwise (the compressed sketch rebuilds deterministically).
    #[test]
    fn cancel_token_aborts_and_resumes_bitwise() {
        use crate::symnmf::engine::CancelToken;
        use crate::symnmf::trace::CancelAfterSink;
        let x = planted(36, 3, 41);
        let mut opts = SymNmfOptions::new(3).with_seed(12);
        opts.max_iters = 7;
        let full = compressed_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);

        let tok = CancelToken::new();
        tok.cancel();
        let cancelled = compressed_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            None,
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.result.iters(), 0);
        let resumed = compressed_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited(),
            Some(&cancelled.checkpoint),
            None,
        );
        assert_results_bitwise_eq(&full.result, &resumed.result, "comp cancel-0 resume");

        let tok = CancelToken::new();
        let mut hook = CancelAfterSink::new(tok.clone(), 2);
        let cancelled = compressed_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            Some(&mut hook),
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.result.iters(), 2);
        let cp = Checkpoint::parse(&cancelled.checkpoint.serialize()).expect("roundtrip");
        let resumed =
            compressed_symnmf_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
        assert_results_bitwise_eq(&full.result, &resumed.result, "comp mid-cancel resume");
    }

    /// Acceptance: checkpoint/resume bitwise (the RRF setup recomputes
    /// deterministically on resume) + deadline-0 initial iterate.
    #[test]
    fn checkpoint_resume_and_deadline() {
        for k in [2usize, 7] {
            let x = planted(9 * k, k, 29);
            let mut opts = SymNmfOptions::new(k).with_seed(31);
            opts.max_iters = 8;
            let full = compressed_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
            let paused = compressed_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited().with_max_steps(3),
                None,
                None,
            );
            assert_eq!(paused.checkpoint.status, RunStatus::Paused);
            let cp = Checkpoint::parse(&paused.checkpoint.serialize()).expect("roundtrip");
            let resumed =
                compressed_symnmf_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
            assert_results_bitwise_eq(&full.result, &resumed.result, &format!("comp k={k}"));

            let dead = compressed_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited().with_deadline(0.0),
                None,
                None,
            );
            assert_eq!(dead.checkpoint.status, RunStatus::Deadline);
            assert!(dead.result.records.is_empty());
            let resumed = compressed_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited(),
                Some(&dead.checkpoint),
                None,
            );
            assert_results_bitwise_eq(
                &full.result,
                &resumed.result,
                &format!("comp deadline-0 k={k}"),
            );
        }
    }

    fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        x.symmetrize();
        x
    }

    #[test]
    fn converges_on_planted() {
        let x = planted(60, 4, 1);
        let mut opts = SymNmfOptions::new(4)
            .with_rule(UpdateRule::Hals)
            .with_seed(2);
        opts.max_iters = 100;
        let res = compressed_symnmf(&x, &opts);
        assert!(res.h.is_nonneg());
        assert!(res.min_residual() < 0.1, "res {}", res.min_residual());
        assert_eq!(res.label, "Comp-HALS");
    }

    /// Driver-level acceptance for `SYMNMF_PRECISION=f32`: on an SBM
    /// workload the f32 compute path's best residual tracks the f64
    /// path's closely — only the two sketch GEMMs dropped precision (f32
    /// multiplies, f64 accumulation); Gram, update, and stop rule are
    /// still f64, and the factors stay nonnegative.
    #[test]
    fn f32_precision_tracks_f64_residual_on_sbm() {
        use crate::data::sbm::{generate, SbmParams};
        let g = generate(&SbmParams::skewed(120, 4, 0.4, 11).with_degrees(12.0, 1.0));
        let mut opts = SymNmfOptions::new(4)
            .with_rule(UpdateRule::Hals)
            .with_seed(3);
        opts.max_iters = 40;
        let r64 = compressed_symnmf(&g.adj, &opts.clone().with_precision(Precision::F64));
        let r32 = compressed_symnmf(&g.adj, &opts.with_precision(Precision::F32));
        assert!(r32.h.is_nonneg());
        let gap = (r32.min_residual() - r64.min_residual()).abs();
        assert!(
            gap < 5e-3 * r64.min_residual().max(1.0),
            "f32 residual {} drifted from f64 residual {} (gap {gap})",
            r32.min_residual(),
            r64.min_residual()
        );
    }

    /// The f32 path is still deterministic and resumable: same options →
    /// bitwise-identical reruns, and a paused f32 run resumes bitwise
    /// (the staged f32 operands rebuild deterministically from the f64
    /// sketch).
    #[test]
    fn f32_path_is_deterministic_and_resumes_bitwise() {
        let x = planted(40, 3, 17);
        let mut opts = SymNmfOptions::new(3)
            .with_rule(UpdateRule::Hals)
            .with_seed(6)
            .with_precision(Precision::F32);
        opts.max_iters = 6;
        let a = compressed_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
        let b = compressed_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
        assert_results_bitwise_eq(&a.result, &b.result, "comp f32 rerun");

        let paused = compressed_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_max_steps(2),
            None,
            None,
        );
        assert_eq!(paused.checkpoint.status, RunStatus::Paused);
        let cp = Checkpoint::parse(&paused.checkpoint.serialize()).expect("roundtrip");
        let resumed =
            compressed_symnmf_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
        assert_results_bitwise_eq(&a.result, &resumed.result, "comp f32 resume");
    }

    /// App. B.1: Compressed-NMF and LAI-NMF behave nearly identically on
    /// symmetric inputs — check final residuals agree.
    #[test]
    fn nearly_identical_to_lai() {
        let x = planted(50, 3, 3);
        let mut opts = SymNmfOptions::new(3)
            .with_rule(UpdateRule::Bpp)
            .with_seed(4);
        opts.max_iters = 80;
        let comp = compressed_symnmf(&x, &opts);
        let lai = lai_symnmf(&x, &opts);
        assert!(
            (comp.min_residual() - lai.min_residual()).abs() < 0.02,
            "Comp {} vs LAI {}",
            comp.min_residual(),
            lai.min_residual()
        );
    }
}
