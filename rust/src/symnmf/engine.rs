//! The resumable solver engine: one step-driven outer loop for every
//! SymNMF method.
//!
//! Every method in the paper — ANLS/HALS/MU (§2.1.1), PGNCG (§2.1.3),
//! LAI-SymNMF (§3), LvS-SymNMF (§4), Compressed-NMF (App. B.1) — shares
//! the same skeleton: initialize H, repeat an alternating update, stop on
//! a residual-based rule. The seed implementation gave each driver a
//! private copy of that outer loop, so cross-cutting features (wall-clock
//! deadlines, mid-solve snapshots, warm-start chaining like §3.3's
//! LAI → IR refinement) had to be re-implemented per method. This module
//! owns the loop once; the methods reduce to *engines* that know how to
//! advance the iterate by one step.
//!
//! ## The state machine
//!
//! ```text
//!   init:   an entry wrapper (symnmf_anls, lvs_symnmf, …) seeds the RNG,
//!           resolves α, draws H₀, builds one engine per stage and a
//!           [`SolveSpec`] (stages + metrics + setup time).
//!
//!   step:   [`run_solver`] drives the active stage's
//!           [`SolverEngine::step`] — one full outer iteration (both
//!           half-updates for alternating methods), all scratch drawn
//!           from the shared [`IterWorkspace`] — and receives a
//!           [`StepOutcome`] (per-phase seconds + sampler stats).
//!
//!   outcome: the loop evaluates exact metrics off the clock, emits one
//!           [`IterRecord`] (to the history AND to an optional
//!           [`TraceSink`]), and feeds the residual to the stage's
//!           [`ConvergencePolicy`] (the §5.1 stopping rule + iteration
//!           cap). A converged or capped stage hands its H to the next
//!           stage as a warm start (that is how LAI-IR is *composed*
//!           rather than special-cased); after the last stage the run is
//!           complete.
//!
//!   checkpoint: before every step the loop honors the [`RunControl`]
//!           budget — a wall-clock **deadline** on the algorithm clock
//!           (setup included, so a deadline of 0 returns the initial
//!           iterate without stepping) or a step quota for cooperative
//!           pausing. Interrupted or not, the run returns a serializable
//!           [`Checkpoint`] of (H, W, iteration counters, RNG state,
//!           stopping-rule state, residual history); resuming from it —
//!           even after a JSON round-trip through another process —
//!           reproduces the uninterrupted run bitwise (times excepted:
//!           they are wall-clock observations, not state).
//! ```
//!
//! ## Bitwise contract
//!
//! For a fixed process configuration the engine path is pinned
//! bit-for-bit against the frozen pre-refactor loops (kept as reference
//! oracles in each method module): identical RNG draw sequence, identical
//! kernel-call order, identical stopping decisions. Deadlines and pauses
//! only ever cut the iteration sequence short — they never perturb the
//! iterations that do run.
//!
//! Since the SIMD dispatch layer (`linalg::simd`) the "fixed process
//! configuration" includes the active kernel ISA: FMA-tier kernels on
//! different ISAs round differently, so a checkpoint produced under one
//! dispatch is only bitwise-resumable under the same dispatch. Every
//! [`Checkpoint`] therefore records the ISA it was produced under, and
//! [`run_solver`] refuses to resume under a different one — set
//! `SYMNMF_KERNEL=<recorded isa>` to force the original kernel (or
//! accept a non-bitwise continuation by re-running from scratch).
//! Checkpoints from before the dispatch layer carry no ISA and resume
//! unconditionally. The same reasoning applies to `SYMNMF_PRECISION`:
//! options are not checkpointed, so resuming with different opts (f32 vs
//! f64 compute) is outside the bitwise contract by construction.

use crate::linalg::simd;
use crate::linalg::{DenseMat, IterWorkspace};
use crate::symnmf::anls::Metrics;
use crate::symnmf::metrics::{IterRecord, StopRule, SymNmfResult};
use crate::symnmf::options::SymNmfOptions;
use crate::util::json::Json;
use crate::util::rng::RngState;
use crate::util::timer::{PhaseTimer, Stopwatch, PHASE_MM, PHASE_SAMPLING, PHASE_SOLVE};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What one engine step reports back to the outer loop: per-phase seconds
/// (the Fig. 3 categories) and, for samplers, the hybrid statistics of
/// Fig. 6. The outer loop owns everything else — wall clock, metrics,
/// records, stopping.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepOutcome {
    pub mm_secs: f64,
    pub solve_secs: f64,
    pub sample_secs: f64,
    /// (deterministic fraction, θ/k), averaged over the W and H samplers
    pub hybrid_stats: Option<(f64, f64)>,
}

/// Serializable snapshot of one engine's resumable iterate state.
#[derive(Clone, Debug)]
pub struct EngineState {
    pub h: DenseMat,
    /// `None` when W aliases H (PGNCG) or for warm starts (the engine
    /// re-derives W = H, exactly like the legacy warm-start entry).
    pub w: Option<DenseMat>,
    /// present only for engines that draw randomness per step (LvS)
    pub rng: Option<RngState>,
}

/// One SymNMF method as a stepper. Construction corresponds to the
/// `init` arrow of the module-header state machine; [`step`] advances the
/// iterate by one full outer iteration against the shared workspace;
/// [`save`]/[`load`] snapshot and restore everything a resumed run needs
/// to replay the remaining iterations bitwise.
///
/// [`step`]: SolverEngine::step
/// [`save`]: SolverEngine::save
/// [`load`]: SolverEngine::load
pub trait SolverEngine {
    /// Current H iterate.
    fn h(&self) -> &DenseMat;

    /// Current W iterate; aliases H for methods that maintain only H.
    fn w(&self) -> &DenseMat;

    /// One outer iteration (both half-updates for alternating methods).
    /// All per-iteration products, Grams and update scratch must come
    /// from `ws` — the steady-state loop allocates nothing.
    fn step(&mut self, ws: &mut IterWorkspace) -> StepOutcome;

    /// Row-sample budget s (sizes the workspace gather buffer); 0 for
    /// methods that never sample.
    fn sample_budget(&self) -> usize {
        0
    }

    /// Snapshot the resumable state.
    fn save(&self) -> EngineState;

    /// Restore from a [`SolverEngine::save`] snapshot (or a warm start
    /// carrying only H). Shapes must match the engine's problem.
    fn load(&mut self, st: &EngineState);
}

/// Stage-level convergence policy — `convergence`'s §5.1 stopping rule
/// plus the outer iteration cap, folded into one resumable object. Each
/// stage of a chain gets a fresh policy (matching the legacy IR loops,
/// which restarted the stopping rule on the true-X continuation).
pub struct ConvergencePolicy {
    max_iters: usize,
    rule: StopRule,
}

impl ConvergencePolicy {
    pub fn from_opts(opts: &SymNmfOptions) -> ConvergencePolicy {
        ConvergencePolicy {
            max_iters: opts.max_iters,
            rule: StopRule::new(opts.tol, opts.patience),
        }
    }

    /// Rebuild mid-run from the checkpointed `(best, stall)` state.
    pub fn from_state(opts: &SymNmfOptions, best: f64, stall: usize) -> ConvergencePolicy {
        ConvergencePolicy {
            max_iters: opts.max_iters,
            rule: StopRule::from_state(opts.tol, opts.patience, best, stall),
        }
    }

    pub fn max_iters(&self) -> usize {
        self.max_iters
    }

    /// Feed the residual of the iteration that just finished; true when
    /// the stage should stop.
    pub fn observe(&mut self, residual: f64) -> bool {
        self.rule.update(residual)
    }

    /// Resumable `(best, stall)` state.
    pub fn state(&self) -> (f64, usize) {
        self.rule.state()
    }
}

/// Cooperative cancellation flag, shared between a controller (a serving
/// loop, a request handler, a trace-sink hook) and the engine loop. The
/// loop checks it **between steps** — before every step, alongside the
/// deadline and quota checks — so a cancel never tears a half-finished
/// iteration: the run aborts at the next step boundary with
/// [`RunStatus::Cancelled`] and a fully valid, resumable [`Checkpoint`].
/// Cancelling before the first step returns the initial iterate
/// unstepped (exactly like a deadline of 0).
///
/// Clones share one flag (it is an `Arc<AtomicBool>`), so the same token
/// can be handed to many trial workers and cancel a whole fleet at once.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; takes effect at the next
    /// between-steps check of every run holding a clone of this token.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }

    /// Clear the flag so the token can gate a resumed run. Only the
    /// controller that owns the job should reset — racing a reset
    /// against an in-flight run turns a cancel into a no-op.
    pub fn reset(&self) {
        self.0.store(false, Ordering::Release);
    }
}

/// Run budget honored before every step: a wall-clock deadline on the
/// algorithm clock (setup + iterations — so a deadline of 0 returns the
/// initial iterate without stepping), a step quota for cooperative
/// pausing, and/or a [`CancelToken`] for mid-flight aborts. All three
/// produce a resumable [`Checkpoint`].
#[derive(Clone, Debug, Default)]
pub struct RunControl {
    pub deadline_secs: Option<f64>,
    pub max_steps: Option<usize>,
    /// checked between steps; a set flag aborts with
    /// [`RunStatus::Cancelled`] (checkpoint still returned)
    pub cancel: Option<CancelToken>,
}

impl RunControl {
    /// No budget: run to convergence (the legacy behavior).
    pub fn unlimited() -> RunControl {
        RunControl::default()
    }

    /// The environment contract: `SYMNMF_DEADLINE_MS` (milliseconds)
    /// imposes a deadline on every solve that goes through the plain
    /// entry points — how CI exercises the deadline path under the full
    /// integration suite without touching call sites. An unset or empty
    /// variable means no deadline; a malformed or negative value panics
    /// loudly rather than silently disabling the deadline a CI job or
    /// operator asked for.
    pub fn from_env() -> RunControl {
        let deadline_secs = match std::env::var("SYMNMF_DEADLINE_MS") {
            Err(_) => None,
            Ok(v) if v.trim().is_empty() => None,
            Ok(v) => match v.trim().parse::<f64>() {
                Ok(ms) if ms >= 0.0 => Some(ms / 1000.0),
                _ => panic!(
                    "SYMNMF_DEADLINE_MS must be a nonnegative number of \
                     milliseconds, got {v:?}"
                ),
            },
        };
        RunControl { deadline_secs, max_steps: None, cancel: None }
    }

    pub fn with_deadline(mut self, secs: f64) -> RunControl {
        self.deadline_secs = Some(secs);
        self
    }

    pub fn with_max_steps(mut self, n: usize) -> RunControl {
        self.max_steps = Some(n);
        self
    }

    pub fn with_cancel(mut self, token: CancelToken) -> RunControl {
        self.cancel = Some(token);
        self
    }
}

/// How a run ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunStatus {
    /// every stage ran to its stopping rule (or iteration cap)
    Completed,
    /// the wall-clock deadline expired; resume to continue
    Deadline,
    /// the step quota was exhausted; resume to continue
    Paused,
    /// a [`CancelToken`] fired between steps; resume to continue
    Cancelled,
}

impl RunStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            RunStatus::Completed => "completed",
            RunStatus::Deadline => "deadline",
            RunStatus::Paused => "paused",
            RunStatus::Cancelled => "cancelled",
        }
    }

    fn parse(s: &str) -> Result<RunStatus, String> {
        match s {
            "completed" => Ok(RunStatus::Completed),
            "deadline" => Ok(RunStatus::Deadline),
            "paused" => Ok(RunStatus::Paused),
            "cancelled" => Ok(RunStatus::Cancelled),
            other => Err(format!("unknown run status {other:?}")),
        }
    }
}

/// Per-iteration observer: every finished iteration's [`IterRecord`]
/// (residual, projected-gradient norm, per-phase seconds) streams through
/// here as it is produced — the once ad-hoc per-driver history vectors
/// are now emitted from this single point. A sink observes the
/// iterations of **this run**: a fresh run streams everything the result
/// will contain; a resumed run streams only the post-resume iterations
/// (the restored prefix lives in the checkpoint's — and the final
/// result's — records, it is not replayed).
pub trait TraceSink {
    /// A stage began (its §5 label). Also fired for the first stage.
    fn on_stage(&mut self, _label: &str) {}

    /// One outer iteration finished.
    fn on_record(&mut self, rec: &IterRecord);
}

/// A [`TraceSink`] that collects everything (tests, ad-hoc tooling).
#[derive(Default)]
pub struct VecSink {
    pub stages: Vec<String>,
    pub records: Vec<IterRecord>,
}

impl TraceSink for VecSink {
    fn on_stage(&mut self, label: &str) {
        self.stages.push(label.to_string());
    }

    fn on_record(&mut self, rec: &IterRecord) {
        self.records.push(rec.clone());
    }
}

/// One stage of a solve: an engine plus its §5 label. Multi-stage specs
/// express warm-start chaining — stage i+1 starts from stage i's final H
/// (the generalized §3.3 Iterative Refinement).
pub struct Stage<'a> {
    pub engine: Box<dyn SolverEngine + 'a>,
    pub label: String,
}

/// Everything [`run_solver`] needs besides options and budget: the stage
/// chain, the exact-metric evaluator (always against the TRUE X), and the
/// setup cost already on the clock (LAI/RRF build time).
pub struct SolveSpec<'a> {
    pub stages: Vec<Stage<'a>>,
    pub metrics: Metrics<'a>,
    pub setup_secs: f64,
    pub phases: PhaseTimer,
}

/// Serializable mid-run snapshot: enough to resume the solve in another
/// process and reproduce the uninterrupted run bitwise (wall-clock fields
/// excepted). Produced by every [`run_solver`] call — a completed run's
/// checkpoint simply reports [`RunStatus::Completed`].
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub status: RunStatus,
    /// active stage index
    pub stage: usize,
    /// iterations completed within the active stage
    pub stage_iter: usize,
    /// global iterations completed (= records.len())
    pub iter: usize,
    /// algorithm clock (setup + iteration seconds) — wall-clock, resumed
    /// runs continue the timeline from here
    pub clock: f64,
    /// active stage's stopping-rule state
    pub stop_best: f64,
    pub stop_stall: usize,
    /// active engine's iterate state (H, W, RNG)
    pub state: EngineState,
    /// residual history so far
    pub records: Vec<IterRecord>,
    /// kernel ISA the producing process dispatched (`None` on checkpoints
    /// from before the SIMD dispatch layer). Resume refuses a mismatch —
    /// FMA-tier kernels round differently per ISA, so continuing under a
    /// different dispatch would silently break the bitwise contract.
    pub isa: Option<String>,
}

/// Result of one [`run_solver`] call: the (possibly partial) solver
/// result plus the checkpoint to resume it.
pub struct EngineRun {
    pub result: SymNmfResult,
    pub checkpoint: Checkpoint,
}

impl EngineRun {
    /// True unless a deadline or pause cut the run short.
    pub fn completed(&self) -> bool {
        self.checkpoint.status == RunStatus::Completed
    }
}

/// The shared outer loop (see the module header for the state machine).
///
/// Drives the stage chain of `spec` under the `ctrl` budget, optionally
/// resuming from a prior checkpoint (the spec must have been rebuilt from
/// the same X and options — setup recomputes deterministically; the
/// checkpoint then overwrites the iterate state). All per-iteration
/// buffers come from `ws`, pre-sized by the caller via
/// [`workspace_for`]; the steady-state loop performs no heap allocation
/// beyond the record history.
pub fn run_solver(
    spec: &mut SolveSpec<'_>,
    opts: &SymNmfOptions,
    ctrl: &RunControl,
    resume: Option<&Checkpoint>,
    mut trace: Option<&mut dyn TraceSink>,
    ws: &mut IterWorkspace,
) -> EngineRun {
    let SolveSpec { stages, metrics, setup_secs, phases } = spec;
    let nstages = stages.len();
    assert!(nstages >= 1, "run_solver: need at least one stage");

    let mut stage;
    let mut stage_iter;
    let mut iter;
    let mut clock;
    let mut records: Vec<IterRecord>;
    let mut policy;
    let mut finished = false;
    match resume {
        Some(cp) => {
            assert!(cp.stage < nstages, "checkpoint stage {} out of range", cp.stage);
            if let Some(saved) = cp.isa.as_deref() {
                let here = simd::active().as_str();
                assert!(
                    saved == here,
                    "checkpoint was produced under kernel ISA '{saved}' but this \
                     process dispatches '{here}'; bitwise resume requires the \
                     original kernel — set SYMNMF_KERNEL={saved} (or restart the \
                     solve from scratch to accept the new dispatch)"
                );
            }
            stage = cp.stage;
            stage_iter = cp.stage_iter;
            iter = cp.iter;
            clock = cp.clock;
            records = cp.records.clone();
            policy = ConvergencePolicy::from_state(opts, cp.stop_best, cp.stop_stall);
            stages[stage].engine.load(&cp.state);
            finished = cp.status == RunStatus::Completed;
            if !finished {
                // the sink contract: every record a sink observes belongs
                // to the most recently announced stage
                if let Some(t) = trace.as_deref_mut() {
                    t.on_stage(&stages[stage].label);
                }
            }
        }
        None => {
            stage = 0;
            stage_iter = 0;
            iter = 0;
            clock = *setup_secs;
            records = Vec::new();
            policy = ConvergencePolicy::from_opts(opts);
            if let Some(t) = trace.as_deref_mut() {
                t.on_stage(&stages[0].label);
            }
        }
    }

    let mut steps_this_run = 0usize;
    let mut status = RunStatus::Completed;
    if !finished {
        'run: loop {
            while stage_iter < policy.max_iters() {
                // cancel outranks the other budgets: a controller that
                // cancels wants the checkpoint to say so, even if the
                // deadline would also have fired at this boundary
                if ctrl.cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                    status = RunStatus::Cancelled;
                    break 'run;
                }
                if ctrl.deadline_secs.is_some_and(|d| clock >= d) {
                    status = RunStatus::Deadline;
                    break 'run;
                }
                if ctrl.max_steps.is_some_and(|n| steps_this_run >= n) {
                    status = RunStatus::Paused;
                    break 'run;
                }
                let engine = stages[stage].engine.as_mut();
                let sw = Stopwatch::start();
                let out = engine.step(ws);
                clock += sw.elapsed_secs();
                phases.add(PHASE_MM, Duration::from_secs_f64(out.mm_secs));
                phases.add(PHASE_SOLVE, Duration::from_secs_f64(out.solve_secs));
                if out.sample_secs > 0.0 {
                    phases.add(PHASE_SAMPLING, Duration::from_secs_f64(out.sample_secs));
                }

                // metrics off the clock (workspace buffers are free here)
                let (res, pg) = metrics.eval_ws(engine.w(), engine.h(), ws);
                let rec = IterRecord {
                    iter,
                    time_secs: clock,
                    residual: res,
                    proj_grad: pg,
                    phase_secs: (out.mm_secs, out.solve_secs, out.sample_secs),
                    hybrid_stats: out.hybrid_stats,
                };
                if let Some(t) = trace.as_deref_mut() {
                    t.on_record(&rec);
                }
                records.push(rec);
                iter += 1;
                stage_iter += 1;
                steps_this_run += 1;
                if policy.observe(res) {
                    break;
                }
            }
            // stage converged or hit its cap
            if stage + 1 >= nstages {
                break 'run;
            }
            // warm-start the next stage from this stage's final H (the
            // legacy IR entries pass H and re-derive W = H)
            let warm = EngineState {
                h: stages[stage].engine.h().clone(),
                w: None,
                rng: None,
            };
            stage += 1;
            stages[stage].engine.load(&warm);
            stage_iter = 0;
            policy = ConvergencePolicy::from_opts(opts);
            if let Some(t) = trace.as_deref_mut() {
                t.on_stage(&stages[stage].label);
            }
        }
    } else if let Some(cp) = resume {
        status = cp.status;
    }

    // The checkpoint is materialized eagerly: one records clone plus two
    // factor clones (engine.save) per SOLVE — microseconds against the
    // m²k products of even a single iteration, and it keeps EngineRun a
    // plain owned value (no lazy-snapshot lifetime coupling to the
    // engine). The plain entry points that drop it pay the same noise.
    let engine = stages[stage].engine.as_ref();
    let (stop_best, stop_stall) = policy.state();
    let checkpoint = Checkpoint {
        status,
        stage,
        stage_iter,
        iter,
        clock,
        stop_best,
        stop_stall,
        state: engine.save(),
        records: records.clone(),
        isa: Some(simd::active().as_str().to_string()),
    };
    let result = SymNmfResult {
        // the ACTIVE stage's label: on completed runs this is the final
        // stage (identical to the legacy labeling); on interrupted runs
        // it truthfully names the stage that was executing — a deadlined
        // LAI-IR run that never reached refinement reports "LAI-…", not
        // "LAI-…-IR".
        label: stages[stage].label.clone(),
        h: engine.h().clone(),
        w: engine.w().clone(),
        records,
        phases: phases.clone(),
        setup_secs: *setup_secs,
    };
    EngineRun { result, checkpoint }
}

/// Size the shared iteration workspace for a stage chain: (m, k) from the
/// first stage's H, the gather budget from the largest sampler.
pub fn workspace_for(spec: &SolveSpec<'_>) -> IterWorkspace {
    let (m, k) = spec.stages[0].engine.h().shape();
    let s = spec
        .stages
        .iter()
        .map(|st| st.engine.sample_budget())
        .max()
        .unwrap_or(0);
    IterWorkspace::with_samples(m, k, s)
}

// ---------------------------------------------------------------------
// Checkpoint serialization.
//
// f64 payloads that must survive bitwise (factors, residuals, RNG state,
// stopping state) are encoded as fixed-width lowercase hex of their IEEE
// bits — `Json::Num` would round-trip too (Rust's shortest-repr Display),
// but hex is proof against any downstream printer and handles NaN/Inf.
// Wall-clock fields are plain numbers: they are observations, not state.
// ---------------------------------------------------------------------

fn hex_f64(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

fn unhex_f64(j: &Json) -> Result<f64, String> {
    let s = j.as_str().ok_or_else(|| "expected f64 hex string".to_string())?;
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .map_err(|e| format!("bad f64 hex {s:?}: {e}"))
}

fn hex_u128(x: u128) -> Json {
    Json::Str(format!("{x:032x}"))
}

fn unhex_u128(j: &Json) -> Result<u128, String> {
    let s = j.as_str().ok_or_else(|| "expected u128 hex string".to_string())?;
    u128::from_str_radix(s, 16).map_err(|e| format!("bad u128 hex {s:?}: {e}"))
}

fn num(j: Option<&Json>, what: &str) -> Result<f64, String> {
    j.and_then(Json::as_f64).ok_or_else(|| format!("missing number {what}"))
}

fn mat_to_json(m: &DenseMat) -> Json {
    use std::fmt::Write as _;
    let mut bits = String::with_capacity(16 * m.data().len());
    for v in m.data() {
        let _ = write!(bits, "{:016x}", v.to_bits());
    }
    Json::obj(vec![
        ("rows", Json::Num(m.rows() as f64)),
        ("cols", Json::Num(m.cols() as f64)),
        ("bits", Json::Str(bits)),
    ])
}

fn mat_from_json(j: &Json) -> Result<DenseMat, String> {
    let rows = num(j.get("rows"), "mat.rows")? as usize;
    let cols = num(j.get("cols"), "mat.cols")? as usize;
    let bits = j
        .get("bits")
        .and_then(Json::as_str)
        .ok_or_else(|| "missing mat.bits".to_string())?;
    if !bits.is_ascii() {
        // guards the fixed-offset slicing below: a multi-byte character
        // straddling a 16-byte boundary would otherwise panic
        return Err("mat.bits must be ASCII hex".to_string());
    }
    // checked size math: corrupted dims must yield Err, never an
    // overflow panic (debug) or a wrapped-through length check (release)
    let count = rows
        .checked_mul(cols)
        .filter(|&n| n.checked_mul(16) == Some(bits.len()))
        .ok_or_else(|| {
            format!("mat.bits length {} != 16·{rows}·{cols}", bits.len())
        })?;
    let mut data = Vec::with_capacity(count);
    for c in 0..count {
        let s = &bits[16 * c..16 * (c + 1)];
        let b = u64::from_str_radix(s, 16).map_err(|e| format!("bad mat hex {s:?}: {e}"))?;
        data.push(f64::from_bits(b));
    }
    Ok(DenseMat::from_vec(rows, cols, data))
}

fn record_to_json(r: &IterRecord) -> Json {
    let (mm, solve, sample) = r.phase_secs;
    Json::obj(vec![
        ("iter", Json::Num(r.iter as f64)),
        ("time_secs", Json::Num(r.time_secs)),
        ("residual", hex_f64(r.residual)),
        (
            "proj_grad",
            r.proj_grad.map(hex_f64).unwrap_or(Json::Null),
        ),
        (
            "phase_secs",
            Json::Arr(vec![Json::Num(mm), Json::Num(solve), Json::Num(sample)]),
        ),
        (
            "hybrid",
            r.hybrid_stats
                .map(|(a, b)| Json::Arr(vec![hex_f64(a), hex_f64(b)]))
                .unwrap_or(Json::Null),
        ),
    ])
}

fn record_from_json(j: &Json) -> Result<IterRecord, String> {
    let phase = j
        .get("phase_secs")
        .and_then(Json::as_arr)
        .filter(|a| a.len() == 3)
        .ok_or_else(|| "missing record.phase_secs[3]".to_string())?;
    let hybrid = match j.get("hybrid") {
        None | Some(Json::Null) => None,
        Some(Json::Arr(a)) if a.len() == 2 => {
            Some((unhex_f64(&a[0])?, unhex_f64(&a[1])?))
        }
        Some(other) => return Err(format!("bad record.hybrid {other:?}")),
    };
    let proj_grad = match j.get("proj_grad") {
        None | Some(Json::Null) => None,
        Some(v) => Some(unhex_f64(v)?),
    };
    Ok(IterRecord {
        iter: num(j.get("iter"), "record.iter")? as usize,
        time_secs: num(j.get("time_secs"), "record.time_secs")?,
        residual: unhex_f64(
            j.get("residual")
                .ok_or_else(|| "missing record.residual".to_string())?,
        )?,
        proj_grad,
        phase_secs: (
            num(Some(&phase[0]), "phase[0]")?,
            num(Some(&phase[1]), "phase[1]")?,
            num(Some(&phase[2]), "phase[2]")?,
        ),
        hybrid_stats: hybrid,
    })
}

/// Checkpoint wire versions. **Version 1** is the full checkpoint: every
/// field including the residual-history records — resuming reproduces the
/// complete stitched history in the final result. **Version 2** is the
/// *factor-only* slim variant: identical resumable iterate state (H, W,
/// RNG, counters, stopping state) but the records are dropped — for
/// long-running fleets whose history already streams to a
/// [`TraceSink`], where re-embedding every iteration's f64 hex in every
/// generation of checkpoint is pure write amplification. A run resumed
/// from a slim checkpoint is still bitwise-exact in factors and future
/// residuals; its result simply contains only the post-resume records.
pub const CHECKPOINT_VERSION_FULL: usize = 1;
pub const CHECKPOINT_VERSION_SLIM: usize = 2;

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        self.to_json_versioned(false)
    }

    /// Factor-only (version 2) encoding — see [`CHECKPOINT_VERSION_SLIM`].
    pub fn to_json_slim(&self) -> Json {
        self.to_json_versioned(true)
    }

    fn to_json_versioned(&self, slim: bool) -> Json {
        let rng = match &self.state.rng {
            Some(r) => Json::obj(vec![
                ("state", hex_u128(r.state)),
                ("inc", hex_u128(r.inc)),
                (
                    "spare",
                    r.gauss_spare.map(hex_f64).unwrap_or(Json::Null),
                ),
            ]),
            None => Json::Null,
        };
        let version = if slim {
            CHECKPOINT_VERSION_SLIM
        } else {
            CHECKPOINT_VERSION_FULL
        };
        let mut fields = vec![
            ("version", Json::Num(version as f64)),
            ("status", Json::Str(self.status.as_str().to_string())),
            ("stage", Json::Num(self.stage as f64)),
            ("stage_iter", Json::Num(self.stage_iter as f64)),
            ("iter", Json::Num(self.iter as f64)),
            ("clock", Json::Num(self.clock)),
            ("stop_best", hex_f64(self.stop_best)),
            ("stop_stall", Json::Num(self.stop_stall as f64)),
            ("h", mat_to_json(&self.state.h)),
            (
                "w",
                self.state
                    .w
                    .as_ref()
                    .map(mat_to_json)
                    .unwrap_or(Json::Null),
            ),
            ("rng", rng),
            (
                "isa",
                self.isa
                    .as_ref()
                    .map(|s| Json::Str(s.clone()))
                    .unwrap_or(Json::Null),
            ),
        ];
        if !slim {
            fields.push((
                "records",
                Json::Arr(self.records.iter().map(record_to_json).collect()),
            ));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Checkpoint, String> {
        let version = num(j.get("version"), "version")? as usize;
        if version != CHECKPOINT_VERSION_FULL && version != CHECKPOINT_VERSION_SLIM {
            return Err(format!(
                "unsupported checkpoint version {version} (supported: \
                 {CHECKPOINT_VERSION_FULL} = full, {CHECKPOINT_VERSION_SLIM} = factor-only)"
            ));
        }
        let status = RunStatus::parse(
            j.get("status")
                .and_then(Json::as_str)
                .ok_or_else(|| "missing status".to_string())?,
        )?;
        let w = match j.get("w") {
            None | Some(Json::Null) => None,
            Some(v) => Some(mat_from_json(v)?),
        };
        let rng = match j.get("rng") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let spare = match v.get("spare") {
                    None | Some(Json::Null) => None,
                    Some(s) => Some(unhex_f64(s)?),
                };
                Some(RngState {
                    state: unhex_u128(
                        v.get("state").ok_or_else(|| "missing rng.state".to_string())?,
                    )?,
                    inc: unhex_u128(
                        v.get("inc").ok_or_else(|| "missing rng.inc".to_string())?,
                    )?,
                    gauss_spare: spare,
                })
            }
        };
        // absent or null on pre-dispatch-layer checkpoints: resume then
        // proceeds without the ISA guard
        let isa = match j.get("isa") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| "isa must be a string or null".to_string())?
                    .to_string(),
            ),
        };
        let records = if version == CHECKPOINT_VERSION_SLIM {
            // factor-only: the history was dropped on purpose (it lives
            // in a trace sink); `iter` alone keeps record numbering
            // global on resume
            Vec::new()
        } else {
            j.get("records")
                .and_then(Json::as_arr)
                .ok_or_else(|| "missing records".to_string())?
                .iter()
                .map(record_from_json)
                .collect::<Result<Vec<_>, _>>()?
        };
        let iter = num(j.get("iter"), "iter")? as usize;
        // cheap internal-consistency validation at the parse boundary —
        // a corrupted checkpoint should fail here with Err, not as a
        // panic deep inside run_solver (stage bounds and factor shapes
        // are still checked there, against the rebuilt spec). Slim
        // checkpoints are exempt: dropping the records is their point.
        if version == CHECKPOINT_VERSION_FULL && iter != records.len() {
            return Err(format!(
                "inconsistent checkpoint: iter = {iter} but {} records",
                records.len()
            ));
        }
        Ok(Checkpoint {
            status,
            stage: num(j.get("stage"), "stage")? as usize,
            stage_iter: num(j.get("stage_iter"), "stage_iter")? as usize,
            iter,
            clock: num(j.get("clock"), "clock")?,
            stop_best: unhex_f64(
                j.get("stop_best").ok_or_else(|| "missing stop_best".to_string())?,
            )?,
            stop_stall: num(j.get("stop_stall"), "stop_stall")? as usize,
            state: EngineState {
                h: mat_from_json(
                    j.get("h").ok_or_else(|| "missing h".to_string())?,
                )?,
                w,
                rng,
            },
            records,
            isa,
        })
    }

    /// Serialize to a JSON string (the inverse of [`Checkpoint::parse`]).
    pub fn serialize(&self) -> String {
        self.to_json().to_string()
    }

    /// Serialize the factor-only (version 2) form — resumable iterate
    /// state without the residual history. [`Checkpoint::parse`] reads
    /// both versions.
    pub fn serialize_slim(&self) -> String {
        self.to_json_slim().to_string()
    }

    /// Parse a serialized checkpoint (version 1 full or version 2
    /// factor-only); unknown versions are rejected with a clear error.
    pub fn parse(s: &str) -> Result<Checkpoint, String> {
        Checkpoint::from_json(&Json::parse(s)?)
    }
}

/// Assert two results are bitwise-identical in everything the engine
/// contract pins: residual history (+ hybrid stats), factors, iteration
/// count, and label. Wall-clock fields are exempt. Shared by the
/// per-method pinning and resume tests.
#[cfg(test)]
pub(crate) fn assert_results_bitwise_eq(a: &SymNmfResult, b: &SymNmfResult, what: &str) {
    assert_eq!(a.label, b.label, "{what}: label");
    assert_eq!(a.iters(), b.iters(), "{what}: iteration count");
    for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        assert_eq!(ra.iter, rb.iter, "{what}: record {i} index");
        assert_eq!(
            ra.residual.to_bits(),
            rb.residual.to_bits(),
            "{what}: residual at iter {i}"
        );
        match (ra.proj_grad, rb.proj_grad) {
            (Some(x), Some(y)) => {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: proj_grad at iter {i}")
            }
            (None, None) => {}
            _ => panic!("{what}: proj_grad presence differs at iter {i}"),
        }
        match (ra.hybrid_stats, rb.hybrid_stats) {
            (Some((x1, x2)), Some((y1, y2))) => {
                assert_eq!(x1.to_bits(), y1.to_bits(), "{what}: hybrid.0 at iter {i}");
                assert_eq!(x2.to_bits(), y2.to_bits(), "{what}: hybrid.1 at iter {i}");
            }
            (None, None) => {}
            _ => panic!("{what}: hybrid presence differs at iter {i}"),
        }
    }
    assert_eq!(a.h.shape(), b.h.shape(), "{what}: H shape");
    for (x, y) in a.h.data().iter().zip(b.h.data()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: H bits");
    }
    assert_eq!(a.w.shape(), b.w.shape(), "{what}: W shape");
    for (x, y) in a.w.data().iter().zip(b.w.data()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: W bits");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn policy_caps_and_stops() {
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 10;
        opts.tol = 1e-4;
        opts.patience = 2;
        let mut p = ConvergencePolicy::from_opts(&opts);
        assert_eq!(p.max_iters(), 10);
        assert!(!p.observe(0.5));
        assert!(!p.observe(0.5)); // stall 1
        assert!(p.observe(0.5)); // stall 2 → stop
        // restored state picks up mid-stall
        let (best, stall) = p.state();
        let mut q = ConvergencePolicy::from_state(&opts, best, stall);
        assert_eq!(q.state(), p.state());
        assert!(q.observe(0.5), "restored rule is already at the threshold");
    }

    #[test]
    fn run_control_env_and_builders() {
        let c = RunControl::unlimited();
        assert!(c.deadline_secs.is_none() && c.max_steps.is_none() && c.cancel.is_none());
        let c = RunControl::unlimited().with_deadline(1.5).with_max_steps(7);
        assert_eq!(c.deadline_secs, Some(1.5));
        assert_eq!(c.max_steps, Some(7));
        let c = RunControl::unlimited().with_cancel(CancelToken::new());
        assert!(c.cancel.is_some());
    }

    #[test]
    fn cancel_token_is_shared_and_resettable() {
        let a = CancelToken::new();
        let b = a.clone();
        assert!(!a.is_cancelled() && !b.is_cancelled());
        b.cancel();
        assert!(a.is_cancelled(), "clones must share one flag");
        a.reset();
        assert!(!b.is_cancelled(), "reset must clear the shared flag");
    }

    #[test]
    fn checkpoint_json_roundtrips_bitwise() {
        let mut rng = Pcg64::seed_from_u64(5);
        for _ in 0..3 {
            rng.gaussian(); // leave a Box–Muller spare in the snapshot
        }
        let h = DenseMat::gaussian(4, 3, &mut rng);
        let w = DenseMat::gaussian(4, 3, &mut rng);
        let cp = Checkpoint {
            status: RunStatus::Paused,
            stage: 1,
            stage_iter: 2,
            iter: 2, // must equal records.len() (validated at parse)
            clock: 0.1234567890123,
            stop_best: f64::INFINITY,
            stop_stall: 3,
            state: EngineState {
                h: h.clone(),
                w: Some(w.clone()),
                rng: Some(rng.state()),
            },
            records: vec![
                IterRecord {
                    iter: 0,
                    time_secs: 0.5,
                    residual: 0.1 + 1e-17, // oddball bits
                    proj_grad: Some(2.5e-3),
                    phase_secs: (0.1, 0.2, 0.0),
                    hybrid_stats: None,
                },
                IterRecord {
                    iter: 1,
                    time_secs: 0.9,
                    residual: f64::NAN,
                    proj_grad: None,
                    phase_secs: (0.0, 0.0, 0.0),
                    hybrid_stats: Some((0.25, 0.75)),
                },
            ],
            isa: Some("scalar".to_string()),
        };
        let text = cp.serialize();
        let back = Checkpoint::parse(&text).expect("parse");
        assert_eq!(back.status, cp.status);
        assert_eq!(back.isa.as_deref(), Some("scalar"), "ISA survives the round-trip");
        assert_eq!(back.stage, 1);
        assert_eq!(back.stage_iter, 2);
        assert_eq!(back.iter, 2);
        assert_eq!(back.stop_best.to_bits(), cp.stop_best.to_bits());
        assert_eq!(back.stop_stall, 3);
        assert_eq!(back.state.rng, cp.state.rng);
        for (a, b) in cp.state.h.data().iter().zip(back.state.h.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in w.data().iter().zip(back.state.w.as_ref().unwrap().data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.records.len(), 2);
        assert_eq!(
            back.records[0].residual.to_bits(),
            cp.records[0].residual.to_bits()
        );
        assert_eq!(back.records[0].proj_grad.unwrap().to_bits(), 2.5e-3f64.to_bits());
        assert!(back.records[1].residual.is_nan(), "NaN must survive hex encoding");
        assert_eq!(
            back.records[1].hybrid_stats.unwrap().1.to_bits(),
            0.75f64.to_bits()
        );
    }

    #[test]
    fn checkpoint_parse_rejects_garbage() {
        assert!(Checkpoint::parse("{}").is_err());
        assert!(Checkpoint::parse("[1,2]").is_err());
        assert!(Checkpoint::parse("{\"status\":\"nope\"}").is_err());
    }

    /// Factor-only (version 2) round-trip: iterate state survives
    /// bitwise, the records are gone, and the version marker is honest.
    #[test]
    fn slim_checkpoint_roundtrips_factors_without_records() {
        let mut rng = Pcg64::seed_from_u64(11);
        let h = DenseMat::gaussian(5, 2, &mut rng);
        let cp = Checkpoint {
            status: RunStatus::Cancelled,
            stage: 0,
            stage_iter: 4,
            iter: 4,
            clock: 1.5,
            stop_best: 0.25,
            stop_stall: 1,
            state: EngineState {
                h: h.clone(),
                w: None,
                rng: Some(rng.state()),
            },
            records: vec![IterRecord {
                iter: 0,
                time_secs: 0.1,
                residual: 0.5,
                proj_grad: None,
                phase_secs: (0.0, 0.0, 0.0),
                hybrid_stats: None,
            }],
            isa: Some(simd::active().as_str().to_string()),
        };
        let text = cp.serialize_slim();
        assert!(!text.contains("records"), "slim form must drop the history");
        let back = Checkpoint::parse(&text).expect("slim parse");
        assert_eq!(back.status, RunStatus::Cancelled);
        assert_eq!(back.isa, cp.isa, "slim form still records the ISA");
        assert_eq!(back.iter, 4, "global iteration counter survives");
        assert!(back.records.is_empty(), "slim checkpoints carry no records");
        assert_eq!(back.stop_best.to_bits(), cp.stop_best.to_bits());
        assert_eq!(back.state.rng, cp.state.rng);
        for (a, b) in h.data().iter().zip(back.state.h.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // the slim form is much smaller than the full form once a real
        // history accumulates — here it just must not be larger
        assert!(text.len() < cp.serialize().len());
    }

    #[test]
    fn checkpoint_parse_rejects_unknown_version() {
        // take a valid checkpoint and bump its version marker
        let cp = Checkpoint {
            status: RunStatus::Completed,
            stage: 0,
            stage_iter: 0,
            iter: 0,
            clock: 0.0,
            stop_best: f64::INFINITY,
            stop_stall: 0,
            state: EngineState {
                h: DenseMat::zeros(2, 1),
                w: None,
                rng: None,
            },
            records: Vec::new(),
            isa: None, // legacy pre-dispatch-layer checkpoints parse too
        };
        let text = cp.serialize().replacen("\"version\":1", "\"version\":3", 1);
        let err = Checkpoint::parse(&text).expect_err("version 3 must be rejected");
        assert!(
            err.contains("unsupported checkpoint version 3"),
            "error must name the bad version: {err}"
        );
    }

    /// Adversarial inputs at the parse boundary — each rejected with a
    /// named `Err`, never a panic: truncation at every prefix length,
    /// duplicated keys, and factor payloads whose hex length disagrees
    /// with the claimed dimensions (oversized, undersized, or dims
    /// large enough to overflow the size math).
    #[test]
    fn checkpoint_parse_survives_adversarial_inputs() {
        let mut rng = Pcg64::seed_from_u64(17);
        let cp = Checkpoint {
            status: RunStatus::Paused,
            stage: 0,
            stage_iter: 1,
            iter: 1,
            clock: 0.5,
            stop_best: 0.5,
            stop_stall: 0,
            state: EngineState {
                h: DenseMat::gaussian(3, 2, &mut rng),
                w: None,
                rng: None,
            },
            records: vec![IterRecord {
                iter: 0,
                time_secs: 0.1,
                residual: 0.5,
                proj_grad: None,
                phase_secs: (0.0, 0.0, 0.0),
                hybrid_stats: None,
            }],
            isa: None,
        };
        let text = cp.serialize();
        assert!(Checkpoint::parse(&text).is_ok(), "fixture must be valid");

        // truncated at EVERY proper prefix: always Err, never panic
        for cut in 0..text.len() {
            assert!(
                Checkpoint::parse(&text[..cut]).is_err(),
                "prefix of length {cut} must be rejected"
            );
        }

        // duplicated key: the JSON layer rejects it by name
        let dup = text.replacen("\"iter\":1", "\"iter\":1,\"iter\":1", 1);
        let err = Checkpoint::parse(&dup).expect_err("duplicate key");
        assert!(err.contains("duplicate key"), "{err}");

        // oversized hex payload: more bits than 16·rows·cols
        let grow = |t: &str, extra: &str| t.replacen("\"bits\":\"", &format!("\"bits\":\"{extra}"), 1);
        let err = Checkpoint::parse(&grow(&text, &"0".repeat(16)))
            .expect_err("oversized payload");
        assert!(err.contains("mat.bits length"), "{err}");
        // undersized: claimed dims larger than the payload
        let small = text.replacen("\"rows\":3", "\"rows\":4", 1);
        let err = Checkpoint::parse(&small).expect_err("undersized payload");
        assert!(err.contains("mat.bits length"), "{err}");
        // hostile dims whose product overflows usize: Err, not an
        // overflow panic or a giant allocation
        let huge = text.replacen("\"rows\":3", &format!("\"rows\":{}", u64::MAX / 2), 1);
        assert!(Checkpoint::parse(&huge).is_err());
        // non-hex garbage inside the payload (length-preserving, so it
        // gets past the size check to the hex decode)
        let start = text.find("\"bits\":\"").unwrap() + "\"bits\":\"".len();
        let mut junk = text.clone();
        junk.replace_range(start..start + 16, &"z".repeat(16));
        let err = Checkpoint::parse(&junk).expect_err("non-hex payload");
        assert!(err.contains("bad mat hex"), "{err}");
    }

    /// Minimal do-nothing engine: lets the resume-guard tests drive
    /// [`run_solver`] without the cost (or numerics) of a real method.
    struct StaticEngine {
        h: DenseMat,
    }

    impl SolverEngine for StaticEngine {
        fn h(&self) -> &DenseMat {
            &self.h
        }
        fn w(&self) -> &DenseMat {
            &self.h
        }
        fn step(&mut self, _ws: &mut IterWorkspace) -> StepOutcome {
            StepOutcome::default()
        }
        fn save(&self) -> EngineState {
            EngineState { h: self.h.clone(), w: None, rng: None }
        }
        fn load(&mut self, st: &EngineState) {
            self.h = st.h.clone();
        }
    }

    fn static_spec(x: &DenseMat) -> SolveSpec<'_> {
        let (m, _) = x.shape();
        SolveSpec {
            stages: vec![Stage {
                engine: Box::new(StaticEngine { h: DenseMat::zeros(m, 2) }),
                label: "static".to_string(),
            }],
            metrics: Metrics::new(x, false),
            setup_secs: 0.0,
            phases: PhaseTimer::new(),
        }
    }

    /// Every checkpoint run_solver produces is stamped with the kernel
    /// ISA the process dispatched — the serve/resume layers rely on it.
    #[test]
    fn run_solver_stamps_active_isa_into_checkpoint() {
        let x = DenseMat::zeros(4, 4);
        let opts = SymNmfOptions::new(2);
        let ctrl = RunControl::unlimited().with_max_steps(0);
        let mut spec = static_spec(&x);
        let mut ws = workspace_for(&spec);
        let run = run_solver(&mut spec, &opts, &ctrl, None, None, &mut ws);
        assert_eq!(
            run.checkpoint.isa.as_deref(),
            Some(simd::active().as_str()),
            "checkpoint must record the active dispatch"
        );
    }

    /// Resuming accepts a matching recorded ISA and (for back-compat)
    /// a legacy checkpoint that recorded none.
    #[test]
    fn resume_accepts_matching_and_legacy_isa() {
        let x = DenseMat::zeros(4, 4);
        let opts = SymNmfOptions::new(2);
        let ctrl = RunControl::unlimited().with_max_steps(0);
        let mut spec = static_spec(&x);
        let mut ws = workspace_for(&spec);
        let run = run_solver(&mut spec, &opts, &ctrl, None, None, &mut ws);
        let mut cp = run.checkpoint;
        // matching ISA: the stamp run_solver just produced
        run_solver(&mut static_spec(&x), &opts, &ctrl, Some(&cp), None, &mut ws);
        // legacy checkpoint: no ISA recorded → guard is skipped
        cp.isa = None;
        run_solver(&mut static_spec(&x), &opts, &ctrl, Some(&cp), None, &mut ws);
    }

    /// A checkpoint produced under a different dispatch must fail loudly
    /// on resume — silently continuing would break the bitwise contract.
    #[test]
    #[should_panic(expected = "kernel ISA")]
    fn resume_refuses_checkpoint_from_different_isa() {
        let x = DenseMat::zeros(4, 4);
        let opts = SymNmfOptions::new(2);
        let ctrl = RunControl::unlimited().with_max_steps(0);
        let mut spec = static_spec(&x);
        let mut ws = workspace_for(&spec);
        let run = run_solver(&mut spec, &opts, &ctrl, None, None, &mut ws);
        let mut cp = run.checkpoint;
        cp.isa = Some(
            if simd::active() == simd::KernelIsa::Scalar { "avx2" } else { "scalar" }
                .to_string(),
        );
        run_solver(&mut static_spec(&x), &opts, &ctrl, Some(&cp), None, &mut ws);
    }
}
