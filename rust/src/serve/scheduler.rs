//! The cancellation-aware slice scheduler.
//!
//! [`Scheduler`] multiplexes submitted jobs over a bounded worker pool:
//! each worker repeatedly pops the best runnable job (highest priority,
//! then earliest deadline, then FIFO), drives **one budgeted slice** of
//! it through the job's solver closure (which wraps
//! [`crate::coordinator::driver::Method::run_controlled_traced`]),
//! persists the resulting checkpoint
//! to the optional [`JobStore`], and either finalizes the job or puts it
//! back in the queue. The worker pool splits the machine exactly like
//! the batched trial driver: with `nt = current_threads()` and `w`
//! workers, each slice runs under [`with_thread_budget`]`(nt / w)`, so
//! total OS-thread demand stays ≈ `nt` while kernel FP geometry remains
//! pinned to the logical width (the bitwise guarantee). Serve workers
//! (`symnmf-serve-N`) are thus the *submitters* to the persistent kernel
//! pool (`symnmf-pool-N`, see [`crate::util::pool`]): their budget keeps
//! pool width + serve width at ≈ the machine width, and a slice's
//! `catch_unwind` isolation sees identical panic behavior under either
//! `SYMNMF_POOL` backend.
//!
//! A slice's [`RunControl`] is the *intersection* of the scheduler's
//! slice granularity ([`SchedulerConfig::slice_steps`] /
//! [`SchedulerConfig::slice_secs`]) and the job's own remaining budget,
//! plus the job's [`CancelToken`]. Because the engine contract says
//! interruption never perturbs the iterations that do run, a job driven
//! in any number of slices — including a cancel and a resume in the
//! middle — finishes with bitwise-identical factors and residual history
//! to the uninterrupted solve (the serve integration suite pins this for
//! every method).

use crate::randnla::SymOp;
use crate::serve::job::{lock_recover, JobHandle, JobInner, JobSpec, JobStatus};
use crate::serve::opcache::{CachedOperator, OpCache, OpKey};
use crate::serve::store::{sanitize_id, JobStore};
use crate::symnmf::engine::{Checkpoint, EngineRun, RunControl, RunStatus, TraceSink};
use crate::symnmf::trace::{open_sink, CancelAfterSink};
use crate::util::threadpool::{current_threads, with_thread_budget};
use crate::util::{failpoint, retry};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};

/// Scheduler policy knobs.
#[derive(Default)]
pub struct SchedulerConfig {
    /// Worker-pool cap; `None` → min(physical width, runnable jobs),
    /// exactly the batched trial driver's split.
    pub workers: Option<usize>,
    /// Engine steps per slice (≥ 1). `None` with `slice_secs` unset
    /// means a job runs its whole remaining budget in one slice.
    pub slice_steps: Option<usize>,
    /// Algorithm-clock seconds per slice (> 0): each slice's deadline is
    /// the job's checkpointed clock plus this much, so every slice makes
    /// progress (the deadline check runs *before* a step).
    pub slice_secs: Option<f64>,
    /// Persist every slice's checkpoint here, keyed by job name.
    pub store: Option<JobStore>,
    /// Persist factor-only (version 2) checkpoints — for fleets whose
    /// history streams through trace sinks.
    pub slim_checkpoints: bool,
}

/// Max-heap key: higher priority first, then earlier deadline, then FIFO.
#[derive(PartialEq, Eq)]
struct ReadyKey {
    priority: i64,
    /// `Option<f64>` deadline mapped monotonically onto u64 (None = MAX)
    deadline_key: u64,
    seq: u64,
    job: usize,
}

fn deadline_key(d: Option<f64>) -> u64 {
    match d {
        None => u64::MAX,
        // nonnegative finite f64s compare like their bit patterns
        Some(x) => x.max(0.0).to_bits(),
    }
}

impl Ord for ReadyKey {
    fn cmp(&self, other: &ReadyKey) -> Ordering {
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.deadline_key.cmp(&self.deadline_key))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for ReadyKey {
    fn partial_cmp(&self, other: &ReadyKey) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

struct QueueState {
    ready: BinaryHeap<ReadyKey>,
    running: usize,
}

/// What one slice hands back to the scheduler: the engine run plus how
/// the operator was obtained (borrowed, resident-cached, or streamed
/// from a spill file) — the latter feeds the job's `spilled_slices`
/// accounting.
struct SliceRun {
    run: EngineRun,
    /// `None`: borrowed operator ([`Scheduler::submit`]). `Some(s)`:
    /// cache-pinned ([`Scheduler::submit_cached`]), with `s` = the pin
    /// was served by the out-of-core tier.
    op_spilled: Option<bool>,
}

/// One job's solver, type-erased at submission: (slice control, resume
/// point, trace) → the slice's [`SliceRun`]. Captures either the `&'x X`
/// operator reference (plain submit) or an `Arc<OpCache>` + key +
/// builder (cached submit — the operator is pinned per slice, so the
/// cache can evict it **between** slices, never under one), plus the
/// method and the options.
type Runner<'x> = Box<
    dyn Fn(&RunControl, Option<&Checkpoint>, Option<&mut dyn TraceSink>) -> SliceRun
        + Sync
        + 'x,
>;

/// A job's persistent streaming sink, shared with the worker that is
/// currently (exclusively) driving the job.
type SharedSink = Mutex<Option<Box<dyn TraceSink + Send>>>;

/// The serving scheduler. `'x` is the lifetime of the operator
/// references jobs run against — submit borrows them, so every operator
/// must outlive the scheduler.
pub struct Scheduler<'x> {
    cfg: SchedulerConfig,
    jobs: Vec<Arc<JobInner>>,
    runners: Vec<Runner<'x>>,
    /// per-job persistent streaming sink (lives across slices, so a
    /// stitched trace file equals the uninterrupted run's history)
    sinks: Vec<SharedSink>,
    queue: Mutex<QueueState>,
    work: Condvar,
    seq: AtomicU64,
}

impl<'x> Scheduler<'x> {
    pub fn new(cfg: SchedulerConfig) -> Scheduler<'x> {
        if let Some(n) = cfg.slice_steps {
            assert!(n >= 1, "slice_steps must be >= 1");
        }
        if let Some(s) = cfg.slice_secs {
            assert!(s > 0.0, "slice_secs must be > 0");
        }
        Scheduler {
            cfg,
            jobs: Vec::new(),
            runners: Vec::new(),
            sinks: Vec::new(),
            queue: Mutex::new(QueueState { ready: BinaryHeap::new(), running: 0 }),
            work: Condvar::new(),
            seq: AtomicU64::new(0),
        }
    }

    pub fn job_count(&self) -> usize {
        self.jobs.len()
    }

    /// Submit one job against operator `x`. Returns its handle; the job
    /// runs when [`Scheduler::drain`] is driven.
    pub fn submit<X: SymOp + Sync + ?Sized>(
        &mut self,
        x: &'x X,
        spec: JobSpec,
    ) -> Result<JobHandle, String> {
        let method = spec.method;
        let opts = spec.opts.clone();
        let runner: Runner<'x> = Box::new(
            move |ctrl: &RunControl,
                  resume: Option<&Checkpoint>,
                  trace: Option<&mut dyn TraceSink>| {
                SliceRun {
                    run: method.run_controlled_traced(&x, &opts, ctrl, resume, trace),
                    op_spilled: None,
                }
            },
        );
        self.submit_runner(spec, runner)
    }

    /// Submit one job against a **cached** operator: every slice pins
    /// `key` in the [`OpCache`] (running `build` only if the entry is
    /// absent or was dropped) and unpins when the slice ends, so the
    /// cache may evict the operator between slices — to its spill file
    /// for packed storage — without ever pulling it out from under a
    /// running solve. Slices served from the out-of-core tier are
    /// counted in the job's [`JobOutcome::spilled_slices`].
    ///
    /// Because the spilled apply is bitwise-identical to the resident
    /// apply (see `linalg::spill`), a job whose operator is evicted and
    /// faulted back mid-run still satisfies the slice/resume bitwise
    /// contract.
    ///
    /// [`JobOutcome::spilled_slices`]: crate::serve::job::JobOutcome
    pub fn submit_cached<F>(
        &mut self,
        cache: &Arc<OpCache>,
        key: OpKey,
        build: F,
        spec: JobSpec,
    ) -> Result<JobHandle, String>
    where
        F: Fn() -> CachedOperator + Sync + 'x,
    {
        let method = spec.method;
        let opts = spec.opts.clone();
        let cache = Arc::clone(cache);
        let runner: Runner<'x> = Box::new(
            move |ctrl: &RunControl,
                  resume: Option<&Checkpoint>,
                  trace: Option<&mut dyn TraceSink>| {
                let pin = cache.pin_or_build(&key, &build);
                SliceRun {
                    op_spilled: Some(pin.is_spilled()),
                    run: method.run_controlled_traced(pin.op(), &opts, ctrl, resume, trace),
                }
            },
        );
        self.submit_runner(spec, runner)
    }

    /// Shared submission tail: sink, store generation sync, queueing.
    fn submit_runner(&mut self, spec: JobSpec, runner: Runner<'x>) -> Result<JobHandle, String> {
        if spec.name.is_empty() {
            return Err("job name must be nonempty".to_string());
        }
        // sanitized-id collision hardening: two DISTINCT raw ids that
        // sanitize to the same filename would share (and GC) one
        // checkpoint lineage in the store — reject at submission, store
        // or not, so the collision can't appear later when a store is
        // added. (Resubmitting the same raw id is the caller's business.)
        let sanitized = sanitize_id(&spec.name);
        for other in &self.jobs {
            if other.name != spec.name && sanitize_id(&other.name) == sanitized {
                return Err(format!(
                    "job id {:?} collides with live job {:?} after sanitization \
                     (both become {sanitized:?}); checkpoint files would share one lineage",
                    spec.name, other.name
                ));
            }
        }
        let sink = match &spec.trace {
            // resumed jobs append after the pre-resume prefix on disk;
            // fresh jobs start a fresh file
            Some((path, format)) => Some(open_sink(path, *format, spec.resume.is_some())?),
            None => None,
        };
        let id = self.jobs.len();
        let inner = Arc::new(JobInner::new(id, &spec));
        // continue the store's generation numbering: a resumed job must
        // write generations ABOVE the persisted ones, or GC (which keeps
        // the numerically newest) would delete the fresh checkpoints and
        // retain the stale pre-resume one
        if let Some(store) = &self.cfg.store {
            if let Some(&g) = store.generations(&inner.name)?.last() {
                lock_recover(&inner.core).gen = g;
            }
        }
        self.runners.push(runner);
        self.sinks.push(Mutex::new(sink));
        self.jobs.push(Arc::clone(&inner));
        self.enqueue(id, inner.priority, inner.deadline_secs);
        Ok(JobHandle { inner })
    }

    /// Put a suspended, cancelled, or failed job back in the ready
    /// queue, clearing its cancel flag so the resumed slices can run.
    /// (The reset is shared: resuming one job of a fleet that shares an
    /// external token clears that token.) Resumption opens a fresh
    /// budget epoch: a `max_steps` budget grants that many steps again;
    /// a job suspended on its algorithm-clock deadline re-suspends
    /// immediately unless the caller raised the deadline out of band.
    /// A failed job restarts from its last good checkpoint (or cold if
    /// its first slice panicked), with the failure message cleared.
    pub fn resume(&self, handle: &JobHandle) -> Result<(), String> {
        let job = self
            .jobs
            .get(handle.id())
            .filter(|j| Arc::ptr_eq(j, &handle.inner))
            .ok_or_else(|| "handle does not belong to this scheduler".to_string())?;
        {
            let mut core = lock_recover(&job.core);
            match core.status {
                JobStatus::Suspended | JobStatus::Cancelled | JobStatus::Failed => {
                    core.status = JobStatus::Queued;
                    core.steps_used = 0;
                    core.failure = None;
                }
                s => {
                    return Err(format!(
                        "cannot resume a job in status {:?}",
                        s.as_str()
                    ))
                }
            }
        }
        job.cancel.reset();
        self.enqueue(job.id, job.priority, job.deadline_secs);
        Ok(())
    }

    fn enqueue(&self, job: usize, priority: i64, deadline: Option<f64>) {
        let key = ReadyKey {
            priority,
            deadline_key: deadline_key(deadline),
            seq: self.seq.fetch_add(1, AtomicOrdering::Relaxed),
            job,
        };
        lock_recover(&self.queue).ready.push(key);
        self.work.notify_all();
    }

    /// Run queued jobs to a terminal status (completed, suspended on
    /// their own budget, or cancelled), multiplexing slices over the
    /// worker pool. Returns when the ready queue is empty and no slice
    /// is in flight. Idempotent: draining with nothing queued is a
    /// no-op, and jobs resumed afterwards need another drain.
    pub fn drain(&self) {
        let nt = current_threads();
        let pending = lock_recover(&self.queue).ready.len();
        if pending == 0 {
            return;
        }
        let workers = self
            .cfg
            .workers
            .unwrap_or(usize::MAX)
            .min(nt)
            .min(pending)
            .max(1);
        let inner_width = (nt / workers).max(1);
        // Serve workers are long-lived job loops, not kernel slots, so
        // they stay scope-spawned (named for profilers) rather than
        // running on the kernel pool. They coexist with it by budget:
        // each worker's slices run under `with_thread_budget(inner_width)`,
        // so `workers × inner_width ≈ nt` bounds the combined demand —
        // a worker's kernel dispatch either stays inline (inner_width 1)
        // or occupies at most inner_width pool slots while the other
        // submitters park on the pool's idle queue.
        std::thread::scope(|s| {
            for i in 0..workers {
                std::thread::Builder::new()
                    .name(format!("symnmf-serve-{i}"))
                    .spawn_scoped(s, || self.worker(inner_width))
                    .expect("spawn serve worker");
            }
        });
    }

    fn worker(&self, inner_width: usize) {
        loop {
            let j = {
                let mut q = lock_recover(&self.queue);
                loop {
                    if let Some(key) = q.ready.pop() {
                        q.running += 1;
                        break key.job;
                    }
                    if q.running == 0 {
                        // nothing runnable and nothing in flight that
                        // could requeue — the drain is over
                        return;
                    }
                    q = self.work.wait(q).unwrap_or_else(PoisonError::into_inner);
                }
            };
            let requeue = self.run_slice(j, inner_width);
            {
                let mut q = lock_recover(&self.queue);
                q.running -= 1;
                if requeue {
                    let job = &self.jobs[j];
                    q.ready.push(ReadyKey {
                        priority: job.priority,
                        deadline_key: deadline_key(job.deadline_secs),
                        seq: self.seq.fetch_add(1, AtomicOrdering::Relaxed),
                        job: j,
                    });
                }
            }
            self.work.notify_all();
        }
    }

    /// Drive one slice of job `j`; returns whether the job goes back in
    /// the ready queue.
    fn run_slice(&self, j: usize, inner_width: usize) -> bool {
        let job = &self.jobs[j];
        let (resume_cp, steps_used, hook, gen) = {
            let mut core = lock_recover(&job.core);
            core.status = JobStatus::Running;
            (core.checkpoint.clone(), core.steps_used, core.cancel_hook, core.gen)
        };
        let start_clock = resume_cp.as_ref().map(|c| c.clock).unwrap_or(0.0);
        let start_iter = resume_cp.as_ref().map(|c| c.iter).unwrap_or(0);

        // slice budget = scheduler granularity ∩ the job's remaining budget
        let remaining_steps = job.max_steps.map(|n| n.saturating_sub(steps_used));
        let slice_steps = match (remaining_steps, self.cfg.slice_steps) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        let slice_deadline = match (job.deadline_secs, self.cfg.slice_secs) {
            (Some(d), Some(s)) => Some(d.min(start_clock + s)),
            (Some(d), None) => Some(d),
            (None, Some(s)) => Some(start_clock + s),
            (None, None) => None,
        };
        let ctrl = RunControl {
            deadline_secs: slice_deadline,
            max_steps: slice_steps,
            cancel: Some(job.cancel.clone()),
        };

        // Panic isolation: the engine (and any fail point inside it)
        // runs under catch_unwind, so one job's panic marks THAT job
        // Failed instead of tearing down the drain scope and every
        // other in-flight job with it. The catch sits inside the
        // thread-budget closure and inside the sink-mutex critical
        // section, so the unwind never crosses either — no budget
        // leakage, no poisoned sink lock. Operator pins (`OpPin`) are
        // owned inside the closure and release via Drop during the
        // unwind, exactly like the opcache's `BusyGuard`.
        let caught = {
            let mut sink_guard = lock_recover(&self.sinks[j]);
            let inner_sink = sink_guard.as_deref_mut().map(|s| s as &mut dyn TraceSink);
            with_thread_budget(inner_width, || {
                catch_unwind(AssertUnwindSafe(|| {
                    // deterministic crash injection for the recovery
                    // suite; no error path here, so `err` escalates too
                    if let Err(e) = failpoint::hit_scoped("slice", &job.name) {
                        panic!("{e}");
                    }
                    match hook {
                        // the one-shot mid-flight cancellation hook,
                        // counting iterations globally across slices
                        Some(n) if start_iter < n => {
                            let mut wrap = CancelAfterSink::resuming(
                                job.cancel.clone(),
                                n,
                                start_iter,
                                inner_sink,
                            );
                            (self.runners[j])(&ctrl, resume_cp.as_ref(), Some(&mut wrap))
                        }
                        Some(_) => {
                            // threshold already satisfied (including
                            // n = 0): cancel before the first step
                            job.cancel.cancel();
                            (self.runners[j])(&ctrl, resume_cp.as_ref(), inner_sink)
                        }
                        None => (self.runners[j])(&ctrl, resume_cp.as_ref(), inner_sink),
                    }
                }))
            })
        };
        let SliceRun { run, op_spilled } = match caught {
            Ok(slice) => slice,
            Err(payload) => {
                let msg = panic_message(payload);
                eprintln!("[serve] job {:?} panicked in a slice: {msg}", job.name);
                let mut core = lock_recover(&job.core);
                core.slices += 1;
                core.status = JobStatus::Failed;
                core.failure = Some(msg);
                // checkpoint/result/run_status keep their last good
                // values (the slice that panicked produced none)
                drop(core);
                job.done.notify_all();
                return false;
            }
        };

        // persist the new generation before publishing the state — a
        // crash after the store write at worst re-runs one slice. A
        // transiently failing save is retried a bounded, deterministic
        // number of times; exhausting the budget degrades persistence
        // (the solve continues in memory) instead of killing the job.
        let mut gen_now = gen;
        let mut save_degraded = false;
        if let Some(store) = &self.cfg.store {
            gen_now = gen + 1;
            let saved = retry::with_retry(retry::DEFAULT_ATTEMPTS, |_| {
                store.save(&job.name, gen_now, &run.checkpoint, self.cfg.slim_checkpoints)
            });
            if let Err(e) = saved {
                // telemetry/persistence loss must not kill the solve
                eprintln!(
                    "[serve] checkpoint save failed for {:?} after {} attempts: {e}; \
                     continuing in memory (persistence degraded)",
                    job.name,
                    retry::DEFAULT_ATTEMPTS
                );
                gen_now = gen;
                save_degraded = true;
            }
        }

        let st = run.checkpoint.status;
        let mut core = lock_recover(&job.core);
        core.slices += 1;
        if save_degraded {
            core.persist_degraded = true;
        }
        if op_spilled == Some(true) {
            core.spilled_slices += 1;
        }
        core.steps_used += run.checkpoint.iter - start_iter;
        core.gen = gen_now;
        core.run_status = Some(st);
        if let Some(n) = hook {
            if st == RunStatus::Cancelled && run.checkpoint.iter >= n {
                core.cancel_hook = None; // fired — disarm for resumption
            }
        }
        let requeue = match st {
            RunStatus::Completed => {
                core.status = JobStatus::Completed;
                false
            }
            RunStatus::Cancelled => {
                core.status = JobStatus::Cancelled;
                false
            }
            RunStatus::Deadline => {
                // the engine's deadline fired: the job's own budget, or
                // merely this slice's?
                if job.deadline_secs.is_some_and(|d| run.checkpoint.clock >= d) {
                    core.status = JobStatus::Suspended;
                    false
                } else {
                    core.status = JobStatus::Queued;
                    true
                }
            }
            RunStatus::Paused => {
                if job.max_steps.is_some_and(|n| core.steps_used >= n) {
                    core.status = JobStatus::Suspended;
                    false
                } else {
                    core.status = JobStatus::Queued;
                    true
                }
            }
        };
        core.checkpoint = Some(run.checkpoint);
        core.result = Some(run.result);
        drop(core);
        if !requeue {
            job.done.notify_all();
        }
        requeue
    }
}

/// Render a caught panic payload for [`JobOutcome::failure`]. Panics
/// raised by `panic!("...")` carry `&str` or `String`; anything else
/// (a `panic_any` payload) gets a placeholder rather than being lost.
///
/// [`JobOutcome::failure`]: crate::serve::job::JobOutcome
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    match payload.downcast_ref::<&str>() {
        Some(s) => (*s).to_string(),
        None => match payload.downcast_ref::<String>() {
            Some(s) => s.clone(),
            None => "non-string panic payload".to_string(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::Method;
    use crate::linalg::{blas, DenseMat};
    use crate::nls::UpdateRule;
    use crate::symnmf::options::SymNmfOptions;
    use crate::util::rng::Pcg64;

    fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        x.symmetrize();
        x
    }

    fn opts(k: usize, max_iters: usize, seed: u64) -> SymNmfOptions {
        let mut o = SymNmfOptions::new(k).with_seed(seed);
        o.max_iters = max_iters;
        o
    }

    #[test]
    fn single_job_drains_to_completion() {
        let x = planted(30, 3, 1);
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let h = sched
            .submit(
                &x,
                JobSpec::new("solo", Method::Exact(UpdateRule::Hals), opts(3, 6, 2)),
            )
            .expect("submit");
        assert_eq!(h.poll(), JobStatus::Queued);
        sched.drain();
        let o = h.await_result();
        assert_eq!(o.status, JobStatus::Completed);
        assert_eq!(o.run_status, Some(RunStatus::Completed));
        assert_eq!(o.slices, 1, "no slicing configured: one slice runs it all");
        assert!(o.expect_result().iters() >= 1);
        assert!(o.expect_result().h.is_nonneg());
        assert!(o.failure.is_none() && !o.persist_degraded);
    }

    /// Slicing at slice_steps=2 must reproduce the one-shot run bitwise
    /// and count its slices.
    #[test]
    fn sliced_run_matches_oneshot_bitwise() {
        let x = planted(30, 3, 5);
        let o = opts(3, 7, 4);
        let method = Method::Exact(UpdateRule::Bpp);
        let full = method
            .run_controlled(&x, &o, &RunControl::unlimited(), None)
            .result;
        let mut sched = Scheduler::new(SchedulerConfig {
            slice_steps: Some(2),
            ..SchedulerConfig::default()
        });
        let h = sched.submit(&x, JobSpec::new("sliced", method, o)).unwrap();
        sched.drain();
        let got = h.await_result();
        assert_eq!(got.status, JobStatus::Completed);
        assert!(got.slices >= 3, "7 iters at 2/slice needs >= 3 slices");
        let got_res = got.expect_result();
        assert_eq!(got_res.iters(), full.iters());
        for (a, b) in full.h.data().iter().zip(got_res.h.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "sliced H != one-shot H");
        }
        for (ra, rb) in full.records.iter().zip(&got_res.records) {
            assert_eq!(ra.residual.to_bits(), rb.residual.to_bits());
        }
    }

    /// A job-level step budget suspends (not completes) with a resumable
    /// checkpoint; resume + drain finishes it bitwise.
    #[test]
    fn job_budget_suspends_then_resumes() {
        let x = planted(28, 2, 9);
        let o = opts(2, 6, 3);
        let method = Method::Exact(UpdateRule::Hals);
        let full = method
            .run_controlled(&x, &o, &RunControl::unlimited(), None)
            .result;
        let mut sched = Scheduler::new(SchedulerConfig {
            slice_steps: Some(1),
            ..SchedulerConfig::default()
        });
        let h = sched
            .submit(&x, JobSpec::new("budgeted", method, o).with_max_steps(2))
            .unwrap();
        sched.drain();
        let o1 = h.await_result();
        assert_eq!(o1.status, JobStatus::Suspended);
        assert_eq!(o1.steps, 2, "step budget must stop after 2 steps");
        assert_eq!(o1.slices, 2, "1 step per slice");
        // resume opens a fresh 2-step epoch; the run needs 6 iterations,
        // so two more epochs finish it
        sched.resume(&h).expect("resume");
        sched.drain();
        let o2 = h.await_result();
        assert_eq!(o2.status, JobStatus::Suspended);
        assert_eq!(o2.steps, 2, "fresh epoch grants max_steps again");
        assert_eq!(o2.expect_checkpoint().iter, 4, "4 iterations done in total");
        sched.resume(&h).expect("resume");
        sched.drain();
        let o3 = h.await_result();
        assert_eq!(o3.status, JobStatus::Completed, "6-iter run done in 3 epochs");
        for (a, b) in full.h.data().iter().zip(o3.expect_result().h.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// The ready-queue ordering contract: priority first (higher wins),
    /// then earliest deadline, then FIFO submission order.
    #[test]
    fn ready_queue_orders_by_priority_deadline_fifo() {
        let mut heap = BinaryHeap::new();
        let mut push = |priority, deadline, seq, job| {
            heap.push(ReadyKey { priority, deadline_key: deadline_key(deadline), seq, job })
        };
        push(0, None, 0, 0); // low priority, no deadline, submitted first
        push(2, Some(9.0), 1, 1); // mid priority, late deadline
        push(2, Some(1.0), 2, 2); // mid priority, early deadline
        push(5, None, 3, 3); // high priority
        push(2, Some(1.0), 4, 4); // ties job 2 → FIFO after it
        let order: Vec<usize> = std::iter::from_fn(|| heap.pop().map(|k| k.job)).collect();
        assert_eq!(order, vec![3, 2, 4, 1, 0]);
        // deadline_key is monotone where it matters
        assert!(deadline_key(Some(0.5)) < deadline_key(Some(2.0)));
        assert!(deadline_key(Some(1e9)) < deadline_key(None));
    }

    /// `cancel_after_iters = 0` means "before the first step": the job
    /// cancels with the initial iterate, and (the hook being one-shot)
    /// resumes to completion.
    #[test]
    fn cancel_after_zero_fires_before_first_step() {
        let x = planted(24, 2, 15);
        let o = opts(2, 5, 8);
        let method = Method::Exact(UpdateRule::Bpp);
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let h = sched
            .submit(&x, JobSpec::new("cancel0", method, o).with_cancel_after(0))
            .unwrap();
        sched.drain();
        let o1 = h.await_result();
        assert_eq!(o1.status, JobStatus::Cancelled);
        assert_eq!(o1.expect_result().iters(), 0, "threshold 0 is satisfied at start");
        sched.resume(&h).expect("resume");
        sched.drain();
        assert_eq!(h.await_result().status, JobStatus::Completed);
    }

    /// Cancelling a queued job before the drain yields the initial
    /// iterate with a valid, resumable checkpoint.
    #[test]
    fn cancel_before_first_step_then_resume() {
        let x = planted(26, 2, 11);
        let o = opts(2, 5, 6);
        let method = Method::Exact(UpdateRule::Hals);
        let full = method
            .run_controlled(&x, &o, &RunControl::unlimited(), None)
            .result;
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let h = sched.submit(&x, JobSpec::new("early", method, o)).unwrap();
        h.cancel();
        sched.drain();
        let o1 = h.await_result();
        assert_eq!(o1.status, JobStatus::Cancelled);
        assert_eq!(o1.run_status, Some(RunStatus::Cancelled));
        assert_eq!(o1.expect_result().iters(), 0, "no step may run");
        assert_eq!(o1.expect_checkpoint().iter, 0);
        sched.resume(&h).expect("resume");
        sched.drain();
        let o2 = h.await_result();
        assert_eq!(o2.status, JobStatus::Completed);
        for (a, b) in full.h.data().iter().zip(o2.expect_result().h.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "resumed-from-0 H != full H");
        }
    }

    /// Satellite: distinct raw ids that sanitize to the same store
    /// filename are rejected at submission — they would share (and GC)
    /// one checkpoint lineage.
    #[test]
    fn sanitized_id_collision_is_rejected_at_submit() {
        let x = planted(20, 2, 21);
        let method = Method::Exact(UpdateRule::Hals);
        let mut sched = Scheduler::new(SchedulerConfig::default());
        sched.submit(&x, JobSpec::new("a.b", method, opts(2, 3, 1))).expect("first");
        let err = sched
            .submit(&x, JobSpec::new("a b", method, opts(2, 3, 2)))
            .expect_err("\"a b\" sanitizes to \"a_b\" — same as \"a.b\"");
        assert!(err.contains("collides") && err.contains("a_b"), "{err}");
        // the exact same raw id is NOT a sanitization collision
        sched.submit(&x, JobSpec::new("a.b", method, opts(2, 3, 3))).expect("same raw id");
        // a clean distinct id still goes through
        sched.submit(&x, JobSpec::new("c", method, opts(2, 3, 4))).expect("distinct");
    }

    /// Tentpole: a panicking slice marks the job Failed with the panic
    /// message, without tearing down the drain; a failed job is
    /// resumable from its last good checkpoint and then matches the
    /// uninterrupted run bitwise.
    #[test]
    fn panicking_slice_fails_the_job_and_resume_recovers_bitwise() {
        use crate::util::failpoint;
        let x = planted(26, 2, 33);
        let o = opts(2, 6, 5);
        let method = Method::Exact(UpdateRule::Hals);
        let full = method
            .run_controlled(&x, &o, &RunControl::unlimited(), None)
            .result;
        let _fp = failpoint::scoped("slice:panicky=panic@2");
        let mut sched = Scheduler::new(SchedulerConfig {
            slice_steps: Some(2),
            ..SchedulerConfig::default()
        });
        let h = sched.submit(&x, JobSpec::new("panicky", method, o)).unwrap();
        sched.drain();
        let o1 = h.await_result();
        assert_eq!(o1.status, JobStatus::Failed);
        let msg = o1.failure.as_deref().expect("failure message");
        assert!(msg.contains("injected panic"), "{msg}");
        assert_eq!(o1.slices, 2, "slice 1 good, slice 2 panicked");
        // the last good checkpoint survives the panic
        assert_eq!(o1.expect_checkpoint().iter, 2);
        // resume restarts from it; the @2 trigger is spent, so the job
        // completes — bitwise equal to the uninterrupted run
        sched.resume(&h).expect("failed jobs are resumable");
        sched.drain();
        let o2 = h.await_result();
        assert_eq!(o2.status, JobStatus::Completed);
        assert!(o2.failure.is_none(), "resume clears the failure");
        for (a, b) in full.h.data().iter().zip(o2.expect_result().h.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "resumed-after-panic H != full H");
        }
    }

    /// A panic on the very FIRST slice leaves no result/checkpoint —
    /// the outcome must still be deliverable (await_result returns, no
    /// hang) with all three payload fields None.
    #[test]
    fn first_slice_panic_yields_an_empty_failed_outcome() {
        use crate::util::failpoint;
        let x = planted(20, 2, 41);
        let method = Method::Exact(UpdateRule::Bpp);
        let _fp = failpoint::scoped("slice:doomed=panic@1");
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let h = sched.submit(&x, JobSpec::new("doomed", method, opts(2, 4, 2))).unwrap();
        sched.drain();
        let o = h.await_result();
        assert_eq!(o.status, JobStatus::Failed);
        assert!(o.result.is_none() && o.checkpoint.is_none() && o.run_status.is_none());
        assert_eq!(o.slices, 1);
        // cold resume: runs from scratch to completion
        sched.resume(&h).expect("resume");
        sched.drain();
        assert_eq!(h.await_result().status, JobStatus::Completed);
    }

    /// Reentrancy: serve workers are plain named threads whose slices
    /// dispatch kernels to the shared pool — several of them
    /// concurrently, each inside `with_thread_budget`. A naive pool
    /// (one that let a busy slot re-submit, or that assumed a single
    /// submitting thread) would deadlock here; the real one serializes
    /// submissions and runs nested dispatch inline. The fleet must
    /// complete under both backends with bitwise-identical factors.
    #[test]
    fn kernel_dispatch_inside_pooled_serve_workers_is_backend_invariant() {
        use crate::util::pool::{self, PoolBackend};
        let x = planted(40, 3, 11);
        let run = |backend| {
            let _g = pool::override_backend(backend);
            let mut sched = Scheduler::new(SchedulerConfig {
                slice_steps: Some(2),
                workers: Some(2),
                ..SchedulerConfig::default()
            });
            let handles: Vec<JobHandle> = (0..3)
                .map(|i| {
                    sched
                        .submit(
                            &x,
                            JobSpec::new(
                                format!("reentrant-{i}"),
                                Method::Exact(UpdateRule::Hals),
                                opts(3, 6, 7 + i as u64),
                            ),
                        )
                        .expect("submit")
                })
                .collect();
            sched.drain();
            handles
                .iter()
                .map(|h| {
                    let o = h.await_result();
                    assert_eq!(o.status, JobStatus::Completed, "{}", backend.as_str());
                    o.expect_result().h.clone()
                })
                .collect::<Vec<DenseMat>>()
        };
        let pooled = run(PoolBackend::Pooled);
        let scoped = run(PoolBackend::Scoped);
        for (job, (hp, hs)) in pooled.iter().zip(&scoped).enumerate() {
            for (a, b) in hp.data().iter().zip(hs.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "job {job}: pooled H != scoped H");
            }
        }
    }

    /// Tentpole: a persistently failing checkpoint save exhausts the
    /// bounded retry and degrades persistence — the solve continues in
    /// memory and the outcome surfaces `persist_degraded`; a transient
    /// (single-shot) failure is healed by the retry and does NOT degrade.
    #[test]
    fn save_failures_retry_then_degrade_without_killing_the_job() {
        use crate::util::failpoint;
        let x = planted(24, 2, 51);
        let method = Method::Exact(UpdateRule::Hals);
        let dir = std::env::temp_dir()
            .join(format!("symnmf-degraded-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = JobStore::open(&dir).expect("open store");

        // persistent failure: every save attempt of job "sticky" errors
        let _fp = failpoint::scoped("ckpt_save:sticky=err");
        let mut sched = Scheduler::new(SchedulerConfig {
            slice_steps: Some(2),
            store: Some(store.clone()),
            ..SchedulerConfig::default()
        });
        let h = sched.submit(&x, JobSpec::new("sticky", method, opts(2, 4, 3))).unwrap();
        sched.drain();
        let o = h.await_result();
        assert_eq!(o.status, JobStatus::Completed, "the solve itself must survive");
        assert!(o.persist_degraded, "every save failed: degraded");
        assert!(store.generations("sticky").unwrap().is_empty(), "nothing persisted");
        // each slice burned the full retry budget deterministically
        assert_eq!(
            failpoint::hits("ckpt_save:sticky") as usize,
            o.slices * crate::util::retry::DEFAULT_ATTEMPTS
        );
        drop(_fp);

        // transient failure: only the first attempt errs; retry heals it
        let _fp = failpoint::scoped("ckpt_save:transient=err_once");
        let mut sched = Scheduler::new(SchedulerConfig {
            slice_steps: Some(2),
            store: Some(store.clone()),
            ..SchedulerConfig::default()
        });
        let h = sched.submit(&x, JobSpec::new("transient", method, opts(2, 4, 3))).unwrap();
        sched.drain();
        let o = h.await_result();
        assert_eq!(o.status, JobStatus::Completed);
        assert!(!o.persist_degraded, "a healed transient must not degrade");
        assert!(!store.generations("transient").unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
