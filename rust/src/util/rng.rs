//! Deterministic PRNG substrate: PCG64 (XSL-RR 128/64) plus the sampling
//! primitives the paper's algorithms need — uniform/gaussian variates
//! (Box–Muller), weighted sampling with replacement for leverage-score
//! sketching (Eq. 2.11), and Fisher–Yates shuffling.
//!
//! `rand`/`rand_distr` are unavailable offline; this implementation is
//! self-contained and reproducible across runs given a seed.

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// Complete serializable PCG64 state — everything needed to resume a
/// stream mid-draw, including the cached Box–Muller spare (dropping it
/// would shift every subsequent gaussian by one variate). Produced by
/// [`Pcg64::state`] and consumed by [`Pcg64::from_state`]; solver
/// checkpoints embed it so a resumed run replays the exact draw sequence
/// of the uninterrupted one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub state: u128,
    pub inc: u128,
    pub gauss_spare: Option<f64>,
}

impl Pcg64 {
    /// Seed with an arbitrary 64-bit value; the stream id is fixed.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (0xda3e_39cb_94b9_5bdb_u128 << 1) | 1,
            gauss_spare: None,
        };
        rng.state = rng
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(rng.inc)
            .wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-trial seeding).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::seed_from_u64(self.next_u64())
    }

    /// Snapshot the full generator state (checkpoint support).
    pub fn state(&self) -> RngState {
        RngState { state: self.state, inc: self.inc, gauss_spare: self.gauss_spare }
    }

    /// Rebuild a generator from a [`RngState`] snapshot: the restored
    /// stream continues bitwise where the snapshotted one left off.
    pub fn from_state(st: &RngState) -> Pcg64 {
        Pcg64 { state: st.state, inc: st.inc, gauss_spare: st.gauss_spare }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire-style rejection for unbiasedness.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let prod = (x as u128) * (n as u128);
                ((prod >> 64) as u64, prod as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal variate via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gaussian()).collect()
    }

    /// Vector of uniforms in [0,1).
    pub fn uniform_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.uniform()).collect()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Alias-method table for O(1) weighted sampling with replacement.
///
/// Leverage-score sampling (paper Eq. 2.11) draws `s` i.i.d. rows from the
/// distribution p_i = l_i(A)/k; Walker's alias method makes each draw O(1)
/// after O(m) setup, which matters because the sampler runs every iteration
/// of LvS-SymNMF.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
    /// Σ weights, cached at construction: the normalization every
    /// sampling probability divides by. Callers that need p_i = w_i/Σw
    /// (the leverage-score rescale factors of Eq. 2.11) read it from
    /// here instead of re-summing the weight vector per call site.
    total: f64,
    /// Worklist scratch reused by [`AliasTable::rebuild`] — grow-only,
    /// always drained back to empty, so a rebuilt table of the same (or
    /// smaller) size allocates nothing.
    small: Vec<usize>,
    large: Vec<usize>,
}

impl AliasTable {
    /// Build from (unnormalized) nonnegative weights. Panics if all zero.
    pub fn new(weights: &[f64]) -> Self {
        let mut t = AliasTable {
            prob: Vec::new(),
            alias: Vec::new(),
            total: 0.0,
            small: Vec::new(),
            large: Vec::new(),
        };
        t.rebuild(weights);
        t
    }

    /// Buffer-less placeholder for persistent workspaces: holds no
    /// allocation until the first [`AliasTable::rebuild`]. Drawing from
    /// an empty table panics (zero-length `below`), matching the
    /// fail-loud policy — a sampler must rebuild before sampling.
    pub fn empty() -> Self {
        AliasTable {
            prob: Vec::new(),
            alias: Vec::new(),
            total: 0.0,
            small: Vec::new(),
            large: Vec::new(),
        }
    }

    /// Data pointers of the internal buffers, for allocation-stability
    /// assertions in tests (the zero-allocation sampler protocol).
    pub fn buffer_ptrs(&self) -> [*const f64; 2] {
        [self.prob.as_ptr(), self.alias.as_ptr() as *const f64]
    }

    /// Rebuild the table in place for a new weight vector, reusing every
    /// buffer (the per-iteration path of the LvS sampler). Arithmetic and
    /// worklist order are identical to [`AliasTable::new`], so a rebuilt
    /// table is bitwise-indistinguishable from a fresh one — same `prob`,
    /// same `alias`, same draw sequence for the same RNG state.
    pub fn rebuild(&mut self, weights: &[f64]) {
        let n = weights.len();
        assert!(n > 0);
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "alias table needs positive total weight");
        self.total = total;
        self.prob.clear();
        self.prob.extend(weights.iter().map(|w| w * n as f64 / total));
        self.alias.clear();
        self.alias.resize(n, 0);
        let prob = &mut self.prob;
        let alias = &mut self.alias;
        let small = &mut self.small;
        let large = &mut self.large;
        small.clear();
        large.clear();
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            alias[s] = l;
            prob[l] = (prob[l] + prob[s]) - 1.0;
            if prob[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Residual numerical leftovers get probability 1.
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
        }
        small.clear();
        large.clear();
    }

    /// Σ of the construction weights (the row-probability normalizer),
    /// summed in the same left-to-right order a caller-side
    /// `weights.iter().sum()` would use — so substituting this cached
    /// value for a re-sum is bitwise-neutral.
    #[inline]
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Draw one index.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let i = rng.below(self.prob.len());
        if rng.uniform() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    /// Draw `s` indices with replacement.
    pub fn sample_many(&self, rng: &mut Pcg64, s: usize) -> Vec<usize> {
        (0..s).map(|_| self.sample(rng)).collect()
    }

    /// Draw `s` indices with replacement into a reused buffer — the
    /// allocation-free form of [`AliasTable::sample_many`] (identical
    /// draw sequence: each draw consumes exactly one `below` and one
    /// `uniform`).
    pub fn sample_many_into(&self, rng: &mut Pcg64, s: usize, out: &mut Vec<usize>) {
        out.clear();
        out.reserve(s);
        for _ in 0..s {
            out.push(self.sample(rng));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    /// A state snapshot taken mid-stream (including a live Box–Muller
    /// spare) resumes the exact draw sequence.
    #[test]
    fn state_roundtrip_resumes_stream_bitwise() {
        let mut a = Pcg64::seed_from_u64(17);
        // put the generator in a non-trivial spot: odd number of
        // gaussians leaves a cached spare
        for _ in 0..3 {
            a.gaussian();
        }
        a.uniform();
        let snap = a.state();
        assert!(snap.gauss_spare.is_some(), "odd gaussian count caches a spare");
        let mut b = Pcg64::from_state(&snap);
        for _ in 0..16 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.uniform().to_bits(), b.uniform().to_bits());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::seed_from_u64(7);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_smoke() {
        let mut rng = Pcg64::seed_from_u64(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seed_from_u64(11);
        let n = 50_000;
        let xs = rng.gaussian_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    /// The cached normalizer equals the caller-side sum bitwise (the
    /// leverage sampler substitutes it for a re-sum of the weights).
    #[test]
    fn alias_table_total_matches_weight_sum() {
        let weights = [0.1, 2.7, 0.0, 5.5, 1.3];
        let table = AliasTable::new(&weights);
        let manual: f64 = weights.iter().sum();
        assert_eq!(table.total().to_bits(), manual.to_bits());
    }

    #[test]
    fn alias_table_matches_weights() {
        let weights = [1.0, 3.0, 0.5, 5.5];
        let table = AliasTable::new(&weights);
        let mut rng = Pcg64::seed_from_u64(5);
        let mut counts = [0usize; 4];
        let n = 100_000;
        for _ in 0..n {
            counts[table.sample(&mut rng)] += 1;
        }
        let total: f64 = weights.iter().sum();
        for (i, &w) in weights.iter().enumerate() {
            let expect = w / total;
            let got = counts[i] as f64 / n as f64;
            assert!((got - expect).abs() < 0.01, "i={i} got={got} expect={expect}");
        }
    }

    /// Rebuilding a warm table produces the same table and draw stream
    /// as a fresh build — rebuild is bitwise-transparent to samplers.
    #[test]
    fn alias_rebuild_matches_fresh_build_bitwise() {
        let first = [4.0, 0.25, 1.5, 0.0, 2.25, 9.0, 0.5];
        let second = [0.75, 3.0, 0.125]; // shrink: buffers must re-size down
        let third = [1.0; 12]; // grow past both
        let mut warm = AliasTable::new(&first);
        for weights in [&second[..], &third[..], &first[..]] {
            warm.rebuild(weights);
            let fresh = AliasTable::new(weights);
            assert_eq!(warm.total().to_bits(), fresh.total().to_bits());
            assert_eq!(warm.alias, fresh.alias);
            assert_eq!(warm.prob.len(), fresh.prob.len());
            for (a, b) in warm.prob.iter().zip(&fresh.prob) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            let mut ra = Pcg64::seed_from_u64(23);
            let mut rb = Pcg64::seed_from_u64(23);
            for _ in 0..64 {
                assert_eq!(warm.sample(&mut ra), fresh.sample(&mut rb));
            }
        }
    }

    /// The into-form draws the identical index sequence and leaves the
    /// RNG in the identical state as the allocating form.
    #[test]
    fn sample_many_into_matches_sample_many() {
        let table = AliasTable::new(&[1.0, 3.0, 0.5, 5.5, 2.0]);
        let mut ra = Pcg64::seed_from_u64(31);
        let mut rb = Pcg64::seed_from_u64(31);
        let alloc = table.sample_many(&mut ra, 97);
        let mut reused = vec![123usize; 4]; // stale contents must be cleared
        table.sample_many_into(&mut rb, 97, &mut reused);
        assert_eq!(alloc, reused);
        assert_eq!(ra.state(), rb.state(), "draw counts must match");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(9);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
