//! RandNLA toolbox (paper §2.2): the randomized range finder and its
//! adaptive variant, the approximate truncated EVD of a symmetric matrix,
//! and leverage-score / hybrid sampling matrices for sketched least
//! squares.

pub mod evd;
pub mod leverage;
pub mod op;
pub mod rrf;

pub use evd::ApxEvd;
pub use leverage::SampleMatrix;
pub use op::SymOp;
