//! Filesystem-backed checkpoint persistence, keyed by job id.
//!
//! Layout: one JSON file per (job, generation) under the store
//! directory — `<id>.g<gen 8-digit>.ckpt.json`, written atomically
//! (temp file + rename) so a reader never observes a torn checkpoint.
//! Every save bumps the generation and then garbage-collects superseded
//! generations beyond the configured retention (default: keep only the
//! newest), because full checkpoints embed the factors — and, in the
//! full (version 1) encoding, the whole residual history — at 16 hex
//! chars per f64: without GC a long-running job would accumulate
//! `O(generations · m·k)` of dead bytes. Factor-only *slim* (version 2)
//! checkpoints drop the history for fleets that stream it to a
//! [`crate::symnmf::trace`] sink instead.
//!
//! Job ids are sanitized into a conservative filename alphabet
//! ([`sanitize_id`]) so an id arriving from a network spec can never
//! escape the store directory.

use crate::symnmf::engine::Checkpoint;
use std::path::{Path, PathBuf};

/// Map an arbitrary job id onto the store's filename alphabet:
/// `[A-Za-z0-9_-]`, everything else replaced by `_`, empty ids become
/// `"job"`. Distinct ids can collide after sanitization; submitters that
/// care (the CLI does) should use clean ids.
pub fn sanitize_id(id: &str) -> String {
    let s: String = id
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '_'
            }
        })
        .collect();
    if s.is_empty() {
        "job".to_string()
    } else {
        s
    }
}

/// A directory of per-job checkpoint generations.
#[derive(Clone, Debug)]
pub struct JobStore {
    dir: PathBuf,
    keep: usize,
}

impl JobStore {
    /// Open (creating if needed) a store rooted at `dir`, retaining one
    /// generation per job.
    pub fn open(dir: &Path) -> Result<JobStore, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("create store dir {dir:?}: {e}"))?;
        Ok(JobStore { dir: dir.to_path_buf(), keep: 1 })
    }

    /// Retain the newest `keep` generations per job (floored at 1).
    pub fn with_keep(mut self, keep: usize) -> JobStore {
        self.keep = keep.max(1);
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_name(id: &str, gen: u64) -> String {
        format!("{}.g{gen:08}.ckpt.json", sanitize_id(id))
    }

    /// Path a given (job, generation) lives at.
    pub fn path_for(&self, id: &str, gen: u64) -> PathBuf {
        self.dir.join(JobStore::file_name(id, gen))
    }

    /// Persist one checkpoint generation (atomic: temp + rename), then
    /// GC generations beyond the retention. `slim` selects the
    /// factor-only version-2 encoding.
    pub fn save(
        &self,
        id: &str,
        gen: u64,
        cp: &Checkpoint,
        slim: bool,
    ) -> Result<PathBuf, String> {
        let path = self.path_for(id, gen);
        let tmp = path.with_extension("json.tmp");
        let text = if slim { cp.serialize_slim() } else { cp.serialize() };
        std::fs::write(&tmp, text).map_err(|e| format!("write {tmp:?}: {e}"))?;
        std::fs::rename(&tmp, &path).map_err(|e| format!("rename to {path:?}: {e}"))?;
        self.gc(id)?;
        Ok(path)
    }

    /// Generations currently on disk for a job, ascending.
    pub fn generations(&self, id: &str) -> Result<Vec<u64>, String> {
        let prefix = format!("{}.g", sanitize_id(id));
        let suffix = ".ckpt.json";
        let mut gens = Vec::new();
        let entries = std::fs::read_dir(&self.dir)
            .map_err(|e| format!("read store dir {:?}: {e}", self.dir))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read store dir entry: {e}"))?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(rest) = name.strip_prefix(&prefix) else { continue };
            let Some(digits) = rest.strip_suffix(suffix) else { continue };
            if let Ok(g) = digits.parse::<u64>() {
                gens.push(g);
            }
        }
        gens.sort_unstable();
        Ok(gens)
    }

    /// Load the newest persisted generation, if any.
    pub fn load_latest(&self, id: &str) -> Result<Option<(u64, Checkpoint)>, String> {
        let Some(&gen) = self.generations(id)?.last() else {
            return Ok(None);
        };
        let path = self.path_for(id, gen);
        let text =
            std::fs::read_to_string(&path).map_err(|e| format!("read {path:?}: {e}"))?;
        let cp = Checkpoint::parse(&text).map_err(|e| format!("parse {path:?}: {e}"))?;
        Ok(Some((gen, cp)))
    }

    /// Remove superseded generations beyond the retention; returns how
    /// many files were deleted.
    pub fn gc(&self, id: &str) -> Result<usize, String> {
        let gens = self.generations(id)?;
        if gens.len() <= self.keep {
            return Ok(0);
        }
        let doomed = &gens[..gens.len() - self.keep];
        let mut removed = 0;
        for &g in doomed {
            let path = self.path_for(id, g);
            std::fs::remove_file(&path).map_err(|e| format!("remove {path:?}: {e}"))?;
            removed += 1;
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMat;
    use crate::symnmf::engine::{EngineState, RunStatus};
    use crate::symnmf::metrics::IterRecord;
    use crate::util::rng::Pcg64;

    fn tmp_store(name: &str) -> JobStore {
        let dir = std::env::temp_dir()
            .join(format!("symnmf-store-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        JobStore::open(&dir).expect("open store")
    }

    fn sample_cp(seed: u64, iters: usize) -> Checkpoint {
        let mut rng = Pcg64::seed_from_u64(seed);
        Checkpoint {
            status: RunStatus::Paused,
            stage: 0,
            stage_iter: iters,
            iter: iters,
            clock: 0.5,
            stop_best: 0.33,
            stop_stall: 1,
            state: EngineState {
                h: DenseMat::gaussian(6, 2, &mut rng),
                w: Some(DenseMat::gaussian(6, 2, &mut rng)),
                rng: None,
            },
            records: (0..iters)
                .map(|i| IterRecord {
                    iter: i,
                    time_secs: 0.1 * (i + 1) as f64,
                    residual: 1.0 / (i + 2) as f64,
                    proj_grad: None,
                    phase_secs: (0.0, 0.0, 0.0),
                    hybrid_stats: None,
                })
                .collect(),
            isa: Some("scalar".to_string()),
        }
    }

    #[test]
    fn sanitizes_hostile_ids() {
        assert_eq!(sanitize_id("trial-3"), "trial-3");
        assert_eq!(sanitize_id("../../etc/passwd"), "______etc_passwd");
        assert_eq!(sanitize_id("a b/c"), "a_b_c");
        assert_eq!(sanitize_id(""), "job");
    }

    #[test]
    fn save_load_roundtrips_and_gcs_superseded_generations() {
        let store = tmp_store("gc").with_keep(2);
        let cp3 = sample_cp(3, 3);
        for (gen, iters) in [(1u64, 1usize), (2, 2), (3, 3)] {
            store
                .save("job-a", gen, &sample_cp(gen, iters), false)
                .expect("save");
        }
        // keep=2: generation 1 must be gone, 2 and 3 retained
        assert_eq!(store.generations("job-a").unwrap(), vec![2, 3]);
        let (gen, back) = store.load_latest("job-a").unwrap().expect("latest");
        assert_eq!(gen, 3);
        assert_eq!(back.iter, 3);
        assert_eq!(back.records.len(), 3);
        for (a, b) in cp3.state.h.data().iter().zip(back.state.h.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "factors must round-trip bitwise");
        }
        // unknown job: no generations, no latest
        assert!(store.generations("ghost").unwrap().is_empty());
        assert!(store.load_latest("ghost").unwrap().is_none());
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn default_retention_keeps_only_newest() {
        let store = tmp_store("keep1");
        for gen in 1..=4u64 {
            store.save("j", gen, &sample_cp(gen, 1), false).expect("save");
        }
        assert_eq!(store.generations("j").unwrap(), vec![4]);
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn slim_saves_parse_without_records() {
        let store = tmp_store("slim");
        let cp = sample_cp(9, 4);
        let path = store.save("s", 1, &cp, true).expect("save slim");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"version\":2"));
        let (_, back) = store.load_latest("s").unwrap().expect("latest");
        assert!(back.records.is_empty(), "slim checkpoints drop the history");
        assert_eq!(back.iter, 4, "but keep the global iteration counter");
        // slim is strictly smaller than the full encoding of the same state
        assert!(text.len() < cp.serialize().len());
        std::fs::remove_dir_all(store.dir()).ok();
    }
}
