//! Request-scoped serving over the resumable solver engines: job store,
//! cancellation-aware scheduler, and streaming traces.
//!
//! The paper's premise is that randomized SymNMF is fast enough to run
//! as a *routine service* on large graphs; PR 4's engine contract
//! (`symnmf::engine`) made every method a step-driven, deadline-aware,
//! checkpointable solve. This module is the serving layer on top: it
//! accepts `(X ref, Method, SymNmfOptions, deadline, priority)` jobs and
//! drives them through `Method::run_controlled` in **budgeted slices**.
//!
//! ## The slice / checkpoint / resume contract
//!
//! ```text
//!   submit ──► Queued ──► Running ──(slice budget hit)──► Queued ...
//!                │            │
//!                │            ├─(stages converged)──► Completed
//!                │            ├─(job budget hit)────► Suspended ─resume─► Queued
//!                │            └─(cancel token)──────► Cancelled ─resume─► Queued
//!                └─ cancel() just trips the token; the engine aborts
//!                   at the next step boundary, checkpoint intact
//! ```
//!
//! * A **slice** is one `run_controlled` call under a [`RunControl`]
//!   that intersects the scheduler's granularity
//!   ([`SchedulerConfig::slice_steps`] / [`SchedulerConfig::slice_secs`])
//!   with the job's own remaining deadline/step budget, plus the job's
//!   [`CancelToken`]. The engine's guarantee — interruption only ever
//!   *cuts the iteration sequence short, never perturbs the iterations
//!   that run* — lifts to the job level: a job driven in any number of
//!   slices, including a cancel and a resume in the middle, produces
//!   **bitwise-identical H, W, and residual history** to the
//!   uninterrupted `Method::run` call (pinned per method, at k ∈ {2, 7},
//!   by `tests/integration_serve.rs`).
//! * Every slice ends in a [`Checkpoint`]; with a [`JobStore`]
//!   configured it is persisted as a new *generation* keyed by job name
//!   (atomic temp+rename write), and superseded generations are
//!   garbage-collected. Factor-only **slim** checkpoints
//!   (`slim_checkpoints`, wire version 2) drop the residual history for
//!   fleets that stream it through trace sinks instead. Both forms
//!   record the kernel **ISA** the producing process dispatched
//!   (`isa` field): resuming a persisted job on a host that dispatches
//!   a different kernel tier fails loudly instead of silently breaking
//!   the bitwise contract — force `SYMNMF_KERNEL=<recorded isa>` on the
//!   new host (if it supports that tier) to migrate a job.
//! * A per-job streaming trace sink ([`crate::symnmf::trace`]) lives
//!   across slices (and appends when a job is submitted with a resume
//!   checkpoint) and flushes per record, so the stitched file's
//!   iteration records equal the uninterrupted run's history exactly
//!   (stage lines re-announce once per resumed slice) — even if the
//!   process dies mid-slice, the prefix is parseable.
//! * The worker pool splits the machine like the batched trial driver
//!   (`with_thread_budget(nt / workers)` around every slice), keeping
//!   kernel FP geometry pinned to the logical thread count — which is
//!   exactly why the bitwise contract survives concurrency. The batch
//!   experiment driver (`coordinator::driver::run_trials_batched_controlled`)
//!   is itself expressed as a fleet of serve jobs, so batch experiments
//!   and the serving path share this one code path.
//!
//! ## The operator cache and the resident-bytes budget
//!
//! A resident service holds many graphs; RAM holds fewer. [`OpCache`]
//! (`serve/opcache.rs`) keeps built `SymPacked`/`CsrMat` operators
//! across requests, keyed by **content hash** ([`OpKey`]), under an
//! optional resident-payload ceiling (`--x-budget-mb` /
//! `SYMNMF_X_BUDGET_MB`). Jobs submitted via
//! [`Scheduler::submit_cached`] pin their operator **per slice**: a pin
//! is a refcount that blocks eviction, so eviction only ever happens
//! between slices. Over budget, the least-recently-touched unpinned
//! entry is evicted — `SymPacked` **spills** to a checksummed panel
//! file (`linalg::spill`) and re-pins stream tiles back on demand
//! (bitwise-identical apply, so the slice/resume contract above is
//! unaffected); `CsrMat` entries are dropped and rebuilt on the next
//! pin. Pinned entries can push residency over the ceiling
//! transiently; the next unpin restores it. Cache counters
//! ([`CacheStats`]) and per-job spilled-slice counts surface in the
//! serve JSON report.
//!
//! ## Crash safety: fail points, panic isolation, retry, recovery
//!
//! The serving layer assumes the process, the disk, and the engines can
//! all fail mid-flight, and pins what happens next:
//!
//! * **Deterministic fault injection** (`util::failpoint`): named sites
//!   — `ckpt_save`, `spill_open` / `spill_read` / `spill_write`,
//!   `opcache_build`, `slice` — armed through `SYMNMF_FAILPOINTS`
//!   (grammar: `site=err|panic|exit[_once|@N]`, comma-separated; every
//!   site also answers a per-key variant like `slice:<job id>`). Unarmed
//!   — the production steady state — a site costs one relaxed atomic
//!   load.
//! * **Panic-isolated workers**: every slice runs under `catch_unwind`,
//!   so one job's panicking engine marks *that* job
//!   [`JobStatus::Failed`] (panic message in [`JobOutcome::failure`])
//!   while the worker thread and every other job keep running,
//!   bit-for-bit unaffected. Failed jobs are resumable from their last
//!   good checkpoint, or cold.
//! * **Bounded deterministic retry** (`util::retry`): transient
//!   checkpoint-save and spill-read errors are retried a fixed number of
//!   times with a yield-counted (clockless) backoff. A save that
//!   exhausts the budget **degrades persistence** — the solve continues
//!   in memory and the outcome surfaces
//!   [`JobOutcome::persist_degraded`] — instead of dying; a spill read
//!   that exhausts it fails the apply loudly (and panic isolation turns
//!   that into a Failed job).
//! * **Restart recovery** ([`recovery`], `symnmf serve --recover`): scan
//!   the store, walk each job's generations newest → oldest, *quarantine*
//!   unparseable files by renaming them to `*.corrupt` (never delete),
//!   and resubmit from the newest valid generation — or cold when none
//!   parses. Because resumed and fresh runs both reproduce the
//!   uninterrupted iteration sequence bitwise, a recovered fleet's
//!   results are bitwise-identical to a never-crashed run (pinned by the
//!   crash-recovery integration tests and CI leg).
//!
//! The `symnmf serve` CLI mode (see `main.rs`) submits jobs from a JSONL
//! spec, drains them to completion, optionally resumes cancelled jobs,
//! and emits per-job reports.
//!
//! [`RunControl`]: crate::symnmf::engine::RunControl
//! [`CancelToken`]: crate::symnmf::engine::CancelToken
//! [`Checkpoint`]: crate::symnmf::engine::Checkpoint
//! [`JobOutcome::failure`]: job::JobOutcome
//! [`JobOutcome::persist_degraded`]: job::JobOutcome

pub mod job;
pub mod opcache;
pub mod recovery;
pub mod scheduler;
pub mod store;

pub use job::{JobHandle, JobOutcome, JobSpec, JobStatus};
pub use opcache::{CacheStats, CachedOperator, OpCache, OpCacheConfig, OpKey, OpPin, PinKind};
pub use recovery::{recover_job, RecoveredJob, RecoveryReport, RecoveryScan};
pub use scheduler::{Scheduler, SchedulerConfig};
pub use store::{sanitize_id, JobStore};
