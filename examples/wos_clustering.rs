//! End-to-end driver (DESIGN.md §End-to-end validation): the full §5.1
//! dense graph-clustering pipeline with ALL THREE LAYERS composed —
//!
//!   data   : planted-topic corpus → tf-idf → EDVW hypergraph expansion
//!            → dense 1024×1024 symmetric adjacency (WoS stand-in);
//!   L1/L2  : the per-iteration hot product X·F executes the AOT-compiled
//!            HLO (JAX model + Pallas matmul kernels) through PJRT — the
//!            1024-wide artifacts built by `make artifacts`;
//!   L3     : the rust coordinator runs deterministic and randomized
//!            SymNMF, clusters the vertices, reports ARI and speedups.
//!
//!     make artifacts && cargo run --release --example wos_clustering

use std::rc::Rc;
use symnmf::clustering::ari::adjusted_rand_index;
use symnmf::coordinator::driver::{run_trials, Method};
use symnmf::coordinator::experiments::wos_workload;
use symnmf::coordinator::report;
use symnmf::nls::UpdateRule;
use symnmf::runtime::{PjrtRuntime, PjrtSymOp};
use symnmf::symnmf::SymNmfOptions;
use symnmf::util::rng::Pcg64;

fn main() {
    // m=1024 matches the products_m1024_k{7,21} AOT artifacts.
    let docs = 1024;
    println!("== building WoS-substitute workload ({docs} docs, 7 topics) ==");
    let w = wos_workload(docs, 1);
    println!(
        "corpus: {} docs x {} terms, {} tokens; EDVW adjacency {}x{} dense",
        w.corpus.counts.rows(),
        w.corpus.counts.cols(),
        w.corpus.counts.nnz(),
        w.adjacency.rows(),
        w.adjacency.cols()
    );

    // wrap X in the PJRT-dispatching operator (three-layer hot path)
    let op: Option<PjrtSymOp> = match PjrtRuntime::from_default_dir() {
        Ok(rt) => {
            println!("PJRT platform: {} ({} artifacts)", rt.platform(), rt.registry.specs.len());
            Some(PjrtSymOp::new(w.adjacency.clone(), Rc::new(rt)))
        }
        Err(e) => {
            println!("PJRT unavailable ({e:#}) — native kernels only");
            None
        }
    };

    let mut opts = SymNmfOptions::new(7).with_seed(3);
    opts.max_iters = 100;

    let methods = [
        Method::Exact(UpdateRule::Hals),
        Method::Lai { rule: UpdateRule::Hals, refine: false },
        Method::Lai { rule: UpdateRule::Hals, refine: true },
        Method::Exact(UpdateRule::Bpp),
        Method::Lai { rule: UpdateRule::Bpp, refine: false },
        Method::Pgncg,
        Method::LaiPgncg { refine: false },
    ];

    println!("\n== running {} methods (1 trial each) ==", methods.len());
    let mut all = Vec::new();
    for m in methods {
        let stats = match &op {
            Some(o) => run_trials(m, o, &opts, Some(&w.labels), 1),
            None => run_trials(m, &w.adjacency, &opts, Some(&w.labels), 1),
        };
        println!(
            "  {:<14} {:>3} iters  {:>7.2}s  res {:.4}  ARI {:.3}",
            stats.label,
            stats.mean_iters,
            stats.mean_time,
            stats.min_res,
            stats.mean_ari
        );
        all.push(stats);
    }

    if let Some(o) = &op {
        let s = o.stats.borrow();
        println!(
            "\nPJRT dispatch: {} kernel calls through the AOT/Pallas path, {} native fallbacks",
            s.pjrt_calls, s.native_calls
        );
    }

    // spectral baseline (§5.1.1)
    let mut rng = Pcg64::seed_from_u64(11);
    let t0 = std::time::Instant::now();
    let spectral = symnmf::clustering::spectral::spectral_cluster(&w.adjacency, 7, &mut rng);
    let sp_ari = adjusted_rand_index(&spectral, &w.labels);
    println!(
        "spectral clustering baseline: ARI {:.3} in {:.2}s",
        sp_ari,
        t0.elapsed().as_secs_f64()
    );

    println!("\n== summary (Table-2 format) ==");
    println!("{}", report::stats_table(&all));
    println!("{}", report::speedups_vs(&all, "HALS"));

    // write convergence curves for plotting
    std::fs::create_dir_all("results").ok();
    let csv = std::path::Path::new("results/wos_convergence.csv");
    report::write_convergence_csv(csv, &all).unwrap();
    println!("wrote {csv:?}");
}
