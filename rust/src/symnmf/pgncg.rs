//! Projected Gauss–Newton with Conjugate Gradients (paper §2.1.3, [22])
//! and its LAI variant (App. B.2, Alg. LAI-PGNCG-SymNMF).
//!
//! The all-at-once method minimizes ‖X − HHᵀ‖ directly. Each outer step
//! solves the Gauss–Newton normal equations JᵀJ·z = g approximately with
//! CG, exploiting the Kronecker structure of J so that the JᵀJ-product is
//! two skinny matmuls (line 11 of Alg. LAI-PGNCG):
//!
//! ```text
//!     Y = 2(P·(HᵀH) + H·(PᵀH)),   g = −2·(X·H − H·(HᵀH))
//! ```
//!
//! then projects: H ← [H − Z]_+. The only X-sized work per outer
//! iteration is the single product X·H — which is why LAI substitution
//! (X·H → U(VᵀH)) accelerates PGNCG just as well as the AU methods,
//! something the compression-based randomized NMF methods cannot do
//! (paper §3.4).

use crate::linalg::{blas, DenseMat, IterWorkspace};
use crate::randnla::SymOp;
use crate::symnmf::anls::Metrics;
use crate::symnmf::init::initial_factor;
use crate::symnmf::lai::build_lai;
use crate::symnmf::metrics::{IterRecord, StopRule, SymNmfResult};
use crate::symnmf::options::SymNmfOptions;
use crate::util::rng::Pcg64;
use crate::util::timer::{PhaseTimer, Stopwatch, PHASE_MM, PHASE_SOLVE};

/// Pre-sized buffers for the CG inner solve — allocated once per
/// [`run_pgncg_loop`], reused across every outer iteration and every CG
/// step (the PGNCG face of the zero-allocation kernel core).
struct CgWorkspace {
    /// m×k: CG right-hand side / residual R
    r: DenseMat,
    /// m×k: accumulated direction Z
    z: DenseMat,
    /// m×k: search direction P
    p: DenseMat,
    /// m×k: JᵀJ·P product
    y: DenseMat,
    /// m×k: H·(PᵀH) partial
    hp: DenseMat,
    /// m×k: H·G product of the outer step (RHS assembly)
    hg: DenseMat,
    /// k×k: PᵀH inner product
    pth: DenseMat,
}

impl CgWorkspace {
    fn new(m: usize, k: usize) -> CgWorkspace {
        CgWorkspace {
            r: DenseMat::zeros(m, k),
            z: DenseMat::zeros(m, k),
            p: DenseMat::zeros(m, k),
            y: DenseMat::zeros(m, k),
            hp: DenseMat::zeros(m, k),
            hg: DenseMat::zeros(m, k),
            pth: DenseMat::zeros(k, k),
        }
    }
}

/// One CG solve of JᵀJ·Z ≈ R (Gauss–Newton direction). `g` = HᵀH is held
/// fixed during the inner solve; `cg.r` holds the right-hand side on
/// entry and the CG residual on exit; the direction lands in `cg.z`.
/// All intermediates come from the workspace — no allocation.
fn cg_direction_ws(h: &DenseMat, g: &DenseMat, iters: usize, cg: &mut CgWorkspace) {
    cg.z.fill(0.0);
    let mut e_old = cg.r.fro_norm_sq();
    if e_old == 0.0 {
        return;
    }
    cg.p.copy_from(&cg.r);
    for _ in 0..iters {
        // Y = JᵀJ·P = 2(P·G + H·(PᵀH))
        blas::matmul_tn_into(&cg.p, h, &mut cg.pth);
        blas::matmul_into(&cg.p, g, &mut cg.y);
        blas::matmul_into(h, &cg.pth, &mut cg.hp);
        cg.y.axpy(1.0, &cg.hp);
        cg.y.scale(2.0);
        let py = blas::dot(cg.p.data(), cg.y.data());
        if py.abs() < 1e-300 {
            break;
        }
        let a = e_old / py;
        cg.z.axpy(a, &cg.p);
        cg.r.axpy(-a, &cg.y);
        let e_new = cg.r.fro_norm_sq();
        if e_new.sqrt() < 1e-12 {
            break;
        }
        let beta = e_new / e_old;
        // p = r + beta·p, in place
        cg.p.scale(beta);
        cg.p.axpy(1.0, &cg.r);
        e_old = e_new;
    }
}

/// Allocating wrapper over [`cg_direction_ws`] (test oracle).
#[cfg(test)]
fn cg_direction(h: &DenseMat, g: &DenseMat, r0: DenseMat, iters: usize) -> DenseMat {
    let (m, k) = r0.shape();
    let mut cg = CgWorkspace::new(m, k);
    cg.r.copy_from(&r0);
    cg_direction_ws(h, g, iters, &mut cg);
    cg.z
}

/// Shared PGNCG loop over any operator (`x_iter` drives the iteration,
/// `metrics` measures against the true X).
fn run_pgncg_loop(
    x_iter: &dyn SymOp,
    opts: &SymNmfOptions,
    mut h: DenseMat,
    metrics: &Metrics,
    label: String,
    setup_secs: f64,
    mut phases: PhaseTimer,
) -> SymNmfResult {
    let mut records: Vec<IterRecord> = Vec::new();
    let mut stop = StopRule::new(opts.tol, opts.patience);
    let mut clock = setup_secs;
    let (m, k) = h.shape();
    // all per-iteration buffers, sized once: X·H, HᵀH and the metric
    // buffers in the shared iteration workspace (PGNCG leaves its
    // Update(G,Y) scratch idle — it has no NLS solve), CG intermediates
    // including the H·G RHS partial in the CG workspace
    let mut ws = IterWorkspace::new(m, k);
    let mut cg = CgWorkspace::new(m, k);

    for iter in 0..opts.max_iters {
        let sw = Stopwatch::start();
        let t = Stopwatch::start();
        x_iter.apply_into(&h, &mut ws.y); // X·H
        blas::gram_into(&h, &mut ws.g); // G = HᵀH
        let mm = t.elapsed_secs();

        let t = Stopwatch::start();
        // gradient direction: R = −g/2 form: R₀ = 2(XH − H·G) is the CG
        // right-hand side (−gradient); Alg. LAI-PGNCG phrases it with the
        // opposite sign and a minus in the final update — equivalent.
        blas::matmul_into(&h, &ws.g, &mut cg.hg); // H·G
        cg.r.copy_from(&ws.y);
        cg.r.axpy(-1.0, &cg.hg);
        cg.r.scale(2.0);
        cg_direction_ws(&h, &ws.g, opts.cg_iters, &mut cg);
        // H ← [H + Z]_+ (Z approximates the Newton step along −gradient)
        h.axpy(1.0, &cg.z);
        h.project_nonneg();
        let solve = t.elapsed_secs();

        clock += sw.elapsed_secs();
        phases.add(PHASE_MM, std::time::Duration::from_secs_f64(mm));
        phases.add(PHASE_SOLVE, std::time::Duration::from_secs_f64(solve));

        let (res, pg) = metrics.eval_ws(&h, &h, &mut ws);
        records.push(IterRecord {
            iter,
            time_secs: clock,
            residual: res,
            proj_grad: pg,
            phase_secs: (mm, solve, 0.0),
            hybrid_stats: None,
        });
        if stop.update(res) {
            break;
        }
    }

    SymNmfResult { label, h: h.clone(), w: h, records, phases, setup_secs }
}

/// PGNCG-SymNMF on the exact X (the paper's "PGNCG" baseline).
pub fn pgncg_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let h0 = initial_factor(x, opts, &mut rng);
    let metrics = Metrics::new(x, true);
    run_pgncg_loop(
        x,
        opts,
        h0,
        &metrics,
        "PGNCG".to_string(),
        0.0,
        PhaseTimer::new(),
    )
}

/// LAI-PGNCG-SymNMF (App. B.2): identical loop against the factored LAI;
/// with `opts.refine`, iterative refinement on the true X afterwards
/// ("PGNCG-IR" rows of Table 2).
pub fn lai_pgncg_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let mut phases = PhaseTimer::new();
    let (lai, setup_secs, _evd) = build_lai(x, opts, &mut rng, &mut phases);
    let h0 = initial_factor(x, opts, &mut rng);
    let metrics = Metrics::new(x, true);
    let result = run_pgncg_loop(
        &lai,
        opts,
        h0,
        &metrics,
        "LAI-PGNCG".to_string(),
        setup_secs,
        phases,
    );
    if !opts.refine {
        return result;
    }
    let clock = result.total_secs();
    let refined = run_pgncg_loop(
        x,
        opts,
        result.h.clone(),
        &metrics,
        "LAI-PGNCG-IR".to_string(),
        clock,
        result.phases.clone(),
    );
    let mut records = result.records;
    let offset = records.len();
    records.extend(refined.records.into_iter().map(|mut r| {
        r.iter += offset;
        r
    }));
    SymNmfResult {
        label: "LAI-PGNCG-IR".to_string(),
        h: refined.h,
        w: refined.w,
        records,
        phases: refined.phases,
        setup_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        x.symmetrize();
        x
    }

    #[test]
    fn pgncg_converges_on_planted() {
        let x = planted(50, 3, 1);
        let mut opts = SymNmfOptions::new(3).with_seed(2);
        opts.max_iters = 80;
        opts.cg_iters = 15;
        let res = pgncg_symnmf(&x, &opts);
        assert!(res.h.is_nonneg());
        let last = res.min_residual();
        let first = res.records.first().unwrap().residual;
        assert!(last < 0.5 * first, "residual {first} → {last}");
    }

    #[test]
    fn cg_direction_solves_psd_system_when_unconstrained() {
        // JᵀJ is PSD but can be singular; pick an RHS in its range
        // (r0 = JᵀJ·y for random y) so CG must recover it exactly.
        let mut rng = Pcg64::seed_from_u64(3);
        let h = DenseMat::uniform(12, 3, 1.0, &mut rng);
        let g = blas::gram(&h);
        let y0 = DenseMat::gaussian(12, 3, &mut rng);
        let r0 = {
            let yth = blas::matmul_tn(&y0, &h);
            let mut r = blas::matmul(&y0, &g);
            r.axpy(1.0, &blas::matmul(&h, &yth));
            r.scale(2.0);
            r
        };
        let z = cg_direction(&h, &g, r0.clone(), 400);
        // apply JᵀJ to z
        let zth = blas::matmul_tn(&z, &h);
        let mut y = blas::matmul(&z, &g);
        y.axpy(1.0, &blas::matmul(&h, &zth));
        y.scale(2.0);
        let rel = y.diff_fro(&r0) / r0.fro_norm();
        assert!(rel < 1e-6, "CG residual {rel}");
    }

    #[test]
    fn lai_pgncg_matches_quality() {
        let x = planted(60, 4, 4);
        let mut opts = SymNmfOptions::new(4).with_seed(5);
        opts.max_iters = 80;
        let exact = pgncg_symnmf(&x, &opts);
        let lai = lai_pgncg_symnmf(&x, &opts);
        assert!(
            lai.min_residual() < exact.min_residual() + 0.05,
            "LAI {} vs exact {}",
            lai.min_residual(),
            exact.min_residual()
        );
    }

    #[test]
    fn ir_label_and_continuation() {
        let x = planted(40, 3, 6);
        let mut opts = SymNmfOptions::new(3).with_seed(7);
        opts.max_iters = 20;
        opts.refine = true;
        let res = lai_pgncg_symnmf(&x, &opts);
        assert_eq!(res.label, "LAI-PGNCG-IR");
        for w in res.records.windows(2) {
            assert!(w[1].time_secs >= w[0].time_secs - 1e-12);
            assert_eq!(w[1].iter, w[0].iter + 1);
        }
    }
}
