//! Rendering: Table-2-style summaries, convergence-curve CSVs (Figs. 1,
//! 2, 5), time-breakdown reports (Fig. 3), hybrid-sampling stats CSVs
//! (Fig. 6) and topword tables (Tables 3/7/8).

use crate::coordinator::driver::MethodStats;
use crate::symnmf::SymNmfResult;
use crate::util::table::{f4, secs, Table};
use crate::util::timer::{PHASE_MM, PHASE_SAMPLING, PHASE_SOLVE};
use std::io::Write;
use std::path::Path;

/// Table 2 layout: Alg. | Iters | Time | Avg. Min-Res | Min-Res | Mean-ARI.
pub fn stats_table(stats: &[MethodStats]) -> String {
    let mut t = Table::new(&["Alg.", "Iters", "Time", "Avg. Min-Res", "Min-Res", "Mean-ARI"]);
    for s in stats {
        let ari = if s.mean_ari.is_nan() {
            "-".to_string()
        } else {
            f4(s.mean_ari)
        };
        t.row(&[
            s.label.clone(),
            format!("{:.1}", s.mean_iters),
            secs(s.mean_time),
            f4(s.avg_min_res),
            f4(s.min_res),
            ari,
        ]);
    }
    t.render()
}

/// Convergence-curve CSV: one row per (trial, iteration) with time,
/// residual and projected gradient — the raw series behind Figs. 1/2/5.
pub fn write_convergence_csv(path: &Path, stats: &[MethodStats]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "method,trial,iter,time_secs,residual,proj_grad")?;
    for s in stats {
        for (t, run) in s.trials.iter().enumerate() {
            for r in &run.records {
                writeln!(
                    f,
                    "{},{},{},{:.6},{:.8},{}",
                    s.label,
                    t,
                    r.iter,
                    r.time_secs,
                    r.residual,
                    r.proj_grad.map(|p| format!("{p:.6}")).unwrap_or_default()
                )?;
            }
        }
    }
    Ok(())
}

/// Fig. 3: per-iteration time breakdown (MM / Solve / Sampling).
pub fn time_breakdown_table(results: &[&SymNmfResult]) -> String {
    let mut t = Table::new(&[
        "Alg.",
        "MM s/iter",
        "Solve s/iter",
        "Sampling s/iter",
        "Total s/iter",
    ]);
    for r in results {
        let iters = r.iters().max(1) as f64;
        let mm = r.phases.get_secs(PHASE_MM) / iters;
        let so = r.phases.get_secs(PHASE_SOLVE) / iters;
        let sa = r.phases.get_secs(PHASE_SAMPLING) / iters;
        t.row(&[
            r.label.clone(),
            format!("{mm:.4}"),
            format!("{so:.4}"),
            format!("{sa:.4}"),
            format!("{:.4}", mm + so + sa),
        ]);
    }
    t.render()
}

/// Fig. 6: hybrid-sampling per-iteration stats CSV.
pub fn write_hybrid_stats_csv(path: &Path, run: &SymNmfResult) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "iter,det_fraction,theta_over_k")?;
    for r in &run.records {
        if let Some((frac, theta)) = r.hybrid_stats {
            writeln!(f, "{},{:.6},{:.6}", r.iter, frac, theta)?;
        }
    }
    Ok(())
}

/// Tables 3/7/8 layout: topics as rows, top words as columns.
pub fn topwords_table(words: &[Vec<String>], topn: usize) -> String {
    let mut headers: Vec<String> = vec!["Topic".to_string()];
    for i in 0..topn {
        headers.push(format!("TW{}", i + 1));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    for (topic, list) in words.iter().enumerate() {
        let mut row = vec![topic.to_string()];
        for i in 0..topn {
            row.push(list.get(i).cloned().unwrap_or_default());
        }
        t.row(&row);
    }
    t.render()
}

/// Speedup summary vs a baseline label (the paper's headline numbers).
pub fn speedups_vs(stats: &[MethodStats], baseline_label: &str) -> String {
    let base = stats
        .iter()
        .find(|s| s.label == baseline_label)
        .map(|s| s.mean_time);
    let mut t = Table::new(&["Alg.", "Time (s)", "Speedup"]);
    for s in stats {
        let sp = base
            .map(|b| format!("{:.2}x", b / s.mean_time.max(1e-12)))
            .unwrap_or_else(|| "-".into());
        t.row(&[s.label.clone(), secs(s.mean_time), sp]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::{run_trials, Method};
    use crate::linalg::{blas, DenseMat};
    use crate::nls::UpdateRule;
    use crate::symnmf::SymNmfOptions;
    use crate::util::rng::Pcg64;

    fn small_stats() -> Vec<MethodStats> {
        let mut rng = Pcg64::seed_from_u64(1);
        let h = DenseMat::uniform(30, 3, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        x.symmetrize();
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 5;
        vec![run_trials(Method::Exact(UpdateRule::Hals), &x, &opts, None, 2)]
    }

    #[test]
    fn table_renders_all_columns() {
        let stats = small_stats();
        let s = stats_table(&stats);
        assert!(s.contains("Alg."));
        assert!(s.contains("HALS"));
        assert!(s.contains("Mean-ARI"));
    }

    #[test]
    fn csv_has_rows() {
        let stats = small_stats();
        let dir = std::env::temp_dir().join("symnmf_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("conv.csv");
        write_convergence_csv(&p, &stats).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.lines().count() > 2);
        assert!(text.starts_with("method,trial,iter"));
    }

    #[test]
    fn topwords_table_shapes() {
        let words = vec![
            vec!["alpha".into(), "beta".into()],
            vec!["gamma".into()],
        ];
        let s = topwords_table(&words, 2);
        assert!(s.contains("TW1"));
        assert!(s.contains("alpha"));
        assert!(s.contains("gamma"));
    }
}
