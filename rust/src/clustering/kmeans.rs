//! Lloyd's k-means with k-means++ seeding — the clustering stage of the
//! spectral baseline (paper §5.1.1 compares against eigs()+kmeans()).

use crate::linalg::DenseMat;
use crate::util::rng::Pcg64;

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// k-means++ initial centers (row indices of `data`).
fn kmeanspp_centers(data: &DenseMat, k: usize, rng: &mut Pcg64) -> DenseMat {
    let m = data.rows();
    let mut centers = DenseMat::zeros(k, data.cols());
    let first = rng.below(m);
    centers.row_mut(0).copy_from_slice(data.row(first));
    let mut d2: Vec<f64> = (0..m)
        .map(|i| sq_dist(data.row(i), centers.row(0)))
        .collect();
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            rng.below(m)
        } else {
            // sample proportional to squared distance
            let mut target = rng.uniform() * total;
            let mut pick = m - 1;
            for (i, &d) in d2.iter().enumerate() {
                if target < d {
                    pick = i;
                    break;
                }
                target -= d;
            }
            pick
        };
        centers.row_mut(c).copy_from_slice(data.row(next));
        for i in 0..m {
            d2[i] = d2[i].min(sq_dist(data.row(i), centers.row(c)));
        }
    }
    centers
}

/// Run k-means; returns (assignments, total within-cluster SSE).
pub fn kmeans(
    data: &DenseMat,
    k: usize,
    max_iters: usize,
    rng: &mut Pcg64,
) -> (Vec<usize>, f64) {
    let m = data.rows();
    let d = data.cols();
    assert!(k >= 1 && m >= k);
    let mut centers = kmeanspp_centers(data, k, rng);
    let mut assign = vec![0usize; m];
    let mut sse = f64::INFINITY;
    for _ in 0..max_iters {
        // assignment step
        let mut changed = false;
        let mut new_sse = 0.0;
        for i in 0..m {
            let row = data.row(i);
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for c in 0..k {
                let dist = sq_dist(row, centers.row(c));
                if dist < bd {
                    bd = dist;
                    best = c;
                }
            }
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
            new_sse += bd;
        }
        sse = new_sse;
        if !changed {
            break;
        }
        // update step
        let mut sums = DenseMat::zeros(k, d);
        let mut counts = vec![0usize; k];
        for i in 0..m {
            let c = assign[i];
            counts[c] += 1;
            crate::linalg::blas::axpy(1.0, data.row(i), sums.row_mut(c));
        }
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f64;
                for v in sums.row_mut(c) {
                    *v *= inv;
                }
                centers.row_mut(c).copy_from_slice(sums.row(c));
            } else {
                // dead center: reseed at the farthest point
                let far = (0..m)
                    .max_by(|&a, &b| {
                        sq_dist(data.row(a), centers.row(assign[a]))
                            .partial_cmp(&sq_dist(data.row(b), centers.row(assign[b])))
                            .unwrap()
                    })
                    .unwrap();
                centers.row_mut(c).copy_from_slice(data.row(far));
            }
        }
    }
    (assign, sse)
}

/// Best of `restarts` k-means runs by SSE.
pub fn kmeans_restarts(
    data: &DenseMat,
    k: usize,
    max_iters: usize,
    restarts: usize,
    rng: &mut Pcg64,
) -> (Vec<usize>, f64) {
    let mut best: Option<(Vec<usize>, f64)> = None;
    for _ in 0..restarts {
        let (a, sse) = kmeans(data, k, max_iters, rng);
        if best.as_ref().map(|(_, b)| sse < *b).unwrap_or(true) {
            best = Some((a, sse));
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::ari::adjusted_rand_index;

    #[test]
    fn separates_well_separated_blobs() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut rows = Vec::new();
        let mut truth = Vec::new();
        for c in 0..3usize {
            for _ in 0..30 {
                rows.push(10.0 * c as f64 + 0.1 * rng.gaussian());
                rows.push(-5.0 * c as f64 + 0.1 * rng.gaussian());
                truth.push(c);
            }
        }
        let data = DenseMat::from_vec(90, 2, rows);
        let (assign, sse) = kmeans_restarts(&data, 3, 50, 3, &mut rng);
        let ari = adjusted_rand_index(&assign, &truth);
        assert!(ari > 0.99, "ari={ari}, sse={sse}");
    }

    #[test]
    fn sse_decreases_with_k() {
        let mut rng = Pcg64::seed_from_u64(2);
        let data = DenseMat::gaussian(60, 3, &mut rng);
        let (_, sse2) = kmeans_restarts(&data, 2, 40, 3, &mut rng);
        let (_, sse5) = kmeans_restarts(&data, 5, 40, 3, &mut rng);
        assert!(sse5 < sse2);
    }

    #[test]
    fn k_equals_m_gives_zero_sse() {
        let mut rng = Pcg64::seed_from_u64(3);
        let data = DenseMat::gaussian(8, 2, &mut rng);
        let (_, sse) = kmeans(&data, 8, 30, &mut rng);
        assert!(sse < 1e-9);
    }
}
