//! BLAS-like dense kernels, shaped for the paper's workloads.
//!
//! The SymNMF hot path multiplies a large square symmetric `X` (m×m) by a
//! skinny factor `F` (m×k, k ≤ ~100). The kernels are organized around
//! three blocking ideas:
//!
//! **Panel packing (the 2×8 NT microkernel).** Products whose right
//! operand is accessed row-contiguously transposed — the skinny-B path of
//! [`matmul_into`] and all of [`matmul_nt_into`] — first pack the right
//! operand into **tile-major panels** and then run a 2×8 register tile
//! over them. With B̃ the n×p logical transpose of the right operand
//! (row j of B̃ = output column j), panel `jp` covers output columns
//! `j0 = 8·jp … j0+7` and interleaves them by reduction index:
//!
//! ```text
//!   panel jp  (8·p contiguous f64, edge columns zero-padded):
//!
//!     t = 0          t = 1                    t = p−1
//!   ┌──────────────┬──────────────┬── ... ──┬──────────────┐
//!   │ B̃[j0  ][0]   │ B̃[j0  ][1]   │         │ B̃[j0  ][p−1] │
//!   │ B̃[j0+1][0]   │ B̃[j0+1][1]   │         │ B̃[j0+1][p−1] │
//!   │   ⋮  (8)     │   ⋮  (8)     │         │   ⋮  (8)     │
//!   │ B̃[j0+7][0]   │ B̃[j0+7][1]   │         │ B̃[j0+7][p−1] │
//!   └──────────────┴──────────────┴── ... ──┴──────────────┘
//! ```
//!
//! The microkernel multiplies two A rows against one panel with 16
//! scalar accumulators: each reduction step is two broadcast loads
//! (`a0[t]`, `a1[t]`) plus ONE contiguous 8-vector load (`panel[t·8..]`),
//! where the previous 2×4 kernel streamed four separate B̃ rows. Every
//! loaded panel element feeds two FMAs, every A element eight. Edge
//! panels (n not a multiple of 8) are zero-padded during packing, so the
//! kernel always accumulates full-width tiles and masks only the final
//! store — the "masked edge tile". Packing is staged in a thread-local
//! [`PanelBuf`], so the steady-state hot loop performs no allocation;
//! for wide operands (> 8 panels) the packing pass itself splits panels
//! across the scope's [`current_threads`] workers — panels write
//! disjoint regions and packing is FP-order-free, so the split is
//! bitwise-neutral at any thread budget.
//! The PR-2 2×4 unpacked kernel is retained as [`matmul_nt_into_unpacked`]
//! — the few-row dispatch target and the oracle the packed path is
//! pinned against.
//!
//! **Cache blocking with symmetry (the SYMM kernel).** [`symm_tall_into`]
//! partitions symmetric X into `SYMM_BLOCK`-sized row/column blocks and
//! walks only the upper-triangle block pairs: each off-diagonal block
//! X[I,J] is read once and applied to both output panels
//! (out[I] += X[I,J]·F[J] and out[J] += X[I,J]ᵀ·F[I]), roughly halving
//! X memory traffic relative to the plain GEMM. Workers accumulate into
//! private m×k buffers (round-robin over block pairs) which are reduced
//! in fixed worker order. The pool/reduction harness is shared with the
//! packed-triangular storage ([`crate::linalg::packed::SymPacked`]) as
//! [`pair_pool_accumulate`]: the accumulator-slot count is pinned to the
//! **logical** width [`num_threads`] while the slots execute on at most
//! [`current_threads`] OS threads — so a thread budget changes scheduling
//! but not one bit of output, which is what keeps batched multi-seed
//! trials bitwise identical to serial runs.
//!
//! `parallel_for_chunks` splits row ranges across cores when more than
//! one is available; partitioning is balanced and deterministic (see
//! [`crate::util::threadpool`]).
//!
//! **Dispatch tiers (PR 6).** The scalar kernels in this module are the
//! permanent correctness oracles; the hot ones also exist as explicit
//! SIMD bodies behind the runtime dispatch in [`crate::linalg::simd`]
//! (AVX-512F / AVX2+FMA / NEON, selected once per process from
//! `SYMNMF_KERNEL` or feature detection). Two numeric tiers:
//!
//! * *bitwise tier* — [`dot`]/[`axpy`] (and the f32 widening axpy of
//!   the sketched pipelines) are dispatched through SIMD bodies that
//!   reproduce this module's FP operation order exactly (separate
//!   mul+add, lanes mirroring the 4-way unrolled accumulators, scalar
//!   reduction order), so every cross-path bitwise pin in the test
//!   suite holds on any tier;
//! * *FMA tier* — the packed NT microkernel, the SYMM tile product,
//!   [`gram_into`] and the HALS row update contract each step to one
//!   rounding. Per output element the accumulation stays t-sequential,
//!   so each variant is pinned to its scalar oracle at **1e-12
//!   relative** by the parity suite (shapes m,k ∈ {1,2,3,7,8,9,31,33,
//!   65}); they are *not* bitwise-equal across tiers, which is why the
//!   active ISA is recorded in checkpoints and trace stage lines.
//!
//! The `*_isa` entry points take an explicit [`KernelIsa`] so tests can
//! pin every supported tier against the oracle in one process; the
//! un-suffixed functions resolve [`crate::linalg::simd::active`] once
//! per call and are what the solvers use.
//!
//! **f32 accumulation policy.** `SYMNMF_PRECISION=f32` (sketched
//! pipelines only) stages operands as f32 and runs f32 multiplies, but
//! every accumulation — including the Gram/residual/stop-rule math —
//! stays f64: each step is `acc_64 += f64(x_32 * y_32)`, an exactly
//! widened f32 product. See [`crate::linalg::simd::widening_axpy_f32`].
//!
//! [`PanelBuf`]: crate::linalg::workspace::PanelBuf
//! [`KernelIsa`]: crate::linalg::simd::KernelIsa

use crate::linalg::simd::{self, KernelIsa};
use crate::linalg::workspace::PanelBuf;
use crate::linalg::DenseMat;
use crate::util::pool;
use crate::util::threadpool::{current_threads, num_threads, parallel_for_chunks, SendPtr};
use std::cell::RefCell;

/// Panel width of the packed NT microkernel (output columns per tile).
pub(crate) const NR: usize = 8;

thread_local! {
    /// Reusable packing target for the tile-major B panels of
    /// [`matmul_into`] (skinny-B path) and [`matmul_nt_into_packed`].
    /// Capacity grows to the largest packed operand seen on the thread
    /// and is then reused, so the steady-state hot loop performs no
    /// allocation even when a solve alternates between B shapes
    /// (e.g. the LAI inner product and the metrics X·H product).
    static PANEL_SCRATCH: RefCell<PanelBuf> = RefCell::new(PanelBuf::new());

    /// Per-call accumulator pool for the multi-slot path of
    /// [`pair_pool_accumulate`]: `num_threads()` private m×k buffers,
    /// reused across calls on the same thread (nested kernel calls from
    /// batched trials each see their own pool).
    static SYMM_ACC: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

/// C = A·B.
pub fn matmul(a: &DenseMat, b: &DenseMat) -> DenseMat {
    let mut c = DenseMat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// C = A·B into a pre-allocated output (hot-path form; no allocation of
/// the output).
///
/// Two regimes (§Perf): for skinny B (n ≤ 64 — the X·F shape that
/// dominates every SymNMF iteration) B is packed once into tile-major
/// panels in the thread-local [`PanelBuf`] and the product runs on the
/// 2×8 register tile of [`packed_nt_rows`]; otherwise the row-axpy
/// formulation is used.
pub fn matmul_into(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "matmul: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (m, n));
    if n <= 64 && ka >= 32 {
        // skinny-B path: pack B straight from row-major storage (each B
        // row scatters contiguously into the panels' t-slots), replacing
        // the staging transpose of the previous implementation — the
        // panel IS the transpose, interleaved for the microkernel.
        PANEL_SCRATCH.with(|cell| {
            let mut buf = cell.borrow_mut();
            let dst = buf.packed(n.div_ceil(NR) * NR * ka);
            pack_b_panels(b.data(), ka, n, dst);
            let panels: &[f64] = dst;
            let adata = a.data();
            let cptr = SendPtr(c.data_mut().as_mut_ptr());
            let isa = simd::active();
            parallel_for_chunks(m, 64, move |lo, hi| {
                simd::packed_nt_rows_isa(isa, adata, ka, panels, n, lo, hi, cptr);
            });
        });
        return;
    }
    let bdata = b.data();
    let adata = a.data();
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    parallel_for_chunks(m, 64, move |lo, hi| {
        let cdata = cptr;
        for i in lo..hi {
            let arow = &adata[i * ka..(i + 1) * ka];
            // SAFETY: rows [lo, hi) are disjoint across workers.
            let crow = unsafe {
                std::slice::from_raw_parts_mut(cdata.0.add(i * n), n)
            };
            crow.fill(0.0);
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &bdata[kk * n..(kk + 1) * n];
                axpy(aik, brow, crow);
            }
        }
    });
}

/// T-blocking of the panel packing: bounds the packed working set per
/// pass to 8·256 doubles (16 KiB, L1-resident) when p is large.
const PACK_TBLK: usize = 256;

/// Pack one panel (output columns `8·jp … 8·jp+7`) of the n×p row-major
/// B̃ operand — the inner body of [`pack_bt_panels`], factored out so the
/// packing pass can split panels across workers.
fn pack_bt_panel(bt: &[f64], n: usize, p: usize, jp: usize, panel: &mut [f64]) {
    debug_assert_eq!(panel.len(), NR * p);
    let j0 = jp * NR;
    let w = (n - j0).min(NR);
    if w < NR {
        panel.fill(0.0);
    }
    for tb in (0..p).step_by(PACK_TBLK) {
        let te = (tb + PACK_TBLK).min(p);
        for jj in 0..w {
            let row = &bt[(j0 + jj) * p..(j0 + jj + 1) * p];
            for t in tb..te {
                panel[t * NR + jj] = row[t];
            }
        }
    }
}

/// Pack the n×p row-major B̃ operand (the logical transpose of the right
/// operand, as handed to [`matmul_nt_into`]) into tile-major panels —
/// see the module-header diagram. Panel `jp` holds output columns
/// `8·jp … 8·jp+7`; within the panel, reduction step `t` stores the
/// eight values `B̃[j0..j0+8][t]` contiguously. Columns past `n` are
/// zero-filled so the masked edge tile accumulates exact zeros.
///
/// For wide operands the panels are split across the calling scope's
/// [`current_threads`] workers: every panel writes a disjoint `dst`
/// region and packing is pure data movement (no FP accumulation), so the
/// parallel pass is bitwise-identical to the serial one at any thread
/// budget. Narrow operands (≤ 8 panels, the skinny-factor hot path) stay
/// on the calling thread — no spawn overhead where packing is cheap.
fn pack_bt_panels(bt: &[f64], n: usize, p: usize, dst: &mut [f64]) {
    let np = n.div_ceil(NR);
    debug_assert_eq!(dst.len(), np * NR * p);
    let dptr = SendPtr(dst.as_mut_ptr());
    parallel_for_chunks(np, 8, move |lo, hi| {
        for jp in lo..hi {
            // SAFETY: panel regions [jp·NR·p, (jp+1)·NR·p) are disjoint
            // across the workers' disjoint panel ranges.
            let panel = unsafe {
                std::slice::from_raw_parts_mut(dptr.0.add(jp * NR * p), NR * p)
            };
            pack_bt_panel(bt, n, p, jp, panel);
        }
    });
}

/// Pack the NT right operand `b` (n×p row-major — already the transpose
/// of the logical right factor) into tile-major panels inside `buf`,
/// returning the packed length. This is exactly the packing pass of
/// [`matmul_nt_into_packed`], exposed so benches can measure it in
/// isolation (`pack_b_panels_par`) and tests can pin the parallel pass
/// against a budget-capped serial run.
pub fn pack_nt_panels(b: &DenseMat, buf: &mut PanelBuf) -> usize {
    let (n, p) = b.shape();
    let len = n.div_ceil(NR) * NR * p;
    pack_bt_panels(b.data(), n, p, buf.packed(len));
    len
}

/// Pack a p×n row-major B operand (the skinny right factor of
/// [`matmul_into`]) into the same tile-major panel layout. Reads stream
/// each B row once; writes land in each panel's contiguous t-slot, so no
/// staging transpose is materialized.
fn pack_b_panels(b: &[f64], p: usize, n: usize, dst: &mut [f64]) {
    let np = n.div_ceil(NR);
    debug_assert_eq!(dst.len(), np * NR * p);
    for t in 0..p {
        let brow = &b[t * n..(t + 1) * n];
        for jp in 0..np {
            let j0 = jp * NR;
            let w = (n - j0).min(NR);
            let d = &mut dst[jp * NR * p + t * NR..jp * NR * p + (t + 1) * NR];
            d[..w].copy_from_slice(&brow[j0..j0 + w]);
            for z in &mut d[w..] {
                *z = 0.0;
            }
        }
    }
}

/// The packed 2×8 NT microkernel: writes C rows [lo, hi) of C = A·B̃ᵀ
/// where `a` is m×p row-major and `panels` is the tile-major packing of
/// the n×p B̃ (see [`pack_bt_panels`]). Rows are processed in pairs
/// against one 8-wide panel per tile: 16 accumulators, and every
/// reduction step is two broadcast loads plus one contiguous 8-vector
/// load — the layout the autovectorizer turns into full-width FMA
/// vectors. Each output element accumulates sequentially over `t`, so
/// the per-element FP order matches the unpacked 2×4 tile.
///
/// This is the scalar oracle of the dispatched
/// [`crate::linalg::simd::packed_nt_rows_isa`] — its body must stay
/// untouched so the SIMD tiers keep a fixed reference.
#[allow(clippy::too_many_arguments)]
pub(crate) fn packed_nt_rows(
    a: &[f64],
    p: usize,
    panels: &[f64],
    n: usize,
    lo: usize,
    hi: usize,
    cptr: SendPtr,
) {
    let np = n.div_ceil(NR);
    let mut i = lo;
    while i + 2 <= hi {
        let a0 = &a[i * p..(i + 1) * p];
        let a1 = &a[(i + 1) * p..(i + 2) * p];
        // SAFETY: rows [lo, hi) are disjoint across workers.
        let c0 = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
        let c1 = unsafe { std::slice::from_raw_parts_mut(cptr.0.add((i + 1) * n), n) };
        for jp in 0..np {
            let j0 = jp * NR;
            let w = (n - j0).min(NR);
            let pb = &panels[jp * NR * p..(jp + 1) * NR * p];
            let mut acc0 = [0.0f64; NR];
            let mut acc1 = [0.0f64; NR];
            for t in 0..p {
                let x0 = a0[t];
                let x1 = a1[t];
                let bv = &pb[t * NR..(t + 1) * NR];
                for jj in 0..NR {
                    acc0[jj] += x0 * bv[jj];
                    acc1[jj] += x1 * bv[jj];
                }
            }
            // masked store: only the w real columns of the edge tile
            c0[j0..j0 + w].copy_from_slice(&acc0[..w]);
            c1[j0..j0 + w].copy_from_slice(&acc1[..w]);
        }
        i += 2;
    }
    if i < hi {
        let a0 = &a[i * p..(i + 1) * p];
        // SAFETY: as above.
        let c0 = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
        for jp in 0..np {
            let j0 = jp * NR;
            let w = (n - j0).min(NR);
            let pb = &panels[jp * NR * p..(jp + 1) * NR * p];
            let mut acc = [0.0f64; NR];
            for t in 0..p {
                let x0 = a0[t];
                let bv = &pb[t * NR..(t + 1) * NR];
                for jj in 0..NR {
                    acc[jj] += x0 * bv[jj];
                }
            }
            c0[j0..j0 + w].copy_from_slice(&acc[..w]);
        }
    }
}

/// The unpacked register-blocked NT microkernel (the PR-2 2×4 tile,
/// retained as the few-row dispatch target of [`matmul_nt_into`] and the
/// oracle the packed path is pinned against): writes C rows [lo, hi) of
/// C = A·BTᵀ, where `a` is m×p row-major and `bt` is n×p row-major (the
/// TRANSPOSE of the logical right operand, so both reduction streams are
/// contiguous). Rows are processed in pairs against 4-column panels of
/// the output: 8 accumulators, 6 loads and 8 FMAs per reduction step.
fn nt_rows(a: &[f64], p: usize, bt: &[f64], n: usize, lo: usize, hi: usize, cptr: SendPtr) {
    let mut i = lo;
    while i + 2 <= hi {
        let a0 = &a[i * p..(i + 1) * p];
        let a1 = &a[(i + 1) * p..(i + 2) * p];
        // SAFETY: rows [lo, hi) are disjoint across workers.
        let c0 = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
        let c1 = unsafe { std::slice::from_raw_parts_mut(cptr.0.add((i + 1) * n), n) };
        nt_row_pair(a0, a1, p, bt, n, c0, c1);
        i += 2;
    }
    if i < hi {
        let a0 = &a[i * p..(i + 1) * p];
        // SAFETY: as above.
        let c0 = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
        nt_row_single(a0, p, bt, n, c0);
    }
}

/// 2×4 tile: two A rows against panels of four BT rows.
#[inline]
#[allow(clippy::too_many_arguments)]
fn nt_row_pair(
    a0: &[f64],
    a1: &[f64],
    p: usize,
    bt: &[f64],
    n: usize,
    c0: &mut [f64],
    c1: &mut [f64],
) {
    let mut j = 0;
    while j + 4 <= n {
        let b0 = &bt[j * p..(j + 1) * p];
        let b1 = &bt[(j + 1) * p..(j + 2) * p];
        let b2 = &bt[(j + 2) * p..(j + 3) * p];
        let b3 = &bt[(j + 3) * p..(j + 4) * p];
        let (mut s00, mut s01, mut s02, mut s03) = (0.0f64, 0.0, 0.0, 0.0);
        let (mut s10, mut s11, mut s12, mut s13) = (0.0f64, 0.0, 0.0, 0.0);
        for t in 0..p {
            let x0 = a0[t];
            let x1 = a1[t];
            s00 += x0 * b0[t];
            s01 += x0 * b1[t];
            s02 += x0 * b2[t];
            s03 += x0 * b3[t];
            s10 += x1 * b0[t];
            s11 += x1 * b1[t];
            s12 += x1 * b2[t];
            s13 += x1 * b3[t];
        }
        c0[j] = s00;
        c0[j + 1] = s01;
        c0[j + 2] = s02;
        c0[j + 3] = s03;
        c1[j] = s10;
        c1[j + 1] = s11;
        c1[j + 2] = s12;
        c1[j + 3] = s13;
        j += 4;
    }
    while j < n {
        let b = &bt[j * p..(j + 1) * p];
        c0[j] = dot(a0, b);
        c1[j] = dot(a1, b);
        j += 1;
    }
}

/// 1×4 tail tile for an odd final row.
fn nt_row_single(a0: &[f64], p: usize, bt: &[f64], n: usize, c0: &mut [f64]) {
    let mut j = 0;
    while j + 4 <= n {
        let b0 = &bt[j * p..(j + 1) * p];
        let b1 = &bt[(j + 1) * p..(j + 2) * p];
        let b2 = &bt[(j + 2) * p..(j + 3) * p];
        let b3 = &bt[(j + 3) * p..(j + 4) * p];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
        for t in 0..p {
            let x = a0[t];
            s0 += x * b0[t];
            s1 += x * b1[t];
            s2 += x * b2[t];
            s3 += x * b3[t];
        }
        c0[j] = s0;
        c0[j + 1] = s1;
        c0[j + 2] = s2;
        c0[j + 3] = s3;
        j += 4;
    }
    while j < n {
        c0[j] = dot(a0, &bt[j * p..(j + 1) * p]);
        j += 1;
    }
}

/// y += alpha * x  (contiguous slices).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    // 4-way unrolled; the autovectorizer turns this into mul-add vectors.
    let n = x.len();
    let chunks = n / 4 * 4;
    let (xh, xt) = x.split_at(chunks);
    let (yh, yt) = y.split_at_mut(chunks);
    for (xc, yc) in xh.chunks_exact(4).zip(yh.chunks_exact_mut(4)) {
        yc[0] += alpha * xc[0];
        yc[1] += alpha * xc[1];
        yc[2] += alpha * xc[2];
        yc[3] += alpha * xc[3];
    }
    for (xi, yi) in xt.iter().zip(yt.iter_mut()) {
        *yi += alpha * xi;
    }
}

#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let chunks = x.len() / 4 * 4;
    let (xh, xt) = x.split_at(chunks);
    let (yh, yt) = y.split_at(chunks);
    for (xc, yc) in xh.chunks_exact(4).zip(yh.chunks_exact(4)) {
        acc0 += xc[0] * yc[0];
        acc1 += xc[1] * yc[1];
        acc2 += xc[2] * yc[2];
        acc3 += xc[3] * yc[3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for (xi, yi) in xt.iter().zip(yt.iter()) {
        acc += xi * yi;
    }
    acc
}

/// C = Aᵀ·B  (A: m×p, B: m×n → C: p×n), streaming both row-major operands
/// once — no explicit transpose is materialized.
pub fn matmul_tn(a: &DenseMat, b: &DenseMat) -> DenseMat {
    let mut c = DenseMat::zeros(a.cols(), b.cols());
    matmul_tn_into(a, b, &mut c);
    c
}

pub fn matmul_tn_into(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    let (m, p) = a.shape();
    let (mb, n) = b.shape();
    assert_eq!(m, mb, "matmul_tn: {:?}ᵀ x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (p, n));
    c.data_mut().fill(0.0);
    let cdata = c.data_mut();
    // bitwise-tier dispatch: simd::axpy reproduces the scalar axpy
    // exactly, so the TN product stays bitwise-stable across ISAs.
    let isa = simd::active();
    for i in 0..m {
        let arow = a.row(i);
        let brow = b.row(i);
        for (t, &ait) in arow.iter().enumerate() {
            if ait == 0.0 {
                continue;
            }
            simd::axpy(isa, ait, brow, &mut cdata[t * n..(t + 1) * n]);
        }
    }
}

/// C = A·Bᵀ (A: m×p, B: n×p → C: m×n): B is already the row-major
/// transpose of the logical right operand, so it packs straight into
/// tile-major panels and the product runs on the 2×8 microkernel.
pub fn matmul_nt(a: &DenseMat, b: &DenseMat) -> DenseMat {
    let mut c = DenseMat::zeros(a.rows(), b.rows());
    matmul_nt_into(a, b, &mut c);
    c
}

/// C = A·Bᵀ into a pre-allocated output (hot-path form; no allocation).
/// Dispatches to the packed-panel kernel when there are enough output
/// rows to amortize the n·p packing pass, and to the unpacked 2×4
/// reference tile otherwise.
pub fn matmul_nt_into(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    if a.rows() >= 4 {
        matmul_nt_into_packed(a, b, c);
    } else {
        matmul_nt_into_unpacked(a, b, c);
    }
}

/// The packed-panel NT product: packs B into the thread-local
/// [`PanelBuf`] (tile-major, zero-padded edge panel) and runs the 2×8
/// microkernel. Exposed so tests can pin it against the unpacked
/// reference on shapes the dispatcher would route elsewhere, and so
/// benches can compare the two directly.
pub fn matmul_nt_into_packed(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    matmul_nt_into_packed_isa(simd::active(), a, b, c);
}

/// [`matmul_nt_into_packed`] with an explicit kernel tier — the parity
/// suite pins every supported tier against the scalar oracle through
/// this entry point, and bitwise tests pin the Scalar tier against the
/// unpacked reference.
pub fn matmul_nt_into_packed_isa(
    isa: KernelIsa,
    a: &DenseMat,
    b: &DenseMat,
    c: &mut DenseMat,
) {
    let (m, p) = a.shape();
    let (n, pb) = b.shape();
    assert_eq!(p, pb, "matmul_nt: {:?} x {:?}ᵀ", a.shape(), b.shape());
    assert_eq!(c.shape(), (m, n));
    PANEL_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        let dst = buf.packed(n.div_ceil(NR) * NR * p);
        pack_bt_panels(b.data(), n, p, dst);
        let panels: &[f64] = dst;
        let adata = a.data();
        let cptr = SendPtr(c.data_mut().as_mut_ptr());
        parallel_for_chunks(m, 64, move |lo, hi| {
            simd::packed_nt_rows_isa(isa, adata, p, panels, n, lo, hi, cptr);
        });
    });
}

/// The unpacked PR-2 NT product (2×4 register tile streaming four
/// strided BT rows per tile). Reference oracle and few-row dispatch
/// target.
pub fn matmul_nt_into_unpacked(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    let (m, p) = a.shape();
    let (n, pb) = b.shape();
    assert_eq!(p, pb, "matmul_nt: {:?} x {:?}ᵀ", a.shape(), b.shape());
    assert_eq!(c.shape(), (m, n));
    let adata = a.data();
    let btdata = b.data();
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    parallel_for_chunks(m, 64, move |lo, hi| {
        nt_rows(adata, p, btdata, n, lo, hi, cptr);
    });
}

/// Gram matrix G = FᵀF (k×k), exploiting symmetry (SYRK): only the upper
/// triangle is accumulated, then mirrored.
pub fn gram(f: &DenseMat) -> DenseMat {
    let mut g = DenseMat::zeros(f.cols(), f.cols());
    gram_into(f, &mut g);
    g
}

/// G = FᵀF into a pre-allocated k×k output (hot-path form; the SYRK of
/// every alternating iteration writes into the [`IterWorkspace`] Gram
/// buffer instead of allocating).
///
/// [`IterWorkspace`]: crate::linalg::workspace::IterWorkspace
pub fn gram_into(f: &DenseMat, g: &mut DenseMat) {
    gram_into_isa(simd::active(), f, g);
}

/// [`gram_into`] with an explicit kernel tier (FMA tier: the upper-
/// triangle row update runs on [`simd::axpy_fma`]; the Scalar tier is
/// bitwise-identical to the historical scalar loop, which was already
/// an axpy over the `u ≥ t` row segment).
pub fn gram_into_isa(isa: KernelIsa, f: &DenseMat, g: &mut DenseMat) {
    let (m, k) = f.shape();
    assert_eq!(g.shape(), (k, k), "gram_into: output must be {k}x{k}");
    {
        let gd = g.data_mut();
        gd.fill(0.0);
        for i in 0..m {
            let row = f.row(i);
            for t in 0..k {
                let v = row[t];
                if v == 0.0 {
                    continue;
                }
                let grow = &mut gd[t * k..(t + 1) * k];
                simd::axpy_fma(isa, v, &row[t..], &mut grow[t..]);
            }
        }
    }
    for t in 0..k {
        for u in (t + 1)..k {
            let v = g.at(t, u);
            g.set(u, t, v);
        }
    }
}

/// Row/column block size of the symmetric kernel. A block pair touches
/// one SYMM_BLOCK² panel of X (128 KiB) plus two SYMM_BLOCK×k panels each
/// of F and the accumulator (64 KiB at k = 32) — comfortably L2-resident
/// while X itself streams through once.
pub(crate) const SYMM_BLOCK: usize = 128;

/// Map an upper-triangle pair index `p` (block-row-major enumeration
/// `(0,0),(0,1),…,(0,nb−1),(1,1),…`) back to its block coordinates.
/// Exact integer scan — O(nb), negligible against the O(block²·k) work
/// of one pair.
#[inline]
pub(crate) fn pair_to_blocks(mut p: usize, nb: usize) -> (usize, usize) {
    let mut ib = 0;
    let mut row = nb; // pairs remaining in block-row ib
    while p >= row {
        p -= row;
        ib += 1;
        row -= 1;
    }
    (ib, ib + p)
}

/// The deterministic pair-pool harness shared by the dense blocked SYMM
/// and the packed-triangular [`SymPacked`] kernel: run `pair_body(p, acc)`
/// for every `p in 0..npairs`, accumulating into `num_threads()` private
/// m×k slots (pair `p` always lands in slot `p % num_threads()`), then
/// reduce the slots into `out` in fixed slot order.
///
/// The slot count — the only structure that affects FP results — is
/// pinned to the **logical** width [`num_threads`]; the slots execute on
/// at most [`current_threads`] OS threads (slot `t` runs on worker
/// `t % phys`, each worker walking its slots in ascending order). A
/// thread budget therefore changes scheduling but never the result: a
/// batched trial running under `with_thread_budget(1)` produces the same
/// bits as a serial full-width run.
///
/// `pair_body` must only **accumulate** into `acc` (slots start zeroed)
/// and must write row blocks derived from its own pair only.
///
/// [`SymPacked`]: crate::linalg::packed::SymPacked
pub(crate) fn pair_pool_accumulate<F>(
    m: usize,
    k: usize,
    npairs: usize,
    out: &mut DenseMat,
    pair_body: F,
) where
    F: Fn(usize, &mut [f64]) + Sync,
{
    assert_eq!(out.shape(), (m, k), "pair_pool_accumulate: output must be {m}x{k}");
    if m == 0 || k == 0 {
        out.data_mut().fill(0.0);
        return;
    }
    let nt = num_threads().min(npairs).max(1);
    if nt == 1 {
        let od = out.data_mut();
        od.fill(0.0);
        for p in 0..npairs {
            pair_body(p, od);
        }
        return;
    }
    SYMM_ACC.with(|cell| {
        let mut pool_ref = cell.borrow_mut();
        let need = nt * m * k;
        if pool_ref.len() < need {
            pool_ref.resize(need, 0.0);
        }
        let pool: &mut [f64] = &mut pool_ref[..need];
        pool.fill(0.0);
        let phys = current_threads().min(nt);
        if phys <= 1 {
            // budgeted to one OS thread: same slots, same assignment,
            // same reduction — just executed sequentially.
            for (t, acc) in pool.chunks_mut(m * k).enumerate() {
                let mut p = t;
                while p < npairs {
                    pair_body(p, acc);
                    p += nt;
                }
            }
        } else {
            let pptr = SendPtr(pool.as_mut_ptr());
            let body = &pair_body;
            // Shared dispatch (persistent pool by default, scoped spawn
            // under SYMNMF_POOL=scoped): phys worker *slots*, each
            // walking accumulator slots w, w+phys, … in ascending order.
            // Slot-to-accumulator assignment depends only on nt and
            // phys, never on the executor, so both backends — and any
            // physical thread count the pool actually uses — produce
            // identical bits.
            pool::dispatch(phys, &|w| {
                let mut t = w;
                while t < nt {
                    // SAFETY: accumulator slot t is touched only by the
                    // dispatch slot with w == t % phys — disjoint.
                    let acc = unsafe {
                        std::slice::from_raw_parts_mut(pptr.0.add(t * m * k), m * k)
                    };
                    let mut p = t;
                    while p < npairs {
                        body(p, acc);
                        p += nt;
                    }
                    t += phys;
                }
            });
        }
        // Deterministic reduction: out[row] = Σ_t acc_t[row], in slot
        // order, row-parallel.
        let pool_s: &[f64] = pool;
        let optr = SendPtr(out.data_mut().as_mut_ptr());
        parallel_for_chunks(m, 256, move |lo, hi| {
            // SAFETY: disjoint row ranges per worker.
            let od = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add(lo * k), (hi - lo) * k)
            };
            od.copy_from_slice(&pool_s[lo * k..hi * k]);
            for t in 1..nt {
                let base = t * m * k;
                let part = &pool_s[base + lo * k..base + hi * k];
                for (o, &v) in od.iter_mut().zip(part) {
                    *o += v;
                }
            }
        });
    });
}

/// out = X·F where X is a large **symmetric** square matrix. Only blocks
/// on or above the block diagonal are read — strictly-lower off-diagonal
/// blocks are never touched, halving X traffic (diagonal blocks are read
/// in full, so X must still be stored as a complete square array; see
/// [`crate::linalg::packed::SymPacked`] for the storage that drops the
/// lower triangle too).
/// Dispatches to the cache-blocked kernel ([`symm_tall_into_blocked`])
/// for the shapes where the saved traffic pays off, and to the generic
/// [`matmul_into`] otherwise: small X, F wide enough that the panel
/// working set would spill L2, or a multi-worker accumulator-pool
/// overhead (≈ 2·nt·m·k element ops to zero + reduce) that would exceed
/// the ≈ m²/2 element reads it saves. The predicate uses the logical
/// [`num_threads`] so the chosen kernel — and therefore the FP result —
/// is independent of any thread budget.
pub fn symm_tall_into(x: &DenseMat, f: &DenseMat, out: &mut DenseMat) {
    let m = x.rows();
    let k = f.cols();
    let nt = num_threads();
    if k > 64 || m < 2 * SYMM_BLOCK || (nt > 1 && m < 4 * nt * k) {
        matmul_into(x, f, out);
        return;
    }
    symm_tall_into_blocked(x, f, out, SYMM_BLOCK);
}

/// The blocked symmetric kernel with an explicit block size (exposed so
/// tests can exercise multi-block tiling on small shapes and benchmarks
/// can sweep block sizes). X must be symmetric: only blocks on or above
/// the block diagonal are read (diagonal blocks in full, including their
/// strictly-lower entries); each off-diagonal block is applied to both
/// output panels. Accumulation and reduction run on the deterministic
/// pair-pool harness ([`pair_pool_accumulate`]) — deterministic for a
/// given process configuration, independent of thread budgets.
pub fn symm_tall_into_blocked(x: &DenseMat, f: &DenseMat, out: &mut DenseMat, block: usize) {
    symm_tall_into_blocked_isa(simd::active(), x, f, out, block);
}

/// [`symm_tall_into_blocked`] with an explicit kernel tier (FMA tier:
/// the per-row tile update runs on [`simd::axpy_fma`]; the Scalar tier
/// reproduces the historical scalar kernel bitwise).
pub fn symm_tall_into_blocked_isa(
    isa: KernelIsa,
    x: &DenseMat,
    f: &DenseMat,
    out: &mut DenseMat,
    block: usize,
) {
    let (m, mc) = x.shape();
    assert_eq!(m, mc, "symm_tall_into: X must be square, got {:?}", x.shape());
    let (mf, k) = f.shape();
    assert_eq!(m, mf, "symm_tall_into: X is {m}x{m} but F has {mf} rows");
    assert_eq!(out.shape(), (m, k), "symm_tall_into: output must be {m}x{k}");
    assert!(block >= 1, "symm_tall_into: block size must be positive");
    if m == 0 || k == 0 {
        out.data_mut().fill(0.0);
        return;
    }
    let nb = m.div_ceil(block);
    let npairs = nb * (nb + 1) / 2;
    let xd = x.data();
    let fd = f.data();
    pair_pool_accumulate(m, k, npairs, out, |p, acc| {
        let (ib, jb) = pair_to_blocks(p, nb);
        symm_block_pair(isa, xd, fd, m, k, block, ib, jb, acc);
    });
}

/// Apply the (ib, jb) upper-triangle block pair of symmetric X to F,
/// accumulating into `acc` (m×k row-major). For ib == jb this is the
/// plain diagonal-block product; for ib < jb the block X[I,J] is read
/// once and applied to both output panels:
/// acc[I] += X[I,J]·F[J] and acc[J] += X[I,J]ᵀ·F[I].
#[allow(clippy::too_many_arguments)]
fn symm_block_pair(
    isa: KernelIsa,
    xd: &[f64],
    fd: &[f64],
    m: usize,
    k: usize,
    block: usize,
    ib: usize,
    jb: usize,
    acc: &mut [f64],
) {
    let i0 = ib * block;
    let i1 = (i0 + block).min(m);
    let j0 = jb * block;
    let j1 = (j0 + block).min(m);
    if ib == jb {
        for i in i0..i1 {
            let xrow = &xd[i * m + j0..i * m + j1];
            let acci = &mut acc[i * k..(i + 1) * k];
            for (jj, &v) in xrow.iter().enumerate() {
                if v != 0.0 {
                    let j = j0 + jj;
                    simd::axpy_fma(isa, v, &fd[j * k..(j + 1) * k], acci);
                }
            }
        }
        return;
    }
    // Off-diagonal pair: i1 <= j0 by construction, so the I-panel and
    // J-panel of the accumulator can be split and written simultaneously.
    let (acc_i, acc_j) = acc.split_at_mut(j0 * k);
    for i in i0..i1 {
        let xrow = &xd[i * m + j0..i * m + j1];
        let fi = &fd[i * k..(i + 1) * k];
        let acci = &mut acc_i[i * k..(i + 1) * k];
        for (jj, &v) in xrow.iter().enumerate() {
            if v != 0.0 {
                let j = j0 + jj;
                simd::axpy_fma(isa, v, &fd[j * k..(j + 1) * k], acci);
                simd::axpy_fma(isa, v, fi, &mut acc_j[(j - j0) * k..(j - j0 + 1) * k]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{dim, forall};
    use crate::util::rng::Pcg64;
    use crate::util::threadpool::with_thread_budget;

    fn naive_matmul(a: &DenseMat, b: &DenseMat) -> DenseMat {
        let (m, k) = a.shape();
        let n = b.cols();
        DenseMat::from_fn(m, n, |i, j| {
            (0..k).map(|t| a.at(i, t) * b.at(t, j)).sum()
        })
    }

    #[test]
    fn matmul_matches_naive_property() {
        forall(
            20,
            100,
            |rng| {
                let m = dim(rng, 1, 30);
                let k = dim(rng, 1, 30);
                let n = dim(rng, 1, 30);
                (DenseMat::gaussian(m, k, rng), DenseMat::gaussian(k, n, rng))
            },
            |(a, b)| {
                let got = matmul(a, b);
                let want = naive_matmul(a, b);
                let err = got.diff_fro(&want);
                if err < 1e-10 * (1.0 + want.fro_norm()) {
                    Ok(())
                } else {
                    Err(format!("err={err}"))
                }
            },
        );
    }

    /// The skinny-B packed-panel path must agree with the naive product
    /// across non-multiple-of-tile shapes (odd row counts, masked edge
    /// panels at every width mod 8).
    #[test]
    fn skinny_register_tile_matches_naive() {
        let mut rng = Pcg64::seed_from_u64(11);
        for m in [1usize, 3, 31, 33, 65] {
            for n in [1usize, 3, 7, 31, 33, 64] {
                // ka >= 32 triggers the packed-panel path
                let ka = 37;
                let a = DenseMat::gaussian(m, ka, &mut rng);
                let b = DenseMat::gaussian(ka, n, &mut rng);
                let got = matmul(&a, &b);
                let want = naive_matmul(&a, &b);
                let err = got.diff_fro(&want);
                assert!(
                    err < 1e-12 * (1.0 + want.fro_norm()),
                    "m={m} n={n}: err={err}"
                );
            }
        }
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        forall(
            15,
            200,
            |rng| {
                let m = dim(rng, 1, 25);
                let p = dim(rng, 1, 25);
                let n = dim(rng, 1, 25);
                (DenseMat::gaussian(m, p, rng), DenseMat::gaussian(m, n, rng),
                 DenseMat::gaussian(n, p, rng))
            },
            |(a, b, c)| {
                let tn = matmul_tn(a, b);
                let tn_want = naive_matmul(&a.transpose(), b);
                if tn.diff_fro(&tn_want) > 1e-10 * (1.0 + tn_want.fro_norm()) {
                    return Err("tn mismatch".into());
                }
                let nt = matmul_nt(a, c);
                let nt_want = naive_matmul(a, &c.transpose());
                if nt.diff_fro(&nt_want) > 1e-10 * (1.0 + nt_want.fro_norm()) {
                    return Err("nt mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nt_into_matches_allocating_form() {
        let mut rng = Pcg64::seed_from_u64(12);
        for (m, p, n) in [(1, 5, 1), (3, 9, 7), (33, 31, 65), (65, 4, 33)] {
            let a = DenseMat::gaussian(m, p, &mut rng);
            let b = DenseMat::gaussian(n, p, &mut rng);
            let want = matmul_nt(&a, &b);
            let mut c = DenseMat::zeros(m, n);
            c.fill(99.0); // stale data must be overwritten
            matmul_nt_into(&a, &b, &mut c);
            assert!(c.diff_fro(&want) == 0.0, "({m},{p},{n})");
        }
    }

    /// The acceptance pinning: packed-panel GEMM vs the PR-2 unpacked
    /// reference (and the naive oracle) at 1e-12 across m,k ∈
    /// {1, 3, 7, 31, 33, 65} — widths 1/3/7 exercise the masked edge
    /// tile inside a single panel, 31/33/65 the panel-boundary masks.
    #[test]
    fn packed_nt_matches_unpacked_reference_across_shapes() {
        let mut rng = Pcg64::seed_from_u64(21);
        for m in [1usize, 3, 7, 31, 33, 65] {
            for n in [1usize, 3, 7, 31, 33, 65] {
                for p in [1usize, 7, 37] {
                    let a = DenseMat::gaussian(m, p, &mut rng);
                    let b = DenseMat::gaussian(n, p, &mut rng);
                    let mut packed = DenseMat::zeros(m, n);
                    packed.fill(41.0); // stale data must be overwritten
                    matmul_nt_into_packed(&a, &b, &mut packed);
                    let mut unpacked = DenseMat::zeros(m, n);
                    unpacked.fill(-17.0);
                    matmul_nt_into_unpacked(&a, &b, &mut unpacked);
                    let err = packed.diff_fro(&unpacked);
                    let scale = 1.0 + unpacked.fro_norm();
                    assert!(
                        err < 1e-12 * scale,
                        "m={m} n={n} p={p}: packed vs unpacked err={err}"
                    );
                    let want = naive_matmul(&a, &b.transpose());
                    let err = packed.diff_fro(&want);
                    assert!(
                        err < 1e-12 * scale,
                        "m={m} n={n} p={p}: packed vs naive err={err}"
                    );
                }
            }
        }
    }

    /// Budget-aware pack parallelism: the parallel panel-packing pass is
    /// bitwise-identical to a budget-1 serial pass at every width
    /// (packing is pure data movement — no FP accumulation to reorder).
    #[test]
    fn parallel_pack_matches_serial_bitwise() {
        use crate::linalg::workspace::PanelBuf;
        let mut rng = Pcg64::seed_from_u64(31);
        for (n, p) in [(1usize, 3usize), (7, 37), (64, 300), (129, 65), (1024, 33)] {
            let b = DenseMat::gaussian(n, p, &mut rng);
            let mut serial = PanelBuf::new();
            let len_s = with_thread_budget(1, || pack_nt_panels(&b, &mut serial));
            let mut par = PanelBuf::new();
            let len_p = pack_nt_panels(&b, &mut par);
            assert_eq!(len_s, len_p);
            let sv = serial.packed(len_s).to_vec();
            for (i, (x, y)) in sv.iter().zip(par.packed(len_p).iter()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "n={n} p={p}: packed element {i} differs"
                );
            }
        }
    }

    /// Wide-B NT products (the shapes whose packing splits across
    /// workers) stay bitwise budget-invariant and pinned to the unpacked
    /// oracle.
    #[test]
    fn packed_nt_wide_b_budget_invariant_bitwise() {
        let mut rng = Pcg64::seed_from_u64(32);
        let a = DenseMat::gaussian(37, 29, &mut rng);
        let b = DenseMat::gaussian(301, 29, &mut rng); // 38 panels → parallel pack
        let mut want = DenseMat::zeros(37, 301);
        matmul_nt_into_packed(&a, &b, &mut want);
        for budget in [1usize, 2, 3] {
            let mut got = DenseMat::zeros(37, 301);
            got.fill(13.0);
            with_thread_budget(budget, || matmul_nt_into_packed(&a, &b, &mut got));
            for (x, y) in want.data().iter().zip(got.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "budget={budget}");
            }
        }
        let mut oracle = DenseMat::zeros(37, 301);
        matmul_nt_into_unpacked(&a, &b, &mut oracle);
        let err = want.diff_fro(&oracle);
        assert!(err < 1e-12 * (1.0 + oracle.fro_norm()), "err={err}");
    }

    /// Zero-padding of the masked edge panel must contribute exact
    /// zeros: a one-column B against a long reduction is the worst case.
    #[test]
    fn packed_edge_panel_padding_is_exact() {
        let mut rng = Pcg64::seed_from_u64(22);
        let a = DenseMat::gaussian(6, 300, &mut rng);
        let b = DenseMat::gaussian(1, 300, &mut rng);
        let mut c = DenseMat::zeros(6, 1);
        // pinned to the Scalar tier: the bitwise claim below compares
        // against the unpacked scalar oracle, and FMA tiers are only
        // 1e-12-pinned, not bitwise.
        matmul_nt_into_packed_isa(simd::KernelIsa::Scalar, &a, &b, &mut c);
        let mut want = DenseMat::zeros(6, 1);
        matmul_nt_into_unpacked(&a, &b, &mut want);
        for (x, y) in c.data().iter().zip(want.data()) {
            // single-column output: both kernels accumulate t-sequentially
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// The issue's scalar-vs-SIMD parity grid for the packed NT
    /// microkernel: every supported tier vs the Scalar oracle at
    /// m,n ∈ {1,2,3,7,8,9,31,33,65} (all mask widths and tile tails),
    /// 1e-12 relative.
    #[test]
    fn packed_nt_simd_tiers_match_scalar_oracle() {
        let mut rng = Pcg64::seed_from_u64(71);
        let p = 37;
        for m in [1usize, 2, 3, 7, 8, 9, 31, 33, 65] {
            for n in [1usize, 2, 3, 7, 8, 9, 31, 33, 65] {
                let a = DenseMat::gaussian(m, p, &mut rng);
                let b = DenseMat::gaussian(n, p, &mut rng);
                let mut want = DenseMat::zeros(m, n);
                matmul_nt_into_packed_isa(simd::KernelIsa::Scalar, &a, &b, &mut want);
                for isa in simd::supported() {
                    let mut got = DenseMat::zeros(m, n);
                    got.fill(7.0); // stale data must be overwritten
                    matmul_nt_into_packed_isa(isa, &a, &b, &mut got);
                    let err = got.diff_fro(&want);
                    assert!(
                        err < 1e-12 * (1.0 + want.fro_norm()),
                        "isa={isa:?} m={m} n={n}: err={err}"
                    );
                }
            }
        }
    }

    /// Parity grid for the dispatched Gram kernel: every supported tier
    /// vs the Scalar oracle, 1e-12 relative (the Scalar tier itself is
    /// bitwise-identical to the historical loop).
    #[test]
    fn gram_simd_tiers_match_scalar_oracle() {
        let mut rng = Pcg64::seed_from_u64(72);
        for m in [1usize, 2, 3, 7, 8, 9, 31, 33, 65] {
            for k in [1usize, 2, 3, 7, 8, 9, 31, 33, 65] {
                let f = DenseMat::gaussian(m, k, &mut rng);
                let mut want = DenseMat::zeros(k, k);
                gram_into_isa(simd::KernelIsa::Scalar, &f, &mut want);
                for isa in simd::supported() {
                    let mut got = DenseMat::zeros(k, k);
                    gram_into_isa(isa, &f, &mut got);
                    let err = got.diff_fro(&want);
                    assert!(
                        err < 1e-12 * (1.0 + want.fro_norm()),
                        "isa={isa:?} m={m} k={k}: err={err}"
                    );
                }
            }
        }
    }

    /// Parity grid for the dispatched blocked SYMM: every supported
    /// tier vs the Scalar oracle across mask-edge shapes, 1e-12.
    #[test]
    fn symm_simd_tiers_match_scalar_oracle() {
        let mut rng = Pcg64::seed_from_u64(73);
        for m in [1usize, 3, 9, 31, 33, 65] {
            let x = random_symmetric(m, &mut rng);
            for k in [1usize, 2, 7, 8, 9, 33] {
                let f = DenseMat::gaussian(m, k, &mut rng);
                let mut want = DenseMat::zeros(m, k);
                symm_tall_into_blocked_isa(simd::KernelIsa::Scalar, &x, &f, &mut want, 8);
                for isa in simd::supported() {
                    let mut got = DenseMat::zeros(m, k);
                    got.fill(-2.0);
                    symm_tall_into_blocked_isa(isa, &x, &f, &mut got, 8);
                    let err = got.diff_fro(&want);
                    assert!(
                        err < 1e-12 * (1.0 + want.fro_norm()),
                        "isa={isa:?} m={m} k={k}: err={err}"
                    );
                }
            }
        }
    }

    /// A fixed dispatch choice must be exactly reproducible: repeated
    /// calls under each forced tier give bitwise-identical output (the
    /// recorded-ISA resume contract relies on this).
    #[test]
    fn forced_tiers_are_bitwise_reproducible_run_to_run() {
        let mut rng = Pcg64::seed_from_u64(74);
        let a = DenseMat::gaussian(33, 37, &mut rng);
        let b = DenseMat::gaussian(31, 37, &mut rng);
        for isa in simd::supported() {
            let mut first = DenseMat::zeros(33, 31);
            matmul_nt_into_packed_isa(isa, &a, &b, &mut first);
            for _ in 0..2 {
                let mut again = DenseMat::zeros(33, 31);
                matmul_nt_into_packed_isa(isa, &a, &b, &mut again);
                for (x, y) in first.data().iter().zip(again.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "isa={isa:?}");
                }
            }
        }
    }

    #[test]
    fn gram_matches_tn_and_is_symmetric_psd() {
        let mut rng = Pcg64::seed_from_u64(5);
        let f = DenseMat::gaussian(40, 9, &mut rng);
        let g = gram(&f);
        let want = matmul_tn(&f, &f);
        assert!(g.diff_fro(&want) < 1e-10);
        for i in 0..9 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..9 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seed_from_u64(6);
        let a = DenseMat::gaussian(8, 8, &mut rng);
        let i = DenseMat::eye(8);
        assert!(matmul(&a, &i).diff_fro(&a) < 1e-14);
        assert!(matmul(&i, &a).diff_fro(&a) < 1e-14);
    }

    #[test]
    fn dot_and_axpy() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut y = vec![1.0; 5];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
        assert_eq!(dot(&x, &x), 55.0);
    }

    fn random_symmetric(m: usize, rng: &mut Pcg64) -> DenseMat {
        let mut x = DenseMat::gaussian(m, m, rng);
        x.symmetrize();
        x
    }

    /// Blocked SYMM vs the generic GEMM at 1e-12, across
    /// non-multiple-of-block shapes and block sizes (including blocks
    /// larger than the matrix and single-row matrices).
    #[test]
    fn blocked_symm_matches_gemm_across_shapes() {
        let mut rng = Pcg64::seed_from_u64(13);
        for m in [1usize, 3, 31, 33, 65] {
            let x = random_symmetric(m, &mut rng);
            for k in [1usize, 3, 31, 33, 65] {
                let f = DenseMat::gaussian(m, k, &mut rng);
                let want = naive_matmul(&x, &f);
                for block in [4usize, 8, 32, 256] {
                    let mut out = DenseMat::zeros(m, k);
                    out.fill(-3.0); // stale data must be overwritten
                    symm_tall_into_blocked(&x, &f, &mut out, block);
                    let err = out.diff_fro(&want);
                    assert!(
                        err < 1e-12 * (1.0 + want.fro_norm()),
                        "m={m} k={k} block={block}: err={err}"
                    );
                }
            }
        }
    }

    /// The pair index inversion must reproduce the block-row-major
    /// upper-triangle enumeration exactly.
    #[test]
    fn pair_to_blocks_inverts_enumeration() {
        for nb in [1usize, 2, 3, 7, 16] {
            let mut p = 0;
            for ib in 0..nb {
                for jb in ib..nb {
                    assert_eq!(pair_to_blocks(p, nb), (ib, jb), "nb={nb} p={p}");
                    p += 1;
                }
            }
            assert_eq!(p, nb * (nb + 1) / 2);
        }
    }

    /// The public dispatcher must agree with the generic GEMM on a shape
    /// large enough to take the blocked path — sized from num_threads()
    /// so the dispatch predicate (m ≥ 4·nt·k) selects the blocked kernel
    /// on any machine, not just small-core-count ones.
    #[test]
    fn symm_dispatch_matches_gemm_on_blocked_shape() {
        let mut rng = Pcg64::seed_from_u64(14);
        let k = 9;
        // + 37 keeps m off the block-size multiples
        let m = (2 * SYMM_BLOCK).max(4 * num_threads() * k) + 37;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, k, &mut rng);
        let mut got = DenseMat::zeros(m, k);
        symm_tall_into(&x, &f, &mut got);
        let want = matmul(&x, &f);
        let err = got.diff_fro(&want);
        assert!(err < 1e-12 * (1.0 + want.fro_norm()), "err={err}");
    }

    /// Same input, repeated calls → bitwise-identical output (the batched
    /// multi-seed driver relies on kernel determinism). Calls the blocked
    /// kernel directly with a small block so the multi-slot
    /// accumulator-pool path runs regardless of the dispatch heuristic.
    #[test]
    fn blocked_symm_is_deterministic() {
        let mut rng = Pcg64::seed_from_u64(15);
        let m = 300;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, 8, &mut rng);
        let mut a = DenseMat::zeros(m, 8);
        let mut b = DenseMat::zeros(m, 8);
        symm_tall_into_blocked(&x, &f, &mut a, 64);
        symm_tall_into_blocked(&x, &f, &mut b, 64);
        for (va, vb) in a.data().iter().zip(b.data()) {
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }

    /// A thread budget must not change a single bit of the blocked SYMM:
    /// the accumulator-slot geometry is pinned to num_threads(), the
    /// budget only reschedules the slots onto fewer OS threads.
    #[test]
    fn blocked_symm_is_budget_invariant_bitwise() {
        let mut rng = Pcg64::seed_from_u64(16);
        let m = 300;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, 8, &mut rng);
        let mut full = DenseMat::zeros(m, 8);
        symm_tall_into_blocked(&x, &f, &mut full, 64);
        for budget in [1usize, 2, 3] {
            let mut capped = DenseMat::zeros(m, 8);
            with_thread_budget(budget, || {
                symm_tall_into_blocked(&x, &f, &mut capped, 64);
            });
            for (va, vb) in full.data().iter().zip(capped.data()) {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "budget={budget} changed the SYMM result"
                );
            }
        }
    }
}
