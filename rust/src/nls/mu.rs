//! Multiplicative Updates (Lee & Seung [39], App. E) in Update(G, Y) form:
//!
//! ```text
//!     W_ij ← W_ij · Y_ij / (W·G)_ij
//! ```
//!
//! Requires Y ≥ 0 (true for nonnegative X and the regularized RHS); a
//! small ε guards the denominator. Included for completeness of the
//! Appendix-E rule set and as an extra baseline in the ablations.

use crate::linalg::{blas, DenseMat};

const EPS: f64 = 1e-16;

/// One multiplicative update of every entry of `w` given (G, Y).
/// Allocating wrapper over [`mu_update_ws`].
pub fn mu_update(g: &DenseMat, y: &DenseMat, w: &mut DenseMat) {
    let mut wg = DenseMat::zeros(w.rows(), w.cols());
    mu_update_ws(g, y, w, &mut wg);
}

/// Multiplicative update with a caller-provided m×k buffer for the W·G
/// denominator product (hot-path form; no allocation).
pub fn mu_update_ws(g: &DenseMat, y: &DenseMat, w: &mut DenseMat, wg: &mut DenseMat) {
    let (m, k) = w.shape();
    assert_eq!(g.shape(), (k, k));
    assert_eq!(y.shape(), (m, k));
    assert_eq!(wg.shape(), (m, k), "mu_update_ws wg shape");
    blas::matmul_into(w, g, wg);
    for i in 0..m {
        let wrow = w.row_mut(i);
        let yrow = y.row(i);
        let grow = wg.row(i);
        for j in 0..k {
            let numer = yrow[j].max(0.0);
            wrow[j] *= numer / (grow[j] + EPS);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn mk(m: usize, k: usize, seed: u64) -> (DenseMat, DenseMat, DenseMat, DenseMat, DenseMat) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let u = DenseMat::uniform(m, k, 1.0, &mut rng);
        let x = blas::matmul_nt(&u, &u);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let w = DenseMat::uniform(m, k, 1.0, &mut rng);
        let g = blas::gram(&h);
        let y = blas::matmul(&x, &h);
        (x, h, w, g, y)
    }

    #[test]
    fn stays_nonnegative() {
        let (_x, _h, mut w, g, y) = mk(20, 4, 1);
        for _ in 0..5 {
            mu_update(&g, &y, &mut w);
        }
        assert!(w.is_nonneg());
    }

    #[test]
    fn does_not_increase_objective() {
        let (x, h, mut w, g, y) = mk(25, 3, 2);
        let obj = |wm: &DenseMat| {
            let rec = blas::matmul_nt(wm, &h);
            let mut d = x.clone();
            d.axpy(-1.0, &rec);
            d.fro_norm_sq()
        };
        let mut prev = obj(&w);
        for _ in 0..10 {
            mu_update(&g, &y, &mut w);
            let cur = obj(&w);
            assert!(cur <= prev + 1e-9, "{prev} → {cur}");
            prev = cur;
        }
    }

    #[test]
    fn fixed_point_at_exact_factorization() {
        // if X = HHᵀ exactly and W = H, the update leaves W ≈ unchanged
        let mut rng = Pcg64::seed_from_u64(3);
        let h = DenseMat::uniform(15, 3, 1.0, &mut rng);
        let x = blas::matmul_nt(&h, &h);
        let g = blas::gram(&h);
        let y = blas::matmul(&x, &h);
        let mut w = h.clone();
        mu_update(&g, &y, &mut w);
        assert!(w.diff_fro(&h) < 1e-10);
    }
}
