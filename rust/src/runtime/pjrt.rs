//! PJRT execution: HLO text → compile → execute on the CPU PJRT client
//! (through [`crate::runtime::backend`], the `xla`-crate facade).
//!
//! Executables compile lazily on first use and are cached for the life of
//! the runtime (one compiled executable per artifact — the AOT model).
//! The f64 (rust-native) ⇄ f32 (artifact) conversion happens here at the
//! boundary; [`literal_from_mat_buffered`] lets hot-path callers reuse one
//! host f32 staging buffer across calls instead of allocating 4·m·k bytes
//! per product.

use crate::err;
use crate::linalg::DenseMat;
use crate::runtime::backend as xla;
use crate::runtime::registry::{ArtifactSpec, Registry};
use crate::util::error::{Context, Error, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

/// A live PJRT CPU client plus the artifact registry and executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub registry: Registry,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    /// Create from an artifact directory (see [`Registry::load`]).
    pub fn new(artifact_dir: &Path) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let registry = Registry::load(artifact_dir).map_err(Error::msg)?;
        Ok(PjrtRuntime { client, registry, cache: RefCell::new(HashMap::new()) })
    }

    /// Create from the default artifact dir; Err if PJRT cannot start.
    pub fn from_default_dir() -> Result<PjrtRuntime> {
        Self::new(&Registry::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compiled(&self, spec: &ArtifactSpec) -> Result<()> {
        let key = spec.path.to_string_lossy().to_string();
        if self.cache.borrow().contains_key(&key) {
            return Ok(());
        }
        let path_str = spec
            .path
            .to_str()
            .ok_or_else(|| err!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parse HLO text {path_str}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {path_str}"))?;
        self.cache.borrow_mut().insert(key, exe);
        Ok(())
    }

    /// Execute an artifact with f64 dense inputs (converted to f32),
    /// returning f64 dense outputs. Scalar inputs are passed as 0-d.
    pub fn execute(&self, spec: &ArtifactSpec, inputs: &[Input]) -> Result<Vec<DenseMat>> {
        if inputs.len() != spec.inputs.len() {
            return Err(err!(
                "artifact {} expects {} inputs, got {}",
                spec.program,
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (inp, shape) in inputs.iter().zip(&spec.inputs) {
            literals.push(inp.to_literal(shape)?);
        }
        self.execute_literals(spec, &literals)
    }

    /// Execute with pre-built literals (hot-path form: callers can cache
    /// the literal of a large constant operand — e.g. the m×m data matrix
    /// X — instead of re-converting 8·m² bytes every call).
    pub fn execute_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        spec: &ArtifactSpec,
        literals: &[L],
    ) -> Result<Vec<DenseMat>> {
        self.compiled(spec)?;
        let key = spec.path.to_string_lossy().to_string();
        let cache = self.cache.borrow();
        let exe = cache.get(&key).expect("compiled above");
        let result = exe.execute(literals).context("execute artifact")?;
        let root = result[0][0].to_literal_sync().context("fetch result")?;
        // aot.py lowers with return_tuple=True → root is a tuple
        let parts = root.to_tuple().context("untuple result")?;
        if parts.len() != spec.outputs.len() {
            return Err(err!(
                "artifact {} returned {} outputs, expected {}",
                spec.program,
                parts.len(),
                spec.outputs.len()
            ));
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, shape) in parts.into_iter().zip(&spec.outputs) {
            let data: Vec<f32> = lit.to_vec().context("read output literal")?;
            let (r, c) = shape_rc(shape);
            outs.push(DenseMat::from_f32(r, c, &data));
        }
        Ok(outs)
    }
}

fn shape_rc(shape: &[usize]) -> (usize, usize) {
    match shape.len() {
        0 => (1, 1),
        1 => (shape[0], 1),
        2 => (shape[0], shape[1]),
        _ => panic!("rank > 2 artifact output unsupported"),
    }
}

/// An input value for artifact execution.
pub enum Input<'a> {
    Mat(&'a DenseMat),
    Scalar(f64),
}

/// Convert a dense f64 matrix to a shaped f32 literal (public so callers
/// can pre-convert and cache constant operands).
pub fn literal_from_mat(m: &DenseMat) -> Result<xla::Literal> {
    let mut scratch = Vec::new();
    literal_from_mat_buffered(m, &mut scratch)
}

/// Like [`literal_from_mat`] but staging the f32 conversion through a
/// caller-owned buffer, so per-iteration callers (the `products_*` hot
/// path) reuse one host allocation across the whole solve instead of
/// allocating 4·m·k bytes per call.
pub fn literal_from_mat_buffered(
    m: &DenseMat,
    scratch: &mut Vec<f32>,
) -> Result<xla::Literal> {
    m.write_f32_into(scratch);
    let lit = xla::Literal::vec1(scratch);
    let dims = [m.rows() as i64, m.cols() as i64];
    lit.reshape(&dims).context("reshape literal")
}

impl<'a> Input<'a> {
    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        match self {
            Input::Scalar(v) => {
                if !shape.is_empty() {
                    return Err(err!("scalar input for non-scalar shape {shape:?}"));
                }
                Ok(xla::Literal::scalar(*v as f32))
            }
            Input::Mat(m) => {
                let (r, c) = shape_rc(shape);
                if m.shape() != (r, c) {
                    return Err(err!(
                        "input shape {:?} ≠ artifact shape {shape:?}",
                        m.shape()
                    ));
                }
                let f32s = m.to_f32();
                let lit = xla::Literal::vec1(&f32s);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).context("reshape literal")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // PJRT round-trip tests live in rust/tests/runtime_pjrt.rs (they need
    // the built artifacts). Here: pure helpers.
    use super::*;

    #[test]
    fn shape_rc_cases() {
        assert_eq!(shape_rc(&[]), (1, 1));
        assert_eq!(shape_rc(&[5]), (5, 1));
        assert_eq!(shape_rc(&[3, 4]), (3, 4));
    }

    #[test]
    fn input_shape_mismatch_rejected() {
        let m = DenseMat::zeros(2, 3);
        let inp = Input::Mat(&m);
        assert!(inp.to_literal(&[3, 2]).is_err());
        assert!(inp.to_literal(&[2, 3]).is_ok());
        assert!(Input::Scalar(1.0).to_literal(&[1]).is_err());
        assert!(Input::Scalar(1.0).to_literal(&[]).is_ok());
    }

    #[test]
    fn buffered_literal_reuses_scratch() {
        let m = DenseMat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut scratch = Vec::new();
        let lit = literal_from_mat_buffered(&m, &mut scratch).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        let cap = scratch.capacity();
        let ptr = scratch.as_ptr();
        let _ = literal_from_mat_buffered(&m, &mut scratch).unwrap();
        assert_eq!(scratch.capacity(), cap);
        assert_eq!(scratch.as_ptr(), ptr, "staging buffer must be reused");
    }
}
