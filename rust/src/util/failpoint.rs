//! Deterministic fail-point injection for the crash-safety suite.
//!
//! Production code marks named failure sites with [`hit`] (or
//! [`hit_scoped`] for per-key variants like `slice:<job id>`). A site
//! does nothing until armed through the `SYMNMF_FAILPOINTS` environment
//! variable or, in tests, through [`scoped`]. When unarmed, a hit costs
//! exactly one relaxed atomic load — no locks, no allocation, no clock.
//!
//! ## Spec grammar
//!
//! ```text
//!   SYMNMF_FAILPOINTS = site=action [ , site=action ... ]
//!   action            = kind | kind_once | kind@N
//!   kind              = err | panic | exit
//! ```
//!
//! * `kind` alone fires on **every** hit of the site.
//! * `kind@N` fires on the **Nth** hit only (1-based) — hits are counted
//!   per site for the life of the process (or the [`scoped`] guard).
//! * `kind_once` is shorthand for `kind@1`.
//!
//! Example: `SYMNMF_FAILPOINTS=ckpt_save=err@3,spill_read=err_once,slice=panic@2`
//! fails the 3rd checkpoint save, fails the first spill-tile read (the
//! bounded retry then heals it), and panics the 2nd scheduler slice.
//!
//! ## Actions
//!
//! * `err` — [`hit`] returns `Err` with a message naming the site and
//!   hit count; the caller's normal error path takes it from there.
//!   Sites with no error path (e.g. `opcache_build`) escalate `err` to a
//!   panic and document that.
//! * `panic` — [`hit`] panics. Under the scheduler's panic isolation
//!   this marks the owning job `Failed` without killing the drain.
//! * `exit` — the process exits immediately with code [`EXIT_CODE`],
//!   simulating a hard crash for restart-recovery tests (no destructors,
//!   no unwinding — exactly what a crash looks like to the `JobStore`).
//!
//! ## Wired sites
//!
//! | site            | location                                  | error path |
//! |-----------------|-------------------------------------------|------------|
//! | `ckpt_save`     | `JobStore::save` (before the temp write)  | save `Err` |
//! | `spill_open`    | `SymPackedSpilled::open`                  | open `Err` |
//! | `spill_read`    | `SymPackedSpilled` tile fault (per attempt) | retried, then panic |
//! | `spill_write`   | `write_spill`                             | write `Err` |
//! | `opcache_build` | `OpCache::pin_or_build` (builder slot)    | escalates to panic |
//! | `slice`         | `Scheduler::run_slice` (inside the catch) | escalates to panic |
//!
//! Every site also checks the scoped variant `site:<key>` first (job id
//! for `ckpt_save`/`slice`), so a test can target one job of a fleet.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Process exit code of the `exit` action — distinctive enough that a
/// recovery test can assert the abort was the injected one.
pub const EXIT_CODE: i32 = 86;

const STATE_UNINIT: u8 = 0;
const STATE_OFF: u8 = 1;
const STATE_ARMED: u8 = 2;

/// Tri-state so the unarmed fast path is a single relaxed load with no
/// separate init flag: 0 = env not read yet, 1 = off, 2 = armed.
static STATE: AtomicU8 = AtomicU8::new(STATE_UNINIT);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Action {
    Err,
    Panic,
    Exit,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Trigger {
    /// fire on every hit
    Every,
    /// fire on the Nth hit only (1-based)
    At(u64),
}

#[derive(Debug)]
struct Site {
    action: Action,
    trigger: Trigger,
    hits: u64,
}

fn registry() -> &'static Mutex<HashMap<String, Site>> {
    static R: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Lock, recovering from poisoning: the registry holds plain counters,
/// and a panic-action site unwinds through callers that may re-enter.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn parse_spec(spec: &str) -> Result<HashMap<String, Site>, String> {
    let mut sites = HashMap::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (site, action) = part
            .split_once('=')
            .ok_or_else(|| format!("fail point {part:?}: expected site=action"))?;
        let site = site.trim();
        if site.is_empty() {
            return Err(format!("fail point {part:?}: empty site name"));
        }
        let action = action.trim();
        let (kind, trigger) = match action.split_once('@') {
            Some((k, n)) => {
                let n: u64 = n
                    .parse()
                    .map_err(|e| format!("fail point {site:?}: bad hit count {n:?}: {e}"))?;
                if n == 0 {
                    return Err(format!("fail point {site:?}: @N is 1-based, got @0"));
                }
                (k, Trigger::At(n))
            }
            None => match action.strip_suffix("_once") {
                Some(k) => (k, Trigger::At(1)),
                None => (action, Trigger::Every),
            },
        };
        let action = match kind {
            "err" => Action::Err,
            "panic" => Action::Panic,
            "exit" => Action::Exit,
            other => {
                return Err(format!(
                    "fail point {site:?}: unknown action {other:?} \
                     (err | panic | exit, optionally _once or @N)"
                ))
            }
        };
        if sites.contains_key(site) {
            return Err(format!("fail point {site:?} specified twice"));
        }
        sites.insert(site.to_string(), Site { action, trigger, hits: 0 });
    }
    Ok(sites)
}

/// Cold path of [`armed`]: read `SYMNMF_FAILPOINTS` once, under the
/// registry lock (idempotent if several threads race here).
#[cold]
fn init_from_env() -> bool {
    let mut reg = lock(registry());
    match STATE.load(Ordering::Relaxed) {
        STATE_OFF => return false,
        STATE_ARMED => return true,
        _ => {}
    }
    let sites = match std::env::var("SYMNMF_FAILPOINTS") {
        Ok(v) if !v.trim().is_empty() => match parse_spec(&v) {
            Ok(s) => s,
            // a malformed spec means the operator thinks injection is on;
            // running without it would silently invalidate the test
            Err(e) => panic!("SYMNMF_FAILPOINTS: {e}"),
        },
        _ => HashMap::new(),
    };
    let armed = !sites.is_empty();
    *reg = sites;
    STATE.store(if armed { STATE_ARMED } else { STATE_OFF }, Ordering::Relaxed);
    armed
}

/// Whether any fail point is armed. The steady-state cost — and the
/// whole cost of an unarmed [`hit`] — is this one relaxed atomic load.
#[inline]
pub fn armed() -> bool {
    match STATE.load(Ordering::Relaxed) {
        STATE_OFF => false,
        STATE_ARMED => true,
        _ => init_from_env(),
    }
}

/// Mark a failure site. Returns `Err` when an armed `err` action fires;
/// panics / exits for the other actions; otherwise `Ok(())`.
#[inline]
pub fn hit(site: &str) -> Result<(), String> {
    if !armed() {
        return Ok(());
    }
    hit_armed(site)
}

/// Mark a failure site with a per-key variant: checks `group:key` first
/// (its hits counted separately), then the bare `group` site. The
/// `format!` only runs when some fail point is armed, keeping the
/// unarmed path allocation-free.
#[inline]
pub fn hit_scoped(group: &str, key: &str) -> Result<(), String> {
    if !armed() {
        return Ok(());
    }
    hit_armed(&format!("{group}:{key}"))?;
    hit_armed(group)
}

fn hit_armed(site: &str) -> Result<(), String> {
    // decide under the lock, act after releasing it — a panic or exit
    // while holding the registry mutex would poison it for other sites
    let fired = {
        let mut reg = lock(registry());
        let Some(s) = reg.get_mut(site) else { return Ok(()) };
        s.hits += 1;
        let fire = match s.trigger {
            Trigger::Every => true,
            Trigger::At(n) => s.hits == n,
        };
        if !fire {
            return Ok(());
        }
        (s.action, s.hits)
    };
    let (action, n) = fired;
    match action {
        Action::Err => Err(format!("fail point {site:?} injected error (hit {n})")),
        Action::Panic => panic!("fail point {site:?} injected panic (hit {n})"),
        Action::Exit => {
            eprintln!("fail point {site:?} injected process exit (hit {n})");
            std::process::exit(EXIT_CODE);
        }
    }
}

/// Hits recorded so far for a site (0 if unknown) — test observability.
pub fn hits(site: &str) -> u64 {
    lock(registry()).get(site).map(|s| s.hits).unwrap_or(0)
}

/// Serializes tests that arm fail points; restores the env-derived
/// configuration on drop.
pub struct FailpointsGuard {
    _serial: MutexGuard<'static, ()>,
}

/// Arm `spec` for the guard's lifetime (test use). Guards serialize on a
/// global lock so concurrent tests cannot see each other's injections;
/// on drop the registry reverts to whatever `SYMNMF_FAILPOINTS` says.
/// Panics on a malformed spec.
pub fn scoped(spec: &str) -> FailpointsGuard {
    static SCOPE_LOCK: Mutex<()> = Mutex::new(());
    let serial = SCOPE_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let sites = parse_spec(spec).unwrap_or_else(|e| panic!("fail point spec: {e}"));
    let mut reg = lock(registry());
    let armed = !sites.is_empty();
    *reg = sites;
    STATE.store(if armed { STATE_ARMED } else { STATE_OFF }, Ordering::Relaxed);
    drop(reg);
    FailpointsGuard { _serial: serial }
}

impl Drop for FailpointsGuard {
    fn drop(&mut self) {
        let mut reg = lock(registry());
        reg.clear();
        // next armed() re-derives from the environment
        STATE.store(STATE_UNINIT, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_hits_are_free_and_ok() {
        let _fp = scoped(""); // explicitly empty: off, and serialized
        assert!(!armed());
        assert!(hit("anything").is_ok());
        assert!(hit_scoped("slice", "job-1").is_ok());
    }

    #[test]
    fn err_fires_on_the_named_hit_only() {
        let _fp = scoped("ckpt_save=err@3");
        assert!(hit("ckpt_save").is_ok());
        assert!(hit("ckpt_save").is_ok());
        let e = hit("ckpt_save").expect_err("3rd hit must fail");
        assert!(e.contains("ckpt_save") && e.contains("hit 3"), "{e}");
        assert!(hit("ckpt_save").is_ok(), "one-shot trigger: 4th hit passes");
        assert_eq!(hits("ckpt_save"), 4);
        assert!(hit("other_site").is_ok(), "unmatched sites never fire");
    }

    #[test]
    fn once_is_shorthand_for_at_1_and_bare_fires_every_hit() {
        let _fp = scoped("a=err_once, b=err");
        assert!(hit("a").is_err());
        assert!(hit("a").is_ok());
        assert!(hit("b").is_err());
        assert!(hit("b").is_err());
    }

    #[test]
    fn scoped_variant_matches_before_the_group_site() {
        let _fp = scoped("slice:victim=err_once");
        assert!(hit_scoped("slice", "bystander").is_ok());
        assert!(hit_scoped("slice", "victim").is_err());
        assert!(hit_scoped("slice", "victim").is_ok(), "once: disarmed");
        assert_eq!(hits("slice:victim"), 2);
        assert_eq!(hits("slice"), 1, "the bare site still counts the pass-through");
    }

    #[test]
    fn panic_action_panics_with_the_site_name() {
        let _fp = scoped("boom=panic_once");
        let p = std::panic::catch_unwind(|| hit("boom")).expect_err("must panic");
        let msg = p.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("boom") && msg.contains("injected panic"), "{msg}");
        assert!(hit("boom").is_ok(), "disarmed after firing once");
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "no_equals",
            "s=frobnicate",
            "s=err@0",
            "s=err@x",
            "s=err,s=panic",
            "=err",
        ] {
            assert!(parse_spec(bad).is_err(), "spec {bad:?} must be rejected");
        }
        // well-formed corner cases parse
        assert!(parse_spec("").unwrap().is_empty());
        assert_eq!(parse_spec("a=exit@5, b=panic").unwrap().len(), 2);
    }

    #[test]
    fn guard_drop_restores_the_env_configuration() {
        {
            let _fp = scoped("x=err");
            assert!(hit("x").is_err());
        }
        // after the guard: env has no SYMNMF_FAILPOINTS in the test
        // runner, so the registry re-derives to off (or stays consistent
        // with the env if the suite was launched with injection on)
        if std::env::var("SYMNMF_FAILPOINTS").is_err() {
            assert!(hit("x").is_ok());
        }
    }
}
