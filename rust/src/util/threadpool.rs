//! Data-parallel helpers (rayon is unavailable offline).
//!
//! `parallel_for_chunks` splits an index range into contiguous chunks
//! and hands them to the process-wide dispatcher in [`crate::util::pool`],
//! which executes them on one of two backends (`SYMNMF_POOL`):
//!
//! * `pooled` (default) — persistent `symnmf-pool-N` workers spawned
//!   once per process, Condvar-parked when idle, fed by epoch-stamped
//!   broadcast. No per-call OS spawn/join on the kernel hot path.
//! * `scoped` — a fresh `std::thread::scope` per call, the historical
//!   implementation, kept as the pinning oracle.
//!
//! On a 1-core image both degrade gracefully to a sequential loop with
//! no threads at all; on multicore machines the dense kernels in
//! `linalg::blas`, the CSR SpMM, and the batched trial driver pick the
//! dispatcher up.
//!
//! ## Logical width vs physical width (the thread-budget contract)
//!
//! Two distinct thread counts govern every kernel:
//!
//! * **Logical width** — [`num_threads`], resolved once per process.
//!   Any structure that affects floating-point results (the blocked-SYMM
//!   accumulator count and its fixed reduction order, the SYMM dispatch
//!   predicate) must be derived from this value ONLY, so results are a
//!   function of the process configuration, never of scheduling.
//! * **Physical width** — [`current_threads`], the logical width capped
//!   by the innermost [`with_thread_budget`] scope on the calling thread.
//!   It bounds how many OS threads a parallel construct may occupy —
//!   chunk counts are capped by it, so a budgeted scope's dispatch never
//!   asks for more slots than its cap.
//!
//! The contract that makes the cap harmless: every `parallel_for_chunks`
//! body computes each index's result independently of the partitioning
//! (all call sites are per-row writes with no cross-chunk reduction), so
//! shrinking the physical width changes scheduling but not one bit of
//! output. Kernels whose FP order *does* depend on a worker count (the
//! SYMM accumulator pool) keep `num_threads()` accumulator slots and
//! merely run those slots on fewer OS threads — see
//! `linalg::blas::pair_pool_accumulate`. This is what lets
//! `run_trials_batched` split the machine between trial workers and
//! inner kernels while staying bitwise identical to the serial driver.
//!
//! ## Why the backend cannot change bits
//!
//! The worker count is resolved **once per process** (see
//! [`num_threads`]), chunk sizes are balanced to within one element, and
//! every dispatch is expressed as "run these `chunks` slot closures" —
//! geometry is fixed *before* the executor is chosen. The pooled backend
//! additionally runs nested dispatch inline on the calling slot (the
//! reentrancy rule in [`crate::util::pool`]): the nested call's chunk
//! geometry is still computed from its budget exactly as under scoped
//! spawning, only the threads it occupies change. Pool choice is
//! consequently never serialized into checkpoints or trace headers —
//! unlike the kernel ISA, it cannot change results, so resume never
//! needs to validate it.
//!
//! ## Panic semantics
//!
//! Both backends run every chunk even if a sibling chunk panics, and
//! rethrow the first panic on the submitting thread after all chunks
//! finish — so `catch_unwind` isolation (the serve scheduler's per-slice
//! guard) behaves identically under either backend.

use std::cell::Cell;
use std::sync::OnceLock;

use super::pool;

/// Raw mutable pointer wrapper so disjoint index ranges of one output
/// buffer can be written from scoped worker threads. Shared by the dense
/// kernels, the CSR SpMM, and the HALS sweep.
///
/// SAFETY contract for users: every worker must write only through
/// offsets derived from its own disjoint `(lo, hi)` range, and the
/// pointee must outlive the parallel call (guaranteed because
/// [`pool::dispatch`] does not return until every slot completes, on
/// either backend).
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Cached worker count, resolved on first use. `parallel_for_chunks` is
/// called from inside every hot kernel, so re-reading (and re-parsing)
/// the environment per call would put a syscall on the per-iteration
/// path.
static NUM_THREADS: OnceLock<usize> = OnceLock::new();

thread_local! {
    /// Innermost thread budget on this thread: 0 = unbudgeted (full
    /// machine width). Set only through [`with_thread_budget`].
    static BUDGET: Cell<usize> = const { Cell::new(0) };
}

/// Number of worker threads to use: `SYMNMF_THREADS` env or available
/// parallelism. Resolved once per process and cached — changing the
/// environment variable after the first kernel call has no effect.
///
/// This is the **logical** width: FP-affecting kernel geometry (the
/// SYMM accumulator count, dispatch predicates) must use it, never
/// [`current_threads`], so results are budget-independent.
pub fn num_threads() -> usize {
    *NUM_THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("SYMNMF_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Effective **physical** width for the calling thread: [`num_threads`]
/// capped by the innermost [`with_thread_budget`] scope. Parallel
/// constructs spawn at most this many workers; it never influences what
/// is computed, only how many OS threads compute it.
pub fn current_threads() -> usize {
    let b = BUDGET.with(Cell::get);
    let nt = num_threads();
    if b == 0 {
        nt
    } else {
        b.min(nt)
    }
}

/// Run `f` with this thread's physical width capped at `n` (floored at
/// 1). Budgets nest by taking the minimum, and the previous budget is
/// restored when the scope ends — including on unwind, so a panicking
/// trial worker does not leak its cap to later work on a pooled thread.
///
/// The budget is per-thread: the batched trial driver sets it *inside*
/// each trial worker's closure, so each worker (and every kernel the
/// solver runs on that worker) sees the split width while the kernels'
/// FP geometry stays pinned to [`num_threads`].
pub fn with_thread_budget<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            BUDGET.with(|b| b.set(self.0));
        }
    }
    let prev = BUDGET.with(Cell::get);
    let cap = if prev == 0 { n.max(1) } else { prev.min(n.max(1)) };
    let _restore = Restore(prev);
    BUDGET.with(|b| b.set(cap));
    f()
}

/// The `c`-th of `chunks` balanced contiguous ranges covering `0..n`:
/// the first `n % chunks` ranges get one extra element, so sizes differ
/// by at most one. The previous `div_ceil` sizing gave every chunk
/// ⌈n/chunks⌉ elements and dumped the shortfall on the tail — e.g. 97
/// rows over 4 workers split 25/25/25/22, and 9 rows over 8 workers left
/// 3 workers with nothing at all. Balanced sizing keeps the slowest
/// worker's share minimal, which matters when the chunk body is the
/// memory-bound inner loop of a kernel.
fn chunk_range(n: usize, chunks: usize, c: usize) -> (usize, usize) {
    debug_assert!(chunks >= 1 && c < chunks);
    let base = n / chunks;
    let rem = n % chunks;
    let lo = c * base + c.min(rem);
    let hi = lo + base + usize::from(c < rem);
    (lo, hi)
}

/// Run `body(lo, hi)` over disjoint subranges covering `0..n` in parallel.
/// `body` must be safe to run concurrently on disjoint ranges, and must
/// compute each index's result independently of the partitioning (every
/// call site is a per-row write) — that is what makes the thread-budget
/// cap on the worker count output-neutral.
pub fn parallel_for_chunks<F>(n: usize, min_chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let nt = current_threads();
    if nt <= 1 || n <= min_chunk {
        body(0, n);
        return;
    }
    let chunks = nt.min(n.div_ceil(min_chunk)).max(1);
    // Slots run under an even split of this scope's width, so nested
    // parallel constructs inside `body` cannot oversubscribe a budgeted
    // scope. Pool workers restore their budget on slot exit, so the cap
    // never leaks between jobs.
    let child = (nt / chunks).max(1);
    pool::dispatch(chunks, &|c| {
        let (lo, hi) = chunk_range(n, chunks, c);
        if lo < hi {
            with_thread_budget(child, || body(lo, hi));
        }
    });
}

/// Map over `0..n`, writing results into a pre-allocated vec (each index
/// written exactly once by one worker). Worker count is capped by the
/// calling thread's budget; slot results are independent of the
/// partitioning, so the cap is output-neutral.
pub fn parallel_map_into<T: Send + Sync, F>(out: &mut [T], min_chunk: usize, f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    let n = out.len();
    let nt = current_threads();
    if nt <= 1 || n <= min_chunk {
        for (i, slot) in out.iter_mut().enumerate() {
            f(i, slot);
        }
        return;
    }
    let chunks = nt.min(n.div_ceil(min_chunk)).max(1);
    // Even split of this scope's width, as in `parallel_for_chunks`: the
    // batched trial driver's solver bodies nest kernel parallelism, and
    // inheritance is what keeps a budgeted batched run's total OS-thread
    // demand at ≈ the budget.
    let child = (nt / chunks).max(1);
    // Raw-pointer partitioning (balanced to within one element via
    // chunk_range): a slot closure shared by every worker cannot carry
    // per-chunk `&mut` slices, so disjointness is by-range instead of
    // by-split_at_mut. SAFETY: chunk ranges tile 0..n without overlap,
    // each slot touches only its own range, and `out` outlives the
    // dispatch (it does not return until every slot completes).
    struct Base<T>(*mut T);
    unsafe impl<T: Send> Send for Base<T> {}
    unsafe impl<T: Sync> Sync for Base<T> {}
    let base = Base(out.as_mut_ptr());
    pool::dispatch(chunks, &|c| {
        let (lo, hi) = chunk_range(n, chunks, c);
        if lo < hi {
            with_thread_budget(child, || {
                for i in lo..hi {
                    let slot = unsafe { &mut *base.0.add(i) };
                    f(i, slot);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let n = 1000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 10, |lo, hi| {
            for i in lo..hi {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_into_writes_each_slot() {
        let mut out = vec![0usize; 257];
        parallel_map_into(&mut out, 8, |i, slot| *slot = i * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn empty_range_ok() {
        parallel_for_chunks(0, 1, |_, _| panic!("must not be called"));
    }

    #[test]
    fn num_threads_is_cached_and_positive() {
        let a = num_threads();
        let b = num_threads();
        assert!(a >= 1);
        assert_eq!(a, b, "cached value must be stable");
    }

    /// Balanced split: ranges tile 0..n exactly and sizes differ by ≤ 1.
    #[test]
    fn chunk_ranges_are_balanced() {
        for n in [1usize, 2, 7, 130, 1000, 1025] {
            for chunks in 1..=8usize.min(n) {
                let mut next = 0usize;
                let mut sizes = Vec::new();
                for c in 0..chunks {
                    let (lo, hi) = chunk_range(n, chunks, c);
                    assert_eq!(lo, next, "ranges must tile contiguously");
                    assert!(hi >= lo);
                    sizes.push(hi - lo);
                    next = hi;
                }
                assert_eq!(next, n, "ranges must cover 0..n");
                let max = *sizes.iter().max().unwrap();
                let min = *sizes.iter().min().unwrap();
                assert!(max - min <= 1, "n={n} chunks={chunks}: {sizes:?}");
            }
        }
    }

    /// Budgets cap, nest by min, and restore on scope exit.
    #[test]
    fn thread_budget_caps_nests_and_restores() {
        let full = num_threads();
        assert_eq!(current_threads(), full, "unbudgeted = full width");
        with_thread_budget(1, || {
            assert_eq!(current_threads(), 1);
            // nesting can only tighten, never widen
            with_thread_budget(8, || {
                assert_eq!(current_threads(), 1);
            });
            assert_eq!(current_threads(), 1);
        });
        assert_eq!(current_threads(), full, "budget must restore");
        with_thread_budget(2, || {
            assert_eq!(current_threads(), 2.min(full));
        });
        // a zero request is floored at one, not treated as "unbudgeted"
        with_thread_budget(0, || {
            assert_eq!(current_threads(), 1);
        });
    }

    /// The budget restores even when the scope unwinds (pooled trial
    /// workers must not leak caps into later work).
    #[test]
    fn thread_budget_restores_on_panic() {
        let full = current_threads();
        let r = std::panic::catch_unwind(|| {
            with_thread_budget(1, || panic!("boom"));
        });
        assert!(r.is_err());
        assert_eq!(current_threads(), full, "budget leaked past unwind");
    }

    /// The two dispatch backends produce identical output from the
    /// same construct (here: every index written once with the same
    /// value) — the geometry is fixed before the executor is chosen.
    #[test]
    fn for_chunks_is_backend_invariant() {
        let run = |backend| {
            let _g = pool::override_backend(backend);
            let mut v = vec![0.0f64; 1031];
            let p = SendPtr(v.as_mut_ptr());
            parallel_for_chunks(v.len(), 16, |lo, hi| {
                for i in lo..hi {
                    unsafe { *p.0.add(i) = (i as f64) * 3.0 + 1.0 };
                }
            });
            v
        };
        let pooled = run(pool::PoolBackend::Pooled);
        let scoped = run(pool::PoolBackend::Scoped);
        assert_eq!(pooled, scoped);
        assert!(pooled.iter().enumerate().all(|(i, &x)| x == (i as f64) * 3.0 + 1.0));
    }

    /// Nested parallelism (a map_into body that itself runs
    /// parallel_for_chunks) covers every index under both backends —
    /// on the pooled side this exercises the inline reentrancy path
    /// that a naive pool would deadlock on.
    #[test]
    fn nested_constructs_cover_indices_on_both_backends() {
        for backend in [pool::PoolBackend::Pooled, pool::PoolBackend::Scoped] {
            let _g = pool::override_backend(backend);
            let mut out = vec![0usize; 13];
            parallel_map_into(&mut out, 1, |i, slot| {
                let counts: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
                parallel_for_chunks(64, 4, |lo, hi| {
                    for j in lo..hi {
                        counts[j].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
                *slot = i + 100;
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i + 100, "{}", backend.as_str());
            }
        }
    }

    /// Under a budget the parallel constructs still cover every index
    /// exactly once (the cap is scheduling-only).
    #[test]
    fn budgeted_constructs_still_cover_indices() {
        with_thread_budget(2, || {
            let n = 513;
            let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            parallel_for_chunks(n, 4, |lo, hi| {
                for i in lo..hi {
                    counts[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
            let mut out = vec![0usize; 97];
            parallel_map_into(&mut out, 1, |i, slot| *slot = i + 1);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i + 1);
            }
        });
    }
}
