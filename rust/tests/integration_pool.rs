//! Dispatch-backend acceptance: every kernel entry point and every
//! end-to-end method must produce **bitwise identical** results under
//! `SYMNMF_POOL=pooled` (persistent workers) and `SYMNMF_POOL=scoped`
//! (per-call spawn, the pinning oracle). The backend only chooses where
//! slot closures execute — chunk geometry and accumulator-slot counts
//! are derived from the logical width before the executor is picked —
//! so any bit of divergence here is a pool bug, not an FP tolerance
//! question.

use std::path::PathBuf;

use symnmf::coordinator::driver::Method;
use symnmf::linalg::{blas, simd, DenseMat, SymPacked, SymPackedSpilled};
use symnmf::nls::{hals, UpdateRule};
use symnmf::sparse::CsrMat;
use symnmf::symnmf::engine::RunControl;
use symnmf::symnmf::options::{SymNmfOptions, Tau};
use symnmf::util::pool::{self, PoolBackend};
use symnmf::util::rng::Pcg64;

/// The shape sweep from the issue: covers the degenerate (1), the
/// sub-microkernel (3, 7), and both sides of every tile boundary
/// (31/33 around 32, 65 past 64).
const SIZES: [usize; 6] = [1, 3, 7, 31, 33, 65];

/// Run `f` once under each backend and return both results.
fn both<T>(f: impl Fn() -> T) -> (T, T) {
    let pooled = {
        let _g = pool::override_backend(PoolBackend::Pooled);
        f()
    };
    let scoped = {
        let _g = pool::override_backend(PoolBackend::Scoped);
        f()
    };
    (pooled, scoped)
}

fn assert_mats_bitwise(a: &DenseMat, b: &DenseMat, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}");
    }
}

fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
    let mut rng = Pcg64::seed_from_u64(seed);
    let h = DenseMat::uniform(m, k, 1.0, &mut rng);
    let mut x = blas::matmul_nt(&h, &h);
    x.symmetrize();
    x
}

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let d = std::env::temp_dir()
            .join(format!("symnmf-pool-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        TempDir(d)
    }
    fn file(&self, name: &str) -> PathBuf {
        self.0.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[test]
fn matmul_nt_packed_is_backend_invariant_per_isa() {
    for isa in simd::supported() {
        for m in SIZES {
            for k in SIZES {
                let mut rng = Pcg64::seed_from_u64(0xA11CE + (m * 67 + k) as u64);
                let a = DenseMat::gaussian(m, k, &mut rng);
                let b = DenseMat::gaussian(m + 2, k, &mut rng);
                let (p, s) = both(|| {
                    let mut c = DenseMat::zeros(m, m + 2);
                    blas::matmul_nt_into_packed_isa(isa, &a, &b, &mut c);
                    c
                });
                assert_mats_bitwise(&p, &s, &format!("matmul_nt {isa:?} m={m} k={k}"));
            }
        }
    }
}

#[test]
fn gram_is_backend_invariant_per_isa() {
    for isa in simd::supported() {
        for m in SIZES {
            for k in SIZES {
                let mut rng = Pcg64::seed_from_u64(0x6AA + (m * 67 + k) as u64);
                let f = DenseMat::gaussian(m, k, &mut rng);
                let (p, s) = both(|| {
                    let mut g = DenseMat::zeros(k, k);
                    blas::gram_into_isa(isa, &f, &mut g);
                    g
                });
                assert_mats_bitwise(&p, &s, &format!("gram {isa:?} m={m} k={k}"));
            }
        }
    }
}

/// Blocked SYMM with a small block so the pair-pool harness actually
/// fans out (m=65, block=8 → 81 block pairs over `num_threads()`
/// accumulator slots).
#[test]
fn blocked_symm_is_backend_invariant_per_isa() {
    for isa in simd::supported() {
        for m in SIZES {
            for k in SIZES {
                let x = planted(m, k.min(m), 0xB10C + (m * 67 + k) as u64);
                let mut rng = Pcg64::seed_from_u64(0xF + (m + k) as u64);
                let f = DenseMat::gaussian(m, k, &mut rng);
                let (p, s) = both(|| {
                    let mut out = DenseMat::zeros(m, k);
                    blas::symm_tall_into_blocked_isa(isa, &x, &f, &mut out, 8);
                    out
                });
                assert_mats_bitwise(&p, &s, &format!("symm {isa:?} m={m} k={k}"));
            }
        }
    }
}

#[test]
fn sympacked_apply_is_backend_invariant_per_isa() {
    for isa in simd::supported() {
        for m in SIZES {
            for k in SIZES {
                let x = planted(m, k.min(m), 0x9ACD + (m * 67 + k) as u64);
                let sp = SymPacked::from_dense_with_block(&x, 8);
                let mut rng = Pcg64::seed_from_u64(0x5EED + (m + k) as u64);
                let f = DenseMat::gaussian(m, k, &mut rng);
                let (p, s) = both(|| {
                    let mut out = DenseMat::zeros(m, k);
                    sp.apply_blocked_into_isa(isa, &f, &mut out);
                    out
                });
                assert_mats_bitwise(&p, &s, &format!("sympacked {isa:?} m={m} k={k}"));
            }
        }
    }
}

/// The out-of-core tier reuses the same pair harness; one spilled
/// operator per (isa) at the largest shape keeps the I/O bounded.
#[test]
fn spilled_apply_is_backend_invariant_per_isa() {
    let dir = TempDir::new("spill-parity");
    let m = 65;
    for isa in simd::supported() {
        for k in [1usize, 7, 33] {
            let x = planted(m, k, 0x0C0DE + k as u64);
            let sp = SymPacked::from_dense_with_block(&x, 8);
            let path = dir.file(&format!("x-{:?}-{k}.spill", isa));
            symnmf::linalg::spill::write_spill(&sp, &path).expect("write spill");
            let spilled = SymPackedSpilled::open(&path).expect("open spill");
            let mut rng = Pcg64::seed_from_u64(0xD15C + k as u64);
            let f = DenseMat::gaussian(m, k, &mut rng);
            let (p, s) = both(|| {
                let mut out = DenseMat::zeros(m, k);
                spilled.apply_blocked_into_isa(isa, &f, &mut out);
                out
            });
            assert_mats_bitwise(&p, &s, &format!("spilled {isa:?} k={k}"));
        }
    }
}

#[test]
fn hals_sweep_is_backend_invariant_per_isa() {
    for isa in simd::supported() {
        for m in SIZES {
            for k in SIZES {
                let mut rng = Pcg64::seed_from_u64(0x4A15 + (m * 67 + k) as u64);
                let h = DenseMat::uniform(m, k, 1.0, &mut rng);
                let g = blas::matmul_tn(&h, &h);
                let y = DenseMat::uniform(m, k, 1.0, &mut rng);
                let w0 = DenseMat::uniform(m, k, 1.0, &mut rng);
                let (p, s) = both(|| {
                    let mut w = w0.clone();
                    hals::hals_sweep_isa(isa, &g, &y, &mut w);
                    w
                });
                assert_mats_bitwise(&p, &s, &format!("hals {isa:?} m={m} k={k}"));
            }
        }
    }
}

#[test]
fn csr_spmm_is_backend_invariant() {
    for m in SIZES {
        for k in SIZES {
            let mut rng = Pcg64::seed_from_u64(0xC52 + (m * 67 + k) as u64);
            // ~30% dense symmetric pattern
            let mut trips = Vec::new();
            for i in 0..m {
                for j in i..m {
                    let v = rng.uniform();
                    if v < 0.3 {
                        trips.push((i, j, v));
                        if i != j {
                            trips.push((j, i, v));
                        }
                    }
                }
            }
            let x = CsrMat::from_coo(m, m, trips);
            let f = DenseMat::gaussian(m, k, &mut rng);
            let (p, s) = both(|| {
                let mut out = DenseMat::zeros(m, k);
                x.spmm_into(&f, &mut out);
                out
            });
            assert_mats_bitwise(&p, &s, &format!("spmm m={m} k={k}"));
        }
    }
}

/// Thread budgets are scheduling-only on either backend: the same SYMM
/// (the one kernel whose FP order depends on a worker count — its
/// accumulator slots are pinned to the logical width) must produce the
/// same bits at full width, under `with_thread_budget(1)`, and under a
/// nested budget, pooled and scoped alike.
#[test]
fn thread_budget_is_bitwise_invariant_on_both_backends() {
    use symnmf::util::threadpool::with_thread_budget;
    let m = 65;
    let k = 7;
    let x = planted(m, k, 0xB0D6E7);
    let mut rng = Pcg64::seed_from_u64(0xF00D);
    let f = DenseMat::gaussian(m, k, &mut rng);
    let apply = || {
        let mut out = DenseMat::zeros(m, k);
        blas::symm_tall_into_blocked_isa(simd::active(), &x, &f, &mut out, 8);
        out
    };
    let (p_full, s_full) = both(apply);
    assert_mats_bitwise(&p_full, &s_full, "budget full width");
    let (p_one, s_one) = both(|| with_thread_budget(1, apply));
    let (p_nest, s_nest) = both(|| with_thread_budget(2, || with_thread_budget(3, apply)));
    for (got, what) in [
        (&p_one, "pooled budget=1"),
        (&s_one, "scoped budget=1"),
        (&p_nest, "pooled nested budget"),
        (&s_nest, "scoped nested budget"),
    ] {
        assert_mats_bitwise(got, &p_full, what);
    }
}

/// End-to-end: one representative of every engine family, solved to
/// completion under each backend, pinned bitwise on factors and
/// residual history.
#[test]
fn methods_end_to_end_are_backend_invariant() {
    let x = planted(40, 3, 77);
    let methods = [
        Method::Exact(UpdateRule::Hals),
        Method::Exact(UpdateRule::Bpp),
        Method::Lai { rule: UpdateRule::Hals, refine: false },
        Method::Comp(UpdateRule::Hals),
        Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS },
        Method::Pgncg,
    ];
    for method in methods {
        let mut o = SymNmfOptions::new(3).with_seed(11);
        o.max_iters = 5;
        let (p, s) = both(|| {
            method
                .run_controlled(&x, &o, &RunControl::unlimited(), None)
                .result
        });
        assert_eq!(p.iters(), s.iters(), "{}", method.label());
        assert_mats_bitwise(&p.h, &s.h, &format!("{} H", method.label()));
        for (i, (ra, rb)) in p.records.iter().zip(&s.records).enumerate() {
            assert_eq!(
                ra.residual.to_bits(),
                rb.residual.to_bits(),
                "{} residual at iter {i}",
                method.label()
            );
        }
    }
}
