//! Method dispatch and multi-trial experiment driving.
//!
//! [`Method`] enumerates every algorithm variant of the paper's §5
//! labeling scheme ("a combination of these labels indicates the method
//! used": update rule × {plain, LAI, Comp} × {-IR} plus PGNCG variants
//! and LvS with its τ policy). [`run_trials`] repeats a method with
//! different seeds and aggregates the Table-2 statistics;
//! [`run_trials_batched`] runs the same seed schedule concurrently over
//! one shared read-only operator with bitwise-identical per-seed results.
//!
//! All dispatch drives the resumable solver engine
//! ([`crate::symnmf::engine`]) directly: [`Method::run_controlled`]
//! exposes deadline/pause/cancel budgets and checkpoint resume per
//! solve, and [`run_trials_batched_controlled`] extends that to whole
//! trial fleets (one checkpoint per seed) by submitting each trial as a
//! job to the serving scheduler ([`crate::serve`]) — batch experiments
//! and the serving path share one code path. The plain entry points
//! honor the `SYMNMF_DEADLINE_MS` environment deadline.

use crate::clustering::ari::adjusted_rand_index;
use crate::linalg::{DenseMat, SymPacked};
use crate::nls::UpdateRule;
use crate::randnla::SymOp;
use crate::serve::{sanitize_id, CachedOperator, JobSpec, OpCache, OpKey, Scheduler, SchedulerConfig};
use crate::symnmf::anls::symnmf_anls_run;
use crate::symnmf::compressed::compressed_symnmf_run;
use crate::symnmf::engine::{Checkpoint, EngineRun, RunControl, TraceSink};
use crate::symnmf::lai::lai_symnmf_run;
use crate::symnmf::lvs::lvs_symnmf_run;
use crate::symnmf::options::{SymNmfOptions, Tau};
use crate::symnmf::pgncg::{lai_pgncg_symnmf_run, pgncg_symnmf_run};
use crate::symnmf::trace::TraceFormat;
use crate::symnmf::SymNmfResult;

/// Every §5 algorithm variant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// deterministic regularized ANLS/HALS/MU ("BPP", "HALS")
    Exact(UpdateRule),
    /// LAI-SymNMF ("LAI-BPP", "LAI-HALS-IR", …)
    Lai { rule: UpdateRule, refine: bool },
    /// Compressed-NMF baseline ("Comp-BPP", "Comp-HALS")
    Comp(UpdateRule),
    /// PGNCG baseline
    Pgncg,
    /// LAI-PGNCG (+ IR)
    LaiPgncg { refine: bool },
    /// LvS-SymNMF with a τ policy ("LvS-HALS (τ=1/s)", …)
    Lvs { rule: UpdateRule, tau: Tau },
}

impl Method {
    /// §5 label.
    pub fn label(&self) -> String {
        match self {
            Method::Exact(r) => r.label().to_string(),
            Method::Lai { rule, refine } => {
                if *refine {
                    format!("LAI-{}-IR", rule.label())
                } else {
                    format!("LAI-{}", rule.label())
                }
            }
            Method::Comp(r) => format!("Comp-{}", r.label()),
            Method::Pgncg => "PGNCG".to_string(),
            Method::LaiPgncg { refine } => {
                if *refine {
                    "LAI-PGNCG-IR".to_string()
                } else {
                    "LAI-PGNCG".to_string()
                }
            }
            Method::Lvs { rule, tau } => {
                let t = match tau {
                    Tau::OneOverS => "τ=1/s".to_string(),
                    Tau::Fixed(v) if (*v - 1.0).abs() < 1e-12 => "τ=1".to_string(),
                    Tau::Fixed(v) => format!("τ={v}"),
                };
                format!("LvS-{} ({t})", rule.label())
            }
        }
    }

    /// Run once on `x` with the given base options (rule/τ/refine fields
    /// are overridden by the method variant), honoring the
    /// `SYMNMF_DEADLINE_MS` environment deadline like every plain entry
    /// point.
    pub fn run<X: SymOp>(&self, x: &X, base: &SymNmfOptions) -> SymNmfResult {
        self.run_controlled(x, base, &RunControl::from_env(), None).result
    }

    /// Drive the method's engine directly: explicit deadline/pause/
    /// cancel budget, optional checkpoint resume. All method dispatch
    /// funnels through here — [`Method::run`] and the trial drivers are
    /// thin layers on top, so every method gets deadline stopping and
    /// pause/resume from the one shared outer loop.
    pub fn run_controlled<X: SymOp>(
        &self,
        x: &X,
        base: &SymNmfOptions,
        ctrl: &RunControl,
        resume: Option<&Checkpoint>,
    ) -> EngineRun {
        self.run_controlled_traced(x, base, ctrl, resume, None)
    }

    /// [`Method::run_controlled`] with per-iteration streaming: every
    /// finished iteration's record goes through `trace` as it is
    /// produced (the serving layer hangs its JSONL/CSV file sinks and
    /// its cancellation hooks here).
    pub fn run_controlled_traced<X: SymOp>(
        &self,
        x: &X,
        base: &SymNmfOptions,
        ctrl: &RunControl,
        resume: Option<&Checkpoint>,
        trace: Option<&mut dyn TraceSink>,
    ) -> EngineRun {
        let mut opts = base.clone();
        match *self {
            Method::Exact(rule) => {
                opts.rule = rule;
                symnmf_anls_run(x, &opts, ctrl, resume, trace)
            }
            Method::Lai { rule, refine } => {
                opts.rule = rule;
                opts.refine = refine;
                lai_symnmf_run(x, &opts, ctrl, resume, trace)
            }
            Method::Comp(rule) => {
                opts.rule = rule;
                compressed_symnmf_run(x, &opts, ctrl, resume, trace)
            }
            Method::Pgncg => pgncg_symnmf_run(x, &opts, ctrl, resume, trace),
            Method::LaiPgncg { refine } => {
                opts.refine = refine;
                lai_pgncg_symnmf_run(x, &opts, ctrl, resume, trace)
            }
            Method::Lvs { rule, tau } => {
                opts.rule = rule;
                opts.tau = tau;
                lvs_symnmf_run(x, &opts, ctrl, resume, trace)
            }
        }
    }
}

/// Aggregated multi-trial statistics — the columns of the paper's
/// Table 2 / Tables 4–6.
#[derive(Clone, Debug)]
pub struct MethodStats {
    pub label: String,
    /// mean iterations until the stopping rule fired
    pub mean_iters: f64,
    /// mean total algorithm time (s)
    pub mean_time: f64,
    /// mean over trials of each trial's minimum residual
    pub avg_min_res: f64,
    /// overall minimum residual across trials
    pub min_res: f64,
    /// mean ARI vs ground truth (NaN when no labels)
    pub mean_ari: f64,
    /// the per-trial results (for convergence-curve CSVs)
    pub trials: Vec<SymNmfResult>,
}

/// The per-trial seed schedule shared by the serial and batched drivers:
/// trial `t` always runs with `base.seed + 1000·t + 1`, so the two paths
/// draw identical per-trial RNG streams.
fn trial_options(base: &SymNmfOptions, t: usize) -> SymNmfOptions {
    let mut opts = base.clone();
    opts.seed = base.seed.wrapping_add(1000 * t as u64 + 1);
    opts
}

/// Aggregate per-trial results into the Table-2 statistics.
fn aggregate(
    label: String,
    results: Vec<SymNmfResult>,
    labels: Option<&[usize]>,
) -> MethodStats {
    let trials = results.len();
    let mean_iters =
        results.iter().map(|r| r.iters() as f64).sum::<f64>() / trials as f64;
    let mean_time =
        results.iter().map(|r| r.total_secs()).sum::<f64>() / trials as f64;
    let avg_min_res =
        results.iter().map(|r| r.min_residual()).sum::<f64>() / trials as f64;
    let min_res = results
        .iter()
        .map(|r| r.min_residual())
        .fold(f64::INFINITY, f64::min);
    let mean_ari = match labels {
        Some(truth) => {
            results
                .iter()
                .map(|r| adjusted_rand_index(&r.cluster_assignments(), truth))
                .sum::<f64>()
                / trials as f64
        }
        None => f64::NAN,
    };
    MethodStats {
        label,
        mean_iters,
        mean_time,
        avg_min_res,
        min_res,
        mean_ari,
        trials: results,
    }
}

/// Run `trials` independent seeded runs serially and aggregate.
pub fn run_trials<X: SymOp>(
    method: Method,
    x: &X,
    base: &SymNmfOptions,
    labels: Option<&[usize]>,
    trials: usize,
) -> MethodStats {
    assert!(trials >= 1);
    let mut results = Vec::with_capacity(trials);
    for t in 0..trials {
        results.push(method.run(x, &trial_options(base, t)));
    }
    aggregate(method.label(), results, labels)
}

/// Batched multi-seed trials: the same seed schedule as [`run_trials`],
/// but trials run concurrently on worker threads over ONE shared
/// read-only operator — X (the dominant memory object) is resident once
/// and its traffic is amortized across seeds, while every trial builds
/// its own private `IterWorkspace` inside the solver it runs.
///
/// Per-seed results are **bitwise identical** to the serial path (a test
/// pins this): trial `t` draws the same RNG stream, and every kernel on
/// the iteration path is deterministic for a fixed process configuration
/// — row partitioning never affects per-row values, and the blocked SYMM
/// accumulator geometry is pinned to the logical `num_threads()` with a
/// fixed-order reduction. Only wall-clock fields differ.
///
/// The machine is split between trial workers and inner kernels with a
/// per-scope thread budget: with `nt = num_threads()` and `T` trials,
/// `min(nt, T)` trial workers each run their solver under
/// `with_thread_budget(nt / workers)`, so total OS-thread demand stays
/// ≈ nt instead of the nt² a fully nested run would spawn, and the
/// per-worker SYMM accumulator pools (nt·m·k f64 each) stop competing
/// for cores they cannot use. The budget caps only *physical*
/// concurrency — kernel FP geometry still derives from `num_threads()`
/// (see [`crate::util::threadpool`]) — which is what preserves the
/// bitwise serial≡batched guarantee. Per-trial `time_secs` still
/// reflects shared-machine wall clock, so use the serial path when
/// per-trial timings must be paper-comparable.
pub fn run_trials_batched<X: SymOp + Sync>(
    method: Method,
    x: &X,
    base: &SymNmfOptions,
    labels: Option<&[usize]>,
    trials: usize,
) -> MethodStats {
    run_trials_batched_controlled(
        method,
        x,
        base,
        labels,
        trials,
        &RunControl::from_env(),
        None,
    )
    .0
}

/// Batched multi-seed trials under an explicit engine budget — the
/// driver face of the resumable solver engine, expressed as a **fleet of
/// serve jobs**: every trial is one [`crate::serve::JobSpec`] (same seed
/// schedule as [`run_trials`], the caller's budget as the job budget,
/// the caller's cancel token shared fleet-wide) submitted to a
/// [`Scheduler`] with no slice granularity, so each trial runs exactly
/// one engine slice under the caller's [`RunControl`]. Batch experiments
/// and the serving path are therefore one code path — the scheduler owns
/// the worker split (min(nt, trials) workers, `with_thread_budget(nt /
/// workers)` inside each) that the pre-serve driver implemented by hand.
///
/// The whole fleet gets **deadline stopping, cancellation, and
/// pause/resume for free**: an interrupted call returns one
/// [`Checkpoint`] per trial, and passing those checkpoints back as
/// `resume` continues every trial bitwise where it stopped — the
/// concatenated fleet equals an uninterrupted run bit for bit (a test
/// pins this), because the budget machinery only ever cuts iteration
/// sequences short, never perturbs them.
pub fn run_trials_batched_controlled<X: SymOp + Sync>(
    method: Method,
    x: &X,
    base: &SymNmfOptions,
    labels: Option<&[usize]>,
    trials: usize,
    ctrl: &RunControl,
    resume: Option<&[Checkpoint]>,
) -> (MethodStats, Vec<Checkpoint>) {
    assert!(trials >= 1);
    if let Some(cps) = resume {
        assert_eq!(cps.len(), trials, "need one checkpoint per trial");
    }
    let mut sched = Scheduler::new(SchedulerConfig::default());
    let handles: Vec<_> = (0..trials)
        .map(|t| {
            let mut spec =
                JobSpec::new(format!("trial-{t}"), method, trial_options(base, t));
            spec.deadline_secs = ctrl.deadline_secs;
            spec.max_steps = ctrl.max_steps;
            spec.cancel = ctrl.cancel.clone();
            spec.resume = resume.map(|cps| cps[t].clone());
            sched.submit(x, spec).expect("trial job submission cannot fail")
        })
        .collect();
    sched.drain();
    let mut results = Vec::with_capacity(trials);
    let mut checkpoints = Vec::with_capacity(trials);
    for h in &handles {
        let o = h.outcome().expect("drained trial job has an outcome");
        results.push(o.expect_result().clone());
        checkpoints.push(o.expect_checkpoint().clone());
    }
    (aggregate(method.label(), results, labels), checkpoints)
}

/// [`run_trials_batched`] against a **cached** operator: the fleet does
/// not borrow X — every trial job pins `key` in the shared [`OpCache`]
/// per slice (building via `build` only on a cold miss), so many fleets
/// over many graphs share one resident-bytes budget and the cache may
/// spill or drop the operator between slices of a running fleet.
///
/// Per-seed results are bitwise-identical to [`run_trials_batched`]
/// over the same operator (a test pins this), whether a trial's slice
/// was served resident or from the out-of-core tier — the spilled apply
/// is bitwise-identical to the resident apply (`linalg::spill`).
pub fn run_trials_cached<F>(
    method: Method,
    cache: &std::sync::Arc<OpCache>,
    key: OpKey,
    build: F,
    base: &SymNmfOptions,
    labels: Option<&[usize]>,
    trials: usize,
) -> MethodStats
where
    F: Fn() -> CachedOperator + Send + Sync,
{
    assert!(trials >= 1);
    let build = std::sync::Arc::new(build);
    let mut sched = Scheduler::new(SchedulerConfig::default());
    let handles: Vec<_> = (0..trials)
        .map(|t| {
            let spec = JobSpec::new(format!("trial-{t}"), method, trial_options(base, t));
            let b = std::sync::Arc::clone(&build);
            sched
                .submit_cached(cache, key.clone(), move || b(), spec)
                .expect("trial job submission cannot fail")
        })
        .collect();
    sched.drain();
    let results = handles
        .iter()
        .map(|h| {
            h.outcome()
                .expect("drained trial job has an outcome")
                .expect_result()
                .clone()
        })
        .collect();
    aggregate(method.label(), results, labels)
}

/// [`run_trials`] with per-trial streaming telemetry: each trial runs as
/// a serve job whose convergence records stream to
/// `<dir>/<label>_t<trial>.<ext>` (flushed per record — the curves are
/// on disk mid-run, not extracted afterwards). Seed schedule and
/// per-trial results are bitwise-identical to the plain drivers; like
/// [`run_trials_batched`], trials share the machine, so per-trial
/// `mean_time` reflects contended wall clock.
pub fn run_trials_streamed<X: SymOp + Sync>(
    method: Method,
    x: &X,
    base: &SymNmfOptions,
    labels: Option<&[usize]>,
    trials: usize,
    dir: &std::path::Path,
    format: TraceFormat,
) -> Result<MethodStats, String> {
    assert!(trials >= 1);
    std::fs::create_dir_all(dir).map_err(|e| format!("create trace dir {dir:?}: {e}"))?;
    let ext = match format {
        TraceFormat::Jsonl => "jsonl",
        TraceFormat::Csv => "csv",
    };
    let stem = sanitize_id(&method.label());
    let mut sched = Scheduler::new(SchedulerConfig::default());
    let handles: Vec<_> = (0..trials)
        .map(|t| {
            let spec = JobSpec::new(format!("{stem}-t{t}"), method, trial_options(base, t))
                .with_trace(dir.join(format!("{stem}_t{t}.{ext}")), format);
            sched.submit(x, spec)
        })
        .collect::<Result<_, _>>()?;
    sched.drain();
    let results = handles
        .iter()
        .map(|h| {
            h.outcome()
                .expect("drained job has an outcome")
                .expect_result()
                .clone()
        })
        .collect();
    Ok(aggregate(method.label(), results, labels))
}

/// Is the packed-X staging option on? `SYMNMF_PACKED_X=1` makes the
/// dense drivers store X as [`SymPacked`] (upper-triangle block panels —
/// half the resident footprint) instead of the full square array.
/// Read per call, not cached: the benches toggle it per run.
pub fn packed_x_enabled() -> bool {
    std::env::var("SYMNMF_PACKED_X").map(|v| v == "1").unwrap_or(false)
}

/// Is batched multi-seed driving on? `SYMNMF_BATCH_TRIALS=1` routes
/// multi-trial runs through [`run_trials_batched`] (bitwise-identical to
/// the serial driver; per-trial wall-clock reflects sharing). The single
/// parsing point for the env contract — benches and integration tests
/// consume this instead of re-reading the variable.
pub fn batch_trials_enabled() -> bool {
    std::env::var("SYMNMF_BATCH_TRIALS").map(|v| v == "1").unwrap_or(false)
}

/// Multi-trial driver for a dense X that honors the packed-X option:
/// when [`packed_x_enabled`], X is staged once as [`SymPacked`] and
/// every seed runs against that single half-sized resident operand —
/// the memory win compounds with `batched`, which shares the one
/// operand across concurrent trial workers. The full `x` can be dropped
/// by the caller after this call.
pub fn run_trials_dense(
    method: Method,
    x: &DenseMat,
    base: &SymNmfOptions,
    labels: Option<&[usize]>,
    trials: usize,
    batched: bool,
) -> MethodStats {
    if packed_x_enabled() {
        let packed = SymPacked::from_dense(x);
        if batched {
            run_trials_batched(method, &packed, base, labels, trials)
        } else {
            run_trials(method, &packed, base, labels, trials)
        }
    } else if batched {
        run_trials_batched(method, x, base, labels, trials)
    } else {
        run_trials(method, x, base, labels, trials)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, DenseMat};
    use crate::util::rng::Pcg64;

    fn planted(m: usize, k: usize, seed: u64) -> (DenseMat, Vec<usize>) {
        // block-structured similarity with ground truth labels
        let mut rng = Pcg64::seed_from_u64(seed);
        let bs = m / k;
        let mut h = DenseMat::zeros(m, k);
        for i in 0..m {
            let c = (i / bs).min(k - 1);
            h.set(i, c, 1.0 + 0.2 * rng.uniform());
        }
        let mut x = blas::matmul_nt(&h, &h);
        x.symmetrize();
        let labels = (0..m).map(|i| (i / bs).min(k - 1)).collect();
        (x, labels)
    }

    #[test]
    fn labels_match_paper_scheme() {
        assert_eq!(Method::Exact(UpdateRule::Bpp).label(), "BPP");
        assert_eq!(
            Method::Lai { rule: UpdateRule::Hals, refine: true }.label(),
            "LAI-HALS-IR"
        );
        assert_eq!(Method::Comp(UpdateRule::Bpp).label(), "Comp-BPP");
        assert_eq!(Method::LaiPgncg { refine: false }.label(), "LAI-PGNCG");
        assert_eq!(
            Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS }.label(),
            "LvS-HALS (τ=1/s)"
        );
        assert_eq!(
            Method::Lvs { rule: UpdateRule::Bpp, tau: Tau::Fixed(1.0) }.label(),
            "LvS-BPP (τ=1)"
        );
    }

    #[test]
    fn trials_aggregate_and_cluster() {
        let (x, labels) = planted(60, 3, 1);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 40;
        let stats = run_trials(
            Method::Exact(UpdateRule::Hals),
            &x,
            &opts,
            Some(&labels),
            3,
        );
        assert_eq!(stats.trials.len(), 3);
        assert!(stats.mean_iters >= 1.0);
        assert!(stats.mean_time > 0.0);
        assert!(stats.min_res <= stats.avg_min_res + 1e-12);
        assert!(
            stats.mean_ari > 0.9,
            "block-perfect input should cluster: ARI {}",
            stats.mean_ari
        );
    }

    /// Acceptance: the batched driver must produce bitwise-identical
    /// per-seed results to the serial path (same per-trial RNG streams,
    /// deterministic kernels) — only wall-clock fields may differ.
    #[test]
    fn batched_trials_bitwise_match_serial() {
        let (x, labels) = planted(48, 3, 5);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 8;
        for method in [
            Method::Exact(UpdateRule::Hals),
            Method::Exact(UpdateRule::Bpp),
            Method::Lai { rule: UpdateRule::Hals, refine: false },
        ] {
            let serial = run_trials(method, &x, &opts, Some(&labels), 3);
            let batched = run_trials_batched(method, &x, &opts, Some(&labels), 3);
            assert_eq!(serial.trials.len(), batched.trials.len());
            for (t, (a, b)) in
                serial.trials.iter().zip(&batched.trials).enumerate()
            {
                assert_eq!(a.iters(), b.iters(), "{} trial {t}", method.label());
                for (va, vb) in a.h.data().iter().zip(b.h.data()) {
                    assert_eq!(
                        va.to_bits(),
                        vb.to_bits(),
                        "{} trial {t}: H differs",
                        method.label()
                    );
                }
                for (va, vb) in a.w.data().iter().zip(b.w.data()) {
                    assert_eq!(
                        va.to_bits(),
                        vb.to_bits(),
                        "{} trial {t}: W differs",
                        method.label()
                    );
                }
                for (ra, rb) in a.records.iter().zip(&b.records) {
                    assert_eq!(
                        ra.residual.to_bits(),
                        rb.residual.to_bits(),
                        "{} trial {t}: residual differs",
                        method.label()
                    );
                }
            }
            // aggregate statistics over the same per-trial data agree too
            // (times excluded — they are wall-clock)
            assert_eq!(serial.min_res.to_bits(), batched.min_res.to_bits());
            assert_eq!(serial.mean_ari.to_bits(), batched.mean_ari.to_bits());
        }
    }

    /// The satellite pinning: under a NON-TRIVIAL outer thread budget the
    /// batched driver must still be bitwise identical to the serial path
    /// — budgets cap physical concurrency only, never FP geometry.
    #[test]
    fn batched_trials_bitwise_match_serial_under_budget() {
        use crate::util::threadpool::with_thread_budget;
        let (x, labels) = planted(48, 3, 9);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 6;
        let method = Method::Exact(UpdateRule::Hals);
        let serial = run_trials(method, &x, &opts, Some(&labels), 3);
        for budget in [1usize, 2] {
            let batched = with_thread_budget(budget, || {
                run_trials_batched(method, &x, &opts, Some(&labels), 3)
            });
            for (t, (a, b)) in serial.trials.iter().zip(&batched.trials).enumerate() {
                assert_eq!(a.iters(), b.iters(), "budget {budget} trial {t}");
                for (va, vb) in a.h.data().iter().zip(b.h.data()) {
                    assert_eq!(
                        va.to_bits(),
                        vb.to_bits(),
                        "budget {budget} trial {t}: H differs"
                    );
                }
                for (ra, rb) in a.records.iter().zip(&b.records) {
                    assert_eq!(
                        ra.residual.to_bits(),
                        rb.residual.to_bits(),
                        "budget {budget} trial {t}: residual differs"
                    );
                }
            }
        }
    }

    /// The engine-era acceptance: a batched fleet paused mid-solve (one
    /// serialized checkpoint per trial) and then resumed reproduces the
    /// uninterrupted serial run bitwise — pause/resume and deadline
    /// semantics come to the trial drivers for free from the shared
    /// engine loop.
    #[test]
    fn batched_controlled_pause_resume_bitwise() {
        use crate::symnmf::engine::RunStatus;
        let (x, labels) = planted(48, 3, 21);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 8;
        let method = Method::Exact(UpdateRule::Hals);
        let full = run_trials(method, &x, &opts, Some(&labels), 3);
        let (paused, cps) = run_trials_batched_controlled(
            method,
            &x,
            &opts,
            Some(&labels),
            3,
            &RunControl::unlimited().with_max_steps(3),
            None,
        );
        for (t, r) in paused.trials.iter().enumerate() {
            assert_eq!(r.iters(), 3, "trial {t} must pause after 3 steps");
        }
        // serialize → parse each checkpoint, then resume the fleet
        let cps: Vec<Checkpoint> = cps
            .iter()
            .map(|c| Checkpoint::parse(&c.serialize()).expect("roundtrip"))
            .collect();
        let (resumed, done) = run_trials_batched_controlled(
            method,
            &x,
            &opts,
            Some(&labels),
            3,
            &RunControl::unlimited(),
            Some(&cps),
        );
        assert!(done.iter().all(|c| c.status == RunStatus::Completed));
        for (t, (a, b)) in full.trials.iter().zip(&resumed.trials).enumerate() {
            assert_eq!(a.iters(), b.iters(), "trial {t}");
            for (va, vb) in a.h.data().iter().zip(b.h.data()) {
                assert_eq!(va.to_bits(), vb.to_bits(), "trial {t}: H differs");
            }
            for (ra, rb) in a.records.iter().zip(&b.records) {
                assert_eq!(
                    ra.residual.to_bits(),
                    rb.residual.to_bits(),
                    "trial {t}: residual differs"
                );
            }
        }
    }

    /// Acceptance (PR 7): a fleet against a cached operator is bitwise
    /// equal to the borrowed-operator fleet, the cache builds X exactly
    /// once for the whole fleet, and a budget small enough to force
    /// spill-eviction between slices changes counters but not one bit
    /// of the results.
    #[test]
    fn cached_trials_bitwise_match_batched_and_build_once() {
        use crate::serve::OpCacheConfig;
        let (x, labels) = planted(48, 3, 13);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 6;
        let method = Method::Exact(UpdateRule::Hals);
        let packed = SymPacked::from_dense(&x);
        let key = OpKey::of_packed(&packed);
        let plain = run_trials_batched(method, &packed, &opts, Some(&labels), 3);

        let dir = std::env::temp_dir()
            .join(format!("symnmf-drv-cache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        let check = |stats: &MethodStats, tag: &str| {
            for (t, (a, b)) in plain.trials.iter().zip(&stats.trials).enumerate() {
                assert_eq!(a.iters(), b.iters(), "{tag} trial {t}");
                for (va, vb) in a.h.data().iter().zip(b.h.data()) {
                    assert_eq!(va.to_bits(), vb.to_bits(), "{tag} trial {t}: H differs");
                }
                for (ra, rb) in a.records.iter().zip(&b.records) {
                    assert_eq!(
                        ra.residual.to_bits(),
                        rb.residual.to_bits(),
                        "{tag} trial {t}: residual differs"
                    );
                }
            }
        };

        // unbudgeted: 3 trials × 1 slice → one build, two resident hits
        let cache = std::sync::Arc::new(OpCache::new(OpCacheConfig::new(dir.clone())));
        let xc = x.clone();
        let cached = run_trials_cached(
            method,
            &cache,
            key.clone(),
            move || CachedOperator::Packed(SymPacked::from_dense(&xc)),
            &opts,
            Some(&labels),
            3,
        );
        check(&cached, "unbudgeted");
        let s = cache.stats();
        assert_eq!(s.misses, 1, "fleet must build X exactly once");
        assert_eq!(s.hits + s.spilled_hits, 2);
        assert_eq!(s.evictions, 0);

        // zero budget: the operator is spill-evicted at every unpin;
        // whether later pins overlap (resident hits) or fault from the
        // spill file is scheduling-dependent, but the build still runs
        // once and every result is bitwise unchanged
        let cache = std::sync::Arc::new(OpCache::new(
            OpCacheConfig::new(dir.clone()).with_budget_mb(0.0),
        ));
        let xc = x.clone();
        let spilled = run_trials_cached(
            method,
            &cache,
            key,
            move || CachedOperator::Packed(SymPacked::from_dense(&xc)),
            &opts,
            Some(&labels),
            3,
        );
        check(&spilled, "budgeted");
        let s = cache.stats();
        assert_eq!(s.misses, 1, "spill-eviction must not force a rebuild");
        assert!(s.evictions >= 1, "zero budget must evict: {s:?}");
        assert!(s.spill_writes >= 1, "packed eviction must spill: {s:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A fleet under a zero deadline returns every trial's initial
    /// iterate unstepped, and resuming it completes to the serial run.
    #[test]
    fn batched_controlled_deadline_zero_then_resume() {
        use crate::symnmf::engine::RunStatus;
        let (x, labels) = planted(40, 2, 33);
        let mut opts = SymNmfOptions::new(2);
        opts.max_iters = 5;
        let method = Method::Exact(UpdateRule::Bpp);
        let (dead, cps) = run_trials_batched_controlled(
            method,
            &x,
            &opts,
            Some(&labels),
            2,
            &RunControl::unlimited().with_deadline(0.0),
            None,
        );
        for (t, (r, c)) in dead.trials.iter().zip(&cps).enumerate() {
            assert_eq!(c.status, RunStatus::Deadline, "trial {t}");
            assert_eq!(r.iters(), 0, "trial {t} must not step");
        }
        let full = run_trials(method, &x, &opts, Some(&labels), 2);
        let (resumed, _) = run_trials_batched_controlled(
            method,
            &x,
            &opts,
            Some(&labels),
            2,
            &RunControl::unlimited(),
            Some(&cps),
        );
        for (t, (a, b)) in full.trials.iter().zip(&resumed.trials).enumerate() {
            for (va, vb) in a.h.data().iter().zip(b.h.data()) {
                assert_eq!(va.to_bits(), vb.to_bits(), "trial {t}: H differs");
            }
        }
    }

    /// The packed-triangular operand drives the same multi-trial quality
    /// as the full dense array (the half-sized resident X of the
    /// SYMNMF_PACKED_X option), serial and batched agreeing bitwise.
    #[test]
    fn packed_operand_trials_cluster_and_batch_bitwise() {
        let (x, labels) = planted(60, 3, 1);
        let packed = SymPacked::from_dense(&x);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 40;
        let stats = run_trials(
            Method::Exact(UpdateRule::Hals),
            &packed,
            &opts,
            Some(&labels),
            2,
        );
        assert!(
            stats.mean_ari > 0.9,
            "packed X should cluster the planted blocks: ARI {}",
            stats.mean_ari
        );
        let batched = run_trials_batched(
            Method::Exact(UpdateRule::Hals),
            &packed,
            &opts,
            Some(&labels),
            2,
        );
        for (a, b) in stats.trials.iter().zip(&batched.trials) {
            for (va, vb) in a.h.data().iter().zip(b.h.data()) {
                assert_eq!(va.to_bits(), vb.to_bits(), "packed batched ≠ serial");
            }
        }
    }

    /// With the packed-X option off (the default), run_trials_dense is
    /// exactly the plain drivers.
    #[test]
    fn run_trials_dense_defaults_to_plain_drivers() {
        let (x, labels) = planted(48, 3, 12);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 5;
        let method = Method::Exact(UpdateRule::Bpp);
        let plain = run_trials(method, &x, &opts, Some(&labels), 2);
        let viadense = run_trials_dense(method, &x, &opts, Some(&labels), 2, false);
        for (a, b) in plain.trials.iter().zip(&viadense.trials) {
            for (va, vb) in a.h.data().iter().zip(b.h.data()) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
        let viabatched = run_trials_dense(method, &x, &opts, Some(&labels), 2, true);
        for (a, b) in plain.trials.iter().zip(&viabatched.trials) {
            for (va, vb) in a.h.data().iter().zip(b.h.data()) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    /// The streaming trial driver is bitwise the serial driver, and the
    /// per-trial trace files hold the full residual history (flushed per
    /// record) by the time the drain returns.
    #[test]
    fn streamed_trials_bitwise_match_serial_and_write_curves() {
        use crate::util::json::Json;
        let (x, labels) = planted(40, 2, 17);
        let mut opts = SymNmfOptions::new(2);
        opts.max_iters = 5;
        let method = Method::Exact(UpdateRule::Hals);
        let dir = std::env::temp_dir()
            .join(format!("symnmf-stream-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let serial = run_trials(method, &x, &opts, Some(&labels), 2);
        let streamed = run_trials_streamed(
            method,
            &x,
            &opts,
            Some(&labels),
            2,
            &dir,
            TraceFormat::Jsonl,
        )
        .expect("streamed driver");
        for (t, (a, b)) in serial.trials.iter().zip(&streamed.trials).enumerate() {
            assert_eq!(a.iters(), b.iters(), "trial {t}");
            for (va, vb) in a.h.data().iter().zip(b.h.data()) {
                assert_eq!(va.to_bits(), vb.to_bits(), "trial {t}: H differs");
            }
            // the streamed file's iter lines reproduce the residual
            // history bitwise (via the residual_hex field)
            let path = dir.join(format!("HALS_t{t}.jsonl"));
            let text = std::fs::read_to_string(&path).expect("trace file");
            let hexes: Vec<String> = text
                .lines()
                .map(|l| Json::parse(l).expect("parseable line"))
                .filter(|j| j.get("type").and_then(Json::as_str) == Some("iter"))
                .map(|j| {
                    j.get("residual_hex").and_then(Json::as_str).unwrap().to_string()
                })
                .collect();
            assert_eq!(hexes.len(), a.iters(), "trial {t}: one line per iteration");
            for (r, hex) in a.records.iter().zip(&hexes) {
                assert_eq!(
                    &format!("{:016x}", r.residual.to_bits()),
                    hex,
                    "trial {t}: streamed residual differs"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn all_methods_run_one_iteration() {
        let (x, _) = planted(40, 2, 2);
        let mut opts = SymNmfOptions::new(2);
        opts.max_iters = 2;
        opts.samples = Some(20);
        for m in [
            Method::Exact(UpdateRule::Bpp),
            Method::Lai { rule: UpdateRule::Hals, refine: false },
            Method::Lai { rule: UpdateRule::Bpp, refine: true },
            Method::Comp(UpdateRule::Hals),
            Method::Pgncg,
            Method::LaiPgncg { refine: false },
            Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS },
        ] {
            let res = m.run(&x, &opts);
            assert!(!res.records.is_empty(), "{}", m.label());
            assert!(res.h.is_nonneg(), "{}", m.label());
        }
    }
}
