//! `symnmf` CLI — run SymNMF methods on generated workloads or
//! MatrixMarket graphs, inspect artifacts, and print runtime diagnostics.
//!
//! Examples:
//!   symnmf run --workload wos --docs 800 --method lai-hals --trials 3
//!   symnmf run --workload oag --m 5000 --method lvs-hals --tau 0.001
//!   symnmf run --input graph.mtx --k 8 --method bpp
//!   symnmf artifacts            # list loaded AOT artifacts
//!   symnmf info                 # platform / runtime diagnostics

use symnmf::coordinator::driver::{run_trials, Method};
use symnmf::coordinator::{experiments, report};
use symnmf::nls::UpdateRule;
use symnmf::runtime::registry::Registry;
use symnmf::runtime::PjrtRuntime;
use symnmf::symnmf::options::{SymNmfOptions, Tau};
use symnmf::util::cli::Args;

fn parse_method(s: &str, tau: Tau) -> Option<Method> {
    let s = s.to_ascii_lowercase();
    let rule = UpdateRule::parse;
    Some(match s.as_str() {
        "bpp" | "hals" | "mu" => Method::Exact(rule(&s)?),
        "pgncg" => Method::Pgncg,
        "lai-pgncg" => Method::LaiPgncg { refine: false },
        "lai-pgncg-ir" => Method::LaiPgncg { refine: true },
        _ => {
            if let Some(rest) = s.strip_prefix("lai-") {
                let (r, refine) = match rest.strip_suffix("-ir") {
                    Some(r) => (r, true),
                    None => (rest, false),
                };
                Method::Lai { rule: rule(r)?, refine }
            } else if let Some(r) = s.strip_prefix("comp-") {
                Method::Comp(rule(r)?)
            } else if let Some(r) = s.strip_prefix("lvs-") {
                Method::Lvs { rule: rule(r)?, tau }
            } else {
                return None;
            }
        }
    })
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let tau = match args.get("tau") {
        Some(t) => Tau::Fixed(t.parse().map_err(|e| format!("bad --tau: {e}"))?),
        None => Tau::OneOverS,
    };
    let method = parse_method(args.get_str("method", "bpp"), tau)
        .ok_or_else(|| format!("unknown method {:?}", args.get_str("method", "")))?;
    let trials = args.get_usize("trials", 1);
    let seed = args.get_usize("seed", 0) as u64;

    if let Some(path) = args.get("input") {
        // user-supplied MatrixMarket graph
        let mut adj =
            symnmf::sparse::io::read_matrix_market(std::path::Path::new(path))?;
        symnmf::sparse::sym::prepare_adjacency(&mut adj);
        let k = args.get_usize("k", 8);
        let mut opts = SymNmfOptions::new(k).with_seed(seed);
        opts.max_iters = args.get_usize("max-iters", 300);
        let stats = run_trials(method, &adj, &opts, None, trials);
        println!("{}", report::stats_table(&[stats]));
        return Ok(());
    }
    match args.get_str("workload", "wos") {
        "wos" => {
            let docs = args.get_usize("docs", 800);
            let w = experiments::wos_workload(docs, seed);
            let mut opts = experiments::wos_options().with_seed(seed);
            opts.max_iters = args.get_usize("max-iters", 300);
            println!(
                "WoS workload: {} docs, dense {}x{} adjacency, 7 topics",
                docs,
                w.adjacency.rows(),
                w.adjacency.cols()
            );
            let stats =
                run_trials(method, &w.adjacency, &opts, Some(&w.labels), trials);
            println!("{}", report::stats_table(&[stats]));
        }
        "oag" => {
            let m = args.get_usize("m", 5000);
            let g = experiments::oag_workload(m, seed);
            let mut opts = experiments::oag_options().with_seed(seed);
            opts.max_iters = args.get_usize("max-iters", 100);
            println!(
                "OAG workload: sparse {}x{} adjacency, {} nnz, k=16",
                g.adj.rows(),
                g.adj.cols(),
                g.adj.nnz()
            );
            let stats = run_trials(method, &g.adj, &opts, Some(&g.labels), trials);
            println!("{}", report::stats_table(&[stats]));
        }
        other => return Err(format!("unknown workload {other:?} (wos|oag)")),
    }
    Ok(())
}

fn cmd_artifacts() -> Result<(), String> {
    let dir = Registry::default_dir();
    let reg = Registry::load(&dir)?;
    if reg.specs.is_empty() {
        println!("no artifacts found in {dir:?} — run `make artifacts`");
        return Ok(());
    }
    println!("{} artifacts in {dir:?}:", reg.specs.len());
    for s in &reg.specs {
        println!(
            "  {:<14} dims={:?} inputs={:?} outputs={:?}",
            s.program, s.dims, s.inputs, s.outputs
        );
    }
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    match PjrtRuntime::from_default_dir() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts loaded: {}", rt.registry.specs.len());
        }
        Err(e) => println!("PJRT unavailable ({e:#}); native kernels only"),
    }
    println!("threads: {}", symnmf::util::threadpool::num_threads());
    Ok(())
}

fn usage() -> &'static str {
    "symnmf — randomized symmetric NMF (Hayashi et al. 2024 reproduction)

USAGE:
  symnmf run [--workload wos|oag] [--method M] [--trials N] [--seed S]
             [--docs N | --m N] [--tau T] [--max-iters N]
             [--input graph.mtx --k K]
  symnmf artifacts      list AOT artifacts
  symnmf info           runtime diagnostics

METHODS:
  bpp hals mu pgncg lai-<rule>[-ir] comp-<rule> lvs-<rule> lai-pgncg[-ir]
"
}

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("artifacts") => cmd_artifacts(),
        Some("info") => cmd_info(),
        _ => {
            println!("{}", usage());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
