//! Tiny CLI argument parser (clap is unavailable offline): subcommand +
//! `--flag value` / `--flag` options, with typed getters and usage text.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args, `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv[1..]. `--key value` becomes an option; a bare `--key`
    /// followed by another `--...` (or nothing) becomes a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let items: Vec<String> = argv.into_iter().collect();
        let mut i = 0;
        while i < items.len() {
            let a = &items[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value =
                    i + 1 < items.len() && !items[i + 1].starts_with("--");
                if next_is_value {
                    out.options.insert(key.to_string(), items[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.push(key.to_string());
                    i += 1;
                }
            } else {
                if out.subcommand.is_none() && out.positional.is_empty() {
                    out.subcommand = Some(a.clone());
                } else {
                    out.positional.push(a.clone());
                }
                i += 1;
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // NOTE the parser's documented ambiguity: a bare `--flag` followed
        // by a non-`--` token consumes it as a value, so positionals go
        // before flags.
        let a = parse("run input.mtx --method lai-hals --k 7 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("method"), Some("lai-hals"));
        assert_eq!(a.get_usize("k", 0), 7);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["input.mtx"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("bench");
        assert_eq!(a.get_usize("trials", 10), 10);
        assert_eq!(a.get_f64("tau", 1.0), 1.0);
        assert_eq!(a.get_str("method", "bpp"), "bpp");
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn negative_number_values() {
        // "--shift -1.5": "-1.5" doesn't start with "--" so it is a value.
        let a = parse("x --shift -1.5");
        assert_eq!(a.get_f64("shift", 0.0), -1.5);
    }
}
