//! Bounded, deterministic retry for transient I/O.
//!
//! The serving layer retries checkpoint saves and spill-tile reads a
//! fixed number of times before degrading. The backoff is counted in
//! scheduler yields, not wall-clock sleeps: no clock reads and no
//! randomness, so a run under fail-point injection is exactly
//! reproducible (the same attempt sequence every time), and the unit
//! tests never wait on real time.

/// Attempts for the serving layer's transient-I/O sites (checkpoint
/// save, spill-tile read): the first try plus two retries.
pub const DEFAULT_ATTEMPTS: usize = 3;

/// Deterministic backoff between attempts: yield the thread
/// `attempt` times. Grows linearly with the attempt count — enough to
/// let a competing writer finish on a loaded box — without ever
/// consulting a clock or an RNG.
pub fn backoff(attempt: usize) {
    for _ in 0..attempt {
        std::thread::yield_now();
    }
}

/// Run `f` up to `attempts` times (≥ 1), backing off between failures;
/// returns the first `Ok` or the **last** error once exhausted. `f`
/// receives the 1-based attempt number.
pub fn with_retry<T, E>(
    attempts: usize,
    mut f: impl FnMut(usize) -> Result<T, E>,
) -> Result<T, E> {
    assert!(attempts >= 1, "with_retry needs at least one attempt");
    let mut attempt = 1;
    loop {
        match f(attempt) {
            Ok(v) => return Ok(v),
            Err(e) => {
                if attempt == attempts {
                    return Err(e);
                }
                backoff(attempt);
                attempt += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_success_short_circuits() {
        let mut calls = 0;
        let r: Result<i32, String> = with_retry(3, |a| {
            calls += 1;
            assert_eq!(a, calls);
            Ok(7)
        });
        assert_eq!(r, Ok(7));
        assert_eq!(calls, 1);
    }

    #[test]
    fn transient_failures_heal_within_the_budget() {
        let mut calls = 0;
        let r: Result<&str, String> = with_retry(3, |a| {
            calls += 1;
            if a < 3 {
                Err(format!("transient {a}"))
            } else {
                Ok("recovered")
            }
        });
        assert_eq!(r, Ok("recovered"));
        assert_eq!(calls, 3);
    }

    #[test]
    fn exhaustion_returns_the_last_error() {
        let mut calls = 0;
        let r: Result<(), String> = with_retry(3, |a| {
            calls += 1;
            Err(format!("attempt {a}"))
        });
        assert_eq!(r, Err("attempt 3".to_string()));
        assert_eq!(calls, 3, "bounded: exactly `attempts` calls");
    }

    #[test]
    fn single_attempt_means_no_retry() {
        let mut calls = 0;
        let r: Result<(), &str> = with_retry(1, |_| {
            calls += 1;
            Err("nope")
        });
        assert!(r.is_err());
        assert_eq!(calls, 1);
    }
}
