//! Micro-benchmarks of the hot-path kernels (the §Perf tool, DESIGN.md
//! §6): dense matmul X·F, Gram, SpMM, CholeskyQR + leverage scores, BPP
//! multi-RHS solve, sampled SpMM, and the PJRT round-trip for the same
//! product — with achieved GF/s against the 1-core f64 roofline.
//!
//!     cargo bench --bench bench_kernels

use std::rc::Rc;
use symnmf::linalg::{blas, qr, DenseMat};
use symnmf::nls::bpp;
use symnmf::randnla::leverage::sample_hybrid;

use symnmf::runtime::{PjrtRuntime, PjrtSymOp};
use symnmf::sparse::CsrMat;
use symnmf::util::bench::{bench, gflops};
use symnmf::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::seed_from_u64(1);
    let m = 1024;
    let k = 16;

    // --- dense X·F (the dominant per-iteration product) ---
    let mut x = DenseMat::gaussian(m, m, &mut rng);
    x.symmetrize();
    let f = DenseMat::gaussian(m, k, &mut rng);
    let mut out = DenseMat::zeros(m, k);
    let r = bench(&format!("dense X·F  ({m}x{m} · {m}x{k})"), 2, 9, || {
        blas::symm_tall_into(&x, &f, &mut out);
    });
    let flops = 2.0 * (m * m * k) as f64;
    println!("{}   {:.2} GF/s", r.report(), gflops(flops, r.median));

    // --- Gram FᵀF ---
    let tall = DenseMat::gaussian(100_000, k, &mut rng);
    let r = bench("gram FᵀF   (100000x16)", 2, 9, || {
        std::hint::black_box(blas::gram(&tall));
    });
    println!(
        "{}   {:.2} GF/s",
        r.report(),
        gflops((100_000 * k * k) as f64, r.median)
    );

    // --- sparse SpMM ---
    let n = 50_000;
    let mut trips = Vec::new();
    for i in 0..n {
        for _ in 0..20 {
            let j = rng.below(n);
            trips.push((i, j, 1.0));
        }
    }
    let sp = CsrMat::from_coo(n, n, trips);
    let fs = DenseMat::gaussian(n, k, &mut rng);
    let mut spout = DenseMat::zeros(n, k);
    let r = bench(&format!("spmm       ({n}x{n}, {} nnz, k={k})", sp.nnz()), 2, 9, || {
        sp.spmm_into(&fs, &mut spout);
    });
    println!(
        "{}   {:.2} GF/s",
        r.report(),
        gflops(2.0 * (sp.nnz() * k) as f64, r.median)
    );

    // --- sampled SpMM (LvS inner product, s = 0.05·n) ---
    let h = DenseMat::gaussian(n, k, &mut rng);
    let lev = qr::leverage_scores(&h);
    let s = n / 20;
    let sm = sample_hybrid(&lev, s, 1.0 / s as f64, &mut rng);
    let w_sq = sm.weights_sq();
    let r = bench(&format!("sampled spmm (s={s})"), 2, 9, || {
        std::hint::black_box(sp.sampled_spmm_sym(&fs, &sm.indices, &w_sq));
    });
    println!("{}", r.report());

    // --- CholeskyQR leverage scores (the per-iteration sampling cost) ---
    let r = bench(&format!("choleskyQR + leverage ({n}x{k})"), 2, 9, || {
        std::hint::black_box(qr::leverage_scores(&h));
    });
    println!("{}", r.report());

    // --- BPP multi-RHS (the Solve bar of Fig. 3) ---
    let g = {
        let a = DenseMat::gaussian(k + 8, k, &mut rng);
        let mut g = blas::gram(&a);
        for i in 0..k {
            *g.at_mut(i, i) += 0.1;
        }
        g
    };
    let y = DenseMat::gaussian(20_000, k, &mut rng);
    let r = bench("BPP multi-RHS (20000 rows, k=16)", 1, 5, || {
        std::hint::black_box(bpp::solve_multi(&g, &y, None));
    });
    println!("{}", r.report());

    // --- PJRT round-trip for the same X·F (AOT Pallas path) ---
    match PjrtRuntime::from_default_dir() {
        Ok(rt) => {
            let f7 = DenseMat::gaussian(m, 7, &mut rng);
            let op = PjrtSymOp::new(x.clone(), Rc::new(rt));
            if op.products_pjrt(&f7).is_some() {
                let r = bench("PJRT products (1024x1024·1024x7 + gram)", 2, 9, || {
                    std::hint::black_box(op.products_pjrt(&f7));
                });
                let flops = 2.0 * (m * m * 7) as f64;
                println!("{}   {:.2} GF/s", r.report(), gflops(flops, r.median));
                // native same-shape comparison
                let mut o7 = DenseMat::zeros(m, 7);
                let r = bench("native products (same shapes)", 2, 9, || {
                    blas::symm_tall_into(&x, &f7, &mut o7);
                    std::hint::black_box(blas::gram(&f7));
                });
                println!("{}   {:.2} GF/s", r.report(), gflops(flops, r.median));
            } else {
                println!("PJRT products artifact for m=1024,k=7 not found — run `make artifacts`");
            }
        }
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
}
