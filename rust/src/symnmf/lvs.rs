//! **LvS-SymNMF** (paper §4, Alg. LvS-SymNMF): every NLS subproblem of
//! the regularized ANLS iteration is sketched by leverage-score row
//! sampling. Exact leverage scores of the (tall, skinny) factor are
//! recomputed each half-iteration via CholeskyQR for O(mk²) — cheap next
//! to the O(m²k)/O(nnz·k) product with X it replaces — and the
//! regularization block √αI is kept deterministically (Eq. 4.1):
//!
//! ```text
//!     ‖S·H·Wᵀ − S·X‖²_F + α‖W − H‖²_F
//! ```
//!
//! → normal equations G = (SH)ᵀ(SH) + αI, Y = X·SᵀS·H + αH.
//!
//! The sampler is the **hybrid** scheme of §4.2 (threshold τ): rows with
//! leverage mass p_i ≥ τ enter deterministically, the rest are drawn with
//! renormalized probabilities — the paper shows τ = 1 (pure random) gives
//! no speedup while τ = 1/s makes the method competitive (§5.2, Fig. 2).

use crate::linalg::workspace::SampleWorkspace;
use crate::linalg::{blas, qr, DenseMat, IterWorkspace};
use crate::nls::{update_into, UpdateRule};
use crate::randnla::leverage::{sample_hybrid, sample_hybrid_ws, SampleMatrix};
use crate::randnla::SymOp;
use crate::symnmf::anls::{resolve_alpha, Metrics};
use crate::symnmf::engine::{
    run_solver, workspace_for, Checkpoint, EngineRun, EngineState, RunControl, SolveSpec,
    SolverEngine, Stage, StepOutcome, TraceSink,
};
#[cfg(test)]
use crate::symnmf::init::init_factor;
use crate::symnmf::init::initial_factor;
use crate::symnmf::metrics::{IterRecord, StopRule, SymNmfResult};
use crate::symnmf::options::SymNmfOptions;
use crate::util::rng::Pcg64;
use crate::util::timer::{PhaseTimer, Stopwatch, PHASE_MM, PHASE_SAMPLING, PHASE_SOLVE};

/// One leverage-score sampling step for a factor F (Alg. LvS-SymNMF
/// lines 4–7): CholeskyQR leverage scores → hybrid sampling matrix.
/// Uses the Q-free formulation (leverage_scores_via_chol, §Perf).
/// Allocating form, retained for the frozen reference loop
/// ([`lvs_symnmf_ws`]); the engine hot path runs [`sample_factor_ws`].
fn sample_factor(f: &DenseMat, s: usize, tau: f64, rng: &mut Pcg64) -> SampleMatrix {
    let lev = qr::leverage_scores_via_chol(f);
    sample_hybrid(&lev, s, tau, rng)
}

/// [`sample_factor`] threaded through the persistent [`SampleWorkspace`]:
/// scores land in `sw.leverage`, the sampling matrix in
/// `sw.indices`/`sw.scales`/`sw.weights_sq` — zero heap allocation once
/// the buffers are warm. The RNG draw sequence is identical to the
/// allocating form (pinned by `sample_hybrid_ws_matches_allocating_bitwise`),
/// so checkpoints taken by either path resume bitwise on the other.
/// Returns (num_deterministic, θ).
fn sample_factor_ws(
    f: &DenseMat,
    s: usize,
    tau: f64,
    rng: &mut Pcg64,
    sw: &mut SampleWorkspace,
) -> (usize, f64) {
    qr::leverage_scores_via_chol_into(f, sw);
    sample_hybrid_ws(s, tau, rng, sw)
}

/// The §5 label of an LvS configuration, shared by the engine wrapper
/// and the frozen reference loop.
fn lvs_label(opts: &SymNmfOptions) -> String {
    let tau_label = match opts.tau {
        crate::symnmf::options::Tau::Fixed(t) if (t - 1.0).abs() < 1e-12 => "τ=1".to_string(),
        crate::symnmf::options::Tau::Fixed(t) => format!("τ={t}"),
        crate::symnmf::options::Tau::OneOverS => "τ=1/s".to_string(),
    };
    format!("LvS-{} ({tau_label})", opts.rule.label())
}

/// LvS-SymNMF as a [`SolverEngine`]: one step is the full
/// sample-H/update-W then sample-W/update-H iteration of Alg.
/// LvS-SymNMF. The engine owns the sampling RNG, so its checkpoint
/// carries (H, W, RNG state) — a resumed run replays the exact remaining
/// sample draws.
pub struct LvsEngine<'a> {
    x: &'a dyn SymOp,
    alpha: f64,
    rule: UpdateRule,
    s: usize,
    tau: f64,
    rng: Pcg64,
    w: DenseMat,
    h: DenseMat,
}

impl<'a> LvsEngine<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        x: &'a dyn SymOp,
        alpha: f64,
        rule: UpdateRule,
        s: usize,
        tau: f64,
        rng: Pcg64,
        h0: DenseMat,
    ) -> LvsEngine<'a> {
        LvsEngine { x, alpha, rule, s, tau, rng, w: h0.clone(), h: h0 }
    }
}

impl SolverEngine for LvsEngine<'_> {
    fn h(&self) -> &DenseMat {
        &self.h
    }

    fn w(&self) -> &DenseMat {
        &self.w
    }

    fn sample_budget(&self) -> usize {
        self.s
    }

    fn step(&mut self, ws: &mut IterWorkspace) -> StepOutcome {
        let k = self.h.cols();
        let mut t_mm = 0.0;
        let mut t_solve = 0.0;
        let mut t_sample = 0.0;

        // --- sample on H, update W (lines 4–10) ---
        // The sampler runs through the persistent workspace
        // (`ws.sample`): scores, Cholesky scratch, alias table and the
        // sampling matrix are all reused buffers, so the steady-state
        // step allocates nothing. Per-half-step stats are captured into
        // locals before the second half-step overwrites the buffers.
        let t = Stopwatch::start();
        let (nd_h, theta_h) =
            sample_factor_ws(&self.h, self.s, self.tau, &mut self.rng, &mut ws.sample);
        self.h
            .gather_rows_scaled_into(&ws.sample.indices, &ws.sample.scales, &mut ws.sf);
        t_sample += t.elapsed_secs();
        let det_frac_h = if ws.sample.indices.is_empty() {
            0.0
        } else {
            nd_h as f64 / ws.sample.indices.len() as f64
        };

        let t = Stopwatch::start();
        self.x.sampled_apply_into(
            &self.h,
            &ws.sample.indices,
            &ws.sample.weights_sq,
            &mut ws.y,
        );
        ws.y.axpy(self.alpha, &self.h);
        blas::gram_into(&ws.sf, &mut ws.g);
        t_mm += t.elapsed_secs();
        ws.g.add_diag(self.alpha);
        let t = Stopwatch::start();
        update_into(self.rule, &ws.g, &ws.y, &mut self.w, &mut ws.update);
        t_solve += t.elapsed_secs();

        // --- sample on W, update H (lines 11–17) ---
        let t = Stopwatch::start();
        let (nd_w, theta_w) =
            sample_factor_ws(&self.w, self.s, self.tau, &mut self.rng, &mut ws.sample);
        self.w
            .gather_rows_scaled_into(&ws.sample.indices, &ws.sample.scales, &mut ws.sf);
        t_sample += t.elapsed_secs();
        let det_frac_w = if ws.sample.indices.is_empty() {
            0.0
        } else {
            nd_w as f64 / ws.sample.indices.len() as f64
        };

        let t = Stopwatch::start();
        self.x.sampled_apply_into(
            &self.w,
            &ws.sample.indices,
            &ws.sample.weights_sq,
            &mut ws.y,
        );
        ws.y.axpy(self.alpha, &self.w);
        blas::gram_into(&ws.sf, &mut ws.g);
        t_mm += t.elapsed_secs();
        ws.g.add_diag(self.alpha);
        let t = Stopwatch::start();
        update_into(self.rule, &ws.g, &ws.y, &mut self.h, &mut ws.update);
        t_solve += t.elapsed_secs();

        let det_frac = 0.5 * (det_frac_h + det_frac_w);
        let theta_over_k = 0.5 * (theta_h + theta_w) / k as f64;
        StepOutcome {
            mm_secs: t_mm,
            solve_secs: t_solve,
            sample_secs: t_sample,
            hybrid_stats: Some((det_frac, theta_over_k)),
        }
    }

    fn save(&self) -> EngineState {
        EngineState {
            h: self.h.clone(),
            w: Some(self.w.clone()),
            rng: Some(self.rng.state()),
        }
    }

    fn load(&mut self, st: &EngineState) {
        assert_eq!(st.h.shape(), self.h.shape(), "LvsEngine::load: H shape mismatch");
        self.h = st.h.clone();
        self.w = match &st.w {
            Some(w) => {
                assert_eq!(w.shape(), self.h.shape(), "LvsEngine::load: W shape mismatch");
                w.clone()
            }
            None => self.h.clone(),
        };
        // LvS has no RNG-free warm-start path (it is never a later chain
        // stage): a state without the sampler RNG is a defective
        // checkpoint, and silently keeping the fresh stream would break
        // the bitwise-resume contract without any signal.
        let r = st
            .rng
            .as_ref()
            .expect("LvsEngine::load: checkpoint must carry the sampler RNG state");
        self.rng = Pcg64::from_state(r);
    }
}

/// LvS-SymNMF. Works for any [`SymOp`]; designed for sparse X where
/// `sampled_apply_into` costs O(s·nnz_row·k). Thin wrapper over the
/// engine path (`SYMNMF_DEADLINE_MS` honored).
pub fn lvs_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    lvs_symnmf_run(x, opts, &RunControl::from_env(), None, None).result
}

/// The controlled engine entry: deadline/pause budgets, checkpoint
/// resume (including the sampler's RNG state), per-iteration tracing.
pub fn lvs_symnmf_run<X: SymOp>(
    x: &X,
    opts: &SymNmfOptions,
    ctrl: &RunControl,
    resume: Option<&Checkpoint>,
    trace: Option<&mut dyn TraceSink>,
) -> EngineRun {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let alpha = resolve_alpha(x, opts);
    let m = x.dim();
    let s = opts.effective_samples(m);
    let tau = opts.tau.value(s);
    let h0 = initial_factor(x, opts, &mut rng);
    let x: &dyn SymOp = x;
    let mut spec = SolveSpec {
        stages: vec![Stage {
            engine: Box::new(LvsEngine::new(x, alpha, opts.rule, s, tau, rng, h0)),
            label: lvs_label(opts),
        }],
        metrics: Metrics::new(x, true),
        setup_secs: 0.0,
        phases: PhaseTimer::new(),
    };
    let mut ws = workspace_for(&spec);
    run_solver(&mut spec, opts, ctrl, resume, trace, &mut ws)
}

/// The frozen pre-engine LvS loop against a caller-provided workspace,
/// kept verbatim as the **reference oracle** the engine path is pinned
/// against. The update loop's sampled products, Gram matrices and
/// update-rule scratch all come from `ws` — no per-iteration O(m·k)
/// allocation. (The sampler itself still builds its index/scale vectors
/// per draw; those are O(s) and belong to the sampling phase, not the
/// kernel core.)
pub fn lvs_symnmf_ws<X: SymOp>(
    x: &X,
    opts: &SymNmfOptions,
    ws: &mut IterWorkspace,
) -> SymNmfResult {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let alpha = resolve_alpha(x, opts);
    let m = x.dim();
    let k = opts.k;
    let s = opts.effective_samples(m);
    let tau = opts.tau.value(s);

    let mut h = initial_factor(x, opts, &mut rng);
    let mut w = h.clone();
    let metrics = Metrics::new(x, true);
    let mut records: Vec<IterRecord> = Vec::new();
    let mut stop = StopRule::new(opts.tol, opts.patience);
    let mut phases = PhaseTimer::new();
    let mut clock = 0.0;

    let label = lvs_label(opts);

    for iter in 0..opts.max_iters {
        let sw = Stopwatch::start();
        let mut t_mm = 0.0;
        let mut t_solve = 0.0;
        let mut t_sample = 0.0;

        // --- sample on H, update W (lines 4–10) ---
        let t = Stopwatch::start();
        let sm_h = sample_factor(&h, s, tau, &mut rng);
        h.gather_rows_scaled_into(&sm_h.indices, &sm_h.scales, &mut ws.sf);
        t_sample += t.elapsed_secs();

        let t = Stopwatch::start();
        x.sampled_apply_into(&h, &sm_h.indices, sm_h.weights_sq(), &mut ws.y);
        ws.y.axpy(alpha, &h);
        blas::gram_into(&ws.sf, &mut ws.g);
        t_mm += t.elapsed_secs();
        ws.g.add_diag(alpha);
        let t = Stopwatch::start();
        update_into(opts.rule, &ws.g, &ws.y, &mut w, &mut ws.update);
        t_solve += t.elapsed_secs();

        // --- sample on W, update H (lines 11–17) ---
        let t = Stopwatch::start();
        let sm_w = sample_factor(&w, s, tau, &mut rng);
        w.gather_rows_scaled_into(&sm_w.indices, &sm_w.scales, &mut ws.sf);
        t_sample += t.elapsed_secs();

        let t = Stopwatch::start();
        x.sampled_apply_into(&w, &sm_w.indices, sm_w.weights_sq(), &mut ws.y);
        ws.y.axpy(alpha, &w);
        blas::gram_into(&ws.sf, &mut ws.g);
        t_mm += t.elapsed_secs();
        ws.g.add_diag(alpha);
        let t = Stopwatch::start();
        update_into(opts.rule, &ws.g, &ws.y, &mut h, &mut ws.update);
        t_solve += t.elapsed_secs();

        clock += sw.elapsed_secs();
        phases.add(PHASE_MM, std::time::Duration::from_secs_f64(t_mm));
        phases.add(PHASE_SOLVE, std::time::Duration::from_secs_f64(t_solve));
        phases.add(PHASE_SAMPLING, std::time::Duration::from_secs_f64(t_sample));

        // --- metrics off the clock (workspace buffers are free here) ---
        let (res, pg) = metrics.eval_ws(&w, &h, ws);
        let det_frac =
            0.5 * (sm_h.deterministic_fraction() + sm_w.deterministic_fraction());
        let theta_over_k = 0.5 * (sm_h.theta + sm_w.theta) / k as f64;
        records.push(IterRecord {
            iter,
            time_secs: clock,
            residual: res,
            proj_grad: pg,
            phase_secs: (t_mm, t_solve, t_sample),
            hybrid_stats: Some((det_frac, theta_over_k)),
        });
        if stop.update(res) {
            break;
        }
    }

    SymNmfResult { label, h, w, records, phases, setup_secs: 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nls::UpdateRule;
    use crate::sparse::CsrMat;
    use crate::symnmf::options::Tau;

    /// Sparse symmetric planted block matrix.
    fn planted_sparse(m: usize, k: usize, seed: u64) -> CsrMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut trips = Vec::new();
        let bs = m / k;
        for i in 0..m {
            for j in (i + 1)..m {
                let same = i / bs == j / bs;
                let p = if same { 0.4 } else { 0.01 };
                if rng.uniform() < p {
                    trips.push((i, j, 1.0));
                    trips.push((j, i, 1.0));
                }
            }
        }
        let mut a = CsrMat::from_coo(m, m, trips);
        crate::sparse::sym::normalize_sym(&mut a);
        a
    }

    #[test]
    fn reduces_residual_on_sparse_blocks() {
        let x = planted_sparse(120, 4, 1);
        let mut opts = SymNmfOptions::new(4)
            .with_rule(UpdateRule::Hals)
            .with_seed(2);
        opts.max_iters = 60;
        opts.samples = Some(60); // 50% sampling on this small test
        let res = lvs_symnmf(&x, &opts);
        let first = res.records.first().unwrap().residual;
        let last = res.min_residual();
        assert!(last < first, "residual {first} → {last}");
        assert!(res.h.is_nonneg());
    }

    /// Acceptance: the LvS update loop draws every sampled product, Gram
    /// and update scratch from the pre-sized workspace — buffer pointers
    /// must survive 3 iterations unchanged.
    #[test]
    fn workspace_buffers_stable_across_iterations() {
        let x = planted_sparse(80, 4, 9);
        let mut opts = SymNmfOptions::new(4)
            .with_rule(UpdateRule::Hals)
            .with_seed(3);
        opts.max_iters = 3;
        opts.samples = Some(40);
        let s = opts.effective_samples(x.rows());
        let mut ws = IterWorkspace::with_samples(x.rows(), 4, s);
        let before = ws.buffer_ptrs();
        let res = lvs_symnmf_ws(&x, &opts, &mut ws);
        assert_eq!(res.iters(), 3);
        assert_eq!(
            ws.buffer_ptrs(),
            before,
            "LvS workspace buffers moved during the update loop"
        );
    }

    /// Tentpole acceptance: after one warm-up step, `LvsEngine::step`
    /// performs zero heap allocation — every workspace buffer pointer,
    /// including the sampling pipeline's (leverage scores, Cholesky
    /// scratch, alias table, indices/scales/weights), survives further
    /// steps unchanged.
    #[test]
    fn engine_step_is_allocation_free_after_warmup() {
        let x = planted_sparse(96, 4, 11);
        let mut rng = Pcg64::seed_from_u64(5);
        let h0 = init_factor(&x, 4, &mut rng);
        let xo: &dyn SymOp = &x;
        let s = 48;
        let mut eng = LvsEngine::new(
            xo,
            0.1,
            UpdateRule::Hals,
            s,
            1.0 / s as f64,
            Pcg64::seed_from_u64(23),
            h0,
        );
        let mut ws = IterWorkspace::with_samples(96, 4, s);
        eng.step(&mut ws); // warm-up: grow-only buffers reach steady size
        let before = ws.buffer_ptrs();
        for _ in 0..3 {
            eng.step(&mut ws);
        }
        assert_eq!(ws.buffer_ptrs(), before, "LvS step allocated after warm-up");
    }

    /// Acceptance: the engine wrapper is bitwise-identical to the frozen
    /// pre-refactor loop — identical sample draws, residual history,
    /// factors, hybrid stats, and label.
    #[test]
    fn engine_path_pinned_bitwise_to_reference() {
        use crate::symnmf::engine::assert_results_bitwise_eq;
        for (k, m) in [(2usize, 60), (7, 105)] {
            let x = planted_sparse(m, k.max(3), 21);
            let mut opts = SymNmfOptions::new(k)
                .with_rule(UpdateRule::Hals)
                .with_seed(13);
            opts.max_iters = 8;
            opts.samples = Some(m / 2);
            let s = opts.effective_samples(x.rows());
            let mut ws = IterWorkspace::with_samples(x.rows(), k, s);
            let oracle = lvs_symnmf_ws(&x, &opts, &mut ws);
            let engine = lvs_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
            assert_results_bitwise_eq(&oracle, &engine.result, &format!("lvs k={k}"));
        }
    }

    /// Acceptance: checkpoint → serialize → resume reproduces the
    /// uninterrupted run bitwise (the RNG state in the checkpoint is what
    /// keeps the remaining sample draws identical), and a deadline of 0
    /// returns the initial iterate without stepping.
    #[test]
    fn checkpoint_resume_and_deadline() {
        use crate::symnmf::engine::{assert_results_bitwise_eq, RunStatus};
        for k in [2usize, 7] {
            let m = 15 * k;
            let x = planted_sparse(m, k.max(3), 31);
            let mut opts = SymNmfOptions::new(k)
                .with_rule(UpdateRule::Hals)
                .with_seed(17);
            opts.max_iters = 7;
            opts.samples = Some(m / 2);
            let full = lvs_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
            let paused = lvs_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited().with_max_steps(2),
                None,
                None,
            );
            assert_eq!(paused.checkpoint.status, RunStatus::Paused);
            assert!(
                paused.checkpoint.state.rng.is_some(),
                "LvS checkpoints must carry the sampler RNG"
            );
            let cp = Checkpoint::parse(&paused.checkpoint.serialize()).expect("roundtrip");
            let resumed =
                lvs_symnmf_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
            assert_results_bitwise_eq(&full.result, &resumed.result, &format!("lvs k={k}"));

            let dead = lvs_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited().with_deadline(0.0),
                None,
                None,
            );
            assert_eq!(dead.checkpoint.status, RunStatus::Deadline);
            assert!(dead.result.records.is_empty());
            let resumed = lvs_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited(),
                Some(&dead.checkpoint),
                None,
            );
            assert_results_bitwise_eq(
                &full.result,
                &resumed.result,
                &format!("lvs deadline-0 k={k}"),
            );
        }
    }

    /// Satellite acceptance: cancel-before-first-step and mid-run cancel
    /// both resume bitwise — the cancelled checkpoint's RNG state keeps
    /// the remaining leverage-score sample draws identical.
    #[test]
    fn cancel_token_aborts_and_resumes_bitwise() {
        use crate::symnmf::engine::{assert_results_bitwise_eq, CancelToken, RunStatus};
        use crate::symnmf::trace::CancelAfterSink;
        let m = 60;
        let x = planted_sparse(m, 3, 47);
        let mut opts = SymNmfOptions::new(3).with_rule(UpdateRule::Hals).with_seed(19);
        opts.max_iters = 7;
        opts.samples = Some(m / 2);
        let full = lvs_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);

        let tok = CancelToken::new();
        tok.cancel();
        let cancelled = lvs_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            None,
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.result.iters(), 0);
        let resumed = lvs_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited(),
            Some(&cancelled.checkpoint),
            None,
        );
        assert_results_bitwise_eq(&full.result, &resumed.result, "lvs cancel-0 resume");

        let tok = CancelToken::new();
        let mut hook = CancelAfterSink::new(tok.clone(), 2);
        let cancelled = lvs_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            Some(&mut hook),
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.result.iters(), 2);
        assert!(
            cancelled.checkpoint.state.rng.is_some(),
            "cancelled LvS checkpoints must carry the sampler RNG"
        );
        let cp = Checkpoint::parse(&cancelled.checkpoint.serialize()).expect("roundtrip");
        let resumed = lvs_symnmf_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
        assert_results_bitwise_eq(&full.result, &resumed.result, "lvs mid-cancel resume");
    }

    #[test]
    fn hybrid_stats_recorded() {
        let x = planted_sparse(80, 4, 3);
        let mut opts = SymNmfOptions::new(4).with_seed(4);
        opts.rule = UpdateRule::Hals;
        opts.max_iters = 5;
        opts.samples = Some(40);
        opts.tau = Tau::OneOverS;
        let res = lvs_symnmf(&x, &opts);
        for r in &res.records {
            let (frac, theta) = r.hybrid_stats.unwrap();
            assert!((0.0..=1.0).contains(&frac));
            assert!((0.0..=1.0 + 1e-9).contains(&theta));
            assert!(r.phase_secs.2 > 0.0, "sampling phase must be timed");
        }
        assert!(res.label.contains("τ=1/s"), "{}", res.label);
    }

    #[test]
    fn tau_one_is_pure_random_label_and_behavior() {
        let x = planted_sparse(60, 3, 5);
        let mut opts = SymNmfOptions::new(3).with_seed(6);
        opts.rule = UpdateRule::Hals;
        opts.max_iters = 3;
        opts.samples = Some(30);
        opts.tau = Tau::Fixed(1.0);
        let res = lvs_symnmf(&x, &opts);
        assert!(res.label.contains("τ=1"), "{}", res.label);
        for r in &res.records {
            let (frac, _) = r.hybrid_stats.unwrap();
            assert_eq!(frac, 0.0, "τ=1 must take no deterministic samples");
        }
    }

    /// With full sampling (s = m, τ→deterministic-all) the sampled normal
    /// equations equal the exact ones, so one LvS iteration must match
    /// one exact ANLS iteration.
    #[test]
    fn full_deterministic_sampling_matches_exact_iteration() {
        let x = planted_sparse(40, 3, 7);
        let mut rng = Pcg64::seed_from_u64(8);
        let h = init_factor(&x, 3, &mut rng);
        // τ = 0 → every row deterministic (p_i ≥ 0 always) but the budget
        // guard trims to s−1... so use the sampler directly with s = m and
        // verify X·SᵀS·H == X·H when S selects every row with weight 1.
        let samples: Vec<usize> = (0..40).collect();
        let weights = vec![1.0; 40];
        let sampled = x.sampled_apply(&h, &samples, &weights);
        let exact = x.apply(&h);
        assert!(sampled.diff_fro(&exact) < 1e-10);
    }
}
