//! Streaming [`TraceSink`]s: convergence telemetry written to disk **as
//! it is produced**, one flushed line per iteration.
//!
//! The engine loop emits every [`IterRecord`] through its optional
//! [`TraceSink`] the moment the iteration finishes. The in-process
//! [`crate::symnmf::engine::VecSink`] collects; the sinks here *stream*:
//! [`JsonlSink`] writes one JSON object per line, [`CsvSink`] one CSV
//! row, and both flush after **every** record. That per-record flush is
//! the whole contract — if the writing process dies mid-run (OOM-killed
//! worker, pre-empted spot node), the prefix already on disk is complete,
//! parseable, and ends at an iteration boundary. A monitoring tail can
//! plot a convergence curve while the solve is still running, and the
//! serving layer ([`crate::serve`]) relies on the same property to keep a
//! job's trace file exact across pause/cancel/resume slices: each slice
//! appends only its own post-resume records, so the stitched file's
//! **iteration records** equal the uninterrupted run's history exactly.
//! (Stage lines are re-announced once per resumed slice — the engine
//! re-states the active stage so every record a sink observes belongs to
//! the most recently announced stage — so consumers should key on the
//! `iter` records, not count `stage` lines.)
//!
//! Write errors do not kill the solve: the sink latches the first error,
//! stops writing, and reports it through `error()` — telemetry loss must
//! never cost the factorization itself.
//!
//! [`CancelAfterSink`] is the cancellation hook built on the same
//! observation point: it trips a [`CancelToken`] once a target number of
//! records has streamed past, which is how tests and the `serve`
//! CLI cancel a solve mid-flight *deterministically* (the engine checks
//! the token between steps, so "cancel after record n" always aborts
//! before step n+1 regardless of wall clock).

use crate::linalg::simd;
use crate::symnmf::engine::{CancelToken, TraceSink};
use crate::symnmf::metrics::IterRecord;
use crate::util::json::Json;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

/// On-disk trace encodings understood by the serving layer and CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    Jsonl,
    Csv,
}

impl TraceFormat {
    pub fn parse(s: &str) -> Result<TraceFormat, String> {
        match s {
            "jsonl" => Ok(TraceFormat::Jsonl),
            "csv" => Ok(TraceFormat::Csv),
            other => Err(format!("unknown trace format {other:?} (jsonl|csv)")),
        }
    }
}

/// Open a boxed streaming sink of the given format (the serving layer's
/// one construction point).
pub fn open_sink(
    path: &Path,
    format: TraceFormat,
    append: bool,
) -> Result<Box<dyn TraceSink + Send>, String> {
    Ok(match format {
        TraceFormat::Jsonl => Box::new(if append {
            JsonlSink::append(path)?
        } else {
            JsonlSink::create(path)?
        }),
        TraceFormat::Csv => Box::new(if append {
            CsvSink::append(path)?
        } else {
            CsvSink::create(path)?
        }),
    })
}

/// Plain numeric field, or `null` when the value is not finite — the
/// in-repo JSON printer would otherwise emit bare `NaN`/`inf` tokens and
/// break parseability of the output. Exact bits always travel in the
/// `*_hex` companions. Shared with the CLI's per-job report writer.
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn create_writer(path: &Path, append: bool) -> Result<BufWriter<File>, String> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("create trace dir {dir:?}: {e}"))?;
        }
    }
    let file = if append {
        std::fs::OpenOptions::new().create(true).append(true).open(path)
    } else {
        File::create(path)
    };
    file.map(BufWriter::new)
        .map_err(|e| format!("create trace file {path:?}: {e}"))
}

/// JSONL streaming sink: one `{"type":"stage",...}` line per stage
/// transition, one `{"type":"iter",...}` line per finished iteration,
/// flushed per line. The residual is written both as a plain number (for
/// plotting) and as IEEE-bit hex (`residual_hex`, for bitwise trajectory
/// comparison across stitched slices).
pub struct JsonlSink {
    path: PathBuf,
    out: Option<BufWriter<File>>,
    stage: String,
    error: Option<String>,
}

impl JsonlSink {
    /// Create (truncating any existing file).
    pub fn create(path: &Path) -> Result<JsonlSink, String> {
        JsonlSink::open(path, false)
    }

    /// Open for appending — resumed jobs add their post-resume records
    /// after the pre-resume prefix instead of truncating it.
    pub fn append(path: &Path) -> Result<JsonlSink, String> {
        JsonlSink::open(path, true)
    }

    fn open(path: &Path, append: bool) -> Result<JsonlSink, String> {
        Ok(JsonlSink {
            path: path.to_path_buf(),
            out: Some(create_writer(path, append)?),
            stage: String::new(),
            error: None,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// First write/flush error, if any — the sink stops writing after it
    /// (and warns once on stderr, since boxed `dyn TraceSink` consumers
    /// cannot reach this accessor).
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    fn emit(&mut self, line: &Json) {
        let Some(out) = self.out.as_mut() else { return };
        let res = writeln!(out, "{line}").and_then(|()| out.flush());
        if let Err(e) = res {
            eprintln!("[trace] stream to {:?} stopped: {e}", self.path);
            self.error = Some(format!("write {:?}: {e}", self.path));
            self.out = None;
        }
    }
}

impl TraceSink for JsonlSink {
    fn on_stage(&mut self, label: &str) {
        self.stage = label.to_string();
        // the stage line doubles as the slice header: it carries the
        // kernel ISA the writing process dispatched (`linalg::simd`), so
        // a stitched trace records which dispatch produced each slice —
        // a resumed slice on different hardware is visible in the file.
        // Kept on the existing stage line (not a separate header line) so
        // the line-count contract of the prefix-durability tests holds.
        let line = Json::obj(vec![
            ("type", Json::Str("stage".to_string())),
            ("label", Json::Str(label.to_string())),
            ("isa", Json::Str(simd::active().as_str().to_string())),
        ]);
        self.emit(&line);
    }

    fn on_record(&mut self, rec: &IterRecord) {
        let (mm, solve, sample) = rec.phase_secs;
        let line = Json::obj(vec![
            ("type", Json::Str("iter".to_string())),
            ("stage", Json::Str(self.stage.clone())),
            ("iter", Json::Num(rec.iter as f64)),
            ("time_secs", Json::Num(rec.time_secs)),
            ("residual", num_or_null(rec.residual)),
            (
                "residual_hex",
                Json::Str(format!("{:016x}", rec.residual.to_bits())),
            ),
            (
                "proj_grad",
                rec.proj_grad.map(num_or_null).unwrap_or(Json::Null),
            ),
            ("mm_secs", Json::Num(mm)),
            ("solve_secs", Json::Num(solve)),
            ("sample_secs", Json::Num(sample)),
            (
                "hybrid",
                rec.hybrid_stats
                    .map(|(a, b)| Json::Arr(vec![num_or_null(a), num_or_null(b)]))
                    .unwrap_or(Json::Null),
            ),
        ]);
        self.emit(&line);
    }
}

/// CSV streaming sink: a fixed header written at creation, one row per
/// iteration, flushed per row.
pub struct CsvSink {
    path: PathBuf,
    out: Option<BufWriter<File>>,
    stage: String,
    error: Option<String>,
}

/// The [`CsvSink`] column schema. Frozen — downstream plotters parse it
/// positionally, so the kernel-ISA annotation lives only in the JSONL
/// stage lines; CSV consumers needing it should trace as JSONL.
pub const CSV_HEADER: &str =
    "stage,iter,time_secs,residual,proj_grad,mm_secs,solve_secs,sample_secs";

impl CsvSink {
    /// Create (truncating any existing file) and write the header.
    pub fn create(path: &Path) -> Result<CsvSink, String> {
        CsvSink::open(path, false)
    }

    /// Open for appending; the header is written only when the file is
    /// new or empty, so a resumed job continues the existing table.
    pub fn append(path: &Path) -> Result<CsvSink, String> {
        CsvSink::open(path, true)
    }

    fn open(path: &Path, append: bool) -> Result<CsvSink, String> {
        let mut out = create_writer(path, append)?;
        let has_prefix = append
            && std::fs::metadata(path).map(|m| m.len() > 0).unwrap_or(false);
        if !has_prefix {
            writeln!(out, "{CSV_HEADER}")
                .and_then(|()| out.flush())
                .map_err(|e| format!("write {path:?}: {e}"))?;
        }
        Ok(CsvSink {
            path: path.to_path_buf(),
            out: Some(out),
            stage: String::new(),
            error: None,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }
}

impl TraceSink for CsvSink {
    fn on_stage(&mut self, label: &str) {
        // CSV has no stage rows; the label becomes a column value
        self.stage = label.to_string();
    }

    fn on_record(&mut self, rec: &IterRecord) {
        let Some(out) = self.out.as_mut() else { return };
        let (mm, solve, sample) = rec.phase_secs;
        let pg = rec.proj_grad.map(|p| p.to_string()).unwrap_or_default();
        let res = writeln!(
            out,
            "{},{},{},{},{},{},{},{}",
            self.stage, rec.iter, rec.time_secs, rec.residual, pg, mm, solve, sample
        )
        .and_then(|()| out.flush());
        if let Err(e) = res {
            eprintln!("[trace] stream to {:?} stopped: {e}", self.path);
            self.error = Some(format!("write {:?}: {e}", self.path));
            self.out = None;
        }
    }
}

/// Trips a [`CancelToken`] once the **global** iteration count reaches
/// `after` — "global" meaning `base + records seen`, where `base` is the
/// iteration count already in the resume checkpoint, so the threshold
/// means the same thing whether the run is fresh or a later slice.
/// Records (and stage transitions) are forwarded to the optional inner
/// sink first, so the record that crosses the threshold is still
/// streamed before the engine sees the flag at the next step boundary.
pub struct CancelAfterSink<'a> {
    token: CancelToken,
    after: usize,
    seen: usize,
    inner: Option<&'a mut dyn TraceSink>,
}

impl<'a> CancelAfterSink<'a> {
    pub fn new(token: CancelToken, after: usize) -> CancelAfterSink<'a> {
        CancelAfterSink { token, after, seen: 0, inner: None }
    }

    /// Start the count at `base` (the resume checkpoint's `iter`) and
    /// forward everything to `inner`.
    pub fn resuming(
        token: CancelToken,
        after: usize,
        base: usize,
        inner: Option<&'a mut dyn TraceSink>,
    ) -> CancelAfterSink<'a> {
        CancelAfterSink { token, after, seen: base, inner }
    }
}

impl TraceSink for CancelAfterSink<'_> {
    fn on_stage(&mut self, label: &str) {
        if let Some(inner) = self.inner.as_deref_mut() {
            inner.on_stage(label);
        }
    }

    fn on_record(&mut self, rec: &IterRecord) {
        if let Some(inner) = self.inner.as_deref_mut() {
            inner.on_record(rec);
        }
        self.seen += 1;
        if self.seen >= self.after {
            self.token.cancel();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, residual: f64) -> IterRecord {
        IterRecord {
            iter,
            time_secs: 0.25 * (iter + 1) as f64,
            residual,
            proj_grad: (iter % 2 == 0).then_some(1e-3),
            phase_secs: (0.1, 0.2, 0.0),
            hybrid_stats: None,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("symnmf-trace-{}-{name}", std::process::id()))
    }

    #[test]
    fn jsonl_sink_streams_parseable_lines() {
        let path = tmp("jsonl-basic.jsonl");
        let mut sink = JsonlSink::create(&path).expect("create");
        sink.on_stage("BPP");
        sink.on_record(&rec(0, 0.5));
        sink.on_record(&rec(1, 0.25));
        assert!(sink.error().is_none());
        drop(sink);
        let text = std::fs::read_to_string(&path).expect("read");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let stage = Json::parse(lines[0]).expect("stage line");
        assert_eq!(stage.get("type").and_then(Json::as_str), Some("stage"));
        assert_eq!(stage.get("label").and_then(Json::as_str), Some("BPP"));
        assert_eq!(
            stage.get("isa").and_then(Json::as_str),
            Some(simd::active().as_str()),
            "stage line records the writing process's kernel dispatch"
        );
        let it = Json::parse(lines[2]).expect("iter line");
        assert_eq!(it.get("iter").and_then(Json::as_usize), Some(1));
        assert_eq!(it.get("stage").and_then(Json::as_str), Some("BPP"));
        assert_eq!(
            it.get("residual_hex").and_then(Json::as_str),
            Some(format!("{:016x}", 0.25f64.to_bits()).as_str())
        );
        std::fs::remove_file(&path).ok();
    }

    /// The flush-per-record contract: kill the writer mid-run (no Drop,
    /// no final flush — the sink is leaked) and the prefix already on
    /// disk must be complete and parseable line by line.
    #[test]
    fn killed_writer_leaves_parseable_prefix() {
        let path = tmp("jsonl-killed.jsonl");
        let mut sink = JsonlSink::create(&path).expect("create");
        sink.on_stage("HALS");
        for i in 0..5 {
            sink.on_record(&rec(i, 1.0 / (i + 1) as f64));
        }
        // simulate the process dying: never run Drop (which would flush
        // BufWriter's buffer) — only the per-record flushes count
        std::mem::forget(sink);
        let text = std::fs::read_to_string(&path).expect("read");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 6, "1 stage + 5 records must be on disk");
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line)
                .unwrap_or_else(|e| panic!("line {i} unparseable: {e}"));
            if i > 0 {
                assert_eq!(j.get("iter").and_then(Json::as_usize), Some(i - 1));
            }
        }
        std::fs::remove_file(&path).ok();

        // same property for the CSV sink
        let path = tmp("csv-killed.csv");
        let mut sink = CsvSink::create(&path).expect("create");
        sink.on_stage("HALS");
        for i in 0..4 {
            sink.on_record(&rec(i, 0.5));
        }
        std::mem::forget(sink);
        let text = std::fs::read_to_string(&path).expect("read");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5, "header + 4 rows must be on disk");
        assert_eq!(lines[0], CSV_HEADER);
        for row in &lines[1..] {
            assert_eq!(
                row.split(',').count(),
                CSV_HEADER.split(',').count(),
                "row has the header's column count: {row}"
            );
            assert!(row.starts_with("HALS,"));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cancel_after_fires_at_global_count() {
        let tok = CancelToken::new();
        let mut sink = CancelAfterSink::new(tok.clone(), 3);
        sink.on_record(&rec(0, 0.9));
        sink.on_record(&rec(1, 0.8));
        assert!(!tok.is_cancelled());
        sink.on_record(&rec(2, 0.7));
        assert!(tok.is_cancelled(), "third record must trip the token");

        // resuming form: base already counts the checkpointed records
        let tok = CancelToken::new();
        let mut sink = CancelAfterSink::resuming(tok.clone(), 3, 2, None);
        sink.on_record(&rec(2, 0.7));
        assert!(tok.is_cancelled(), "base 2 + 1 record reaches the threshold");
    }
}
