//! **LAI-SymNMF** (paper §3, Alg. LAI-SymNMF): compute a randomized
//! approximate truncated EVD X ≈ U·Λ·Uᵀ once (Apx-EVD over RRF/Ada-RRF),
//! then run any SymNMF iteration against the factored input, where the
//! bottleneck product X·F becomes U·(Vᵀ·F) at O(mlk) instead of O(m²k).
//!
//! Practical considerations of §3.3 are both implemented:
//! * **Ada-RRF** — adaptive choice of the power-iteration count q;
//! * **Iterative Refinement (IR)** — after the LAI iterations converge,
//!   continue with the true X under the same stopping rule.
//!
//! Under `SYMNMF_PRECISION=f32` (or [`SymNmfOptions::precision`]) the
//! two skinny matmuls of the factored apply run with f32-staged U/V
//! operands and f64 accumulation — the same policy as the compressed
//! pipeline (see `compressed`'s module header); everything downstream
//! (Gram, update, residual, IR over the true X) stays f64.

use crate::linalg::simd::{self, Precision};
use crate::linalg::{blas, DenseMat, F32Buf};
use crate::randnla::evd::{apx_evd, apx_evd_adaptive, ApxEvd};
use crate::randnla::SymOp;
use crate::symnmf::anls::{resolve_alpha, AltEngine, Metrics};
#[cfg(test)]
use crate::symnmf::anls::run_alternating_loop;
use crate::symnmf::engine::{
    run_solver, workspace_for, Checkpoint, EngineRun, RunControl, SolveSpec, Stage, TraceSink,
};
use crate::symnmf::init::initial_factor;
use crate::symnmf::metrics::SymNmfResult;
use crate::symnmf::options::{PowerIter, SymNmfOptions};
use crate::util::rng::Pcg64;
use crate::util::timer::{PhaseTimer, Stopwatch, PHASE_MM};

/// The factored low-rank approximate input X ≈ U·Vᵀ (V = U·Λ) as a
/// [`SymOp`]: `apply_into` costs two skinny matmuls. The l×k inner
/// product Vᵀ·F is staged through an interior scratch buffer (sized on
/// first use, reused across every call of a solve) so the hot loop
/// allocates nothing. The scratch lives behind a `Mutex` (uncontended in
/// the single-threaded solve loop, so the lock is noise next to the
/// matmuls) to keep `LaiOp: Sync` for the planned batched multi-seed
/// runs that share one read-only operator across worker threads.
pub struct LaiOp {
    pub u: DenseMat,
    pub v: DenseMat,
    fro_sq: f64,
    max_v: f64,
    mean_v: f64,
    /// l×k scratch for Vᵀ·F, reused across `apply_into` calls
    vtf: std::sync::Mutex<DenseMat>,
    /// compute precision of the two skinny matmuls (module header)
    precision: Precision,
    /// f32 stagings of U / V (empty under [`Precision::F64`])
    u32: Vec<f32>,
    v32: Vec<f32>,
    /// grow-only f32 stagings of F and Vᵀ·F, behind the same
    /// uncontended-Mutex pattern as `vtf` to keep `LaiOp: Sync`
    stage32: std::sync::Mutex<(F32Buf, F32Buf)>,
}

impl LaiOp {
    /// Wrap an approximate EVD; `alpha_source` supplies max/mean of the
    /// TRUE X so that α and the init scale match the exact algorithms.
    /// The apply runs in f64; see [`LaiOp::with_precision`].
    pub fn new<X: SymOp>(evd: &ApxEvd, alpha_source: &X) -> LaiOp {
        LaiOp {
            u: evd.u.clone(),
            v: evd.v(),
            fro_sq: evd.fro_norm_sq(),
            max_v: alpha_source.max_value(),
            mean_v: alpha_source.mean_value(),
            vtf: std::sync::Mutex::new(DenseMat::zeros(0, 0)),
            precision: Precision::F64,
            u32: Vec::new(),
            v32: Vec::new(),
            stage32: std::sync::Mutex::new((F32Buf::new(), F32Buf::new())),
        }
    }

    /// Select the apply's compute precision; [`Precision::F32`] stages
    /// the U/V operands as f32 once, here.
    pub fn with_precision(mut self, precision: Precision) -> LaiOp {
        self.precision = precision;
        let (u32, v32) = match precision {
            Precision::F64 => (Vec::new(), Vec::new()),
            Precision::F32 => (self.u.to_f32(), self.v.to_f32()),
        };
        self.u32 = u32;
        self.v32 = v32;
        self
    }
}

impl SymOp for LaiOp {
    fn dim(&self) -> usize {
        self.u.rows()
    }

    fn apply_into(&self, f: &DenseMat, out: &mut DenseMat) {
        // U·(Vᵀ·F): (l×k) inner product then (m×l)(l×k)
        let l = self.v.cols();
        let k = f.cols();
        let mut vtf = self.vtf.lock().unwrap_or_else(|e| e.into_inner());
        if vtf.shape() != (l, k) {
            *vtf = DenseMat::zeros(l, k); // first call (or width change) only
        }
        match self.precision {
            Precision::F64 => {
                blas::matmul_tn_into(&self.v, f, &mut *vtf);
                blas::matmul_into(&self.u, &*vtf, out);
            }
            Precision::F32 => {
                // staged f32 operands, f64 accumulation (module header)
                let isa = simd::active();
                let m = self.u.rows();
                let mut st = self.stage32.lock().unwrap_or_else(|e| e.into_inner());
                let (fstage, pstage) = &mut *st;
                let sf = fstage.stage(f.data());
                simd::matmul_tn_f32_into(isa, &self.v32, m, l, sf, k, &mut vtf);
                let sp = pstage.stage(vtf.data());
                simd::matmul_f32_into(isa, &self.u32, m, l, sp, k, out);
            }
        }
    }

    fn fro_norm_sq(&self) -> f64 {
        self.fro_sq
    }

    fn max_value(&self) -> f64 {
        self.max_v
    }

    fn mean_value(&self) -> f64 {
        self.mean_v
    }

    fn sampled_apply_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        // V·SᵀS·F ... not used by LAI-SymNMF; provide the generic form
        // U·(VᵀSᵀ)(S F) for completeness (setup-grade path; allocates).
        let scales: Vec<f64> = weights_sq.iter().map(|w| w.sqrt()).collect();
        let sv = self.v.gather_rows_scaled(samples, &scales);
        let sf = f.gather_rows_scaled(samples, &scales);
        let inner = blas::matmul_tn(&sv, &sf);
        blas::matmul_into(&self.u, &inner, out);
    }
}

/// Build the LAI (Apx-EVD) per the options' power policy, timing it as
/// setup + MM work. The returned operator applies at the options'
/// resolved compute precision (the Apx-EVD itself is always f64).
pub fn build_lai<X: SymOp>(
    x: &X,
    opts: &SymNmfOptions,
    rng: &mut Pcg64,
    phases: &mut PhaseTimer,
) -> (LaiOp, f64, ApxEvd) {
    let sw = Stopwatch::start();
    let l = opts.sketch_width();
    let evd = match opts.power {
        PowerIter::Static(q) => apx_evd(x, l, q, rng),
        PowerIter::Adaptive { q_max, tol } => apx_evd_adaptive(x, l, q_max, tol, rng),
    };
    let secs = sw.elapsed_secs();
    phases.add(PHASE_MM, std::time::Duration::from_secs_f64(secs));
    let op = LaiOp::new(&evd, x).with_precision(opts.resolved_precision());
    (op, secs, evd)
}

/// LAI-SymNMF with alternating updates (Alg. LAI-SymNMF); set
/// `opts.refine` for the "-IR" variants of §5.1. Thin wrapper over the
/// engine chain (`SYMNMF_DEADLINE_MS` honored).
pub fn lai_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    lai_symnmf_run(x, opts, &RunControl::from_env(), None, None).result
}

/// The controlled engine entry. LAI-SymNMF is engine *composition*: the
/// RRF/Apx-EVD build is the setup phase, stage 0 is the shared
/// [`AltEngine`] over the factored [`LaiOp`], and Iterative Refinement
/// (§3.3) is simply a second [`AltEngine`] stage over the true X that
/// the shared outer loop warm-starts from stage 0's final H — no
/// LAI-specific loop code remains.
pub fn lai_symnmf_run<X: SymOp>(
    x: &X,
    opts: &SymNmfOptions,
    ctrl: &RunControl,
    resume: Option<&Checkpoint>,
    trace: Option<&mut dyn TraceSink>,
) -> EngineRun {
    let xd: &dyn SymOp = x;
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let alpha = resolve_alpha(x, opts);
    let mut phases = PhaseTimer::new();
    let (lai, setup_secs, _evd) = build_lai(x, opts, &mut rng, &mut phases);
    let h0 = initial_factor(x, opts, &mut rng);
    let base_label = format!("LAI-{}", opts.rule.label());
    let mut stages: Vec<Stage<'_>> = vec![Stage {
        engine: Box::new(AltEngine::new(&lai, alpha, opts.rule, h0.clone())),
        label: base_label.clone(),
    }];
    if opts.refine {
        stages.push(Stage {
            engine: Box::new(AltEngine::new(xd, alpha, opts.rule, h0)),
            label: format!("{base_label}-IR"),
        });
    }
    let mut spec = SolveSpec {
        stages,
        metrics: Metrics::new(xd, true),
        setup_secs,
        phases,
    };
    let mut ws = workspace_for(&spec);
    run_solver(&mut spec, opts, ctrl, resume, trace, &mut ws)
}

/// The frozen pre-engine LAI(-IR) entry (pinning oracle): legacy
/// alternating loop over the LAI, then an explicit IR continuation with
/// stitched records.
#[cfg(test)]
pub(crate) fn lai_symnmf_reference<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let alpha = resolve_alpha(x, opts);
    let mut phases = PhaseTimer::new();
    let (lai, setup_secs, _evd) = build_lai(x, opts, &mut rng, &mut phases);
    let h0 = initial_factor(x, opts, &mut rng);
    let metrics = Metrics::new(x, true);

    let base_label = format!("LAI-{}", opts.rule.label());
    let mut result = run_alternating_loop(
        &lai,
        alpha,
        opts,
        h0,
        &metrics,
        base_label.clone(),
        setup_secs,
        phases,
    );

    if opts.refine {
        // Iterative Refinement: same loop, true X, warm start, clock
        // carries on from where LAI stopped.
        let clock = result.total_secs();
        let h_warm = result.h.clone();
        let refined = run_alternating_loop(
            x.as_dyn(),
            alpha,
            opts,
            h_warm,
            &metrics,
            format!("{base_label}-IR"),
            clock,
            result.phases.clone(),
        );
        // stitch the iteration logs together
        let mut records = result.records;
        let offset = records.len();
        records.extend(refined.records.into_iter().map(|mut r| {
            r.iter += offset;
            r
        }));
        return SymNmfResult {
            label: format!("{base_label}-IR"),
            h: refined.h,
            w: refined.w,
            records,
            phases: refined.phases,
            setup_secs,
        };
    }
    result.label = base_label;
    result
}

/// Helper: view a concrete SymOp as a trait object (the reference loop
/// takes &dyn).
#[cfg(test)]
trait AsDyn: SymOp + Sized {
    fn as_dyn(&self) -> &dyn SymOp {
        self
    }
}
#[cfg(test)]
impl<T: SymOp> AsDyn for T {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nls::UpdateRule;
    use crate::symnmf::anls::symnmf_anls;

    fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        x.symmetrize();
        x
    }

    #[test]
    fn lai_op_approximates_apply() {
        let x = planted(80, 4, 1);
        let mut rng = Pcg64::seed_from_u64(2);
        let opts = SymNmfOptions::new(4);
        let mut phases = PhaseTimer::new();
        let (lai, _secs, _evd) = build_lai(&x, &opts, &mut rng, &mut phases);
        let f = DenseMat::gaussian(80, 4, &mut rng);
        let exact = SymOp::apply(&x, &f);
        let approx = lai.apply(&f);
        let rel = exact.diff_fro(&approx) / exact.fro_norm();
        assert!(rel < 1e-6, "planted rank-4 ⊂ l=12 sketch: rel={rel}");

        // the write-into form must agree and must reuse its interior
        // Vᵀ·F scratch across calls (zero-alloc hot path)
        let mut out = DenseMat::zeros(80, 4);
        lai.apply_into(&f, &mut out);
        assert!(out.diff_fro(&approx) < 1e-14);
        let scratch_ptr = lai.vtf.lock().unwrap().data().as_ptr();
        lai.apply_into(&f, &mut out);
        assert_eq!(
            lai.vtf.lock().unwrap().data().as_ptr(),
            scratch_ptr,
            "LaiOp scratch must be reused across applies"
        );
    }

    /// The f32-staged apply tracks the f64 apply to f32-level accuracy
    /// and is deterministic (bitwise-equal across repeated calls).
    #[test]
    fn f32_apply_tracks_f64_and_is_deterministic() {
        let x = planted(60, 3, 21);
        let opts = SymNmfOptions::new(3);
        let mut phases = PhaseTimer::new();
        let mut rng = Pcg64::seed_from_u64(9);
        let (lai, _s, _e) = build_lai(&x, &opts, &mut rng, &mut phases);
        // identical Apx-EVD (same seed), f32 apply tier
        let mut rng = Pcg64::seed_from_u64(9);
        let opts32 = opts.clone().with_precision(Precision::F32);
        let (lai32, _s, _e) = build_lai(&x, &opts32, &mut rng, &mut phases);

        let mut rng = Pcg64::seed_from_u64(33);
        let f = DenseMat::gaussian(60, 3, &mut rng);
        let exact = lai.apply(&f);
        let mut out = DenseMat::zeros(60, 3);
        lai32.apply_into(&f, &mut out);
        let rel = exact.diff_fro(&out) / exact.fro_norm();
        assert!(rel < 1e-4, "f32 apply must track f64: rel={rel}");

        let mut again = DenseMat::zeros(60, 3);
        lai32.apply_into(&f, &mut again);
        for (a, b) in out.data().iter().zip(again.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 apply must be deterministic");
        }
    }

    #[test]
    fn lai_symnmf_matches_exact_quality_on_low_rank() {
        let x = planted(70, 4, 3);
        for rule in [UpdateRule::Bpp, UpdateRule::Hals] {
            let mut opts = SymNmfOptions::new(4).with_rule(rule).with_seed(7);
            opts.max_iters = 120;
            let exact = symnmf_anls(&x, &opts);
            let lai = lai_symnmf(&x, &opts);
            assert!(lai.h.is_nonneg());
            assert!(
                lai.min_residual() < exact.min_residual() + 0.05,
                "{rule:?}: LAI {} vs exact {}",
                lai.min_residual(),
                exact.min_residual()
            );
            assert!(lai.setup_secs > 0.0);
        }
    }

    /// Acceptance: the engine chain is bitwise-identical to the frozen
    /// pre-refactor LAI(-IR) entry — the IR warm start through the
    /// shared outer loop reproduces the legacy stitching exactly.
    #[test]
    fn engine_path_pinned_bitwise_to_reference() {
        use crate::symnmf::engine::{assert_results_bitwise_eq, RunControl};
        for (m, k) in [(30, 2), (63, 7)] {
            let x = planted(m, k, 13);
            for refine in [false, true] {
                let mut opts = SymNmfOptions::new(k)
                    .with_rule(UpdateRule::Hals)
                    .with_seed(17);
                opts.max_iters = 9;
                opts.refine = refine;
                let oracle = lai_symnmf_reference(&x, &opts);
                let engine = lai_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
                assert_results_bitwise_eq(
                    &oracle,
                    &engine.result,
                    &format!("lai refine={refine} k={k}"),
                );
            }
        }
    }

    /// Acceptance: checkpoint/resume bitwise across BOTH stages of the
    /// IR chain, plus deadline-0 initial iterate.
    #[test]
    fn checkpoint_resume_and_deadline() {
        use crate::symnmf::engine::{assert_results_bitwise_eq, RunControl, RunStatus};
        for k in [2usize, 7] {
            let x = planted(10 * k, k, 23);
            let mut opts = SymNmfOptions::new(k).with_rule(UpdateRule::Hals).with_seed(5);
            opts.max_iters = 6;
            opts.refine = true;
            let full = lai_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
            for steps in [2usize, opts.max_iters + 1] {
                let paused = lai_symnmf_run(
                    &x,
                    &opts,
                    &RunControl::unlimited().with_max_steps(steps),
                    None,
                    None,
                );
                if steps < full.result.iters() {
                    assert_eq!(paused.checkpoint.status, RunStatus::Paused);
                }
                let cp =
                    Checkpoint::parse(&paused.checkpoint.serialize()).expect("roundtrip");
                let resumed =
                    lai_symnmf_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
                assert_results_bitwise_eq(
                    &full.result,
                    &resumed.result,
                    &format!("lai-ir k={k} pause@{steps}"),
                );
            }
            let dead = lai_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited().with_deadline(0.0),
                None,
                None,
            );
            assert_eq!(dead.checkpoint.status, RunStatus::Deadline);
            assert!(dead.result.records.is_empty());
            let resumed = lai_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited(),
                Some(&dead.checkpoint),
                None,
            );
            assert_results_bitwise_eq(
                &full.result,
                &resumed.result,
                &format!("lai deadline-0 k={k}"),
            );
        }
    }

    /// Satellite acceptance: cancel-before-first-step and a mid-run
    /// cancel that lands INSIDE the IR stage chain both leave resumable
    /// checkpoints that complete to the uninterrupted run bitwise.
    #[test]
    fn cancel_token_aborts_and_resumes_bitwise() {
        use crate::symnmf::engine::{
            assert_results_bitwise_eq, CancelToken, RunControl, RunStatus,
        };
        use crate::symnmf::trace::CancelAfterSink;
        let x = planted(40, 2, 37);
        let mut opts = SymNmfOptions::new(2).with_rule(UpdateRule::Hals).with_seed(9);
        opts.max_iters = 5;
        opts.refine = true; // two warm-started stages
        let full = lai_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);

        let tok = CancelToken::new();
        tok.cancel();
        let cancelled = lai_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            None,
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.result.iters(), 0);
        let resumed = lai_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited(),
            Some(&cancelled.checkpoint),
            None,
        );
        assert_results_bitwise_eq(&full.result, &resumed.result, "lai cancel-0 resume");

        // cancel after the LAI stage's cap (5 records) — the abort lands
        // in the IR continuation stage
        let tok = CancelToken::new();
        let mut hook = CancelAfterSink::new(tok.clone(), opts.max_iters + 1);
        let cancelled = lai_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            Some(&mut hook),
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.checkpoint.stage, 1, "abort inside the IR stage");
        let cp = Checkpoint::parse(&cancelled.checkpoint.serialize()).expect("roundtrip");
        let resumed = lai_symnmf_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
        assert_results_bitwise_eq(&full.result, &resumed.result, "lai mid-cancel resume");
    }

    #[test]
    fn ir_continues_and_improves_or_matches() {
        let x = planted(60, 3, 4);
        let mut opts = SymNmfOptions::new(3).with_seed(8);
        opts.max_iters = 60;
        opts.refine = false;
        let plain = lai_symnmf(&x, &opts);
        opts.refine = true;
        let ir = lai_symnmf(&x, &opts);
        assert!(ir.label.ends_with("-IR"));
        assert!(ir.iters() >= plain.iters(), "IR adds iterations");
        assert!(ir.min_residual() <= plain.min_residual() + 1e-6);
    }

    #[test]
    fn clock_includes_setup() {
        let x = planted(50, 3, 5);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 5;
        let res = lai_symnmf(&x, &opts);
        assert!(res.records[0].time_secs >= res.setup_secs);
    }
}
