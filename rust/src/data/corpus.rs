//! Planted-topic document–term corpus (Web-of-Science stand-in).
//!
//! Each of `k` topics owns a block of "anchor" terms plus a shared
//! background vocabulary with Zipf-distributed frequencies. A document
//! samples tokens from a (1−γ)·topic + γ·background mixture; labels are
//! the planted topics. The generator also produces human-readable
//! synthetic words so the Tables 3/7/8 topword reports read naturally.

use crate::sparse::CsrMat;
use crate::util::rng::{AliasTable, Pcg64};

/// A generated corpus: docs×terms counts, ground-truth labels, vocabulary.
pub struct Corpus {
    /// docs × terms raw counts
    pub counts: CsrMat,
    /// planted topic of each document
    pub labels: Vec<usize>,
    /// synthetic vocabulary (terms)
    pub vocab: Vec<String>,
    pub num_topics: usize,
}

/// Corpus generator parameters.
pub struct CorpusParams {
    pub num_docs: usize,
    pub num_terms: usize,
    pub num_topics: usize,
    /// mean tokens per document
    pub doc_len: usize,
    /// background-mixture weight γ ∈ [0,1); higher → noisier clustering
    pub noise: f64,
    /// fraction of topical tokens drawn from a *different* random topic
    /// (cross-topic bleed — real corpora are not block-diagonal)
    pub topic_mix: f64,
    pub seed: u64,
}

impl Default for CorpusParams {
    fn default() -> Self {
        CorpusParams {
            num_docs: 800,
            num_terms: 2000,
            num_topics: 7,
            doc_len: 80,
            noise: 0.35,
            topic_mix: 0.0,
            seed: 0,
        }
    }
}

const SYLLABLES: &[&str] = &[
    "ra", "mi", "ko", "ta", "lu", "ve", "so", "ni", "pa", "de", "ga", "ri",
    "mo", "ze", "bu", "ka", "ti", "le", "fo", "su",
];

fn synth_word(idx: usize) -> String {
    let mut s = String::new();
    let mut x = idx + 7;
    for _ in 0..3 {
        s.push_str(SYLLABLES[x % SYLLABLES.len()]);
        x /= SYLLABLES.len();
    }
    s
}

/// Generate a corpus.
pub fn generate(params: &CorpusParams) -> Corpus {
    let CorpusParams { num_docs, num_terms, num_topics, doc_len, noise, topic_mix, seed } = *params;
    assert!(num_terms >= 2 * num_topics, "need enough terms for anchors");
    let mut rng = Pcg64::seed_from_u64(seed);

    // term ownership: first (1−shared) fraction of terms split across
    // topics as anchors; the rest is shared background.
    let anchors_per_topic = (num_terms / 2) / num_topics;
    let background_start = anchors_per_topic * num_topics;

    // Zipf weights for the background block. Exponent 1.6 (real text is
    // 1–1.3 for full vocabularies, steeper for stopword-dominated tails):
    // concentrates the background on few effective dimensions so the
    // adjacency spectrum decays the way real corpora's do — this is what
    // lets Ada-RRF stop after a few power iterations (App. D).
    let bg_weights: Vec<f64> = (background_start..num_terms)
        .enumerate()
        .map(|(r, _)| (1.0 + r as f64).powf(-1.6))
        .collect();
    let bg_table = AliasTable::new(&bg_weights);

    // per-topic Zipf over its anchor block
    let topic_weights: Vec<f64> = (0..anchors_per_topic)
        .map(|r| 1.0 / (1.0 + r as f64))
        .collect();
    let topic_table = AliasTable::new(&topic_weights);

    // Zipf-imbalanced class sizes (real corpora are never balanced; the
    // imbalance also slows NMF convergence the way real text does).
    let topic_sizes: Vec<f64> = (0..num_topics).map(|r| 1.0 / (1.0 + r as f64)).collect();
    let topic_of_doc = AliasTable::new(&topic_sizes);

    let mut trips: Vec<(usize, usize, f64)> = Vec::new();
    let mut labels = Vec::with_capacity(num_docs);
    for d in 0..num_docs {
        let topic = if d < num_topics {
            d // every topic non-empty
        } else {
            topic_of_doc.sample(&mut rng)
        };
        labels.push(topic);
        // document length ~ doc_len ± 25%
        let len = (doc_len as f64 * (0.75 + 0.5 * rng.uniform())) as usize;
        for _ in 0..len.max(1) {
            let term = if rng.uniform() < noise {
                background_start + bg_table.sample(&mut rng)
            } else {
                let t = if topic_mix > 0.0 && rng.uniform() < topic_mix {
                    rng.below(num_topics) // cross-topic bleed
                } else {
                    topic
                };
                t * anchors_per_topic + topic_table.sample(&mut rng)
            };
            trips.push((d, term, 1.0));
        }
    }
    let counts = CsrMat::from_coo(num_docs, num_terms, trips);
    let vocab = (0..num_terms).map(synth_word).collect();
    Corpus { counts, labels, vocab, num_topics }
}

/// tf-idf transform of a docs×terms count matrix:
/// tfidf(d,t) = tf(d,t) · ln(N / (1 + df(t))). Rows with zero norm stay 0.
pub fn tfidf(counts: &CsrMat) -> CsrMat {
    let n_docs = counts.rows() as f64;
    // document frequency per term
    let mut df = vec![0usize; counts.cols()];
    for d in 0..counts.rows() {
        let (cols, _) = counts.row(d);
        for &t in cols {
            df[t] += 1;
        }
    }
    let idf: Vec<f64> = df
        .iter()
        .map(|&f| (n_docs / (1.0 + f as f64)).ln().max(0.0))
        .collect();
    let mut trips = Vec::with_capacity(counts.nnz());
    for d in 0..counts.rows() {
        let (cols, vals) = counts.row(d);
        for (&t, &v) in cols.iter().zip(vals) {
            let w = v * idf[t];
            if w > 0.0 {
                trips.push((d, t, w));
            }
        }
    }
    CsrMat::from_coo(counts.rows(), counts.cols(), trips)
}

/// Top `n` words for each cluster by mean tf-idf association — the
/// Tables 3/7/8 report. `assign` maps docs to clusters.
pub fn topwords(
    tfidf_mat: &CsrMat,
    vocab: &[String],
    assign: &[usize],
    k: usize,
    n: usize,
) -> Vec<Vec<String>> {
    let t = tfidf_mat.cols();
    let mut sums = vec![vec![0.0f64; t]; k];
    let mut sizes = vec![0usize; k];
    for d in 0..tfidf_mat.rows() {
        let c = assign[d];
        sizes[c] += 1;
        let (cols, vals) = tfidf_mat.row(d);
        for (&j, &v) in cols.iter().zip(vals) {
            sums[c][j] += v;
        }
    }
    (0..k)
        .map(|c| {
            let mut idx: Vec<usize> = (0..t).collect();
            idx.sort_by(|&a, &b| sums[c][b].partial_cmp(&sums[c][a]).unwrap());
            idx.into_iter()
                .take(n)
                .filter(|&j| sums[c][j] > 0.0)
                .map(|j| vocab[j].clone())
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let c = generate(&CorpusParams {
            num_docs: 70,
            num_terms: 200,
            num_topics: 7,
            doc_len: 30,
            noise: 0.2,
            topic_mix: 0.0,
            seed: 1,
        });
        assert_eq!(c.counts.rows(), 70);
        assert_eq!(c.counts.cols(), 200);
        assert_eq!(c.labels.len(), 70);
        assert_eq!(c.vocab.len(), 200);
        assert!(c.labels.iter().all(|&l| l < 7));
        // Zipf-imbalanced but every class non-empty
        let sizes = crate::clustering::assign::cluster_sizes(&c.labels, 7);
        assert!(sizes.iter().all(|&s| s >= 1));
        assert!(sizes[0] > sizes[6], "sizes should be imbalanced: {sizes:?}");
    }

    #[test]
    fn anchors_separate_topics() {
        // with low noise, docs of different topics share few terms
        let c = generate(&CorpusParams {
            num_docs: 40,
            num_terms: 400,
            num_topics: 4,
            doc_len: 60,
            noise: 0.0,
            topic_mix: 0.0,
            seed: 2,
        });
        // doc 0 (topic 0) and doc 1 (topic 1) must have disjoint terms
        let (t0, _) = c.counts.row(0);
        let (t1, _) = c.counts.row(1);
        let s0: std::collections::HashSet<_> = t0.iter().collect();
        assert!(t1.iter().all(|t| !s0.contains(t)));
    }

    #[test]
    fn tfidf_downweights_common_terms() {
        // a term in every doc gets idf ≈ ln(N/(N+1)) → clamped to 0
        let counts = CsrMat::from_coo(
            3,
            2,
            vec![
                (0, 0, 5.0),
                (1, 0, 3.0),
                (2, 0, 2.0), // term 0 everywhere
                (0, 1, 2.0), // term 1 rare
            ],
        );
        let w = tfidf(&counts);
        assert_eq!(w.get(0, 0), 0.0, "ubiquitous term zeroed");
        assert!(w.get(0, 1) > 0.0, "rare term kept");
    }

    #[test]
    fn topwords_find_anchor_terms() {
        let c = generate(&CorpusParams {
            num_docs: 60,
            num_terms: 300,
            num_topics: 3,
            doc_len: 80,
            noise: 0.1,
            topic_mix: 0.0,
            seed: 3,
        });
        let w = tfidf(&c.counts);
        let words = topwords(&w, &c.vocab, &c.labels, 3, 10);
        assert_eq!(words.len(), 3);
        // each topic's top words must be mostly anchors (first 150 terms,
        // 50 per topic): check word of topic 0 is among terms 0..50
        let anchors_per_topic = 150 / 3;
        for (topic, list) in words.iter().enumerate() {
            assert!(!list.is_empty());
            let top = &list[0];
            let idx = c.vocab.iter().position(|v| v == top).unwrap();
            assert!(
                idx >= topic * anchors_per_topic
                    && idx < (topic + 1) * anchors_per_topic,
                "topic {topic} top word index {idx}"
            );
        }
    }
}
