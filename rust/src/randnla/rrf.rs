//! Randomized Range Finder (paper Alg. RRF) and the adaptive variant
//! Ada-RRF (paper Alg. Ada-RRF / App. D) that picks the power-iteration
//! count q automatically by monitoring the QB-decomposition residual.
//!
//! For a symmetric input the power scheme Y = (XXᵀ)^q XΩ = X^{2q+1}Ω is
//! realized by repeated application of X with re-orthonormalization
//! between applications (numerically essential; plain powering washes out
//! the trailing subspace in float arithmetic).

use crate::linalg::{qr, DenseMat};
use crate::randnla::op::SymOp;
use crate::util::rng::Pcg64;

/// Result of a range-finder run.
pub struct RrfResult {
    /// Orthonormal basis Q ∈ R^{m×l} for the (approximate) leading range.
    pub q_basis: DenseMat,
    /// Number of applications of X performed (q power iterations apply X
    /// q+1 times — App. D).
    pub applications: usize,
    /// Relative QB residual ‖X − QQᵀX‖_F / ‖X‖_F after each check
    /// (Ada-RRF only; empty for the static variant).
    pub residual_history: Vec<f64>,
}

/// Static RRF with a fixed exponent q (paper Alg. RRF).
///
/// `l = r + rho` columns are drawn; the caller passes l directly.
pub fn rrf<X: SymOp>(x: &X, l: usize, q: usize, rng: &mut Pcg64) -> RrfResult {
    let m = x.dim();
    let omega = DenseMat::gaussian(m, l, rng);
    // one m×l product buffer reused across every power step (apply_into)
    let mut y = DenseMat::zeros(m, l);
    x.apply_into(&omega, &mut y);
    // CholeskyQR for the re-orthonormalizations (§Perf): ~10× faster than
    // Householder at these shapes; each power step re-orthonormalizes so
    // the squared-conditioning loss never accumulates (jittered fallback
    // guards the pathological case).
    let mut qb = qr::orthonormalize(&y);
    let mut applications = 1;
    for _ in 0..q {
        x.apply_into(&qb, &mut y);
        applications += 1;
        qb = qr::orthonormalize(&y);
    }
    RrfResult { q_basis: qb, applications, residual_history: Vec::new() }
}

/// Ada-RRF (paper Alg. Ada-RRF): after each application of X the residual
/// of the implied QB-decomposition is evaluated for free via the trace
/// trick (App. D):  ‖QB − X‖²_F = ‖X‖²_F − tr(BBᵀ) with B = QᵀX = (XQ)ᵀ.
/// Iteration stops once the *relative* residual improves by less than
/// `tol` (the paper uses 1e-3 per power iteration for WoS) or `q_max`
/// power iterations have run.
pub fn ada_rrf<X: SymOp>(
    x: &X,
    l: usize,
    q_max: usize,
    tol: f64,
    rng: &mut Pcg64,
) -> RrfResult {
    let m = x.dim();
    let xnorm_sq = x.fro_norm_sq();
    let omega = DenseMat::gaussian(m, l, rng);
    // one m×l product buffer reused across every power step (apply_into)
    let mut y = DenseMat::zeros(m, l);
    x.apply_into(&omega, &mut y);
    let mut qb = qr::orthonormalize(&y);
    let mut applications = 1;
    let mut history: Vec<f64> = Vec::new();

    // Stopping is judged on the residual improvement per power iteration,
    // both in absolute terms (`tol`, the paper's 1e-3-style threshold)
    // and relative to the FIRST power iteration's improvement: once an
    // extra application of X recovers < 15% of what the first one did,
    // further powering is no longer paying for its O(m²l) cost. The
    // relative guard makes the rule scale-free on flat spectra (graph
    // Laplacian-normalized inputs), where absolute improvements can sit
    // just above any fixed tol for many iterations.
    let mut first_gain: Option<f64> = None;
    for _ in 0..q_max {
        // B = (X·Q)ᵀ; one application both advances the power iteration
        // and prices the residual check — "if q power iterations are
        // performed we only apply X, q+1 times".
        x.apply_into(&qb, &mut y);
        applications += 1;
        let resid_sq = (xnorm_sq - y.fro_norm_sq()).max(0.0);
        let rel = (resid_sq / xnorm_sq.max(1e-300)).sqrt();
        qb = qr::orthonormalize(&y);
        let stop = match history.last() {
            None => false,
            Some(prev) => {
                let gain = prev - rel;
                let fg = *first_gain.get_or_insert(gain.max(1e-300));
                gain < tol || gain < 0.15 * fg
            }
        };
        history.push(rel);
        if stop {
            break;
        }
    }
    RrfResult { q_basis: qb, applications, residual_history: history }
}

/// Relative QB residual of a basis: ‖X − QQᵀX‖_F / ‖X‖_F (costs one
/// application; used by tests and diagnostics).
pub fn qb_residual<X: SymOp>(x: &X, q_basis: &DenseMat) -> f64 {
    let b = x.apply(q_basis);
    let xn = x.fro_norm_sq();
    ((xn - b.fro_norm_sq()).max(0.0) / xn.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;

    /// Symmetric rank-r test matrix plus small noise.
    fn low_rank_sym(m: usize, r: usize, noise: f64, rng: &mut Pcg64) -> DenseMat {
        let u = DenseMat::gaussian(m, r, rng);
        let mut x = blas::matmul_nt(&u, &u);
        let mut e = DenseMat::gaussian(m, m, rng);
        e.symmetrize();
        x.axpy(noise, &e);
        x.symmetrize();
        x
    }

    #[test]
    fn rrf_captures_low_rank_range() {
        let mut rng = Pcg64::seed_from_u64(42);
        let x = low_rank_sym(80, 5, 0.0, &mut rng);
        let res = rrf(&x, 10, 1, &mut rng);
        assert_eq!(res.q_basis.shape(), (80, 10));
        // basis is orthonormal
        let qtq = blas::gram(&res.q_basis);
        assert!(qtq.diff_fro(&DenseMat::eye(10)) < 1e-10);
        // exact rank 5 < l=10 → residual ~ 0
        assert!(qb_residual(&x, &res.q_basis) < 1e-8);
    }

    #[test]
    fn power_iterations_improve_noisy_capture() {
        let mut rng = Pcg64::seed_from_u64(7);
        let x = low_rank_sym(100, 4, 0.5, &mut rng);
        let r0 = qb_residual(&x, &rrf(&x, 6, 0, &mut rng).q_basis);
        let r2 = qb_residual(&x, &rrf(&x, 6, 2, &mut rng).q_basis);
        assert!(
            r2 <= r0 + 1e-9,
            "q=2 should not be worse: q0 {r0} vs q2 {r2}"
        );
    }

    #[test]
    fn ada_rrf_stops_early_on_easy_input() {
        let mut rng = Pcg64::seed_from_u64(9);
        let x = low_rank_sym(60, 3, 0.0, &mut rng);
        let res = ada_rrf(&x, 8, 10, 1e-3, &mut rng);
        // exactly low-rank → first residual already ~0, stop after 2 checks
        assert!(res.applications <= 3, "applications={}", res.applications);
        assert!(*res.residual_history.first().unwrap() < 1e-6);
    }

    #[test]
    fn ada_rrf_residual_history_is_monotone_nonincreasing() {
        let mut rng = Pcg64::seed_from_u64(11);
        let x = low_rank_sym(90, 6, 1.0, &mut rng);
        let res = ada_rrf(&x, 10, 6, 0.0, &mut rng);
        for w in res.residual_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-8, "history {:?}", res.residual_history);
        }
    }

    #[test]
    fn trace_trick_matches_explicit_residual() {
        let mut rng = Pcg64::seed_from_u64(13);
        let x = low_rank_sym(50, 4, 0.3, &mut rng);
        let res = rrf(&x, 8, 1, &mut rng);
        let fast = qb_residual(&x, &res.q_basis);
        // explicit: ‖X − Q(QᵀX)‖ / ‖X‖
        let b = blas::matmul_tn(&res.q_basis, &x);
        let rec = blas::matmul(&res.q_basis, &b);
        let explicit = x.diff_fro(&rec) / x.fro_norm();
        assert!((fast - explicit).abs() < 1e-8, "fast {fast} explicit {explicit}");
    }
}
