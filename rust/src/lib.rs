//! # symnmf — Randomized Algorithms for Symmetric Nonnegative Matrix Factorization
//!
//! A full reproduction of Hayashi, Aksoy, Ballard & Park (2024):
//! *Randomized Algorithms for Symmetric Nonnegative Matrix Factorization*.
//!
//! The crate implements, from scratch:
//!
//! * the two proposed randomized algorithms — [`symnmf::lai`] (LAI-SymNMF:
//!   SymNMF of a randomized low-rank approximate input, with iterative
//!   refinement and the adaptive randomized range finder) and
//!   [`symnmf::lvs`] (LvS-SymNMF: leverage-score-sampled NLS subproblems
//!   with the hybrid deterministic+random scheme of §4.2);
//! * every deterministic baseline the paper compares against — regularized
//!   ANLS with the BPP active-set solver, regularized HALS, PGNCG, and the
//!   Compressed-NMF baseline of Tepper & Sapiro;
//! * the RandNLA toolbox they build on — randomized range finder, adaptive
//!   RRF, approximate truncated EVD, exact leverage scores via CholeskyQR,
//!   hybrid sampling matrices;
//! * the numerical substrate — dense blocked BLAS-like kernels, Cholesky /
//!   CholeskyQR / Householder QR, a symmetric eigensolver, CSR sparse
//!   matrices with SpMM and row sampling;
//! * the evaluation stack — graph construction (EDVW hypergraph expansion,
//!   stochastic block models), clustering (argmax assignment, ARI,
//!   similarity silhouettes, k-means, a spectral-clustering baseline),
//!   and an experiment driver that regenerates every table and figure of
//!   the paper's §5.
//!
//! The dense per-iteration hot spot (the products `X·F` and `FᵀF`) can be
//! executed either by the native rust kernels or through AOT-compiled
//! XLA/PJRT executables whose HLO was lowered from a JAX model calling
//! Pallas kernels (see `python/compile/` and [`runtime`]). Python never
//! runs at request time.

pub mod clustering;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod nls;
pub mod randnla;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod symnmf;
pub mod util;
