//! Data generation and construction pipelines for the §5 experiments.
//!
//! The paper's data sets (Web of Science, Microsoft OAG) are not
//! redistributable / far beyond this testbed; per DESIGN.md §3 we build
//! synthetic equivalents that exercise identical code paths:
//!
//! * [`corpus`] — a planted-topic document–term corpus with Zipf
//!   vocabulary and tf-idf weighting (WoS stand-in), plus the topword
//!   extraction used by Tables 3/7/8;
//! * [`edvw`] — the EDVW hypergraph → symmetric adjacency construction
//!   of [27] (documents = vertices, terms = hyperedges), producing the
//!   dense symmetric input of §5.1;
//! * [`sbm`] — a stochastic block model with a dominant core block
//!   (OAG stand-in), producing the large sparse input of §5.2.

pub mod corpus;
pub mod edvw;
pub mod sbm;
