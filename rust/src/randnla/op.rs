//! Abstraction over the symmetric data matrix X.
//!
//! Every algorithm in the paper touches X only through the product X·F
//! with a skinny dense block F (that is the entire point of LAI-SymNMF:
//! §3 "Computing matrix products with the data matrix X is the main
//! computational bottleneck"). `SymOp` captures exactly that interface, so
//! the same algorithm code runs against:
//!
//!  * a dense [`DenseMat`] (the cache-blocked symmetric kernel
//!    `blas::symm_tall_into`, which skips strictly-lower off-diagonal
//!    blocks of X — X must still be stored in full),
//!  * a packed-triangular dense [`crate::linalg::SymPacked`] (upper
//!    triangle only, block-panel layout — half the resident footprint,
//!    same blocked kernel structure),
//!  * a sparse [`CsrMat`] (column-panel-tiled CSR SpMM),
//!  * a PJRT-backed dense operator ([`crate::runtime::exec::PjrtSymOp`])
//!    whose X·F executes the AOT-compiled Pallas kernel, and
//!  * a factored LAI `U·Vᵀ` ([`crate::symnmf::lai::LaiOp`]).
//!
//! ## Write-into dispatch protocol
//!
//! The *required* methods are the write-into forms [`SymOp::apply_into`]
//! and [`SymOp::sampled_apply_into`]: each backend implements them
//! natively against a caller-provided output buffer (pre-sized by the
//! per-iteration [`crate::linalg::workspace::IterWorkspace`]), so the
//! steady-state hot loop of every driver performs zero heap allocation.
//! The allocating [`SymOp::apply`] / [`SymOp::sampled_apply`] remain as
//! thin default wrappers for setup-phase and test callers. Backends must
//! fully overwrite `out` (accumulating backends zero it first).
//!
//! ## Sampled apply: gather reformulation and bitwise contract
//!
//! The LvS sampled product X·SᵀS·F is, per sample r, a rank-1 scatter
//! `out[j,:] += w_r·X[j,i_r]·F[i_r,:]`. Parallelizing the scatter
//! directly would race on output rows, and atomics or per-thread
//! partials would change the floating-point summation order. Instead
//! every parallel backend reformulates it as a **gather over disjoint
//! output-row chunks**: each [`crate::util::pool`] worker owns a
//! j-range `[lo,hi)` and accumulates the contributions of *all* samples
//! into its own rows, walking samples in submission order with j
//! ascending inside each sample — exactly the order of the serial loop
//! restricted to that range. Per output element the partial sums
//! therefore arrive in an identical sequence, so the parallel kernels
//! are **bitwise-equal to serial by construction** at any thread count,
//! on either `SYMNMF_POOL` backend, for every dispatched ISA (the
//! per-row axpy routes through the bitwise tier of
//! [`crate::linalg::simd`], itself pinned to the scalar loop). Each
//! backend retains its serial loop as a pinning oracle —
//! [`sampled_apply_dense_serial`], `CsrMat::sampled_spmm_sym_into_serial`,
//! `SymPacked::sampled_apply_into_serial`,
//! `SymPackedSpilled::sampled_apply_into_serial` — and the
//! `integration_lvs_parity` suite asserts bit equality across the full
//! ISA × pool × backend matrix.

use crate::linalg::simd::{self, KernelIsa};
use crate::linalg::{blas, DenseMat};
use crate::sparse::CsrMat;
use crate::util::threadpool::{parallel_for_chunks, SendPtr};

/// A symmetric linear operator X ∈ R^{m×m} accessed via block products.
pub trait SymOp {
    /// Dimension m.
    fn dim(&self) -> usize;

    /// Write X·F (F: m×k dense) into the pre-allocated `out` (m×k). This
    /// is the hot-path form every backend implements natively; `out` is
    /// fully overwritten.
    fn apply_into(&self, f: &DenseMat, out: &mut DenseMat);

    /// Compute X·F, allocating the output — thin wrapper over
    /// [`SymOp::apply_into`] for setup-phase and test callers.
    fn apply(&self, f: &DenseMat) -> DenseMat {
        let mut out = DenseMat::zeros(self.dim(), f.cols());
        self.apply_into(f, &mut out);
        out
    }

    /// ‖X‖²_F — needed by the Ada-RRF residual trick (App. D) and the
    /// normalized-residual stopping criterion (App. C).
    fn fro_norm_sq(&self) -> f64;

    /// max entry — the paper's recommended α = max(X) (§5.1).
    fn max_value(&self) -> f64;

    /// mean entry ζ — the §5 initialization scale 2·√(ζ/k).
    fn mean_value(&self) -> f64;

    /// Write the sampled product X·SᵀS·F (LvS-SymNMF) into the
    /// pre-allocated `out` (m×k, fully overwritten). Dense/sparse impls
    /// use O(s·row) accumulation.
    fn sampled_apply_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    );

    /// Allocating wrapper over [`SymOp::sampled_apply_into`].
    fn sampled_apply(&self, f: &DenseMat, samples: &[usize], weights_sq: &[f64]) -> DenseMat {
        let mut out = DenseMat::zeros(self.dim(), f.cols());
        self.sampled_apply_into(f, samples, weights_sq, &mut out);
        out
    }
}

/// Blanket impl so `&dyn SymOp` (and any `&T`) satisfies the generic
/// `X: SymOp` bounds of the solver entry points. Every method (including
/// the defaulted allocating forms) forwards, so backend overrides like
/// `PjrtSymOp::apply` stay in effect through references.
impl<T: SymOp + ?Sized> SymOp for &T {
    fn dim(&self) -> usize {
        (**self).dim()
    }
    fn apply_into(&self, f: &DenseMat, out: &mut DenseMat) {
        (**self).apply_into(f, out)
    }
    fn apply(&self, f: &DenseMat) -> DenseMat {
        (**self).apply(f)
    }
    fn fro_norm_sq(&self) -> f64 {
        (**self).fro_norm_sq()
    }
    fn max_value(&self) -> f64 {
        (**self).max_value()
    }
    fn mean_value(&self) -> f64 {
        (**self).mean_value()
    }
    fn sampled_apply_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        (**self).sampled_apply_into(f, samples, weights_sq, out)
    }
    fn sampled_apply(&self, f: &DenseMat, samples: &[usize], weights_sq: &[f64]) -> DenseMat {
        (**self).sampled_apply(f, samples, weights_sq)
    }
}

impl SymOp for DenseMat {
    fn dim(&self) -> usize {
        debug_assert_eq!(self.rows(), self.cols());
        self.rows()
    }

    fn apply_into(&self, f: &DenseMat, out: &mut DenseMat) {
        blas::symm_tall_into(self, f, out);
    }

    fn fro_norm_sq(&self) -> f64 {
        DenseMat::fro_norm_sq(self)
    }

    fn max_value(&self) -> f64 {
        DenseMat::max_value(self)
    }

    fn mean_value(&self) -> f64 {
        self.mean()
    }

    fn sampled_apply_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        sampled_apply_dense_isa(simd::active(), self, f, samples, weights_sq, out);
    }
}

/// Serial scalar oracle for the dense sampled product X·SᵀS·F:
/// sample-major scatter with j ascending inside each sample. Retained
/// verbatim as the pinning reference for [`sampled_apply_dense_isa`].
///
/// X·SᵀS·F = Σ_r w_r · x_{:,i_r} ⊗ F[i_r,:]; with X symmetric the
/// column x_{:,i_r} is row i_r, so this is a scaled row gather — the
/// "copying large portions of a large dense data matrix" cost the paper
/// calls out in §5.1.1.
pub fn sampled_apply_dense_serial(
    x: &DenseMat,
    f: &DenseMat,
    samples: &[usize],
    weights_sq: &[f64],
    out: &mut DenseMat,
) {
    let k = f.cols();
    assert_eq!(out.shape(), (x.rows(), k), "sampled_apply_into shape");
    let od = out.data_mut();
    od.fill(0.0);
    for (&ir, &w) in samples.iter().zip(weights_sq) {
        let xrow = x.row(ir);
        let frow = f.row(ir);
        for (j, &xv) in xrow.iter().enumerate() {
            if xv != 0.0 {
                blas::axpy(w * xv, frow, &mut od[j * k..(j + 1) * k]);
            }
        }
    }
}

/// Parallel, ISA-dispatched dense sampled product — the scatter of
/// [`sampled_apply_dense_serial`] reformulated as a gather over disjoint
/// output-row chunks (module docs). Each worker owns `j ∈ [lo,hi)` and
/// walks all samples in order, reading the contiguous segment
/// `X[i_r, lo..hi]` (X symmetric ⇒ X[j,i_r] = X[i_r,j]), so the
/// per-element accumulation order matches the serial oracle exactly and
/// the result is bitwise-identical at any thread count.
pub fn sampled_apply_dense_isa(
    isa: KernelIsa,
    x: &DenseMat,
    f: &DenseMat,
    samples: &[usize],
    weights_sq: &[f64],
    out: &mut DenseMat,
) {
    let m = x.rows();
    let k = f.cols();
    assert_eq!(x.cols(), m, "sampled_apply expects square X");
    assert_eq!(out.shape(), (m, k), "sampled_apply_into shape");
    assert_eq!(samples.len(), weights_sq.len(), "samples/weights length");
    let xd = x.data();
    let fd = f.data();
    let optr = SendPtr(out.data_mut().as_mut_ptr());
    parallel_for_chunks(m, 64, move |lo, hi| {
        // SAFETY: chunks hand out disjoint [lo,hi) row ranges, so each
        // worker touches a disjoint slice of `out`.
        let od =
            unsafe { std::slice::from_raw_parts_mut(optr.0.add(lo * k), (hi - lo) * k) };
        od.fill(0.0);
        for (&ir, &w) in samples.iter().zip(weights_sq) {
            let frow = &fd[ir * k..(ir + 1) * k];
            let xseg = &xd[ir * m + lo..ir * m + hi];
            for (j, &xv) in xseg.iter().enumerate() {
                if xv != 0.0 {
                    simd::axpy(isa, w * xv, frow, &mut od[j * k..(j + 1) * k]);
                }
            }
        }
    });
}

impl SymOp for CsrMat {
    fn dim(&self) -> usize {
        debug_assert_eq!(self.rows(), self.cols());
        self.rows()
    }

    fn apply_into(&self, f: &DenseMat, out: &mut DenseMat) {
        self.spmm_into(f, out);
    }

    fn fro_norm_sq(&self) -> f64 {
        CsrMat::fro_norm_sq(self)
    }

    fn max_value(&self) -> f64 {
        CsrMat::max_value(self)
    }

    fn mean_value(&self) -> f64 {
        self.mean_dense()
    }

    fn sampled_apply_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        self.sampled_spmm_sym_into(f, samples, weights_sq, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_sym_pair(n: usize, rng: &mut Pcg64) -> (CsrMat, DenseMat) {
        let mut trips = Vec::new();
        for i in 0..n {
            for j in i..n {
                if rng.uniform() < 0.3 {
                    let v = rng.uniform();
                    trips.push((i, j, v));
                    if i != j {
                        trips.push((j, i, v));
                    }
                }
            }
        }
        let sp = CsrMat::from_coo(n, n, trips);
        let de = sp.to_dense();
        (sp, de)
    }

    #[test]
    fn dense_and_sparse_agree() {
        let mut rng = Pcg64::seed_from_u64(1);
        let (sp, de) = random_sym_pair(24, &mut rng);
        let f = DenseMat::gaussian(24, 5, &mut rng);
        assert!(SymOp::apply(&de, &f).diff_fro(&sp.apply(&f)) < 1e-12);
        assert!((SymOp::fro_norm_sq(&de) - SymOp::fro_norm_sq(&sp)).abs() < 1e-12);

        let samples = vec![0, 3, 3, 7];
        let w = vec![0.5, 1.0, 2.0, 0.25];
        let a = SymOp::sampled_apply(&de, &f, &samples, &w);
        let b = sp.sampled_apply(&f, &samples, &w);
        assert!(a.diff_fro(&b) < 1e-12);
    }

    #[test]
    fn into_forms_overwrite_stale_output() {
        // apply_into / sampled_apply_into must fully overwrite `out`,
        // including entries a previous iteration left behind.
        let mut rng = Pcg64::seed_from_u64(2);
        let (sp, de) = random_sym_pair(18, &mut rng);
        let f = DenseMat::gaussian(18, 4, &mut rng);
        let samples = vec![1, 4, 4, 9];
        let w = vec![0.7, 1.3, 0.2, 2.0];
        let mut out = DenseMat::zeros(18, 4);
        out.fill(77.0);
        SymOp::apply_into(&de, &f, &mut out);
        assert!(out.diff_fro(&sp.apply(&f)) < 1e-12);
        out.fill(-5.0);
        SymOp::sampled_apply_into(&sp, &f, &samples, &w, &mut out);
        assert!(out.diff_fro(&SymOp::sampled_apply(&de, &f, &samples, &w)) < 1e-12);
    }
}
