//! Cross-request operator cache: many graphs, one resident-bytes budget.
//!
//! A serve process that rebuilds its `SymPacked`/`CsrMat` operators on
//! every request is a batch runner; a resident service holds them across
//! requests. [`OpCache`] is that layer: built operators keyed by
//! **content hash** ([`OpKey`]: dims + block + FNV-1a 64 over the
//! payload bytes — `linalg::spill::Fnv64`, zero-dep), refcounted
//! **pins** so a job mid-slice can never lose its operator, and **LRU
//! eviction by resident payload bytes** under a configurable ceiling.
//!
//! ## Eviction policy
//!
//! * The budget comes from [`OpCacheConfig`] (`--x-budget-mb` on the
//!   serve CLI, or the `SYMNMF_X_BUDGET_MB` env var; MiB). No budget =
//!   never evict.
//! * Accounting covers operator **payload** bytes (packed tiles, CSR
//!   arrays). A spilled operator's payload lives on disk and counts as
//!   zero; its bounded read-ring scratch (≤ threads · block² · 16 B,
//!   lazily grown) is documented scratch, like the SYMM accumulator
//!   pool.
//! * When an insert or an unpin leaves the cache over budget, the
//!   least-recently-touched entry that is Ready, unpinned, and still
//!   resident is evicted, repeatedly, until under budget or nothing is
//!   evictable. Pinned entries are **never** evicted — concurrent pins
//!   can push residency over the ceiling transiently; the next unpin
//!   restores it.
//! * Eviction is tiered by operator kind: `Packed` **spills** — the
//!   payload is written once to a content-addressed file
//!   (`<spill_dir>/<dim>-<block>-<hash>.sympk`, temp + rename, see
//!   `linalg::spill`) and the entry swaps to a [`SymPackedSpilled`]
//!   that streams panels back on demand, so a re-pin faults tiles
//!   instead of rebuilding (and a pre-existing valid spill file is
//!   reused without rewriting). `Csr` entries are **dropped** and
//!   rebuilt through the caller's builder on the next pin (CSR payloads
//!   are cheap to rebuild relative to packing). A spilled entry never
//!   promotes back to resident (follow-on; see ROADMAP).
//! * If a spill write fails (disk full), the entry is kept resident,
//!   marked unspillable, and skipped by future victim scans — the cache
//!   degrades to over-budget rather than losing an operator.
//!
//! Hit/miss/eviction counters ([`CacheStats`]) surface in the serve
//! JSON report; the serve-smoke CI leg asserts a cache hit skips
//! operator construction entirely.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};

use crate::linalg::spill::{write_spill, Fnv64};
use crate::linalg::{DenseMat, SymPacked, SymPackedSpilled};
use crate::randnla::SymOp;
use crate::sparse::CsrMat;

/// Where the cache spills and how much operator payload may stay
/// resident.
#[derive(Clone, Debug)]
pub struct OpCacheConfig {
    /// Resident payload ceiling in bytes; `None` disables eviction.
    pub budget_bytes: Option<u64>,
    /// Directory for spill files (created on first spill).
    pub spill_dir: PathBuf,
}

impl OpCacheConfig {
    /// Unbudgeted cache spilling under `spill_dir`.
    pub fn new(spill_dir: PathBuf) -> OpCacheConfig {
        OpCacheConfig { budget_bytes: None, spill_dir }
    }

    /// Set the ceiling in MiB (the unit of `--x-budget-mb` /
    /// `SYMNMF_X_BUDGET_MB`).
    pub fn with_budget_mb(mut self, mb: f64) -> OpCacheConfig {
        self.budget_bytes = Some((mb * 1024.0 * 1024.0) as u64);
        self
    }

    /// Apply `SYMNMF_X_BUDGET_MB` from the environment if set (and
    /// parseable); explicit configuration wins over the env var.
    pub fn budget_from_env(mut self) -> OpCacheConfig {
        if self.budget_bytes.is_none() {
            if let Ok(s) = std::env::var("SYMNMF_X_BUDGET_MB") {
                if let Ok(mb) = s.trim().parse::<f64>() {
                    return self.with_budget_mb(mb);
                }
            }
        }
        self
    }
}

/// Content identity of a built operator: dimensions, panel block size
/// (0 for CSR storage), and an FNV-1a 64 hash over the payload bytes.
/// Two sources that build byte-identical operators share one cache
/// entry — and one spill file, whose name embeds this key.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct OpKey {
    pub dim: usize,
    pub block: usize,
    pub content: u64,
}

impl OpKey {
    /// Key of a packed operator: dims, block, and the packed payload.
    pub fn of_packed(sp: &SymPacked) -> OpKey {
        let mut h = Fnv64::new();
        h.write_u64(sp.dim() as u64);
        h.write_u64(sp.block() as u64);
        for &v in sp.payload() {
            h.write_f64(v);
        }
        OpKey { dim: sp.dim(), block: sp.block(), content: h.finish() }
    }

    /// Key of a CSR operator: shape, nnz, and every (col, value) pair in
    /// row-major order. `block = 0` marks CSR storage, so the same graph
    /// cached as CSR and as packed are distinct entries.
    pub fn of_csr(x: &CsrMat) -> OpKey {
        let mut h = Fnv64::new();
        h.write_u64(x.rows() as u64);
        h.write_u64(x.cols() as u64);
        h.write_u64(x.nnz() as u64);
        for i in 0..x.rows() {
            let (cols, vals) = x.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                h.write_u64(j as u64);
                h.write_f64(v);
            }
        }
        OpKey { dim: x.rows(), block: 0, content: h.finish() }
    }

    /// Spill file name for this key (content-addressed).
    fn spill_name(&self) -> String {
        format!("{}-{}-{:016x}.sympk", self.dim, self.block, self.content)
    }
}

/// A cache-resident operator in one of its tiers. Implements [`SymOp`],
/// so a job runs against it unchanged whichever tier it is in when the
/// slice pins it.
#[derive(Debug)]
pub enum CachedOperator {
    /// Resident packed-triangular storage.
    Packed(SymPacked),
    /// Resident sparse storage.
    Csr(CsrMat),
    /// Payload on disk; panels fault back through the read ring.
    Spilled(SymPackedSpilled),
}

impl CachedOperator {
    /// The content key of a resident operator (what the CLI and drivers
    /// register it under). Spilled operators are created internally by
    /// eviction and already have a key.
    pub fn key(&self) -> OpKey {
        match self {
            CachedOperator::Packed(sp) => OpKey::of_packed(sp),
            CachedOperator::Csr(x) => OpKey::of_csr(x),
            CachedOperator::Spilled(s) => panic!(
                "CachedOperator::key on spilled operator {} (keys are computed at insert, before spilling)",
                s.path().display()
            ),
        }
    }

    /// Payload bytes counted against the resident budget.
    pub fn resident_payload_bytes(&self) -> u64 {
        match self {
            CachedOperator::Packed(sp) => 8 * sp.packed_len() as u64,
            CachedOperator::Csr(x) => (16 * x.nnz() + 8 * (x.rows() + 1)) as u64,
            CachedOperator::Spilled(_) => 0,
        }
    }

    /// Is this the out-of-core tier?
    pub fn is_spilled(&self) -> bool {
        matches!(self, CachedOperator::Spilled(_))
    }
}

impl SymOp for CachedOperator {
    fn dim(&self) -> usize {
        match self {
            CachedOperator::Packed(sp) => SymOp::dim(sp),
            CachedOperator::Csr(x) => SymOp::dim(x),
            CachedOperator::Spilled(s) => SymOp::dim(s),
        }
    }

    fn apply_into(&self, f: &DenseMat, out: &mut DenseMat) {
        match self {
            CachedOperator::Packed(sp) => sp.apply_into(f, out),
            CachedOperator::Csr(x) => x.apply_into(f, out),
            CachedOperator::Spilled(s) => s.apply_into(f, out),
        }
    }

    fn fro_norm_sq(&self) -> f64 {
        match self {
            CachedOperator::Packed(sp) => sp.fro_norm_sq(),
            CachedOperator::Csr(x) => SymOp::fro_norm_sq(x),
            CachedOperator::Spilled(s) => SymOp::fro_norm_sq(s),
        }
    }

    fn max_value(&self) -> f64 {
        match self {
            CachedOperator::Packed(sp) => SymOp::max_value(sp),
            CachedOperator::Csr(x) => SymOp::max_value(x),
            CachedOperator::Spilled(s) => SymOp::max_value(s),
        }
    }

    fn mean_value(&self) -> f64 {
        match self {
            CachedOperator::Packed(sp) => SymOp::mean_value(sp),
            CachedOperator::Csr(x) => SymOp::mean_value(x),
            CachedOperator::Spilled(s) => SymOp::mean_value(s),
        }
    }

    fn sampled_apply_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        match self {
            CachedOperator::Packed(sp) => sp.sampled_apply_into(f, samples, weights_sq, out),
            CachedOperator::Csr(x) => x.sampled_apply_into(f, samples, weights_sq, out),
            CachedOperator::Spilled(s) => s.sampled_apply_into(f, samples, weights_sq, out),
        }
    }
}

/// How a pin was satisfied — surfaced so callers can account slices
/// served from the out-of-core tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PinKind {
    /// Resident hit: the operator was in memory.
    Hit,
    /// Out-of-core hit: the operator streams from its spill file.
    SpilledHit,
    /// Miss: the builder ran (first insert, or rebuild of a dropped
    /// CSR entry).
    Miss,
}

/// A refcounted pin on a cache entry: while any pin is live the entry
/// cannot be evicted. Dropping the pin unpins and re-enforces the
/// budget — the scheduler pins per slice, so eviction happens **between**
/// a job's slices, never under one.
pub struct OpPin<'c> {
    cache: &'c OpCache,
    idx: usize,
    op: Arc<CachedOperator>,
    kind: PinKind,
}

impl OpPin<'_> {
    /// The pinned operator (resident or spilled — both serve `SymOp`).
    pub fn op(&self) -> &CachedOperator {
        &self.op
    }

    /// How this pin was satisfied.
    pub fn kind(&self) -> PinKind {
        self.kind
    }

    /// Is the pinned operator serving from its spill file?
    pub fn is_spilled(&self) -> bool {
        self.op.is_spilled()
    }
}

impl Drop for OpPin<'_> {
    fn drop(&mut self) {
        self.cache.unpin(self.idx);
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EntryState {
    /// Pinnable (op may still be `None` if a dropped entry awaits
    /// rebuild).
    Ready,
    /// A thread is building or spilling this entry; pinners wait.
    Busy,
}

struct Entry {
    key: OpKey,
    op: Option<Arc<CachedOperator>>,
    state: EntryState,
    pins: usize,
    touch: u64,
    /// A spill attempt failed (e.g. disk full): keep resident, skip in
    /// victim scans.
    spill_failed: bool,
}

struct Inner {
    entries: Vec<Entry>,
    index: BTreeMap<OpKey, usize>,
    clock: u64,
    resident: u64,
    hits: u64,
    spilled_hits: u64,
    misses: u64,
    evictions: u64,
    spill_writes: u64,
}

/// Counter snapshot for reports and assertions.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Pins served by a resident operator.
    pub hits: u64,
    /// Pins served by a spilled operator (no construction, panels
    /// stream from disk).
    pub spilled_hits: u64,
    /// Pins that ran the builder.
    pub misses: u64,
    /// Entries moved out of the resident tier (spilled or dropped).
    pub evictions: u64,
    /// Spill files written (a reused pre-existing file does not count).
    pub spill_writes: u64,
    /// Current resident payload bytes.
    pub resident_bytes: u64,
    /// Entries ever inserted (all tiers).
    pub entries: usize,
    /// The configured ceiling, if any.
    pub budget_bytes: Option<u64>,
}

/// The cross-request operator cache. Shared across scheduler workers as
/// `Arc<OpCache>`; all state sits behind one mutex (operators are
/// built and spilled **outside** the lock, with a Busy state + condvar
/// so concurrent pinners of the same key neither double-build nor
/// observe a half-evicted entry).
pub struct OpCache {
    cfg: OpCacheConfig,
    inner: Mutex<Inner>,
    cond: Condvar,
}

impl OpCache {
    pub fn new(cfg: OpCacheConfig) -> OpCache {
        OpCache {
            cfg,
            inner: Mutex::new(Inner {
                entries: Vec::new(),
                index: BTreeMap::new(),
                clock: 0,
                resident: 0,
                hits: 0,
                spilled_hits: 0,
                misses: 0,
                evictions: 0,
                spill_writes: 0,
            }),
            cond: Condvar::new(),
        }
    }

    /// The configured budget, if any.
    pub fn budget_bytes(&self) -> Option<u64> {
        self.cfg.budget_bytes
    }

    /// Pin the operator under `key`, running `build` only if the entry
    /// is absent or was dropped ([`PinKind::Miss`]). The build runs
    /// without the cache lock; concurrent pinners of the same key wait
    /// for it instead of building twice. The returned pin keeps the
    /// entry unevictable until dropped.
    pub fn pin_or_build<F>(&self, key: &OpKey, build: F) -> OpPin<'_>
    where
        F: FnOnce() -> CachedOperator,
    {
        let idx = {
            let mut g = self.inner.lock().unwrap();
            loop {
                match g.index.get(key).copied() {
                    Some(i) => {
                        if g.entries[i].state == EntryState::Busy {
                            g = self.cond.wait(g).unwrap();
                            continue;
                        }
                        if let Some(op) = g.entries[i].op.clone() {
                            let spilled = op.is_spilled();
                            if spilled {
                                g.spilled_hits += 1;
                            } else {
                                g.hits += 1;
                            }
                            g.clock += 1;
                            let clock = g.clock;
                            let e = &mut g.entries[i];
                            e.pins += 1;
                            e.touch = clock;
                            let kind =
                                if spilled { PinKind::SpilledHit } else { PinKind::Hit };
                            return OpPin { cache: self, idx: i, op, kind };
                        }
                        // dropped entry: this thread rebuilds it
                        g.entries[i].state = EntryState::Busy;
                        break i;
                    }
                    None => {
                        let i = g.entries.len();
                        g.entries.push(Entry {
                            key: key.clone(),
                            op: None,
                            state: EntryState::Busy,
                            pins: 0,
                            touch: 0,
                            spill_failed: false,
                        });
                        g.index.insert(key.clone(), i);
                        break i;
                    }
                }
            }
        };
        // Build outside the lock; if the builder panics, release the
        // Busy state so waiters retry (and become the builder).
        let guard = BusyGuard { cache: self, idx, armed: true };
        // deterministic builder-crash injection; this site has no error
        // path, so `err` escalates to a panic — the BusyGuard releases
        // Busy during the unwind, exactly like a real builder panic
        if let Err(e) = crate::util::failpoint::hit("opcache_build") {
            panic!("{e}");
        }
        let op = Arc::new(build());
        let bytes = op.resident_payload_bytes();
        std::mem::forget(guard);
        {
            let mut g = self.inner.lock().unwrap();
            g.misses += 1;
            g.resident += bytes;
            g.clock += 1;
            let clock = g.clock;
            let e = &mut g.entries[idx];
            e.op = Some(Arc::clone(&op));
            e.state = EntryState::Ready;
            e.pins += 1;
            e.touch = clock;
        }
        self.cond.notify_all();
        self.enforce_budget();
        OpPin { cache: self, idx, op, kind: PinKind::Miss }
    }

    fn unpin(&self, idx: usize) {
        {
            let mut g = self.inner.lock().unwrap();
            let e = &mut g.entries[idx];
            debug_assert!(e.pins > 0, "opcache: unpin without pin");
            e.pins -= 1;
        }
        self.enforce_budget();
    }

    /// Evict least-recently-touched unpinned resident entries until the
    /// resident payload fits the budget (or nothing more is evictable).
    /// Spill I/O runs outside the lock under the victim's Busy state.
    fn enforce_budget(&self) {
        let Some(budget) = self.cfg.budget_bytes else { return };
        loop {
            // Victim selection under the lock.
            let (idx, key, op) = {
                let mut g = self.inner.lock().unwrap();
                if g.resident <= budget {
                    return;
                }
                let mut victim: Option<(u64, usize)> = None;
                for (i, e) in g.entries.iter().enumerate() {
                    let evictable = e.pins == 0
                        && e.state == EntryState::Ready
                        && !e.spill_failed
                        && e.op.as_ref().is_some_and(|op| !op.is_spilled());
                    if evictable && victim.is_none_or(|(t, _)| e.touch < t) {
                        victim = Some((e.touch, i));
                    }
                }
                let Some((_, i)) = victim else { return }; // all pinned/spilled
                g.entries[i].state = EntryState::Busy;
                (i, g.entries[i].key.clone(), g.entries[i].op.clone().unwrap())
            };
            let bytes = op.resident_payload_bytes();
            match &*op {
                CachedOperator::Packed(sp) => {
                    let path = self.cfg.spill_dir.join(key.spill_name());
                    // Content-addressed: a pre-existing valid file (an
                    // earlier eviction, or a previous process) is reused
                    // without rewriting.
                    let (opened, wrote) = match SymPackedSpilled::open(&path) {
                        Ok(s) => (Ok(s), false),
                        Err(_) => (
                            write_spill(sp, &path).and_then(|()| SymPackedSpilled::open(&path)),
                            true,
                        ),
                    };
                    let mut g = self.inner.lock().unwrap();
                    match opened {
                        Ok(spilled) => {
                            g.resident -= bytes;
                            g.evictions += 1;
                            if wrote {
                                g.spill_writes += 1;
                            }
                            let e = &mut g.entries[idx];
                            e.op = Some(Arc::new(CachedOperator::Spilled(spilled)));
                            e.state = EntryState::Ready;
                        }
                        Err(err) => {
                            eprintln!(
                                "opcache: spill of {} failed ({err}); keeping resident",
                                key.spill_name()
                            );
                            let e = &mut g.entries[idx];
                            e.spill_failed = true;
                            e.state = EntryState::Ready;
                        }
                    }
                }
                CachedOperator::Csr(_) => {
                    let mut g = self.inner.lock().unwrap();
                    g.resident -= bytes;
                    g.evictions += 1;
                    let e = &mut g.entries[idx];
                    e.op = None;
                    e.state = EntryState::Ready;
                }
                CachedOperator::Spilled(_) => unreachable!("spilled entries are not victims"),
            }
            self.cond.notify_all();
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            hits: g.hits,
            spilled_hits: g.spilled_hits,
            misses: g.misses,
            evictions: g.evictions,
            spill_writes: g.spill_writes,
            resident_bytes: g.resident,
            entries: g.entries.len(),
            budget_bytes: self.cfg.budget_bytes,
        }
    }
}

/// Releases a Busy entry if the builder panics (drop during unwind);
/// forgotten on the success path.
struct BusyGuard<'c> {
    cache: &'c OpCache,
    idx: usize,
    armed: bool,
}

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            let mut g = self.cache.inner.lock().unwrap();
            let e = &mut g.entries[self.idx];
            e.op = None;
            e.state = EntryState::Ready;
            drop(g);
            self.cache.cond.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use std::sync::atomic::{AtomicUsize, Ordering};

    struct TempDir(PathBuf);

    impl TempDir {
        fn new(tag: &str) -> TempDir {
            let d = std::env::temp_dir()
                .join(format!("symnmf-opcache-test-{tag}-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&d);
            std::fs::create_dir_all(&d).unwrap();
            TempDir(d)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    fn packed_fixture(seed: u64, m: usize) -> SymPacked {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut x = DenseMat::gaussian(m, m, &mut rng);
        x.symmetrize();
        SymPacked::from_dense_with_block(&x, 8)
    }

    fn csr_fixture(seed: u64, m: usize) -> CsrMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut trips = Vec::new();
        for i in 0..m {
            trips.push((i, i, 2.0));
            for _ in 0..3 {
                let j = rng.below(m);
                let v = 1.0 + rng.uniform();
                trips.push((i, j, v));
                if i != j {
                    trips.push((j, i, v));
                }
            }
        }
        CsrMat::from_coo(m, m, trips)
    }

    /// A second pin of the same content never runs the builder — the
    /// acceptance criterion "a cache hit skips operator construction
    /// entirely", counter-asserted.
    #[test]
    fn hit_skips_construction_entirely() {
        let dir = TempDir::new("hit");
        let cache = OpCache::new(OpCacheConfig::new(dir.0.clone()));
        let builds = AtomicUsize::new(0);
        let sp = packed_fixture(1, 16);
        let key = OpKey::of_packed(&sp);
        let build = || {
            builds.fetch_add(1, Ordering::SeqCst);
            CachedOperator::Packed(packed_fixture(1, 16))
        };
        {
            let pin = cache.pin_or_build(&key, build);
            assert_eq!(pin.kind(), PinKind::Miss);
        }
        {
            let pin = cache.pin_or_build(&key, build);
            assert_eq!(pin.kind(), PinKind::Hit);
            assert!(!pin.is_spilled());
        }
        assert_eq!(builds.load(Ordering::SeqCst), 1, "hit must not rebuild");
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.evictions), (1, 1, 0));
        assert_eq!(st.resident_bytes, 8 * sp.packed_len() as u64);
    }

    /// LRU by touch order: with room for two packed operators, touching
    /// A before inserting C evicts B (the least recently used), which
    /// spills and then serves as a spilled hit — bitwise-equal to the
    /// resident apply.
    #[test]
    fn lru_evicts_least_recently_touched_to_spill() {
        let dir = TempDir::new("lru");
        let m = 32;
        let one = 8 * packed_fixture(0, m).packed_len() as u64;
        let cache = OpCache::new(OpCacheConfig {
            budget_bytes: Some(2 * one + one / 2),
            spill_dir: dir.0.clone(),
        });
        let mk = |seed: u64| CachedOperator::Packed(packed_fixture(seed, m));
        let keys: Vec<OpKey> =
            (0..3).map(|s| OpKey::of_packed(&packed_fixture(s, m))).collect();
        drop(cache.pin_or_build(&keys[0], || mk(0))); // A
        drop(cache.pin_or_build(&keys[1], || mk(1))); // B
        drop(cache.pin_or_build(&keys[0], || mk(0))); // touch A
        drop(cache.pin_or_build(&keys[2], || mk(2))); // C → evicts B
        let st = cache.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.spill_writes, 1);
        assert!(st.resident_bytes <= st.budget_bytes.unwrap());
        // A stayed resident; B comes back as a spilled hit
        {
            let pin = cache.pin_or_build(&keys[0], || mk(0));
            assert_eq!(pin.kind(), PinKind::Hit);
        }
        let mut rng = Pcg64::seed_from_u64(9);
        let f = DenseMat::gaussian(m, 4, &mut rng);
        let want = {
            let resident = packed_fixture(1, m);
            let mut out = DenseMat::zeros(m, 4);
            resident.apply_blocked_into(&f, &mut out);
            out
        };
        {
            let pin = cache.pin_or_build(&keys[1], || mk(1));
            assert_eq!(pin.kind(), PinKind::SpilledHit);
            let mut got = DenseMat::zeros(m, 4);
            pin.op().apply_into(&f, &mut got);
            for (a, b) in want.data().iter().zip(got.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "spilled apply must match resident");
            }
        }
        assert_eq!(cache.stats().misses, 3, "no eviction ever reran a builder");
    }

    /// A pinned entry is never evicted, even when it alone exceeds the
    /// budget; dropping the pin evicts it.
    #[test]
    fn pinned_entries_survive_budget_pressure() {
        let dir = TempDir::new("pin");
        let cache = OpCache::new(OpCacheConfig {
            budget_bytes: Some(1), // nothing fits
            spill_dir: dir.0.clone(),
        });
        let sp = packed_fixture(5, 24);
        let key = OpKey::of_packed(&sp);
        let pin = cache.pin_or_build(&key, || CachedOperator::Packed(packed_fixture(5, 24)));
        let st = cache.stats();
        assert_eq!(st.evictions, 0, "pinned entry must not be evicted");
        assert!(st.resident_bytes > st.budget_bytes.unwrap(), "transiently over budget");
        assert!(!pin.is_spilled());
        drop(pin);
        let st = cache.stats();
        assert_eq!(st.evictions, 1, "unpin must re-enforce the budget");
        assert_eq!(st.resident_bytes, 0, "spilled payload counts zero");
        // pinning again streams from the spill file
        let pin = cache.pin_or_build(&key, || panic!("must not rebuild"));
        assert_eq!(pin.kind(), PinKind::SpilledHit);
    }

    /// CSR entries evict by dropping and rebuild through the caller's
    /// builder on the next pin.
    #[test]
    fn csr_eviction_drops_and_rebuilds() {
        let dir = TempDir::new("csr");
        let cache = OpCache::new(OpCacheConfig {
            budget_bytes: Some(1),
            spill_dir: dir.0.clone(),
        });
        let builds = AtomicUsize::new(0);
        let x = csr_fixture(7, 20);
        let key = OpKey::of_csr(&x);
        let build = || {
            builds.fetch_add(1, Ordering::SeqCst);
            CachedOperator::Csr(csr_fixture(7, 20))
        };
        drop(cache.pin_or_build(&key, build)); // built, then dropped on unpin
        let st = cache.stats();
        assert_eq!((st.evictions, st.spill_writes), (1, 0), "csr drops, never spills");
        assert_eq!(st.resident_bytes, 0);
        let pin = cache.pin_or_build(&key, build);
        assert_eq!(pin.kind(), PinKind::Miss, "dropped entry rebuilds");
        assert_eq!(builds.load(Ordering::SeqCst), 2);
    }

    /// The `opcache_build` fail point escalates to a panic in the
    /// builder slot; the `BusyGuard` releases the Busy entry during the
    /// unwind, so a later pin of the same key rebuilds cleanly instead
    /// of deadlocking on a Busy entry whose builder is gone.
    #[test]
    fn builder_failpoint_panic_releases_the_busy_entry() {
        let dir = TempDir::new("fp");
        let cache = OpCache::new(OpCacheConfig::new(dir.0.clone()));
        let sp = packed_fixture(3, 16);
        let key = OpKey::of_packed(&sp);
        let _fp = crate::util::failpoint::scoped("opcache_build=panic_once");
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cache.pin_or_build(&key, || CachedOperator::Packed(packed_fixture(3, 16)))
        }))
        .expect_err("armed fail point must panic before the builder runs");
        let msg = p.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("opcache_build"), "{msg}");
        // the one-shot injection is spent: the retry pin builds
        let pin = cache.pin_or_build(&key, || CachedOperator::Packed(packed_fixture(3, 16)));
        assert_eq!(pin.kind(), PinKind::Miss, "released Busy entry rebuilds");
        assert!(!pin.is_spilled());
    }

    /// No budget → nothing is ever evicted.
    #[test]
    fn unbudgeted_cache_never_evicts() {
        let dir = TempDir::new("nobudget");
        let cache = OpCache::new(OpCacheConfig::new(dir.0.clone()));
        for seed in 0..4 {
            let sp = packed_fixture(seed, 24);
            let key = OpKey::of_packed(&sp);
            drop(cache.pin_or_build(&key, move || CachedOperator::Packed(sp)));
        }
        let st = cache.stats();
        assert_eq!((st.misses, st.evictions), (4, 0));
        assert_eq!(st.entries, 4);
    }
}
