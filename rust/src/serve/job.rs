//! Job descriptions and handles for the serving layer.
//!
//! A [`JobSpec`] is the unit of work a client submits: *which* solve to
//! run (operator reference + [`Method`] + [`SymNmfOptions`]) and *under
//! what service terms* (priority, total algorithm-clock deadline, step
//! budget, checkpoint slimming, trace streaming). Submission returns a
//! [`JobHandle`] — the client-side face of the job — whose API is
//! deliberately tiny: `poll` (non-blocking status), `cancel` (trip the
//! job's [`CancelToken`]; the engine aborts at the next step boundary),
//! and `await_result` (block until the job reaches a terminal status and
//! return its [`JobOutcome`]). Handles are cheap `Arc` clones and safe to
//! use from any thread, including while the scheduler is draining.

use crate::coordinator::driver::Method;
use crate::symnmf::engine::{CancelToken, Checkpoint, RunStatus};
use crate::symnmf::metrics::SymNmfResult;
use crate::symnmf::options::SymNmfOptions;
use crate::symnmf::trace::TraceFormat;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

/// Lock a job-state mutex, recovering the data if a panicking thread
/// poisoned it. Job state is plain bookkeeping mutated under short
/// critical sections; the panic that poisoned the lock was isolated by
/// the scheduler's `catch_unwind`, so the state is consistent and the
/// conservative poison default (propagate the panic to every reader)
/// would needlessly take down healthy jobs' handles.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Everything a client supplies to run one solve as a serve job.
#[derive(Clone)]
pub struct JobSpec {
    /// Store key and report label; must be unique within a scheduler
    /// when a [`crate::serve::JobStore`] is configured (checkpoint files
    /// are keyed by it).
    pub name: String,
    pub method: Method,
    pub opts: SymNmfOptions,
    /// Higher runs first; ties broken by earliest deadline, then FIFO.
    pub priority: i64,
    /// Total budget on the *algorithm clock* (setup + iteration seconds,
    /// accumulated across slices and resubmissions via the checkpoint's
    /// `clock`). Reaching it suspends the job with its checkpoint.
    pub deadline_secs: Option<f64>,
    /// Total engine-step budget for this submission (counted across
    /// slices). Reaching it suspends the job with its checkpoint.
    pub max_steps: Option<usize>,
    /// Ops/test hook: trip the job's cancel token once the global
    /// iteration count reaches this value (deterministic mid-flight
    /// cancellation — see [`crate::symnmf::trace::CancelAfterSink`]).
    /// One-shot: disarmed after it fires, so the job can be resumed.
    pub cancel_after_iters: Option<usize>,
    /// Share an external token (e.g. one token cancelling a whole
    /// fleet). A fresh private token is created when `None`.
    pub cancel: Option<CancelToken>,
    /// Resume from a prior checkpoint (full or factor-only slim).
    pub resume: Option<Checkpoint>,
    /// Stream per-iteration telemetry to this file, flushed per record.
    pub trace: Option<(PathBuf, TraceFormat)>,
}

impl JobSpec {
    pub fn new(name: impl Into<String>, method: Method, opts: SymNmfOptions) -> JobSpec {
        JobSpec {
            name: name.into(),
            method,
            opts,
            priority: 0,
            deadline_secs: None,
            max_steps: None,
            cancel_after_iters: None,
            cancel: None,
            resume: None,
            trace: None,
        }
    }

    pub fn with_priority(mut self, p: i64) -> JobSpec {
        self.priority = p;
        self
    }

    pub fn with_deadline(mut self, secs: f64) -> JobSpec {
        self.deadline_secs = Some(secs);
        self
    }

    pub fn with_max_steps(mut self, n: usize) -> JobSpec {
        self.max_steps = Some(n);
        self
    }

    pub fn with_cancel_after(mut self, iters: usize) -> JobSpec {
        self.cancel_after_iters = Some(iters);
        self
    }

    pub fn with_cancel_token(mut self, token: CancelToken) -> JobSpec {
        self.cancel = Some(token);
        self
    }

    pub fn with_resume(mut self, cp: Checkpoint) -> JobSpec {
        self.resume = Some(cp);
        self
    }

    pub fn with_trace(mut self, path: PathBuf, format: TraceFormat) -> JobSpec {
        self.trace = Some((path, format));
        self
    }
}

/// Scheduler-side lifecycle of a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// waiting in the ready queue for a worker
    Queued,
    /// a worker is driving a slice right now
    Running,
    /// the job's own budget (deadline or step quota) is exhausted;
    /// resumable from its checkpoint
    Suspended,
    /// every stage ran to its stopping rule
    Completed,
    /// the cancel token fired; resumable from its checkpoint
    Cancelled,
    /// a slice panicked; the panic message is in
    /// [`JobOutcome::failure`], and the job is resumable from its last
    /// good checkpoint (or cold, if no slice ever finished)
    Failed,
}

impl JobStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Suspended => "suspended",
            JobStatus::Completed => "completed",
            JobStatus::Cancelled => "cancelled",
            JobStatus::Failed => "failed",
        }
    }

    /// Terminal for a drain: the scheduler will not run the job again
    /// unless it is explicitly resumed.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobStatus::Suspended
                | JobStatus::Completed
                | JobStatus::Cancelled
                | JobStatus::Failed
        )
    }
}

/// What a finished (terminal) job hands back: the possibly-partial solver
/// result, the checkpoint to resume it, and slice accounting.
///
/// `run_status`, `result`, and `checkpoint` are `None` only for a
/// [`JobStatus::Failed`] job whose very first slice panicked — any
/// completed slice leaves all three behind.
#[derive(Clone)]
pub struct JobOutcome {
    pub status: JobStatus,
    /// how the *last completed slice* ended
    pub run_status: Option<RunStatus>,
    pub result: Option<SymNmfResult>,
    pub checkpoint: Option<Checkpoint>,
    /// engine slices driven (across cancel/resume), panicked ones
    /// included
    pub slices: usize,
    /// slices whose operator pin was served by the out-of-core tier
    /// (the `SymPacked` payload streamed from its spill file); always 0
    /// for jobs submitted against a borrowed operator
    pub spilled_slices: usize,
    /// engine steps run under this scheduler (excludes a resume
    /// checkpoint's prior iterations)
    pub steps: usize,
    /// the panic message, for a [`JobStatus::Failed`] job
    pub failure: Option<String>,
    /// some checkpoint generation could not be persisted even after the
    /// bounded retry: the solve finished in memory, but the store may
    /// lag the state reported here (sticky once set)
    pub persist_degraded: bool,
}

impl JobOutcome {
    /// The solver result; panics (with the job's own failure message,
    /// if any) when no slice ever finished. Convenience for callers
    /// that already checked `status` — tests, drivers.
    pub fn expect_result(&self) -> &SymNmfResult {
        self.result.as_ref().unwrap_or_else(|| match &self.failure {
            Some(f) => panic!("job failed before any slice finished: {f}"),
            None => panic!("job has no result"),
        })
    }

    /// The resume checkpoint; panics when no slice ever finished.
    pub fn expect_checkpoint(&self) -> &Checkpoint {
        self.checkpoint.as_ref().unwrap_or_else(|| match &self.failure {
            Some(f) => panic!("job failed before any slice finished: {f}"),
            None => panic!("job has no checkpoint"),
        })
    }
}

/// Mutable per-job state, behind the job's mutex.
pub(crate) struct JobCore {
    pub(crate) status: JobStatus,
    pub(crate) checkpoint: Option<Checkpoint>,
    pub(crate) result: Option<SymNmfResult>,
    pub(crate) run_status: Option<RunStatus>,
    pub(crate) slices: usize,
    pub(crate) spilled_slices: usize,
    pub(crate) steps_used: usize,
    /// latest persisted store generation (0 = none yet)
    pub(crate) gen: u64,
    /// the one-shot cancel-after hook; `None` once fired
    pub(crate) cancel_hook: Option<usize>,
    /// panic message of the slice that failed the job
    pub(crate) failure: Option<String>,
    /// a checkpoint save exhausted its retry budget (sticky)
    pub(crate) persist_degraded: bool,
}

/// Shared job object: immutable service terms + the mutex-guarded core.
pub(crate) struct JobInner {
    pub(crate) id: usize,
    pub(crate) name: String,
    pub(crate) priority: i64,
    pub(crate) deadline_secs: Option<f64>,
    pub(crate) max_steps: Option<usize>,
    pub(crate) cancel: CancelToken,
    pub(crate) core: Mutex<JobCore>,
    pub(crate) done: Condvar,
}

impl JobInner {
    pub(crate) fn new(id: usize, spec: &JobSpec) -> JobInner {
        JobInner {
            id,
            name: spec.name.clone(),
            priority: spec.priority,
            deadline_secs: spec.deadline_secs,
            max_steps: spec.max_steps,
            cancel: spec.cancel.clone().unwrap_or_default(),
            core: Mutex::new(JobCore {
                status: JobStatus::Queued,
                checkpoint: spec.resume.clone(),
                result: None,
                run_status: None,
                slices: 0,
                spilled_slices: 0,
                steps_used: 0,
                gen: 0,
                cancel_hook: spec.cancel_after_iters,
                failure: None,
                persist_degraded: false,
            }),
            done: Condvar::new(),
        }
    }

    fn outcome_locked(core: &JobCore) -> Option<JobOutcome> {
        if !core.status.is_terminal() {
            return None;
        }
        Some(JobOutcome {
            status: core.status,
            run_status: core.run_status,
            result: core.result.clone(),
            checkpoint: core.checkpoint.clone(),
            slices: core.slices,
            spilled_slices: core.spilled_slices,
            steps: core.steps_used,
            failure: core.failure.clone(),
            persist_degraded: core.persist_degraded,
        })
    }
}

/// Client-side face of a submitted job. Cheap to clone; usable from any
/// thread.
#[derive(Clone)]
pub struct JobHandle {
    pub(crate) inner: Arc<JobInner>,
}

impl JobHandle {
    pub fn id(&self) -> usize {
        self.inner.id
    }

    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Non-blocking status snapshot.
    pub fn poll(&self) -> JobStatus {
        lock_recover(&self.inner.core).status
    }

    /// Trip the job's cancel token. The engine aborts at the next step
    /// boundary and the job lands in [`JobStatus::Cancelled`] with a
    /// valid checkpoint; a queued job is cancelled by its next (trivial)
    /// slice. Idempotent.
    pub fn cancel(&self) {
        self.inner.cancel.cancel();
    }

    /// The latest checkpoint, if any slice has run (or a resume
    /// checkpoint was supplied).
    pub fn checkpoint(&self) -> Option<Checkpoint> {
        lock_recover(&self.inner.core).checkpoint.clone()
    }

    /// Terminal outcome if the job has reached one, without blocking.
    pub fn outcome(&self) -> Option<JobOutcome> {
        JobInner::outcome_locked(&lock_recover(&self.inner.core))
    }

    /// Block until the job reaches a terminal status (completed,
    /// suspended, cancelled, or failed — the scheduler must be draining
    /// on some thread, or have drained already) and return its outcome.
    pub fn await_result(&self) -> JobOutcome {
        let mut core = lock_recover(&self.inner.core);
        loop {
            if let Some(o) = JobInner::outcome_locked(&core) {
                return o;
            }
            core = self
                .inner
                .done
                .wait(core)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }
}
