//! Dense numerical linear algebra substrate (f64, row-major).
//!
//! Everything the paper's algorithms need is implemented here from
//! scratch: blocked matmul/Gram kernels ([`blas`]), Cholesky factorization
//! and triangular solves ([`chol`]), CholeskyQR + Householder QR and row
//! leverage scores ([`qr`]), a cyclic-Jacobi symmetric eigensolver
//! ([`eig`]) used by Apx-EVD (paper Alg. Apx-EVD line 5), and the
//! zero-allocation per-iteration buffer workspace ([`workspace`]) behind
//! the `apply_into` kernel dispatch protocol, and the packed-triangular
//! symmetric storage ([`packed`]) that halves the resident footprint of
//! the dense data matrix, with an out-of-core tier ([`spill`]) that
//! streams the same panels from a checksummed on-disk file. The hot
//! kernels are runtime-dispatched over explicit SIMD tiers ([`simd`]:
//! AVX-512F/AVX2+FMA/NEON with the scalar bodies kept as oracles,
//! selected once per process from `SYMNMF_KERNEL` or feature
//! detection).

pub mod blas;
pub mod chol;
pub mod dense;
pub mod eig;
pub mod packed;
pub mod qr;
pub mod simd;
pub mod spill;
pub mod workspace;

pub use dense::DenseMat;
pub use packed::SymPacked;
pub use spill::SymPackedSpilled;
pub use simd::{KernelIsa, Precision};
pub use workspace::{F32Buf, IterWorkspace, PanelBuf, UpdateScratch};
