"""L2 correctness: model programs vs oracles, shape contracts, and the
mathematical invariants the rust coordinator relies on."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

DIM = st.integers(min_value=2, max_value=24)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@settings(max_examples=15, deadline=None)
@given(m=DIM, k=DIM, seed=st.integers(0, 2**31 - 1))
def test_products(m, k, seed):
    rng = np.random.default_rng(seed)
    x, f = rand(rng, m, m), rand(rng, m, k)
    xf, g = model.products(x, f)
    rxf, rg = ref.products(x, f)
    np.testing.assert_allclose(xf, rxf, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(g, rg, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=DIM, l=DIM, k=DIM, seed=st.integers(0, 2**31 - 1))
def test_lai_products(m, l, k, seed):
    rng = np.random.default_rng(seed)
    u, v, f = rand(rng, m, l), rand(rng, m, l), rand(rng, m, k)
    y, g = model.lai_products(u, v, f)
    ry, rg = ref.lai_products(u, v, f)
    np.testing.assert_allclose(y, ry, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(g, rg, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(m=DIM, k=st.integers(2, 10), seed=st.integers(0, 2**31 - 1))
def test_hals_sweep_matches_sequential_ref(m, k, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, m)
    x = (x + x.T) / 2
    h = jnp.abs(rand(rng, m, k))
    w = jnp.abs(rand(rng, m, k))
    alpha = jnp.float32(1.5)
    xh, g = ref.products(x, h)
    got = model.hals_sweep(xh, g, w, h, alpha)
    want = ref.hals_sweep(xh, g, w, h, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_hals_sweep_nonnegative_output():
    rng = np.random.default_rng(3)
    m, k = 20, 5
    x = rand(rng, m, m)
    h = jnp.abs(rand(rng, m, k))
    w = jnp.abs(rand(rng, m, k))
    xh, g = ref.products(x, h)
    out = np.asarray(model.hals_sweep(xh, g, w, h, jnp.float32(0.5)))
    assert (out >= 0).all()


def test_hals_sweep_decreases_regularized_objective():
    """A full W-sweep must not increase ‖X − WHᵀ‖² + α‖W − H‖² (HALS is
    exact coordinate minimization per column)."""
    rng = np.random.default_rng(7)
    m, k = 30, 4
    a = np.abs(rng.standard_normal((m, m)))
    x = jnp.asarray((a + a.T) / 2, dtype=jnp.float32)
    h = jnp.abs(rand(rng, m, k))
    w = jnp.abs(rand(rng, m, k))
    alpha = jnp.float32(1.0)

    def obj(wm):
        return (jnp.linalg.norm(x - wm @ h.T) ** 2
                + alpha * jnp.linalg.norm(wm - h) ** 2)

    xh, g = ref.products(x, h)
    w2 = model.hals_sweep(xh, g, w, h, alpha)
    assert float(obj(w2)) <= float(obj(w)) + 1e-3
