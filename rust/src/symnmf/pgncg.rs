//! Projected Gauss–Newton with Conjugate Gradients (paper §2.1.3, [22])
//! and its LAI variant (App. B.2, Alg. LAI-PGNCG-SymNMF).
//!
//! The all-at-once method minimizes ‖X − HHᵀ‖ directly. Each outer step
//! solves the Gauss–Newton normal equations JᵀJ·z = g approximately with
//! CG, exploiting the Kronecker structure of J so that the JᵀJ-product is
//! two skinny matmuls (line 11 of Alg. LAI-PGNCG):
//!
//! ```text
//!     Y = 2(P·(HᵀH) + H·(PᵀH)),   g = −2·(X·H − H·(HᵀH))
//! ```
//!
//! then projects: H ← [H − Z]_+. The only X-sized work per outer
//! iteration is the single product X·H — which is why LAI substitution
//! (X·H → U(VᵀH)) accelerates PGNCG just as well as the AU methods,
//! something the compression-based randomized NMF methods cannot do
//! (paper §3.4).

use crate::linalg::{blas, DenseMat, IterWorkspace};
use crate::randnla::SymOp;
use crate::symnmf::anls::Metrics;
use crate::symnmf::engine::{
    run_solver, workspace_for, Checkpoint, EngineRun, EngineState, RunControl, SolveSpec,
    SolverEngine, Stage, StepOutcome, TraceSink,
};
use crate::symnmf::init::initial_factor;
use crate::symnmf::lai::build_lai;
#[cfg(test)]
use crate::symnmf::metrics::{IterRecord, StopRule};
use crate::symnmf::metrics::SymNmfResult;
use crate::symnmf::options::SymNmfOptions;
use crate::util::rng::Pcg64;
#[cfg(test)]
use crate::util::timer::{PHASE_MM, PHASE_SOLVE};
use crate::util::timer::{PhaseTimer, Stopwatch};

/// Pre-sized buffers for the CG inner solve — allocated once per
/// [`PgncgEngine`] (and per reference-loop run), reused across every
/// outer iteration and every CG step (the PGNCG face of the
/// zero-allocation kernel core). Carries no cross-iteration state: every
/// buffer is fully rewritten before it is read each step.
struct CgWorkspace {
    /// m×k: CG right-hand side / residual R
    r: DenseMat,
    /// m×k: accumulated direction Z
    z: DenseMat,
    /// m×k: search direction P
    p: DenseMat,
    /// m×k: JᵀJ·P product
    y: DenseMat,
    /// m×k: H·(PᵀH) partial
    hp: DenseMat,
    /// m×k: H·G product of the outer step (RHS assembly)
    hg: DenseMat,
    /// k×k: PᵀH inner product
    pth: DenseMat,
}

impl CgWorkspace {
    fn new(m: usize, k: usize) -> CgWorkspace {
        CgWorkspace {
            r: DenseMat::zeros(m, k),
            z: DenseMat::zeros(m, k),
            p: DenseMat::zeros(m, k),
            y: DenseMat::zeros(m, k),
            hp: DenseMat::zeros(m, k),
            hg: DenseMat::zeros(m, k),
            pth: DenseMat::zeros(k, k),
        }
    }
}

/// One CG solve of JᵀJ·Z ≈ R (Gauss–Newton direction). `g` = HᵀH is held
/// fixed during the inner solve; `cg.r` holds the right-hand side on
/// entry and the CG residual on exit; the direction lands in `cg.z`.
/// All intermediates come from the workspace — no allocation.
fn cg_direction_ws(h: &DenseMat, g: &DenseMat, iters: usize, cg: &mut CgWorkspace) {
    cg.z.fill(0.0);
    let mut e_old = cg.r.fro_norm_sq();
    if e_old == 0.0 {
        return;
    }
    cg.p.copy_from(&cg.r);
    for _ in 0..iters {
        // Y = JᵀJ·P = 2(P·G + H·(PᵀH))
        blas::matmul_tn_into(&cg.p, h, &mut cg.pth);
        blas::matmul_into(&cg.p, g, &mut cg.y);
        blas::matmul_into(h, &cg.pth, &mut cg.hp);
        cg.y.axpy(1.0, &cg.hp);
        cg.y.scale(2.0);
        let py = blas::dot(cg.p.data(), cg.y.data());
        if py.abs() < 1e-300 {
            break;
        }
        let a = e_old / py;
        cg.z.axpy(a, &cg.p);
        cg.r.axpy(-a, &cg.y);
        let e_new = cg.r.fro_norm_sq();
        if e_new.sqrt() < 1e-12 {
            break;
        }
        let beta = e_new / e_old;
        // p = r + beta·p, in place
        cg.p.scale(beta);
        cg.p.axpy(1.0, &cg.r);
        e_old = e_new;
    }
}

/// Allocating wrapper over [`cg_direction_ws`] (test oracle).
#[cfg(test)]
fn cg_direction(h: &DenseMat, g: &DenseMat, r0: DenseMat, iters: usize) -> DenseMat {
    let (m, k) = r0.shape();
    let mut cg = CgWorkspace::new(m, k);
    cg.r.copy_from(&r0);
    cg_direction_ws(h, g, iters, &mut cg);
    cg.z
}

/// PGNCG as a [`SolverEngine`]: one step is one projected Gauss–Newton
/// outer iteration (X·H product, CG inner solve, projected update).
/// PGNCG maintains only H (W aliases it) and the CG workspace carries no
/// cross-iteration state, so its checkpoint is just H.
pub struct PgncgEngine<'a> {
    x: &'a dyn SymOp,
    cg_iters: usize,
    h: DenseMat,
    cg: CgWorkspace,
}

impl<'a> PgncgEngine<'a> {
    pub fn new(x: &'a dyn SymOp, cg_iters: usize, h0: DenseMat) -> PgncgEngine<'a> {
        let (m, k) = h0.shape();
        PgncgEngine { x, cg_iters, h: h0, cg: CgWorkspace::new(m, k) }
    }
}

impl SolverEngine for PgncgEngine<'_> {
    fn h(&self) -> &DenseMat {
        &self.h
    }

    fn w(&self) -> &DenseMat {
        &self.h
    }

    fn step(&mut self, ws: &mut IterWorkspace) -> StepOutcome {
        let t = Stopwatch::start();
        self.x.apply_into(&self.h, &mut ws.y); // X·H
        blas::gram_into(&self.h, &mut ws.g); // G = HᵀH
        let mm = t.elapsed_secs();

        let t = Stopwatch::start();
        // CG right-hand side R₀ = 2(XH − H·G), see the module header
        blas::matmul_into(&self.h, &ws.g, &mut self.cg.hg);
        self.cg.r.copy_from(&ws.y);
        self.cg.r.axpy(-1.0, &self.cg.hg);
        self.cg.r.scale(2.0);
        cg_direction_ws(&self.h, &ws.g, self.cg_iters, &mut self.cg);
        self.h.axpy(1.0, &self.cg.z);
        self.h.project_nonneg();
        let solve = t.elapsed_secs();

        StepOutcome { mm_secs: mm, solve_secs: solve, ..StepOutcome::default() }
    }

    fn save(&self) -> EngineState {
        EngineState { h: self.h.clone(), w: None, rng: None }
    }

    fn load(&mut self, st: &EngineState) {
        assert_eq!(st.h.shape(), self.h.shape(), "PgncgEngine::load: H shape mismatch");
        self.h = st.h.clone();
    }
}

/// The frozen pre-engine PGNCG loop, kept verbatim as the **reference
/// oracle** the engine path is pinned against (`x_iter` drives the
/// iteration, `metrics` measures against the true X).
#[cfg(test)]
fn run_pgncg_loop(
    x_iter: &dyn SymOp,
    opts: &SymNmfOptions,
    mut h: DenseMat,
    metrics: &Metrics,
    label: String,
    setup_secs: f64,
    mut phases: PhaseTimer,
) -> SymNmfResult {
    let mut records: Vec<IterRecord> = Vec::new();
    let mut stop = StopRule::new(opts.tol, opts.patience);
    let mut clock = setup_secs;
    let (m, k) = h.shape();
    // all per-iteration buffers, sized once: X·H, HᵀH and the metric
    // buffers in the shared iteration workspace (PGNCG leaves its
    // Update(G,Y) scratch idle — it has no NLS solve), CG intermediates
    // including the H·G RHS partial in the CG workspace
    let mut ws = IterWorkspace::new(m, k);
    let mut cg = CgWorkspace::new(m, k);

    for iter in 0..opts.max_iters {
        let sw = Stopwatch::start();
        let t = Stopwatch::start();
        x_iter.apply_into(&h, &mut ws.y); // X·H
        blas::gram_into(&h, &mut ws.g); // G = HᵀH
        let mm = t.elapsed_secs();

        let t = Stopwatch::start();
        // gradient direction: R = −g/2 form: R₀ = 2(XH − H·G) is the CG
        // right-hand side (−gradient); Alg. LAI-PGNCG phrases it with the
        // opposite sign and a minus in the final update — equivalent.
        blas::matmul_into(&h, &ws.g, &mut cg.hg); // H·G
        cg.r.copy_from(&ws.y);
        cg.r.axpy(-1.0, &cg.hg);
        cg.r.scale(2.0);
        cg_direction_ws(&h, &ws.g, opts.cg_iters, &mut cg);
        // H ← [H + Z]_+ (Z approximates the Newton step along −gradient)
        h.axpy(1.0, &cg.z);
        h.project_nonneg();
        let solve = t.elapsed_secs();

        clock += sw.elapsed_secs();
        phases.add(PHASE_MM, std::time::Duration::from_secs_f64(mm));
        phases.add(PHASE_SOLVE, std::time::Duration::from_secs_f64(solve));

        let (res, pg) = metrics.eval_ws(&h, &h, &mut ws);
        records.push(IterRecord {
            iter,
            time_secs: clock,
            residual: res,
            proj_grad: pg,
            phase_secs: (mm, solve, 0.0),
            hybrid_stats: None,
        });
        if stop.update(res) {
            break;
        }
    }

    SymNmfResult { label, h: h.clone(), w: h, records, phases, setup_secs }
}

/// PGNCG-SymNMF on the exact X (the paper's "PGNCG" baseline) — thin
/// wrapper over the engine path (`SYMNMF_DEADLINE_MS` honored).
pub fn pgncg_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    pgncg_symnmf_run(x, opts, &RunControl::from_env(), None, None).result
}

/// The controlled engine entry for exact PGNCG.
pub fn pgncg_symnmf_run<X: SymOp>(
    x: &X,
    opts: &SymNmfOptions,
    ctrl: &RunControl,
    resume: Option<&Checkpoint>,
    trace: Option<&mut dyn TraceSink>,
) -> EngineRun {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let h0 = initial_factor(x, opts, &mut rng);
    let x: &dyn SymOp = x;
    let mut spec = SolveSpec {
        stages: vec![Stage {
            engine: Box::new(PgncgEngine::new(x, opts.cg_iters, h0)),
            label: "PGNCG".to_string(),
        }],
        metrics: Metrics::new(x, true),
        setup_secs: 0.0,
        phases: PhaseTimer::new(),
    };
    let mut ws = workspace_for(&spec);
    run_solver(&mut spec, opts, ctrl, resume, trace, &mut ws)
}

/// LAI-PGNCG-SymNMF (App. B.2): the same engine against the factored
/// LAI; with `opts.refine`, a second warm-started stage on the true X
/// ("PGNCG-IR" rows of Table 2). Thin wrapper over the engine chain
/// (`SYMNMF_DEADLINE_MS` honored).
pub fn lai_pgncg_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    lai_pgncg_symnmf_run(x, opts, &RunControl::from_env(), None, None).result
}

/// The controlled engine entry for LAI-PGNCG (± IR): the RRF build is
/// the setup phase; refinement is engine *composition* — a second
/// [`PgncgEngine`] stage over the true X, warm-started by the shared
/// outer loop.
pub fn lai_pgncg_symnmf_run<X: SymOp>(
    x: &X,
    opts: &SymNmfOptions,
    ctrl: &RunControl,
    resume: Option<&Checkpoint>,
    trace: Option<&mut dyn TraceSink>,
) -> EngineRun {
    let xd: &dyn SymOp = x;
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let mut phases = PhaseTimer::new();
    let (lai, setup_secs, _evd) = build_lai(x, opts, &mut rng, &mut phases);
    let h0 = initial_factor(x, opts, &mut rng);
    let mut stages: Vec<Stage<'_>> = vec![Stage {
        engine: Box::new(PgncgEngine::new(&lai, opts.cg_iters, h0.clone())),
        label: "LAI-PGNCG".to_string(),
    }];
    if opts.refine {
        stages.push(Stage {
            engine: Box::new(PgncgEngine::new(xd, opts.cg_iters, h0)),
            label: "LAI-PGNCG-IR".to_string(),
        });
    }
    let mut spec = SolveSpec {
        stages,
        metrics: Metrics::new(xd, true),
        setup_secs,
        phases,
    };
    let mut ws = workspace_for(&spec);
    run_solver(&mut spec, opts, ctrl, resume, trace, &mut ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symnmf::engine::{assert_results_bitwise_eq, RunStatus};

    /// The frozen pre-engine "PGNCG" entry (pinning oracle).
    fn pgncg_symnmf_reference<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
        let mut rng = Pcg64::seed_from_u64(opts.seed);
        let h0 = initial_factor(x, opts, &mut rng);
        let metrics = Metrics::new(x, true);
        run_pgncg_loop(x, opts, h0, &metrics, "PGNCG".to_string(), 0.0, PhaseTimer::new())
    }

    /// The frozen pre-engine "LAI-PGNCG(-IR)" entry (pinning oracle):
    /// LAI build → PGNCG loop → optional IR continuation with stitched
    /// records.
    fn lai_pgncg_symnmf_reference<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
        let mut rng = Pcg64::seed_from_u64(opts.seed);
        let mut phases = PhaseTimer::new();
        let (lai, setup_secs, _evd) = build_lai(x, opts, &mut rng, &mut phases);
        let h0 = initial_factor(x, opts, &mut rng);
        let metrics = Metrics::new(x, true);
        let result = run_pgncg_loop(
            &lai,
            opts,
            h0,
            &metrics,
            "LAI-PGNCG".to_string(),
            setup_secs,
            phases,
        );
        if !opts.refine {
            return result;
        }
        let clock = result.total_secs();
        let refined = run_pgncg_loop(
            x,
            opts,
            result.h.clone(),
            &metrics,
            "LAI-PGNCG-IR".to_string(),
            clock,
            result.phases.clone(),
        );
        let mut records = result.records;
        let offset = records.len();
        records.extend(refined.records.into_iter().map(|mut r| {
            r.iter += offset;
            r
        }));
        SymNmfResult {
            label: "LAI-PGNCG-IR".to_string(),
            h: refined.h,
            w: refined.w,
            records,
            phases: refined.phases,
            setup_secs,
        }
    }

    /// Acceptance: engine wrappers pinned bitwise to the frozen loops —
    /// exact PGNCG and both LAI variants (the IR chain exercises the
    /// engine-composition warm start).
    #[test]
    fn engine_path_pinned_bitwise_to_reference() {
        for (m, k) in [(30, 2), (56, 7)] {
            let x = planted(m, k, 8);
            let mut opts = SymNmfOptions::new(k).with_seed(9);
            opts.max_iters = 10;
            opts.cg_iters = 8;
            let oracle = pgncg_symnmf_reference(&x, &opts);
            let engine = pgncg_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
            assert_results_bitwise_eq(&oracle, &engine.result, &format!("pgncg k={k}"));
            for refine in [false, true] {
                opts.refine = refine;
                let oracle = lai_pgncg_symnmf_reference(&x, &opts);
                let engine =
                    lai_pgncg_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
                assert_results_bitwise_eq(
                    &oracle,
                    &engine.result,
                    &format!("lai-pgncg refine={refine} k={k}"),
                );
            }
        }
    }

    /// Acceptance: checkpoint/resume bitwise + deadline-0 initial-iterate
    /// for PGNCG and the two-stage LAI-PGNCG-IR chain (pausing inside
    /// stage 0 AND inside stage 1).
    #[test]
    fn checkpoint_resume_and_deadline() {
        for k in [2usize, 7] {
            let x = planted(12 * k, k, 6);
            let mut opts = SymNmfOptions::new(k).with_seed(3);
            opts.max_iters = 6;
            opts.cg_iters = 6;
            opts.refine = true;
            let full = lai_pgncg_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
            assert!(full.result.iters() > opts.max_iters, "IR stage must add iterations");
            // pause points: inside the LAI stage (2 steps) and inside the
            // IR stage (max_iters + 2 steps)
            for steps in [2usize, opts.max_iters + 2] {
                let paused = lai_pgncg_symnmf_run(
                    &x,
                    &opts,
                    &RunControl::unlimited().with_max_steps(steps),
                    None,
                    None,
                );
                if steps < full.result.iters() {
                    assert_eq!(paused.checkpoint.status, RunStatus::Paused);
                    assert_eq!(paused.result.iters(), steps);
                }
                let cp =
                    Checkpoint::parse(&paused.checkpoint.serialize()).expect("roundtrip");
                let resumed = lai_pgncg_symnmf_run(
                    &x,
                    &opts,
                    &RunControl::unlimited(),
                    Some(&cp),
                    None,
                );
                assert_results_bitwise_eq(
                    &full.result,
                    &resumed.result,
                    &format!("lai-pgncg-ir k={k} pause@{steps}"),
                );
            }

            let pg_full = pgncg_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
            let dead = pgncg_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited().with_deadline(0.0),
                None,
                None,
            );
            assert_eq!(dead.checkpoint.status, RunStatus::Deadline);
            assert!(dead.result.records.is_empty());
            let resumed = pgncg_symnmf_run(
                &x,
                &opts,
                &RunControl::unlimited(),
                Some(&dead.checkpoint),
                None,
            );
            assert_results_bitwise_eq(
                &pg_full.result,
                &resumed.result,
                &format!("pgncg deadline-0 k={k}"),
            );
        }
    }

    fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        x.symmetrize();
        x
    }

    /// Satellite acceptance: cancel-before-first-step and mid-run cancel
    /// for PGNCG (and the LAI-PGNCG chain), both resuming bitwise.
    #[test]
    fn cancel_token_aborts_and_resumes_bitwise() {
        use crate::symnmf::engine::CancelToken;
        use crate::symnmf::trace::CancelAfterSink;
        let x = planted(36, 3, 43);
        let mut opts = SymNmfOptions::new(3).with_seed(21);
        opts.max_iters = 6;
        opts.cg_iters = 5;
        let full = pgncg_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);

        let tok = CancelToken::new();
        tok.cancel();
        let cancelled = pgncg_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            None,
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.result.iters(), 0);
        let resumed = pgncg_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited(),
            Some(&cancelled.checkpoint),
            None,
        );
        assert_results_bitwise_eq(&full.result, &resumed.result, "pgncg cancel-0 resume");

        let tok = CancelToken::new();
        let mut hook = CancelAfterSink::new(tok.clone(), 2);
        let cancelled = pgncg_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            Some(&mut hook),
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.result.iters(), 2);
        let cp = Checkpoint::parse(&cancelled.checkpoint.serialize()).expect("roundtrip");
        let resumed = pgncg_symnmf_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
        assert_results_bitwise_eq(&full.result, &resumed.result, "pgncg mid-cancel resume");

        // the two-stage chain: cancel lands mid-flight, resume completes
        opts.refine = true;
        let full = lai_pgncg_symnmf_run(&x, &opts, &RunControl::unlimited(), None, None);
        let tok = CancelToken::new();
        let mut hook = CancelAfterSink::new(tok.clone(), 3);
        let cancelled = lai_pgncg_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            Some(&mut hook),
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        let resumed = lai_pgncg_symnmf_run(
            &x,
            &opts,
            &RunControl::unlimited(),
            Some(&cancelled.checkpoint),
            None,
        );
        assert_results_bitwise_eq(
            &full.result,
            &resumed.result,
            "lai-pgncg mid-cancel resume",
        );
    }

    #[test]
    fn pgncg_converges_on_planted() {
        let x = planted(50, 3, 1);
        let mut opts = SymNmfOptions::new(3).with_seed(2);
        opts.max_iters = 80;
        opts.cg_iters = 15;
        let res = pgncg_symnmf(&x, &opts);
        assert!(res.h.is_nonneg());
        let last = res.min_residual();
        let first = res.records.first().unwrap().residual;
        assert!(last < 0.5 * first, "residual {first} → {last}");
    }

    #[test]
    fn cg_direction_solves_psd_system_when_unconstrained() {
        // JᵀJ is PSD but can be singular; pick an RHS in its range
        // (r0 = JᵀJ·y for random y) so CG must recover it exactly.
        let mut rng = Pcg64::seed_from_u64(3);
        let h = DenseMat::uniform(12, 3, 1.0, &mut rng);
        let g = blas::gram(&h);
        let y0 = DenseMat::gaussian(12, 3, &mut rng);
        let r0 = {
            let yth = blas::matmul_tn(&y0, &h);
            let mut r = blas::matmul(&y0, &g);
            r.axpy(1.0, &blas::matmul(&h, &yth));
            r.scale(2.0);
            r
        };
        let z = cg_direction(&h, &g, r0.clone(), 400);
        // apply JᵀJ to z
        let zth = blas::matmul_tn(&z, &h);
        let mut y = blas::matmul(&z, &g);
        y.axpy(1.0, &blas::matmul(&h, &zth));
        y.scale(2.0);
        let rel = y.diff_fro(&r0) / r0.fro_norm();
        assert!(rel < 1e-6, "CG residual {rel}");
    }

    #[test]
    fn lai_pgncg_matches_quality() {
        let x = planted(60, 4, 4);
        let mut opts = SymNmfOptions::new(4).with_seed(5);
        opts.max_iters = 80;
        let exact = pgncg_symnmf(&x, &opts);
        let lai = lai_pgncg_symnmf(&x, &opts);
        assert!(
            lai.min_residual() < exact.min_residual() + 0.05,
            "LAI {} vs exact {}",
            lai.min_residual(),
            exact.min_residual()
        );
    }

    #[test]
    fn ir_label_and_continuation() {
        let x = planted(40, 3, 6);
        let mut opts = SymNmfOptions::new(3).with_seed(7);
        opts.max_iters = 20;
        opts.refine = true;
        let res = lai_pgncg_symnmf(&x, &opts);
        assert_eq!(res.label, "LAI-PGNCG-IR");
        for w in res.records.windows(2) {
            assert!(w[1].time_secs >= w[0].time_secs - 1e-12);
            assert_eq!(w[1].iter, w[0].iter + 1);
        }
    }
}
