//! MatrixMarket coordinate-format IO for sparse matrices — the standard
//! interchange format for graph data sets, so users can run the binaries
//! on their own graphs (`symnmf run --input graph.mtx`).

use crate::sparse::CsrMat;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Read a MatrixMarket `coordinate real {general|symmetric}` file.
pub fn read_matrix_market(path: &Path) -> Result<CsrMat, String> {
    let file = std::fs::File::open(path).map_err(|e| format!("open {path:?}: {e}"))?;
    let reader = std::io::BufReader::new(file);
    let mut lines = reader.lines();

    let header = lines
        .next()
        .ok_or("empty file")?
        .map_err(|e| e.to_string())?;
    if !header.starts_with("%%MatrixMarket") {
        return Err("missing %%MatrixMarket header".into());
    }
    let lower = header.to_lowercase();
    if !lower.contains("coordinate") {
        return Err("only coordinate format supported".into());
    }
    let symmetric = lower.contains("symmetric");
    let pattern = lower.contains("pattern");

    let mut dims: Option<(usize, usize, usize)> = None;
    let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
    for line in lines {
        let line = line.map_err(|e| e.to_string())?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        if dims.is_none() {
            let m: usize = it.next().ok_or("bad size line")?.parse().map_err(|e| format!("{e}"))?;
            let n: usize = it.next().ok_or("bad size line")?.parse().map_err(|e| format!("{e}"))?;
            let nnz: usize = it.next().ok_or("bad size line")?.parse().map_err(|e| format!("{e}"))?;
            dims = Some((m, n, nnz));
            triplets.reserve(if symmetric { 2 * nnz } else { nnz });
            continue;
        }
        let i: usize = it.next().ok_or("bad entry")?.parse().map_err(|e| format!("{e}"))?;
        let j: usize = it.next().ok_or("bad entry")?.parse().map_err(|e| format!("{e}"))?;
        let v: f64 = if pattern {
            1.0
        } else {
            it.next().ok_or("bad entry")?.parse().map_err(|e| format!("{e}"))?
        };
        let (i, j) = (i - 1, j - 1); // 1-based → 0-based
        triplets.push((i, j, v));
        if symmetric && i != j {
            triplets.push((j, i, v));
        }
    }
    let (m, n, _) = dims.ok_or("missing size line")?;
    Ok(CsrMat::from_coo(m, n, triplets))
}

/// Write in `coordinate real general` format.
pub fn write_matrix_market(path: &Path, a: &CsrMat) -> Result<(), String> {
    let file = std::fs::File::create(path).map_err(|e| format!("create {path:?}: {e}"))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "%%MatrixMarket matrix coordinate real general").map_err(|e| e.to_string())?;
    writeln!(w, "{} {} {}", a.rows(), a.cols(), a.nnz()).map_err(|e| e.to_string())?;
    for i in 0..a.rows() {
        let (cols, vals) = a.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            writeln!(w, "{} {} {v}", i + 1, j + 1).map_err(|e| e.to_string())?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_general() {
        let a = CsrMat::from_coo(3, 4, vec![(0, 1, 1.5), (2, 3, -2.0), (1, 1, 7.0)]);
        let dir = std::env::temp_dir().join("symnmf_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.mtx");
        write_matrix_market(&path, &a).unwrap();
        let b = read_matrix_market(&path).unwrap();
        assert_eq!(b.rows(), 3);
        assert_eq!(b.cols(), 4);
        assert_eq!(b.nnz(), 3);
        assert_eq!(b.get(0, 1), 1.5);
        assert_eq!(b.get(2, 3), -2.0);
    }

    #[test]
    fn reads_symmetric_and_pattern() {
        let dir = std::env::temp_dir().join("symnmf_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate pattern symmetric\n% comment\n3 3 2\n2 1\n3 3\n",
        )
        .unwrap();
        let a = read_matrix_market(&path).unwrap();
        assert_eq!(a.get(1, 0), 1.0);
        assert_eq!(a.get(0, 1), 1.0, "mirrored");
        assert_eq!(a.get(2, 2), 1.0, "diagonal not mirrored twice");
        assert_eq!(a.nnz(), 3);
    }
}
