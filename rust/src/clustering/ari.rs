//! Adjusted Rand Index — the clustering-quality metric of the WoS
//! experiments (paper §5.1, Table 2 "Mean-ARI").

/// ARI between two labelings (arbitrary label values).
pub fn adjusted_rand_index(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return 1.0;
    }
    let ka = a.iter().max().map(|&m| m + 1).unwrap_or(0);
    let kb = b.iter().max().map(|&m| m + 1).unwrap_or(0);
    // contingency table
    let mut table = vec![0usize; ka * kb];
    let mut rows = vec![0usize; ka];
    let mut cols = vec![0usize; kb];
    for (&x, &y) in a.iter().zip(b.iter()) {
        table[x * kb + y] += 1;
        rows[x] += 1;
        cols[y] += 1;
    }
    let c2 = |x: usize| (x * x.saturating_sub(1)) as f64 / 2.0;
    let sum_ij: f64 = table.iter().map(|&x| c2(x)).sum();
    let sum_a: f64 = rows.iter().map(|&x| c2(x)).sum();
    let sum_b: f64 = cols.iter().map(|&x| c2(x)).sum();
    let total = c2(n);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-300 {
        return 1.0; // degenerate: identical trivial partitions
    }
    (sum_ij - expected) / (max_index - expected)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn identical_partitions_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn permuted_labels_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![2, 2, 0, 0, 1, 1];
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_labels_score_near_zero() {
        let mut rng = Pcg64::seed_from_u64(1);
        let n = 5000;
        let a: Vec<usize> = (0..n).map(|_| rng.below(5)).collect();
        let b: Vec<usize> = (0..n).map(|_| rng.below(5)).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 0.02, "ari={ari}");
    }

    #[test]
    fn known_value() {
        // classic example: ARI of these partitions ≈ 0.24242424
        let a = vec![0, 0, 0, 1, 1, 1, 2, 2, 2];
        let b = vec![0, 0, 1, 1, 2, 2, 2, 0, 1];
        let ari = adjusted_rand_index(&a, &b);
        assert!((ari - (-1.0 / 27.0)).abs() < 1e-9, "ari={ari}");
    }

    #[test]
    fn partial_agreement_between_zero_and_one() {
        let a = vec![0, 0, 0, 0, 1, 1, 1, 1];
        let b = vec![0, 0, 0, 1, 1, 1, 1, 1];
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari > 0.3 && ari < 1.0, "ari={ari}");
    }
}
