//! Minimal error-context substrate (anyhow is unavailable offline; see
//! DESIGN.md §2 "Offline-dependency substitutions"): a string-backed
//! error, a `Result` alias defaulting to it, a `Context` extension trait
//! mirroring `anyhow::Context`, and the [`err!`](crate::err) macro for
//! formatted construction.

use std::fmt;

/// A string-backed error with context chaining via `Context`.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Result alias defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style extension: wrap an error with a message.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string (the `anyhow!` stand-in).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_chains_messages() {
        let base: Result<(), String> = Err("inner".to_string());
        let e = base.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let base: Result<(), String> = Err("inner".to_string());
        let e = base.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3: inner");
    }

    #[test]
    fn err_macro_formats() {
        let e = crate::err!("bad shape {:?}", (2, 3));
        assert!(e.to_string().contains("(2, 3)"));
        // alternate formatting ({:#}) must also render
        assert!(format!("{e:#}").contains("bad shape"));
    }
}
