//! Symmetrically regularized alternating updating (paper §2.1.1–2.1.2):
//! iterate the two NLS problems of Eq. 2.4,
//!
//! ```text
//!     min_{W≥0} ‖[H; √αI]·Wᵀ − [X; √αHᵀ]‖   and symmetrically for H,
//! ```
//!
//! through their normal-equation pair (G = FᵀF + αI, Y = X·F + αF) and
//! the Update(G, Y) rule (BPP / HALS / MU). This single loop, generic
//! over [`SymOp`], is also the engine of LAI-SymNMF (X replaced by the
//! factored approximation) and Compressed-NMF (projected products).

use crate::linalg::{blas, DenseMat, IterWorkspace};
use crate::nls::{update_into, UpdateRule};
use crate::randnla::SymOp;
use crate::symnmf::convergence::{normalized_residual, projected_gradient_norm_sym};
use crate::symnmf::engine::{
    run_solver, workspace_for, Checkpoint, EngineRun, EngineState, RunControl, SolveSpec,
    SolverEngine, Stage, StepOutcome, TraceSink,
};
use crate::symnmf::init::initial_factor;
use crate::symnmf::metrics::{IterRecord, StopRule, SymNmfResult};
use crate::symnmf::options::SymNmfOptions;
use crate::util::rng::Pcg64;
use crate::util::timer::{PhaseTimer, Stopwatch, PHASE_MM, PHASE_SOLVE};

/// Exact-metric evaluator: residual (and optional projected gradient)
/// against the TRUE data matrix, evaluated off the clock so every method
/// is billed only for its own algorithmic work (see `IterRecord`).
pub struct Metrics<'a> {
    pub x: &'a dyn SymOp,
    pub x_norm_sq: f64,
    pub proj_grad: bool,
}

impl<'a> Metrics<'a> {
    pub fn new(x: &'a dyn SymOp, proj_grad: bool) -> Self {
        Metrics { x, x_norm_sq: x.fro_norm_sq(), proj_grad }
    }

    /// (normalized residual of ‖X − WHᵀ‖, optional projected gradient)
    pub fn eval(&self, w: &DenseMat, h: &DenseMat) -> (f64, Option<f64>) {
        let xh = self.x.apply(h);
        let gw = blas::gram(w);
        let gh = blas::gram(h);
        let res = normalized_residual(self.x_norm_sq, &xh, w, &gw, &gh);
        let pg = self
            .proj_grad
            .then(|| projected_gradient_norm_sym(h, &xh, &gh));
        (res, pg)
    }

    /// [`Metrics::eval`] drawing the X·H and Gram buffers from the
    /// iteration workspace (`xh`, `g`, `g2` — all free between
    /// iterations). The residual path allocates nothing; when
    /// `proj_grad` is enabled the projected-gradient evaluation still
    /// builds one m×k H·G product internally (off the clock, see
    /// [`projected_gradient_norm_sym`]).
    pub fn eval_ws(
        &self,
        w: &DenseMat,
        h: &DenseMat,
        ws: &mut IterWorkspace,
    ) -> (f64, Option<f64>) {
        self.x.apply_into(h, &mut ws.xh);
        blas::gram_into(w, &mut ws.g2);
        blas::gram_into(h, &mut ws.g);
        let res = normalized_residual(self.x_norm_sq, &ws.xh, w, &ws.g2, &ws.g);
        let pg = self
            .proj_grad
            .then(|| projected_gradient_norm_sym(h, &ws.xh, &ws.g));
        (res, pg)
    }
}

/// Resolve α: the paper's recommendation α = max(X) (§5.1, from [35]).
pub fn resolve_alpha<X: SymOp + ?Sized>(x: &X, opts: &SymNmfOptions) -> f64 {
    opts.alpha.unwrap_or_else(|| x.max_value())
}

/// The pre-engine alternating loop, kept verbatim as the **frozen
/// reference oracle** the engine path is pinned against (and as the
/// legacy arm of the `engine_step_overhead` bench). Production entry
/// points run [`AltEngine`] under [`run_solver`] instead. `x` is
/// whatever operator the caller wants the iteration to see (true X,
/// LAI, …); `metrics` always measures against the true X. `setup_secs`
/// pre-loads the clock (LAI build time). Sizes a fresh [`IterWorkspace`]
/// from (m, k) and delegates to [`run_alternating_loop_ws`].
///
/// [`run_solver`]: crate::symnmf::engine::run_solver
#[allow(clippy::too_many_arguments)]
pub fn run_alternating_loop(
    x: &dyn SymOp,
    alpha: f64,
    opts: &SymNmfOptions,
    h: DenseMat,
    metrics: &Metrics,
    label: String,
    setup_secs: f64,
    phases: PhaseTimer,
) -> SymNmfResult {
    let mut ws = IterWorkspace::new(x.dim(), opts.k);
    run_alternating_loop_ws(x, alpha, opts, h, metrics, label, setup_secs, phases, &mut ws)
}

/// The alternating loop against a caller-provided workspace. The
/// steady-state iteration performs no heap allocation: X·F products land
/// in `ws.y` via [`SymOp::apply_into`], Gram matrices in `ws.g` via
/// [`blas::gram_into`], and the Update(G, Y) rules draw their scratch
/// from `ws.update` (see [`crate::linalg::workspace`]).
#[allow(clippy::too_many_arguments)]
pub fn run_alternating_loop_ws(
    x: &dyn SymOp,
    alpha: f64,
    opts: &SymNmfOptions,
    mut h: DenseMat,
    metrics: &Metrics,
    label: String,
    setup_secs: f64,
    phases: PhaseTimer,
    ws: &mut IterWorkspace,
) -> SymNmfResult {
    let mut w = h.clone();
    let mut records: Vec<IterRecord> = Vec::new();
    let mut stop = StopRule::new(opts.tol, opts.patience);
    let mut phases = phases;
    let mut clock = setup_secs;

    for iter in 0..opts.max_iters {
        let sw = Stopwatch::start();
        let mut mm = 0.0;
        let mut solve = 0.0;

        // --- W update: G = HᵀH + αI, Y = X·H + αH ---
        let t = Stopwatch::start();
        x.apply_into(&h, &mut ws.y);
        blas::gram_into(&h, &mut ws.g);
        mm += t.elapsed_secs();
        ws.g.add_diag(alpha);
        ws.y.axpy(alpha, &h);
        let t = Stopwatch::start();
        update_into(opts.rule, &ws.g, &ws.y, &mut w, &mut ws.update);
        solve += t.elapsed_secs();

        // --- H update: G = WᵀW + αI, Y = X·W + αW ---
        let t = Stopwatch::start();
        x.apply_into(&w, &mut ws.y);
        blas::gram_into(&w, &mut ws.g);
        mm += t.elapsed_secs();
        ws.g.add_diag(alpha);
        ws.y.axpy(alpha, &w);
        let t = Stopwatch::start();
        update_into(opts.rule, &ws.g, &ws.y, &mut h, &mut ws.update);
        solve += t.elapsed_secs();

        clock += sw.elapsed_secs();
        phases.add(PHASE_MM, std::time::Duration::from_secs_f64(mm));
        phases.add(PHASE_SOLVE, std::time::Duration::from_secs_f64(solve));

        // --- metrics, off the clock (workspace buffers are free here) ---
        let (res, pg) = metrics.eval_ws(&w, &h, ws);
        records.push(IterRecord {
            iter,
            time_secs: clock,
            residual: res,
            proj_grad: pg,
            phase_secs: (mm, solve, 0.0),
            hybrid_stats: None,
        });
        if stop.update(res) {
            break;
        }
    }

    SymNmfResult { label, h, w, records, phases, setup_secs }
}

/// The alternating-updating methods as a [`SolverEngine`]: one step is
/// the full W-then-H alternating iteration of Eq. 2.4 against any
/// [`SymOp`] — the true X (the "BPP"/"HALS"/"MU" baselines), the
/// factored LAI, or any other operator. Stateless between steps except
/// for the factor pair, so its checkpoint is just (H, W).
pub struct AltEngine<'a> {
    x: &'a dyn SymOp,
    alpha: f64,
    rule: UpdateRule,
    w: DenseMat,
    h: DenseMat,
}

impl<'a> AltEngine<'a> {
    pub fn new(x: &'a dyn SymOp, alpha: f64, rule: UpdateRule, h0: DenseMat) -> AltEngine<'a> {
        AltEngine { x, alpha, rule, w: h0.clone(), h: h0 }
    }
}

impl SolverEngine for AltEngine<'_> {
    fn h(&self) -> &DenseMat {
        &self.h
    }

    fn w(&self) -> &DenseMat {
        &self.w
    }

    fn step(&mut self, ws: &mut IterWorkspace) -> StepOutcome {
        let mut mm = 0.0;
        let mut solve = 0.0;

        // --- W update: G = HᵀH + αI, Y = X·H + αH ---
        let t = Stopwatch::start();
        self.x.apply_into(&self.h, &mut ws.y);
        blas::gram_into(&self.h, &mut ws.g);
        mm += t.elapsed_secs();
        ws.g.add_diag(self.alpha);
        ws.y.axpy(self.alpha, &self.h);
        let t = Stopwatch::start();
        update_into(self.rule, &ws.g, &ws.y, &mut self.w, &mut ws.update);
        solve += t.elapsed_secs();

        // --- H update: G = WᵀW + αI, Y = X·W + αW ---
        let t = Stopwatch::start();
        self.x.apply_into(&self.w, &mut ws.y);
        blas::gram_into(&self.w, &mut ws.g);
        mm += t.elapsed_secs();
        ws.g.add_diag(self.alpha);
        ws.y.axpy(self.alpha, &self.w);
        let t = Stopwatch::start();
        update_into(self.rule, &ws.g, &ws.y, &mut self.h, &mut ws.update);
        solve += t.elapsed_secs();

        StepOutcome { mm_secs: mm, solve_secs: solve, ..StepOutcome::default() }
    }

    fn save(&self) -> EngineState {
        EngineState { h: self.h.clone(), w: Some(self.w.clone()), rng: None }
    }

    fn load(&mut self, st: &EngineState) {
        assert_eq!(st.h.shape(), self.h.shape(), "AltEngine::load: H shape mismatch");
        self.h = st.h.clone();
        self.w = match &st.w {
            Some(w) => {
                assert_eq!(w.shape(), self.h.shape(), "AltEngine::load: W shape mismatch");
                w.clone()
            }
            // warm start: re-derive W = H, as the legacy entry did
            None => self.h.clone(),
        };
    }
}

/// Standard SymNMF via regularized ANLS/HALS/MU on the exact X
/// (the paper's deterministic baselines "BPP" and "HALS") — thin wrapper
/// over the engine path, honoring the `SYMNMF_DEADLINE_MS` environment
/// deadline.
pub fn symnmf_anls<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    symnmf_anls_run(x, opts, &RunControl::from_env(), None, None).result
}

/// The controlled engine entry: deadline/pause budgets, checkpoint
/// resume, and per-iteration tracing. `resume` must come from a run over
/// the same X and options.
pub fn symnmf_anls_run<X: SymOp>(
    x: &X,
    opts: &SymNmfOptions,
    ctrl: &RunControl,
    resume: Option<&Checkpoint>,
    trace: Option<&mut dyn TraceSink>,
) -> EngineRun {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let alpha = resolve_alpha(x, opts);
    let h0 = initial_factor(x, opts, &mut rng);
    let x: &dyn SymOp = x;
    let mut spec = SolveSpec {
        stages: vec![Stage {
            engine: Box::new(AltEngine::new(x, alpha, opts.rule, h0)),
            label: opts.rule.label().to_string(),
        }],
        metrics: Metrics::new(x, true),
        setup_secs: 0.0,
        phases: PhaseTimer::new(),
    };
    let mut ws = workspace_for(&spec);
    run_solver(&mut spec, opts, ctrl, resume, trace, &mut ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nls::UpdateRule;
    use crate::symnmf::engine::{assert_results_bitwise_eq, RunStatus, VecSink};

    /// The frozen pre-engine entry point (the oracle of the pinning
    /// tests): seed → α → H₀ → legacy alternating loop.
    fn symnmf_anls_reference<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
        let mut rng = Pcg64::seed_from_u64(opts.seed);
        let alpha = resolve_alpha(x, opts);
        let h0 = initial_factor(x, opts, &mut rng);
        let metrics = Metrics::new(x, true);
        run_alternating_loop(
            x,
            alpha,
            opts,
            h0,
            &metrics,
            opts.rule.label().to_string(),
            0.0,
            PhaseTimer::new(),
        )
    }

    /// A symmetric nonnegative matrix with planted rank-k structure.
    pub fn planted(m: usize, k: usize, noise: f64, seed: u64) -> DenseMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        if noise > 0.0 {
            let mut e = DenseMat::uniform(m, m, noise, &mut rng);
            e.symmetrize();
            x.axpy(1.0, &e);
        }
        x.symmetrize();
        x
    }

    #[test]
    fn converges_on_planted_problem_all_rules() {
        let x = planted(60, 4, 0.0, 1);
        for rule in [UpdateRule::Bpp, UpdateRule::Hals, UpdateRule::Mu] {
            let mut opts = SymNmfOptions::new(4).with_rule(rule).with_seed(3);
            opts.max_iters = 150;
            let res = symnmf_anls(&x, &opts);
            assert!(res.h.is_nonneg());
            assert!(res.w.is_nonneg());
            let final_res = res.final_residual();
            assert!(
                final_res < 0.15,
                "{rule:?} residual {final_res} too high"
            );
            // residual roughly decreasing
            let first = res.records.first().unwrap().residual;
            assert!(final_res <= first + 1e-9);
        }
    }

    #[test]
    fn w_and_h_converge_together() {
        // large α forces W ≈ H (the Eq. 2.3 coupling)
        let x = planted(40, 3, 0.0, 2);
        let mut opts = SymNmfOptions::new(3).with_seed(5);
        opts.max_iters = 100;
        let res = symnmf_anls(&x, &opts);
        let rel = res.w.diff_fro(&res.h) / res.h.fro_norm();
        assert!(rel < 0.05, "‖W−H‖/‖H‖ = {rel}");
    }

    /// Acceptance: no heap allocation in the steady-state iteration — all
    /// products, Grams and update scratch come from the pre-sized
    /// workspace, whose buffer pointers must be bit-identical across
    /// iterations (a reallocation or buffer replacement would move them).
    #[test]
    fn workspace_buffers_stable_across_iterations() {
        for rule in [UpdateRule::Bpp, UpdateRule::Hals, UpdateRule::Mu] {
            let x = planted(40, 3, 0.0, 9);
            let mut opts = SymNmfOptions::new(3).with_rule(rule).with_seed(1);
            opts.max_iters = 3;
            let alpha = resolve_alpha(&x, &opts);
            let mut rng = Pcg64::seed_from_u64(2);
            let h0 = initial_factor(&x, &opts, &mut rng);
            let metrics = Metrics::new(&x, true);
            let mut ws = crate::linalg::IterWorkspace::new(40, 3);
            let before = ws.buffer_ptrs();
            let res = run_alternating_loop_ws(
                &x,
                alpha,
                &opts,
                h0,
                &metrics,
                "ws-test".to_string(),
                0.0,
                PhaseTimer::new(),
                &mut ws,
            );
            assert_eq!(res.iters(), 3, "{rule:?}: patience must not fire in 3 iters");
            assert_eq!(
                ws.buffer_ptrs(),
                before,
                "{rule:?}: workspace buffers moved during the hot loop"
            );
            assert!(res.h.is_nonneg());
        }
    }

    /// Acceptance: the engine wrapper is bitwise-identical to the frozen
    /// pre-refactor loop for every update rule — residual history,
    /// factors, iteration count, and label.
    #[test]
    fn engine_path_pinned_bitwise_to_reference() {
        for rule in [UpdateRule::Bpp, UpdateRule::Hals, UpdateRule::Mu] {
            for (m, k) in [(40, 2), (56, 7)] {
                let x = planted(m, k, 0.05, 11);
                let mut opts = SymNmfOptions::new(k).with_rule(rule).with_seed(4);
                opts.max_iters = 12;
                let oracle = symnmf_anls_reference(&x, &opts);
                let engine =
                    symnmf_anls_run(&x, &opts, &RunControl::unlimited(), None, None);
                assert_results_bitwise_eq(
                    &oracle,
                    &engine.result,
                    &format!("anls {rule:?} m={m} k={k}"),
                );
                assert!(engine.completed());
            }
        }
    }

    /// Acceptance: checkpoint → serialize → resume reproduces the
    /// uninterrupted run bitwise at k ∈ {2, 7}.
    #[test]
    fn checkpoint_resume_reproduces_uninterrupted_run() {
        for k in [2usize, 7] {
            let x = planted(8 * k, k, 0.05, 3);
            let mut opts = SymNmfOptions::new(k).with_seed(6);
            opts.max_iters = 10;
            let full = symnmf_anls_run(&x, &opts, &RunControl::unlimited(), None, None);
            let paused = symnmf_anls_run(
                &x,
                &opts,
                &RunControl::unlimited().with_max_steps(3),
                None,
                None,
            );
            assert_eq!(paused.checkpoint.status, RunStatus::Paused);
            assert_eq!(paused.result.iters(), 3);
            let cp = Checkpoint::parse(&paused.checkpoint.serialize()).expect("roundtrip");
            let resumed =
                symnmf_anls_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
            assert!(resumed.completed());
            assert_results_bitwise_eq(&full.result, &resumed.result, &format!("k={k}"));
        }
    }

    /// Acceptance: a deadline of 0 returns the initial iterate without
    /// stepping, and the checkpoint it leaves behind resumes to the full
    /// run bitwise.
    #[test]
    fn deadline_zero_returns_initial_iterate() {
        let x = planted(40, 3, 0.0, 7);
        let mut opts = SymNmfOptions::new(3).with_seed(2);
        opts.max_iters = 8;
        let run = symnmf_anls_run(
            &x,
            &opts,
            &RunControl::unlimited().with_deadline(0.0),
            None,
            None,
        );
        assert_eq!(run.checkpoint.status, RunStatus::Deadline);
        assert!(run.result.records.is_empty(), "no iteration may run");
        // the returned iterate IS the §5 initialization
        let mut rng = Pcg64::seed_from_u64(opts.seed);
        let h0 = initial_factor(&x, &opts, &mut rng);
        for (a, b) in run.result.h.data().iter().zip(h0.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "H must be the initial iterate");
        }
        let full = symnmf_anls_run(&x, &opts, &RunControl::unlimited(), None, None);
        let resumed = symnmf_anls_run(
            &x,
            &opts,
            &RunControl::unlimited(),
            Some(&run.checkpoint),
            None,
        );
        assert_results_bitwise_eq(&full.result, &resumed.result, "deadline-0 resume");
    }

    /// Satellite acceptance: a cancel token set before the first step
    /// returns the initial iterate with a valid checkpoint, and a
    /// mid-run cancel (tripped deterministically by a trace-sink hook)
    /// aborts at the next step boundary — both resume to the
    /// uninterrupted run bitwise.
    #[test]
    fn cancel_token_aborts_and_resumes_bitwise() {
        use crate::symnmf::engine::CancelToken;
        use crate::symnmf::trace::CancelAfterSink;
        let x = planted(40, 3, 0.05, 13);
        let mut opts = SymNmfOptions::new(3).with_seed(8);
        opts.max_iters = 8;
        let full = symnmf_anls_run(&x, &opts, &RunControl::unlimited(), None, None);

        // cancel before the first step
        let tok = CancelToken::new();
        tok.cancel();
        let cancelled = symnmf_anls_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            None,
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.result.iters(), 0, "no step may run");
        let cp = Checkpoint::parse(&cancelled.checkpoint.serialize()).expect("roundtrip");
        let resumed = symnmf_anls_run(&x, &opts, &RunControl::unlimited(), Some(&cp), None);
        assert_results_bitwise_eq(&full.result, &resumed.result, "anls cancel-0 resume");

        // cancel mid-run: the hook fires after the 2nd record, the
        // engine aborts before step 3
        let tok = CancelToken::new();
        let mut hook = CancelAfterSink::new(tok.clone(), 2);
        let cancelled = symnmf_anls_run(
            &x,
            &opts,
            &RunControl::unlimited().with_cancel(tok),
            None,
            Some(&mut hook),
        );
        assert_eq!(cancelled.checkpoint.status, RunStatus::Cancelled);
        assert_eq!(cancelled.result.iters(), 2, "abort at the next step boundary");
        let resumed = symnmf_anls_run(
            &x,
            &opts,
            &RunControl::unlimited(),
            Some(&cancelled.checkpoint),
            None,
        );
        assert_results_bitwise_eq(&full.result, &resumed.result, "anls mid-cancel resume");
    }

    /// The trace sink observes exactly the records that land in the
    /// result, plus the stage label.
    #[test]
    fn trace_sink_streams_the_history() {
        let x = planted(30, 3, 0.1, 5);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 6;
        let mut sink = VecSink::default();
        let run = symnmf_anls_run(
            &x,
            &opts,
            &RunControl::unlimited(),
            None,
            Some(&mut sink),
        );
        assert_eq!(sink.stages, vec!["BPP".to_string()]);
        assert_eq!(sink.records.len(), run.result.iters());
        for (a, b) in sink.records.iter().zip(&run.result.records) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.residual.to_bits(), b.residual.to_bits());
        }
    }

    /// The engine outer loop keeps the zero-allocation contract: the
    /// shared workspace buffers must not move across a multi-iteration
    /// engine run.
    #[test]
    fn engine_workspace_buffers_stable() {
        let x = planted(40, 3, 0.0, 9);
        let mut opts = SymNmfOptions::new(3).with_rule(UpdateRule::Hals).with_seed(1);
        opts.max_iters = 3;
        let mut rng = Pcg64::seed_from_u64(opts.seed);
        let alpha = resolve_alpha(&x, &opts);
        let h0 = initial_factor(&x, &opts, &mut rng);
        let xd: &dyn SymOp = &x;
        let mut spec = SolveSpec {
            stages: vec![Stage {
                engine: Box::new(AltEngine::new(xd, alpha, opts.rule, h0)),
                label: "ws-test".to_string(),
            }],
            metrics: Metrics::new(xd, true),
            setup_secs: 0.0,
            phases: PhaseTimer::new(),
        };
        let mut ws = workspace_for(&spec);
        let before = ws.buffer_ptrs();
        let run = run_solver(
            &mut spec,
            &opts,
            &RunControl::unlimited(),
            None,
            None,
            &mut ws,
        );
        assert_eq!(run.result.iters(), 3, "patience must not fire in 3 iters");
        assert_eq!(ws.buffer_ptrs(), before, "workspace buffers moved in the engine loop");
    }

    #[test]
    fn records_are_monotone_in_time() {
        let x = planted(30, 3, 0.1, 3);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 20;
        let res = symnmf_anls(&x, &opts);
        for w in res.records.windows(2) {
            assert!(w[1].time_secs >= w[0].time_secs);
        }
        assert!(res.iters() <= 20);
    }

    #[test]
    fn stopping_rule_halts_early_on_easy_input() {
        let x = planted(50, 3, 0.0, 4);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 300;
        let res = symnmf_anls(&x, &opts);
        assert!(
            res.iters() < 300,
            "should stop before the cap, took {}",
            res.iters()
        );
    }
}
