//! SymNMF algorithms: the paper's two randomized methods and every
//! baseline they are compared against.
//!
//! * [`anls`] — symmetrically regularized ANLS / HALS / MU (paper §2.1.1,
//!   Eq. 2.3–2.4), the deterministic baseline family.
//! * [`pgncg`] — Projected Gauss–Newton with CG (paper §2.1.3).
//! * [`lai`] — **LAI-SymNMF** (paper §3): SymNMF of a randomized low-rank
//!   approximate input, with Iterative Refinement and Ada-RRF (§3.3), and
//!   LAI-PGNCG (App. B.2).
//! * [`lvs`] — **LvS-SymNMF** (paper §4): leverage-score-sampled NLS
//!   subproblems with hybrid deterministic+random sampling (§4.2).
//! * [`compressed`] — the Compressed-NMF baseline (Tepper & Sapiro [51])
//!   extended to SymNMF (App. B.1).
//!
//! All methods speak [`crate::randnla::SymOp`], share the Update(G, Y)
//! solver toolbox ([`crate::nls`]), the §5 initialization ([`init`]) and
//! the App. C stopping criteria ([`convergence`]); per-iteration metrics
//! land in [`metrics`]. Every method executes as a step-driven
//! [`engine::SolverEngine`] inside the shared resumable outer loop of
//! [`engine`] — wall-clock deadlines, checkpoint/resume, and
//! per-iteration [`engine::TraceSink`] telemetry come from that one loop;
//! the `symnmf_*` entry points are thin wrappers over it, pinned bitwise
//! to the frozen pre-engine reference loops kept in each module.

pub mod anls;
pub mod compressed;
pub mod convergence;
pub mod engine;
pub mod init;
pub mod lai;
pub mod lvs;
pub mod metrics;
pub mod options;
pub mod pgncg;
pub mod trace;

pub use engine::{
    CancelToken, Checkpoint, EngineRun, RunControl, RunStatus, SolverEngine, StepOutcome,
    TraceSink,
};
pub use trace::{CancelAfterSink, CsvSink, JsonlSink, TraceFormat};
pub use metrics::{IterRecord, SymNmfResult};
pub use options::SymNmfOptions;
