//! Sparse graph clustering (paper §5.2, OAG stand-in): LvS-SymNMF with
//! hybrid leverage-score sampling vs pure-random sampling vs the exact
//! method, with the Fig. 3 time breakdown, silhouette scores and
//! topword-style cluster summaries.
//!
//!     cargo run --release --example oag_sparse [-- --m 20000]

use symnmf::clustering::silhouette::cluster_silhouettes;
use symnmf::coordinator::driver::Method;
use symnmf::coordinator::experiments::oag_workload;
use symnmf::coordinator::report;
use symnmf::nls::UpdateRule;
use symnmf::symnmf::options::{SymNmfOptions, Tau};
use symnmf::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let m = args.get_usize("m", 8000);
    println!("== building OAG-substitute SBM graph (m={m}, k=16, skewed) ==");
    let g = oag_workload(m, 1);
    println!(
        "adjacency: {}x{} sparse, {} nnz (avg degree {:.1})",
        g.adj.rows(),
        g.adj.cols(),
        g.adj.nnz(),
        g.adj.nnz() as f64 / m as f64
    );

    let mut opts = SymNmfOptions::new(16).with_seed(2);
    opts.max_iters = args.get_usize("max-iters", 30);

    let methods = [
        Method::Exact(UpdateRule::Hals),
        Method::Lvs { rule: UpdateRule::Hals, tau: Tau::Fixed(1.0) },
        Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS },
        Method::Lvs { rule: UpdateRule::Bpp, tau: Tau::OneOverS },
    ];

    let mut results = Vec::new();
    for method in methods {
        let res = method.run(&g.adj, &opts);
        println!(
            "  {:<20} {:>3} iters  {:>7.2}s  min-res {:.5}",
            res.label,
            res.iters(),
            res.total_secs(),
            res.min_residual()
        );
        results.push(res);
    }

    println!("\n== Fig. 3: per-iteration time breakdown ==");
    let refs: Vec<&symnmf::symnmf::SymNmfResult> = results.iter().collect();
    println!("{}", report::time_breakdown_table(&refs));

    // silhouettes of the hybrid-LvS clustering (§5.2.1)
    let hybrid = &results[2];
    let assign = hybrid.cluster_assignments();
    let (scores, sizes) = cluster_silhouettes(&g.adj, &assign, 16);
    println!("== silhouette scores per cluster ({}) ==", hybrid.label);
    for (c, (s, n)) in scores.iter().zip(&sizes).enumerate() {
        if *n > 0 {
            println!("  cluster {c:>2}: size {n:>7}, silhouette {s:>6.3}");
        }
    }

    // hybrid sampling statistics (Fig. 6)
    std::fs::create_dir_all("results").ok();
    let p = std::path::Path::new("results/oag_hybrid_stats.csv");
    report::write_hybrid_stats_csv(p, hybrid).unwrap();
    println!("\nwrote {p:?} (Fig. 6 series)");
}
