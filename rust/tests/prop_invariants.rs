//! Property-based cross-module invariants (propcheck harness): the
//! coordinator-level guarantees the paper's theory promises, checked on
//! randomized inputs.

use symnmf::linalg::{blas, eig, qr, DenseMat};
use symnmf::nls::{bpp, update, UpdateRule};
use symnmf::randnla::evd::apx_evd;
use symnmf::randnla::leverage::{sample_hybrid, sample_standard, theorem21_sample_count};
use symnmf::randnla::SymOp;
use symnmf::sparse::CsrMat;
use symnmf::symnmf::lai::LaiOp;
use symnmf::util::propcheck::{dim, forall};
use symnmf::util::rng::Pcg64;

/// Theorem 2.1, empirically: with the prescribed sample count, the
/// sampled-NLS solution error obeys ‖x̂ − x‖ ≤ √ε·‖r‖/σ_min(A) with
/// high probability. We run several instances and require the bound to
/// hold in the vast majority (δ = 0.4, generous ε).
#[test]
fn theorem21_error_bound_holds_with_high_probability() {
    let delta = 0.4;
    let eps = 0.5;
    let mut failures = 0;
    let cases = 24;
    for case in 0..cases {
        let mut rng = Pcg64::seed_from_u64(900 + case);
        let k = 4;
        let m = 4000;
        let a = DenseMat::gaussian(m, k, &mut rng);
        // b with substantial residual (not in range(A))
        let x_true: Vec<f64> = (0..k).map(|_| rng.uniform()).collect();
        let mut b: Vec<f64> = (0..m)
            .map(|i| {
                let mut s = 0.0;
                for j in 0..k {
                    s += a.at(i, j) * x_true[j];
                }
                s + rng.gaussian()
            })
            .collect();
        // exact NLS solution via BPP on the normal equations
        let g = blas::gram(&a);
        let y: Vec<f64> = (0..k)
            .map(|j| (0..m).map(|i| a.at(i, j) * b[i]).sum())
            .collect();
        let x_nls = bpp::solve_row(&g, &y, 200);
        // residual norm
        let mut r_norm_sq = 0.0;
        for i in 0..m {
            let mut pred = 0.0;
            for j in 0..k {
                pred += a.at(i, j) * x_nls[j];
            }
            let r = pred - b[i];
            r_norm_sq += r * r;
        }
        let sv = eig::singular_values(&a);
        let sigma_min = *sv.last().unwrap();

        // sampled problem with the Theorem 2.1 count (capped at m)
        let s = theorem21_sample_count(k, delta, eps).min(m);
        let lev = qr::leverage_scores(&a);
        let sm = sample_standard(&lev, s, &mut rng);
        let sa = a.gather_rows_scaled(&sm.indices, &sm.scales);
        let sb: Vec<f64> = sm
            .indices
            .iter()
            .zip(&sm.scales)
            .map(|(&i, &c)| c * b[i])
            .collect();
        let sg = blas::gram(&sa);
        let sy: Vec<f64> = (0..k)
            .map(|j| (0..sa.rows()).map(|i| sa.at(i, j) * sb[i]).sum())
            .collect();
        let x_hat = bpp::solve_row(&sg, &sy, 200);

        let err: f64 = x_hat
            .iter()
            .zip(&x_nls)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let bound = eps.sqrt() * r_norm_sq.sqrt() / sigma_min;
        if err > bound {
            failures += 1;
        }
        b.clear(); // silence unused-mut lint paths
    }
    // δ = 0.4 → expect ≤ ~40% failures; demand < 50% with slack
    assert!(
        failures * 2 < cases,
        "Theorem 2.1 bound violated in {failures}/{cases} cases"
    );
}

/// Lemma 4.2 flavor: hybrid sampling satisfies SC1 at least as well as
/// standard sampling on spiked-leverage inputs, at equal budget.
#[test]
fn hybrid_sc1_at_least_as_good_on_spiked_inputs() {
    let mut wins = 0;
    let trials = 12;
    for t in 0..trials {
        let mut rng = Pcg64::seed_from_u64(1700 + t);
        let mut f = DenseMat::gaussian(600, 4, &mut rng);
        for j in 0..4 {
            f.set(11, j, 60.0 * (j as f64 + 1.0));
            f.set(222, j, -50.0 * (j as f64 + 1.5));
        }
        let (q, _) = qr::householder_qr(&f);
        let lev = qr::leverage_scores_from_q(&q);
        let s = 60;
        let sc1 = |sm: &symnmf::randnla::SampleMatrix| {
            let sq = q.gather_rows_scaled(&sm.indices, &sm.scales);
            blas::gram(&sq).diff_fro(&DenseMat::eye(4))
        };
        let hybrid = sc1(&sample_hybrid(&lev, s, 1.0 / s as f64, &mut rng));
        let standard = sc1(&sample_standard(&lev, s, &mut rng));
        if hybrid <= standard + 1e-9 {
            wins += 1;
        }
    }
    assert!(
        wins * 3 >= trials * 2,
        "hybrid won only {wins}/{trials} SC1 comparisons"
    );
}

/// Backend-agreement property for the write-into kernel dispatch layer:
/// `apply_into` / `sampled_apply_into` must match the allocating paths to
/// 1e-12 across the `DenseMat`, `CsrMat` and `LaiOp` backends on random
/// shapes — with the output buffer pre-filled with garbage, so any
/// backend that forgets to fully overwrite its output fails loudly.
#[test]
fn apply_into_matches_allocating_paths_across_backends() {
    forall(
        12,
        4400,
        |rng| {
            let n = dim(rng, 4, 28);
            let k = dim(rng, 1, 6);
            // random symmetric sparse pattern + matching dense copy
            let mut trips = Vec::new();
            for i in 0..n {
                for j in i..n {
                    if rng.uniform() < 0.4 {
                        let v = rng.uniform();
                        trips.push((i, j, v));
                        if i != j {
                            trips.push((j, i, v));
                        }
                    }
                }
            }
            // guarantee at least one entry so X isn't all-zero
            trips.push((0, 0, 1.0 + rng.uniform()));
            let sp = CsrMat::from_coo(n, n, trips);
            let de = sp.to_dense();
            let f = DenseMat::gaussian(n, k, rng);
            let s = dim(rng, 1, n);
            let samples: Vec<usize> = (0..s).map(|_| rng.below(n)).collect();
            let weights: Vec<f64> = (0..s).map(|_| rng.uniform() + 0.1).collect();
            (sp, de, f, samples, weights)
        },
        |(sp, de, f, samples, weights)| {
            let n = de.rows();
            let k = f.cols();
            let mut out = DenseMat::zeros(n, k);

            // reference: the allocating dense path
            let want_apply = SymOp::apply(de, f);
            let want_sampled = SymOp::sampled_apply(de, f, samples, weights);

            // dense + sparse backends, stale output pre-fill
            out.fill(1e9);
            SymOp::apply_into(de, f, &mut out);
            if out.diff_fro(&want_apply) > 1e-12 {
                return Err("dense apply_into mismatch".into());
            }
            out.fill(-1e9);
            SymOp::apply_into(sp, f, &mut out);
            if out.diff_fro(&want_apply) > 1e-12 {
                return Err("sparse apply_into mismatch".into());
            }
            out.fill(1e9);
            SymOp::sampled_apply_into(de, f, samples, weights, &mut out);
            if out.diff_fro(&want_sampled) > 1e-12 {
                return Err("dense sampled_apply_into mismatch".into());
            }
            out.fill(-1e9);
            SymOp::sampled_apply_into(sp, f, samples, weights, &mut out);
            if out.diff_fro(&want_sampled) > 1e-12 {
                return Err("sparse sampled_apply_into mismatch".into());
            }

            // LAI backend: apply_into must match its own allocating form
            // (U·(VᵀF) via allocating skinny matmuls) exactly
            let mut rng2 = Pcg64::seed_from_u64(7);
            let evd = apx_evd(de, n.min(2 * k + 2), 1, &mut rng2);
            let lai = LaiOp::new(&evd, de);
            let lai_want = blas::matmul(&lai.u, &blas::matmul_tn(&lai.v, f));
            out.fill(1e9);
            SymOp::apply_into(&lai, f, &mut out);
            if out.diff_fro(&lai_want) > 1e-12 {
                return Err("LaiOp apply_into mismatch".into());
            }
            if SymOp::apply(&lai, f).diff_fro(&lai_want) > 1e-12 {
                return Err("LaiOp allocating apply mismatch".into());
            }
            Ok(())
        },
    );
}

/// Blocked-kernel pinning (the PR-2 satellite): the blocked SYMM, the
/// column-tiled SpMM, and the transpose-free HALS sweep must each match
/// their naive/reference counterparts at 1e-12 across every pair of
/// non-multiple-of-block shapes m, k ∈ {1, 3, 31, 33, 65}.
#[test]
fn blocked_kernels_match_references_across_shapes() {
    let shapes = [1usize, 3, 31, 33, 65];
    let mut rng = Pcg64::seed_from_u64(4242);
    for &m in &shapes {
        // symmetric dense X and a matching sparse copy
        let mut xd = DenseMat::gaussian(m, m, &mut rng);
        xd.symmetrize();
        let mut trips = Vec::new();
        for i in 0..m {
            for j in 0..m {
                let v = xd.at(i, j);
                if v != 0.0 {
                    trips.push((i, j, v));
                }
            }
        }
        let xs = CsrMat::from_coo(m, m, trips);
        for &k in &shapes {
            let f = DenseMat::gaussian(m, k, &mut rng);
            let want = blas::matmul(&xd, &f);
            let tol = 1e-12 * (1.0 + want.fro_norm());

            // blocked SYMM (forced multi-block tiling via small blocks)
            for block in [4usize, 32] {
                let mut out = DenseMat::zeros(m, k);
                out.fill(5.0);
                blas::symm_tall_into_blocked(&xd, &f, &mut out, block);
                assert!(
                    out.diff_fro(&want) < tol,
                    "SYMM m={m} k={k} block={block}"
                );
            }

            // tiled SpMM vs the same dense product
            let mut out = DenseMat::zeros(m, k);
            out.fill(-5.0);
            xs.spmm_into(&f, &mut out);
            assert!(out.diff_fro(&want) < tol, "SpMM m={m} k={k}");

            // transpose-free HALS vs the staged-transpose reference
            let mut g = blas::gram(&f);
            g.add_diag(0.9);
            let y = DenseMat::gaussian(m, k, &mut rng);
            let mut w0 = DenseMat::uniform(m, k, 1.0, &mut rng);
            let mut w_ref = w0.clone();
            symnmf::nls::hals::hals_sweep(&g, &y, &mut w0);
            symnmf::nls::hals::hals_sweep_reference(&g, &y, &mut w_ref);
            assert!(
                w0.diff_fro(&w_ref) < 1e-12 * (1.0 + w_ref.fro_norm()),
                "HALS m={m} k={k}"
            );
        }
    }
}

/// Update(G, Y) invariants across random problems: nonnegativity and
/// monotone objective for every rule.
#[test]
fn update_rules_invariants_property() {
    forall(
        12,
        2100,
        |rng| {
            let m = dim(rng, 5, 40);
            let k = dim(rng, 2, 6);
            let u = DenseMat::uniform(m, k, 1.0, rng);
            let x = blas::matmul_nt(&u, &u);
            let h = DenseMat::uniform(m, k, 1.0, rng);
            let w0 = DenseMat::uniform(m, k, 1.0, rng);
            (x, h, w0)
        },
        |(x, h, w0)| {
            let g = blas::gram(h);
            let y = blas::matmul(x, h);
            let obj = |wm: &DenseMat| {
                let rec = blas::matmul_nt(wm, h);
                let mut d = x.clone();
                d.axpy(-1.0, &rec);
                d.fro_norm_sq()
            };
            let before = obj(w0);
            for rule in [UpdateRule::Bpp, UpdateRule::Hals, UpdateRule::Mu] {
                let w = update(rule, &g, &y, w0);
                if !w.is_nonneg() {
                    return Err(format!("{rule:?} produced negatives"));
                }
                let after = obj(&w);
                if after > before + 1e-6 * (1.0 + before) {
                    return Err(format!("{rule:?} increased objective {before} → {after}"));
                }
            }
            Ok(())
        },
    );
}

/// RRF capture property: residual decreases monotonically in sketch
/// width l for fixed q.
#[test]
fn rrf_residual_monotone_in_width() {
    forall(
        8,
        2500,
        |rng| {
            let m = 50 + dim(rng, 0, 30);
            let r = dim(rng, 2, 5);
            let u = DenseMat::gaussian(m, r, rng);
            let mut x = blas::matmul_nt(&u, &u);
            let mut e = DenseMat::gaussian(m, m, rng);
            e.symmetrize();
            x.axpy(0.1, &e);
            x.symmetrize();
            (x, r)
        },
        |(x, r)| {
            let mut rng = Pcg64::seed_from_u64(77);
            let narrow = symnmf::randnla::rrf::rrf(x, *r, 1, &mut rng);
            let wide = symnmf::randnla::rrf::rrf(x, 2 * r + 4, 1, &mut rng);
            let rn = symnmf::randnla::rrf::qb_residual(x, &narrow.q_basis);
            let rw = symnmf::randnla::rrf::qb_residual(x, &wide.q_basis);
            if rw <= rn + 0.05 {
                Ok(())
            } else {
                Err(format!("wider sketch worse: {rn} vs {rw}"))
            }
        },
    );
}
