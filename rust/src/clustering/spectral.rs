//! Spectral clustering baseline (paper §5.1.1, methodology of Ng–Jordan–
//! Weiss [45] as used in [27]): embed vertices with the leading k
//! eigenvectors of the (already symmetrically normalized) adjacency,
//! row-normalize, k-means. Eigenvectors come from the same randomized
//! Apx-EVD used elsewhere in the crate.

use crate::linalg::DenseMat;
use crate::randnla::evd::apx_evd;
use crate::randnla::SymOp;
use crate::util::rng::Pcg64;

/// Spectral clustering into k groups; returns assignments.
pub fn spectral_cluster<X: SymOp>(x: &X, k: usize, rng: &mut Pcg64) -> Vec<usize> {
    // oversampled randomized EVD, then keep the k leading eigenvectors
    let l = (2 * k).min(x.dim());
    let evd = apx_evd(x, l, 2, rng);
    let m = x.dim();
    let mut embed = DenseMat::zeros(m, k);
    for i in 0..m {
        for j in 0..k {
            embed.set(i, j, evd.u.at(i, j));
        }
    }
    // row-normalize (NJW step)
    for i in 0..m {
        let row = embed.row_mut(i);
        let norm: f64 = row.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm > 1e-300 {
            for v in row {
                *v /= norm;
            }
        }
    }
    crate::clustering::kmeans::kmeans_restarts(&embed, k, 100, 5, rng).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::ari::adjusted_rand_index;
    use crate::sparse::CsrMat;

    #[test]
    fn recovers_planted_blocks() {
        let mut rng = Pcg64::seed_from_u64(1);
        let m = 90;
        let k = 3;
        let bs = m / k;
        let mut trips = Vec::new();
        for i in 0..m {
            for j in (i + 1)..m {
                let p = if i / bs == j / bs { 0.5 } else { 0.02 };
                if rng.uniform() < p {
                    trips.push((i, j, 1.0));
                    trips.push((j, i, 1.0));
                }
            }
        }
        let mut a = CsrMat::from_coo(m, m, trips);
        crate::sparse::sym::normalize_sym(&mut a);
        let assign = spectral_cluster(&a, k, &mut rng);
        let truth: Vec<usize> = (0..m).map(|i| i / bs).collect();
        let ari = adjusted_rand_index(&assign, &truth);
        assert!(ari > 0.8, "ari={ari}");
    }
}
