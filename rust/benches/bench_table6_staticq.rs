//! Regenerates paper **Figure 5 + Table 6** (App. G.1): the same WoS
//! suite but with a STATIC q=2 power-iteration count instead of Ada-RRF.
//!
//! Shape to reproduce: without Ada-RRF the plain randomized variants land
//! on worse residual/ARI; IR repairs quality at extra cost; Ada-RRF
//! (Table 2) dominates the static choice overall.
//!
//!     cargo bench --bench bench_table6_staticq
//! writes results/table6.txt

use symnmf::coordinator::driver::run_trials;
use symnmf::coordinator::experiments::{fig1_table2_methods, static_q_options, wos_workload};
use symnmf::coordinator::report;

fn main() {
    let docs = std::env::var("SYMNMF_BENCH_DOCS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let trials = 2;
    println!("== Table 6 bench: static q=2 (no Ada-RRF) on WoS ({docs} docs) ==");
    let w = wos_workload(docs, 1);
    let mut opts = static_q_options().with_seed(60);
    opts.max_iters = 150;

    let mut all = Vec::new();
    for method in fig1_table2_methods() {
        let stats = run_trials(method, &w.adjacency, &opts, Some(&w.labels), trials);
        println!(
            "  {:<14} {:7.3}s  min-res {:.4}  ARI {:.3}",
            stats.label, stats.mean_time, stats.min_res, stats.mean_ari
        );
        all.push(stats);
    }
    let table = report::stats_table(&all);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table6.txt", &table).unwrap();
    println!("\n{table}\nwrote results/table6.txt (compare against results/table2.txt)");
}
