//! Empirically verifies the paper's theory section (§4.3): **Theorem
//! 2.1** (leverage-score sampling for NLS) and the **Lemma 4.2/4.3**
//! hybrid-sampling sample-complexity claim.
//!
//! For ensembles of random overdetermined NLS problems it reports, per
//! sample budget s: the fraction of instances where the error bound
//! ‖x̂−x‖ ≤ √ε‖r‖/σ_min(A) holds (must exceed 1−δ), and the hybrid-vs-
//! standard SC1 deviation on coherent designs (hybrid needs only
//! s_D + ξφ samples vs kφ — Lemma discussion).
//!
//!     cargo bench --bench bench_nls_theory
//! writes results/thm21.txt

use symnmf::linalg::{blas, eig, qr, DenseMat};
use symnmf::nls::bpp;
use symnmf::randnla::leverage::{
    sample_hybrid, sample_standard, theorem21_sample_count,
};
use symnmf::util::rng::Pcg64;

fn solve_nls(a: &DenseMat, b: &[f64]) -> Vec<f64> {
    let g = blas::gram(a);
    let k = a.cols();
    let y: Vec<f64> = (0..k)
        .map(|j| (0..a.rows()).map(|i| a.at(i, j) * b[i]).sum())
        .collect();
    bpp::solve_row(&g, &y, 300)
}

fn main() {
    let mut out = String::new();
    let (m, k) = (8_000, 6);
    let (delta, eps) = (0.2, 0.5);
    let s_star = theorem21_sample_count(k, delta, eps).min(m);
    out.push_str(&format!(
        "Theorem 2.1 verification: A {m}x{k}, δ={delta}, ε={eps} → s* = {s_star}\n\
         bound: ‖x̂−x‖ ≤ √ε·‖r‖/σ_min(A)\n\n  s      hold-rate  median-err/bound\n"
    ));

    let instances = 20;
    for s in [k * 10, k * 40, k * 160, s_star] {
        let mut holds = 0;
        let mut ratios = Vec::new();
        for inst in 0..instances {
            let mut rng = Pcg64::seed_from_u64(5000 + inst);
            let a = DenseMat::gaussian(m, k, &mut rng);
            let x_true: Vec<f64> = (0..k).map(|_| rng.uniform()).collect();
            let b: Vec<f64> = (0..m)
                .map(|i| {
                    let mut acc = 0.0;
                    for j in 0..k {
                        acc += a.at(i, j) * x_true[j];
                    }
                    acc + rng.gaussian()
                })
                .collect();
            let x_nls = solve_nls(&a, &b);
            let mut r_sq = 0.0;
            for i in 0..m {
                let mut p = 0.0;
                for j in 0..k {
                    p += a.at(i, j) * x_nls[j];
                }
                r_sq += (p - b[i]) * (p - b[i]);
            }
            let sigma_min = *eig::singular_values(&a).last().unwrap();
            let bound = eps.sqrt() * r_sq.sqrt() / sigma_min;

            let lev = qr::leverage_scores(&a);
            let sm = sample_standard(&lev, s, &mut rng);
            let sa = a.gather_rows_scaled(&sm.indices, &sm.scales);
            let sb: Vec<f64> = sm
                .indices
                .iter()
                .zip(&sm.scales)
                .map(|(&i, &c)| c * b[i])
                .collect();
            let x_hat = solve_nls(&sa, &sb);
            let err: f64 = x_hat
                .iter()
                .zip(&x_nls)
                .map(|(p, q)| (p - q) * (p - q))
                .sum::<f64>()
                .sqrt();
            if err <= bound {
                holds += 1;
            }
            ratios.push(err / bound);
        }
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.push_str(&format!(
            "  {:<6} {:>6.2}    {:>8.4}\n",
            s,
            holds as f64 / instances as f64,
            ratios[instances as usize / 2]
        ));
    }

    // --- hybrid vs standard SC1 on coherent designs (Lemma 4.2) ---------
    out.push_str("\nHybrid vs standard SC1 deviation ‖(SQ)ᵀSQ − I‖ on spiked designs:\n");
    out.push_str("  s      standard   hybrid(τ=1/s)\n");
    for s in [40usize, 80, 160, 320] {
        let mut dev_std = Vec::new();
        let mut dev_hyb = Vec::new();
        for t in 0..10 {
            let mut rng = Pcg64::seed_from_u64(9000 + t);
            let mut f = DenseMat::gaussian(3_000, 4, &mut rng);
            for j in 0..4 {
                f.set(100, j, 80.0 * (j as f64 + 1.0));
                f.set(2000, j, -65.0 * (j as f64 + 0.7));
            }
            let (q, _) = qr::householder_qr(&f);
            let lev = qr::leverage_scores_from_q(&q);
            for (devs, hybrid) in [(&mut dev_std, false), (&mut dev_hyb, true)] {
                let sm = if hybrid {
                    sample_hybrid(&lev, s, 1.0 / s as f64, &mut rng)
                } else {
                    sample_standard(&lev, s, &mut rng)
                };
                let sq = q.gather_rows_scaled(&sm.indices, &sm.scales);
                devs.push(blas::gram(&sq).diff_fro(&DenseMat::eye(4)));
            }
        }
        let med = |v: &mut Vec<f64>| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        out.push_str(&format!(
            "  {:<6} {:>8.4}   {:>8.4}\n",
            s,
            med(&mut dev_std),
            med(&mut dev_hyb)
        ));
    }

    println!("{out}");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/thm21.txt", &out).unwrap();
    println!("wrote results/thm21.txt");
}
