//! Runtime-dispatched SIMD kernel tiers over the scalar oracles in
//! [`crate::linalg::blas`].
//!
//! **Dispatch model.** The active instruction set is selected exactly
//! once per process ([`active`]): detection prefers AVX-512F, then
//! AVX2+FMA on x86_64, NEON on aarch64, and falls back to scalar
//! everywhere else. `SYMNMF_KERNEL=scalar|avx2|avx512|neon|auto`
//! overrides detection; forcing an ISA the host cannot execute (or a
//! name the dispatcher does not know) panics rather than silently
//! degrading, mirroring the fail-loud policy of the engine's
//! `RunControl` env parsing. Because the choice is process-wide and
//! immutable, a fixed dispatch is bitwise-reproducible run-to-run — the
//! property the checkpoint layer records (`Checkpoint.isa`) so a resume
//! on different hardware can force the original kernel instead of
//! silently breaking the bitwise-resume guarantee.
//!
//! **Two numeric tiers.** Every dispatched routine belongs to one of:
//!
//! * **bitwise tier** ([`dot`], [`axpy`], [`widening_axpy_f32`]): the
//!   SIMD body reproduces the scalar oracle's FP operation order
//!   exactly — multiplies and adds stay separate (no FMA contraction),
//!   vector lanes mirror the scalar code's 4-way unrolled accumulators
//!   (`acc0..acc3`), and the horizontal reduction applies the same
//!   left-associated `((l0+l1)+l2)+l3` sum the scalar path uses. These
//!   variants are pinned **bitwise** against the oracle, so routines
//!   whose cross-path tests demand exact equality (Cholesky/QR/eig
//!   pivoting, the HALS reference pins, trace reproducibility) can run
//!   vectorized without perturbing a single bit.
//! * **FMA tier** ([`dot_fma`], [`axpy_fma`], [`packed_nt_rows_isa`]):
//!   fused multiply-add contracts each `acc += x*b` step to one rounding
//!   instead of two. Per output element the accumulation stays
//!   t-sequential (lane `jj` of the NT tile only ever accumulates column
//!   `jj`), so the drift per step is at most one ulp of the running sum
//!   — well inside the 1e-12 relative pin the parity suite enforces at
//!   every masked-edge shape. FMA-tier kernels back the throughput
//!   paths: the packed NT microkernel (widened 2×8 → 4×8 on AVX2,
//!   4×8-on-one-register on AVX-512F), the blocked SYMM tile product,
//!   `gram_into`, and the HALS row update.
//!
//! **f32 compute tier.** The sketched pipelines (Compressed, LAI) can
//! opt into `SYMNMF_PRECISION=f32` ([`Precision`]): sketch operands are
//! staged as f32 and the inner GEMMs run f32 multiplies — halving memory
//! traffic and doubling SIMD lanes — while every accumulation and all
//! residual/stop-rule evaluation stays f64. [`widening_axpy_f32`]
//! implements the policy kernel: `y[j] += f64(alpha_32 * x_32[j])`, an
//! f32 product widened exactly to f64 before the f64 add. The widening
//! is exact and element-independent, so the SIMD variant is bitwise
//! equal to the scalar one — precision loss comes only from the f32
//! product itself, which the driver-level residual-gap test bounds.

use crate::linalg::blas;
use crate::linalg::DenseMat;
use crate::util::threadpool::{parallel_for_chunks, SendPtr};
use std::sync::OnceLock;

/// An instruction-set tier the kernel dispatcher can select.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelIsa {
    /// Portable scalar Rust — the correctness oracle, always supported.
    Scalar,
    /// x86_64 AVX2 + FMA (256-bit vectors, fused multiply-add).
    Avx2,
    /// x86_64 AVX-512F (512-bit vectors, masked stores).
    Avx512,
    /// aarch64 Advanced SIMD (128-bit vectors).
    Neon,
}

impl KernelIsa {
    /// Stable lowercase name — the `SYMNMF_KERNEL` vocabulary, and the
    /// string recorded in checkpoints, traces and bench headers.
    pub fn as_str(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
            KernelIsa::Avx512 => "avx512",
            KernelIsa::Neon => "neon",
        }
    }

    /// Inverse of [`as_str`](Self::as_str) (case-insensitive). `None`
    /// for names outside the dispatch vocabulary.
    pub fn parse(s: &str) -> Option<KernelIsa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelIsa::Scalar),
            "avx2" => Some(KernelIsa::Avx2),
            "avx512" => Some(KernelIsa::Avx512),
            "neon" => Some(KernelIsa::Neon),
            _ => None,
        }
    }

    /// Can the current host execute this tier's instructions?
    pub fn is_supported(self) -> bool {
        match self {
            KernelIsa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelIsa::Avx2 => {
                is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
            }
            #[cfg(target_arch = "x86_64")]
            KernelIsa::Avx512 => is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            KernelIsa::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }
}

/// The best tier the host supports: AVX-512F > AVX2+FMA > NEON > scalar.
pub fn detect() -> KernelIsa {
    for isa in [KernelIsa::Avx512, KernelIsa::Avx2, KernelIsa::Neon] {
        if isa.is_supported() {
            return isa;
        }
    }
    KernelIsa::Scalar
}

/// Every tier the host supports, best first, scalar always last — the
/// iteration domain of the scalar-vs-SIMD parity suite.
pub fn supported() -> Vec<KernelIsa> {
    let mut out = Vec::new();
    for isa in [KernelIsa::Avx512, KernelIsa::Avx2, KernelIsa::Neon] {
        if isa.is_supported() {
            out.push(isa);
        }
    }
    out.push(KernelIsa::Scalar);
    out
}

/// Resolve an optional `SYMNMF_KERNEL` override to a usable tier.
/// Unset, empty, or `auto` → [`detect`]; a known-but-unsupported name or
/// an unknown name panics (fail-loud: a forced kernel that silently fell
/// back would break the bitwise-resume contract it exists to protect).
pub fn resolve(forced: Option<&str>) -> KernelIsa {
    let raw = forced.map(str::trim).unwrap_or("");
    if raw.is_empty() || raw.eq_ignore_ascii_case("auto") {
        return detect();
    }
    match KernelIsa::parse(raw) {
        Some(isa) if isa.is_supported() => isa,
        Some(isa) => {
            let avail: Vec<&str> = supported().iter().map(|i| i.as_str()).collect();
            panic!(
                "SYMNMF_KERNEL={}: {} is not supported on this host \
                 (supported: {})",
                raw,
                isa.as_str(),
                avail.join(", ")
            );
        }
        None => panic!(
            "SYMNMF_KERNEL={raw}: expected scalar|avx2|avx512|neon|auto"
        ),
    }
}

static ACTIVE: OnceLock<KernelIsa> = OnceLock::new();

/// The process-wide dispatch choice, selected once on first use from
/// `SYMNMF_KERNEL` (or feature detection when unset). Immutable for the
/// process lifetime, so a fixed environment gives bitwise-reproducible
/// kernels run-to-run.
pub fn active() -> KernelIsa {
    *ACTIVE.get_or_init(|| {
        let forced = std::env::var("SYMNMF_KERNEL").ok();
        resolve(forced.as_deref())
    })
}

/// Best-effort host name for bench/baseline provenance (`HOSTNAME` env,
/// then the kernel's hostname file, then `"unknown"`). Never fails.
pub fn hostname() -> String {
    if let Ok(h) = std::env::var("HOSTNAME") {
        let t = h.trim();
        if !t.is_empty() {
            return t.to_string();
        }
    }
    if let Ok(h) = std::fs::read_to_string("/proc/sys/kernel/hostname") {
        let t = h.trim();
        if !t.is_empty() {
            return t.to_string();
        }
    }
    "unknown".to_string()
}

/// Compute precision of the sketched pipelines' inner GEMMs (see the
/// module header's f32 tier). Accumulation and residual evaluation are
/// f64 under both settings; `F32` changes only the staged operand
/// storage and the per-element product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F64,
    F32,
}

impl Precision {
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    pub fn parse(s: &str) -> Option<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f64" => Some(Precision::F64),
            "f32" => Some(Precision::F32),
            _ => None,
        }
    }

    /// Read `SYMNMF_PRECISION` (unset/empty → `F64`); panics on values
    /// outside `f64|f32`, mirroring the fail-loud env policy.
    pub fn from_env() -> Precision {
        match std::env::var("SYMNMF_PRECISION") {
            Err(_) => Precision::F64,
            Ok(raw) => {
                let t = raw.trim();
                if t.is_empty() {
                    return Precision::F64;
                }
                Precision::parse(t).unwrap_or_else(|| {
                    panic!("SYMNMF_PRECISION={t}: expected f64|f32")
                })
            }
        }
    }
}

// ---------------------------------------------------------------------
// Bitwise tier: SIMD bodies that reproduce the scalar FP order exactly.
// ---------------------------------------------------------------------

/// Dispatched dot product — **bitwise-equal** to [`blas::dot`] on every
/// tier (see module header). `isa` must come from [`supported`] /
/// [`active`] / [`resolve`].
#[inline]
pub fn dot(isa: KernelIsa, x: &[f64], y: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa, KernelIsa::Avx2 | KernelIsa::Avx512) {
        // AVX-512 routes to the 256-bit body on purpose: the 4-lane
        // grouping is what makes the reduction bitwise-equal to scalar.
        // SAFETY: `isa` is supported on this host by the caller contract,
        // and avx512f implies avx2.
        return unsafe { x86::dot_avx2(x, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: as above.
        return unsafe { neon::dot_neon(x, y) };
    }
    let _ = isa;
    blas::dot(x, y)
}

/// Dispatched axpy — **bitwise-equal** to [`blas::axpy`] on every tier
/// (element-independent mul+add; no reduction to reorder).
#[inline]
pub fn axpy(isa: KernelIsa, alpha: f64, x: &[f64], y: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa, KernelIsa::Avx2 | KernelIsa::Avx512) {
        // SAFETY: caller contract as in [`dot`].
        return unsafe { x86::axpy_avx2(alpha, x, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: as above.
        return unsafe { neon::axpy_neon(alpha, x, y) };
    }
    let _ = isa;
    blas::axpy(alpha, x, y)
}

/// Dispatched scaled copy `out[j] = alpha * x[j]` — the fused
/// scaled-gather kernel behind `DenseMat::gather_rows_scaled_into` (the
/// S·F row rescale of Eq. 2.11). A single element-independent multiply,
/// so every SIMD variant is **bitwise-equal** to the scalar body.
#[inline]
pub fn scale_into(isa: KernelIsa, alpha: f64, x: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    if matches!(isa, KernelIsa::Avx2 | KernelIsa::Avx512) {
        // SAFETY: caller contract as in [`dot`].
        return unsafe { x86::scale_into_avx2(alpha, x, out) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: as above.
        return unsafe { neon::scale_into_neon(alpha, x, out) };
    }
    let _ = isa;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = alpha * v;
    }
}

/// The f32-tier policy kernel: `y[j] += f64(alpha * x[j])` — f32
/// product, exact widening, f64 accumulate. Element-independent, so the
/// SIMD variants are **bitwise-equal** to the scalar body.
#[inline]
pub fn widening_axpy_f32(isa: KernelIsa, alpha: f32, x: &[f32], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if matches!(isa, KernelIsa::Avx2 | KernelIsa::Avx512) {
        // SAFETY: caller contract as in [`dot`].
        return unsafe { x86::widening_axpy_f32_avx2(alpha, x, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: as above.
        return unsafe { neon::widening_axpy_f32_neon(alpha, x, y) };
    }
    let _ = isa;
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi += f64::from(alpha * *xi);
    }
}

// ---------------------------------------------------------------------
// FMA tier: contracted multiply-adds, pinned to scalar at 1e-12.
// ---------------------------------------------------------------------

/// Dispatched dot product on the FMA tier (one rounding per step;
/// 1e-12-pinned against [`blas::dot`], not bitwise). Backs the HALS row
/// update.
#[inline]
pub fn dot_fma(isa: KernelIsa, x: &[f64], y: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa, KernelIsa::Avx2 | KernelIsa::Avx512) {
        // SAFETY: caller contract as in [`dot`].
        return unsafe { x86::dot_fma_avx2(x, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: as above.
        return unsafe { neon::dot_fma_neon(x, y) };
    }
    let _ = isa;
    blas::dot(x, y)
}

/// Dispatched axpy on the FMA tier (1e-12-pinned against
/// [`blas::axpy`]). Backs the SYMM tile product and `gram_into`.
#[inline]
pub fn axpy_fma(isa: KernelIsa, alpha: f64, x: &[f64], y: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa, KernelIsa::Avx2 | KernelIsa::Avx512) {
        // SAFETY: caller contract as in [`dot`].
        return unsafe { x86::axpy_fma_avx2(alpha, x, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: as above.
        return unsafe { neon::axpy_fma_neon(alpha, x, y) };
    }
    let _ = isa;
    blas::axpy(alpha, x, y)
}

/// Dispatched packed NT microkernel: writes C rows `[lo, hi)` of
/// C = A·B̃ᵀ over the tile-major panels of `blas::pack_bt_panels`/
/// `pack_b_panels`. The scalar tier is the untouched 2×8 oracle
/// [`blas::packed_nt_rows`]; AVX2 widens to a 4×8 FMA tile, AVX-512F
/// keeps 4×8 with one 512-bit register per row and a masked edge store,
/// NEON runs 2×8 on 128-bit FMA lanes. Per output element the
/// accumulation is t-sequential on every tier, so each variant is
/// 1e-12-pinned against the oracle at all masked-edge shapes.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn packed_nt_rows_isa(
    isa: KernelIsa,
    a: &[f64],
    p: usize,
    panels: &[f64],
    n: usize,
    lo: usize,
    hi: usize,
    cptr: SendPtr,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if isa == KernelIsa::Avx512 {
            // SAFETY: caller contract as in [`dot`]; row ranges [lo, hi)
            // are disjoint across workers (same contract as the oracle).
            return unsafe { x86::packed_nt_rows_avx512(a, p, panels, n, lo, hi, cptr) };
        }
        if isa == KernelIsa::Avx2 {
            // SAFETY: as above.
            return unsafe { x86::packed_nt_rows_avx2(a, p, panels, n, lo, hi, cptr) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: as above.
        return unsafe { neon::packed_nt_rows_neon(a, p, panels, n, lo, hi, cptr) };
    }
    let _ = isa;
    blas::packed_nt_rows(a, p, panels, n, lo, hi, cptr);
}

// ---------------------------------------------------------------------
// f32 compute tier: staged-operand GEMMs with f64 accumulation.
// ---------------------------------------------------------------------

/// C = A·B where both operands are staged f32 (A: m×p, B: p×n, both
/// row-major) and C accumulates in f64 — the compressed pipeline's
/// `B̂ᵀ·(QᵀH)` product under `SYMNMF_PRECISION=f32`. Row-parallel like
/// [`blas::matmul_into`]; every per-element step is the
/// [`widening_axpy_f32`] policy kernel, so results are identical across
/// ISAs and deterministic at any thread budget (row-disjoint writes).
/// The fan-out runs on the shared persistent pool (see [`crate::util::pool`]).
pub fn matmul_f32_into(
    isa: KernelIsa,
    a: &[f32],
    m: usize,
    p: usize,
    b: &[f32],
    n: usize,
    c: &mut DenseMat,
) {
    assert_eq!(a.len(), m * p, "matmul_f32: A must be {m}x{p}");
    assert_eq!(b.len(), p * n, "matmul_f32: B must be {p}x{n}");
    assert_eq!(c.shape(), (m, n), "matmul_f32: output must be {m}x{n}");
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    parallel_for_chunks(m, 64, move |lo, hi| {
        for i in lo..hi {
            let arow = &a[i * p..(i + 1) * p];
            // SAFETY: rows [lo, hi) are disjoint across workers.
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
            crow.fill(0.0);
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                widening_axpy_f32(isa, aik, &b[kk * n..(kk + 1) * n], crow);
            }
        }
    });
}

/// C = Aᵀ·B with staged f32 operands (A: m×p, B: m×n row-major → C: p×n
/// f64) — the compressed pipeline's `QᵀH` sketch product under
/// `SYMNMF_PRECISION=f32`. Serial row-streaming like
/// [`blas::matmul_tn_into`]; per-element steps go through
/// [`widening_axpy_f32`].
pub fn matmul_tn_f32_into(
    isa: KernelIsa,
    a: &[f32],
    m: usize,
    p: usize,
    b: &[f32],
    n: usize,
    c: &mut DenseMat,
) {
    assert_eq!(a.len(), m * p, "matmul_tn_f32: A must be {m}x{p}");
    assert_eq!(b.len(), m * n, "matmul_tn_f32: B must be {m}x{n}");
    assert_eq!(c.shape(), (p, n), "matmul_tn_f32: output must be {p}x{n}");
    let cdata = c.data_mut();
    cdata.fill(0.0);
    for i in 0..m {
        let arow = &a[i * p..(i + 1) * p];
        let brow = &b[i * n..(i + 1) * n];
        for (t, &ait) in arow.iter().enumerate() {
            if ait == 0.0 {
                continue;
            }
            widening_axpy_f32(isa, ait, brow, &mut cdata[t * n..(t + 1) * n]);
        }
    }
}

// ---------------------------------------------------------------------
// x86_64 bodies.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use crate::linalg::blas::NR;
    use crate::util::threadpool::SendPtr;
    use std::arch::x86_64::*;

    /// Bitwise-equal AVX2 dot: one 256-bit accumulator whose four lanes
    /// reproduce the scalar body's `acc0..acc3` exactly (separate mul
    /// and add — FMA contraction would change the rounding), reduced in
    /// the scalar order `((l0+l1)+l2)+l3`, identical sequential tail.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4 * 4;
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc = _mm256_setzero_pd();
        let mut t = 0;
        while t < chunks {
            let xv = _mm256_loadu_pd(xp.add(t));
            let yv = _mm256_loadu_pd(yp.add(t));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(xv, yv));
            t += 4;
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
        for j in chunks..n {
            s += x[j] * y[j];
        }
        s
    }

    /// Bitwise-equal AVX2 axpy (element-independent mul+add).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4 * 4;
        let av = _mm256_set1_pd(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut t = 0;
        while t < chunks {
            let xv = _mm256_loadu_pd(xp.add(t));
            let yv = _mm256_loadu_pd(yp.add(t));
            _mm256_storeu_pd(yp.add(t), _mm256_add_pd(yv, _mm256_mul_pd(av, xv)));
            t += 4;
        }
        for j in chunks..n {
            y[j] += alpha * x[j];
        }
    }

    /// Bitwise-equal AVX2 scaled copy (element-independent multiply).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_into_avx2(alpha: f64, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), out.len());
        let n = x.len();
        let chunks = n / 4 * 4;
        let av = _mm256_set1_pd(alpha);
        let xp = x.as_ptr();
        let op = out.as_mut_ptr();
        let mut t = 0;
        while t < chunks {
            let xv = _mm256_loadu_pd(xp.add(t));
            _mm256_storeu_pd(op.add(t), _mm256_mul_pd(av, xv));
            t += 4;
        }
        for j in chunks..n {
            out[j] = alpha * x[j];
        }
    }

    /// FMA-tier dot (contracted steps; 1e-12-pinned, not bitwise).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot_fma_avx2(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4 * 4;
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc = _mm256_setzero_pd();
        let mut t = 0;
        while t < chunks {
            let xv = _mm256_loadu_pd(xp.add(t));
            let yv = _mm256_loadu_pd(yp.add(t));
            acc = _mm256_fmadd_pd(xv, yv, acc);
            t += 4;
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
        for j in chunks..n {
            s += x[j] * y[j];
        }
        s
    }

    /// FMA-tier axpy (contracted steps; 1e-12-pinned, not bitwise).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn axpy_fma_avx2(alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4 * 4;
        let av = _mm256_set1_pd(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut t = 0;
        while t < chunks {
            let xv = _mm256_loadu_pd(xp.add(t));
            let yv = _mm256_loadu_pd(yp.add(t));
            _mm256_storeu_pd(yp.add(t), _mm256_fmadd_pd(av, xv, yv));
            t += 4;
        }
        for j in chunks..n {
            y[j] += alpha * x[j];
        }
    }

    /// Masked tile store: full 8-wide store on interior panels, staged
    /// through a stack buffer on the edge panel (w < 8) — the SIMD
    /// version of the oracle's `copy_from_slice(&acc[..w])`.
    #[target_feature(enable = "avx2")]
    unsafe fn store_masked_256(dst: *mut f64, w: usize, lo: __m256d, hi: __m256d) {
        if w == NR {
            _mm256_storeu_pd(dst, lo);
            _mm256_storeu_pd(dst.add(4), hi);
        } else {
            let mut buf = [0.0f64; NR];
            _mm256_storeu_pd(buf.as_mut_ptr(), lo);
            _mm256_storeu_pd(buf.as_mut_ptr().add(4), hi);
            std::ptr::copy_nonoverlapping(buf.as_ptr(), dst, w);
        }
    }

    /// AVX2+FMA packed NT microkernel, widened to a 4×8 tile: four A
    /// rows against one panel, eight 256-bit accumulators; each
    /// reduction step is two contiguous panel loads, four broadcasts and
    /// eight FMAs. 2-row and 1-row tails mirror the oracle's structure.
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn packed_nt_rows_avx2(
        a: &[f64],
        p: usize,
        panels: &[f64],
        n: usize,
        lo: usize,
        hi: usize,
        cptr: SendPtr,
    ) {
        let np = n.div_ceil(NR);
        let mut i = lo;
        while i + 4 <= hi {
            let a0 = a[i * p..(i + 1) * p].as_ptr();
            let a1 = a[(i + 1) * p..(i + 2) * p].as_ptr();
            let a2 = a[(i + 2) * p..(i + 3) * p].as_ptr();
            let a3 = a[(i + 3) * p..(i + 4) * p].as_ptr();
            for jp in 0..np {
                let j0 = jp * NR;
                let w = (n - j0).min(NR);
                let pb = panels[jp * NR * p..(jp + 1) * NR * p].as_ptr();
                let mut c0l = _mm256_setzero_pd();
                let mut c0h = _mm256_setzero_pd();
                let mut c1l = _mm256_setzero_pd();
                let mut c1h = _mm256_setzero_pd();
                let mut c2l = _mm256_setzero_pd();
                let mut c2h = _mm256_setzero_pd();
                let mut c3l = _mm256_setzero_pd();
                let mut c3h = _mm256_setzero_pd();
                for t in 0..p {
                    let bl = _mm256_loadu_pd(pb.add(t * NR));
                    let bh = _mm256_loadu_pd(pb.add(t * NR + 4));
                    let x0 = _mm256_set1_pd(*a0.add(t));
                    c0l = _mm256_fmadd_pd(x0, bl, c0l);
                    c0h = _mm256_fmadd_pd(x0, bh, c0h);
                    let x1 = _mm256_set1_pd(*a1.add(t));
                    c1l = _mm256_fmadd_pd(x1, bl, c1l);
                    c1h = _mm256_fmadd_pd(x1, bh, c1h);
                    let x2 = _mm256_set1_pd(*a2.add(t));
                    c2l = _mm256_fmadd_pd(x2, bl, c2l);
                    c2h = _mm256_fmadd_pd(x2, bh, c2h);
                    let x3 = _mm256_set1_pd(*a3.add(t));
                    c3l = _mm256_fmadd_pd(x3, bl, c3l);
                    c3h = _mm256_fmadd_pd(x3, bh, c3h);
                }
                // SAFETY: rows [lo, hi) are disjoint across workers.
                store_masked_256(cptr.0.add(i * n + j0), w, c0l, c0h);
                store_masked_256(cptr.0.add((i + 1) * n + j0), w, c1l, c1h);
                store_masked_256(cptr.0.add((i + 2) * n + j0), w, c2l, c2h);
                store_masked_256(cptr.0.add((i + 3) * n + j0), w, c3l, c3h);
            }
            i += 4;
        }
        while i + 2 <= hi {
            let a0 = a[i * p..(i + 1) * p].as_ptr();
            let a1 = a[(i + 1) * p..(i + 2) * p].as_ptr();
            for jp in 0..np {
                let j0 = jp * NR;
                let w = (n - j0).min(NR);
                let pb = panels[jp * NR * p..(jp + 1) * NR * p].as_ptr();
                let mut c0l = _mm256_setzero_pd();
                let mut c0h = _mm256_setzero_pd();
                let mut c1l = _mm256_setzero_pd();
                let mut c1h = _mm256_setzero_pd();
                for t in 0..p {
                    let bl = _mm256_loadu_pd(pb.add(t * NR));
                    let bh = _mm256_loadu_pd(pb.add(t * NR + 4));
                    let x0 = _mm256_set1_pd(*a0.add(t));
                    c0l = _mm256_fmadd_pd(x0, bl, c0l);
                    c0h = _mm256_fmadd_pd(x0, bh, c0h);
                    let x1 = _mm256_set1_pd(*a1.add(t));
                    c1l = _mm256_fmadd_pd(x1, bl, c1l);
                    c1h = _mm256_fmadd_pd(x1, bh, c1h);
                }
                store_masked_256(cptr.0.add(i * n + j0), w, c0l, c0h);
                store_masked_256(cptr.0.add((i + 1) * n + j0), w, c1l, c1h);
            }
            i += 2;
        }
        if i < hi {
            let a0 = a[i * p..(i + 1) * p].as_ptr();
            for jp in 0..np {
                let j0 = jp * NR;
                let w = (n - j0).min(NR);
                let pb = panels[jp * NR * p..(jp + 1) * NR * p].as_ptr();
                let mut cl = _mm256_setzero_pd();
                let mut ch = _mm256_setzero_pd();
                for t in 0..p {
                    let bl = _mm256_loadu_pd(pb.add(t * NR));
                    let bh = _mm256_loadu_pd(pb.add(t * NR + 4));
                    let x0 = _mm256_set1_pd(*a0.add(t));
                    cl = _mm256_fmadd_pd(x0, bl, cl);
                    ch = _mm256_fmadd_pd(x0, bh, ch);
                }
                store_masked_256(cptr.0.add(i * n + j0), w, cl, ch);
            }
        }
    }

    /// AVX-512F packed NT microkernel: 4×8 tile with one 512-bit
    /// accumulator per row; the masked edge store is a single
    /// `_mm512_mask_storeu_pd` with the low-w bitmask.
    #[target_feature(enable = "avx512f")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn packed_nt_rows_avx512(
        a: &[f64],
        p: usize,
        panels: &[f64],
        n: usize,
        lo: usize,
        hi: usize,
        cptr: SendPtr,
    ) {
        let np = n.div_ceil(NR);
        let mut i = lo;
        while i + 4 <= hi {
            let a0 = a[i * p..(i + 1) * p].as_ptr();
            let a1 = a[(i + 1) * p..(i + 2) * p].as_ptr();
            let a2 = a[(i + 2) * p..(i + 3) * p].as_ptr();
            let a3 = a[(i + 3) * p..(i + 4) * p].as_ptr();
            for jp in 0..np {
                let j0 = jp * NR;
                let w = (n - j0).min(NR);
                let mask = ((1u16 << w) - 1) as u8;
                let pb = panels[jp * NR * p..(jp + 1) * NR * p].as_ptr();
                let mut c0 = _mm512_setzero_pd();
                let mut c1 = _mm512_setzero_pd();
                let mut c2 = _mm512_setzero_pd();
                let mut c3 = _mm512_setzero_pd();
                for t in 0..p {
                    let bv = _mm512_loadu_pd(pb.add(t * NR));
                    c0 = _mm512_fmadd_pd(_mm512_set1_pd(*a0.add(t)), bv, c0);
                    c1 = _mm512_fmadd_pd(_mm512_set1_pd(*a1.add(t)), bv, c1);
                    c2 = _mm512_fmadd_pd(_mm512_set1_pd(*a2.add(t)), bv, c2);
                    c3 = _mm512_fmadd_pd(_mm512_set1_pd(*a3.add(t)), bv, c3);
                }
                // SAFETY: rows [lo, hi) are disjoint across workers.
                _mm512_mask_storeu_pd(cptr.0.add(i * n + j0), mask, c0);
                _mm512_mask_storeu_pd(cptr.0.add((i + 1) * n + j0), mask, c1);
                _mm512_mask_storeu_pd(cptr.0.add((i + 2) * n + j0), mask, c2);
                _mm512_mask_storeu_pd(cptr.0.add((i + 3) * n + j0), mask, c3);
            }
            i += 4;
        }
        while i + 2 <= hi {
            let a0 = a[i * p..(i + 1) * p].as_ptr();
            let a1 = a[(i + 1) * p..(i + 2) * p].as_ptr();
            for jp in 0..np {
                let j0 = jp * NR;
                let w = (n - j0).min(NR);
                let mask = ((1u16 << w) - 1) as u8;
                let pb = panels[jp * NR * p..(jp + 1) * NR * p].as_ptr();
                let mut c0 = _mm512_setzero_pd();
                let mut c1 = _mm512_setzero_pd();
                for t in 0..p {
                    let bv = _mm512_loadu_pd(pb.add(t * NR));
                    c0 = _mm512_fmadd_pd(_mm512_set1_pd(*a0.add(t)), bv, c0);
                    c1 = _mm512_fmadd_pd(_mm512_set1_pd(*a1.add(t)), bv, c1);
                }
                _mm512_mask_storeu_pd(cptr.0.add(i * n + j0), mask, c0);
                _mm512_mask_storeu_pd(cptr.0.add((i + 1) * n + j0), mask, c1);
            }
            i += 2;
        }
        if i < hi {
            let a0 = a[i * p..(i + 1) * p].as_ptr();
            for jp in 0..np {
                let j0 = jp * NR;
                let w = (n - j0).min(NR);
                let mask = ((1u16 << w) - 1) as u8;
                let pb = panels[jp * NR * p..(jp + 1) * NR * p].as_ptr();
                let mut c0 = _mm512_setzero_pd();
                for t in 0..p {
                    let bv = _mm512_loadu_pd(pb.add(t * NR));
                    c0 = _mm512_fmadd_pd(_mm512_set1_pd(*a0.add(t)), bv, c0);
                }
                _mm512_mask_storeu_pd(cptr.0.add(i * n + j0), mask, c0);
            }
        }
    }

    /// Bitwise-equal AVX2 widening f32 axpy: f32 product in 128-bit
    /// lanes, exact `cvtps_pd` widening, f64 add — per element exactly
    /// the scalar policy kernel.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn widening_axpy_f32_avx2(alpha: f32, x: &[f32], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4 * 4;
        let av = _mm_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut t = 0;
        while t < chunks {
            let prod = _mm_mul_ps(av, _mm_loadu_ps(xp.add(t)));
            let wide = _mm256_cvtps_pd(prod);
            let yv = _mm256_loadu_pd(yp.add(t));
            _mm256_storeu_pd(yp.add(t), _mm256_add_pd(yv, wide));
            t += 4;
        }
        for j in chunks..n {
            y[j] += f64::from(alpha * x[j]);
        }
    }
}

// ---------------------------------------------------------------------
// aarch64 bodies.
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use crate::linalg::blas::NR;
    use crate::util::threadpool::SendPtr;
    use std::arch::aarch64::*;

    /// Bitwise-equal NEON dot: two 128-bit accumulators whose lanes
    /// reproduce the scalar `acc0..acc3` grouping, reduced in scalar
    /// order, identical sequential tail.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_neon(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4 * 4;
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        let mut t = 0;
        while t < chunks {
            let x01 = vld1q_f64(xp.add(t));
            let x23 = vld1q_f64(xp.add(t + 2));
            let y01 = vld1q_f64(yp.add(t));
            let y23 = vld1q_f64(yp.add(t + 2));
            acc01 = vaddq_f64(acc01, vmulq_f64(x01, y01));
            acc23 = vaddq_f64(acc23, vmulq_f64(x23, y23));
            t += 4;
        }
        let mut s = ((vgetq_lane_f64::<0>(acc01) + vgetq_lane_f64::<1>(acc01))
            + vgetq_lane_f64::<0>(acc23))
            + vgetq_lane_f64::<1>(acc23);
        for j in chunks..n {
            s += x[j] * y[j];
        }
        s
    }

    /// Bitwise-equal NEON axpy (element-independent mul+add).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_neon(alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 2 * 2;
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut t = 0;
        while t < chunks {
            let xv = vld1q_f64(xp.add(t));
            let yv = vld1q_f64(yp.add(t));
            vst1q_f64(yp.add(t), vaddq_f64(yv, vmulq_n_f64(xv, alpha)));
            t += 2;
        }
        for j in chunks..n {
            y[j] += alpha * x[j];
        }
    }

    /// Bitwise-equal NEON scaled copy (element-independent multiply).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn scale_into_neon(alpha: f64, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), out.len());
        let n = x.len();
        let chunks = n / 2 * 2;
        let xp = x.as_ptr();
        let op = out.as_mut_ptr();
        let mut t = 0;
        while t < chunks {
            let xv = vld1q_f64(xp.add(t));
            vst1q_f64(op.add(t), vmulq_n_f64(xv, alpha));
            t += 2;
        }
        for j in chunks..n {
            out[j] = alpha * x[j];
        }
    }

    /// FMA-tier NEON dot (fused steps; 1e-12-pinned, not bitwise).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_fma_neon(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4 * 4;
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        let mut t = 0;
        while t < chunks {
            acc01 = vfmaq_f64(acc01, vld1q_f64(xp.add(t)), vld1q_f64(yp.add(t)));
            acc23 = vfmaq_f64(acc23, vld1q_f64(xp.add(t + 2)), vld1q_f64(yp.add(t + 2)));
            t += 4;
        }
        let mut s = ((vgetq_lane_f64::<0>(acc01) + vgetq_lane_f64::<1>(acc01))
            + vgetq_lane_f64::<0>(acc23))
            + vgetq_lane_f64::<1>(acc23);
        for j in chunks..n {
            s += x[j] * y[j];
        }
        s
    }

    /// FMA-tier NEON axpy (fused steps; 1e-12-pinned, not bitwise).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_fma_neon(alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 2 * 2;
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut t = 0;
        while t < chunks {
            let xv = vld1q_f64(xp.add(t));
            let yv = vld1q_f64(yp.add(t));
            vst1q_f64(yp.add(t), vfmaq_n_f64(yv, xv, alpha));
            t += 2;
        }
        for j in chunks..n {
            y[j] += alpha * x[j];
        }
    }

    /// Masked tile store: full-width on interior panels, staged through
    /// a stack buffer on the edge panel.
    #[target_feature(enable = "neon")]
    unsafe fn store_masked_neon(
        dst: *mut f64,
        w: usize,
        a: float64x2_t,
        b: float64x2_t,
        c: float64x2_t,
        d: float64x2_t,
    ) {
        if w == NR {
            vst1q_f64(dst, a);
            vst1q_f64(dst.add(2), b);
            vst1q_f64(dst.add(4), c);
            vst1q_f64(dst.add(6), d);
        } else {
            let mut buf = [0.0f64; NR];
            vst1q_f64(buf.as_mut_ptr(), a);
            vst1q_f64(buf.as_mut_ptr().add(2), b);
            vst1q_f64(buf.as_mut_ptr().add(4), c);
            vst1q_f64(buf.as_mut_ptr().add(6), d);
            std::ptr::copy_nonoverlapping(buf.as_ptr(), dst, w);
        }
    }

    /// NEON packed NT microkernel: 2×8 tile on 128-bit FMA lanes (eight
    /// accumulators per row pair), matching the oracle's structure.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn packed_nt_rows_neon(
        a: &[f64],
        p: usize,
        panels: &[f64],
        n: usize,
        lo: usize,
        hi: usize,
        cptr: SendPtr,
    ) {
        let np = n.div_ceil(NR);
        let mut i = lo;
        while i + 2 <= hi {
            let a0 = a[i * p..(i + 1) * p].as_ptr();
            let a1 = a[(i + 1) * p..(i + 2) * p].as_ptr();
            for jp in 0..np {
                let j0 = jp * NR;
                let w = (n - j0).min(NR);
                let pb = panels[jp * NR * p..(jp + 1) * NR * p].as_ptr();
                let mut c0a = vdupq_n_f64(0.0);
                let mut c0b = vdupq_n_f64(0.0);
                let mut c0c = vdupq_n_f64(0.0);
                let mut c0d = vdupq_n_f64(0.0);
                let mut c1a = vdupq_n_f64(0.0);
                let mut c1b = vdupq_n_f64(0.0);
                let mut c1c = vdupq_n_f64(0.0);
                let mut c1d = vdupq_n_f64(0.0);
                for t in 0..p {
                    let ba = vld1q_f64(pb.add(t * NR));
                    let bb = vld1q_f64(pb.add(t * NR + 2));
                    let bc = vld1q_f64(pb.add(t * NR + 4));
                    let bd = vld1q_f64(pb.add(t * NR + 6));
                    let x0 = *a0.add(t);
                    c0a = vfmaq_n_f64(c0a, ba, x0);
                    c0b = vfmaq_n_f64(c0b, bb, x0);
                    c0c = vfmaq_n_f64(c0c, bc, x0);
                    c0d = vfmaq_n_f64(c0d, bd, x0);
                    let x1 = *a1.add(t);
                    c1a = vfmaq_n_f64(c1a, ba, x1);
                    c1b = vfmaq_n_f64(c1b, bb, x1);
                    c1c = vfmaq_n_f64(c1c, bc, x1);
                    c1d = vfmaq_n_f64(c1d, bd, x1);
                }
                // SAFETY: rows [lo, hi) are disjoint across workers.
                store_masked_neon(cptr.0.add(i * n + j0), w, c0a, c0b, c0c, c0d);
                store_masked_neon(cptr.0.add((i + 1) * n + j0), w, c1a, c1b, c1c, c1d);
            }
            i += 2;
        }
        if i < hi {
            let a0 = a[i * p..(i + 1) * p].as_ptr();
            for jp in 0..np {
                let j0 = jp * NR;
                let w = (n - j0).min(NR);
                let pb = panels[jp * NR * p..(jp + 1) * NR * p].as_ptr();
                let mut ca = vdupq_n_f64(0.0);
                let mut cb = vdupq_n_f64(0.0);
                let mut cc = vdupq_n_f64(0.0);
                let mut cd = vdupq_n_f64(0.0);
                for t in 0..p {
                    let x0 = *a0.add(t);
                    ca = vfmaq_n_f64(ca, vld1q_f64(pb.add(t * NR)), x0);
                    cb = vfmaq_n_f64(cb, vld1q_f64(pb.add(t * NR + 2)), x0);
                    cc = vfmaq_n_f64(cc, vld1q_f64(pb.add(t * NR + 4)), x0);
                    cd = vfmaq_n_f64(cd, vld1q_f64(pb.add(t * NR + 6)), x0);
                }
                store_masked_neon(cptr.0.add(i * n + j0), w, ca, cb, cc, cd);
            }
        }
    }

    /// Bitwise-equal NEON widening f32 axpy: f32 product, exact
    /// widening via `vcvt_f64_f32`, f64 add.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn widening_axpy_f32_neon(alpha: f32, x: &[f32], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4 * 4;
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut t = 0;
        while t < chunks {
            let prod = vmulq_n_f32(vld1q_f32(xp.add(t)), alpha);
            let lo = vcvt_f64_f32(vget_low_f32(prod));
            let hi = vcvt_f64_f32(vget_high_f32(prod));
            vst1q_f64(yp.add(t), vaddq_f64(vld1q_f64(yp.add(t)), lo));
            vst1q_f64(yp.add(t + 2), vaddq_f64(vld1q_f64(yp.add(t + 2)), hi));
            t += 4;
        }
        for j in chunks..n {
            y[j] += f64::from(alpha * x[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// The parity-suite shape grid from the issue: every unroll edge of
    /// the 4-way scalar bodies and the 8-wide tiles.
    const LENS: [usize; 10] = [0, 1, 2, 3, 7, 8, 9, 31, 33, 65];

    fn randvec(n: usize, rng: &mut Pcg64) -> Vec<f64> {
        DenseMat::gaussian(1, n.max(1), rng).data()[..n].to_vec()
    }

    #[test]
    fn supported_lists_scalar_last_and_active_is_supported() {
        let sup = supported();
        assert_eq!(*sup.last().unwrap(), KernelIsa::Scalar);
        for isa in &sup {
            assert!(isa.is_supported());
        }
        assert!(sup.contains(&detect()));
        assert!(sup.contains(&active()));
        // the process-wide choice is stable
        assert_eq!(active(), active());
    }

    #[test]
    fn isa_names_roundtrip() {
        for isa in [
            KernelIsa::Scalar,
            KernelIsa::Avx2,
            KernelIsa::Avx512,
            KernelIsa::Neon,
        ] {
            assert_eq!(KernelIsa::parse(isa.as_str()), Some(isa));
            assert_eq!(
                KernelIsa::parse(&isa.as_str().to_ascii_uppercase()),
                Some(isa)
            );
        }
        assert_eq!(KernelIsa::parse("sse9"), None);
    }

    #[test]
    fn resolve_defaults_to_detection() {
        assert_eq!(resolve(None), detect());
        assert_eq!(resolve(Some("")), detect());
        assert_eq!(resolve(Some("auto")), detect());
        assert_eq!(resolve(Some("  AUTO ")), detect());
        assert_eq!(resolve(Some("scalar")), KernelIsa::Scalar);
    }

    #[test]
    #[should_panic(expected = "SYMNMF_KERNEL")]
    fn resolve_rejects_unknown_name() {
        resolve(Some("sse9"));
    }

    #[test]
    fn resolve_fails_loud_on_unsupported_isa() {
        // Some ISA in the vocabulary is always unsupported on any one
        // host (avx512 and neon are mutually exclusive architectures).
        let unsupported = [KernelIsa::Avx512, KernelIsa::Avx2, KernelIsa::Neon]
            .into_iter()
            .find(|isa| !isa.is_supported())
            .unwrap();
        let err = std::panic::catch_unwind(|| resolve(Some(unsupported.as_str())))
            .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(
            msg.contains("not supported"),
            "panic should name the unsupported ISA: {msg}"
        );
    }

    #[test]
    fn hostname_is_nonempty() {
        assert!(!hostname().is_empty());
    }

    #[test]
    fn precision_parses_and_defaults() {
        assert_eq!(Precision::parse("f64"), Some(Precision::F64));
        assert_eq!(Precision::parse(" F32 "), Some(Precision::F32));
        assert_eq!(Precision::parse("f16"), None);
        assert_eq!(Precision::F64.as_str(), "f64");
        assert_eq!(Precision::F32.as_str(), "f32");
    }

    /// Bitwise tier: the dispatched dot/axpy reproduce the scalar
    /// oracle bit-for-bit on every supported ISA at every unroll edge.
    #[test]
    fn dot_and_axpy_are_bitwise_equal_to_scalar_on_every_isa() {
        let mut rng = Pcg64::seed_from_u64(61);
        for &n in &LENS {
            let x = randvec(n, &mut rng);
            let y = randvec(n, &mut rng);
            let want_dot = blas::dot(&x, &y);
            let mut want_y = y.clone();
            blas::axpy(1.75, &x, &mut want_y);
            for isa in supported() {
                let got = dot(isa, &x, &y);
                assert_eq!(
                    got.to_bits(),
                    want_dot.to_bits(),
                    "dot isa={isa:?} n={n}"
                );
                let mut got_y = y.clone();
                axpy(isa, 1.75, &x, &mut got_y);
                for (a, b) in got_y.iter().zip(&want_y) {
                    assert_eq!(a.to_bits(), b.to_bits(), "axpy isa={isa:?} n={n}");
                }
            }
        }
    }

    /// Bitwise tier: the scaled copy reproduces the scalar body
    /// bit-for-bit on every supported ISA at every unroll edge, and
    /// fully overwrites stale output.
    #[test]
    fn scale_into_is_bitwise_equal_to_scalar_on_every_isa() {
        let mut rng = Pcg64::seed_from_u64(65);
        for &n in &LENS {
            let x = randvec(n, &mut rng);
            let mut want = vec![f64::NAN; n];
            scale_into(KernelIsa::Scalar, -2.3, &x, &mut want);
            for (w, &v) in want.iter().zip(&x) {
                assert_eq!(w.to_bits(), (-2.3 * v).to_bits());
            }
            for isa in supported() {
                let mut got = vec![f64::NAN; n]; // stale garbage
                scale_into(isa, -2.3, &x, &mut got);
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "scale isa={isa:?} n={n}");
                }
            }
        }
    }

    /// FMA tier: contracted dot/axpy stay within 1e-12 relative of the
    /// scalar oracle on every supported ISA.
    #[test]
    fn fma_dot_and_axpy_match_scalar_to_1e12() {
        let mut rng = Pcg64::seed_from_u64(62);
        for &n in &LENS {
            let x = randvec(n, &mut rng);
            let y = randvec(n, &mut rng);
            let want_dot = blas::dot(&x, &y);
            let mut want_y = y.clone();
            blas::axpy(-0.37, &x, &mut want_y);
            for isa in supported() {
                let got = dot_fma(isa, &x, &y);
                let scale = 1.0 + want_dot.abs();
                assert!(
                    (got - want_dot).abs() < 1e-12 * scale,
                    "dot_fma isa={isa:?} n={n}: {got} vs {want_dot}"
                );
                let mut got_y = y.clone();
                axpy_fma(isa, -0.37, &x, &mut got_y);
                for (a, b) in got_y.iter().zip(&want_y) {
                    assert!(
                        (a - b).abs() < 1e-12 * (1.0 + b.abs()),
                        "axpy_fma isa={isa:?} n={n}"
                    );
                }
            }
        }
    }

    /// The f32 policy kernel is bitwise-identical across ISAs (the
    /// widening is exact and element-independent).
    #[test]
    fn widening_axpy_f32_is_bitwise_equal_across_isas() {
        let mut rng = Pcg64::seed_from_u64(63);
        for &n in &LENS {
            let x: Vec<f32> = randvec(n, &mut rng).iter().map(|&v| v as f32).collect();
            let y0 = randvec(n, &mut rng);
            let mut want = y0.clone();
            widening_axpy_f32(KernelIsa::Scalar, 0.6f32, &x, &mut want);
            for isa in supported() {
                let mut got = y0.clone();
                widening_axpy_f32(isa, 0.6f32, &x, &mut got);
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "isa={isa:?} n={n}");
                }
            }
        }
    }

    /// The staged-f32 GEMMs agree with the f64 kernels to f32 product
    /// accuracy, and are bitwise-identical across ISAs.
    #[test]
    fn f32_gemms_track_f64_and_are_isa_invariant() {
        let mut rng = Pcg64::seed_from_u64(64);
        for (m, p, n) in [(1usize, 1usize, 1usize), (3, 7, 2), (9, 31, 8), (33, 9, 7)] {
            let a = DenseMat::gaussian(m, p, &mut rng);
            let b = DenseMat::gaussian(p, n, &mut rng);
            let a32 = a.to_f32();
            let b32 = b.to_f32();

            let mut want = DenseMat::zeros(m, n);
            blas::matmul_into(&a, &b, &mut want);
            let mut got = DenseMat::zeros(m, n);
            matmul_f32_into(KernelIsa::Scalar, &a32, m, p, &b32, n, &mut got);
            let err = got.diff_fro(&want);
            assert!(
                err < 1e-5 * (1.0 + want.fro_norm()),
                "matmul_f32 ({m},{p},{n}): err={err}"
            );
            for isa in supported() {
                let mut other = DenseMat::zeros(m, n);
                matmul_f32_into(isa, &a32, m, p, &b32, n, &mut other);
                for (x, y) in other.data().iter().zip(got.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "isa={isa:?}");
                }
            }

            // Aᵀ·B with A reinterpreted as m×p against a m×n B
            let b2 = DenseMat::gaussian(m, n, &mut rng);
            let b2_32 = b2.to_f32();
            let mut want_tn = DenseMat::zeros(p, n);
            blas::matmul_tn_into(&a, &b2, &mut want_tn);
            let mut got_tn = DenseMat::zeros(p, n);
            matmul_tn_f32_into(KernelIsa::Scalar, &a32, m, p, &b2_32, n, &mut got_tn);
            let err = got_tn.diff_fro(&want_tn);
            assert!(
                err < 1e-5 * (1.0 + want_tn.fro_norm()),
                "matmul_tn_f32 ({m},{p},{n}): err={err}"
            );
            for isa in supported() {
                let mut other = DenseMat::zeros(p, n);
                matmul_tn_f32_into(isa, &a32, m, p, &b2_32, n, &mut other);
                for (x, y) in other.data().iter().zip(got_tn.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "isa={isa:?}");
                }
            }
        }
    }
}
