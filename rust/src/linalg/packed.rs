//! Packed-triangular symmetric storage: the half-sized resident X.
//!
//! The dense blocked SYMM kernel (`blas::symm_tall_into`) already reads
//! only the upper-triangle *blocks* of X, but X itself is still stored
//! as a full m×m array — the strictly-lower half occupies memory that is
//! never touched. [`SymPacked`] drops it: only the blocks on or above
//! the block diagonal are stored, halving the resident footprint of the
//! dominant memory object. That compounds with the batched multi-seed
//! driver (`coordinator::driver::run_trials_batched`), which amortizes
//! ONE resident X across every concurrent trial.
//!
//! ## Block-panel layout and index math
//!
//! X is partitioned into `block`-sized row/column blocks,
//! `nb = ⌈m/block⌉` per side (edge blocks truncated, never padded).
//! The upper-triangle block pairs are stored back to back in
//! block-row-major order, each pair as a dense row-major `bi×bj` tile:
//!
//! ```text
//!   data:  [ (0,0) | (0,1) | … | (0,nb−1) | (1,1) | … | (nb−1,nb−1) ]
//!
//!   pair index of (ib, jb), ib ≤ jb (block-row-major enumeration):
//!       idx(ib, jb) = ib·(2·nb − ib + 1)/2 + (jb − ib)
//!       (block-row ib contributes nb − ib pairs, so the row base is
//!        Σ_{r<ib} (nb − r) = ib·nb − ib(ib−1)/2 = ib·(2nb − ib + 1)/2)
//!
//!   byte offset: block_off[idx] (precomputed prefix sums of bi·bj —
//!       edge tiles make the tile sizes irregular, so offsets are a
//!       table, not a closed form)
//!
//!   entry X[i, j] with i ≤ j:
//!       ib = i / block, jb = j / block   (ib ≤ jb holds)
//!       within-tile: row i − ib·block, col j − jb·block, leading dim bj
//!   entry X[i, j] with i > j: stored once as X[j, i] (upper wins);
//!       reading it walks the stored tile (jb, ib) down its
//!       (i − ib·block)-th column — the mirrored, strided access that
//!       only the row-sampled product ever performs.
//! ```
//!
//! Diagonal tiles are stored **full** (both triangles, mirrored from the
//! upper triangle at construction): a diagonal tile is read in full by
//! the kernel anyway, and storing `bi×bi` instead of `bi(bi+1)/2` keeps
//! every tile a plain row-major matrix — the same inner loops as the
//! dense [`symm_block_pair`] path, byte for byte. The overhead is
//! ≤ `m·block/2` doubles (≈ 0.8% of the full matrix at m = 16384,
//! block = 128).
//!
//! ## Kernel equivalence
//!
//! [`SymPacked::apply_into`] runs on the same deterministic pair-pool
//! harness ([`pair_pool_accumulate`]) as the dense blocked SYMM, with
//! identical pair enumeration, identical per-tile inner loops, and the
//! identical fixed-order reduction — so for a given process
//! configuration the packed product equals the dense blocked product to
//! the last bit, and is invariant under thread budgets and under the
//! dispatch backend (the harness fans out on the shared persistent
//! pool, see [`crate::util::pool`]). The aggregate
//! statistics (`fro_norm_sq`, `max_value`, `mean_value`) are computed
//! once at construction from the stored triangle (off-diagonal tiles
//! weighted twice) and cached, so the SymOp surface stays O(1) where the
//! dense operator rescans X.
//!
//! ## Out-of-core tier
//!
//! Because every tile lives at a precomputed offset (`block_off`), the
//! packed payload is directly spillable: `linalg::spill` serializes it
//! to a versioned, checksummed panel file (header: dim, block,
//! packed_len, cached stats; little-endian f64 tiles at
//! `HEADER_LEN + 8·block_off[p]`), and [`SymPackedSpilled`] streams
//! tiles back through a small reusable read-buffer ring while driving
//! the **same** [`tile_pair_apply_slice`] kernel on the **same**
//! [`pair_pool_accumulate`] harness — which is why the spilled apply is
//! bitwise-identical to the resident one on every kernel tier. See
//! `linalg/spill.rs` for the file format, and `serve/opcache.rs` for
//! the eviction policy that decides when an operator moves to this
//! tier.
//!
//! [`symm_block_pair`]: crate::linalg::blas
//! [`pair_pool_accumulate`]: crate::linalg::blas
//! [`SymPackedSpilled`]: crate::linalg::spill::SymPackedSpilled

use crate::linalg::blas::{axpy, pair_pool_accumulate, pair_to_blocks, SYMM_BLOCK};
use crate::linalg::simd::{self, KernelIsa};
use crate::linalg::DenseMat;
use crate::randnla::SymOp;
use crate::sparse::CsrMat;
use crate::util::threadpool::{parallel_for_chunks, SendPtr};

/// Packed-triangular symmetric matrix in block-panel layout (see the
/// module header for the index math). Implements [`SymOp`], so every
/// solver driver runs on it unchanged.
#[derive(Clone, Debug)]
pub struct SymPacked {
    m: usize,
    block: usize,
    nb: usize,
    /// upper-triangle tiles, block-row-major, each row-major bi×bj
    data: Vec<f64>,
    /// prefix offsets of each tile in `data` (len = npairs + 1)
    block_off: Vec<usize>,
    /// ‖X‖²_F of the full (mirrored) matrix, cached at construction
    fro_sq: f64,
    /// max entry of the full matrix, cached at construction
    max: f64,
    /// mean entry of the full matrix, cached at construction
    mean: f64,
}

/// Block layout of the packed upper triangle: (nb, per-tile prefix
/// offsets, total stored elements). One definition shared by every
/// constructor — and by the spill reader (`linalg::spill`), which
/// recomputes the layout from the header's (dim, block) and rejects a
/// file whose recorded `packed_len` disagrees — so the resident,
/// streaming, and on-disk addressing can never drift apart.
pub(crate) fn block_layout(m: usize, block: usize) -> (usize, Vec<usize>, usize) {
    let nb = m.div_ceil(block);
    let npairs = nb * (nb + 1) / 2;
    let bdim = |b: usize| (m - b * block).min(block);
    let mut block_off = Vec::with_capacity(npairs + 1);
    let mut total = 0usize;
    for ib in 0..nb {
        for jb in ib..nb {
            block_off.push(total);
            total += bdim(ib) * bdim(jb);
        }
    }
    block_off.push(total);
    (nb, block_off, total)
}

/// Aggregate statistics (Σv, Σv², max) over packed storage: tiles in
/// block-row-major order, row-major within each tile — the ONE canonical
/// accumulation order every constructor shares (off-diagonal tiles
/// weighted twice for the mirrored half), which is what makes the cached
/// stats bitwise-identical across construction paths.
fn packed_stats(nb: usize, block_off: &[usize], data: &[f64]) -> (f64, f64, f64) {
    let (mut sum, mut ss, mut mx) = (0.0f64, 0.0f64, f64::NEG_INFINITY);
    let mut p = 0;
    for ib in 0..nb {
        for jb in ib..nb {
            let bd = &data[block_off[p]..block_off[p + 1]];
            if ib == jb {
                // each stored diagonal-tile entry (mirrored lower ones
                // included) is one entry of the full matrix
                for &v in bd {
                    sum += v;
                    ss += v * v;
                    if v > mx {
                        mx = v;
                    }
                }
            } else {
                // each stored off-diagonal entry appears twice in the
                // mirrored matrix
                for &v in bd {
                    sum += 2.0 * v;
                    ss += 2.0 * v * v;
                    if v > mx {
                        mx = v;
                    }
                }
            }
            p += 1;
        }
    }
    (sum, ss, mx)
}

impl SymPacked {
    /// Pack the upper triangle of a square matrix with the production
    /// block size (the SYMM cache block). For entries where X[i,j] and
    /// X[j,i] disagree, the upper triangle wins.
    pub fn from_dense(x: &DenseMat) -> SymPacked {
        SymPacked::from_dense_with_block(x, SYMM_BLOCK)
    }

    /// Pack with an explicit block size (exposed so tests can exercise
    /// multi-tile layouts on small shapes).
    pub fn from_dense_with_block(x: &DenseMat, block: usize) -> SymPacked {
        let (m, mc) = x.shape();
        assert_eq!(m, mc, "SymPacked: X must be square, got {:?}", x.shape());
        assert!(block >= 1, "SymPacked: block size must be positive");
        let (nb, block_off, total) = block_layout(m, block);
        let mut data = vec![0.0; total];
        let xd = x.data();
        let mut p = 0;
        for ib in 0..nb {
            let i0 = ib * block;
            let i1 = (i0 + block).min(m);
            for jb in ib..nb {
                let j0 = jb * block;
                let j1 = (j0 + block).min(m);
                let bj = j1 - j0;
                let bd = &mut data[block_off[p]..block_off[p + 1]];
                if ib == jb {
                    // diagonal tile stored full; lower entries mirrored
                    // from the upper triangle ("upper wins")
                    for i in i0..i1 {
                        let dst = &mut bd[(i - i0) * bj..(i - i0 + 1) * bj];
                        for j in j0..j1 {
                            dst[j - j0] =
                                if i <= j { xd[i * m + j] } else { xd[j * m + i] };
                        }
                    }
                } else {
                    for i in i0..i1 {
                        bd[(i - i0) * bj..(i - i0 + 1) * bj]
                            .copy_from_slice(&xd[i * m + j0..i * m + j1]);
                    }
                }
                p += 1;
            }
        }
        let (sum, ss, mx) = packed_stats(nb, &block_off, &data);
        SymPacked {
            m,
            block,
            nb,
            data,
            block_off,
            fro_sq: ss,
            max: mx,
            mean: sum / (m * m) as f64,
        }
    }

    /// Pack a sparse symmetric matrix by **streaming** the CSR upper
    /// triangle straight into the block panels — no transient
    /// `to_dense()`, so a huge sparse-to-dense promotion never holds the
    /// full m² square array (peak resident: the packed triangle plus the
    /// CSR itself). Bitwise-identical to the densifying path
    /// (`from_csr_via_dense`, the test-only pinning oracle): the
    /// scatter writes exactly the entries the dense pack would copy
    /// (upper triangle wins, diagonal-tile lower entries mirrored from
    /// the upper), and the aggregate statistics are accumulated in a
    /// second pass over the packed storage — which IS the dense pack's
    /// iteration order (tiles block-row-major, row-major within a tile).
    pub fn from_csr(x: &CsrMat) -> SymPacked {
        SymPacked::from_csr_with_block(x, SYMM_BLOCK)
    }

    /// Streaming CSR construction with an explicit block size (exposed
    /// so tests can exercise multi-tile and edge-tile layouts).
    pub fn from_csr_with_block(x: &CsrMat, block: usize) -> SymPacked {
        let (m, mc) = (x.rows(), x.cols());
        assert_eq!(m, mc, "SymPacked: X must be square, got {m}x{mc}");
        assert!(block >= 1, "SymPacked: block size must be positive");
        let (nb, block_off, total) = block_layout(m, block);
        let bdim = |b: usize| (m - b * block).min(block);
        let mut data = vec![0.0; total];
        // Scatter the stored upper triangle; tiles strictly below the
        // block diagonal are never materialized, and lower entries inside
        // a diagonal tile come from mirroring the upper value — exactly
        // the "upper wins" rule of the dense pack.
        for i in 0..m {
            let (cols, vals) = x.row(i);
            let ib = i / block;
            let li = i - ib * block;
            let start = cols.partition_point(|&j| j < i);
            for (&j, &v) in cols[start..].iter().zip(&vals[start..]) {
                let jb = j / block;
                let p = ib * (2 * nb - ib + 1) / 2 + (jb - ib);
                let bj = bdim(jb);
                let tile = &mut data[block_off[p]..block_off[p + 1]];
                let lj = j - jb * block;
                tile[li * bj + lj] = v;
                if jb == ib && j != i {
                    tile[lj * bj + li] = v;
                }
            }
        }
        let (sum, ss, mx) = packed_stats(nb, &block_off, &data);
        SymPacked {
            m,
            block,
            nb,
            data,
            block_off,
            fro_sq: ss,
            max: mx,
            mean: sum / (m * m) as f64,
        }
    }

    /// The pre-streaming construction — densify through
    /// [`CsrMat::to_dense`], then pack. Kept as the pinning oracle for
    /// [`SymPacked::from_csr`]; it materializes the full m² array, so it
    /// is compiled only into the test harness — release builds carry no
    /// densifying path.
    #[cfg(test)]
    pub fn from_csr_via_dense(x: &CsrMat) -> SymPacked {
        SymPacked::from_dense(&x.to_dense())
    }

    /// Dimension m.
    pub fn dim(&self) -> usize {
        self.m
    }

    /// Block size of the panel layout.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Stored elements — ≈ m(m + block)/2, vs m² for the full array.
    pub fn packed_len(&self) -> usize {
        self.data.len()
    }

    /// The packed payload (tiles block-row-major) — what the spill
    /// writer serializes verbatim.
    pub(crate) fn payload(&self) -> &[f64] {
        &self.data
    }

    /// Cached aggregate statistics `(fro_sq, max, mean)`, exposed so the
    /// spill header can carry them bit for bit (a spilled operator never
    /// rescans the payload to answer the SymOp stat surface).
    pub(crate) fn stats(&self) -> (f64, f64, f64) {
        (self.fro_sq, self.max, self.mean)
    }

    /// Rows/cols of block index `b` (edge blocks truncated).
    #[inline]
    fn bdim(&self, b: usize) -> usize {
        (self.m - b * self.block).min(self.block)
    }

    /// Pair index of tile (ib, jb), ib ≤ jb — see the module header.
    #[inline]
    fn pair_index(&self, ib: usize, jb: usize) -> usize {
        debug_assert!(ib <= jb && jb < self.nb);
        ib * (2 * self.nb - ib + 1) / 2 + (jb - ib)
    }

    /// Tile (ib, jb) as a row-major slice (ib ≤ jb).
    #[inline]
    fn tile(&self, ib: usize, jb: usize) -> &[f64] {
        let p = self.pair_index(ib, jb);
        &self.data[self.block_off[p]..self.block_off[p + 1]]
    }

    /// Unpack to a full square matrix (test/debug aid).
    pub fn to_dense(&self) -> DenseMat {
        let mut out = DenseMat::zeros(self.m, self.m);
        for ib in 0..self.nb {
            let i0 = ib * self.block;
            for jb in ib..self.nb {
                let j0 = jb * self.block;
                let bj = self.bdim(jb);
                let bd = self.tile(ib, jb);
                for li in 0..self.bdim(ib) {
                    let i = i0 + li;
                    for lj in 0..bj {
                        let j = j0 + lj;
                        let v = bd[li * bj + lj];
                        out.set(i, j, v);
                        if i != j {
                            out.set(j, i, v);
                        }
                    }
                }
            }
        }
        out
    }

    /// out = X·F on the packed storage: the same upper-triangle
    /// block-pair walk, per-tile inner loops, and fixed-order
    /// accumulator-pool reduction as the dense
    /// [`symm_tall_into_blocked`], reading each stored tile exactly once
    /// and applying off-diagonal tiles to both output panels.
    ///
    /// [`symm_tall_into_blocked`]: crate::linalg::blas::symm_tall_into_blocked
    pub fn apply_blocked_into(&self, f: &DenseMat, out: &mut DenseMat) {
        self.apply_blocked_into_isa(simd::active(), f, out);
    }

    /// [`apply_blocked_into`](Self::apply_blocked_into) with an explicit
    /// kernel tier (FMA tier: per-tile row updates run on
    /// [`simd::axpy_fma`]; the Scalar tier reproduces the historical
    /// kernel bitwise) — the parity suite's entry point.
    pub fn apply_blocked_into_isa(&self, isa: KernelIsa, f: &DenseMat, out: &mut DenseMat) {
        let m = self.m;
        let (mf, k) = f.shape();
        assert_eq!(m, mf, "SymPacked::apply: X is {m}x{m} but F has {mf} rows");
        assert_eq!(out.shape(), (m, k), "SymPacked::apply: output must be {m}x{k}");
        if m == 0 || k == 0 {
            out.data_mut().fill(0.0);
            return;
        }
        let nb = self.nb;
        let npairs = nb * (nb + 1) / 2;
        let fd = f.data();
        pair_pool_accumulate(m, k, npairs, out, |p, acc| {
            let (ib, jb) = pair_to_blocks(p, nb);
            self.tile_pair_apply(isa, fd, k, ib, jb, acc);
        });
    }

    /// Apply one stored tile (ib, jb) to F, accumulating into the m×k
    /// accumulator — the packed twin of the dense `symm_block_pair`.
    fn tile_pair_apply(
        &self,
        isa: KernelIsa,
        fd: &[f64],
        k: usize,
        ib: usize,
        jb: usize,
        acc: &mut [f64],
    ) {
        tile_pair_apply_slice(isa, self.m, self.block, ib, jb, self.tile(ib, jb), fd, k, acc);
    }
}

/// Apply one row-major tile (ib, jb) of the packed layout to F,
/// accumulating into the m×k accumulator — the packed twin of the dense
/// `symm_block_pair`, hoisted out of [`SymPacked`] so the resident and
/// spilled operators drive the **one** kernel body: `bd` is the tile
/// slice wherever it lives (the resident payload, or a just-read spill
/// ring buffer). Bitwise parity between the two tiers reduces to both
/// calling this function with identical arguments in the identical
/// [`pair_pool_accumulate`] slot order.
#[allow(clippy::too_many_arguments)]
pub(crate) fn tile_pair_apply_slice(
    isa: KernelIsa,
    m: usize,
    block: usize,
    ib: usize,
    jb: usize,
    bd: &[f64],
    fd: &[f64],
    k: usize,
    acc: &mut [f64],
) {
    let i0 = ib * block;
    let i1 = (i0 + block).min(m);
    let j0 = jb * block;
    let j1 = (j0 + block).min(m);
    let bj = j1 - j0;
    debug_assert_eq!(bd.len(), (i1 - i0) * bj);
    if ib == jb {
        for i in i0..i1 {
            let xrow = &bd[(i - i0) * bj..(i - i0 + 1) * bj];
            let acci = &mut acc[i * k..(i + 1) * k];
            for (jj, &v) in xrow.iter().enumerate() {
                if v != 0.0 {
                    let j = j0 + jj;
                    simd::axpy_fma(isa, v, &fd[j * k..(j + 1) * k], acci);
                }
            }
        }
        return;
    }
    // Off-diagonal tile: i1 <= j0 by construction, so the I-panel
    // and J-panel of the accumulator can be split and written
    // simultaneously.
    let (acc_i, acc_j) = acc.split_at_mut(j0 * k);
    for i in i0..i1 {
        let xrow = &bd[(i - i0) * bj..(i - i0 + 1) * bj];
        let fi = &fd[i * k..(i + 1) * k];
        let acci = &mut acc_i[i * k..(i + 1) * k];
        for (jj, &v) in xrow.iter().enumerate() {
            if v != 0.0 {
                let j = j0 + jj;
                simd::axpy_fma(isa, v, &fd[j * k..(j + 1) * k], acci);
                simd::axpy_fma(isa, v, fi, &mut acc_j[(j - j0) * k..(j - j0 + 1) * k]);
            }
        }
    }
}

impl SymOp for SymPacked {
    fn dim(&self) -> usize {
        self.m
    }

    fn apply_into(&self, f: &DenseMat, out: &mut DenseMat) {
        self.apply_blocked_into(f, out);
    }

    fn fro_norm_sq(&self) -> f64 {
        self.fro_sq
    }

    fn max_value(&self) -> f64 {
        self.max
    }

    fn mean_value(&self) -> f64 {
        self.mean
    }

    fn sampled_apply_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        self.sampled_apply_into_isa(simd::active(), f, samples, weights_sq, out);
    }
}

impl SymPacked {
    /// Serial scalar oracle for the sampled product. Same accumulation
    /// as the dense operator (X·SᵀS·F = Σ_r w_r · x_{:,i_r} ⊗ F[i_r,:]):
    /// per sample, walk row i_r of X in ascending j. Tiles left of the
    /// diagonal tile are mirrored — column li of the stored tile
    /// (jb, ib), the only strided access in the layout; the diagonal
    /// tile and the tiles to its right give the row contiguously.
    /// Retained verbatim as the pinning reference for
    /// [`SymPacked::sampled_apply_into_isa`].
    pub fn sampled_apply_into_serial(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        let k = f.cols();
        assert_eq!(out.shape(), (self.m, k), "sampled_apply_into shape");
        let od = out.data_mut();
        od.fill(0.0);
        let block = self.block;
        for (&ir, &w) in samples.iter().zip(weights_sq) {
            let frow = f.row(ir);
            let ib = ir / block;
            let li = ir - ib * block;
            for jb in 0..self.nb {
                let j0 = jb * block;
                let j1 = (j0 + block).min(self.m);
                if jb < ib {
                    let bd = self.tile(jb, ib);
                    let ld = self.bdim(ib); // cols of tile (jb, ib)
                    for j in j0..j1 {
                        let v = bd[(j - j0) * ld + li];
                        if v != 0.0 {
                            axpy(w * v, frow, &mut od[j * k..(j + 1) * k]);
                        }
                    }
                } else {
                    let bd = self.tile(ib, jb);
                    let bj = j1 - j0;
                    let xrow = &bd[li * bj..(li + 1) * bj];
                    for (jj, &v) in xrow.iter().enumerate() {
                        if v != 0.0 {
                            let j = j0 + jj;
                            axpy(w * v, frow, &mut od[j * k..(j + 1) * k]);
                        }
                    }
                }
            }
        }
    }

    /// Parallel, ISA-dispatched sampled product — the scatter of
    /// [`SymPacked::sampled_apply_into_serial`] reformulated as a gather
    /// over disjoint block-row chunks (see `randnla::op` module docs).
    /// Each worker owns the output rows of block-rows
    /// `jb ∈ [cb_lo, cb_hi)` and walks all samples in order, visiting
    /// only the tiles whose column range intersects its chunk with the
    /// identical mirrored-tile index math; per output element the
    /// accumulation order matches the serial oracle exactly, so the
    /// result is bitwise-identical at any thread count.
    pub fn sampled_apply_into_isa(
        &self,
        isa: KernelIsa,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        let k = f.cols();
        assert_eq!(out.shape(), (self.m, k), "sampled_apply_into shape");
        assert_eq!(samples.len(), weights_sq.len(), "samples/weights length");
        let block = self.block;
        let fd = f.data();
        let optr = SendPtr(out.data_mut().as_mut_ptr());
        parallel_for_chunks(self.nb, 1, move |cb_lo, cb_hi| {
            let lo = cb_lo * block;
            let hi = (cb_hi * block).min(self.m);
            // SAFETY: chunks hand out disjoint block-row ranges, so each
            // worker touches a disjoint slice of `out`.
            let od = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add(lo * k), (hi - lo) * k)
            };
            od.fill(0.0);
            for (&ir, &w) in samples.iter().zip(weights_sq) {
                let frow = &fd[ir * k..(ir + 1) * k];
                let ib = ir / block;
                let li = ir - ib * block;
                for jb in cb_lo..cb_hi {
                    let j0 = jb * block;
                    let j1 = (j0 + block).min(self.m);
                    if jb < ib {
                        let bd = self.tile(jb, ib);
                        let ld = self.bdim(ib); // cols of tile (jb, ib)
                        for j in j0..j1 {
                            let v = bd[(j - j0) * ld + li];
                            if v != 0.0 {
                                let o = (j - lo) * k;
                                simd::axpy(isa, w * v, frow, &mut od[o..o + k]);
                            }
                        }
                    } else {
                        let bd = self.tile(ib, jb);
                        let bj = j1 - j0;
                        let xrow = &bd[li * bj..(li + 1) * bj];
                        for (jj, &v) in xrow.iter().enumerate() {
                            if v != 0.0 {
                                let o = (j0 + jj - lo) * k;
                                simd::axpy(isa, w * v, frow, &mut od[o..o + k]);
                            }
                        }
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Pcg64;
    use crate::util::threadpool::with_thread_budget;

    fn random_symmetric(m: usize, rng: &mut Pcg64) -> DenseMat {
        let mut x = DenseMat::gaussian(m, m, rng);
        x.symmetrize();
        x
    }

    /// Packing then unpacking a symmetric matrix is the identity, at
    /// every block size (including blocks larger than the matrix).
    #[test]
    fn roundtrip_is_exact() {
        let mut rng = Pcg64::seed_from_u64(1);
        for m in [1usize, 3, 31, 33, 65] {
            let x = random_symmetric(m, &mut rng);
            for block in [4usize, 8, 32, 256] {
                let sp = SymPacked::from_dense_with_block(&x, block);
                let back = sp.to_dense();
                for (a, b) in x.data().iter().zip(back.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "m={m} block={block}");
                }
            }
        }
    }

    /// Packed storage really is about half the full array (plus the
    /// full-diagonal-tile overhead bounded by m·block/2).
    #[test]
    fn packed_len_is_half_plus_diagonal_overhead() {
        let m = 300;
        let block = 32;
        let mut rng = Pcg64::seed_from_u64(2);
        let x = random_symmetric(m, &mut rng);
        let sp = SymPacked::from_dense_with_block(&x, block);
        let full = m * m;
        let upper = m * (m + 1) / 2;
        assert!(sp.packed_len() >= upper, "must hold at least the triangle");
        assert!(
            sp.packed_len() <= upper + m * block / 2 + block * block,
            "len {} exceeds triangle {} + diagonal-tile overhead",
            sp.packed_len(),
            upper
        );
        assert!(sp.packed_len() * 2 < full + m * block + 2 * block * block);
    }

    /// The acceptance pinning: SymPacked::apply_into vs the PR-2 dense
    /// blocked kernel at 1e-12 across m,k ∈ {1, 3, 7, 31, 33, 65} and
    /// several tile sizes (edge tiles everywhere).
    #[test]
    fn apply_matches_dense_blocked_across_shapes() {
        let mut rng = Pcg64::seed_from_u64(3);
        for m in [1usize, 3, 7, 31, 33, 65] {
            let x = random_symmetric(m, &mut rng);
            for k in [1usize, 3, 7, 31, 33, 65] {
                let f = DenseMat::gaussian(m, k, &mut rng);
                for block in [4usize, 8, 32, 256] {
                    let sp = SymPacked::from_dense_with_block(&x, block);
                    let mut want = DenseMat::zeros(m, k);
                    want.fill(-3.0);
                    blas::symm_tall_into_blocked(&x, &f, &mut want, block);
                    let mut got = DenseMat::zeros(m, k);
                    got.fill(7.0); // stale data must be overwritten
                    sp.apply_blocked_into(&f, &mut got);
                    let err = got.diff_fro(&want);
                    assert!(
                        err < 1e-12 * (1.0 + want.fro_norm()),
                        "m={m} k={k} block={block}: err={err}"
                    );
                }
            }
        }
    }

    /// The issue's scalar-vs-SIMD parity grid for the packed apply:
    /// every supported tier vs the forced-Scalar oracle at 1e-12 across
    /// mask-edge shapes.
    #[test]
    fn apply_simd_tiers_match_scalar_oracle() {
        let mut rng = Pcg64::seed_from_u64(41);
        for m in [1usize, 2, 3, 7, 8, 9, 31, 33, 65] {
            let x = random_symmetric(m, &mut rng);
            for k in [1usize, 3, 8, 9, 33] {
                let f = DenseMat::gaussian(m, k, &mut rng);
                let sp = SymPacked::from_dense_with_block(&x, 8);
                let mut want = DenseMat::zeros(m, k);
                sp.apply_blocked_into_isa(KernelIsa::Scalar, &f, &mut want);
                for isa in simd::supported() {
                    let mut got = DenseMat::zeros(m, k);
                    got.fill(5.0); // stale data must be overwritten
                    sp.apply_blocked_into_isa(isa, &f, &mut got);
                    let err = got.diff_fro(&want);
                    assert!(
                        err < 1e-12 * (1.0 + want.fro_norm()),
                        "isa={isa:?} m={m} k={k}: err={err}"
                    );
                }
            }
        }
    }

    /// Same tile size + same process config → the packed product equals
    /// the dense blocked product bitwise (identical pair walk, inner
    /// loops, and reduction).
    #[test]
    fn apply_is_bitwise_equal_to_dense_blocked() {
        let mut rng = Pcg64::seed_from_u64(4);
        let m = 300;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, 8, &mut rng);
        let sp = SymPacked::from_dense_with_block(&x, 64);
        let mut dense = DenseMat::zeros(m, 8);
        blas::symm_tall_into_blocked(&x, &f, &mut dense, 64);
        let mut packed = DenseMat::zeros(m, 8);
        sp.apply_blocked_into(&f, &mut packed);
        for (a, b) in dense.data().iter().zip(packed.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// A thread budget must not change a single bit of the packed apply
    /// (slot geometry pinned to num_threads()).
    #[test]
    fn apply_is_budget_invariant_bitwise() {
        let mut rng = Pcg64::seed_from_u64(5);
        let m = 300;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, 8, &mut rng);
        let sp = SymPacked::from_dense_with_block(&x, 64);
        let mut full = DenseMat::zeros(m, 8);
        sp.apply_blocked_into(&f, &mut full);
        for budget in [1usize, 2, 3] {
            let mut capped = DenseMat::zeros(m, 8);
            with_thread_budget(budget, || {
                sp.apply_blocked_into(&f, &mut capped);
            });
            for (a, b) in full.data().iter().zip(capped.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "budget={budget}");
            }
        }
    }

    /// The cached aggregate statistics match the dense operator.
    #[test]
    fn stats_match_dense_operator() {
        let mut rng = Pcg64::seed_from_u64(6);
        for m in [1usize, 33, 129] {
            let x = random_symmetric(m, &mut rng);
            let sp = SymPacked::from_dense_with_block(&x, 32);
            let fro = DenseMat::fro_norm_sq(&x);
            assert!(
                (SymOp::fro_norm_sq(&sp) - fro).abs() <= 1e-12 * (1.0 + fro.abs()),
                "m={m} fro"
            );
            assert_eq!(SymOp::max_value(&sp), DenseMat::max_value(&x), "m={m} max");
            let mean = x.mean();
            assert!(
                (SymOp::mean_value(&sp) - mean).abs() <= 1e-12 * (1.0 + mean.abs()),
                "m={m} mean"
            );
        }
    }

    /// The mirrored (strided) row walk of the sampled product agrees
    /// with the dense operator, including repeated and edge-tile rows.
    #[test]
    fn sampled_apply_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(7);
        let m = 45;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, 5, &mut rng);
        let samples = vec![0usize, 13, 13, 31, 44, 7];
        let w = vec![0.5, 1.0, 2.0, 0.25, 1.5, 0.75];
        let want = SymOp::sampled_apply(&x, &f, &samples, &w);
        for block in [8usize, 16, 64] {
            let sp = SymPacked::from_dense_with_block(&x, block);
            let mut got = DenseMat::zeros(m, 5);
            got.fill(-9.0); // stale data must be overwritten
            SymOp::sampled_apply_into(&sp, &f, &samples, &w, &mut got);
            let err = got.diff_fro(&want);
            assert!(err < 1e-12 * (1.0 + want.fro_norm()), "block={block}: err={err}");
        }
    }

    /// The streaming CSR construction is bitwise-identical to the
    /// densifying oracle — packed data, offsets, and all three cached
    /// aggregate statistics — across block sizes, densities, and an
    /// asymmetric input (upper-wins semantics).
    #[test]
    fn from_csr_streamed_matches_densifying_path_bitwise() {
        let mut rng = Pcg64::seed_from_u64(41);
        for (n, density) in [(1usize, 1.0), (7, 0.5), (45, 0.3), (90, 0.05)] {
            let mut trips = Vec::new();
            for i in 0..n {
                for j in i..n {
                    if rng.uniform() < density {
                        let v = rng.gaussian();
                        trips.push((i, j, v));
                        if i != j {
                            trips.push((j, i, v));
                        }
                    }
                }
            }
            // a few asymmetric strays: lower-only entries must vanish,
            // upper-only entries must win and mirror into diagonal tiles
            if n > 10 {
                trips.push((n - 1, 0, 7.5)); // lower-only → dropped
                trips.push((2, 3, -4.25)); // upper-only inside a tile
            }
            let sp = CsrMat::from_coo(n, n, trips);
            for block in [4usize, 8, 32, 256] {
                let streamed = SymPacked::from_csr_with_block(&sp, block);
                let oracle = SymPacked::from_dense_with_block(&sp.to_dense(), block);
                assert_eq!(streamed.block_off, oracle.block_off, "n={n} block={block}");
                assert_eq!(
                    streamed.data.len(),
                    oracle.data.len(),
                    "n={n} block={block}"
                );
                for (i, (a, b)) in streamed.data.iter().zip(&oracle.data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "n={n} block={block}: packed element {i}"
                    );
                }
                assert_eq!(streamed.fro_sq.to_bits(), oracle.fro_sq.to_bits());
                assert_eq!(streamed.max.to_bits(), oracle.max.to_bits());
                assert_eq!(streamed.mean.to_bits(), oracle.mean.to_bits());
            }
        }
        // the production entry (default block) routes through the stream
        let sp = CsrMat::from_coo(3, 3, vec![(0, 1, 2.0), (1, 0, 2.0), (2, 2, 1.0)]);
        let a = SymPacked::from_csr(&sp);
        let b = SymPacked::from_csr_via_dense(&sp);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// Large-sparse smoke: a matrix whose square array would be ~69 MB
    /// streams into the packed triangle directly, and the operator
    /// agrees with the sparse SpMM.
    #[test]
    fn from_csr_streamed_large_sparse_smoke() {
        let m = 3000;
        let mut rng = Pcg64::seed_from_u64(43);
        let mut trips = Vec::new();
        for _ in 0..6 * m {
            let i = rng.below(m);
            let j = rng.below(m);
            let v = 1.0 + rng.uniform();
            trips.push((i, j, v));
            if i != j {
                trips.push((j, i, v));
            }
        }
        for i in 0..m {
            trips.push((i, i, 2.0)); // keep the diagonal populated
        }
        let sp = CsrMat::from_coo(m, m, trips);
        let packed = SymPacked::from_csr(&sp);
        assert!(
            packed.packed_len() < m * m * 3 / 5,
            "packed triangle must stay well under the square array"
        );
        let fro_sp = CsrMat::fro_norm_sq(&sp);
        let fro_pk = SymOp::fro_norm_sq(&packed);
        assert!(
            (fro_sp - fro_pk).abs() <= 1e-9 * (1.0 + fro_sp),
            "fro {fro_sp} vs {fro_pk}"
        );
        let f = DenseMat::gaussian(m, 3, &mut rng);
        let want = sp.spmm(&f);
        let got = SymOp::apply(&packed, &f);
        let err = got.diff_fro(&want);
        assert!(err < 1e-10 * (1.0 + want.fro_norm()), "err={err}");
    }

    /// Construction from CSR matches construction from the densified
    /// matrix (and the production from_dense block size).
    #[test]
    fn from_csr_matches_dense_path() {
        let mut rng = Pcg64::seed_from_u64(8);
        let n = 40;
        let mut trips = Vec::new();
        for i in 0..n {
            for j in i..n {
                if rng.uniform() < 0.3 {
                    let v = rng.uniform();
                    trips.push((i, j, v));
                    if i != j {
                        trips.push((j, i, v));
                    }
                }
            }
        }
        let sp_mat = CsrMat::from_coo(n, n, trips);
        let packed = SymPacked::from_csr(&sp_mat);
        let dense = sp_mat.to_dense();
        let f = DenseMat::gaussian(n, 4, &mut rng);
        let got = SymOp::apply(&packed, &f);
        let want = sp_mat.apply(&f);
        assert!(got.diff_fro(&want) < 1e-12 * (1.0 + want.fro_norm()));
        assert!((SymOp::fro_norm_sq(&packed) - SymOp::fro_norm_sq(&dense)).abs() < 1e-12);
    }

    /// When X[i,j] ≠ X[j,i], the upper triangle wins everywhere —
    /// including inside diagonal tiles.
    #[test]
    fn upper_triangle_wins_on_asymmetric_input() {
        let x = DenseMat::from_fn(5, 5, |i, j| (10 * i + j) as f64);
        let sp = SymPacked::from_dense_with_block(&x, 2);
        let d = sp.to_dense();
        for i in 0..5 {
            for j in 0..5 {
                let (a, b) = if i <= j { (i, j) } else { (j, i) };
                assert_eq!(d.at(i, j), (10 * a + b) as f64, "({i},{j})");
            }
        }
    }
}
