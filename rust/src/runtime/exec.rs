//! Typed entry points over the PJRT runtime, including [`PjrtSymOp`]:
//! a dense symmetric operator whose X·F product executes the AOT-compiled
//! Pallas matmul kernel when an artifact matches the shape, falling back
//! to the native blocked kernel otherwise (logged once per shape).
//!
//! This is the piece that closes the three-layer loop: L3 SymNMF
//! iterations call `apply_into`, which runs HLO lowered from the L2 JAX
//! model calling the L1 Pallas kernels. The operator participates in the
//! zero-allocation dispatch protocol of [`SymOp`]: the m×m input literal
//! is converted once and cached, and the skinny-factor f32 staging buffer
//! is reused across every call of a solve. [`PjrtSymOp::solve`] drives a
//! method's resumable engine ([`crate::symnmf::engine`]) directly over
//! the operator — deadlines, pause/resume, and per-iteration telemetry
//! on the accelerator path.

use crate::coordinator::driver::Method;
use crate::linalg::{blas, DenseMat};
use crate::randnla::SymOp;
use crate::runtime::backend as xla;
use crate::runtime::pjrt::{literal_from_mat_buffered, Input, PjrtRuntime};
use crate::symnmf::engine::{Checkpoint, EngineRun, RunControl};
use crate::symnmf::options::SymNmfOptions;
use std::cell::RefCell;
use std::collections::HashSet;
use std::rc::Rc;

/// Dense symmetric operator backed by PJRT `products_*` artifacts.
pub struct PjrtSymOp {
    x: DenseMat,
    /// pre-converted f32 literal of X, built once (8·m² bytes saved per call)
    x_lit: RefCell<Option<xla::Literal>>,
    /// reusable f32 staging buffer for the skinny factor F (host-buffer
    /// reuse across calls — no per-product conversion allocation)
    f_scratch: RefCell<Vec<f32>>,
    runtime: Rc<PjrtRuntime>,
    /// count of PJRT-dispatched / native-fallback applies (diagnostics)
    pub stats: RefCell<DispatchStats>,
    warned: RefCell<HashSet<usize>>,
}

#[derive(Default, Debug, Clone)]
pub struct DispatchStats {
    pub pjrt_calls: usize,
    pub native_calls: usize,
}

impl PjrtSymOp {
    pub fn new(x: DenseMat, runtime: Rc<PjrtRuntime>) -> PjrtSymOp {
        assert_eq!(x.rows(), x.cols(), "PjrtSymOp needs a square matrix");
        PjrtSymOp {
            x,
            x_lit: RefCell::new(None),
            f_scratch: RefCell::new(Vec::new()),
            runtime,
            stats: RefCell::new(DispatchStats::default()),
            warned: RefCell::new(HashSet::new()),
        }
    }

    pub fn inner(&self) -> &DenseMat {
        &self.x
    }

    /// The (X·F, FᵀF) pair through PJRT if possible: Some((xf, gram)) on
    /// the PJRT path, None if no artifact matches this width.
    pub fn products_pjrt(&self, f: &DenseMat) -> Option<(DenseMat, DenseMat)> {
        let m = self.x.rows();
        let k = f.cols();
        let spec = self.runtime.registry.find("products", &[("m", m), ("k", k)])?;
        // lazily build + cache the X literal
        if self.x_lit.borrow().is_none() {
            let mut scratch = Vec::new();
            match literal_from_mat_buffered(&self.x, &mut scratch) {
                Ok(lit) => *self.x_lit.borrow_mut() = Some(lit),
                Err(e) => {
                    eprintln!("[runtime] literal conversion failed ({e:#})");
                    return None;
                }
            }
        }
        let f_lit = {
            let mut scratch = self.f_scratch.borrow_mut();
            literal_from_mat_buffered(f, &mut scratch).ok()?
        };
        let guard = self.x_lit.borrow();
        let x_lit = guard.as_ref().expect("cached above");
        let result = self.runtime.execute_literals(spec, &[x_lit, &f_lit]);
        match result {
            Ok(mut outs) => {
                let gram = outs.pop()?;
                let xf = outs.pop()?;
                self.stats.borrow_mut().pjrt_calls += 1;
                Some((xf, gram))
            }
            Err(e) => {
                eprintln!("[runtime] PJRT execute failed ({e:#}); using native kernel");
                None
            }
        }
    }

    /// Drive a SymNMF method's engine directly over this operator: every
    /// X·F product of the solve dispatches through the PJRT artifact
    /// path (with native fallback), and the run carries the full engine
    /// contract — deadline stopping, cooperative pausing, checkpoint
    /// resume. This is the request-scoped serving shape: a traffic
    /// handler can run with a per-request deadline, ship the checkpoint,
    /// and resume on the next request.
    pub fn solve(
        &self,
        method: Method,
        opts: &SymNmfOptions,
        ctrl: &RunControl,
        resume: Option<&Checkpoint>,
    ) -> EngineRun {
        method.run_controlled(self, opts, ctrl, resume)
    }

    fn warn_fallback(&self, k: usize) {
        if self.warned.borrow_mut().insert(k) {
            eprintln!(
                "[runtime] no products_m{}_k{k} artifact; native fallback for this width",
                self.x.rows(),
            );
        }
    }
}

impl SymOp for PjrtSymOp {
    fn dim(&self) -> usize {
        self.x.rows()
    }

    fn apply_into(&self, f: &DenseMat, out: &mut DenseMat) {
        if let Some((xf, _gram)) = self.products_pjrt(f) {
            out.copy_from(&xf);
            return;
        }
        self.warn_fallback(f.cols());
        self.stats.borrow_mut().native_calls += 1;
        blas::symm_tall_into(&self.x, f, out);
    }

    /// Allocating override: on the PJRT path the execute boundary already
    /// materializes the result, so return it directly (no extra copy).
    fn apply(&self, f: &DenseMat) -> DenseMat {
        if let Some((xf, _gram)) = self.products_pjrt(f) {
            return xf;
        }
        self.warn_fallback(f.cols());
        self.stats.borrow_mut().native_calls += 1;
        SymOp::apply(&self.x, f)
    }

    fn fro_norm_sq(&self) -> f64 {
        DenseMat::fro_norm_sq(&self.x)
    }

    fn max_value(&self) -> f64 {
        DenseMat::max_value(&self.x)
    }

    fn mean_value(&self) -> f64 {
        self.x.mean()
    }

    fn sampled_apply_into(
        &self,
        f: &DenseMat,
        samples: &[usize],
        weights_sq: &[f64],
        out: &mut DenseMat,
    ) {
        SymOp::sampled_apply_into(&self.x, f, samples, weights_sq, out);
    }
}

/// Execute the `lai_products` artifact: (U·(Vᵀ·F), FᵀF). Returns None if
/// no artifact matches (caller falls back to native skinny matmuls).
pub fn lai_products_pjrt(
    runtime: &PjrtRuntime,
    u: &DenseMat,
    v: &DenseMat,
    f: &DenseMat,
) -> Option<(DenseMat, DenseMat)> {
    let (m, l) = u.shape();
    let k = f.cols();
    let spec = runtime
        .registry
        .find("lai_products", &[("m", m), ("l", l), ("k", k)])?;
    let outs = runtime
        .execute(spec, &[Input::Mat(u), Input::Mat(v), Input::Mat(f)])
        .ok()?;
    let mut it = outs.into_iter();
    let y = it.next()?;
    let g = it.next()?;
    Some((y, g))
}

/// Execute the `hals_sweep` artifact: fused regularized HALS column sweep
/// (paper Eq. 2.6) on the PJRT path. Returns the updated W, or None if no
/// artifact matches.
pub fn hals_sweep_pjrt(
    runtime: &PjrtRuntime,
    xh: &DenseMat,
    g: &DenseMat,
    w: &DenseMat,
    h: &DenseMat,
    alpha: f64,
) -> Option<DenseMat> {
    let (m, k) = w.shape();
    let spec = runtime.registry.find("hals_sweep", &[("m", m), ("k", k)])?;
    let outs = runtime
        .execute(
            spec,
            &[
                Input::Mat(xh),
                Input::Mat(g),
                Input::Mat(w),
                Input::Mat(h),
                Input::Scalar(alpha),
            ],
        )
        .ok()?;
    outs.into_iter().next()
}
