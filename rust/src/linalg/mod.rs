//! Dense numerical linear algebra substrate (f64, row-major).
//!
//! Everything the paper's algorithms need is implemented here from
//! scratch: blocked matmul/Gram kernels ([`blas`]), Cholesky factorization
//! and triangular solves ([`chol`]), CholeskyQR + Householder QR and row
//! leverage scores ([`qr`]), and a cyclic-Jacobi symmetric eigensolver
//! ([`eig`]) used by Apx-EVD (paper Alg. Apx-EVD line 5).

pub mod blas;
pub mod chol;
pub mod dense;
pub mod eig;
pub mod qr;

pub use dense::DenseMat;
