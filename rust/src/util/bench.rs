//! Micro-benchmark timing harness (criterion is unavailable offline):
//! warmup + repeated timing, reporting min / median / mean. Used by the
//! `cargo bench` targets (all `harness = false`).

use std::time::Instant;

/// Timing summary in seconds.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub reps: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} min {:>10.6}s  median {:>10.6}s  mean {:>10.6}s  (n={})",
            self.name, self.min, self.median, self.mean, self.reps
        )
    }
}

/// Run `f` `warmup` times unmeasured, then `reps` times measured.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> BenchResult {
    assert!(reps >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / reps as f64;
    BenchResult { name: name.to_string(), reps, min, median, mean }
}

/// Pretty GF/s for a flop count and seconds.
pub fn gflops(flops: f64, secs: f64) -> f64 {
    flops / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let mut i = 0u64;
        let r = bench("noop", 2, 9, || {
            i = i.wrapping_add(1);
            std::hint::black_box(i);
        });
        assert!(r.min <= r.median);
        assert!(r.median <= r.mean * 3.0 + 1e-9);
        assert_eq!(r.reps, 9);
        assert!(r.report().contains("noop"));
    }
}
